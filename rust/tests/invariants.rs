//! Property-based invariant tests (hand-rolled harness in
//! `odimo::util::prop` — proptest is not in the offline crate cache).
//!
//! These cover the coordinator-adjacent pure logic: discretization,
//! one-hot construction, Eq. 6 contiguity, the Fig. 4 reorg pass, the
//! simulators (on every registered platform, including the tri-CU one),
//! Pareto extraction, and dataset determinism.

use odimo::datasets::rng::Rng;
use odimo::datasets::{Split, SynthDataset};
use odimo::mapping::{discretize, expected_counts, one_hot_theta, reorganize, SearchKind};
use odimo::pareto::{is_pareto, pareto_front, Point};
use odimo::soc::{analytical, detailed, Layer, LayerAssignment, LayerType, Mapping, Platform};
use odimo::util::prop::{check, gen};

fn platforms() -> [Platform; 3] {
    [Platform::diana(), Platform::darkside(), Platform::trident()]
}

fn rand_platform(rng: &mut Rng) -> Platform {
    platforms()[rng.below(3)]
}

fn rand_layer(rng: &mut Rng, name: &str) -> Layer {
    let hw = [4usize, 8, 16, 32][rng.below(4)];
    Layer {
        name: name.to_string(),
        ltype: LayerType::Conv,
        cin: gen::usize_in(rng, 1, 64),
        cout: gen::usize_in(rng, 1, 64),
        k: [1usize, 3, 5][rng.below(3)],
        ox: hw,
        oy: hw,
        stride: 1,
        searchable: true,
    }
}

fn rand_mapping(rng: &mut Rng, layers: &[Layer], platform: Platform) -> Mapping {
    Mapping {
        platform,
        layers: layers
            .iter()
            .map(|l| LayerAssignment {
                layer: l.name.clone(),
                cu_of: gen::cu_vec_n(rng, l.cout, platform.n_cus()),
            })
            .collect(),
    }
}

// ---------------------------------------------------------------------------
// mapping / θ invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_discretize_partitions_channels() {
    for n_cus in [2usize, 3] {
        check(
            200,
            |r| {
                let c = gen::usize_in(r, 1, 96);
                (c, gen::f32_vec(r, n_cus * c, -3.0, 3.0))
            },
            |(c, theta)| {
                let a = discretize(SearchKind::Channel, theta, *c, n_cus, "l");
                a.cu_of.len() == *c
                    && a.counts(n_cus).iter().sum::<usize>() == *c
                    && a.cu_of.iter().all(|&cu| (cu as usize) < n_cus)
            },
        );
    }
}

#[test]
fn prop_one_hot_roundtrips_channel() {
    for n_cus in [2usize, 3] {
        check(
            200,
            |r| {
                let c = gen::usize_in(r, 1, 64);
                (c, gen::f32_vec(r, n_cus * c, -2.0, 2.0))
            },
            |(c, theta)| {
                let a = discretize(SearchKind::Channel, theta, *c, n_cus, "l");
                let oh = one_hot_theta(SearchKind::Channel, &a, n_cus);
                discretize(SearchKind::Channel, &oh, *c, n_cus, "l") == a
            },
        );
    }
}

#[test]
fn prop_split_always_contiguous() {
    check(
        200,
        |r| {
            let c = gen::usize_in(r, 1, 128);
            (c, gen::f32_vec(r, c + 1, -4.0, 4.0))
        },
        |(c, theta)| {
            let a = discretize(SearchKind::Split, theta, *c, 2, "l");
            a.is_contiguous()
                && one_hot_theta(SearchKind::Split, &a, 2).len() == c + 1
                && discretize(
                    SearchKind::Split,
                    &one_hot_theta(SearchKind::Split, &a, 2),
                    *c,
                    2,
                    "l",
                ) == a
        },
    );
}

#[test]
fn prop_expected_counts_sum_to_cout() {
    for n_cus in [2usize, 3] {
        for kind in [SearchKind::Channel, SearchKind::Split, SearchKind::Layerwise] {
            if kind == SearchKind::Split && n_cus != 2 {
                continue;
            }
            check(
                100,
                |r| {
                    let c = gen::usize_in(r, 1, 64);
                    (c, gen::f32_vec(r, kind.theta_len(c, n_cus), -3.0, 3.0))
                },
                |(c, theta)| {
                    let n = expected_counts(kind, theta, *c, n_cus);
                    n.iter().all(|&x| x >= -1e-6)
                        && (n.iter().sum::<f64>() - *c as f64).abs() < 1e-6
                        && n.len() == kind.columns(n_cus)
                },
            );
        }
    }
}

#[test]
fn prop_reorg_preserves_function() {
    for platform in platforms() {
        let n_cus = platform.n_cus();
        check(
            200,
            |r| {
                let c = gen::usize_in(r, 1, 96);
                gen::cu_vec_n(r, c, n_cus)
            },
            |cu_of| {
                let a = LayerAssignment {
                    layer: "l".into(),
                    cu_of: cu_of.clone(),
                };
                let m = Mapping {
                    platform,
                    layers: vec![a.clone()],
                };
                let r = reorganize(&m);
                let lr = &r.layers[0];
                // valid permutation, contiguous result, counts preserved,
                // sub-layers tile [0, C) in ascending CU order
                let after = lr.reorganized_assignment(&a);
                let covered: usize = lr.sub_layers.iter().map(|s| s.end - s.start).sum();
                let ascending = lr.sub_layers.windows(2).all(|w| w[0].cu < w[1].cu);
                lr.is_valid_permutation()
                    && after.is_contiguous()
                    && (0..n_cus as u8).all(|cu| after.count(cu) == a.count(cu))
                    && covered == cu_of.len()
                    && ascending
            },
        );
    }
}

// ---------------------------------------------------------------------------
// simulator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_cu_cycles_monotone_in_channels() {
    check(
        100,
        |r| (rand_layer(r, "l"), gen::usize_in(r, 1, 63), r.below(3)),
        |(layer, n, pi)| {
            platforms()[*pi].cus().iter().all(|cu| {
                analytical::cu_cycles(cu, layer, *n) <= analytical::cu_cycles(cu, layer, n + 1)
            })
        },
    );
}

#[test]
fn prop_detailed_never_below_analytical() {
    check(
        100,
        |r| {
            let layers: Vec<Layer> = (0..gen::usize_in(r, 1, 6))
                .map(|i| rand_layer(r, &format!("l{i}")))
                .collect();
            let platform = rand_platform(r);
            let m = rand_mapping(r, &layers, platform);
            (layers, m)
        },
        |(layers, m)| {
            let a = analytical::execute(layers, m, &[]);
            let d = detailed::execute(layers, m, &[]);
            d.total_cycles >= a.total_cycles && d.energy_uj >= 0.0
        },
    );
}

#[test]
fn prop_energy_has_idle_floor() {
    check(
        100,
        |r| {
            let platform = rand_platform(r);
            let layers = vec![rand_layer(r, "l")];
            let m = rand_mapping(r, &layers, platform);
            (layers, m)
        },
        |(layers, m)| {
            let rep = analytical::execute(layers, m, &[]);
            let (_, p_idle, freq) = analytical::power(m.platform);
            let idle_floor = p_idle * rep.total_cycles as f64 / freq * 1e-3;
            rep.energy_uj >= idle_floor - 1e-9
        },
    );
}

#[test]
fn prop_utilization_bounded() {
    check(
        100,
        |r| {
            let platform = rand_platform(r);
            let layers: Vec<Layer> = (0..gen::usize_in(r, 1, 5))
                .map(|i| rand_layer(r, &format!("l{i}")))
                .collect();
            let m = rand_mapping(r, &layers, platform);
            (layers, m)
        },
        |(layers, m)| {
            let d = detailed::execute(layers, m, &[]);
            d.utilization.len() == m.platform.n_cus()
                && d.utilization.iter().all(|&u| (0.0..=1.0 + 1e-9).contains(&u))
        },
    );
}

#[test]
fn prop_sim_deterministic() {
    check(
        50,
        |r| {
            let platform = rand_platform(r);
            let layers = vec![rand_layer(r, "a"), rand_layer(r, "b")];
            let m = rand_mapping(r, &layers, platform);
            (layers, m)
        },
        |(layers, m)| {
            let d1 = detailed::execute(layers, m, &[]);
            let d2 = detailed::execute(layers, m, &[]);
            d1.total_cycles == d2.total_cycles && d1.energy_uj == d2.energy_uj
        },
    );
}

#[test]
fn prop_channel_fractions_partition_unity() {
    check(
        60,
        |r| {
            let platform = rand_platform(r);
            let layers = vec![rand_layer(r, "a")];
            let m = rand_mapping(r, &layers, platform);
            (layers, m)
        },
        |(layers, m)| {
            let rep = analytical::execute(layers, m, &[]);
            let k = m.platform.n_cus();
            let total: f64 = (0..k).map(|c| rep.channel_fraction(c)).sum();
            let off = rep.offload_channel_fraction();
            (total - 1.0).abs() < 1e-9 && (off - (1.0 - rep.channel_fraction(0))).abs() < 1e-9
        },
    );
}

// ---------------------------------------------------------------------------
// pareto invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_pareto_front_is_antichain_and_complete() {
    check(
        200,
        |r| {
            let n = gen::usize_in(r, 1, 40);
            (0..n)
                .map(|_| Point {
                    cost: r.uniform(0.0, 100.0) as f64,
                    acc: r.uniform(0.0, 1.0) as f64,
                })
                .collect::<Vec<_>>()
        },
        |pts| {
            let front = pareto_front(pts);
            // every front point is non-dominated
            let all_pareto = front.iter().all(|&i| is_pareto(&pts[i], pts));
            // every non-front point is dominated by some front point
            let complete = (0..pts.len()).all(|i| {
                front.contains(&i)
                    || front.iter().any(|&j| pts[j].dominates(&pts[i]))
                    // duplicates of a front point are dropped but not dominated
                    || front.iter().any(|&j| pts[j] == pts[i])
            });
            all_pareto && complete
        },
    );
}

// ---------------------------------------------------------------------------
// dataset invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_dataset_deterministic_and_seed_sensitive() {
    check(
        20,
        |r| (r.next_u64() % 1000, gen::usize_in(r, 2, 50)),
        |&(seed, classes)| {
            let d1 = SynthDataset::new(8, classes, 1.0, seed);
            let d2 = SynthDataset::new(8, classes, 1.0, seed);
            let d3 = SynthDataset::new(8, classes, 1.0, seed + 1);
            let (x1, y1) = d1.batch(Split::Train, 0, 4);
            let (x2, y2) = d2.batch(Split::Train, 0, 4);
            let (x3, _) = d3.batch(Split::Train, 0, 4);
            x1 == x2 && y1 == y2 && x1 != x3
        },
    );
}

#[test]
fn prop_labels_in_range() {
    check(
        30,
        |r| {
            let classes = gen::usize_in(r, 2, 100);
            let d = SynthDataset::new(8, classes, 1.0, r.next_u64());
            let (_, y) = d.batch(Split::Val, 7, 32);
            (classes, y)
        },
        |(classes, y)| y.iter().all(|&l| (l as usize) < *classes && l >= 0),
    );
}
