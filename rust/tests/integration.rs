//! Integration tests over real AOT artifacts: the full runtime →
//! coordinator → simulator path. Skipped (with a notice) when
//! `make artifacts` has not been run.
//!
//! Structured as two umbrella tests (one per platform) so each variant's
//! four executables are XLA-compiled once and shared across sub-checks —
//! PJRT handles are not `Send`, so a lazy global is not an option.

use odimo::config::ExperimentConfig;
use odimo::coordinator::{baselines, run_baseline, Baseline, Trainer};
use odimo::datasets::Split;
use odimo::mapping::SearchKind;
use odimo::runtime::{BackendKind, ModelBackend, StepHparams};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = odimo::repo_root().join("artifacts");
    if dir.join("diana_resnet20_c10.manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        None
    }
}

fn trainer(variant: &str) -> Option<Trainer> {
    let dir = artifacts_dir()?;
    let mut cfg = ExperimentConfig::for_variant(variant);
    cfg.steps_per_epoch = 4;
    cfg.eval_batches = 2;
    Some(Trainer::create(&dir, cfg, Some(BackendKind::Xla)).expect("trainer loads"))
}

fn hp(lam: f32, lr_th: f32) -> StepHparams {
    StepHparams {
        lam,
        cost_sel: 0.0,
        lr_w: 1e-2,
        lr_th,
    }
}

#[test]
fn diana_suite() {
    let Some(tr) = trainer("diana_resnet20_c10") else {
        return;
    };

    // -- end-to-end: step, eval, cost report ---------------------------------
    let mut state = tr.init_state().expect("init");
    let m = tr.run_epoch(&mut state, hp(0.0, 0.0), 0).expect("epoch");
    assert!(m.loss.is_finite() && m.loss > 0.0);
    assert!((0.0..=1.0).contains(&m.acc));
    assert!(m.cost_lat > 0.0 && m.cost_energy > 0.0);
    let (acc, loss) = tr.evaluate(&state, Split::Val).expect("eval");
    assert!((0.0..=1.0).contains(&acc));
    assert!(loss.is_finite());
    let (mat, totals) = tr.backend.cost_report(&state).expect("cost");
    assert_eq!(mat.len(), tr.manifest().layers.len() * 4);
    assert!(totals[0] > 0.0 && totals[1] > 0.0);

    // -- eval determinism ------------------------------------------------------
    let (a1, l1) = tr.evaluate(&state, Split::Test).expect("eval");
    let (a2, l2) = tr.evaluate(&state, Split::Test).expect("eval");
    assert_eq!(a1, a2);
    assert_eq!(l1, l2);

    // -- θ freeze roundtrip + drift-free frozen phases ------------------------
    let mapping = tr.discretize_all(&state).expect("discretize");
    assert_eq!(mapping.layers.len(), tr.manifest().layers.len());
    tr.freeze_mapping(&mut state, &mapping).expect("freeze");
    let mapping2 = tr.discretize_all(&state).expect("discretize again");
    for (a, b) in mapping.layers.iter().zip(&mapping2.layers) {
        assert_eq!(a, b, "discretize(freeze(m)) != m at {}", a.layer);
    }
    tr.run_epoch(&mut state, hp(0.0, 0.0), 1).expect("epoch");
    let mapping3 = tr.discretize_all(&state).expect("discretize 3");
    for (a, b) in mapping.layers.iter().zip(&mapping3.layers) {
        assert_eq!(a, b, "θ drifted during frozen phase at {}", a.layer);
    }

    // -- search moves θ ---------------------------------------------------------
    let mut state = tr.init_state().expect("init");
    let before = tr.theta_of(&state, "stem").expect("theta");
    for e in 0..2 {
        tr.run_epoch(&mut state, hp(5e-5, 0.05), e).expect("epoch");
    }
    let after = tr.theta_of(&state, "stem").expect("theta");
    assert_ne!(before, after, "θ did not move during search");

    // -- strong λ finds a cheaper-than-all-digital mapping ---------------------
    let lam = (50.0 / tr.manifest().cost_scale.latency_cycles) as f32;
    for e in 2..6 {
        tr.run_epoch(&mut state, hp(lam, 0.2), e).expect("epoch");
    }
    let mapping = tr.discretize_all(&state).expect("discretize");
    let (ana, _) = tr.simulate(&mapping);
    let all0 = baselines::baseline_mapping(&tr, Baseline::AllOn(0));
    let (ana0, _) = tr.simulate(&all0);
    assert!(
        ana.total_cycles < ana0.total_cycles,
        "search under strong λ ({}) did not beat all-digital ({})",
        ana.total_cycles,
        ana0.total_cycles
    );

    // -- baselines distinct & ordered -------------------------------------------
    let m1 = baselines::baseline_mapping(&tr, Baseline::AllOn(1));
    let mio = baselines::baseline_mapping(&tr, Baseline::IoSplit);
    let mmc = baselines::baseline_mapping(&tr, Baseline::MinCost);
    let (a1r, _) = tr.simulate(&m1);
    let (amc, _) = tr.simulate(&mmc);
    assert!(a1r.total_cycles < ana0.total_cycles, "analog beats digital");
    assert!(amc.total_cycles <= ana0.total_cycles);
    assert!(amc.total_cycles <= a1r.total_cycles);
    let first = mio
        .layers
        .iter()
        .find(|l| {
            tr.manifest()
                .layers
                .iter()
                .any(|s| s.searchable && s.name == l.layer)
        })
        .unwrap();
    assert!(first.cu_of.iter().all(|&c| c == 0), "IO layer on digital");

    // -- full baseline run produces a complete record ---------------------------
    let rec = run_baseline(&tr, Baseline::AllOn(1)).expect("baseline run");
    assert_eq!(rec.label, "all-ternary");
    assert!(rec.test_acc >= 0.0);
    assert!(rec.det_cycles > rec.ana_cycles, "detailed adds overheads");
    assert!(rec.offload_frac > 0.9);
    assert_eq!(rec.util.len(), tr.platform.n_cus());
    assert_eq!(rec.per_layer.len(), tr.manifest().layers.len());
}

#[test]
fn darkside_suite() {
    let Some(tr) = trainer("darkside_mbv1_c10") else {
        return;
    };
    assert_eq!(tr.kind, SearchKind::Split);
    let mut state = tr.init_state().expect("init");
    for e in 0..2 {
        tr.run_epoch(&mut state, hp(1e-6, 0.1), e).expect("epoch");
    }
    // Eq. 6: every discretized searchable layer must be contiguous
    let mapping = tr.discretize_all(&state).expect("discretize");
    for asg in &mapping.layers {
        assert!(
            asg.is_contiguous(),
            "Eq. 6 violated: {} not contiguous: {:?}",
            asg.layer,
            asg.cu_of
        );
    }
    // deploy both sims; detailed must exceed analytical
    let (ana, det) = tr.simulate(&mapping);
    assert!(det.total_cycles > ana.total_cycles);
    // corner baselines ordered the Darkside way: all-DW is much faster
    let m0 = baselines::baseline_mapping(&tr, Baseline::AllOn(0));
    let m1 = baselines::baseline_mapping(&tr, Baseline::AllOn(1));
    let (a0, _) = tr.simulate(&m0);
    let (a1, _) = tr.simulate(&m1);
    assert!(
        a1.total_cycles * 3 < a0.total_cycles,
        "DWE mapping ({}) should be >3x faster than std-conv-on-cluster ({})",
        a1.total_cycles,
        a0.total_cycles
    );
}

#[test]
fn prune_variant_loads_and_steps() {
    let Some(tr) = trainer("diana_resnet20_c10_prune") else {
        return;
    };
    assert_eq!(tr.kind, SearchKind::Prune);
    let mut state = tr.init_state().expect("init");
    let m = tr.run_epoch(&mut state, hp(1e-6, 0.05), 0).expect("epoch");
    assert!(m.loss.is_finite());
    let mapping = tr.discretize_all(&state).expect("discretize");
    // pruned-geometry simulation must not exceed the unpruned all-digital net
    let (ana, _) = tr.simulate(&mapping);
    let all_keep = baselines::baseline_mapping(&tr, Baseline::AllOn(0));
    let (ana_keep, _) = tr.simulate(&all_keep);
    assert!(ana.total_cycles <= ana_keep.total_cycles);
}
