//! Planned-executor tests: the thread-count determinism matrix (now on
//! the persistent worker pool, including oversubscribed counts), the
//! arena-reuse (zero steady-state allocation) pin, the 1×1 conv
//! fast-path and fused-im2col packed-conv bit-identity pins,
//! thread-count validation, Adam convergence on a synthetic task, and
//! the natively-built `_prune`/`_layerwise` baseline search spaces.
//!
//! Packing coverage: the backend attaches a step-scoped weight-pack
//! handle to every non-depthwise conv and the FC head unconditionally,
//! so every backend-driven test below (the determinism matrix, the
//! arena pin, eval invariance, Adam) exercises the packed f32 tiers —
//! the tests that pin this assert `packing_enabled()` explicitly.
//!
//! The determinism contract under test: the intra-step shard structure
//! depends only on the batch size, every reduction runs in shard-index
//! order, and the row-sharded kernels assign each output element to
//! exactly one worker — so the *same seed must produce bit-identical
//! losses and θ at any thread count*.

use odimo::config::ExperimentConfig;
use odimo::coordinator::{sweep, Trainer};
use odimo::datasets::{Split, SynthDataset};
use odimo::mapping::SearchKind;
use odimo::runtime::{
    BackendKind, ModelBackend, NativeBackend, NativeOptions, StepHparams, TrainState, WOptimizer,
};

fn hp_default() -> StepHparams {
    StepHparams {
        lam: 1e-7,
        cost_sel: 0.0,
        lr_w: 1e-2,
        lr_th: 5e-2,
    }
}

fn build(variant: &str, threads: usize, w_optimizer: WOptimizer) -> NativeBackend {
    NativeBackend::build_with(
        variant,
        NativeOptions {
            threads,
            w_optimizer,
        },
    )
    .expect("native variant")
}

/// Run `steps` train steps on deterministic synthetic batches; returns
/// the per-step loss metric and the final state.
fn run_steps(be: &NativeBackend, seed: i32, steps: usize) -> (Vec<f32>, TrainState) {
    let m = be.manifest();
    let ds = SynthDataset::from_name(&m.dataset.name, m.dataset.hw, m.dataset.classes, 7);
    let mut state = be.init_state(seed).expect("init");
    let hp = hp_default();
    let mut losses = Vec::with_capacity(steps);
    for i in 0..steps {
        let (x, y) = ds.batch(Split::Train, i as u64, m.dataset.batch);
        let metrics = be.train_step(&mut state, &x, &y, hp).expect("step");
        losses.push(metrics[0]);
    }
    (losses, state)
}

fn theta_bits(be: &NativeBackend, state: &TrainState) -> Vec<u32> {
    be.state_specs()
        .iter()
        .enumerate()
        .filter(|(_, s)| s.name.ends_with("/theta"))
        .flat_map(|(i, _)| state.leaves[i].iter().map(|v| v.to_bits()))
        .collect()
}

/// Thread counts the matrices sweep beyond the serial reference: the
/// historical 2/4, an 8-row (tape-level lanes only engage beyond the
/// 4 shard tasks) and an oversubscribed 2×cores row — capped by the
/// pool's 4×cores validation limit, deduped, ascending.
fn matrix_threads() -> Vec<usize> {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let cap = odimo::runtime::native::max_threads();
    let mut ts: Vec<usize> = [2usize, 4, 8, 2 * cores]
        .into_iter()
        .filter(|&t| t >= 2 && t <= cap)
        .collect();
    ts.sort_unstable();
    ts.dedup();
    ts
}

/// The determinism matrix: 1/2/4/8/oversubscribed threads × {resnet8,
/// mbv1} × {diana, gap9} must produce bit-identical losses and θ after
/// 3 steps.
#[test]
fn thread_count_determinism_matrix() {
    // The backend always hands packed-weight handles to the tape, so
    // this matrix pins that the packed f32 tiers (and the fused-im2col
    // conv lowering) are lane-count invariant, not just the unpacked
    // ones.
    assert!(
        odimo::runtime::native::packing_enabled(),
        "determinism matrix must run with the packed tiers on"
    );
    for arch in ["resnet8", "mbv1"] {
        for soc in ["diana", "gap9"] {
            let variant = format!("{soc}_{arch}_tiny");
            let be1 = build(&variant, 1, WOptimizer::SgdMomentum);
            let (losses1, state1) = run_steps(&be1, 3, 3);
            let theta1 = theta_bits(&be1, &state1);
            assert!(losses1.iter().all(|l| l.is_finite()), "{variant}: {losses1:?}");
            for threads in matrix_threads() {
                let bet = build(&variant, threads, WOptimizer::SgdMomentum);
                let (losses_t, state_t) = run_steps(&bet, 3, 3);
                let theta_t = theta_bits(&bet, &state_t);
                assert_eq!(
                    losses1.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
                    losses_t.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
                    "{variant}: losses differ at {threads} threads"
                );
                assert_eq!(
                    theta1, theta_t,
                    "{variant}: θ differs at {threads} threads"
                );
                // every W leaf must match too, bit for bit
                for (a, b) in state1.leaves.iter().zip(&state_t.leaves) {
                    assert_eq!(
                        a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "{variant}: state leaf differs at {threads} threads"
                    );
                }
            }
        }
    }
}

/// Oversubscription: more pool workers than the machine has cores is
/// pure scheduling — the shard structure, lane ranges and reduction
/// order never see the thread count, so results stay bit-identical.
#[test]
fn determinism_survives_oversubscription() {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let over = 2 * cores; // > cores, within the 4x validation cap
    let be1 = build("trident_tiny_tiny", 1, WOptimizer::SgdMomentum);
    let (losses1, state1) = run_steps(&be1, 5, 3);
    let beo = build("trident_tiny_tiny", over, WOptimizer::SgdMomentum);
    let (losses_o, state_o) = run_steps(&beo, 5, 3);
    assert_eq!(
        losses1.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        losses_o.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        "losses differ at {over} threads on {cores} cores"
    );
    for (a, b) in state1.leaves.iter().zip(&state_o.leaves) {
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "state leaf differs at {over} threads"
        );
    }
}

/// Absurd worker counts are a config typo, not a request: the backend
/// rejects anything beyond 4x the available cores with a clear error.
#[test]
fn absurd_thread_count_is_rejected() {
    let cap = odimo::runtime::native::max_threads();
    let err = NativeBackend::build_with(
        "trident_tiny_tiny",
        NativeOptions {
            threads: cap + 1,
            w_optimizer: WOptimizer::SgdMomentum,
        },
    )
    .expect_err("oversubscription beyond 4x cores must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("available cores"), "{msg}");
    // the cap itself is still accepted
    assert!(NativeBackend::build_with(
        "trident_tiny_tiny",
        NativeOptions {
            threads: cap,
            w_optimizer: WOptimizer::SgdMomentum,
        },
    )
    .is_ok());
}

/// The 1×1/stride-1 conv fast path must be *bit-identical* to the
/// im2col reference lowering — forward value, input gradient and weight
/// gradient — on a fixed seed. (The patch matrix of a pointwise conv is
/// the input verbatim, so the fast path is the same arithmetic with the
/// copies removed.)
#[test]
fn conv1x1_fast_path_is_bit_identical_to_im2col() {
    use odimo::runtime::native::{Tape, Tensor};
    let (n, h, w, cin, cout) = (2usize, 5usize, 5usize, 7usize, 6usize);
    let x0: Vec<f32> = (0..n * h * w * cin)
        .map(|i| (i as f32 * 0.37).sin())
        .collect();
    let w0: Vec<f32> = (0..cout * cin).map(|i| (i as f32 * 0.23).cos()).collect();
    let run = |im2col: bool| -> (Vec<u32>, Vec<u32>, Vec<u32>) {
        let mut t = Tape::new();
        let x = t.leaf(Tensor::new(vec![n, h, w, cin], x0.clone()));
        let wv = t.leaf(Tensor::new(vec![cout, cin], w0.clone()));
        let y = if im2col {
            t.conv2d_im2col(x, wv, 1, 1)
        } else {
            t.conv2d(x, wv, 1, 1) // dispatches to the fast path
        };
        let ybits = t.val(y).data.iter().map(|v| v.to_bits()).collect();
        let loss = t.sum_all(y);
        let mut grads = t.backward(loss);
        let dx = grads.take(x).iter().map(|v| v.to_bits()).collect();
        let dw = grads.take(wv).iter().map(|v| v.to_bits()).collect();
        (ybits, dx, dw)
    };
    let (y_fast, dx_fast, dw_fast) = run(false);
    let (y_ref, dx_ref, dw_ref) = run(true);
    assert_eq!(y_fast, y_ref, "forward differs");
    assert_eq!(dx_fast, dx_ref, "input gradient differs");
    assert_eq!(dw_fast, dw_ref, "weight gradient differs");
}

/// The fused-im2col packed conv lowering (patches streamed straight
/// into A-panels, weights from the step-scoped pack cache) must be
/// *bit-identical* to the materialized `conv2d_im2col` reference —
/// forward value, input gradient and weight gradient — at both strides.
/// The panel pads are exact zeros that never enter a stored element's
/// accumulation chain, and the packed microkernels replay the unpacked
/// reduction trees, so fusion is pure data movement.
#[test]
fn fused_packed_conv_is_bit_identical_to_im2col() {
    use odimo::runtime::native::{PackHandle, Tape, Tensor, WeightPackSlot};
    use std::sync::Arc;
    assert!(odimo::runtime::native::packing_enabled());
    let (n, h, w, cin, cout, k) = (2usize, 6usize, 6usize, 5usize, 7usize, 3usize);
    let f = k * k * cin;
    let x0: Vec<f32> = (0..n * h * w * cin)
        .map(|i| (i as f32 * 0.29).sin())
        .collect();
    let w0: Vec<f32> = (0..cout * f).map(|i| (i as f32 * 0.19).cos()).collect();
    for stride in [1usize, 2] {
        let run = |pack: Option<&PackHandle>| -> (Vec<u32>, Vec<u32>, Vec<u32>) {
            let mut t = Tape::new();
            let x = t.leaf(Tensor::new(vec![n, h, w, cin], x0.clone()));
            let wv = t.leaf(Tensor::new(vec![cout, f], w0.clone()));
            let y = match pack {
                Some(_) => t.conv2d_with_pack(x, wv, k, stride, pack), // fused
                None => t.conv2d_im2col(x, wv, k, stride),             // reference
            };
            let ybits = t.val(y).data.iter().map(|v| v.to_bits()).collect();
            let loss = t.sum_all(y);
            let mut grads = t.backward(loss);
            let dx = grads.take(x).iter().map(|v| v.to_bits()).collect();
            let dw = grads.take(wv).iter().map(|v| v.to_bits()).collect();
            (ybits, dx, dw)
        };
        let slot = Arc::new(WeightPackSlot::new(cout, f));
        let handle = PackHandle::new(slot, 1, cout, f);
        let (y_fused, dx_fused, dw_fused) = run(Some(&handle));
        let (y_ref, dx_ref, dw_ref) = run(None);
        assert_eq!(y_fused, y_ref, "stride {stride}: forward differs");
        assert_eq!(dx_fused, dx_ref, "stride {stride}: input gradient differs");
        assert_eq!(dw_fused, dw_ref, "stride {stride}: weight gradient differs");
    }
}

/// Same pin for the pointwise fast path on the pack cache: a 1×1 conv
/// with a weight-pack handle runs its GEMMs on the cached mm/bt layouts
/// and must match the unpacked fast path bit for bit.
#[test]
fn pointwise_packed_conv_is_bit_identical_to_unpacked() {
    use odimo::runtime::native::{PackHandle, Tape, Tensor, WeightPackSlot};
    use std::sync::Arc;
    let (n, h, w, cin, cout) = (2usize, 5usize, 5usize, 6usize, 9usize);
    let x0: Vec<f32> = (0..n * h * w * cin)
        .map(|i| (i as f32 * 0.41).sin())
        .collect();
    let w0: Vec<f32> = (0..cout * cin).map(|i| (i as f32 * 0.13).cos()).collect();
    let run = |pack: Option<&PackHandle>| -> (Vec<u32>, Vec<u32>, Vec<u32>) {
        let mut t = Tape::new();
        let x = t.leaf(Tensor::new(vec![n, h, w, cin], x0.clone()));
        let wv = t.leaf(Tensor::new(vec![cout, cin], w0.clone()));
        let y = t.conv2d_with_pack(x, wv, 1, 1, pack);
        let ybits = t.val(y).data.iter().map(|v| v.to_bits()).collect();
        let loss = t.sum_all(y);
        let mut grads = t.backward(loss);
        let dx = grads.take(x).iter().map(|v| v.to_bits()).collect();
        let dw = grads.take(wv).iter().map(|v| v.to_bits()).collect();
        (ybits, dx, dw)
    };
    let slot = Arc::new(WeightPackSlot::new(cout, cin));
    let handle = PackHandle::new(slot, 1, cout, cin);
    let (y_p, dx_p, dw_p) = run(Some(&handle));
    let (y_u, dx_u, dw_u) = run(None);
    assert_eq!(y_p, y_u, "pointwise forward differs");
    assert_eq!(dx_p, dx_u, "pointwise input gradient differs");
    assert_eq!(dw_p, dw_u, "pointwise weight gradient differs");
}

/// The laned (channel-sharded) depthwise backward must be bit-identical
/// to the serial reference: a lone pool task gets the pool's full width
/// as kernel lanes, so a 3-wide pool drives the dw backward with 3
/// lanes, and every gradient bit must match the 1-lane tape.
#[test]
fn laned_dw_backward_matches_serial_reference() {
    use odimo::runtime::native::{Tape, Tensor, WorkerPool};
    let (n, h, w, c, k) = (2usize, 7usize, 7usize, 5usize, 3usize);
    let x0: Vec<f32> = (0..n * h * w * c).map(|i| (i as f32 * 0.31).sin()).collect();
    let w0: Vec<f32> = (0..c * k * k).map(|i| (i as f32 * 0.17).cos()).collect();
    let run = |tape: &mut Tape| -> (Vec<u32>, Vec<u32>, Vec<u32>) {
        let x = tape.leaf(Tensor::new(vec![n, h, w, c], x0.clone()));
        let wv = tape.leaf(Tensor::new(vec![c, k * k], w0.clone()));
        let y = tape.dw_conv2d(x, wv, k, 1);
        let ybits = tape.val(y).data.iter().map(|v| v.to_bits()).collect();
        let loss = tape.sum_all(y);
        let mut grads = tape.backward(loss);
        let dx = grads.take(x).iter().map(|v| v.to_bits()).collect();
        let dw = grads.take(wv).iter().map(|v| v.to_bits()).collect();
        (ybits, dx, dw)
    };
    let mut t_ref = Tape::new(); // serial scope
    let reference = run(&mut t_ref);
    let pool = WorkerPool::new(3);
    let laned = pool
        .run_tasks(1, &|_i, scope| {
            let mut t = Tape::new();
            t.set_kernel_scope(scope.clone());
            run(&mut t)
        })
        .pop()
        .expect("one task");
    assert_eq!(reference.0, laned.0, "dw forward differs under lanes");
    assert_eq!(reference.1, laned.1, "dx differs under lanes");
    assert_eq!(reference.2, laned.2, "dW differs under lanes");
}

/// Eval must be bit-identical across thread counts as well (shard sums
/// run in shard-index order).
#[test]
fn eval_is_thread_count_invariant() {
    let variant = "gap9_resnet8_tiny";
    let be1 = build(variant, 1, WOptimizer::SgdMomentum);
    let m = be1.manifest();
    let ds = SynthDataset::from_name(&m.dataset.name, m.dataset.hw, m.dataset.classes, 9);
    let (x, y) = ds.batch(Split::Val, 0, m.dataset.batch);
    let state = be1.init_state(1).expect("init");
    let r1 = be1.eval_batch(&state, &x, &y).expect("eval");
    for threads in matrix_threads() {
        let bet = build(variant, threads, WOptimizer::SgdMomentum);
        let rt = bet.eval_batch(&state, &x, &y).expect("eval");
        assert_eq!(
            r1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            rt.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "eval differs at {threads} threads"
        );
    }
}

/// The arena pin: after the first step, steady-state steps perform no
/// arena growth — every buffer of step t+1 is recycled from step t.
#[test]
fn steady_state_steps_do_not_grow_the_arena() {
    // Packing on: the fused A-panels, pack-scratch buffers and the
    // weight-pack cache must all be either plan-sized (arena) or
    // step-scoped slot reuse — steady-state steps allocate nothing.
    assert!(
        odimo::runtime::native::packing_enabled(),
        "arena pin must cover the packed-tier scratch sizing"
    );
    let be = build("trident_tiny_tiny", 2, WOptimizer::SgdMomentum);
    assert!(be.planned_elems() > 0, "the planning pass must size something");
    let m = be.manifest();
    let ds = SynthDataset::from_name(&m.dataset.name, m.dataset.hw, m.dataset.classes, 11);
    let mut state = be.init_state(0).expect("init");
    let hp = hp_default();
    let (x, y) = ds.batch(Split::Train, 0, m.dataset.batch);
    be.train_step(&mut state, &x, &y, hp).expect("step");
    be.eval_batch(&state, &x, &y).expect("eval");
    let after_warm = be.arena_grown();
    eprintln!(
        "  arena: planned {} elems, first-step growth {} buffers",
        be.planned_elems(),
        after_warm
    );
    for i in 1..4 {
        let (x, y) = ds.batch(Split::Train, i, m.dataset.batch);
        be.train_step(&mut state, &x, &y, hp).expect("step");
        be.eval_batch(&state, &x, &y).expect("eval");
    }
    assert_eq!(
        be.arena_grown(),
        after_warm,
        "steady-state train/eval steps must not allocate"
    );
}

/// Adam satellite: the native optimizer converges on the synthetic task
/// (fixed-precision net, no θ) and carries its m/v/t state leaves.
#[test]
fn adam_converges_on_synthetic_task() {
    let be = build("diana_tiny_tiny_fixed", 2, WOptimizer::Adam);
    assert_eq!(be.manifest().w_optimizer, "adam");
    let names: Vec<&str> = be.state_specs().iter().map(|s| s.name.as_str()).collect();
    assert!(names.contains(&"opt_w/t"), "{names:?}");
    assert!(names.iter().any(|n| n.ends_with("/w/m")));
    assert!(names.iter().any(|n| n.ends_with("/w/v")));

    let m = be.manifest();
    let ds = SynthDataset::from_name(&m.dataset.name, m.dataset.hw, m.dataset.classes, 13);
    let mut state = be.init_state(0).expect("init");
    let hp = StepHparams {
        lam: 0.0,
        cost_sel: 0.0,
        lr_w: 2e-3,
        lr_th: 0.0,
    };
    let mut first = 0.0f64;
    let mut last = 0.0f64;
    const EPOCHS: usize = 8;
    const STEPS: usize = 6;
    for e in 0..EPOCHS {
        let mut mean = 0.0f64;
        for i in 0..STEPS {
            let (x, y) = ds.batch(Split::Train, (e * STEPS + i) as u64, m.dataset.batch);
            let metrics = be.train_step(&mut state, &x, &y, hp).expect("step");
            assert!(metrics[0].is_finite());
            mean += metrics[0] as f64 / STEPS as f64;
        }
        if e == 0 {
            first = mean;
        }
        last = mean;
    }
    let t_idx = names.iter().position(|n| *n == "opt_w/t").unwrap();
    assert_eq!(
        state.leaves[t_idx][0] as usize,
        EPOCHS * STEPS,
        "adam step counter must advance once per step"
    );
    assert!(
        last < 0.9 * first,
        "adam failed to converge: first-epoch loss {first:.4}, last {last:.4}"
    );
}

/// Same seed, same schedule: Adam is deterministic across thread counts
/// too (the update runs once, on the tree-reduced gradients).
#[test]
fn adam_is_thread_count_invariant() {
    let run = |threads: usize| {
        let be = build("diana_tiny_tiny_fixed", threads, WOptimizer::Adam);
        run_steps(&be, 21, 3)
    };
    let (l1, s1) = run(1);
    let (l4, s4) = run(4);
    assert_eq!(
        l1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        l4.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );
    for (a, b) in s1.leaves.iter().zip(&s4.leaves) {
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }
}

// ---------------------------------------------------------------------------
// native prune / layerwise baseline search spaces
// ---------------------------------------------------------------------------

fn tiny_trainer(variant: &str, seed: i32) -> Trainer {
    let mut cfg = ExperimentConfig::for_variant(variant);
    cfg.warmup_epochs = 1;
    cfg.search_epochs = 1;
    cfg.final_epochs = 1;
    cfg.steps_per_epoch = 2;
    cfg.eval_batches = 1;
    cfg.lambdas = vec![0.1, 1.0];
    cfg.seed = seed;
    cfg.threads = 2;
    Trainer::create(
        &odimo::repo_root().join("artifacts"),
        cfg,
        Some(BackendKind::Native),
    )
    .expect("native trainer")
}

#[test]
fn prune_search_space_runs_natively() {
    let tr = tiny_trainer("diana_tiny_tiny_prune", 2);
    assert_eq!(tr.kind, SearchKind::Prune);
    assert_eq!(tr.manifest().search_kind, "prune");
    let recs = sweep(&tr).expect("prune sweep");
    assert_eq!(recs.len(), 2);
    for r in &recs {
        assert!(r.test_acc.is_finite());
        assert!(r.det_cycles > 0);
        for asg in &r.mapping.layers {
            // prune assignments only use {keep=0, prune=1}
            assert!(asg.cu_of.iter().all(|&c| c <= 1), "{:?}", asg.cu_of);
        }
    }
    // the kept-channel totals are sane (deployment prunes the rest)
    for r in &recs {
        let kept: usize = r.mapping.layers.iter().map(|a| a.count(0)).sum();
        let total: usize = r.mapping.layers.iter().map(|a| a.cu_of.len()).sum();
        assert!(kept <= total, "kept {kept} of {total}");
    }
}

#[test]
fn layerwise_search_space_runs_natively() {
    let tr = tiny_trainer("gap9_tiny_tiny_layerwise", 4);
    assert_eq!(tr.kind, SearchKind::Layerwise);
    assert_eq!(tr.manifest().search_kind, "layerwise");
    let recs = sweep(&tr).expect("layerwise sweep");
    assert_eq!(recs.len(), 2);
    for r in &recs {
        assert!(r.test_acc.is_finite());
        assert!(r.mapping.is_well_formed());
        for asg in &r.mapping.layers {
            // one gate per layer → uniform channel assignment
            if let Some(&first) = asg.cu_of.first() {
                assert!(
                    asg.cu_of.iter().all(|&c| c == first),
                    "layerwise assignment must be uniform: {:?}",
                    asg.cu_of
                );
            }
        }
    }
}
