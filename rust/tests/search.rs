//! Integration tests for the unified search subsystem: the
//! never-dominated-by-greedy property of coordinate descent on every
//! registered platform, descent termination at a fixed point, evaluator
//! cache consistency against both simulators, and capacity-feasible
//! moves end to end.

use odimo::experiments::{microbench_layers, SOCMAP_LAMBDAS};
use odimo::pareto::Point;
use odimo::search::{
    feasible_counts, mapping_penalty, CachingEvaluator, CoordinateDescent, CostEvaluator,
    Greedy, RandomRestart, SearchStrategy,
};
use odimo::soc::{analytical, detailed, Layer, Platform};

fn builtin_platforms() -> [Platform; 4] {
    [
        Platform::diana(),
        Platform::darkside(),
        Platform::trident(),
        Platform::gap9(),
    ]
}

fn workload_for(p: Platform) -> Vec<Layer> {
    let style = if p.name() == "diana" { "resnet" } else { "mobilenet" };
    microbench_layers(style)
}

// ---------------------------------------------------------------------------
// the property the ISSUE names: descent ≥ greedy, pointwise, everywhere
// ---------------------------------------------------------------------------

#[test]
fn descent_never_dominated_by_greedy_on_any_platform() {
    for p in builtin_platforms() {
        let layers = workload_for(p);
        for &lam in &SOCMAP_LAMBDAS {
            let mut eval = CachingEvaluator::detailed(p, &layers);
            let g = Greedy.search(p, &layers, lam, &mut eval);
            let mut eval = CachingEvaluator::detailed(p, &layers);
            let d = CoordinateDescent::default().search(p, &layers, lam, &mut eval);
            let gp = Point {
                cost: g.cost as f64,
                acc: -g.penalty,
            };
            let dp = Point {
                cost: d.cost as f64,
                acc: -d.penalty,
            };
            assert!(
                !gp.dominates(&dp),
                "{} λ={lam}: greedy (cost {}, penalty {}) dominates descent (cost {}, penalty {})",
                p.name(),
                g.cost,
                g.penalty,
                d.cost,
                d.penalty
            );
            // and the scalarized objective never regresses
            let jg = lam * g.cost as f64 + g.penalty;
            let jd = lam * d.cost as f64 + d.penalty;
            assert!(jd <= jg, "{} λ={lam}: J {jd} > greedy J {jg}", p.name());
        }
    }
}

#[test]
fn restart_never_dominated_by_greedy_on_trident() {
    // restart 0 is the plain greedy-start descent, so the multi-seed
    // strategy inherits the same guarantee
    let p = Platform::trident();
    let layers = workload_for(p);
    for &lam in &[0.0, 16.0, 4096.0] {
        let mut eval = CachingEvaluator::detailed(p, &layers);
        let g = Greedy.search(p, &layers, lam, &mut eval);
        let mut eval = CachingEvaluator::detailed(p, &layers);
        let r = RandomRestart::default().search(p, &layers, lam, &mut eval);
        let gp = Point {
            cost: g.cost as f64,
            acc: -g.penalty,
        };
        let rp = Point {
            cost: r.cost as f64,
            acc: -r.penalty,
        };
        assert!(!gp.dominates(&rp), "λ={lam}");
    }
}

// ---------------------------------------------------------------------------
// termination
// ---------------------------------------------------------------------------

#[test]
fn descent_terminates_and_is_a_fixed_point() {
    for p in builtin_platforms() {
        let layers = workload_for(p);
        let cd = CoordinateDescent::default();
        let mut eval = CachingEvaluator::detailed(p, &layers);
        let out = cd.search(p, &layers, 256.0, &mut eval);
        assert!(
            out.stats.rounds <= cd.max_rounds,
            "{}: {} rounds",
            p.name(),
            out.stats.rounds
        );
        // the result must be a fixed point: a fresh descent from it makes
        // no move and confirms in one sweep
        let (again, rounds, moves) = cd.descend(&layers, 256.0, &mut eval, &out.mapping);
        assert_eq!(moves, 0, "{}: descent result was not a fixed point", p.name());
        assert_eq!(rounds, 1);
        assert_eq!(again.layers, out.mapping.layers);
    }
}

// ---------------------------------------------------------------------------
// evaluator cache consistency
// ---------------------------------------------------------------------------

#[test]
fn evaluator_cache_is_consistent_with_both_simulators() {
    let p = Platform::trident();
    let layers = workload_for(p);
    let mapping = odimo::search::greedy_mapping(p, &layers, 16.0);
    let k = p.n_cus();

    let mut det_eval = CachingEvaluator::detailed(p, &layers);
    let mut ana_eval = CachingEvaluator::analytical(p, &layers);
    // incremental sums equal whole-network execution, cold and warm cache
    for _ in 0..2 {
        assert_eq!(
            det_eval.network_cost(&mapping),
            detailed::execute(&layers, &mapping, &[]).total_cycles
        );
        assert_eq!(
            ana_eval.network_cost(&mapping),
            analytical::execute(&layers, &mapping, &[]).total_cycles
        );
    }
    let s = det_eval.stats();
    assert_eq!(s.calls, 2 * layers.len() as u64);
    assert_eq!(s.cache_hits, layers.len() as u64, "second pass must be all hits");

    // cached per-layer values match fresh single-layer simulation
    for (li, (l, a)) in layers.iter().zip(&mapping.layers).enumerate() {
        let counts = a.counts(k);
        assert_eq!(
            det_eval.layer_cost(li, &counts),
            detailed::layer_latency(p, l, &counts, false)
        );
        assert_eq!(
            ana_eval.layer_cost(li, &counts),
            analytical::layer_latency(p, l, &counts, false)
        );
    }
}

// ---------------------------------------------------------------------------
// capacity feasibility end to end
// ---------------------------------------------------------------------------

#[test]
fn search_strategies_respect_mem_capacities() {
    // every built-in descriptor now declares weight-memory capacities;
    // on the microbench workloads a feasible placement always exists, so
    // no strategy may return counts that violate one
    for p in builtin_platforms() {
        let layers = workload_for(p);
        for &lam in &[0.0, 256.0, 65536.0] {
            for strategy in [
                &Greedy as &dyn SearchStrategy,
                &CoordinateDescent::default(),
                &RandomRestart::default(),
            ] {
                let mut eval = CachingEvaluator::detailed(p, &layers);
                let out = strategy.search(p, &layers, lam, &mut eval);
                for (l, a) in layers.iter().zip(&out.mapping.layers) {
                    let counts = a.counts(p.n_cus());
                    assert!(
                        feasible_counts(p, l, &counts),
                        "{} {} λ={lam} {}: {counts:?} violates capacity/eligibility",
                        p.name(),
                        strategy.name(),
                        l.name
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// bookkeeping sanity
// ---------------------------------------------------------------------------

#[test]
fn outcomes_report_strategy_metadata() {
    let p = Platform::trident();
    let layers = workload_for(p);
    let mut eval = CachingEvaluator::detailed(p, &layers);
    let d = CoordinateDescent::default().search(p, &layers, 16.0, &mut eval);
    assert_eq!(d.stats.strategy, "descent");
    assert!(d.stats.rounds >= 1);
    assert!(d.stats.evaluator_calls > 0);
    assert_eq!(d.penalty, mapping_penalty(&layers, &d.mapping));

    let mut eval = CachingEvaluator::detailed(p, &layers);
    let r = RandomRestart::default().search(p, &layers, 16.0, &mut eval);
    assert_eq!(r.stats.strategy, "restart");
    assert_eq!(r.stats.restarts, RandomRestart::default().restarts);
    assert!(r.stats.evaluator_calls >= d.stats.evaluator_calls);
}
