//! Native-engine tests: finite-difference gradient checks for every op,
//! cost-model pinning against the analytical simulator, same-seed
//! determinism, and end-to-end sweep feasibility on every built-in SoC.
//!
//! Gradient checks compare the tape's reverse-mode gradients against
//! central differences of the recorded forward computation (f32 forward,
//! ε = 1e-2, max relative error < 1e-2 — the acceptance bar). The
//! straight-through fake-quant ops are non-differentiable by design;
//! their *defined* gradient (identity) is asserted exactly instead.

use odimo::config::ExperimentConfig;
use odimo::coordinator::{sweep, Trainer};
use odimo::datasets::rng::Rng;
use odimo::mapping::SearchKind;
use odimo::runtime::native::{QuantKind, Tape, Tensor, Var};
use odimo::runtime::{BackendKind, ModelBackend, NativeBackend, StepHparams};
use odimo::search::feasible_counts;
use odimo::soc::{Layer, LayerType, Platform};

// ---------------------------------------------------------------------------
// gradient-check harness
// ---------------------------------------------------------------------------

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::from_stream(seed, 0x6AD, 0);
    (0..n).map(|_| 0.5 * rng.normal()).collect()
}

/// Check d(scalar objective)/d(input leaf) against central differences.
///
/// `build` records the objective on a fresh tape given the leaf data and
/// returns `(tape, leaf var, objective var)`.
fn grad_check<F>(name: &str, data: &[f32], build: F)
where
    F: Fn(&[f32]) -> (Tape, Var, Var),
{
    let (tape, leaf, obj) = build(data);
    let analytic = tape.grad_of(obj, leaf);
    assert_eq!(analytic.data.len(), data.len(), "{name}: grad shape");
    const EPS: f32 = 1e-2;
    let mut worst = 0.0f64;
    for i in 0..data.len() {
        let mut plus = data.to_vec();
        plus[i] += EPS;
        let mut minus = data.to_vec();
        minus[i] -= EPS;
        let (tp, _, op) = build(&plus);
        let (tm, _, om) = build(&minus);
        let fd = (tp.val(op).item() as f64 - tm.val(om).item() as f64) / (2.0 * EPS as f64);
        let an = analytic.data[i] as f64;
        let rel = (an - fd).abs() / an.abs().max(fd.abs()).max(1e-2);
        worst = worst.max(rel);
        assert!(
            rel < 1e-2,
            "{name}[{i}]: analytic {an:.6} vs central-diff {fd:.6} (rel {rel:.4})"
        );
    }
    eprintln!("  grad_check {name}: max rel err {worst:.2e}");
}

/// Objective wrapper: random-weighted sum of the op output — a plain sum
/// would feed the op a symmetric all-ones upstream gradient (degenerate
/// for softmax/BN, whose backward vanishes under uniform g).
fn weighted(tape: &mut Tape, v: Var, seed: u64) -> Var {
    let n = tape.val(v).elem_count();
    let w = rand_vec(n, seed ^ 0x5EED);
    let shape = tape.val(v).shape.clone();
    let wv = tape.leaf(Tensor::new(shape, w));
    let p = tape.mul(v, wv);
    tape.sum_all(p)
}

#[test]
fn grad_matmul() {
    let a0 = rand_vec(6, 1);
    grad_check("matmul/a", &a0, |d| {
        let mut t = Tape::new();
        let a = t.leaf(Tensor::new(vec![2, 3], d.to_vec()));
        let b = t.leaf(Tensor::new(vec![3, 2], rand_vec(6, 2)));
        let y = t.matmul(a, b);
        let o = weighted(&mut t, y, 4);
        (t, a, o)
    });
    let b0 = rand_vec(6, 3);
    grad_check("matmul/b", &b0, |d| {
        let mut t = Tape::new();
        let a = t.leaf(Tensor::new(vec![2, 3], rand_vec(6, 1)));
        let b = t.leaf(Tensor::new(vec![3, 2], d.to_vec()));
        let y = t.matmul(a, b);
        let o = weighted(&mut t, y, 5);
        (t, b, o)
    });
}

#[test]
fn grad_conv2d() {
    // x: [1, 4, 4, 2], w: [3, 2*3*3] — stride 1 and 2
    for stride in [1usize, 2] {
        let x0 = rand_vec(32, 10 + stride as u64);
        grad_check(&format!("conv2d/x s{stride}"), &x0, |d| {
            let mut t = Tape::new();
            let x = t.leaf(Tensor::new(vec![1, 4, 4, 2], d.to_vec()));
            let w = t.leaf(Tensor::new(vec![3, 18], rand_vec(54, 20)));
            let y = t.conv2d(x, w, 3, stride);
            let o = weighted(&mut t, y, 21);
            (t, x, o)
        });
        let w0 = rand_vec(54, 30 + stride as u64);
        grad_check(&format!("conv2d/w s{stride}"), &w0, |d| {
            let mut t = Tape::new();
            let x = t.leaf(Tensor::new(vec![1, 4, 4, 2], rand_vec(32, 40)));
            let w = t.leaf(Tensor::new(vec![3, 18], d.to_vec()));
            let y = t.conv2d(x, w, 3, stride);
            let o = weighted(&mut t, y, 22);
            (t, w, o)
        });
    }
}

#[test]
fn grad_dw_conv2d() {
    for stride in [1usize, 2] {
        let x0 = rand_vec(48, 50 + stride as u64);
        grad_check(&format!("dw/x s{stride}"), &x0, |d| {
            let mut t = Tape::new();
            let x = t.leaf(Tensor::new(vec![1, 4, 4, 3], d.to_vec()));
            let w = t.leaf(Tensor::new(vec![3, 9], rand_vec(27, 60)));
            let y = t.dw_conv2d(x, w, 3, stride);
            let o = weighted(&mut t, y, 61);
            (t, x, o)
        });
        let w0 = rand_vec(27, 70 + stride as u64);
        grad_check(&format!("dw/w s{stride}"), &w0, |d| {
            let mut t = Tape::new();
            let x = t.leaf(Tensor::new(vec![1, 4, 4, 3], rand_vec(48, 80)));
            let w = t.leaf(Tensor::new(vec![3, 9], d.to_vec()));
            let y = t.dw_conv2d(x, w, 3, stride);
            let o = weighted(&mut t, y, 62);
            (t, w, o)
        });
    }
}

#[test]
fn grad_relu() {
    // inputs pushed ≥ 0.2 away from the kink so ±ε stays on one side
    let x0: Vec<f32> = rand_vec(8, 90)
        .into_iter()
        .map(|v| if v >= 0.0 { v + 0.2 } else { v - 0.2 })
        .collect();
    grad_check("relu/x", &x0, |d| {
        let mut t = Tape::new();
        let x = t.leaf(Tensor::new(vec![8], d.to_vec()));
        let y = t.relu(x);
        let o = weighted(&mut t, y, 91);
        (t, x, o)
    });
}

#[test]
fn grad_batch_norm() {
    let x0 = rand_vec(12, 100);
    grad_check("bn/x", &x0, |d| {
        let mut t = Tape::new();
        let x = t.leaf(Tensor::new(vec![4, 3], d.to_vec()));
        let s = t.leaf(Tensor::new(vec![3], vec![1.2, 0.8, 1.0]));
        let b = t.leaf(Tensor::new(vec![3], vec![0.1, -0.2, 0.0]));
        let (y, _, _) = t.batch_norm_train(x, s, b);
        let o = weighted(&mut t, y, 101);
        (t, x, o)
    });
    let s0 = vec![1.1f32, 0.9, 1.3];
    grad_check("bn/scale", &s0, |d| {
        let mut t = Tape::new();
        let x = t.leaf(Tensor::new(vec![4, 3], rand_vec(12, 100)));
        let s = t.leaf(Tensor::new(vec![3], d.to_vec()));
        let b = t.leaf(Tensor::new(vec![3], vec![0.1, -0.2, 0.0]));
        let (y, _, _) = t.batch_norm_train(x, s, b);
        let o = weighted(&mut t, y, 102);
        (t, s, o)
    });
    let b0 = vec![0.3f32, -0.1, 0.2];
    grad_check("bn/bias", &b0, |d| {
        let mut t = Tape::new();
        let x = t.leaf(Tensor::new(vec![4, 3], rand_vec(12, 100)));
        let s = t.leaf(Tensor::new(vec![3], vec![1.2, 0.8, 1.0]));
        let b = t.leaf(Tensor::new(vec![3], d.to_vec()));
        let (y, _, _) = t.batch_norm_train(x, s, b);
        let o = weighted(&mut t, y, 103);
        (t, b, o)
    });
}

#[test]
fn grad_pool_bias_affine() {
    let x0 = rand_vec(2 * 2 * 2 * 3, 110);
    grad_check("gap/x", &x0, |d| {
        let mut t = Tape::new();
        let x = t.leaf(Tensor::new(vec![2, 2, 2, 3], d.to_vec()));
        let y = t.global_avg_pool(x);
        let o = weighted(&mut t, y, 111);
        (t, x, o)
    });
    let b0 = rand_vec(3, 112);
    grad_check("add_bias/b", &b0, |d| {
        let mut t = Tape::new();
        let x = t.leaf(Tensor::new(vec![2, 3], rand_vec(6, 113)));
        let b = t.leaf(Tensor::new(vec![3], d.to_vec()));
        let y = t.add_bias(x, b);
        let o = weighted(&mut t, y, 115);
        (t, b, o)
    });
    let x1 = rand_vec(6, 114);
    grad_check("channel_affine/x", &x1, |d| {
        let mut t = Tape::new();
        let x = t.leaf(Tensor::new(vec![2, 3], d.to_vec()));
        let y = t.channel_affine(x, vec![1.5, 0.5, 2.0], vec![0.1, 0.0, -0.3]);
        let o = weighted(&mut t, y, 116);
        (t, x, o)
    });
}

#[test]
fn grad_softmax_ce() {
    let l0 = rand_vec(8, 120);
    grad_check("ce/logits", &l0, |d| {
        let mut t = Tape::new();
        let logits = t.leaf(Tensor::new(vec![2, 4], d.to_vec()));
        let (loss, _) = t.softmax_ce(logits, &[1, 3]);
        (t, logits, loss)
    });
}

#[test]
fn grad_theta_path() {
    // θ → masked softmax → effective weights (with a masked column):
    // the full differentiable-mapping path of the search
    let th0 = rand_vec(3 * 3, 130);
    let quants = [QuantKind::Int8, QuantKind::Identity, QuantKind::Ternary];
    let mask = [true, false, true];
    grad_check("theta/softmax+effw", &th0, |d| {
        let mut t = Tape::new();
        let th = t.leaf(Tensor::new(vec![3, 3], d.to_vec()));
        let w = t.leaf(Tensor::new(vec![3, 8], rand_vec(24, 131)));
        let p = t.softmax_rows_masked(th, &mask);
        let weff = t.effective_weights(w, p, &quants);
        let o = weighted(&mut t, weff, 132);
        (t, th, o)
    });
    // counts path: θ → softmax → col_sum → weighted scalar
    grad_check("theta/col_sum", &th0, |d| {
        let mut t = Tape::new();
        let th = t.leaf(Tensor::new(vec![3, 3], d.to_vec()));
        let p = t.softmax_rows_masked(th, &mask);
        let n = t.col_sum(p);
        let o = weighted(&mut t, n, 133);
        (t, th, o)
    });
}

#[test]
fn grad_layer_cost() {
    // fractional counts away from integer kinks: the op is locally linear
    // there, so central differences match the interpolation slope exactly
    let layer = Layer {
        name: "t".into(),
        ltype: LayerType::Conv,
        cin: 16,
        cout: 32,
        k: 3,
        ox: 8,
        oy: 8,
        stride: 1,
        searchable: true,
    };
    let platform = Platform::diana();
    let n0 = vec![12.4f32, 19.6];
    let l2 = layer.clone();
    grad_check("layer_cost/latency+energy", &n0, move |d| {
        let mut t = Tape::new();
        let n = t.leaf(Tensor::new(vec![2], d.to_vec()));
        let lc = t.layer_cost(
            n,
            &l2,
            platform.cus(),
            platform.p_idle_mw(),
            platform.freq_mhz(),
            false,
        );
        // mix both components so each count feeds the objective
        let o = t.weighted_pair(lc, 1e-3, 5.0);
        (t, n, o)
    });
}

#[test]
fn ste_gradient_is_identity() {
    // the quantizers are step functions — their STE backward is the
    // *defined* identity, asserted exactly (FD would see zero slope)
    for kind in [QuantKind::Int8, QuantKind::Ternary] {
        let mut t = Tape::new();
        let w = t.leaf(Tensor::new(vec![2, 4], rand_vec(8, 140)));
        let q = t.fake_quant_ste(w, kind);
        let o = t.sum_all(q);
        let g = t.grad_of(o, w);
        assert_eq!(g.data, vec![1.0; 8], "{kind:?} STE must pass gradient through");
    }
}

// ---------------------------------------------------------------------------
// cost model pinned to the analytical simulator
// ---------------------------------------------------------------------------

#[test]
fn frozen_cost_report_matches_analytical_simulator() {
    let be = NativeBackend::build("trident_tiny_tiny").expect("backend");
    let tr = trainer_for("trident_tiny_tiny", 42);
    let mut state = be.init_state(42).expect("init");
    // freeze θ to the discretized mapping → expected counts are integral
    let mapping = tr.discretize_all(&state).expect("discretize");
    tr.freeze_mapping(&mut state, &mapping).expect("freeze");
    let (_, totals) = be.cost_report(&state).expect("cost report");
    let (ana, _) = tr.simulate(&mapping);
    let rel = (totals[0] as f64 - ana.total_cycles as f64).abs() / ana.total_cycles as f64;
    assert!(
        rel < 1e-3,
        "in-graph latency {} vs simulator {} (rel {rel})",
        totals[0],
        ana.total_cycles
    );
    let rel_e = (totals[1] as f64 - ana.energy_uj).abs() / ana.energy_uj;
    assert!(
        rel_e < 1e-3,
        "in-graph energy {} vs simulator {} (rel {rel_e})",
        totals[1],
        ana.energy_uj
    );
}

// ---------------------------------------------------------------------------
// end-to-end: train / sweep on the native backend
// ---------------------------------------------------------------------------

fn tiny_cfg(variant: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::for_variant(variant);
    cfg.warmup_epochs = 1;
    cfg.search_epochs = 1;
    cfg.final_epochs = 1;
    cfg.steps_per_epoch = 2;
    cfg.eval_batches = 1;
    cfg.lambdas = vec![0.1, 1.0, 10.0];
    cfg
}

fn trainer_for(variant: &str, seed: i32) -> Trainer {
    let mut cfg = tiny_cfg(variant);
    cfg.seed = seed;
    Trainer::create(
        &odimo::repo_root().join("artifacts"),
        cfg,
        Some(BackendKind::Native),
    )
    .expect("native trainer")
}

#[test]
fn native_train_step_moves_weights_and_theta() {
    let tr = trainer_for("trident_tiny_tiny", 0);
    assert_eq!(tr.kind, SearchKind::Channel);
    let mut state = tr.init_state().expect("init");
    let before_w = state.leaf_f32("params/stem/w").expect("w leaf");
    let before_th = tr.theta_of(&state, "stem").expect("theta");
    let hp = StepHparams {
        lam: (1.0 / tr.manifest().cost_scale.latency_cycles) as f32,
        cost_sel: 0.0,
        lr_w: 1e-2,
        lr_th: 5e-2,
    };
    let m = tr.run_epoch(&mut state, hp, 0).expect("epoch");
    assert!(m.loss.is_finite() && m.loss > 0.0, "loss {m:?}");
    assert!((0.0..=1.0).contains(&m.acc));
    assert!(m.cost_lat > 0.0 && m.cost_energy > 0.0);
    let after_w = state.leaf_f32("params/stem/w").expect("w leaf");
    let after_th = tr.theta_of(&state, "stem").expect("theta");
    assert_ne!(before_w, after_w, "W did not move");
    assert_ne!(before_th, after_th, "θ did not move under λ > 0, lr_th > 0");
    // masked θ columns stay pinned at the one-hot floor (dwe is conv-ineligible)
    let k = tr.platform.n_cus();
    for c in 0..after_th.len() / k {
        assert_eq!(
            after_th[c * k + 1],
            -odimo::mapping::ONE_HOT_LOGIT,
            "masked column moved at row {c}"
        );
    }
    // eval is deterministic and well-formed
    let (a1, l1) = tr.evaluate(&state, odimo::datasets::Split::Val).expect("eval");
    let (a2, l2) = tr.evaluate(&state, odimo::datasets::Split::Val).expect("eval");
    assert_eq!(a1, a2);
    assert_eq!(l1, l2);
    assert!((0.0..=1.0).contains(&a1));
}

#[test]
fn evaluate_errors_on_zero_eval_batches() {
    let mut cfg = tiny_cfg("trident_tiny_tiny");
    cfg.eval_batches = 0;
    let tr = Trainer::create(
        &odimo::repo_root().join("artifacts"),
        cfg,
        Some(BackendKind::Native),
    )
    .expect("native trainer");
    let state = tr.init_state().expect("init");
    let err = tr
        .evaluate(&state, odimo::datasets::Split::Val)
        .expect_err("eval_batches = 0 must be an error, not NaN");
    assert!(format!("{err:#}").contains("eval_batches"), "{err:#}");
}

/// The determinism satellite: two same-seed native runs produce identical
/// RunRecords (modulo wall-clock timing, which is not part of the result).
#[test]
fn same_seed_native_sweeps_are_identical() {
    let run = || {
        let tr = trainer_for("diana_tiny_tiny", 7);
        sweep(&tr).expect("sweep")
    };
    let a = run();
    let b = run();
    assert_eq!(a.len(), b.len());
    assert!(a.len() >= 3, "≥3 RunRecords expected, got {}", a.len());
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.lambda, rb.lambda);
        assert_eq!(ra.val_acc, rb.val_acc, "val_acc drifted at λ={:?}", ra.lambda);
        assert_eq!(ra.test_acc, rb.test_acc);
        assert_eq!(ra.ana_cycles, rb.ana_cycles);
        assert_eq!(ra.det_cycles, rb.det_cycles);
        assert_eq!(ra.ana_energy_uj, rb.ana_energy_uj);
        assert_eq!(ra.offload_frac, rb.offload_frac);
        for (la, lb) in ra.mapping.layers.iter().zip(&rb.mapping.layers) {
            assert_eq!(la, lb, "mapping drifted at λ={:?}", ra.lambda);
        }
    }
}

/// Acceptance path: native sweeps emit non-empty records whose
/// discretized mappings pass the PR-2 feasibility check — on the paper
/// SoCs and both JSON-defined 3-CU SoCs.
#[test]
fn native_sweep_feasible_on_all_builtin_socs() {
    for soc in ["diana", "darkside", "trident", "gap9"] {
        let tr = trainer_for(&format!("{soc}_tiny_tiny"), 3);
        let recs = sweep(&tr).expect("sweep");
        assert!(recs.len() >= 3, "{soc}: got {} records", recs.len());
        let k = tr.platform.n_cus();
        for r in &recs {
            assert!(!r.per_layer.is_empty(), "{soc}: empty record");
            assert_eq!(r.util.len(), k);
            assert!(r.det_cycles > 0);
            assert!(r.mapping.is_well_formed());
            for (layer, asg) in tr.layers.iter().zip(&r.mapping.layers) {
                assert!(
                    feasible_counts(tr.platform, layer, &asg.counts(k)),
                    "{soc} λ={:?}: layer {} infeasible: {:?}",
                    r.lambda,
                    layer.name,
                    asg.counts(k)
                );
            }
        }
    }
}

/// Strong cost pressure must not *increase* the analytical cost of the
/// discretized mapping relative to the λ→0 point (same seed, same data).
#[test]
fn lambda_pressure_is_monotone_in_the_right_direction() {
    let run_at = |lam_rel: f64| {
        let tr = trainer_for("trident_tiny_tiny", 11);
        let mut state = tr.init_state().expect("init");
        let hp = StepHparams {
            lam: (lam_rel / tr.manifest().cost_scale.latency_cycles) as f32,
            cost_sel: 0.0,
            lr_w: 1e-2,
            lr_th: 0.1,
        };
        for e in 0..4 {
            tr.run_epoch(&mut state, hp, e).expect("epoch");
        }
        let mapping = tr.discretize_all(&state).expect("discretize");
        let (ana, _) = tr.simulate(&mapping);
        ana.total_cycles
    };
    let cheap = run_at(50.0);
    let free = run_at(0.0);
    assert!(
        cheap <= free,
        "strong λ mapping ({cheap}) costs more than λ=0 mapping ({free})"
    );
}

#[test]
fn backend_selection_and_state_contract() {
    let be = NativeBackend::build("gap9_resnet20_c10").expect("gap9 native supernet");
    assert_eq!(be.backend_name(), "native");
    let m = be.manifest();
    assert_eq!(m.platform, "gap9");
    assert_eq!(m.search_kind, "channel");
    // θ leaves are [cout, K] for the 3-CU SoC
    let k = 3;
    let stem = m.layers.iter().find(|l| l.name == "stem").unwrap();
    assert_eq!(stem.theta_len, k * stem.cout);
    let state = be.init_state(0).expect("init");
    assert_eq!(state.leaves.len(), be.state_len());
    // fixed variants drop θ but keep the same W/optimizer layout
    let fx = NativeBackend::build("gap9_resnet20_c10_fixed").expect("fixed supernet");
    assert_eq!(fx.manifest().search_kind, "fixed");
    assert!(fx.state_specs().iter().all(|s| !s.name.ends_with("/theta")));
    assert!(fx.state_len() < be.state_len());
}
