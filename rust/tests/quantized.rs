//! Quantized-inference path tests: the `QuantNet` discretization of a
//! trained state (`NativeBackend::quantize`) and its integer forward.
//!
//! The validation contract (see `runtime/native/qkernels.rs`):
//!
//! * stored `code · scale` reproduces the training forward's
//!   `QuantKind::quant_row` output **bit-exactly** per weight row;
//! * the genuinely-quantized forward (int8 activations, i32-accumulator
//!   GEMM) tracks [`QuantNet::forward_f32_reference`] — the same
//!   discretized network in f32 with unquantized activations — within a
//!   documented tolerance: logits linf error ≤ 10% of `1 + max|logit|`.
//!   The only divergence source is symmetric per-tensor activation
//!   quantization (≤ 0.5/127 of each layer input's amax per element),
//!   so the bound is loose by design and holds on every builtin SoC;
//! * for `_fixed` variants (no θ) the f32 reference is semantically the
//!   tape's eval forward, so its metrics must match
//!   `ModelBackend::eval_batch` to accumulation-order noise.

use odimo::datasets::{Split, SynthDataset};
use odimo::runtime::native::qkernels::logits_metrics;
use odimo::runtime::native::QuantKind;
use odimo::runtime::{
    ModelBackend, NativeBackend, NativeOptions, StepHparams, TrainState, WOptimizer,
};

fn hp() -> StepHparams {
    StepHparams {
        lam: 1e-7,
        cost_sel: 0.0,
        lr_w: 1e-2,
        lr_th: 5e-2,
    }
}

fn build(variant: &str) -> NativeBackend {
    NativeBackend::build_with(
        variant,
        NativeOptions {
            threads: 1,
            w_optimizer: WOptimizer::SgdMomentum,
        },
    )
    .expect("native variant")
}

/// Train a few steps so the state is no longer at init (BN stats moved,
/// θ differentiated), then return it with a held-out batch.
fn trained_state(be: &NativeBackend, steps: usize) -> (TrainState, Vec<f32>, Vec<i32>) {
    let m = be.manifest();
    let ds = SynthDataset::from_name(&m.dataset.name, m.dataset.hw, m.dataset.classes, 9);
    let mut state = be.init_state(21).expect("init");
    for i in 0..steps {
        let (x, y) = ds.batch(Split::Train, i as u64, m.dataset.batch);
        be.train_step(&mut state, &x, &y, hp()).expect("step");
    }
    let (x, y) = ds.batch(Split::Test, 0, m.dataset.batch);
    (state, x, y)
}

/// `code · scale` must equal the fake-quant `quant_row` output bit for
/// bit on every integer row; Identity rows carry no codes; Zero rows
/// dequantize to exact zeros.
#[test]
fn codes_times_scale_match_quant_row_bit_exactly() {
    for variant in ["diana_tiny_tiny", "gap9_tiny_tiny", "trident_tiny_tiny"] {
        let be = build(variant);
        let (state, _, _) = trained_state(&be, 2);
        let qnet = be.quantize(&state).expect("quantize");
        let spec = qnet.spec();
        for gi in 0..spec.n_convs() {
            let ql = qnet.layer(gi);
            let f = spec.fan_in(gi);
            for (r, &kind) in ql.kinds.iter().enumerate() {
                let deq = &ql.w_deq[r * f..(r + 1) * f];
                let codes = &ql.codes[r * f..(r + 1) * f];
                match kind {
                    QuantKind::Int8 | QuantKind::Ternary => {
                        for (c, (&code, &d)) in codes.iter().zip(deq).enumerate() {
                            let got = code as f32 * ql.scales[r];
                            assert_eq!(
                                got.to_bits(),
                                d.to_bits(),
                                "{variant} g{gi} row {r} col {c}: {got} vs quant_row {d}"
                            );
                        }
                        if kind == QuantKind::Ternary {
                            assert!(codes.iter().all(|&c| (-1..=1).contains(&c)));
                        }
                    }
                    QuantKind::Zero => {
                        assert!(deq.iter().all(|&d| d == 0.0), "{variant} g{gi} row {r}");
                        assert!(codes.iter().all(|&c| c == 0));
                    }
                    QuantKind::Identity => {
                        assert!(codes.iter().all(|&c| c == 0), "{variant} g{gi} row {r}");
                    }
                }
            }
        }
    }
}

/// The integer forward vs the f32 fake-quant reference on every builtin
/// SoC's supernet, plus the `_fixed`/`_prune`/`_layerwise` spaces: linf
/// logits error within the documented activation-quantization budget.
#[test]
fn quantized_forward_tracks_f32_reference_on_all_socs() {
    let variants = [
        "diana_tiny_tiny",
        "darkside_tiny_tiny",
        "trident_tiny_tiny",
        "gap9_tiny_tiny",
        "diana_tiny_tiny_fixed",
        "diana_tiny_tiny_prune",
        "gap9_tiny_tiny_layerwise",
    ];
    for variant in variants {
        let be = build(variant);
        let (state, x, y) = trained_state(&be, 2);
        let n = y.len();
        let qnet = be.quantize(&state).expect("quantize");
        let lq = qnet.forward(&x, n);
        let lf = qnet.forward_f32_reference(&x, n);
        assert_eq!(lq.len(), n * qnet.spec().classes);
        assert!(lf.iter().all(|v| v.is_finite()), "{variant}: reference logits");
        let amax = lf.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let tol = 0.10 * (1.0 + amax);
        for (i, (&q, &f)) in lq.iter().zip(&lf).enumerate() {
            assert!(q.is_finite(), "{variant}: quantized logit {i} not finite");
            assert!(
                (q - f).abs() <= tol,
                "{variant} logit {i}: quantized {q} vs reference {f} (tol {tol})"
            );
        }
    }
}

/// On a `_fixed` variant (no θ anywhere) the discretized f32 reference
/// is the same computation as the tape's eval forward — its metrics must
/// agree with `eval_batch` up to accumulation-order noise, and the
/// genuinely-quantized metrics must stay close.
#[test]
fn fixed_variant_metrics_tie_into_tape_eval() {
    let be = build("diana_tiny_tiny_fixed");
    let (state, x, y) = trained_state(&be, 3);
    let n = y.len();

    let tape_metrics = be.eval_batch(&state, &x, &y).expect("eval");
    let qnet = be.quantize(&state).expect("quantize");
    let lf = qnet.forward_f32_reference(&x, n);
    let (ref_correct, ref_loss) = logits_metrics(&lf, &y, qnet.spec().classes);

    // f32 reference vs tape: same math, different accumulation order
    assert_eq!(
        ref_correct, tape_metrics[0],
        "reference correct-count vs tape eval"
    );
    let loss_err = (ref_loss - tape_metrics[1]).abs();
    assert!(
        loss_err <= 1e-3 * (1.0 + tape_metrics[1].abs()),
        "reference loss {ref_loss} vs tape {} (err {loss_err})",
        tape_metrics[1]
    );

    // integer forward: same metric pair through the public entry point,
    // close to the reference (activation quantization only)
    let qm = be.eval_batch_quantized(&state, &x, &y).expect("qeval");
    assert_eq!(qm.len(), 2);
    assert!(qm[0] >= 0.0 && qm[0] <= n as f32, "correct = {}", qm[0]);
    assert!(qm[1].is_finite() && qm[1] > 0.0, "loss = {}", qm[1]);
    let dl = (qm[1] - ref_loss).abs();
    assert!(
        dl <= 0.15 * (1.0 + ref_loss),
        "quantized loss {} vs reference {ref_loss} (Δ {dl})",
        qm[1]
    );
    let dc = (qm[0] - ref_correct).abs();
    assert!(
        dc <= (n as f32 * 0.25).max(2.0),
        "quantized correct {} vs reference {ref_correct}",
        qm[0]
    );
}

/// The quantized forward's determinism matrix, the integer analogue of
/// `native_exec::thread_count_determinism_matrix`. The contract is
/// stronger than the f32 one: activation scales are computed per fixed
/// batch shard (`NSHARDS`, never the thread count) and integer addition
/// is associative, so logits and metrics must be *bit-identical* at any
/// thread count. (Cross-*tier* identity of the integer GEMM itself —
/// naive vs blocked vs SIMD — is pinned exactly in `tests/kernels.rs`;
/// this test never flips the process-global SIMD toggle, per the
/// `tensor` module's contract.)
#[test]
fn quantized_eval_bit_identical_across_threads_and_tiers() {
    for arch in ["resnet8", "mbv1"] {
        for soc in ["diana", "gap9"] {
            let variant = format!("{soc}_{arch}_tiny");
            let be1 = build(&variant);
            let (state, x, y) = trained_state(&be1, 2);
            let n = y.len();
            let qnet1 = be1.quantize(&state).expect("quantize");
            let logits1: Vec<u32> = qnet1.forward(&x, n).iter().map(|v| v.to_bits()).collect();
            let m1 = be1.eval_batch_quantized(&state, &x, &y).expect("qeval");
            // same ladder as native_exec::matrix_threads: 2/4/8 plus an
            // oversubscribed 2×cores row, capped by the backend's limit
            let cores = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1);
            let cap = odimo::runtime::native::max_threads();
            let mut matrix: Vec<usize> = [2usize, 4, 8, 2 * cores]
                .into_iter()
                .filter(|&t| t >= 2 && t <= cap)
                .collect();
            matrix.sort_unstable();
            matrix.dedup();
            for threads in matrix {
                let bet = NativeBackend::build_with(
                    &variant,
                    NativeOptions {
                        threads,
                        w_optimizer: WOptimizer::SgdMomentum,
                    },
                )
                .expect("native variant");
                let qnett = bet.quantize(&state).expect("quantize");
                let logits_t: Vec<u32> =
                    qnett.forward(&x, n).iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    logits1, logits_t,
                    "{variant}: quantized logits differ at {threads} threads"
                );
                let mt = bet.eval_batch_quantized(&state, &x, &y).expect("qeval");
                assert_eq!(
                    m1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    mt.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{variant}: quantized metrics differ at {threads} threads"
                );
            }
        }
    }
}

/// Weight prepacking is a build-time, one-time event: the packed slab is
/// exactly the size `plan::quant_pack_plan` computed from the spec, the
/// dispatched tier is fixed at `quantize` time, and neither changes —
/// nor do the logits — across repeated evals. (Which tier gets picked is
/// host-dependent; *that* the pick is stable and the packed forward is
/// reproducible is not.)
#[test]
fn prepack_slab_is_plan_sized_and_stable_across_evals() {
    use odimo::runtime::native::plan::quant_pack_plan;
    let be = build("diana_tiny_tiny");
    let (state, x, y) = trained_state(&be, 2);
    let n = y.len();
    let qnet = be.quantize(&state).expect("quantize");
    let planned = quant_pack_plan(qnet.spec()).total;
    assert!(planned > 0, "tiny variant has dense convs to pack");
    assert_eq!(qnet.packed_len(), planned, "slab sized by quant_pack_plan");
    let tier = qnet.tier();
    let l1: Vec<u32> = qnet.forward(&x, n).iter().map(|v| v.to_bits()).collect();
    for _ in 0..3 {
        let _ = qnet.eval_batch(&x, &y).expect("qeval");
    }
    assert_eq!(qnet.packed_len(), planned, "pack slab changed after evals");
    assert_eq!(qnet.tier(), tier, "tier re-decided after build");
    let l2: Vec<u32> = qnet.forward(&x, n).iter().map(|v| v.to_bits()).collect();
    assert_eq!(l1, l2, "packed forward not reproducible across evals");
}

/// Prune-mode discretization: each searchable channel keeps the primary
/// CU's quantizer iff its keep-logit wins, else the row is Zero — read
/// straight off the θ leaves.
#[test]
fn prune_discretization_follows_theta() {
    let be = build("diana_tiny_tiny_prune");
    let (state, _, _) = trained_state(&be, 3);
    let qnet = be.quantize(&state).expect("quantize");
    let spec = qnet.spec();
    let theta_leaves: Vec<Option<usize>> = (0..spec.n_convs())
        .map(|gi| {
            let name = format!("params/{}/theta", spec.layers[gi].name);
            be.state_specs().iter().position(|s| s.name == name)
        })
        .collect();
    let mut searchable = 0;
    for gi in 0..spec.n_convs() {
        let ql = qnet.layer(gi);
        let Some(tleaf) = theta_leaves[gi] else {
            // non-searchable: primary CU everywhere
            assert!(ql.kinds.iter().all(|&k| k == spec.quants[0]), "g{gi}");
            continue;
        };
        searchable += 1;
        let th = &state.leaves[tleaf];
        assert_eq!(th.len(), ql.kinds.len() * 2);
        for (r, &kind) in ql.kinds.iter().enumerate() {
            let want = if th[r * 2] >= th[r * 2 + 1] {
                spec.quants[0]
            } else {
                QuantKind::Zero
            };
            assert_eq!(kind, want, "g{gi} row {r}: θ = {:?}", &th[r * 2..r * 2 + 2]);
        }
    }
    assert!(searchable > 0, "prune variant has no searchable geometry");
}
