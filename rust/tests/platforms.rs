//! Integration tests for the N-CU platform substrate: descriptor
//! round-trips, runtime discovery, N-way discretization, the Table III
//! analytical-vs-detailed parity invariant on every registered platform,
//! and the full artifact-free deployment pipeline (`socmap`) on the
//! JSON-defined tri-CU SoC — sweep → discretize → reorg → detailed sim →
//! per-CU report.

use odimo::experiments::{microbench_layers, socmap_point, SOCMAP_LAMBDAS};
use odimo::mapping::{discretize, one_hot_theta, SearchKind};
use odimo::soc::{analytical, detailed, LayerAssignment, Mapping, Platform, PlatformSpec};

fn builtin_platforms() -> [Platform; 4] {
    [
        Platform::diana(),
        Platform::darkside(),
        Platform::trident(),
        Platform::gap9(),
    ]
}

// ---------------------------------------------------------------------------
// descriptor loading
// ---------------------------------------------------------------------------

#[test]
fn builtin_specs_roundtrip_through_json() {
    for p in builtin_platforms() {
        let spec = p.spec();
        let text = spec.to_json().to_string_pretty();
        let re = PlatformSpec::parse(&text).expect("re-parse");
        assert_eq!(*spec, re, "{} descriptor does not round-trip", p.name());
    }
}

#[test]
fn descriptors_on_disk_match_builtins() {
    // the embedded built-ins are literally the checked-in hw/*.json files;
    // if the checkout has them, the two must agree
    for p in builtin_platforms() {
        let path = odimo::repo_root()
            .join("hw")
            .join(format!("{}.json", p.name()));
        if let Ok(text) = std::fs::read_to_string(&path) {
            let on_disk = PlatformSpec::parse(&text).expect("hw/*.json parses");
            assert_eq!(*p.spec(), on_disk, "{} drifted from hw/", p.name());
        }
    }
}

#[test]
fn runtime_discovery_loads_new_descriptor() {
    // drop a descriptor under hw/ and resolve it purely by name
    let dir = odimo::repo_root().join("hw");
    if !dir.exists() {
        eprintln!("SKIP: no hw/ directory in this checkout");
        return;
    }
    let name = "ittest-quad";
    let path = dir.join(format!("{name}.json"));
    let mut spec = Platform::trident().spec().clone();
    spec.name = name.to_string();
    spec.cus.push({
        let mut extra = spec.cus[1].clone();
        extra.name = "dwe2".into();
        extra
    });
    if std::fs::write(&path, spec.to_json().to_string_pretty()).is_err() {
        eprintln!("SKIP: hw/ not writable in this checkout");
        return;
    }
    let loaded = Platform::get(name);
    std::fs::remove_file(&path).ok();
    let loaded = loaded.expect("runtime discovery");
    assert_eq!(loaded.n_cus(), 4);
    assert_eq!(loaded.cus()[3].name, "dwe2");
}

#[test]
fn malformed_descriptor_is_an_error_not_a_panic() {
    assert!(PlatformSpec::parse("{").is_err());
    assert!(PlatformSpec::parse(r#"{"name": "x"}"#).is_err());
    assert!("no-such-platform".parse::<Platform>().is_err());
}

// ---------------------------------------------------------------------------
// N-way discretization
// ---------------------------------------------------------------------------

#[test]
fn discretize_three_way_on_tri_cu_spec() {
    let p = Platform::trident();
    let k = p.n_cus();
    assert_eq!(k, 3);
    let cout = 12;
    // θ rows favoring column (c mod 3)
    let mut theta = vec![0.0f32; k * cout];
    for c in 0..cout {
        theta[c * k + c % k] = 4.0;
    }
    let a = discretize(SearchKind::Channel, &theta, cout, k, "l");
    for (c, &cu) in a.cu_of.iter().enumerate() {
        assert_eq!(cu as usize, c % k);
    }
    let counts = a.counts(k);
    assert_eq!(counts, vec![4, 4, 4]);
    // one-hot freeze → discretize is the identity, as the coordinator needs
    let oh = one_hot_theta(SearchKind::Channel, &a, k);
    assert_eq!(discretize(SearchKind::Channel, &oh, cout, k, "l"), a);
}

// ---------------------------------------------------------------------------
// Table III invariant: analytical underestimates detailed, everywhere
// ---------------------------------------------------------------------------

#[test]
fn analytical_detailed_parity_on_all_platforms() {
    for p in builtin_platforms() {
        let style = if p.name() == "diana" { "resnet" } else { "mobilenet" };
        let layers = microbench_layers(style);
        let k = p.n_cus();
        for (si, split) in [0.0, 0.35, 0.8].iter().enumerate() {
            let m = Mapping {
                platform: p,
                layers: layers
                    .iter()
                    .map(|l| {
                        let n_off = (l.cout as f64 * split) as usize;
                        LayerAssignment::offload_round_robin(&l.name, l.cout, n_off, k)
                    })
                    .collect(),
            };
            assert!(m.is_well_formed());
            let a = analytical::execute(&layers, &m, &[]);
            let d = detailed::execute(&layers, &m, &[]);
            assert!(
                d.total_cycles > a.total_cycles,
                "{} split#{si}: detailed {} <= analytical {}",
                p.name(),
                d.total_cycles,
                a.total_cycles
            );
            assert_eq!(a.utilization.len(), k);
            assert_eq!(d.utilization.len(), k);
            // per-layer, per-CU: the detailed cycles dominate too
            for (al, dl) in a.layers.iter().zip(&d.layers) {
                for col in 0..k {
                    assert!(
                        dl.per_cu[col].cycles >= al.per_cu[col].cycles,
                        "{} {} cu{col}",
                        p.name(),
                        al.layer
                    );
                    assert_eq!(dl.per_cu[col].channels, al.per_cu[col].channels);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// end-to-end on the JSON-defined 3-CU platform (no artifacts needed)
// ---------------------------------------------------------------------------

#[test]
fn socmap_pipeline_runs_end_to_end_on_trident() {
    let p = Platform::trident();
    let layers = microbench_layers("mobilenet");
    let mut first_cycles = None;
    let mut last_cycles = 0u64;
    let mut saw_all_three_busy = false;
    for &lam in &SOCMAP_LAMBDAS {
        let (mapping, ana, det) = socmap_point(p, &layers, lam);
        // the deployed mapping is contiguous per layer (post-reorg)
        assert!(mapping.is_well_formed());
        for asg in &mapping.layers {
            assert!(asg.is_contiguous(), "λ={lam} {}", asg.layer);
        }
        // reports carry all three CU columns
        assert_eq!(ana.n_cus(), 3);
        assert_eq!(det.n_cus(), 3);
        assert_eq!(det.utilization.len(), 3);
        assert!(det.total_cycles > ana.total_cycles);
        first_cycles.get_or_insert(ana.total_cycles);
        last_cycles = ana.total_cycles;
        if det.utilization.iter().all(|&u| u > 0.0) {
            saw_all_three_busy = true;
        }
    }
    // full cost pressure beats the no-pressure mapping
    assert!(last_cycles < first_cycles.unwrap());
    assert!(
        saw_all_three_busy,
        "some λ must put work on all 3 CUs of the tri-CU SoC"
    );
}

#[test]
fn socmap_runs_on_two_cu_builtins_too() {
    let lam = *SOCMAP_LAMBDAS.last().unwrap();
    for p in [Platform::diana(), Platform::darkside()] {
        let style = if p.name() == "diana" { "resnet" } else { "mobilenet" };
        let layers = microbench_layers(style);
        let (_, ana0, _) = socmap_point(p, &layers, 0.0);
        let (_, ana_hi, det_hi) = socmap_point(p, &layers, lam);
        assert!(ana_hi.total_cycles <= ana0.total_cycles, "{}", p.name());
        assert_eq!(det_hi.utilization.len(), 2, "{}", p.name());
    }
}
