//! Kernel edge-shape and SIMD-agreement tests.
//!
//! The scalar matmul kernels are the engine's bit-identity determinism
//! reference; the `simd-kernels` build must agree with them everywhere.
//! Per element the register tiles accumulate in the same order as the
//! scalar kernels, so the only permitted divergence is signed zeros
//! (the scalar axpy panels skip zero multipliers, the SIMD tiles do
//! not) — hence the matmul comparisons here are small-tolerance, the
//! elementwise helpers exact. Every test drives shapes that hit the
//! panel edges: m not divisible by the 4-row tile, n not divisible by
//! the 16-column tile, k not divisible by the 8 lanes, single rows and
//! single columns. The dispatcher tests run under *both* builds, so the
//! default CI job pins the scalar path and the simd job the tiled one.

use odimo::runtime::native::tensor::{
    axpy_into, matmul_at_into, matmul_bt_into, matmul_into, scale_add_into,
};
use odimo::runtime::native::Tape;

/// Deterministic pseudo-random fill in [-0.5, 0.5), with exact zeros
/// sprinkled in so the scalar skip-zero branches execute.
fn fill(len: usize, seed: u64) -> Vec<f32> {
    let mut st = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
    (0..len)
        .map(|i| {
            st = st
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            if i % 7 == 3 {
                0.0
            } else {
                ((st >> 40) as f32 / (1u64 << 24) as f32) - 0.5
            }
        })
        .collect()
}

fn assert_close(got: &[f32], want: &[f64], tol: f64, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let err = (g as f64 - w).abs();
        assert!(
            err <= tol * (1.0 + w.abs()),
            "{what}[{i}]: got {g}, want {w} (err {err:.3e})"
        );
    }
}

fn naive_mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0f64; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p] as f64;
            for j in 0..n {
                c[i * n + j] += av * b[p * n + j] as f64;
            }
        }
    }
    c
}

fn naive_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            c[i * n + j] = (0..k)
                .map(|p| a[i * k + p] as f64 * b[j * k + p] as f64)
                .sum();
        }
    }
    c
}

fn naive_at(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0f64; k * n];
    for r in 0..m {
        for i in 0..k {
            let av = a[r * k + i] as f64;
            for j in 0..n {
                c[i * n + j] += av * b[r * n + j] as f64;
            }
        }
    }
    c
}

/// Shapes straddling every panel boundary: 4-row tiles, 16-column
/// blocks, 8-lane chunks, plus degenerate 1-row/1-column cases.
const SHAPES: [(usize, usize, usize); 9] = [
    (1, 1, 1),
    (1, 17, 1),
    (3, 8, 16),
    (4, 16, 16),
    (5, 9, 17),
    (7, 23, 31),
    (2, 5, 33),
    (13, 64, 10),
    (6, 144, 20),
];

#[test]
fn matmul_dispatch_matches_naive_on_edge_shapes() {
    for &(m, k, n) in &SHAPES {
        let a = fill(m * k, 11 + (m * 31 + k * 7 + n) as u64);
        let b = fill(k * n, 13 + (m + k * 5 + n * 3) as u64);
        let mut c = vec![0.0f32; m * n];
        matmul_into(&a, &b, &mut c, m, k, n);
        assert_close(&c, &naive_mm(&a, &b, m, k, n), 1e-4, &format!("mm {m}x{k}x{n}"));
    }
}

#[test]
fn matmul_bt_dispatch_matches_naive_on_edge_shapes() {
    for &(m, k, n) in &SHAPES {
        let a = fill(m * k, 17 + (m * 3 + k + n * 11) as u64);
        let b = fill(n * k, 19 + (m + k * 13 + n) as u64);
        let mut c = vec![0.0f32; m * n];
        matmul_bt_into(&a, &b, &mut c, m, k, n);
        assert_close(&c, &naive_bt(&a, &b, m, k, n), 1e-4, &format!("bt {m}x{k}x{n}"));
    }
}

#[test]
fn matmul_at_dispatch_matches_naive_on_edge_shapes() {
    for &(m, k, n) in &SHAPES {
        let a = fill(m * k, 23 + (m * 7 + k * 3 + n) as u64);
        let b = fill(m * n, 29 + (m + k + n * 17) as u64);
        let mut c = vec![0.0f32; k * n];
        matmul_at_into(&a, &b, &mut c, m, k, n);
        assert_close(&c, &naive_at(&a, &b, m, k, n), 1e-4, &format!("at {m}x{k}x{n}"));
    }
}

/// The optimizer helpers must be bit-exact against the plain loops
/// under either build — they run inside the determinism contract.
#[test]
fn elementwise_helpers_are_bit_exact() {
    for &len in &[1usize, 7, 8, 9, 16, 31, 100] {
        let x = fill(len, 41 + len as u64);
        let y0 = fill(len, 43 + len as u64);

        let mut y = y0.clone();
        axpy_into(&mut y, -0.37, &x);
        for (j, (&yv, (&y0v, &xv))) in y.iter().zip(y0.iter().zip(&x)).enumerate() {
            let want = y0v + (-0.37f32) * xv;
            assert_eq!(yv.to_bits(), want.to_bits(), "axpy len {len} elem {j}");
        }

        let mut y = y0.clone();
        scale_add_into(&mut y, 0.9, &x);
        for (j, (&yv, (&y0v, &xv))) in y.iter().zip(y0.iter().zip(&x)).enumerate() {
            let want = 0.9f32 * y0v + xv;
            assert_eq!(yv.to_bits(), want.to_bits(), "scale_add len {len} elem {j}");
        }
    }
}

/// Depthwise conv through the tape with a channel count that divides
/// neither the 8 SIMD lanes nor the 4-row panels, against a naive
/// same-padding reference.
#[test]
fn dw_conv_odd_channels_matches_naive() {
    let (nb, h, w, c, k, stride) = (2usize, 6usize, 6usize, 5usize, 3usize, 2usize);
    let x = fill(nb * h * w * c, 53);
    let wts = fill(c * k * k, 59);
    let mut tape = Tape::new();
    let xv = tape.leaf_copy(vec![nb, h, w, c], &x);
    let wv = tape.leaf_copy(vec![c, k * k], &wts);
    let y = tape.dw_conv2d(xv, wv, k, stride);
    let yv = tape.val(y);

    // same-padding geometry (matches runtime/native/tape.rs)
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let pad = (((oh - 1) * stride + k).saturating_sub(h)) / 2;
    let mut want = vec![0.0f64; nb * oh * ow * c];
    for b in 0..nb {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let mut acc = 0.0f64;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let src = ((b * h + iy as usize) * w + ix as usize) * c + ch;
                            acc += x[src] as f64 * wts[ch * k * k + ky * k + kx] as f64;
                        }
                    }
                    want[((b * oh + oy) * ow + ox) * c + ch] = acc;
                }
            }
        }
    }
    assert_eq!(yv.shape, vec![nb, oh, ow, c]);
    assert_close(&yv.data, &want, 1e-5, "dw conv 5ch");
}

/// The integer GEMM tiers. Unlike the f32 kernels (where only signed
/// zeros may differ), integer adds are associative — every tier must be
/// *exactly* equal on every shape, including the panel edges: 1-row,
/// 1-column, k and n not multiples of the 4-column/8-lane tiles.
mod qmatmul_tiers {
    use odimo::runtime::native::qkernels::{
        pack_b, qmatmul_bt_dequant_into, qmatmul_bt_into, qmatmul_bt_into_blocked,
        qmatmul_bt_into_naive, qmatmul_bt_packed_dequant_into, qmatmul_bt_packed_into,
        qmatmul_bt_packed_into_blocked, quant_packed_len,
    };

    /// Deterministic i8 fill over the full code range (incl. -128 —
    /// the kernels must not assume the ±127 clamp).
    fn fill_i8(len: usize, seed: u64) -> Vec<i8> {
        let mut st = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
        (0..len)
            .map(|_| {
                st = st
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                (st >> 40) as i8
            })
            .collect()
    }

    fn naive_i64(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i64> {
        let mut c = vec![0i64; m * n];
        for i in 0..m {
            for j in 0..n {
                c[i * n + j] = (0..k)
                    .map(|p| a[i * k + p] as i64 * b[j * k + p] as i64)
                    .sum();
            }
        }
        c
    }

    #[test]
    fn all_tiers_exactly_equal_on_panel_edge_shapes() {
        for &(m, k, n) in &super::SHAPES {
            let a = fill_i8(m * k, 101 + (m * 31 + k * 7 + n) as u64);
            let b = fill_i8(n * k, 103 + (m + k * 5 + n * 3) as u64);
            let want = naive_i64(&a, &b, m, k, n);
            let mut naive = vec![0i32; m * n];
            let mut blocked = vec![0i32; m * n];
            let mut dispatch = vec![0i32; m * n];
            qmatmul_bt_into_naive(&a, &b, &mut naive, m, k, n);
            qmatmul_bt_into_blocked(&a, &b, &mut blocked, m, k, n);
            qmatmul_bt_into(&a, &b, &mut dispatch, m, k, n);
            for (i, (&g, &w)) in naive.iter().zip(&want).enumerate() {
                assert_eq!(g as i64, w, "naive {m}x{k}x{n} elem {i}");
            }
            assert_eq!(naive, blocked, "blocked {m}x{k}x{n}");
            assert_eq!(naive, dispatch, "dispatch {m}x{k}x{n}");
            #[cfg(feature = "simd-kernels")]
            {
                use odimo::runtime::native::qkernels::qmatmul_bt_into_simd;
                let mut simd = vec![0i32; m * n];
                qmatmul_bt_into_simd(&a, &b, &mut simd, m, k, n);
                assert_eq!(naive, simd, "simd {m}x{k}x{n}");
            }
        }
    }

    /// The packed tiers (panel-major prepacked B, what a built QuantNet
    /// actually drives) must exactly equal the unpacked naive tier on
    /// every panel-edge shape — including the full-dispatch and arch
    /// entry points, which on hosts without the CPU features (or with
    /// −128 codes on x86) provably fall back and must *still* be exact.
    #[test]
    fn packed_tiers_exactly_equal_unpacked_on_panel_edge_shapes() {
        for &(m, k, n) in &super::SHAPES {
            let a = fill_i8(m * k, 113 + (m * 31 + k * 7 + n) as u64);
            let b = fill_i8(n * k, 127 + (m + k * 5 + n * 3) as u64);
            let pb = pack_b(&b, k, n);
            assert_eq!(pb.data.len(), quant_packed_len(k, n), "pack len {m}x{k}x{n}");
            let mut naive = vec![0i32; m * n];
            qmatmul_bt_into_naive(&a, &b, &mut naive, m, k, n);
            let mut packed = vec![0i32; m * n];
            qmatmul_bt_packed_into_blocked(&a, &pb, &mut packed, m);
            assert_eq!(naive, packed, "packed blocked {m}x{k}x{n}");
            let mut dispatch = vec![0i32; m * n];
            qmatmul_bt_packed_into(&a, &pb, &mut dispatch, m);
            assert_eq!(naive, dispatch, "packed dispatch {m}x{k}x{n}");
            #[cfg(feature = "simd-kernels")]
            {
                use odimo::runtime::native::qkernels::qmatmul_bt_packed_into_simd;
                let mut simd = vec![0i32; m * n];
                qmatmul_bt_packed_into_simd(&a, &pb, &mut simd, m);
                assert_eq!(naive, simd, "packed simd {m}x{k}x{n}");
            }
            #[cfg(feature = "arch-kernels")]
            {
                use odimo::runtime::native::qkernels::qmatmul_bt_packed_into_arch;
                let mut arch = vec![0i32; m * n];
                let ran = qmatmul_bt_packed_into_arch(&a, &pb, &mut arch, m);
                assert_eq!(naive, arch, "packed arch {m}x{k}x{n} (ran={ran})");
                // fill_i8 covers the full i8 range, so most shapes hit a
                // −128 code — the x86 sign-transfer tiers must decline
                #[cfg(target_arch = "x86_64")]
                assert!(
                    !(ran && pb.has_m128),
                    "x86 arch tier must fall back on -128 codes ({m}x{k}x{n})"
                );
            }
            // fused dequant over the packed drive, bitwise
            let dq: Vec<f32> = (0..n).map(|j| 1e-3 * (j + 1) as f32).collect();
            let mut fused = vec![0.0f32; m * n];
            qmatmul_bt_packed_dequant_into(&a, &pb, &mut fused, m, &dq);
            for i in 0..m {
                for j in 0..n {
                    let want = naive[i * n + j] as f32 * dq[j];
                    assert_eq!(
                        fused[i * n + j].to_bits(),
                        want.to_bits(),
                        "packed dequant {m}x{k}x{n} ({i},{j})"
                    );
                }
            }
        }
    }

    /// Adversarial saturation-edge suite: inputs chosen so an i16
    /// intermediate would saturate (or `sign_epi8` would wrap) if the
    /// arch kernels' exactness arguments were wrong anywhere. Every
    /// entry point must match the i64 reference exactly — on AVX2/NEON
    /// hosts the arch kernels actually run for the −128-free patterns;
    /// elsewhere (and for −128-containing B on x86) the dispatch falls
    /// back, which must be just as exact.
    #[test]
    fn saturation_edges_exactly_match_i64_reference() {
        // k straddles the 8-lane granule, n straddles the 4-col panel
        const EDGE_SHAPES: [(usize, usize, usize); 6] = [
            (3, 8, 4),
            (2, 9, 5),
            (4, 16, 3),
            (1, 23, 6),
            (5, 40, 7),
            (2, 7, 1),
        ];
        type Gen = fn(usize) -> i8;
        // (label, a pattern, b pattern)
        let patterns: [(&str, Gen, Gen); 5] = [
            // B has −128 → x86 arch tiers must decline, fallback exact
            ("all_m128", |_| -128, |_| -128),
            // max positive maddubs pair sums: 2·127·127 = 32258 < 32767
            ("pos127", |_| 127, |_| 127),
            // arch path RUNS (B is −128-free): |a|=128 × 127 pairs give
            // the extreme −32512/+32512 intermediates
            ("m128_a_127_b", |_| -128, |_| 127),
            // alternating ±127 both sides
            (
                "alt",
                |i| if i % 2 == 0 { 127 } else { -127 },
                |i| if i % 2 == 0 { -127 } else { 127 },
            ),
            // −128 sprinkled into B only → fallback, exact
            (
                "m128_b_only",
                |i| if i % 3 == 0 { 1 } else { -1 },
                |i| if i % 5 == 0 { -128 } else { 7 },
            ),
        ];
        for &(m, k, n) in &EDGE_SHAPES {
            for &(label, fa, fb) in &patterns {
                let a: Vec<i8> = (0..m * k).map(fa).collect();
                let b: Vec<i8> = (0..n * k).map(fb).collect();
                let want = naive_i64(&a, &b, m, k, n);
                // k ≤ 40 → |dot| ≤ 40·128² < i32::MAX: i32 tiers can
                // represent every reference value exactly
                let mut got = vec![0i32; m * n];
                qmatmul_bt_into(&a, &b, &mut got, m, k, n);
                for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(g as i64, w, "{label} unpacked {m}x{k}x{n} elem {i}");
                }
                let pb = pack_b(&b, k, n);
                let mut got = vec![0i32; m * n];
                qmatmul_bt_packed_into(&a, &pb, &mut got, m);
                for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(g as i64, w, "{label} packed {m}x{k}x{n} elem {i}");
                }
                #[cfg(feature = "arch-kernels")]
                {
                    use odimo::runtime::native::qkernels::qmatmul_bt_packed_into_arch;
                    let mut got = vec![0i32; m * n];
                    let ran = qmatmul_bt_packed_into_arch(&a, &pb, &mut got, m);
                    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                        assert_eq!(g as i64, w, "{label} arch(ran={ran}) {m}x{k}x{n} elem {i}");
                    }
                    #[cfg(target_arch = "x86_64")]
                    assert!(
                        !(ran && pb.has_m128),
                        "{label}: x86 arch tier must fall back on -128 codes"
                    );
                }
            }
        }
    }

    /// The fused dequant kernel is the same tier sweep with one f32
    /// multiply per finished accumulator — bit-identical to scaling the
    /// plain integer output.
    #[test]
    fn dequant_kernel_matches_scaled_integer_output_bitwise() {
        for &(m, k, n) in &super::SHAPES {
            let a = fill_i8(m * k, 107 + (m * 3 + k + n * 11) as u64);
            let b = fill_i8(n * k, 109 + (m + k * 13 + n) as u64);
            // include a pruned-style zero scale
            let dq: Vec<f32> = (0..n)
                .map(|j| if j % 5 == 4 { 0.0 } else { 1e-3 * (j + 1) as f32 })
                .collect();
            let mut ints = vec![0i32; m * n];
            qmatmul_bt_into_naive(&a, &b, &mut ints, m, k, n);
            let mut fused = vec![0.0f32; m * n];
            qmatmul_bt_dequant_into(&a, &b, &mut fused, m, k, n, &dq);
            for i in 0..m {
                for j in 0..n {
                    let want = ints[i * n + j] as f32 * dq[j];
                    assert_eq!(
                        fused[i * n + j].to_bits(),
                        want.to_bits(),
                        "dequant {m}x{k}x{n} ({i},{j})"
                    );
                }
            }
        }
    }
}

/// The packed-panel f32 training tiers. Packing is a pure relayout
/// whose edge pads are exactly `0.0` and never enter a stored element's
/// accumulation chain, so — unlike the scalar-vs-SIMD comparisons,
/// where axpy signed zeros may differ — packed and unpacked must agree
/// *bitwise* under both builds, on every panel-edge shape, signed zeros
/// included. `fill` sprinkles exact zeros so the scalar skip-zero
/// branches execute on both sides of each comparison.
mod packed_f32_tiers {
    use std::sync::Arc;

    use super::{assert_close, fill, naive_bt, naive_mm, SHAPES};
    use odimo::runtime::native::tensor::{
        bt_packed_len, matmul_at_into, matmul_bt_into, matmul_bt_packed_into, matmul_into,
        matmul_packed_into, mm_packed_len, pack_bt_into, pack_mm_into,
        par_matmul_at_into_packed, par_matmul_bt_packed_into, par_matmul_packed_into,
    };
    use odimo::runtime::native::{PackHandle, WeightPackSlot, WorkerPool};

    #[test]
    fn packed_bt_is_bit_identical_to_unpacked_dispatch() {
        for &(m, k, n) in &SHAPES {
            let a = fill(m * k, 131 + (m * 31 + k * 7 + n) as u64);
            let b = fill(n * k, 137 + (m + k * 5 + n * 3) as u64);
            let mut want = vec![0.0f32; m * n];
            matmul_bt_into(&a, &b, &mut want, m, k, n);
            // NAN canary: packing must overwrite every position, pads
            // included — a surviving NAN means an unwritten pad slot
            let mut pb = vec![f32::NAN; bt_packed_len(k, n)];
            pack_bt_into(&b, k, n, &mut pb);
            assert!(
                pb.iter().all(|x| !x.is_nan()),
                "bt pack left unwritten slots at {m}x{k}x{n}"
            );
            let mut got = vec![0.0f32; m * n];
            matmul_bt_packed_into(&a, &pb, &mut got, m, k, n);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "packed bt {m}x{k}x{n} elem {i}");
            }
            assert_close(
                &got,
                &naive_bt(&a, &b, m, k, n),
                1e-4,
                &format!("packed bt vs naive {m}x{k}x{n}"),
            );
        }
    }

    #[test]
    fn packed_mm_is_bit_identical_to_unpacked_dispatch() {
        for &(m, k, n) in &SHAPES {
            let a = fill(m * k, 139 + (m * 3 + k + n * 11) as u64);
            let b = fill(k * n, 149 + (m + k * 13 + n) as u64);
            let mut want = vec![0.0f32; m * n];
            matmul_into(&a, &b, &mut want, m, k, n);
            let mut pb = vec![f32::NAN; mm_packed_len(k, n)];
            pack_mm_into(&b, k, n, &mut pb);
            assert!(
                pb.iter().all(|x| !x.is_nan()),
                "mm pack left unwritten slots at {m}x{k}x{n}"
            );
            let mut got = vec![0.0f32; m * n];
            matmul_packed_into(&a, &pb, &mut got, m, k, n);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "packed mm {m}x{k}x{n} elem {i}");
            }
            assert_close(
                &got,
                &naive_mm(&a, &b, m, k, n),
                1e-4,
                &format!("packed mm vs naive {m}x{k}x{n}"),
            );
        }
    }

    /// The packed at tier transposes a column panel and runs the mm
    /// register tile on it — per output element the accumulation over
    /// `r` keeps the unpacked kernel's order and skip-zero behavior, so
    /// serial unpacked vs lane-sharded packed is bitwise too.
    #[test]
    fn par_packed_tiers_are_bit_identical_for_any_lane_count() {
        for &(m, k, n) in &SHAPES {
            let a = fill(m * k, 151 + (m * 7 + k * 3 + n) as u64);
            let bt = fill(n * k, 157 + (m + k + n * 17) as u64);
            let bm = fill(k * n, 163 + (m * 5 + k + n) as u64);
            let bat = fill(m * n, 167 + (m + k * 11 + n) as u64);
            let mut pbt = vec![0.0f32; bt_packed_len(k, n)];
            pack_bt_into(&bt, k, n, &mut pbt);
            let mut pbm = vec![0.0f32; mm_packed_len(k, n)];
            pack_mm_into(&bm, k, n, &mut pbm);
            let mut want_bt = vec![0.0f32; m * n];
            matmul_bt_packed_into(&a, &pbt, &mut want_bt, m, k, n);
            let mut want_mm = vec![0.0f32; m * n];
            matmul_packed_into(&a, &pbm, &mut want_mm, m, k, n);
            let mut want_at = vec![0.0f32; k * n];
            matmul_at_into(&a, &bat, &mut want_at, m, k, n);
            for &t in &[1usize, 2, 3, 5] {
                let pool = WorkerPool::new(t);
                let got = pool.run_tasks(1, &|_, scope| {
                    let mut gbt = vec![0.0f32; m * n];
                    par_matmul_bt_packed_into(&a, &pbt, &mut gbt, m, k, n, scope);
                    let mut gmm = vec![0.0f32; m * n];
                    par_matmul_packed_into(&a, &pbm, &mut gmm, m, k, n, scope);
                    let mut gat = vec![0.0f32; k * n];
                    let mut pack = vec![0.0f32; k * m];
                    par_matmul_at_into_packed(&a, &bat, &mut gat, m, k, n, scope, &mut pack);
                    (gbt, gmm, gat)
                });
                let (gbt, gmm, gat) = &got[0];
                for (i, (g, w)) in gbt.iter().zip(&want_bt).enumerate() {
                    assert_eq!(g.to_bits(), w.to_bits(), "par bt t={t} {m}x{k}x{n} elem {i}");
                }
                for (i, (g, w)) in gmm.iter().zip(&want_mm).enumerate() {
                    assert_eq!(g.to_bits(), w.to_bits(), "par mm t={t} {m}x{k}x{n} elem {i}");
                }
                for (i, (g, w)) in gat.iter().zip(&want_at).enumerate() {
                    assert_eq!(g.to_bits(), w.to_bits(), "par at t={t} {m}x{k}x{n} elem {i}");
                }
            }
        }
    }

    /// Signed zeros: `-0.0 + 0.0 = +0.0`, so a pad sneaking into any
    /// accumulation chain would flip a stored `-0.0`. Craft inputs full
    /// of `±0.0` on pad-straddling shapes and require exact bits.
    #[test]
    fn signed_zeros_survive_packing() {
        let (m, k, n) = (3usize, 5usize, 6usize); // k%8≠0, n%4≠0, n%16≠0
        let mut a = fill(m * k, 173);
        for (i, v) in a.iter_mut().enumerate().take(k) {
            *v = if i % 2 == 0 { -0.0 } else { 0.0 };
        }
        let mut bt = fill(n * k, 179);
        for v in bt.iter_mut().step_by(3) {
            *v = -0.0;
        }
        let mut want = vec![0.0f32; m * n];
        matmul_bt_into(&a, &bt, &mut want, m, k, n);
        let mut pb = vec![0.0f32; bt_packed_len(k, n)];
        pack_bt_into(&bt, k, n, &mut pb);
        let mut got = vec![0.0f32; m * n];
        matmul_bt_packed_into(&a, &pb, &mut got, m, k, n);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "signed-zero bt elem {i}");
        }

        let mut bm = fill(k * n, 181);
        for v in bm.iter_mut().step_by(4) {
            *v = -0.0;
        }
        let mut want = vec![0.0f32; m * n];
        matmul_into(&a, &bm, &mut want, m, k, n);
        let mut pb = vec![0.0f32; mm_packed_len(k, n)];
        pack_mm_into(&bm, k, n, &mut pb);
        let mut got = vec![0.0f32; m * n];
        matmul_packed_into(&a, &pb, &mut got, m, k, n);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "signed-zero mm elem {i}");
        }
    }

    /// A [`WeightPackSlot`] guard must hold exactly the two layouts a
    /// direct pack of the weight produces: `bt` serves the GEMMs that
    /// contract over `cols` (conv forward, FC dA), `mm` the ones that
    /// contract over `rows` (conv dX, FC forward).
    #[test]
    fn weight_pack_slot_guard_matches_direct_packs() {
        let (rows, cols) = (10usize, 21usize); // rows%4≠0, cols%8≠0
        let w = fill(rows * cols, 191);
        let slot = Arc::new(WeightPackSlot::new(rows, cols));
        let handle = PackHandle::new(slot, 1, rows, cols);
        let guard = handle.packed(&w);
        let mut bt = vec![0.0f32; bt_packed_len(cols, rows)];
        pack_bt_into(&w, cols, rows, &mut bt);
        assert_eq!(guard.bt().len(), bt.len());
        for (i, (g, d)) in guard.bt().iter().zip(&bt).enumerate() {
            assert_eq!(g.to_bits(), d.to_bits(), "slot bt elem {i}");
        }
        let mut mm = vec![0.0f32; mm_packed_len(rows, cols)];
        pack_mm_into(&w, rows, cols, &mut mm);
        assert_eq!(guard.mm().len(), mm.len());
        for (i, (g, d)) in guard.mm().iter().zip(&mm).enumerate() {
            assert_eq!(g.to_bits(), d.to_bits(), "slot mm elem {i}");
        }
    }
}

#[cfg(feature = "simd-kernels")]
mod simd_vs_scalar {
    use super::{fill, SHAPES};
    use odimo::runtime::native::tensor::{
        matmul_at_into_scalar, matmul_bt_into_scalar, matmul_into_scalar, simd,
    };

    /// The bt kernel shares the scalar `dot`'s chunk/halving-tree/
    /// remainder recipe per output element — bit-identical, not merely
    /// close.
    #[test]
    fn bt_kernel_is_bit_identical_to_scalar() {
        for &(m, k, n) in &SHAPES {
            let a = fill(m * k, 61 + (m + k + n) as u64);
            let b = fill(n * k, 67 + (m * k) as u64);
            let mut cs = vec![0.0f32; m * n];
            let mut cv = vec![0.0f32; m * n];
            matmul_bt_into_scalar(&a, &b, &mut cs, m, k, n);
            simd::matmul_bt_into(&a, &b, &mut cv, m, k, n);
            for (i, (s, v)) in cs.iter().zip(&cv).enumerate() {
                assert_eq!(s.to_bits(), v.to_bits(), "bt {m}x{k}x{n} elem {i}");
            }
        }
    }

    /// The axpy-panel kernels keep per-element accumulation order, so
    /// scalar and SIMD agree to (at most) signed-zero differences —
    /// compared here with a zero-tolerance absolute check.
    #[test]
    fn axpy_kernels_match_scalar_exactly_in_value() {
        for &(m, k, n) in &SHAPES {
            let a = fill(m * k, 71 + (m * 5 + k + n) as u64);
            let b = fill(k * n, 73 + (m + k + n * 7) as u64);
            let mut cs = vec![0.0f32; m * n];
            let mut cv = vec![0.0f32; m * n];
            matmul_into_scalar(&a, &b, &mut cs, m, k, n);
            simd::matmul_into(&a, &b, &mut cv, m, k, n);
            for (i, (s, v)) in cs.iter().zip(&cv).enumerate() {
                assert_eq!(*s, *v, "mm {m}x{k}x{n} elem {i} (values, not bits)");
            }

            let bt = fill(m * n, 79 + (m + k * 3 + n) as u64);
            let mut cs = vec![0.0f32; k * n];
            let mut cv = vec![0.0f32; k * n];
            matmul_at_into_scalar(&a, &bt, &mut cs, m, k, n);
            simd::matmul_at_into(&a, &bt, &mut cv, m, k, n);
            for (i, (s, v)) in cs.iter().zip(&cv).enumerate() {
                assert_eq!(*s, *v, "at {m}x{k}x{n} elem {i} (values, not bits)");
            }
        }
    }

    /// Elementwise lane helpers vs their scalar loops at lengths around
    /// the 8-lane boundary — exact bits.
    #[test]
    fn elementwise_slices_are_bit_identical() {
        for &len in &[1usize, 7, 8, 9, 15, 16, 17, 64, 100] {
            let x = fill(len, 83 + len as u64);
            let w = fill(len, 89 + len as u64);
            let y0 = fill(len, 97 + len as u64);

            let mut ys = y0.clone();
            let mut yv = y0.clone();
            for ((a, &b), &c) in ys.iter_mut().zip(&x).zip(&w) {
                *a += b * c;
            }
            simd::fma_slice(&mut yv, &x, &w);
            assert_eq!(ys, yv, "fma len {len}");

            let mut ys = y0.clone();
            let mut yv = y0.clone();
            for (a, &b) in ys.iter_mut().zip(&x) {
                *a += 0.25 * b;
            }
            simd::axpy_slice(&mut yv, 0.25, &x);
            assert_eq!(ys, yv, "axpy len {len}");

            let mut os = vec![0.0f32; len];
            let mut ov = vec![0.0f32; len];
            for (i, o) in os.iter_mut().enumerate() {
                *o = (x[i] - w[i]) * y0[i];
            }
            simd::sub_mul_slice(&mut ov, &x, &w, &y0);
            assert_eq!(os, ov, "sub_mul len {len}");

            let mut os = vec![0.0f32; len];
            let mut ov = vec![0.0f32; len];
            for (i, o) in os.iter_mut().enumerate() {
                *o = x[i] * w[i] + y0[i];
            }
            simd::affine_slice(&mut ov, &x, &w, &y0);
            assert_eq!(os, ov, "affine len {len}");
        }
    }
}
