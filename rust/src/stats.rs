//! Statistics helpers for the hardware-model validation (paper Table III):
//! mean absolute percentage error, Pearson and Spearman correlation.

/// Mean absolute percentage error of `pred` w.r.t. `truth`, in percent.
/// Entries with `truth == 0` are skipped.
pub fn mape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mut s = 0.0;
    let mut n = 0usize;
    for (&p, &t) in pred.iter().zip(truth) {
        if t != 0.0 {
            s += ((p - t) / t).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        100.0 * s / n as f64
    }
}

/// Pearson linear correlation coefficient.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    if n < 2.0 {
        return 1.0;
    }
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (&x, &y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    if va == 0.0 || vb == 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Fractional ranks with ties averaged (the convention Spearman needs).
pub fn ranks(v: &[f64]) -> Vec<f64> {
    let n = v.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| v[i].partial_cmp(&v[j]).unwrap());
    let mut r = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && v[idx[j + 1]] == v[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            r[k] = avg;
        }
        i = j + 1;
    }
    r
}

/// Spearman rank correlation coefficient.
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    pearson(&ranks(a), &ranks(b))
}

/// Arithmetic mean.
pub fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect_linear() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [-2.0, -4.0, -6.0, -8.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [1.0, 8.0, 27.0, 64.0, 125.0]; // monotone, nonlinear
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
        assert!(pearson(&a, &b) < 1.0);
    }

    #[test]
    fn ranks_handle_ties() {
        let r = ranks(&[1.0, 2.0, 2.0, 3.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn mape_basic() {
        assert!((mape(&[90.0, 110.0], &[100.0, 100.0]) - 10.0).abs() < 1e-12);
        // zero-truth entries are skipped
        assert!((mape(&[90.0, 5.0], &[100.0, 0.0]) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(pearson(&[1.0], &[2.0]), 1.0);
        assert_eq!(pearson(&[1.0, 1.0], &[1.0, 2.0]), 0.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
