//! Dataset substrate.
//!
//! The paper evaluates on CIFAR-10/CIFAR-100/ImageNet; those corpora are
//! not available in this environment, so [`synth`] provides deterministic
//! synthetic classification datasets with the properties the experiments
//! actually rely on (see DESIGN.md §2): a tunable accuracy gap under
//! weight ternarization / depthwise substitution, and difficulty that
//! grows with class count and resolution.

pub mod rng;
pub mod synth;

pub use synth::{Split, SynthDataset};
