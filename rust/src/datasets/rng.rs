//! Small deterministic RNG (SplitMix64 + xoshiro256**), dependency-free.
//!
//! Dataset generation must be bit-reproducible across runs and machines so
//! that every experiment in EXPERIMENTS.md can be regenerated exactly;
//! this is a fixed, seeded generator rather than `rand` to keep that
//! guarantee independent of crate versions.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream from (seed, stream ids) — used to key
    /// per-(split, batch) sample generation.
    pub fn from_stream(seed: u64, a: u64, b: u64) -> Self {
        Self::new(
            seed ^ a.wrapping_mul(0xA24BAED4963EE407) ^ b.wrapping_mul(0x9FB21C651E98DF25),
        )
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-7);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::from_stream(1, 0, 0);
        let mut b = Rng::from_stream(1, 0, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 20000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.uniform(-2.0, 5.0);
            assert!((-2.0..5.0).contains(&v));
            let k = r.below(10);
            assert!(k < 10);
        }
    }
}
