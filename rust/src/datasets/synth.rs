//! Deterministic synthetic image-classification datasets.
//!
//! Stand-ins for CIFAR-10 / CIFAR-100 / ImageNet (DESIGN.md §2). Each class
//! owns a smooth low-frequency prototype (a seeded coarse grid, bilinearly
//! upsampled to the target resolution); a sample is
//!
//! ```text
//!   x = contrast · P_class  +  σ · noise  +  brightness
//! ```
//!
//! with per-sample contrast/brightness jitter and optional horizontal
//! flips. The signal-to-noise knob `sigma` plus the class count reproduce
//! the property the experiments need: harder tasks (more classes, more
//! noise) lose measurably more accuracy under ternarization or depthwise
//! substitution, so the Pareto trade-off the paper studies actually
//! exists.
//!
//! Samples are generated on the fly, keyed by `(seed, split, batch_index)`
//! — no storage, perfectly reproducible, and every batch is distinct.

use super::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Split {
    Train,
    Val,
    Test,
}

impl Split {
    fn stream_id(self) -> u64 {
        match self {
            Split::Train => 0x1111,
            Split::Val => 0x2222,
            Split::Test => 0x3333,
        }
    }
}

/// A synthetic classification dataset (NHWC f32 images, i32 labels).
#[derive(Debug, Clone)]
pub struct SynthDataset {
    pub hw: usize,
    pub classes: usize,
    pub sigma: f32,
    seed: u64,
    /// per-class prototypes, each `hw*hw*3`
    protos: Vec<Vec<f32>>,
}

/// Coarse-grid resolution of the class prototypes.
const PROTO_GRID: usize = 8;

impl SynthDataset {
    /// `name` follows the manifest dataset names ("synth-cifar10", ...).
    pub fn from_name(name: &str, hw: usize, classes: usize, seed: u64) -> Self {
        // noise level tuned per task family: more classes -> naturally
        // harder; sigma adds the quantization-sensitivity headroom.
        let sigma = match name {
            "synth-cifar10" => 0.9,
            "synth-cifar100" => 1.1,
            "synth-imagenet" => 1.3,
            _ => 1.0,
        };
        Self::new(hw, classes, sigma, seed)
    }

    pub fn new(hw: usize, classes: usize, sigma: f32, seed: u64) -> Self {
        let mut protos = Vec::with_capacity(classes);
        for c in 0..classes {
            let mut rng = Rng::from_stream(seed, 0xBEEF, c as u64);
            protos.push(Self::make_proto(hw, &mut rng));
        }
        Self {
            hw,
            classes,
            sigma,
            seed,
            protos,
        }
    }

    /// Low-frequency prototype: PROTO_GRID² control points per channel,
    /// bilinearly upsampled, normalized to zero mean / unit variance.
    fn make_proto(hw: usize, rng: &mut Rng) -> Vec<f32> {
        let g = PROTO_GRID;
        let mut grid = vec![0.0f32; g * g * 3];
        for v in grid.iter_mut() {
            *v = rng.normal();
        }
        let mut img = vec![0.0f32; hw * hw * 3];
        let scale = (g - 1) as f32 / (hw - 1).max(1) as f32;
        for y in 0..hw {
            for x in 0..hw {
                let fy = y as f32 * scale;
                let fx = x as f32 * scale;
                let y0 = (fy as usize).min(g - 2);
                let x0 = (fx as usize).min(g - 2);
                let dy = fy - y0 as f32;
                let dx = fx - x0 as f32;
                for ch in 0..3 {
                    let at = |yy: usize, xx: usize| grid[(yy * g + xx) * 3 + ch];
                    let v = at(y0, x0) * (1.0 - dy) * (1.0 - dx)
                        + at(y0, x0 + 1) * (1.0 - dy) * dx
                        + at(y0 + 1, x0) * dy * (1.0 - dx)
                        + at(y0 + 1, x0 + 1) * dy * dx;
                    img[(y * hw + x) * 3 + ch] = v;
                }
            }
        }
        // normalize
        let n = img.len() as f32;
        let mean = img.iter().sum::<f32>() / n;
        let var = img.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
        let inv = 1.0 / var.sqrt().max(1e-6);
        for v in img.iter_mut() {
            *v = (*v - mean) * inv;
        }
        img
    }

    /// Generate batch `index` of `split`: returns `(x NHWC, y)`.
    pub fn batch(&self, split: Split, index: u64, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let mut x = vec![0.0f32; batch * self.hw * self.hw * 3];
        let mut y = vec![0i32; batch];
        let px = self.hw * self.hw * 3;
        for b in 0..batch {
            let mut rng = Rng::from_stream(
                self.seed ^ split.stream_id(),
                index,
                b as u64,
            );
            let cls = rng.below(self.classes);
            y[b] = cls as i32;
            let contrast = rng.uniform(0.8, 1.2);
            let brightness = 0.15 * rng.normal();
            let flip = rng.next_f32() < 0.5;
            let proto = &self.protos[cls];
            let dst = &mut x[b * px..(b + 1) * px];
            for yy in 0..self.hw {
                for xx in 0..self.hw {
                    let sx = if flip { self.hw - 1 - xx } else { xx };
                    for ch in 0..3 {
                        let v = proto[(yy * self.hw + sx) * 3 + ch];
                        dst[(yy * self.hw + xx) * 3 + ch] =
                            contrast * v + self.sigma * rng.normal() + brightness;
                    }
                }
            }
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_batches() {
        let ds = SynthDataset::new(16, 10, 1.0, 42);
        let (x1, y1) = ds.batch(Split::Train, 3, 8);
        let (x2, y2) = ds.batch(Split::Train, 3, 8);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn batches_differ_by_index_and_split() {
        let ds = SynthDataset::new(16, 10, 1.0, 42);
        let (x1, _) = ds.batch(Split::Train, 0, 8);
        let (x2, _) = ds.batch(Split::Train, 1, 8);
        let (x3, _) = ds.batch(Split::Test, 0, 8);
        assert_ne!(x1, x2);
        assert_ne!(x1, x3);
    }

    #[test]
    fn labels_cover_classes() {
        let ds = SynthDataset::new(8, 10, 1.0, 7);
        let (_, y) = ds.batch(Split::Train, 0, 512);
        let mut seen = [false; 10];
        for &l in &y {
            assert!((0..10).contains(&l));
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all classes sampled in 512 draws");
    }

    #[test]
    fn shapes_and_finiteness() {
        let ds = SynthDataset::new(32, 100, 1.2, 1);
        let (x, y) = ds.batch(Split::Val, 5, 4);
        assert_eq!(x.len(), 4 * 32 * 32 * 3);
        assert_eq!(y.len(), 4);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn prototypes_are_normalized() {
        let ds = SynthDataset::new(32, 5, 1.0, 9);
        for p in &ds.protos {
            let n = p.len() as f32;
            let mean = p.iter().sum::<f32>() / n;
            let var = p.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
            assert!(mean.abs() < 1e-3);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }
}
