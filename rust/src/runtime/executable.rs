//! PJRT executable wrapper: load HLO text → compile → typed execute.
//!
//! Follows the reference wiring in `/opt/xla-example/load_hlo`: artifacts
//! are HLO **text** (jax ≥ 0.5 emits protos with 64-bit instruction ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids), and
//! every lowered function returns one tuple (lowered with
//! `return_tuple=True`) which we decompose back into per-output literals.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use super::manifest::FunctionSpec;

/// A compiled AOT function plus its manifest signature.
pub struct LoadedFn {
    pub name: String,
    pub spec: FunctionSpec,
    exe: PjRtLoadedExecutable,
    /// cumulative wall time spent inside `call` (profiling aid)
    pub exec_nanos: std::cell::Cell<u128>,
    pub calls: std::cell::Cell<u64>,
}

impl LoadedFn {
    pub fn load(
        client: &PjRtClient,
        name: &str,
        path: &Path,
        spec: FunctionSpec,
    ) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
            anyhow!("loading HLO text {}: {e:?}", path.display())
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", name))?;
        Ok(Self {
            name: name.to_string(),
            spec,
            exe,
            exec_nanos: std::cell::Cell::new(0),
            calls: std::cell::Cell::new(0),
        })
    }

    /// Execute with literal inputs; returns the decomposed output tuple.
    /// Accepts owned or borrowed literals.
    pub fn call<L: std::borrow::Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Literal>> {
        if args.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                args.len()
            ));
        }
        let t0 = Instant::now();
        let bufs = self
            .exe
            .execute(args)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.name))?;
        let tuple = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {} output: {e:?}", self.name))?;
        let outs = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untupling {} output: {e:?}", self.name))?;
        self.exec_nanos
            .set(self.exec_nanos.get() + t0.elapsed().as_nanos());
        self.calls.set(self.calls.get() + 1);
        if outs.len() != self.spec.outputs.len() {
            return Err(anyhow!(
                "{}: manifest promises {} outputs, executable returned {}",
                self.name,
                self.spec.outputs.len(),
                outs.len()
            ));
        }
        Ok(outs)
    }

    /// Mean wall-clock per call so far, in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        let c = self.calls.get().max(1);
        self.exec_nanos.get() as f64 / 1e6 / c as f64
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

/// Build an f32 literal of the given shape.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    if dims.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape f32 literal: {e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    if dims.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape i32 literal: {e:?}"))
}

pub fn lit_scalar_f32(v: f32) -> Literal {
    Literal::scalar(v)
}

pub fn lit_scalar_i32(v: i32) -> Literal {
    Literal::scalar(v)
}

pub fn to_vec_f32(l: &Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("literal to f32 vec: {e:?}"))
}
