//! Runtime layer: the training-backend boundary.
//!
//! The coordinator never constructs computations — it drives a
//! [`ModelBackend`] through the four functions every model variant
//! provides (`init` / `train` / `eval` / `cost`). Two backends implement
//! the trait:
//!
//! * [`native`] — the pure-Rust engine: an f32 tensor + reverse-mode
//!   autodiff core and a K-column supernet builder that constructs the
//!   search spaces directly from the layer table and the platform
//!   registry. No artifacts, no XLA — `repro sweep --backend native`
//!   works with `cargo run` alone, on any registered SoC.
//! * [`ModelRuntime`] — the XLA/PJRT artifact loader: manifest-driven
//!   HLO-text compile + typed execute of the AOT executables produced by
//!   `make artifacts` (see [`manifest`], [`executable`]).
//!
//! [`TrainState`] is deliberately backend-neutral (named host `f32`
//! leaves): the phase logic in `coordinator` snapshots, restores, freezes
//! and discretizes θ without knowing which engine computes the gradients.
//! Pick an implementation with [`load_backend`]; [`default_backend`]
//! chooses `native` unless AOT artifacts exist for the variant.

pub mod executable;
pub mod manifest;
pub mod native;

use std::path::Path;

use anyhow::{anyhow, bail, Result};
use xla::{Literal, PjRtClient};

pub use executable::{lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32, to_vec_f32, LoadedFn};
pub use manifest::{IoSpec, LayerSpec, Manifest};
pub use native::{NativeBackend, NativeOptions, WOptimizer};

/// Train-loop hyper-scalars fed to every `train` call.
#[derive(Debug, Clone, Copy)]
pub struct StepHparams {
    /// cost strength λ (Eq. 1); 0 during warmup/final-training
    pub lam: f32,
    /// 0 = latency target (Eq. 3), 1 = energy target (Eq. 4)
    pub cost_sel: f32,
    pub lr_w: f32,
    pub lr_th: f32,
}

/// Mutable training state: params + optimizer state as named host-side
/// `f32` buffers, kept in the backend's flattening order so they loop
/// straight back into the next `train` call. Backend-neutral: the phase
/// logic (freeze, discretize, snapshot/restore) works on this type alone.
pub struct TrainState {
    pub leaves: Vec<Vec<f32>>,
    /// names parallel to `leaves` (from the backend's state signature)
    pub names: Vec<String>,
}

impl TrainState {
    pub fn leaf_index(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    /// Fetch a named leaf as f32 host data.
    pub fn leaf_f32(&self, name: &str) -> Result<Vec<f32>> {
        let i = self
            .leaf_index(name)
            .ok_or_else(|| anyhow!("no state leaf '{name}'"))?;
        Ok(self.leaves[i].clone())
    }

    /// Replace a named leaf (e.g. freezing θ to a discretized one-hot).
    pub fn set_leaf_f32(&mut self, name: &str, shape: &[usize], data: &[f32]) -> Result<()> {
        let i = self
            .leaf_index(name)
            .ok_or_else(|| anyhow!("no state leaf '{name}'"))?;
        let want: usize = shape.iter().product();
        if want != data.len() || data.len() != self.leaves[i].len() {
            return Err(anyhow!(
                "leaf '{name}': shape {shape:?} / data {} does not match existing {} elements",
                data.len(),
                self.leaves[i].len()
            ));
        }
        self.leaves[i] = data.to_vec();
        Ok(())
    }

    /// Snapshot the raw f32 contents of every leaf (checkpointing).
    pub fn snapshot(&self) -> Vec<Vec<f32>> {
        self.leaves.clone()
    }

    /// Restore from a snapshot taken on an identically-shaped state.
    pub fn restore(&mut self, snap: &[Vec<f32>]) -> Result<()> {
        if snap.len() != self.leaves.len() {
            return Err(anyhow!(
                "snapshot has {} leaves, state has {}",
                snap.len(),
                self.leaves.len()
            ));
        }
        for (leaf, data) in self.leaves.iter_mut().zip(snap) {
            if leaf.len() != data.len() {
                return Err(anyhow!("snapshot leaf size mismatch"));
            }
            leaf.clone_from(data);
        }
        Ok(())
    }
}

/// One training engine for one model variant — the boundary the
/// coordinator programs against. Batches cross as host `f32`/`i32`
/// buffers (NHWC images, label vector); the backend owns device
/// transfer, graph construction and differentiation.
pub trait ModelBackend {
    /// Backend family name ("native" | "xla").
    fn backend_name(&self) -> &'static str;

    /// Static model metadata: layer table, dataset, cost scale, platform.
    fn manifest(&self) -> &Manifest;

    /// Name/shape of every state leaf, in flattening order.
    fn state_specs(&self) -> &[IoSpec];

    /// Build the initial [`TrainState`] from a seed.
    fn init_state(&self, seed: i32) -> Result<TrainState>;

    /// One training step; advances `state` in place and returns the metric
    /// vector `[loss, ce, acc, cost_lat_cycles, cost_energy_uj]`.
    fn train_step(
        &self,
        state: &mut TrainState,
        x: &[f32],
        y: &[i32],
        hp: StepHparams,
    ) -> Result<Vec<f32>>;

    /// Evaluate one batch (inference mode): returns `[correct, loss_sum]`.
    fn eval_batch(&self, state: &TrainState, x: &[f32], y: &[i32]) -> Result<Vec<f32>>;

    /// Cost report from current θ: `(layer matrix row-major, totals
    /// [latency_cycles, energy_uj])`. The XLA artifacts emit `[L, 4]`
    /// two-CU rows; the native engine emits `[L, 2K]` rows
    /// (`n_0..n_{K-1}, cyc_0..cyc_{K-1}`) for a K-CU platform.
    fn cost_report(&self, state: &TrainState) -> Result<(Vec<f32>, Vec<f32>)>;

    fn batch(&self) -> usize {
        self.manifest().dataset.batch
    }

    fn state_len(&self) -> usize {
        self.state_specs().len()
    }
}

/// Which training engine to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// pure-Rust tensor/autodiff engine (no artifacts needed)
    Native,
    /// AOT XLA artifacts through PJRT (requires `make artifacts` and real
    /// `xla_extension` bindings)
    Xla,
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<BackendKind> {
        match s {
            "native" => Ok(BackendKind::Native),
            "xla" => Ok(BackendKind::Xla),
            other => bail!("unknown backend '{other}' (expected native|xla)"),
        }
    }
}

/// Default engine for a variant: XLA when its AOT artifacts exist (they
/// were built deliberately), the native engine otherwise.
pub fn default_backend(artifacts: &Path, variant: &str) -> BackendKind {
    if artifacts.join(format!("{variant}.manifest.json")).exists() {
        BackendKind::Xla
    } else {
        BackendKind::Native
    }
}

/// Construct a backend for `variant` with default execution options.
pub fn load_backend(
    kind: BackendKind,
    artifacts: &Path,
    variant: &str,
) -> Result<Box<dyn ModelBackend>> {
    load_backend_with(kind, artifacts, variant, NativeOptions::default())
}

/// Construct a backend for `variant`. `opts` configures the native
/// engine (thread count, W optimizer); the XLA artifacts bake their own
/// optimizer in, so a non-default `w_optimizer` is rejected there rather
/// than silently ignored.
pub fn load_backend_with(
    kind: BackendKind,
    artifacts: &Path,
    variant: &str,
    opts: NativeOptions,
) -> Result<Box<dyn ModelBackend>> {
    match kind {
        BackendKind::Native => Ok(Box::new(NativeBackend::build_with(variant, opts)?)),
        BackendKind::Xla => {
            if opts.w_optimizer != WOptimizer::SgdMomentum {
                bail!(
                    "w_optimizer '{}' is a native-engine option; the XLA artifacts \
                     of '{variant}' bake their optimizer in at AOT time",
                    opts.w_optimizer.name()
                );
            }
            Ok(Box::new(ModelRuntime::load(artifacts, variant)?))
        }
    }
}

/// All four compiled functions of one model variant (the XLA backend).
pub struct ModelRuntime {
    pub manifest: Manifest,
    pub init: LoadedFn,
    pub train: LoadedFn,
    pub eval: LoadedFn,
    pub cost: LoadedFn,
    state_specs: Vec<IoSpec>,
    #[allow(dead_code)]
    client: PjRtClient,
}

impl ModelRuntime {
    /// Load and compile a variant from the artifacts directory.
    pub fn load(artifacts_dir: &Path, variant: &str) -> Result<Self> {
        let client = cpu_client()?;
        let manifest = Manifest::load(artifacts_dir, variant)?;
        let load = |name: &str| -> Result<LoadedFn> {
            LoadedFn::load(
                &client,
                &format!("{variant}:{name}"),
                &manifest.hlo_path(name)?,
                manifest.function(name)?.clone(),
            )
        };
        let init = load("init")?;
        let train = load("train")?;
        let eval = load("eval")?;
        let cost = load("cost")?;
        let state_len = manifest.train_state_len()?;
        let state_specs = train.spec.inputs[..state_len].to_vec();
        Ok(Self {
            manifest,
            init,
            train,
            eval,
            cost,
            state_specs,
            client,
        })
    }

    /// The first `n` host leaves → literals (shapes from the manifest).
    /// Callers that only feed the params prefix (eval/cost) avoid
    /// marshalling the optimizer-state leaves entirely.
    fn state_literals(&self, state: &TrainState, n: usize) -> Result<Vec<Literal>> {
        state.leaves[..n]
            .iter()
            .zip(&self.state_specs)
            .map(|(leaf, spec)| lit_f32(&spec.shape, leaf))
            .collect()
    }

    fn batch_literals(&self, x: &[f32], y: &[i32]) -> Result<(Literal, Literal)> {
        let m = &self.manifest.dataset;
        Ok((
            lit_f32(&[m.batch, m.hw, m.hw, 3], x)?,
            lit_i32(&[m.batch], y)?,
        ))
    }
}

impl ModelBackend for ModelRuntime {
    fn backend_name(&self) -> &'static str {
        "xla"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn state_specs(&self) -> &[IoSpec] {
        &self.state_specs
    }

    /// Run `init(seed)` and package the state for the train loop.
    fn init_state(&self, seed: i32) -> Result<TrainState> {
        let outs = self.init.call(&[lit_scalar_i32(seed)])?;
        if outs.len() != self.state_specs.len() {
            return Err(anyhow!(
                "init produced {} leaves, train expects {} state inputs",
                outs.len(),
                self.state_specs.len()
            ));
        }
        Ok(TrainState {
            leaves: outs.iter().map(to_vec_f32).collect::<Result<_>>()?,
            names: self.state_specs.iter().map(|s| s.name.clone()).collect(),
        })
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        x: &[f32],
        y: &[i32],
        hp: StepHparams,
    ) -> Result<Vec<f32>> {
        let leaves = self.state_literals(state, state.leaves.len())?;
        let (xl, yl) = self.batch_literals(x, y)?;
        let scalars = [
            lit_scalar_f32(hp.lam),
            lit_scalar_f32(hp.cost_sel),
            lit_scalar_f32(hp.lr_w),
            lit_scalar_f32(hp.lr_th),
        ];
        // manifest input order: params…, opt_w…, opt_th…, x, y, lam,
        // cost_sel, lr_w, lr_th — exactly state ++ batch ++ scalars.
        let mut args: Vec<&Literal> = Vec::with_capacity(leaves.len() + 6);
        args.extend(leaves.iter());
        args.push(&xl);
        args.push(&yl);
        args.extend(scalars.iter());
        let mut outs = self.train.call(&args)?;
        let metrics = outs.pop().ok_or_else(|| anyhow!("train returned no outputs"))?;
        state.leaves = outs.iter().map(to_vec_f32).collect::<Result<_>>()?;
        to_vec_f32(&metrics)
    }

    fn eval_batch(&self, state: &TrainState, x: &[f32], y: &[i32]) -> Result<Vec<f32>> {
        let n_inputs = self.eval.spec.inputs.len();
        let n_params = n_inputs.checked_sub(2).ok_or_else(|| {
            anyhow!(
                "{}: eval signature too short ({n_inputs} inputs; needs at least \
                 the params plus the x and y batch tensors)",
                self.manifest.variant
            )
        })?;
        if n_params > state.leaves.len() {
            return Err(anyhow!(
                "{}: eval wants {n_params} param inputs but the state has only {} leaves",
                self.manifest.variant,
                state.leaves.len()
            ));
        }
        let leaves = self.state_literals(state, n_params)?;
        let (xl, yl) = self.batch_literals(x, y)?;
        let mut args: Vec<&Literal> = leaves.iter().collect();
        args.push(&xl);
        args.push(&yl);
        let outs = self.eval.call(&args)?;
        to_vec_f32(&outs[0])
    }

    fn cost_report(&self, state: &TrainState) -> Result<(Vec<f32>, Vec<f32>)> {
        let n_params = self.cost.spec.inputs.len();
        if n_params > state.leaves.len() {
            return Err(anyhow!(
                "{}: cost wants {n_params} param inputs but the state has only {} leaves",
                self.manifest.variant,
                state.leaves.len()
            ));
        }
        let leaves = self.state_literals(state, n_params)?;
        let args: Vec<&Literal> = leaves.iter().collect();
        let outs = self.cost.call(&args)?;
        Ok((to_vec_f32(&outs[0])?, to_vec_f32(&outs[1])?))
    }
}

/// Create the CPU PJRT client (one per runtime).
pub fn cpu_client() -> Result<PjRtClient> {
    PjRtClient::cpu().map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))
}
