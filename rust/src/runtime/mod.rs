//! Runtime layer: PJRT client + manifest-driven artifact loading.
//!
//! The coordinator never constructs XLA computations — it only loads the
//! AOT artifacts produced by `make artifacts` and executes them. This
//! module owns that boundary:
//!
//! * [`manifest`] — the JSON contract (shapes/dtypes/layer table);
//! * [`executable`] — HLO-text → PJRT compile → typed execute;
//! * [`ModelRuntime`] — the four compiled functions of one model variant
//!   plus the [`TrainState`] that loops through them.

pub mod executable;
pub mod manifest;

use std::path::Path;

use anyhow::{anyhow, Result};
use xla::{Literal, PjRtClient};

pub use executable::{lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32, to_vec_f32, LoadedFn};
pub use manifest::{IoSpec, LayerSpec, Manifest};

/// Train-loop hyper-scalars fed to every `train` call.
#[derive(Debug, Clone, Copy)]
pub struct StepHparams {
    /// cost strength λ (Eq. 1); 0 during warmup/final-training
    pub lam: f32,
    /// 0 = latency target (Eq. 3), 1 = energy target (Eq. 4)
    pub cost_sel: f32,
    pub lr_w: f32,
    pub lr_th: f32,
}

/// Mutable training state: params + both optimizer states, kept as
/// literals in manifest flattening order so they loop straight back into
/// the next `train` call.
pub struct TrainState {
    pub leaves: Vec<Literal>,
    /// names parallel to `leaves` (from the manifest train signature)
    pub names: Vec<String>,
}

impl TrainState {
    pub fn leaf_index(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    /// Fetch a named leaf as f32 host data.
    pub fn leaf_f32(&self, name: &str) -> Result<Vec<f32>> {
        let i = self
            .leaf_index(name)
            .ok_or_else(|| anyhow!("no state leaf '{name}'"))?;
        to_vec_f32(&self.leaves[i])
    }

    /// Replace a named leaf (e.g. freezing θ to a discretized one-hot).
    pub fn set_leaf_f32(&mut self, name: &str, shape: &[usize], data: &[f32]) -> Result<()> {
        let i = self
            .leaf_index(name)
            .ok_or_else(|| anyhow!("no state leaf '{name}'"))?;
        self.leaves[i] = lit_f32(shape, data)?;
        Ok(())
    }

    /// Snapshot the raw f32 contents of every leaf (checkpointing).
    pub fn snapshot(&self) -> Result<Vec<Vec<f32>>> {
        self.leaves.iter().map(to_vec_f32).collect()
    }

    /// Restore from a snapshot taken on an identically-shaped state.
    pub fn restore(&mut self, snap: &[Vec<f32>], specs: &[IoSpec]) -> Result<()> {
        if snap.len() != self.leaves.len() {
            return Err(anyhow!(
                "snapshot has {} leaves, state has {}",
                snap.len(),
                self.leaves.len()
            ));
        }
        for (i, data) in snap.iter().enumerate() {
            self.leaves[i] = lit_f32(&specs[i].shape, data)?;
        }
        Ok(())
    }
}

/// All four compiled functions of one model variant.
pub struct ModelRuntime {
    pub manifest: Manifest,
    pub init: LoadedFn,
    pub train: LoadedFn,
    pub eval: LoadedFn,
    pub cost: LoadedFn,
    state_len: usize,
}

impl ModelRuntime {
    /// Load and compile a variant from the artifacts directory.
    pub fn load(client: &PjRtClient, artifacts_dir: &Path, variant: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir, variant)?;
        let load = |name: &str| -> Result<LoadedFn> {
            LoadedFn::load(
                client,
                &format!("{variant}:{name}"),
                &manifest.hlo_path(name)?,
                manifest.function(name)?.clone(),
            )
        };
        let init = load("init")?;
        let train = load("train")?;
        let eval = load("eval")?;
        let cost = load("cost")?;
        let state_len = manifest.train_state_len()?;
        Ok(Self {
            manifest,
            init,
            train,
            eval,
            cost,
            state_len,
        })
    }

    /// Run `init(seed)` and package the state for the train loop.
    pub fn init_state(&self, seed: i32) -> Result<TrainState> {
        let outs = self.init.call(&[lit_scalar_i32(seed)])?;
        let names = self
            .train
            .spec
            .inputs
            .iter()
            .take(self.state_len)
            .map(|s| s.name.clone())
            .collect::<Vec<_>>();
        if outs.len() != self.state_len {
            return Err(anyhow!(
                "init produced {} leaves, train expects {} state inputs",
                outs.len(),
                self.state_len
            ));
        }
        Ok(TrainState {
            leaves: outs,
            names,
        })
    }

    /// One training step; advances `state` in place and returns the metric
    /// vector `[loss, ce, acc, cost_lat_cycles, cost_energy_uj]`.
    pub fn train_step(
        &self,
        state: &mut TrainState,
        x: &Literal,
        y: &Literal,
        hp: StepHparams,
    ) -> Result<Vec<f32>> {
        let scalars = [
            lit_scalar_f32(hp.lam),
            lit_scalar_f32(hp.cost_sel),
            lit_scalar_f32(hp.lr_w),
            lit_scalar_f32(hp.lr_th),
        ];
        // manifest input order: params…, opt_w…, opt_th…, x, y, lam,
        // cost_sel, lr_w, lr_th — exactly state ++ batch ++ scalars.
        let mut args: Vec<&Literal> = Vec::with_capacity(state.leaves.len() + 6);
        args.extend(state.leaves.iter());
        args.push(x);
        args.push(y);
        args.extend(scalars.iter());
        let mut outs = self.train.call(&args)?;
        let metrics = outs.pop().ok_or_else(|| anyhow!("train returned no outputs"))?;
        state.leaves = outs;
        to_vec_f32(&metrics)
    }

    /// Evaluate one batch: returns `[correct, loss_sum]`.
    pub fn eval_batch(&self, state: &TrainState, x: &Literal, y: &Literal) -> Result<Vec<f32>> {
        let n_params = self
            .eval
            .spec
            .inputs
            .len()
            .checked_sub(2)
            .ok_or_else(|| anyhow!("eval signature too short"))?;
        let mut args: Vec<&Literal> = state.leaves[..n_params].iter().collect();
        args.push(x);
        args.push(y);
        let outs = self.eval.call(&args)?;
        to_vec_f32(&outs[0])
    }

    /// Cost report from current θ: `(layer_mat [L,4] row-major, totals [2])`.
    pub fn cost_report(&self, state: &TrainState) -> Result<(Vec<f32>, Vec<f32>)> {
        let n_params = self.cost.spec.inputs.len();
        let args: Vec<&Literal> = state.leaves[..n_params].iter().collect();
        let outs = self.cost.call(&args)?;
        Ok((to_vec_f32(&outs[0])?, to_vec_f32(&outs[1])?))
    }

    pub fn batch(&self) -> usize {
        self.manifest.dataset.batch
    }

    pub fn state_len(&self) -> usize {
        self.state_len
    }
}

/// Create the CPU PJRT client (one per process).
pub fn cpu_client() -> Result<PjRtClient> {
    PjRtClient::cpu().map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))
}
