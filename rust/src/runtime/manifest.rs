//! AOT manifest: the JSON contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! Every model variant ships four HLO-text files (`init`, `train`, `eval`,
//! `cost`) plus one manifest describing, in *flattening order*, every
//! input/output tensor of each function. The runtime binds buffers strictly
//! by this order; names are used for θ-leaf lookup and debugging.
//! Parsed with the in-tree JSON module (`util::json`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Value;

/// One tensor in a function signature.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32" | "u32"
}

impl IoSpec {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Value) -> Result<Self> {
        Ok(Self {
            name: v.str_of("name")?,
            shape: v
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            dtype: v.str_of("dtype")?,
        })
    }
}

/// One lowered function (HLO file + io signature).
#[derive(Debug, Clone)]
pub struct FunctionSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl FunctionSpec {
    fn parse(v: &Value) -> Result<Self> {
        let specs = |key: &str| -> Result<Vec<IoSpec>> {
            v.req(key)?.as_arr()?.iter().map(IoSpec::parse).collect()
        };
        Ok(Self {
            file: v.str_of("file")?,
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
        })
    }
}

/// Static geometry of one network layer, in cost-report row order.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub name: String,
    pub ltype: String, // "conv" | "dw" | "pw" | "fc" | "search"
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub ox: usize,
    pub oy: usize,
    pub stride: usize,
    pub searchable: bool,
    pub theta_len: usize,
}

impl LayerSpec {
    fn parse(v: &Value) -> Result<Self> {
        Ok(Self {
            name: v.str_of("name")?,
            ltype: v.str_of("ltype")?,
            cin: v.usize_of("cin")?,
            cout: v.usize_of("cout")?,
            k: v.usize_of("k")?,
            ox: v.usize_of("ox")?,
            oy: v.usize_of("oy")?,
            stride: v.usize_of("stride")?,
            searchable: v.bool_of("searchable")?,
            theta_len: v.usize_of("theta_len")?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: String,
    pub hw: usize,
    pub classes: usize,
    pub batch: usize,
}

#[derive(Debug, Clone)]
pub struct CostScale {
    pub latency_cycles: f64,
    pub energy_uj: f64,
}

/// The full manifest for one model variant.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub variant: String,
    pub platform: String, // "diana" | "darkside"
    pub w_optimizer: String,
    pub search_kind: String, // "channel" | "split" | "layerwise" | "prune" | "fixed"
    pub dataset: DatasetSpec,
    pub layers: Vec<LayerSpec>,
    pub cost_scale: CostScale,
    pub metrics_train: Vec<String>,
    pub metrics_eval: Vec<String>,
    pub functions: BTreeMap<String, FunctionSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Parse a manifest from JSON text.
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let v = crate::util::json::parse(text)?;
        let ds = v.req("dataset")?;
        let cs = v.req("cost_scale")?;
        let strings = |key: &str| -> Result<Vec<String>> {
            v.req(key)?
                .as_arr()?
                .iter()
                .map(|s| Ok(s.as_str()?.to_string()))
                .collect()
        };
        let mut functions = BTreeMap::new();
        for (name, fv) in v.req("functions")?.as_obj()? {
            functions.insert(
                name.clone(),
                FunctionSpec::parse(fv).with_context(|| format!("function '{name}'"))?,
            );
        }
        Ok(Self {
            variant: v.str_of("variant")?,
            platform: v.str_of("platform")?,
            w_optimizer: v.str_of("w_optimizer")?,
            search_kind: v.str_of("search_kind")?,
            dataset: DatasetSpec {
                name: ds.str_of("name")?,
                hw: ds.usize_of("hw")?,
                classes: ds.usize_of("classes")?,
                batch: ds.usize_of("batch")?,
            },
            layers: v
                .req("layers")?
                .as_arr()?
                .iter()
                .map(LayerSpec::parse)
                .collect::<Result<_>>()?,
            cost_scale: CostScale {
                latency_cycles: cs.f64_of("latency_cycles")?,
                energy_uj: cs.f64_of("energy_uj")?,
            },
            metrics_train: strings("metrics_train")?,
            metrics_eval: strings("metrics_eval")?,
            functions,
            dir: dir.to_path_buf(),
        })
    }

    /// Load `<dir>/<variant>.manifest.json`.
    pub fn load(dir: &Path, variant: &str) -> Result<Self> {
        let path = dir.join(format!("{variant}.manifest.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text, dir).with_context(|| format!("parsing manifest {}", path.display()))
    }

    pub fn function(&self, name: &str) -> Result<&FunctionSpec> {
        self.functions
            .get(name)
            .ok_or_else(|| anyhow!("manifest {}: no function '{name}'", self.variant))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.function(name)?.file))
    }

    /// Searchable layers, in order.
    pub fn searchable_layers(&self) -> Vec<&LayerSpec> {
        self.layers.iter().filter(|l| l.searchable).collect()
    }

    /// Index of the θ leaf for layer `layer` within the inputs of `fun`.
    pub fn theta_input_index(&self, fun: &str, layer: &str) -> Result<usize> {
        let want = format!("params/{layer}/theta");
        let f = self.function(fun)?;
        f.inputs
            .iter()
            .position(|s| s.name == want)
            .ok_or_else(|| anyhow!("{}: no input '{want}'", self.variant))
    }

    /// Number of leading inputs that carry state (params + both optimizer
    /// states) for the train function — the part that loops back.
    pub fn train_state_len(&self) -> Result<usize> {
        let f = self.function("train")?;
        Ok(f.inputs
            .iter()
            .take_while(|s| {
                s.name.starts_with("params/")
                    || s.name.starts_with("opt_w/")
                    || s.name.starts_with("opt_th/")
            })
            .count())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest_json() -> &'static str {
        r#"{
          "variant": "v", "platform": "diana", "w_optimizer": "sgdm",
          "search_kind": "channel",
          "dataset": {"name": "d", "hw": 32, "classes": 10, "batch": 64},
          "layers": [
            {"name": "stem", "ltype": "conv", "cin": 3, "cout": 8, "k": 3,
             "ox": 32, "oy": 32, "stride": 1, "searchable": true,
             "theta_len": 16},
            {"name": "fc", "ltype": "fc", "cin": 32, "cout": 10, "k": 1,
             "ox": 1, "oy": 1, "stride": 1, "searchable": false,
             "theta_len": 0}
          ],
          "cost_scale": {"latency_cycles": 1e5, "energy_uj": 10.0},
          "metrics_train": ["loss"], "metrics_eval": ["correct"],
          "functions": {
            "train": {"file": "v_train.hlo.txt",
              "inputs": [
                {"name": "params/stem/theta", "shape": [8, 2], "dtype": "f32"},
                {"name": "opt_w/t", "shape": [], "dtype": "f32"},
                {"name": "opt_th/t", "shape": [], "dtype": "f32"},
                {"name": "x", "shape": [64, 32, 32, 3], "dtype": "f32"}],
              "outputs": []}
          }
        }"#
    }

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::parse(sample_manifest_json(), Path::new("/tmp")).unwrap();
        assert_eq!(m.searchable_layers().len(), 1);
        assert_eq!(m.theta_input_index("train", "stem").unwrap(), 0);
        assert_eq!(m.train_state_len().unwrap(), 3);
        assert_eq!(
            m.function("train").unwrap().inputs[3].elem_count(),
            64 * 32 * 32 * 3
        );
        assert!(m.function("nope").is_err());
        assert_eq!(m.cost_scale.latency_cycles, 1e5);
        assert_eq!(m.dataset.batch, 64);
    }

    #[test]
    fn missing_key_errors() {
        assert!(Manifest::parse("{}", Path::new("/tmp")).is_err());
    }
}
