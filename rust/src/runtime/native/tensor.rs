//! Dense f32 host tensors and the three matmul kernels the native
//! training engine is built on.
//!
//! The kernels are cache-blocked plain safe Rust tuned for
//! auto-vectorization, in the three variants reverse-mode conv/FC need —
//! `A·B`, `A·Bᵀ` (im2col · flattened-weightᵀ and its `dA`), and `Aᵀ·B`
//! (the `dW` reduction) — without ever materializing a transposed copy:
//!
//! * the axpy-style kernels (`matmul_into`, `matmul_at_into`) process
//!   output rows in register-blocked panels of [`MR`], so each streamed
//!   B row is reused `MR` times from registers/L1 instead of once;
//! * the dot-product kernel (`matmul_bt_into`) splits each dot into
//!   [`LANES`] independent accumulators combined in a fixed order —
//!   rustc cannot reorder strict-FP reductions on its own, so the split
//!   is what lets the inner loop vectorize at all.
//!
//! Every kernel writes into a caller-provided buffer (the arena hands
//! these out) and has a `par_*` wrapper that shards *output rows* across
//! the lanes of a persistent-pool [`KernelScope`] (no per-call thread
//! spawning — see [`super::pool`]). Each output element is always
//! computed by exactly one lane with a lane-count-independent
//! accumulation order, so results are bit-identical for any worker
//! count — the property the engine's determinism contract rests on.
//!
//! With the `simd-kernels` cargo feature the public entry points
//! dispatch to the register-tiled microkernels in [`simd`] instead:
//! portable [`simd::F32x8`] lanes (fixed-size arrays the
//! autovectorizer maps onto vector registers) with the output tile
//! kept in registers across the whole reduction loop. The scalar
//! kernels remain compiled in as the bit-identity reference —
//! [`matmul_into_scalar`] and friends — and the SIMD path is pinned
//! against them by relative-error tolerance tests (`tests/kernels.rs`).
//! Per output element the SIMD kernels accumulate in the same index
//! order as the scalar ones, so the lane-count determinism contract
//! holds under either build; only scalar-vs-SIMD bits may differ (the
//! scalar axpy kernels skip exact-zero multipliers, which can flip a
//! signed zero). [`set_simd_enabled`] is a bench-only escape hatch so
//! one `simd-kernels` binary can measure both paths; it is process
//! global — tests compare [`simd`] and `*_scalar` functions directly
//! instead of toggling it.
//!
//! On top of the blocked kernels sits a **packed-panel tier** for every
//! orientation, ported from the quantized path's `bt_drive_packed`
//! layout (PR 7/9): [`pack_bt_into`] interleaves `PK_NR` B rows per
//! `LANES`-chunk for the dot kernel, [`pack_mm_into`] lays B out in
//! `PK_NB`-column panels for the axpy tile, and [`pack_at_panel`]
//! transposes A column panels (the tier PR 8 introduced, now available
//! under every build). Panel edges are zero-padded and pad products
//! never reach a stored output element, so packing is bit-free; per
//! output element each packed kernel replays its unpacked tier's exact
//! reduction recipe (the `dot` chunk/halving-tree/remainder order for
//! bt, the skip-exact-zero axpy order for mm/at), so **packed and
//! unpacked tiers are bit-identical under both builds** — the scalar
//! kernels stay the single bit-identity reference (DESIGN.md §5.7).
//! [`WeightPackSlot`]/[`PackHandle`] add the step-scoped weight-pack
//! cache: the backend hands each layer a handle stamped with the
//! current step epoch, the first shard to consume the weight packs both
//! layouts once (under `Op::Pack`), and every other shard and the
//! backward GEMMs reuse them through a shared read lock.
//! [`set_packing_enabled`] is the bench-only escape hatch mirroring
//! [`set_simd_enabled`], so one binary can time packed vs unpacked.
//!
//! The `arch-kernels` feature adds a third, architecture-intrinsic int8
//! GEMM tier in [`arch`] (AVX2 `maddubs` / AVX-512-VNNI `vpdpbusd` on
//! x86_64, NEON `vmull` / `sdot` on aarch64), selected by runtime
//! CPU-feature detection ([`arch::isa`]) and consumed by the packed
//! qmatmul drive in [`super::qkernels`]. Detection itself is compiled
//! unconditionally so every build can report what the host supports.

use std::sync::{Arc, RwLock, RwLockReadGuard};

use super::pool::KernelScope;
use super::profile::{self, Op};

/// A shaped dense f32 buffer (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor {
            shape: Vec::new(),
            data: vec![v],
        }
    }

    pub fn elem_count(&self) -> usize {
        self.data.len()
    }

    /// Scalar value (panics if not a single element).
    pub fn item(&self) -> f32 {
        debug_assert_eq!(self.data.len(), 1);
        self.data[0]
    }
}

/// Output-row panel height of the axpy kernels.
const MR: usize = 4;
/// Independent accumulators per dot product (must divide SIMD widths).
const LANES: usize = 8;
/// Weight rows per panel of the packed `A·Bᵀ` tier (four dots share one
/// streamed A chunk, matching the unpacked dot kernel's row group).
pub const PK_NR: usize = 4;
/// Output columns per panel of the packed `A·B` tier (one register tile
/// wide: two 8-lane vectors).
pub const PK_NB: usize = 16;

// ---------------------------------------------------------------------------
// dispatch: scalar bit-identity reference vs feature-gated SIMD microkernels
// ---------------------------------------------------------------------------

#[cfg(feature = "simd-kernels")]
mod toggle {
    use std::sync::atomic::{AtomicBool, Ordering};

    static SIMD: AtomicBool = AtomicBool::new(true);

    /// Whether the public kernel entry points take the SIMD path
    /// (default: yes under `simd-kernels`).
    pub fn simd_enabled() -> bool {
        SIMD.load(Ordering::Relaxed)
    }

    /// Bench-only: flip the dispatch so one binary can time both paths.
    /// Process-global — never call from concurrent tests.
    pub fn set_simd_enabled(on: bool) {
        SIMD.store(on, Ordering::Relaxed)
    }
}

#[cfg(feature = "simd-kernels")]
pub use toggle::{set_simd_enabled, simd_enabled};

mod packing {
    use std::sync::atomic::{AtomicBool, Ordering};

    static PACKING: AtomicBool = AtomicBool::new(true);

    /// Whether the engine's pack-aware call sites (conv/FC GEMMs, the
    /// at-tier pack scratch) take the packed-panel tier (default: yes —
    /// the packed tiers are bit-identical to the unpacked ones, so this
    /// is a speed choice, never a numerics one).
    pub fn packing_enabled() -> bool {
        PACKING.load(Ordering::Relaxed)
    }

    /// Bench-only: flip packed-tier dispatch so one binary can time the
    /// packed and unpacked paths. Process global — never call from
    /// concurrent tests.
    pub fn set_packing_enabled(on: bool) {
        PACKING.store(on, Ordering::Relaxed)
    }
}

pub use packing::{packing_enabled, set_packing_enabled};

/// `C[m,n] = A[m,k] · B[k,n]`, overwriting `c`.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    #[cfg(feature = "simd-kernels")]
    if simd_enabled() {
        simd::matmul_into(a, b, c, m, k, n);
        return;
    }
    matmul_into_scalar(a, b, c, m, k, n);
}

/// `C[m,n] = A[m,k] · B[n,k]ᵀ` (row-by-row dot products), overwriting `c`.
pub fn matmul_bt_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    #[cfg(feature = "simd-kernels")]
    if simd_enabled() {
        simd::matmul_bt_into(a, b, c, m, k, n);
        return;
    }
    matmul_bt_into_scalar(a, b, c, m, k, n);
}

/// `C[k,n] = A[m,k]ᵀ · B[m,n]` (rank-1 accumulation over rows of A/B),
/// overwriting `c`.
pub fn matmul_at_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(c.len(), k * n);
    matmul_at_rows(a, b, c, m, k, n, 0, k);
}

/// Rows `i0..i1` of `C[k,n] = A[m,k]ᵀ · B[m,n]` into `chunk` (the
/// shard primitive behind [`par_matmul_at_into`]).
pub fn matmul_at_rows(
    a: &[f32],
    b: &[f32],
    chunk: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    i0: usize,
    i1: usize,
) {
    #[cfg(feature = "simd-kernels")]
    if simd_enabled() {
        simd::matmul_at_rows(a, b, chunk, m, k, n, i0, i1);
        return;
    }
    matmul_at_rows_scalar(a, b, chunk, m, k, n, i0, i1);
}

/// `y += α·x` element-wise (the optimizer's axpy). Dispatches like the
/// matmuls; the SIMD lane loop computes the scalar loop's exact bits
/// (`a + (−b)·c` and `a − b·c` are identical in IEEE-754), so this is
/// safe in the determinism-critical update path under either build.
pub fn axpy_into(y: &mut [f32], alpha: f32, x: &[f32]) {
    #[cfg(feature = "simd-kernels")]
    if simd_enabled() {
        simd::axpy_slice(y, alpha, x);
        return;
    }
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

/// `y = c·y + x` element-wise (the SGD momentum recurrence). Same
/// bit-identity argument as [`axpy_into`].
pub fn scale_add_into(y: &mut [f32], c: f32, x: &[f32]) {
    #[cfg(feature = "simd-kernels")]
    if simd_enabled() {
        simd::scale_add_slice(y, c, x);
        return;
    }
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv = c * *yv + xv;
    }
}

/// Scalar reference for [`matmul_into`] (always compiled).
pub fn matmul_into_scalar(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.iter_mut().for_each(|x| *x = 0.0);
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + MR).min(m);
        let cpanel = &mut c[i0 * n..i1 * n];
        for p in 0..k {
            let brow = &b[p * n..(p + 1) * n];
            for (pi, crow) in cpanel.chunks_exact_mut(n).enumerate() {
                let aip = a[(i0 + pi) * k + p];
                if aip == 0.0 {
                    continue;
                }
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += aip * bv;
                }
            }
        }
        i0 = i1;
    }
}

/// Scalar reference for [`matmul_bt_into`] (always compiled).
pub fn matmul_bt_into_scalar(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv = dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// Scalar reference for [`matmul_at_into`] (always compiled):
/// `C = Aᵀ·B` over the full row range.
pub fn matmul_at_into_scalar(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(c.len(), k * n);
    matmul_at_rows_scalar(a, b, c, m, k, n, 0, k);
}

/// Scalar reference for [`matmul_at_rows`]: rows `i0..i1` of
/// `C[k,n] = A[m,k]ᵀ · B[m,n]` into `chunk`, accumulating rank-1
/// updates over `r` in index order (lane-count independent).
pub fn matmul_at_rows_scalar(
    a: &[f32],
    b: &[f32],
    chunk: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    i0: usize,
    i1: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert!(chunk.len() >= (i1 - i0) * n);
    chunk[..(i1 - i0) * n].iter_mut().for_each(|x| *x = 0.0);
    for r in 0..m {
        let brow = &b[r * n..(r + 1) * n];
        for i in i0..i1 {
            let ari = a[r * k + i];
            if ari == 0.0 {
                continue;
            }
            let crow = &mut chunk[(i - i0) * n..(i - i0 + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += ari * bv;
            }
        }
    }
}

/// `LANES`-way split dot product with a fixed combination order (a
/// pairwise halving tree, so the order is derived from `LANES`):
/// vectorizable under strict FP, deterministic across thread counts.
#[inline]
fn dot(x: &[f32], y: &[f32]) -> f32 {
    const { assert!(LANES.is_power_of_two()) };
    debug_assert_eq!(x.len(), y.len());
    let xc = x.chunks_exact(LANES);
    let yc = y.chunks_exact(LANES);
    let (xr, yr) = (xc.remainder(), yc.remainder());
    let mut acc = [0.0f32; LANES];
    for (cx, cy) in xc.zip(yc) {
        for l in 0..LANES {
            acc[l] += cx[l] * cy[l];
        }
    }
    let mut width = LANES;
    while width > 1 {
        width /= 2;
        for l in 0..width {
            acc[l] += acc[l + width];
        }
    }
    let mut s = acc[0];
    for (&xv, &yv) in xr.iter().zip(yr) {
        s += xv * yv;
    }
    s
}

// ---------------------------------------------------------------------------
// packed-panel f32 tier: layouts, pack routines, packed microkernels
// ---------------------------------------------------------------------------

/// Reduction length padded up to whole `LANES` chunks (the bt-pack's k
/// edge; pads are exactly 0.0).
pub fn f32_k_pad(k: usize) -> usize {
    k.div_ceil(LANES) * LANES
}

/// Buffer length of a [`pack_bt_into`] pack of `B[n,k]`.
pub fn bt_packed_len(k: usize, n: usize) -> usize {
    n.div_ceil(PK_NR) * PK_NR * f32_k_pad(k)
}

/// Buffer length of a [`pack_mm_into`] pack of `B[k,n]` (`k` is not
/// padded — the axpy kernels stream whole `p` rows, and padding the
/// reduction would change the scalar tail order).
pub fn mm_packed_len(k: usize, n: usize) -> usize {
    n.div_ceil(PK_NB) * PK_NB * k
}

/// Pack `B[n,k]` (row-major) into the panel-major bt layout: panels of
/// `PK_NR` rows, each `LANES`-chunk of the panel's rows interleaved at
/// `panel·PK_NR·k_pad + chunk·PK_NR·LANES + row·LANES + lane`, row and
/// k edges zero-padded. The packed bt kernels consume one panel as a
/// single forward stream. Pads are exactly 0.0 and never reach a stored
/// output element, so packing is bit-free (DESIGN.md §5.7).
pub fn pack_bt_into(b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    let _p = profile::time(Op::Pack);
    let k_pad = f32_k_pad(k);
    let len = bt_packed_len(k, n);
    debug_assert_eq!(b.len(), n * k);
    debug_assert!(out.len() >= len);
    out[..len].iter_mut().for_each(|x| *x = 0.0);
    for j in 0..n {
        let base = (j / PK_NR) * PK_NR * k_pad + (j % PK_NR) * LANES;
        let row = &b[j * k..(j + 1) * k];
        for (bi, chunk) in row.chunks(LANES).enumerate() {
            out[base + bi * PK_NR * LANES..][..chunk.len()].copy_from_slice(chunk);
        }
    }
}

/// Pack `B[k,n]` (row-major) into the panel-major mm layout: panels of
/// `PK_NB` columns at `panel·PK_NB·k + p·PK_NB + col`, the column edge
/// zero-padded. Each register tile then loads its two B vectors from
/// one contiguous stream instead of striding across B rows. Same
/// bit-free-pad argument as [`pack_bt_into`].
pub fn pack_mm_into(b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    let _p = profile::time(Op::Pack);
    let len = mm_packed_len(k, n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert!(out.len() >= len);
    out[..len].iter_mut().for_each(|x| *x = 0.0);
    for p in 0..k {
        let brow = &b[p * n..(p + 1) * n];
        for (jp, cols) in brow.chunks(PK_NB).enumerate() {
            out[jp * PK_NB * k + p * PK_NB..][..cols.len()].copy_from_slice(cols);
        }
    }
}

/// Transpose the column panel `A[:, i0..i1]` of `A[m,k]` into `panel`
/// (`[(i1−i0) × m]` row-major) — the pack step of the at tier, split
/// out of the GEMM so its time lands in the `Op::Pack` bucket.
pub fn pack_at_panel(a: &[f32], panel: &mut [f32], m: usize, k: usize, i0: usize, i1: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert!(panel.len() >= (i1 - i0) * m);
    for t in 0..(i1 - i0) {
        let dst = &mut panel[t * m..(t + 1) * m];
        for (r, d) in dst.iter_mut().enumerate() {
            *d = a[r * k + i0 + t];
        }
    }
}

/// Scalar packed-`A·Bᵀ` tier: per output element the chunk /
/// halving-tree / scalar-remainder recipe is exactly the scalar
/// [`dot`]'s, reading B from the packed panels — bit-identical to
/// [`matmul_bt_into_scalar`]. Padded panel rows are computed (their
/// products hit only zero pads) and never stored.
pub fn matmul_bt_packed_scalar(
    a: &[f32],
    pb: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    debug_assert!(pb.len() >= bt_packed_len(k, n));
    let k_pad = f32_k_pad(k);
    let k_main = k - k % LANES;
    let nb_main = k_main / LANES;
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut j0 = 0;
        while j0 < n {
            let jn = (j0 + PK_NR).min(n) - j0;
            let panel = &pb[(j0 / PK_NR) * PK_NR * k_pad..];
            let mut acc = [[0.0f32; LANES]; PK_NR];
            for bi in 0..nb_main {
                let av = &arow[bi * LANES..(bi + 1) * LANES];
                let blk = &panel[bi * PK_NR * LANES..];
                for (t, at) in acc.iter_mut().enumerate() {
                    let brow = &blk[t * LANES..(t + 1) * LANES];
                    for l in 0..LANES {
                        at[l] += av[l] * brow[l];
                    }
                }
            }
            let tail = &panel[nb_main * PK_NR * LANES..];
            for (t, at) in acc.iter_mut().enumerate().take(jn) {
                let mut width = LANES;
                while width > 1 {
                    width /= 2;
                    for l in 0..width {
                        at[l] += at[l + width];
                    }
                }
                let mut s = at[0];
                for (q, &av) in arow[k_main..].iter().enumerate() {
                    s += av * tail[t * LANES + q];
                }
                crow[j0 + t] = s;
            }
            j0 += PK_NR;
        }
    }
}

/// Scalar packed-`A·B` tier: the per-element accumulation order and
/// exact-zero skip of [`matmul_into_scalar`], reading B rows from the
/// packed column panels — bit-identical to it.
pub fn matmul_packed_scalar(a: &[f32], pb: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    debug_assert!(pb.len() >= mm_packed_len(k, n));
    c.iter_mut().for_each(|x| *x = 0.0);
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + MR).min(m);
        for p in 0..k {
            for pi in 0..(i1 - i0) {
                let aip = a[(i0 + pi) * k + p];
                if aip == 0.0 {
                    continue;
                }
                let crow = &mut c[(i0 + pi) * n..(i0 + pi + 1) * n];
                for (jp, cols) in crow.chunks_mut(PK_NB).enumerate() {
                    let src = &pb[jp * PK_NB * k + p * PK_NB..];
                    for (cv, &bv) in cols.iter_mut().zip(src) {
                        *cv += aip * bv;
                    }
                }
            }
        }
        i0 = i1;
    }
}

/// Packed-B `C[m,n] = A[m,k] · B[n,k]ᵀ`: `pb` is a [`pack_bt_into`]
/// pack of B. Every tier shares the `dot` reduction recipe, so this is
/// bit-identical to [`matmul_bt_into`] under both builds and either
/// toggle state.
pub fn matmul_bt_packed_into(a: &[f32], pb: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    #[cfg(feature = "simd-kernels")]
    if simd_enabled() {
        simd::matmul_bt_packed(a, pb, c, m, k, n);
        return;
    }
    matmul_bt_packed_scalar(a, pb, c, m, k, n);
}

/// Packed-B `C[m,n] = A[m,k] · B[k,n]`: `pb` is a [`pack_mm_into`]
/// pack of B. Bit-identical to [`matmul_into`] under both builds.
pub fn matmul_packed_into(a: &[f32], pb: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    #[cfg(feature = "simd-kernels")]
    if simd_enabled() {
        simd::matmul_packed(a, pb, c, m, k, n);
        return;
    }
    matmul_packed_scalar(a, pb, c, m, k, n);
}

// ---------------------------------------------------------------------------
// step-scoped weight-pack cache
// ---------------------------------------------------------------------------

/// Both pack layouts of one `[rows × cols]` weight matrix, refreshed at
/// most once per step epoch. The buffers are allocated once at backend
/// build time (sized by `plan::weight_pack_plan`) and reused forever —
/// steady-state steps allocate nothing here.
pub struct WeightPackSlot {
    state: RwLock<PackState>,
}

struct PackState {
    /// step epoch the buffers currently hold (0 = never filled; the
    /// backend stamps handles starting from epoch 1)
    epoch: u64,
    /// [`pack_bt_into`] layout of W (`k = cols`): the forward conv GEMM
    /// and the FC backward dA consume this orientation
    bt: Vec<f32>,
    /// [`pack_mm_into`] layout of W (`k = rows`): the conv backward
    /// dX/dcols GEMM and the FC forward consume this orientation
    mm: Vec<f32>,
}

impl WeightPackSlot {
    pub fn new(rows: usize, cols: usize) -> WeightPackSlot {
        WeightPackSlot {
            state: RwLock::new(PackState {
                epoch: 0,
                bt: vec![0.0; bt_packed_len(cols, rows)],
                mm: vec![0.0; mm_packed_len(rows, cols)],
            }),
        }
    }
}

/// Step-scoped handle to a shared [`WeightPackSlot`], stamped with the
/// backend's current step epoch. Cheap to clone (it rides inside tape
/// backward closures). The first consumer in a step packs both layouts
/// under the write lock; every later consumer — other batch shards, the
/// same shard's backward GEMMs — gets the shared read guard
/// immediately. Every shard computes bit-identical effective weights
/// (the engine's lane-count determinism contract), so which shard packs
/// is unobservable in the numbers.
#[derive(Clone)]
pub struct PackHandle {
    slot: Arc<WeightPackSlot>,
    epoch: u64,
    rows: usize,
    cols: usize,
}

impl PackHandle {
    pub fn new(slot: Arc<WeightPackSlot>, epoch: u64, rows: usize, cols: usize) -> PackHandle {
        PackHandle {
            slot,
            epoch,
            rows,
            cols,
        }
    }

    /// Both pack layouts of `w` (`[rows × cols]` row-major) for this
    /// handle's step epoch, packing on first touch.
    pub fn packed(&self, w: &[f32]) -> PackGuard<'_> {
        debug_assert_eq!(w.len(), self.rows * self.cols);
        {
            let g = self.slot.state.read().unwrap();
            if g.epoch == self.epoch {
                return PackGuard(g);
            }
        }
        {
            let mut g = self.slot.state.write().unwrap();
            if g.epoch != self.epoch {
                let st = &mut *g;
                pack_bt_into(w, self.cols, self.rows, &mut st.bt);
                pack_mm_into(w, self.rows, self.cols, &mut st.mm);
                st.epoch = self.epoch;
            }
        }
        PackGuard(self.slot.state.read().unwrap())
    }
}

/// Shared read guard over a filled [`WeightPackSlot`].
pub struct PackGuard<'a>(RwLockReadGuard<'a, PackState>);

impl PackGuard<'_> {
    /// The [`pack_bt_into`] layout (`k = cols`, `n = rows`).
    pub fn bt(&self) -> &[f32] {
        &self.0.bt
    }

    /// The [`pack_mm_into`] layout (`k = rows`, `n = cols`).
    pub fn mm(&self) -> &[f32] {
        &self.0.mm
    }
}

// ---------------------------------------------------------------------------
// persistent-pool wrappers: shard output rows, bit-identical results
// ---------------------------------------------------------------------------

/// Raw mutable base pointer smuggled into the SPMD lane closure; each
/// lane reslices its own disjoint row range from it.
#[derive(Clone, Copy)]
struct RowBase(*mut f32);

unsafe impl Send for RowBase {}
unsafe impl Sync for RowBase {}

/// Split `rows` output rows across the scope's kernel lanes; each chunk
/// of `c` is produced by exactly one lane with the serial row closure
/// `f(r0, r1, chunk)`, over the same contiguous index-ordered ranges
/// the scoped-thread wrappers used — so results are bit-identical for
/// any lane count. Falls back to a serial call for 1 lane or tiny
/// outputs. Public: the depthwise conv shards its output rows through
/// the same primitive.
pub fn par_rows<F>(c: &mut [f32], rows: usize, row_elems: usize, scope: &KernelScope, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let t = scope.lanes().min(rows).max(1);
    if t <= 1 {
        f(0, rows, c);
        return;
    }
    debug_assert!(c.len() >= rows * row_elems);
    // contiguous row ranges [w*rows/t, (w+1)*rows/t); every lane writes a
    // disjoint chunk, and scope.run does not return until all lanes are
    // done, so the resliced &mut chunks never alias or escape
    let base = RowBase(c.as_mut_ptr());
    scope.run(&|lane| {
        if lane >= t {
            return;
        }
        let r0 = lane * rows / t;
        let r1 = (lane + 1) * rows / t;
        if r0 == r1 {
            return;
        }
        let chunk = unsafe {
            std::slice::from_raw_parts_mut(base.0.add(r0 * row_elems), (r1 - r0) * row_elems)
        };
        f(r0, r1, chunk);
    });
}

/// Parallel [`matmul_into`]: rows of C sharded across the scope's lanes.
///
/// The `Op::Matmul` probe sits *inside* the lane closure (the
/// lane-summed attribution convention — see [`super::profile`]), so the
/// bucket records summed CPU time across lanes, not caller wall time.
/// The same placement holds for every `par_matmul_*` wrapper below.
pub fn par_matmul_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    scope: &KernelScope,
) {
    debug_assert_eq!(c.len(), m * n);
    par_rows(c, m, n, scope, |r0, r1, chunk| {
        let _p = profile::time(Op::Matmul);
        matmul_into(&a[r0 * k..r1 * k], b, chunk, r1 - r0, k, n);
    });
}

/// Parallel [`matmul_bt_into`]: rows of C sharded across the scope's lanes.
pub fn par_matmul_bt_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    scope: &KernelScope,
) {
    debug_assert_eq!(c.len(), m * n);
    par_rows(c, m, n, scope, |r0, r1, chunk| {
        let _p = profile::time(Op::Matmul);
        matmul_bt_into(&a[r0 * k..r1 * k], b, chunk, r1 - r0, k, n);
    });
}

/// Parallel [`matmul_at_into`]: rows of C (the k axis) sharded across
/// the scope's lanes — each lane reads all of A/B but owns disjoint C
/// rows, so the per-element accumulation order over `m` is unchanged.
pub fn par_matmul_at_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    scope: &KernelScope,
) {
    debug_assert_eq!(c.len(), k * n);
    par_rows(c, k, n, scope, |i0, i1, chunk| {
        let _p = profile::time(Op::Matmul);
        matmul_at_rows(a, b, chunk, m, k, n, i0, i1);
    });
}

/// Parallel [`matmul_bt_packed_into`]: rows of C sharded across the
/// scope's lanes; every lane reads the same shared weight pack (packed
/// once per step by the [`PackHandle`] cache, so no `Op::Pack` time is
/// spent here).
pub fn par_matmul_bt_packed_into(
    a: &[f32],
    pb: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    scope: &KernelScope,
) {
    debug_assert_eq!(c.len(), m * n);
    par_rows(c, m, n, scope, |r0, r1, chunk| {
        let _p = profile::time(Op::Matmul);
        matmul_bt_packed_into(&a[r0 * k..r1 * k], pb, chunk, r1 - r0, k, n);
    });
}

/// Parallel [`matmul_packed_into`]: rows of C sharded across the
/// scope's lanes; every lane reads the same shared weight pack.
pub fn par_matmul_packed_into(
    a: &[f32],
    pb: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    scope: &KernelScope,
) {
    debug_assert_eq!(c.len(), m * n);
    par_rows(c, m, n, scope, |r0, r1, chunk| {
        let _p = profile::time(Op::Matmul);
        matmul_packed_into(&a[r0 * k..r1 * k], pb, chunk, r1 - r0, k, n);
    });
}

/// Packed-panel tier of the parallel `Aᵀ·B` kernel. The plain at-kernel
/// is the weakest of the three orientations: its inner loops re-walk A
/// down `k`-strided columns. Here each lane first transposes its own
/// disjoint column panel of A into `pack` ([`pack_at_panel`], counted
/// in the `Op::Pack` bucket), then runs the strong row-major
/// [`matmul_into`] dispatcher on the panel — both builds take the
/// packed tier. Per output element the rank-1 accumulation over `m`
/// stays in the same ascending index order with each build's own
/// skip-exact-zero behavior, so the packed tier is bit-identical to the
/// same build's unpacked kernel at any lane count.
///
/// `pack` must hold at least `k·m` f32 (lane `i0..i1` uses
/// `pack[i0·m..i1·m]` — the arena sizes it via `plan::step_sizes`).
/// Under the bench's [`set_packing_enabled`] escape hatch it falls back
/// to [`par_matmul_at_into`], which stays the bit-identity reference.
#[allow(clippy::too_many_arguments)]
pub fn par_matmul_at_into_packed(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    scope: &KernelScope,
    pack: &mut [f32],
) {
    debug_assert_eq!(c.len(), k * n);
    debug_assert!(pack.len() >= k * m);
    if !packing_enabled() {
        par_matmul_at_into(a, b, c, m, k, n, scope);
        return;
    }
    let pbase = RowBase(pack.as_mut_ptr());
    par_rows(c, k, n, scope, |i0, i1, chunk| {
        // lanes own disjoint [i0·m, i1·m) panel ranges, same aliasing
        // argument as par_rows' own chunks
        let panel = unsafe { std::slice::from_raw_parts_mut(pbase.0.add(i0 * m), (i1 - i0) * m) };
        {
            let _p = profile::time(Op::Pack);
            pack_at_panel(a, panel, m, k, i0, i1);
        }
        let _p = profile::time(Op::Matmul);
        matmul_into(panel, b, chunk, i1 - i0, m, n);
    });
}

// ---------------------------------------------------------------------------
// feature-gated SIMD microkernels
// ---------------------------------------------------------------------------

/// Register-tiled microkernels on portable 8-lane f32 vectors.
///
/// [`F32x8`] is a plain aligned `[f32; 8]` with elementwise ops — the
/// fixed lane count and straight-line lane loops pin the autovectorizer
/// to one vector register per value, without arch intrinsics. The
/// kernels keep the output tile in registers across the whole reduction
/// loop, so C-row load/store traffic (the dominant cost of the scalar
/// axpy panels) disappears and each streamed B row feeds `MR_S` rows ×
/// 16 columns of output.
///
/// Per output element the reduction index order matches the scalar
/// kernels exactly, and `mul_add` is a separate multiply-then-add (no
/// fused FMA), so the only scalar-vs-SIMD divergence is the scalar
/// kernels' skip of exact-zero multipliers (a signed-zero difference at
/// most for the axpy forms). The dot-product kernel reuses the scalar
/// split-accumulator recipe verbatim per dot. Column tiling depends
/// only on `n` and per-element accumulators are private registers, so
/// `par_rows` sharding stays bit-identical for any lane count.
#[cfg(feature = "simd-kernels")]
pub mod simd {
    use super::{bt_packed_len, dot, f32_k_pad, mm_packed_len, PK_NB, PK_NR};

    /// Rows per register tile.
    const MR_S: usize = 4;
    /// Columns per register tile (two 8-lane vectors).
    const NB: usize = 16;
    // the packed layouts are panelized for exactly this tile geometry
    const _: () = assert!(NB == PK_NB && MR_S == PK_NR);

    /// Portable 8-lane f32 vector: an aligned array the autovectorizer
    /// lowers to one 256-bit (or two 128-bit) register(s).
    #[derive(Debug, Clone, Copy)]
    #[repr(align(32))]
    pub struct F32x8(pub [f32; 8]);

    /// Portable 8-lane i16 vector — the widening layer of the integer
    /// GEMM (`qkernels`): i8 codes widen to i16 on load, multiply as
    /// i32. `i16 × i16` products of int8 codes never exceed `127²`, so
    /// the widening chain is exact at every step.
    #[derive(Debug, Clone, Copy)]
    #[repr(align(16))]
    pub struct I16x8(pub [i16; 8]);

    impl I16x8 {
        pub const LANES: usize = 8;

        /// Sign-extend the first 8 `i8` codes of `s`.
        #[inline(always)]
        pub fn widen(s: &[i8]) -> I16x8 {
            let mut v = [0i16; 8];
            for (d, &c) in v.iter_mut().zip(&s[..8]) {
                *d = c as i16;
            }
            I16x8(v)
        }
    }

    /// Portable 8-lane i32 accumulator for the integer GEMM. Integer
    /// addition is associative, so — unlike [`F32x8`] — any lane layout
    /// or horizontal-sum order produces the same bits by construction.
    #[derive(Debug, Clone, Copy)]
    #[repr(align(32))]
    pub struct I32x8(pub [i32; 8]);

    impl I32x8 {
        pub const LANES: usize = 8;

        #[inline(always)]
        pub fn zero() -> I32x8 {
            I32x8([0; 8])
        }

        /// Elementwise `self + a·b` with the products widened to i32.
        #[inline(always)]
        #[must_use]
        pub fn mul_add_widen(self, a: I16x8, b: I16x8) -> I32x8 {
            let mut o = self.0;
            for l in 0..Self::LANES {
                o[l] += a.0[l] as i32 * b.0[l] as i32;
            }
            I32x8(o)
        }

        /// Horizontal sum (order-free: integer adds are associative).
        #[inline(always)]
        pub fn hsum(self) -> i32 {
            self.0.iter().sum()
        }
    }

    impl F32x8 {
        pub const LANES: usize = 8;

        #[inline(always)]
        pub fn zero() -> F32x8 {
            F32x8([0.0; 8])
        }

        #[inline(always)]
        pub fn splat(v: f32) -> F32x8 {
            F32x8([v; 8])
        }

        /// Load the first 8 elements of `s`.
        #[inline(always)]
        pub fn load(s: &[f32]) -> F32x8 {
            let mut v = [0.0f32; 8];
            v.copy_from_slice(&s[..8]);
            F32x8(v)
        }

        /// Store into the first 8 elements of `d`.
        #[inline(always)]
        pub fn store(self, d: &mut [f32]) {
            d[..8].copy_from_slice(&self.0);
        }

        /// Elementwise `self + a·b`, as a separate multiply then add —
        /// the same per-lane arithmetic as the scalar kernels (never a
        /// fused FMA, which would change the bits).
        #[inline(always)]
        #[must_use]
        pub fn mul_add(self, a: F32x8, b: F32x8) -> F32x8 {
            let mut o = self.0;
            for l in 0..Self::LANES {
                o[l] += a.0[l] * b.0[l];
            }
            F32x8(o)
        }

        /// Horizontal sum by the same pairwise halving tree the scalar
        /// `dot` uses, so vector dots reduce in identical order.
        #[inline(always)]
        pub fn hsum(self) -> f32 {
            let mut acc = self.0;
            let mut width = Self::LANES;
            while width > 1 {
                width /= 2;
                for l in 0..width {
                    acc[l] += acc[l + width];
                }
            }
            acc[0]
        }
    }

    /// SIMD `C[m,n] = A[m,k] · B[k,n]`: MR_S×16 output tile in
    /// registers, k-loop streams one B row per step.
    pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        let n_main = n - n % NB;
        let mut i0 = 0;
        while i0 < m {
            let i1 = (i0 + MR_S).min(m);
            let rows = i1 - i0;
            let mut j = 0;
            while j < n_main {
                let mut acc = [[F32x8::zero(); 2]; MR_S];
                for p in 0..k {
                    let b0 = F32x8::load(&b[p * n + j..]);
                    let b1 = F32x8::load(&b[p * n + j + 8..]);
                    for (r, accr) in acc.iter_mut().enumerate().take(rows) {
                        let av = F32x8::splat(a[(i0 + r) * k + p]);
                        accr[0] = accr[0].mul_add(av, b0);
                        accr[1] = accr[1].mul_add(av, b1);
                    }
                }
                for (r, accr) in acc.iter().enumerate().take(rows) {
                    let off = (i0 + r) * n + j;
                    accr[0].store(&mut c[off..]);
                    accr[1].store(&mut c[off + 8..]);
                }
                j += NB;
            }
            if j < n {
                // tail columns: scalar, same p-order accumulation
                for r in 0..rows {
                    let arow = &a[(i0 + r) * k..(i0 + r + 1) * k];
                    let crow = &mut c[(i0 + r) * n + j..(i0 + r) * n + n];
                    crow.iter_mut().for_each(|x| *x = 0.0);
                    for (p, &ap) in arow.iter().enumerate() {
                        if ap == 0.0 {
                            continue;
                        }
                        let brow = &b[p * n + j..p * n + n];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += ap * bv;
                        }
                    }
                }
            }
            i0 = i1;
        }
    }

    /// SIMD `C[m,n] = A[m,k] · B[n,k]ᵀ`: four dots share one streamed A
    /// row; per dot the chunk/hsum/remainder recipe is the scalar
    /// `dot`'s, so each output element's bits match the scalar kernel.
    pub fn matmul_bt_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        debug_assert_eq!(c.len(), m * n);
        const NR_S: usize = 4;
        let k_main = k - k % F32x8::LANES;
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            let mut j = 0;
            while j + NR_S <= n {
                let mut acc = [F32x8::zero(); NR_S];
                let mut p = 0;
                while p < k_main {
                    let xv = F32x8::load(&arow[p..]);
                    for (t, at) in acc.iter_mut().enumerate() {
                        *at = at.mul_add(xv, F32x8::load(&b[(j + t) * k + p..]));
                    }
                    p += F32x8::LANES;
                }
                for (t, at) in acc.iter().enumerate() {
                    let mut s = at.hsum();
                    for q in k_main..k {
                        s += arow[q] * b[(j + t) * k + q];
                    }
                    crow[j + t] = s;
                }
                j += NR_S;
            }
            for jj in j..n {
                crow[jj] = dot(arow, &b[jj * k..(jj + 1) * k]);
            }
        }
    }

    /// SIMD rows `i0..i1` of `C[k,n] = A[m,k]ᵀ · B[m,n]`: MR_S×16
    /// register tile, rank-1 updates streamed over `r` in index order.
    pub fn matmul_at_rows(
        a: &[f32],
        b: &[f32],
        chunk: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        i0: usize,
        i1: usize,
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), m * n);
        debug_assert!(chunk.len() >= (i1 - i0) * n);
        chunk[..(i1 - i0) * n].iter_mut().for_each(|x| *x = 0.0);
        let n_main = n - n % NB;
        let mut ib = i0;
        while ib < i1 {
            let ie = (ib + MR_S).min(i1);
            let rows = ie - ib;
            let mut j = 0;
            while j < n_main {
                let mut acc = [[F32x8::zero(); 2]; MR_S];
                for r in 0..m {
                    let b0 = F32x8::load(&b[r * n + j..]);
                    let b1 = F32x8::load(&b[r * n + j + 8..]);
                    for (t, acct) in acc.iter_mut().enumerate().take(rows) {
                        let av = F32x8::splat(a[r * k + ib + t]);
                        acct[0] = acct[0].mul_add(av, b0);
                        acct[1] = acct[1].mul_add(av, b1);
                    }
                }
                for (t, acct) in acc.iter().enumerate().take(rows) {
                    let off = (ib - i0 + t) * n + j;
                    acct[0].store(&mut chunk[off..]);
                    acct[1].store(&mut chunk[off + 8..]);
                }
                j += NB;
            }
            if j < n {
                for r in 0..m {
                    let brow = &b[r * n + j..r * n + n];
                    for t in 0..rows {
                        let ari = a[r * k + ib + t];
                        if ari == 0.0 {
                            continue;
                        }
                        let off = (ib - i0 + t) * n;
                        let crow = &mut chunk[off + j..off + n];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += ari * bv;
                        }
                    }
                }
            }
            ib = ie;
        }
    }

    /// SIMD `C = Aᵀ·B` over the full row range (tests/benches).
    pub fn matmul_at_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(c.len(), k * n);
        matmul_at_rows(a, b, c, m, k, n, 0, k);
    }

    /// Packed-panel rows `i0..i1` of `C[k,n] = A[m,k]ᵀ · B[m,n]`: the
    /// column panel `A[:, i0..i1]` is transposed once into `panel`
    /// (`[(i1−i0) × m]` row-major) and the strong [`matmul_into`]
    /// register tile runs on it — the `k`-strided A walk of
    /// [`matmul_at_rows`] becomes a contiguous stream. Per output
    /// element both kernels accumulate over `r ∈ 0..m` in ascending
    /// order (main tiles never skip, tail columns share the same
    /// skip-exact-zero scalar loop), so the results are bit-identical.
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_at_panel(
        a: &[f32],
        b: &[f32],
        chunk: &mut [f32],
        panel: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        i0: usize,
        i1: usize,
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), m * n);
        let rows = i1 - i0;
        debug_assert!(chunk.len() >= rows * n);
        debug_assert!(panel.len() >= rows * m);
        super::pack_at_panel(a, panel, m, k, i0, i1);
        matmul_into(&panel[..rows * m], b, &mut chunk[..rows * n], rows, m, n);
    }

    /// SIMD packed-`A·Bᵀ` tier: the [`super::pack_bt_into`] panels feed
    /// the [`matmul_bt_into`] recipe — per chunk one streamed A vector
    /// multiplies `PK_NR` contiguous interleaved B rows, then the hsum
    /// tree and the scalar k-remainder (read from the panel's partial
    /// block, *after* the tree, so padding never enters the vector
    /// accumulators). Bit-identical to [`matmul_bt_into`], and — since
    /// the dot recipe is shared — to the scalar kernels too.
    pub fn matmul_bt_packed(a: &[f32], pb: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(c.len(), m * n);
        debug_assert!(pb.len() >= bt_packed_len(k, n));
        let k_pad = f32_k_pad(k);
        let k_main = k - k % F32x8::LANES;
        let nb_main = k_main / F32x8::LANES;
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            let mut j0 = 0;
            while j0 < n {
                let jn = (j0 + PK_NR).min(n) - j0;
                let panel = &pb[(j0 / PK_NR) * PK_NR * k_pad..];
                let mut acc = [F32x8::zero(); PK_NR];
                for bi in 0..nb_main {
                    let xv = F32x8::load(&arow[bi * F32x8::LANES..]);
                    let blk = &panel[bi * PK_NR * F32x8::LANES..];
                    for (t, at) in acc.iter_mut().enumerate() {
                        *at = at.mul_add(xv, F32x8::load(&blk[t * F32x8::LANES..]));
                    }
                }
                let tail = &panel[nb_main * PK_NR * F32x8::LANES..];
                for (t, at) in acc.iter().enumerate().take(jn) {
                    let mut s = at.hsum();
                    for (q, &av) in arow[k_main..].iter().enumerate() {
                        s += av * tail[t * F32x8::LANES + q];
                    }
                    crow[j0 + t] = s;
                }
                j0 += PK_NR;
            }
        }
    }

    /// SIMD packed-`A·B` tier: [`matmul_into`]'s MR_S×16 register tile
    /// with both B vectors loaded from one contiguous
    /// [`super::pack_mm_into`] panel stream instead of striding across
    /// B rows. Tail columns run the same scalar skip-zero loop reading
    /// the zero-padded last panel. Bit-identical to [`matmul_into`].
    pub fn matmul_packed(a: &[f32], pb: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(c.len(), m * n);
        debug_assert!(pb.len() >= mm_packed_len(k, n));
        let n_main = n - n % NB;
        let mut i0 = 0;
        while i0 < m {
            let i1 = (i0 + MR_S).min(m);
            let rows = i1 - i0;
            let mut j = 0;
            while j < n_main {
                let ppanel = &pb[(j / NB) * NB * k..];
                let mut acc = [[F32x8::zero(); 2]; MR_S];
                for p in 0..k {
                    let b0 = F32x8::load(&ppanel[p * NB..]);
                    let b1 = F32x8::load(&ppanel[p * NB + 8..]);
                    for (r, accr) in acc.iter_mut().enumerate().take(rows) {
                        let av = F32x8::splat(a[(i0 + r) * k + p]);
                        accr[0] = accr[0].mul_add(av, b0);
                        accr[1] = accr[1].mul_add(av, b1);
                    }
                }
                for (r, accr) in acc.iter().enumerate().take(rows) {
                    let off = (i0 + r) * n + j;
                    accr[0].store(&mut c[off..]);
                    accr[1].store(&mut c[off + 8..]);
                }
                j += NB;
            }
            if j < n {
                // tail columns: scalar skip-zero accumulation in the
                // same p-order, reading the zero-padded last panel
                let ppanel = &pb[(n_main / NB) * NB * k..];
                for r in 0..rows {
                    let arow = &a[(i0 + r) * k..(i0 + r + 1) * k];
                    let crow = &mut c[(i0 + r) * n + j..(i0 + r) * n + n];
                    crow.iter_mut().for_each(|x| *x = 0.0);
                    for (p, &ap) in arow.iter().enumerate() {
                        if ap == 0.0 {
                            continue;
                        }
                        let brow = &ppanel[p * NB..p * NB + (n - n_main)];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += ap * bv;
                        }
                    }
                }
            }
            i0 = i1;
        }
    }

    // -- elementwise panels (dw-conv taps, batch-norm rows) ----------------
    //
    // These are pure elementwise maps, so the 8-lane main loop plus a
    // scalar tail computes exactly the scalar kernels' bits — they are
    // speed, not a numerics variant.

    /// `y[j] += alpha * x[j]` — axpy (quant-branch mix, SGD update).
    pub fn axpy_slice(y: &mut [f32], alpha: f32, x: &[f32]) {
        let n = y.len();
        debug_assert_eq!(x.len(), n);
        let av = F32x8::splat(alpha);
        let main = n - n % F32x8::LANES;
        let mut j = 0;
        while j < main {
            let acc = F32x8::load(&y[j..]).mul_add(av, F32x8::load(&x[j..]));
            acc.store(&mut y[j..]);
            j += F32x8::LANES;
        }
        for jj in main..n {
            y[jj] += alpha * x[jj];
        }
    }

    /// `y[j] = c * y[j] + x[j]` — the SGD momentum recurrence.
    pub fn scale_add_slice(y: &mut [f32], c: f32, x: &[f32]) {
        let n = y.len();
        debug_assert_eq!(x.len(), n);
        let cv = F32x8::splat(c);
        let main = n - n % F32x8::LANES;
        let mut j = 0;
        while j < main {
            let acc = F32x8::load(&x[j..]).mul_add(cv, F32x8::load(&y[j..]));
            acc.store(&mut y[j..]);
            j += F32x8::LANES;
        }
        for jj in main..n {
            y[jj] = c * y[jj] + x[jj];
        }
    }

    /// `y[j] += x[j] * w[j]` — one depthwise-conv tap over a channel row.
    pub fn fma_slice(y: &mut [f32], x: &[f32], w: &[f32]) {
        let n = y.len();
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(w.len(), n);
        let main = n - n % F32x8::LANES;
        let mut j = 0;
        while j < main {
            let acc = F32x8::load(&y[j..]).mul_add(F32x8::load(&x[j..]), F32x8::load(&w[j..]));
            acc.store(&mut y[j..]);
            j += F32x8::LANES;
        }
        for jj in main..n {
            y[jj] += x[jj] * w[jj];
        }
    }

    /// `out[j] = (x[j] - m[j]) * s[j]` — batch-norm x̂ row.
    pub fn sub_mul_slice(out: &mut [f32], x: &[f32], m: &[f32], s: &[f32]) {
        let n = out.len();
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(m.len(), n);
        debug_assert_eq!(s.len(), n);
        let main = n - n % F32x8::LANES;
        let mut j = 0;
        while j < main {
            let xv = F32x8::load(&x[j..]);
            let mv = F32x8::load(&m[j..]);
            let sv = F32x8::load(&s[j..]);
            let mut o = [0.0f32; F32x8::LANES];
            for l in 0..F32x8::LANES {
                o[l] = (xv.0[l] - mv.0[l]) * sv.0[l];
            }
            F32x8(o).store(&mut out[j..]);
            j += F32x8::LANES;
        }
        for jj in main..n {
            out[jj] = (x[jj] - m[jj]) * s[jj];
        }
    }

    /// `out[j] = x[j] * a[j] + b[j]` — folded affine / BN scale-shift row.
    pub fn affine_slice(out: &mut [f32], x: &[f32], a: &[f32], b: &[f32]) {
        let n = out.len();
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(a.len(), n);
        debug_assert_eq!(b.len(), n);
        let main = n - n % F32x8::LANES;
        let mut j = 0;
        while j < main {
            let acc = F32x8::load(&b[j..]).mul_add(F32x8::load(&x[j..]), F32x8::load(&a[j..]));
            acc.store(&mut out[j..]);
            j += F32x8::LANES;
        }
        for jj in main..n {
            out[jj] = x[jj] * a[jj] + b[jj];
        }
    }
}

/// Architecture-intrinsic int8 GEMM kernels and the runtime CPU-feature
/// detection that selects them.
///
/// Detection ([`arch::isa`], [`arch::cpu_features`]) is compiled on every
/// build so bench records can always report what the host supports; the
/// kernel submodules ([`arch::x86`], [`arch::aarch`]) only exist under the
/// `arch-kernels` feature on their target arch. Each kernel computes one
/// packed `QNR×QLANES` panel (see `qkernels::pack_b_into`): four weight
/// rows × the full reduction, returning four exact i32 dot products.
///
/// Exactness arguments (every tier must bitwise-match the i64 reference):
///
/// * **AVX2 / VNNI (u8×i8)**: signed a·b is computed as |a|·sign(b,a)
///   (the sign-transfer trick). Weight codes are clamped to ±127 at
///   quantization time, so `sign_epi8` never wraps (−(−128) hazard) —
///   and when adversarial inputs *do* contain −128 weights, pack time
///   detects it and dispatch falls back to the portable tier. With
///   |a| ≤ 128 and |b| ≤ 127 each `maddubs` pair sum is ≤ 2·128·127 =
///   32512 < i16::MAX: saturation is neutralized, not tolerated.
///   `vpdpbusd` accumulates quads straight into i32 (non-saturating by
///   definition) under the same preprocessing.
/// * **NEON**: `vmull_s8` widens i8×i8→i16 exactly and `vpadalq_s16`
///   accumulates into i32; `sdot` is an exact signed i8 quad dot. No
///   −128 gate needed. i32 addition is associative, so block order and
///   the mixed sdot/vmull tail cannot change the result.
pub mod arch {
    use std::sync::OnceLock;

    /// The instruction set the int8 panel kernels would run on, best
    /// tier first. `None` means only the portable tiers are available.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Isa {
        None,
        Avx2,
        Avx512Vnni,
        Neon,
        NeonDot,
    }

    impl Isa {
        pub fn name(self) -> &'static str {
            match self {
                Isa::None => "none",
                Isa::Avx2 => "avx2",
                Isa::Avx512Vnni => "avx512vnni",
                Isa::Neon => "neon",
                Isa::NeonDot => "neon_dot",
            }
        }
    }

    /// Best int8-kernel ISA on this host, detected once per process.
    pub fn isa() -> Isa {
        static ISA: OnceLock<Isa> = OnceLock::new();
        *ISA.get_or_init(detect)
    }

    #[cfg(target_arch = "x86_64")]
    fn detect() -> Isa {
        // vpdpbusd on ymm registers needs VNNI *and* VL; plain AVX2 is
        // the broadly-available fallback. (VEX-encoded AVX-VNNI without
        // AVX-512 is left to a future PR.)
        if std::arch::is_x86_feature_detected!("avx512vnni")
            && std::arch::is_x86_feature_detected!("avx512vl")
        {
            Isa::Avx512Vnni
        } else if std::arch::is_x86_feature_detected!("avx2") {
            Isa::Avx2
        } else {
            Isa::None
        }
    }

    #[cfg(target_arch = "aarch64")]
    fn detect() -> Isa {
        if std::arch::is_aarch64_feature_detected!("dotprod") {
            Isa::NeonDot
        } else if std::arch::is_aarch64_feature_detected!("neon") {
            Isa::Neon
        } else {
            Isa::None
        }
    }

    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    fn detect() -> Isa {
        Isa::None
    }

    /// The four CPU features the bench record reports, with their
    /// detected state on this host (always all-false off x86/ARM).
    pub fn cpu_features() -> [(&'static str, bool); 4] {
        #[cfg(target_arch = "x86_64")]
        {
            [
                ("avx2", std::arch::is_x86_feature_detected!("avx2")),
                (
                    "avx512vnni",
                    std::arch::is_x86_feature_detected!("avx512vnni")
                        && std::arch::is_x86_feature_detected!("avx512vl"),
                ),
                ("neon", false),
                ("dotprod", false),
            ]
        }
        #[cfg(target_arch = "aarch64")]
        {
            [
                ("avx2", false),
                ("avx512vnni", false),
                ("neon", std::arch::is_aarch64_feature_detected!("neon")),
                ("dotprod", std::arch::is_aarch64_feature_detected!("dotprod")),
            ]
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            [
                ("avx2", false),
                ("avx512vnni", false),
                ("neon", false),
                ("dotprod", false),
            ]
        }
    }

    #[cfg(all(feature = "arch-kernels", target_arch = "x86_64"))]
    pub mod x86 {
        use std::arch::x86_64::*;

        /// The bi-th 8-byte activation chunk as a broadcastable i64:
        /// in-bounds chunks come from the row, the final partial chunk
        /// from the caller's zero-padded tail buffer.
        #[inline(always)]
        unsafe fn a_chunk(arow: &[i8], atail: &[i8; 8], full: usize, bi: usize) -> i64 {
            let p = if bi < full {
                arow.as_ptr().add(bi * 8)
            } else {
                atail.as_ptr()
            };
            core::ptr::read_unaligned(p as *const i64)
        }

        /// Horizontal reduce: row t of the panel owns i32 lanes 2t,2t+1.
        #[target_feature(enable = "avx2")]
        #[inline]
        unsafe fn row_sums(acc: __m256i) -> [i32; 4] {
            let mut lanes = [0i32; 8];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
            [
                lanes[0] + lanes[1],
                lanes[2] + lanes[3],
                lanes[4] + lanes[5],
                lanes[6] + lanes[7],
            ]
        }

        /// One packed 4×k panel via `maddubs`. Caller contract: AVX2
        /// detected at runtime, and `panel` is free of −128 codes.
        #[target_feature(enable = "avx2")]
        pub unsafe fn qpanel_avx2(arow: &[i8], atail: &[i8; 8], panel: &[i8]) -> [i32; 4] {
            let full = arow.len() / 8;
            let ones = _mm256_set1_epi16(1);
            let mut acc = _mm256_setzero_si256();
            for (bi, blk) in panel.chunks_exact(32).enumerate() {
                let av = _mm256_set1_epi64x(a_chunk(arow, atail, full, bi));
                let bv = _mm256_loadu_si256(blk.as_ptr() as *const __m256i);
                // u8×i8 sign transfer: |a| ∈ [0,128] (abs(−128)=128 is a
                // valid u8), sign moved onto b. Codes are −128-free, so
                // sign_epi8 never wraps; pair sums ≤ 2·128·127 = 32512 <
                // i16::MAX — maddubs cannot saturate.
                let p16 = _mm256_maddubs_epi16(_mm256_abs_epi8(av), _mm256_sign_epi8(bv, av));
                acc = _mm256_add_epi32(acc, _mm256_madd_epi16(p16, ones));
            }
            row_sums(acc)
        }

        /// `acc += dot4(u8 x, i8 y)` per i32 lane via AVX-512-VNNI
        /// `vpdpbusd` on ymm registers. Inline asm rather than the
        /// intrinsic: the AVX-512 intrinsics need a newer stable rustc
        /// than this crate pins, while the mnemonic assembles anywhere
        /// and the `ymm_reg` class only requires AVX at compile time.
        /// Runtime gating (avx512vnni+avx512vl) is the dispatcher's job.
        #[target_feature(enable = "avx2")]
        #[inline]
        unsafe fn dpbusd(acc: __m256i, x: __m256i, y: __m256i) -> __m256i {
            let mut out = acc;
            std::arch::asm!(
                "vpdpbusd {acc}, {x}, {y}",
                acc = inout(ymm_reg) out,
                x = in(ymm_reg) x,
                y = in(ymm_reg) y,
                options(pure, nomem, nostack)
            );
            out
        }

        /// One packed 4×k panel via `vpdpbusd` (non-saturating quad dot
        /// straight into i32). Same caller contract and preprocessing as
        /// [`qpanel_avx2`], plus avx512vnni+avx512vl detected.
        #[target_feature(enable = "avx2")]
        pub unsafe fn qpanel_vnni(arow: &[i8], atail: &[i8; 8], panel: &[i8]) -> [i32; 4] {
            let full = arow.len() / 8;
            let mut acc = _mm256_setzero_si256();
            for (bi, blk) in panel.chunks_exact(32).enumerate() {
                let av = _mm256_set1_epi64x(a_chunk(arow, atail, full, bi));
                let bv = _mm256_loadu_si256(blk.as_ptr() as *const __m256i);
                acc = dpbusd(acc, _mm256_abs_epi8(av), _mm256_sign_epi8(bv, av));
            }
            row_sums(acc)
        }
    }

    #[cfg(all(feature = "arch-kernels", target_arch = "aarch64"))]
    pub mod aarch {
        use std::arch::aarch64::*;

        /// Pointer to the bi-th 8-byte activation chunk (zero-padded
        /// tail buffer for the final partial chunk).
        #[inline(always)]
        unsafe fn a_ptr(arow: &[i8], atail: &[i8; 8], full: usize, bi: usize) -> *const i8 {
            if bi < full {
                arow.as_ptr().add(bi * 8)
            } else {
                atail.as_ptr()
            }
        }

        /// One packed 4×k panel via `vmull_s8` (exact i8×i8→i16) +
        /// `vpadalq_s16` (pairwise widen-accumulate into i32).
        #[target_feature(enable = "neon")]
        pub unsafe fn qpanel_neon(arow: &[i8], atail: &[i8; 8], panel: &[i8]) -> [i32; 4] {
            let full = arow.len() / 8;
            let mut acc = [vdupq_n_s32(0); 4];
            for (bi, blk) in panel.chunks_exact(32).enumerate() {
                let av = vld1_s8(a_ptr(arow, atail, full, bi));
                for (t, at) in acc.iter_mut().enumerate() {
                    let bv = vld1_s8(blk.as_ptr().add(t * 8));
                    *at = vpadalq_s16(*at, vmull_s8(av, bv));
                }
            }
            [
                vaddvq_s32(acc[0]),
                vaddvq_s32(acc[1]),
                vaddvq_s32(acc[2]),
                vaddvq_s32(acc[3]),
            ]
        }

        /// `acc.4s += sdot(a.16b, b.16b)` via inline asm — the aarch64
        /// assembler gates the mnemonic, hence the dotprod target
        /// feature; runtime gating is the dispatcher's job.
        #[target_feature(enable = "neon,dotprod")]
        #[inline]
        unsafe fn sdot_acc(acc: int32x4_t, a: int8x16_t, b: int8x16_t) -> int32x4_t {
            let mut out = acc;
            std::arch::asm!(
                "sdot {acc:v}.4s, {a:v}.16b, {b:v}.16b",
                acc = inout(vreg) out,
                a = in(vreg) a,
                b = in(vreg) b,
                options(pure, nomem, nostack)
            );
            out
        }

        /// One packed 4×k panel via `sdot`, consuming blocks in pairs
        /// (sdot wants 16-byte operands; blocks are 8 bytes per row).
        /// An odd tail block goes through the exact vmull path into the
        /// same accumulators — i32 addition is associative, so mixing
        /// cannot change the result.
        #[target_feature(enable = "neon,dotprod")]
        pub unsafe fn qpanel_neon_dot(arow: &[i8], atail: &[i8; 8], panel: &[i8]) -> [i32; 4] {
            let full = arow.len() / 8;
            let nblocks = panel.len() / 32;
            let mut acc = [vdupq_n_s32(0); 4];
            let mut bi = 0;
            while bi + 1 < nblocks {
                let av = vcombine_s8(
                    vld1_s8(a_ptr(arow, atail, full, bi)),
                    vld1_s8(a_ptr(arow, atail, full, bi + 1)),
                );
                let (b0, b1) = (&panel[bi * 32..], &panel[(bi + 1) * 32..]);
                for (t, at) in acc.iter_mut().enumerate() {
                    let bv = vcombine_s8(
                        vld1_s8(b0.as_ptr().add(t * 8)),
                        vld1_s8(b1.as_ptr().add(t * 8)),
                    );
                    *at = sdot_acc(*at, av, bv);
                }
                bi += 2;
            }
            if bi < nblocks {
                let av = vld1_s8(a_ptr(arow, atail, full, bi));
                let blk = &panel[bi * 32..];
                for (t, at) in acc.iter_mut().enumerate() {
                    let bv = vld1_s8(blk.as_ptr().add(t * 8));
                    *at = vpadalq_s16(*at, vmull_s8(av, bv));
                }
            }
            [
                vaddvq_s32(acc[0]),
                vaddvq_s32(acc[1]),
                vaddvq_s32(acc[2]),
                vaddvq_s32(acc[3]),
            ]
        }
    }
}

// ---------------------------------------------------------------------------
// allocating conveniences (tests, call sites without an arena)
// ---------------------------------------------------------------------------

/// `C[m,n] = A[m,k] · B[k,n]`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_into(a, b, &mut c, m, k, n);
    c
}

/// `C[m,n] = A[m,k] · B[n,k]ᵀ` (row-by-row dot products).
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_bt_into(a, b, &mut c, m, k, n);
    c
}

/// `C[k,n] = A[m,k]ᵀ · B[m,n]` (rank-1 accumulation over rows of A/B).
pub fn matmul_at(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; k * n];
    matmul_at_into(a, b, &mut c, m, k, n);
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn matmul_variants_agree() {
        let (m, k, n) = (3, 5, 4);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.7).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.3).cos()).collect();
        let want = naive(&a, &b, m, k, n);
        assert_eq!(matmul(&a, &b, m, k, n), want);
        // bt: feed B transposed
        let mut bt = vec![0.0; k * n];
        for p in 0..k {
            for j in 0..n {
                bt[j * k + p] = b[p * n + j];
            }
        }
        let got = matmul_bt(&a, &bt, m, k, n);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5);
        }
        // at: feed A transposed
        let mut at = vec![0.0; m * k];
        for i in 0..m {
            for p in 0..k {
                at[p * m + i] = a[i * k + p];
            }
        }
        let got = matmul_at(&at, &b, k, m, n);
        // note: matmul_at computes Aᵀ·B with A of shape [m̃=k, k̃=m]
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn parallel_kernels_are_bit_identical_for_any_lane_count() {
        use super::super::pool::WorkerPool;
        // odd sizes so row chunks are uneven and the dot remainder is hit
        let (m, k, n) = (23, 37, 19);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.11).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.07).cos()).collect();
        let bt: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.05).sin()).collect();
        let at: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.13).cos()).collect();
        let mut base_mm = vec![0.0; m * n];
        matmul_into(&a, &b, &mut base_mm, m, k, n);
        let mut base_bt = vec![0.0; m * n];
        matmul_bt_into(&a, &bt, &mut base_bt, m, k, n);
        let mut base_at = vec![0.0; k * n];
        matmul_at_into(&at, &b, &mut base_at, m, k, n);
        for t in [1usize, 2, 3, 4, 7] {
            // run_tasks with one task puts every pool slot in the kernel group
            let pool = WorkerPool::new(t);
            let out = pool.run_tasks(1, &|_i, scope| {
                assert_eq!(scope.lanes(), t);
                let mut c_mm = vec![1.0; m * n];
                par_matmul_into(&a, &b, &mut c_mm, m, k, n, scope);
                let mut c_bt = vec![1.0; m * n];
                par_matmul_bt_into(&a, &bt, &mut c_bt, m, k, n, scope);
                let mut c_at = vec![1.0; k * n];
                par_matmul_at_into(&at, &b, &mut c_at, m, k, n, scope);
                (c_mm, c_bt, c_at)
            });
            let (c_mm, c_bt, c_at) = &out[0];
            assert_eq!(c_mm, &base_mm, "matmul t={t}");
            assert_eq!(c_bt, &base_bt, "matmul_bt t={t}");
            assert_eq!(c_at, &base_at, "matmul_at t={t}");
        }
    }

    #[test]
    fn packed_at_tier_matches_unpacked_for_any_lane_count() {
        use super::super::pool::WorkerPool;
        // odd shape: uneven lane panels, a 16-column main tile and a
        // scalar tail (n = 19 = 16 + 3)
        let (m, k, n) = (29, 13, 19);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.17).sin()).collect();
        let b: Vec<f32> = (0..m * n).map(|i| (i as f32 * 0.09).cos()).collect();
        let mut base = vec![0.0; k * n];
        matmul_at_into(&a, &b, &mut base, m, k, n);
        for t in [1usize, 2, 3, 5] {
            let pool = WorkerPool::new(t);
            let out = pool.run_tasks(1, &|_i, scope| {
                let mut c = vec![1.0; k * n];
                let mut pack = vec![0.0; k * m];
                par_matmul_at_into_packed(&a, &b, &mut c, m, k, n, scope, &mut pack);
                c
            });
            assert_eq!(&out[0], &base, "packed at t={t}");
        }
    }

    #[test]
    fn packed_bt_and_mm_tiers_are_bit_identical_to_unpacked() {
        use super::super::pool::WorkerPool;
        // odd shapes: partial k chunk (k = 21 = 2·8 + 5), partial bt row
        // panel (n % 4 ≠ 0) and a partial mm column panel (n % 16 ≠ 0);
        // exact zeros sprinkled in A exercise the skip-zero paths
        let (m, k, n) = (23, 21, 19);
        let a: Vec<f32> = (0..m * k)
            .map(|i| if i % 7 == 3 { 0.0 } else { (i as f32 * 0.11).sin() })
            .collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.07).cos()).collect();
        let bt: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.05).sin()).collect();
        let mut base_mm = vec![0.0; m * n];
        matmul_into(&a, &b, &mut base_mm, m, k, n);
        let mut base_bt = vec![0.0; m * n];
        matmul_bt_into(&a, &bt, &mut base_bt, m, k, n);

        let mut pbt = vec![f32::NAN; bt_packed_len(k, n)];
        pack_bt_into(&bt, k, n, &mut pbt);
        let mut pmm = vec![f32::NAN; mm_packed_len(k, n)];
        pack_mm_into(&b, k, n, &mut pmm);

        let mut c = vec![1.0; m * n];
        matmul_bt_packed_into(&a, &pbt, &mut c, m, k, n);
        assert_eq!(c, base_bt, "packed bt (serial)");
        let mut c = vec![1.0; m * n];
        matmul_packed_into(&a, &pmm, &mut c, m, k, n);
        assert_eq!(c, base_mm, "packed mm (serial)");

        for t in [1usize, 2, 3, 5] {
            let pool = WorkerPool::new(t);
            let out = pool.run_tasks(1, &|_i, scope| {
                let mut c_bt = vec![1.0; m * n];
                par_matmul_bt_packed_into(&a, &pbt, &mut c_bt, m, k, n, scope);
                let mut c_mm = vec![1.0; m * n];
                par_matmul_packed_into(&a, &pmm, &mut c_mm, m, k, n, scope);
                (c_bt, c_mm)
            });
            let (c_bt, c_mm) = &out[0];
            assert_eq!(c_bt, &base_bt, "packed bt t={t}");
            assert_eq!(c_mm, &base_mm, "packed mm t={t}");
        }
    }

    #[test]
    fn weight_pack_cache_fills_once_per_epoch() {
        let (rows, cols) = (6, 11);
        let w: Vec<f32> = (0..rows * cols).map(|i| (i as f32 * 0.19).sin()).collect();
        let slot = Arc::new(WeightPackSlot::new(rows, cols));

        let mut want_bt = vec![0.0; bt_packed_len(cols, rows)];
        pack_bt_into(&w, cols, rows, &mut want_bt);
        let mut want_mm = vec![0.0; mm_packed_len(rows, cols)];
        pack_mm_into(&w, rows, cols, &mut want_mm);

        let h1 = PackHandle::new(slot.clone(), 1, rows, cols);
        {
            let g = h1.packed(&w);
            assert_eq!(g.bt(), &want_bt[..]);
            assert_eq!(g.mm(), &want_mm[..]);
        }
        // same epoch: cache hit — a different w must NOT be repacked
        let w2: Vec<f32> = w.iter().map(|x| x + 1.0).collect();
        {
            let g = h1.packed(&w2);
            assert_eq!(g.bt(), &want_bt[..], "same-epoch handle repacked");
        }
        // bumped epoch: refreshes from the new weights
        let h2 = PackHandle::new(slot, 2, rows, cols);
        let mut want_bt2 = vec![0.0; bt_packed_len(cols, rows)];
        pack_bt_into(&w2, cols, rows, &mut want_bt2);
        {
            let g = h2.packed(&w2);
            assert_eq!(g.bt(), &want_bt2[..], "new epoch did not repack");
        }
    }

    #[test]
    fn tensor_basics() {
        let t = Tensor::zeros(vec![2, 3]);
        assert_eq!(t.elem_count(), 6);
        assert_eq!(Tensor::scalar(2.5).item(), 2.5);
    }
}
