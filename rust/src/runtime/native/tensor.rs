//! Dense f32 host tensors and the three matmul kernels the native
//! training engine is built on.
//!
//! The kernels are plain safe Rust tuned for auto-vectorization: the
//! inner loops run over contiguous row slices (`iter().zip()` so the
//! compiler can prove no aliasing) and the three variants cover exactly
//! the access patterns reverse-mode conv/FC need — `A·B`, `A·Bᵀ`
//! (im2col · flattened-weightᵀ and its `dA`), and `Aᵀ·B` (the `dW`
//! reduction) — without ever materializing a transposed copy.

/// A shaped dense f32 buffer (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor {
            shape: Vec::new(),
            data: vec![v],
        }
    }

    pub fn elem_count(&self) -> usize {
        self.data.len()
    }

    /// Scalar value (panics if not a single element).
    pub fn item(&self) -> f32 {
        debug_assert_eq!(self.data.len(), 1);
        self.data[0]
    }
}

/// `C[m,n] = A[m,k] · B[k,n]`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for p in 0..k {
            let aip = a[i * k + p];
            if aip == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aip * bv;
            }
        }
    }
    c
}

/// `C[m,n] = A[m,k] · B[n,k]ᵀ` (row-by-row dot products).
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *cv = acc;
        }
    }
    c
}

/// `C[k,n] = A[m,k]ᵀ · B[m,n]` (rank-1 accumulation over rows of A/B).
pub fn matmul_at(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    let mut c = vec![0.0f32; k * n];
    for r in 0..m {
        let brow = &b[r * n..(r + 1) * n];
        for i in 0..k {
            let ari = a[r * k + i];
            if ari == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += ari * bv;
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn matmul_variants_agree() {
        let (m, k, n) = (3, 5, 4);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.7).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.3).cos()).collect();
        let want = naive(&a, &b, m, k, n);
        assert_eq!(matmul(&a, &b, m, k, n), want);
        // bt: feed B transposed
        let mut bt = vec![0.0; k * n];
        for p in 0..k {
            for j in 0..n {
                bt[j * k + p] = b[p * n + j];
            }
        }
        let got = matmul_bt(&a, &bt, m, k, n);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5);
        }
        // at: feed A transposed
        let mut at = vec![0.0; m * k];
        for i in 0..m {
            for p in 0..k {
                at[p * m + i] = a[i * k + p];
            }
        }
        let got = matmul_at(&at, &b, k, m, n);
        // note: matmul_at computes Aᵀ·B with A of shape [m̃=k, k̃=m]
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn tensor_basics() {
        let t = Tensor::zeros(vec![2, 3]);
        assert_eq!(t.elem_count(), 6);
        assert_eq!(Tensor::scalar(2.5).item(), 2.5);
    }
}
