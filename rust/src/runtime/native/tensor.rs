//! Dense f32 host tensors and the three matmul kernels the native
//! training engine is built on.
//!
//! The kernels are cache-blocked plain safe Rust tuned for
//! auto-vectorization, in the three variants reverse-mode conv/FC need —
//! `A·B`, `A·Bᵀ` (im2col · flattened-weightᵀ and its `dA`), and `Aᵀ·B`
//! (the `dW` reduction) — without ever materializing a transposed copy:
//!
//! * the axpy-style kernels (`matmul_into`, `matmul_at_into`) process
//!   output rows in register-blocked panels of [`MR`], so each streamed
//!   B row is reused `MR` times from registers/L1 instead of once;
//! * the dot-product kernel (`matmul_bt_into`) splits each dot into
//!   [`LANES`] independent accumulators combined in a fixed order —
//!   rustc cannot reorder strict-FP reductions on its own, so the split
//!   is what lets the inner loop vectorize at all.
//!
//! Every kernel writes into a caller-provided buffer (the arena hands
//! these out) and has a `par_*` wrapper that shards *output rows* across
//! the lanes of a persistent-pool [`KernelScope`] (no per-call thread
//! spawning — see [`super::pool`]). Each output element is always
//! computed by exactly one lane with a lane-count-independent
//! accumulation order, so results are bit-identical for any worker
//! count — the property the engine's determinism contract rests on.

use super::pool::KernelScope;

/// A shaped dense f32 buffer (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor {
            shape: Vec::new(),
            data: vec![v],
        }
    }

    pub fn elem_count(&self) -> usize {
        self.data.len()
    }

    /// Scalar value (panics if not a single element).
    pub fn item(&self) -> f32 {
        debug_assert_eq!(self.data.len(), 1);
        self.data[0]
    }
}

/// Output-row panel height of the axpy kernels.
const MR: usize = 4;
/// Independent accumulators per dot product (must divide SIMD widths).
const LANES: usize = 8;

/// `C[m,n] = A[m,k] · B[k,n]`, overwriting `c`.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.iter_mut().for_each(|x| *x = 0.0);
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + MR).min(m);
        let cpanel = &mut c[i0 * n..i1 * n];
        for p in 0..k {
            let brow = &b[p * n..(p + 1) * n];
            for (pi, crow) in cpanel.chunks_exact_mut(n).enumerate() {
                let aip = a[(i0 + pi) * k + p];
                if aip == 0.0 {
                    continue;
                }
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += aip * bv;
                }
            }
        }
        i0 = i1;
    }
}

/// `C[m,n] = A[m,k] · B[n,k]ᵀ` (row-by-row dot products), overwriting `c`.
pub fn matmul_bt_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv = dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// `C[k,n] = A[m,k]ᵀ · B[m,n]` (rank-1 accumulation over rows of A/B),
/// overwriting `c`.
pub fn matmul_at_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    c.iter_mut().for_each(|x| *x = 0.0);
    for r in 0..m {
        let brow = &b[r * n..(r + 1) * n];
        for i in 0..k {
            let ari = a[r * k + i];
            if ari == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += ari * bv;
            }
        }
    }
}

/// `LANES`-way split dot product with a fixed combination order (a
/// pairwise halving tree, so the order is derived from `LANES`):
/// vectorizable under strict FP, deterministic across thread counts.
#[inline]
fn dot(x: &[f32], y: &[f32]) -> f32 {
    const { assert!(LANES.is_power_of_two()) };
    debug_assert_eq!(x.len(), y.len());
    let xc = x.chunks_exact(LANES);
    let yc = y.chunks_exact(LANES);
    let (xr, yr) = (xc.remainder(), yc.remainder());
    let mut acc = [0.0f32; LANES];
    for (cx, cy) in xc.zip(yc) {
        for l in 0..LANES {
            acc[l] += cx[l] * cy[l];
        }
    }
    let mut width = LANES;
    while width > 1 {
        width /= 2;
        for l in 0..width {
            acc[l] += acc[l + width];
        }
    }
    let mut s = acc[0];
    for (&xv, &yv) in xr.iter().zip(yr) {
        s += xv * yv;
    }
    s
}

// ---------------------------------------------------------------------------
// persistent-pool wrappers: shard output rows, bit-identical results
// ---------------------------------------------------------------------------

/// Raw mutable base pointer smuggled into the SPMD lane closure; each
/// lane reslices its own disjoint row range from it.
#[derive(Clone, Copy)]
struct RowBase(*mut f32);

unsafe impl Send for RowBase {}
unsafe impl Sync for RowBase {}

/// Split `rows` output rows across the scope's kernel lanes; each chunk
/// of `c` is produced by exactly one lane with the serial row closure
/// `f(r0, r1, chunk)`, over the same contiguous index-ordered ranges
/// the scoped-thread wrappers used — so results are bit-identical for
/// any lane count. Falls back to a serial call for 1 lane or tiny
/// outputs. Public: the depthwise conv shards its output rows through
/// the same primitive.
pub fn par_rows<F>(c: &mut [f32], rows: usize, row_elems: usize, scope: &KernelScope, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let t = scope.lanes().min(rows).max(1);
    if t <= 1 {
        f(0, rows, c);
        return;
    }
    debug_assert!(c.len() >= rows * row_elems);
    // contiguous row ranges [w*rows/t, (w+1)*rows/t); every lane writes a
    // disjoint chunk, and scope.run does not return until all lanes are
    // done, so the resliced &mut chunks never alias or escape
    let base = RowBase(c.as_mut_ptr());
    scope.run(&|lane| {
        if lane >= t {
            return;
        }
        let r0 = lane * rows / t;
        let r1 = (lane + 1) * rows / t;
        if r0 == r1 {
            return;
        }
        let chunk = unsafe {
            std::slice::from_raw_parts_mut(base.0.add(r0 * row_elems), (r1 - r0) * row_elems)
        };
        f(r0, r1, chunk);
    });
}

/// Parallel [`matmul_into`]: rows of C sharded across the scope's lanes.
pub fn par_matmul_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    scope: &KernelScope,
) {
    debug_assert_eq!(c.len(), m * n);
    par_rows(c, m, n, scope, |r0, r1, chunk| {
        matmul_into(&a[r0 * k..r1 * k], b, chunk, r1 - r0, k, n);
    });
}

/// Parallel [`matmul_bt_into`]: rows of C sharded across the scope's lanes.
pub fn par_matmul_bt_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    scope: &KernelScope,
) {
    debug_assert_eq!(c.len(), m * n);
    par_rows(c, m, n, scope, |r0, r1, chunk| {
        matmul_bt_into(&a[r0 * k..r1 * k], b, chunk, r1 - r0, k, n);
    });
}

/// Parallel [`matmul_at_into`]: rows of C (the k axis) sharded across
/// the scope's lanes — each lane reads all of A/B but owns disjoint C
/// rows, so the per-element accumulation order over `m` is unchanged.
pub fn par_matmul_at_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    scope: &KernelScope,
) {
    debug_assert_eq!(c.len(), k * n);
    par_rows(c, k, n, scope, |i0, i1, chunk| {
        chunk.iter_mut().for_each(|x| *x = 0.0);
        for r in 0..m {
            let brow = &b[r * n..(r + 1) * n];
            for i in i0..i1 {
                let ari = a[r * k + i];
                if ari == 0.0 {
                    continue;
                }
                let crow = &mut chunk[(i - i0) * n..(i - i0 + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += ari * bv;
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// allocating conveniences (tests, call sites without an arena)
// ---------------------------------------------------------------------------

/// `C[m,n] = A[m,k] · B[k,n]`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_into(a, b, &mut c, m, k, n);
    c
}

/// `C[m,n] = A[m,k] · B[n,k]ᵀ` (row-by-row dot products).
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_bt_into(a, b, &mut c, m, k, n);
    c
}

/// `C[k,n] = A[m,k]ᵀ · B[m,n]` (rank-1 accumulation over rows of A/B).
pub fn matmul_at(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; k * n];
    matmul_at_into(a, b, &mut c, m, k, n);
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn matmul_variants_agree() {
        let (m, k, n) = (3, 5, 4);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.7).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.3).cos()).collect();
        let want = naive(&a, &b, m, k, n);
        assert_eq!(matmul(&a, &b, m, k, n), want);
        // bt: feed B transposed
        let mut bt = vec![0.0; k * n];
        for p in 0..k {
            for j in 0..n {
                bt[j * k + p] = b[p * n + j];
            }
        }
        let got = matmul_bt(&a, &bt, m, k, n);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5);
        }
        // at: feed A transposed
        let mut at = vec![0.0; m * k];
        for i in 0..m {
            for p in 0..k {
                at[p * m + i] = a[i * k + p];
            }
        }
        let got = matmul_at(&at, &b, k, m, n);
        // note: matmul_at computes Aᵀ·B with A of shape [m̃=k, k̃=m]
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn parallel_kernels_are_bit_identical_for_any_lane_count() {
        use super::super::pool::WorkerPool;
        // odd sizes so row chunks are uneven and the dot remainder is hit
        let (m, k, n) = (23, 37, 19);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.11).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.07).cos()).collect();
        let bt: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.05).sin()).collect();
        let at: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.13).cos()).collect();
        let mut base_mm = vec![0.0; m * n];
        matmul_into(&a, &b, &mut base_mm, m, k, n);
        let mut base_bt = vec![0.0; m * n];
        matmul_bt_into(&a, &bt, &mut base_bt, m, k, n);
        let mut base_at = vec![0.0; k * n];
        matmul_at_into(&at, &b, &mut base_at, m, k, n);
        for t in [1usize, 2, 3, 4, 7] {
            // run_tasks with one task puts every pool slot in the kernel group
            let pool = WorkerPool::new(t);
            let out = pool.run_tasks(1, &|_i, scope| {
                assert_eq!(scope.lanes(), t);
                let mut c_mm = vec![1.0; m * n];
                par_matmul_into(&a, &b, &mut c_mm, m, k, n, scope);
                let mut c_bt = vec![1.0; m * n];
                par_matmul_bt_into(&a, &bt, &mut c_bt, m, k, n, scope);
                let mut c_at = vec![1.0; k * n];
                par_matmul_at_into(&at, &b, &mut c_at, m, k, n, scope);
                (c_mm, c_bt, c_at)
            });
            let (c_mm, c_bt, c_at) = &out[0];
            assert_eq!(c_mm, &base_mm, "matmul t={t}");
            assert_eq!(c_bt, &base_bt, "matmul_bt t={t}");
            assert_eq!(c_at, &base_at, "matmul_at t={t}");
        }
    }

    #[test]
    fn tensor_basics() {
        let t = Tensor::zeros(vec![2, 3]);
        assert_eq!(t.elem_count(), 6);
        assert_eq!(Tensor::scalar(2.5).item(), 2.5);
    }
}
