//! The planning pass of the planned executor: a one-time shape-inference
//! walk over the supernet graph that enumerates every buffer one training
//! step allocates — parameter copies, activations, im2col patch matrices,
//! θ machinery, gradient slots and backward scratch — so the per-shard
//! [`Arena`]s can be sized *before* the first step runs.
//!
//! The walk mirrors `supernet::forward` + `Tape::backward` step for step:
//! for each plan step it adds the op's output value (and, because the
//! reverse sweep zero-initializes one slot per node, a same-sized gradient
//! buffer), the op's tracked auxiliaries, and the backward closure's
//! scratch buffers. The result is a `length → count` multiset that
//! [`ExecPlan::prime`] pre-allocates into an arena; a primed steady-state
//! step then performs no allocations at all (pinned by
//! `tests/native_exec.rs`). If an op's allocation behavior changes without
//! this walk being updated the engine still works — the arena grows once,
//! on the first step, and the growth counter makes the drift visible.

use std::collections::HashMap;

use crate::soc::LayerType;

use super::arena::Arena;
use super::pool::task_lanes;
use super::supernet::{PlanStep, SearchMode, SupernetSpec};
use super::tape::FUSE_ROWS;

/// `length → buffer count` multiset collector.
#[derive(Default)]
struct SizeBag {
    counts: HashMap<usize, usize>,
}

impl SizeBag {
    /// `count` plain buffers of `len` elements.
    fn add(&mut self, len: usize, count: usize) {
        if len > 0 && count > 0 {
            *self.counts.entry(len).or_default() += count;
        }
    }

    /// A tape *node* of `len` elements: its forward value plus the
    /// zero-initialized gradient slot the reverse sweep gives it.
    fn add_node(&mut self, len: usize, count: usize) {
        self.add(len, 2 * count);
    }
}

/// Sized allocation plan for the per-shard arenas of one native variant.
pub struct ExecPlan {
    /// `(len, count)` per shard slot, aligned with the backend's arenas
    shard_sizes: Vec<Vec<(usize, usize)>>,
    /// batch rows each shard processes
    pub shard_n: Vec<usize>,
}

impl ExecPlan {
    /// Plan `shards` fixed batch shards of a `batch`-row step executed
    /// on a pool of `width` slots: each shard's kernel-lane count
    /// ([`task_lanes`]) sizes its per-lane fused-conv A-panels, so the
    /// exact-length arena free lists hit in the steady state.
    pub fn new(spec: &SupernetSpec, batch: usize, shards: usize, width: usize) -> ExecPlan {
        let s = shards.min(batch).max(1);
        let mut shard_n = Vec::with_capacity(s);
        let mut shard_sizes = Vec::with_capacity(s);
        for i in 0..s {
            let n = (i + 1) * batch / s - i * batch / s;
            shard_n.push(n);
            shard_sizes.push(step_sizes(spec, n, task_lanes(width, s, i)));
        }
        ExecPlan {
            shard_sizes,
            shard_n,
        }
    }

    pub fn shards(&self) -> usize {
        self.shard_n.len()
    }

    /// Pre-allocate shard `i`'s buffers into `arena`.
    pub fn prime(&self, i: usize, arena: &mut Arena) {
        for &(len, count) in &self.shard_sizes[i] {
            arena.prime(len, count);
        }
    }

    /// Total f32 elements the plan provisions across all shards.
    pub fn planned_elems(&self) -> usize {
        self.shard_sizes
            .iter()
            .flatten()
            .map(|&(len, count)| len * count)
            .sum()
    }
}

/// Peak buffer capacities of one *quantized inference* shard — the
/// sizing side of [`super::qkernels::QuantNet`]'s per-shard scratch,
/// mirroring its `forward_shard` walk the same way [`step_sizes`]
/// mirrors the training step. The quantized path recycles a small
/// free-list of f32 buffers instead of an arena (its liveness pattern
/// is a simple ping-pong plus a residual/patch buffer), so all it
/// needs from planning is "how big can any one buffer get".
pub struct QuantPlan {
    /// largest single f32 buffer (activation, patch matrix or conv out)
    pub buf_elems: usize,
    /// f32 buffers live at once (act ping-pong + residual + cols + pool)
    pub buf_count: usize,
    /// largest i8 activation-code buffer (= largest quantized GEMM lhs)
    pub code_elems: usize,
    /// widest per-channel dequant row
    pub chan_max: usize,
    /// shard logits
    pub logit_elems: usize,
}

/// Walk the plan for an `n`-row shard and record peak quantized-forward
/// buffer sizes, so `QuantNet`'s scratch can be primed up front and
/// steady-state quantized evals allocate nothing.
pub fn quant_shard_plan(spec: &SupernetSpec, n: usize) -> QuantPlan {
    let hw = spec.dataset.hw;
    let mut buf_elems = n * hw * hw * 3; // shard input copy
    let mut code_elems = 0usize;
    let mut chan_max = 0usize;
    let mut cur_hw = hw;
    let mut conv = |gi: usize, input_hw: usize| {
        let l = &spec.layers[gi];
        let f = spec.fan_in(gi);
        let rows = n * l.ox * l.oy;
        // activation codes cover whichever slab feeds the integer
        // kernel: the full input for depthwise, the patch matrix (or
        // the input itself, pointwise) for dense convs
        let quant_src = if l.ltype == LayerType::Dw {
            n * input_hw * input_hw * l.cin
        } else {
            rows * f
        };
        if l.ltype != LayerType::Dw && !(l.k == 1 && l.stride == 1) {
            buf_elems = buf_elems.max(rows * f); // im2col patches
        }
        buf_elems = buf_elems.max(rows * l.cout);
        code_elems = code_elems.max(quant_src);
        chan_max = chan_max.max(l.cout);
    };
    for step in &spec.plan {
        match *step {
            PlanStep::Conv(i) => {
                conv(i, cur_hw);
                cur_hw = spec.layers[i].ox;
            }
            PlanStep::ResBlock { c1, c2, dn } => {
                conv(c1, cur_hw);
                conv(c2, spec.layers[c1].ox);
                if let Some(d) = dn {
                    conv(d, cur_hw);
                }
                cur_hw = spec.layers[c2].ox;
            }
            PlanStep::DwPw { dw, pw } => {
                conv(dw, cur_hw);
                conv(pw, spec.layers[dw].ox);
                cur_hw = spec.layers[pw].ox;
            }
        }
    }
    QuantPlan {
        buf_elems,
        // live at once in `forward_shard`: cur + h + h2 + downsample out
        // + patch matrix (transient) + pooled head
        buf_count: 6,
        code_elems,
        chan_max,
        logit_elems: n * spec.classes,
    }
}

/// Sized layout of the prepacked quantized-weight slab — one offset per
/// conv geometry (None for depthwise layers, whose per-channel taps
/// never run the GEMM), plus the slab total. Depends only on the spec's
/// geometry (never on θ or the trained weights), so `QuantNet::build`
/// can allocate the whole slab once, pack into it, and steady-state
/// evals never grow it.
pub struct QuantPackPlan {
    pub offsets: Vec<Option<usize>>,
    pub total: usize,
}

/// Walk the conv geometries and lay out the packed-B slab
/// (`qkernels::pack_b_into` layout, sized by `quant_packed_len`).
pub fn quant_pack_plan(spec: &SupernetSpec) -> QuantPackPlan {
    use super::qkernels::quant_packed_len;
    let mut offsets = Vec::with_capacity(spec.n_convs());
    let mut total = 0usize;
    for gi in 0..spec.n_convs() {
        let l = &spec.layers[gi];
        if l.ltype == LayerType::Dw {
            offsets.push(None);
        } else {
            offsets.push(Some(total));
            total += quant_packed_len(spec.fan_in(gi), l.cout);
        }
    }
    QuantPackPlan { offsets, total }
}

/// Geometry of the step-scoped f32 weight-pack slots: one `(rows, cols)`
/// weight storage shape per conv (None for depthwise, whose per-channel
/// taps never run a GEMM), plus the FC matrix. Depends only on the
/// spec's geometry, so `backend` builds one `WeightPackSlot` per entry
/// at construction time and steady-state steps repack in place without
/// allocating.
pub struct WeightPackPlan {
    /// per-conv weight shape `[rows = cout, cols = fan_in]`
    pub convs: Vec<Option<(usize, usize)>>,
    /// FC weight shape `[rows = fc_cin, cols = classes]`
    pub fc: (usize, usize),
}

/// Walk the conv geometries and lay out the f32 weight-pack slots
/// (mirroring [`quant_pack_plan`] for the quantized slab).
pub fn weight_pack_plan(spec: &SupernetSpec) -> WeightPackPlan {
    let mut convs = Vec::with_capacity(spec.n_convs());
    for gi in 0..spec.n_convs() {
        let l = &spec.layers[gi];
        if l.ltype == LayerType::Dw {
            convs.push(None);
        } else {
            convs.push(Some((l.cout, spec.fan_in(gi))));
        }
    }
    WeightPackPlan {
        convs,
        fc: (spec.fc_cin, spec.classes),
    }
}

/// Buffer multiset of one training step on an `n`-row batch shard whose
/// kernel scope runs `lanes` lanes.
fn step_sizes(spec: &SupernetSpec, n: usize, lanes: usize) -> Vec<(usize, usize)> {
    let mut bag = SizeBag::default();
    let hw = spec.dataset.hw;

    // --- staged parameter leaves --------------------------------------
    for gi in 0..spec.n_convs() {
        let l = &spec.layers[gi];
        bag.add_node(l.cout * spec.fan_in(gi), 1); // w
        bag.add_node(l.cout, 2); // bn scale, bias
        if l.searchable {
            bag.add_node(spec.theta_shape(gi).iter().product(), 1);
        }
    }
    bag.add_node(spec.fc_cin * spec.classes, 1); // fc/w
    bag.add_node(spec.classes, 1); // fc/b
    bag.add_node(n * hw * hw * 3, 1); // x

    // --- forward plan walk --------------------------------------------
    let mut n_search = 0usize;
    let mut cur_hw = hw;
    for step in &spec.plan {
        match *step {
            PlanStep::Conv(i) => {
                conv_bn_sizes(&mut bag, spec, n, i, cur_hw, true, lanes);
                cur_hw = spec.layers[i].ox;
                n_search += spec.layers[i].searchable as usize;
            }
            PlanStep::ResBlock { c1, c2, dn } => {
                conv_bn_sizes(&mut bag, spec, n, c1, cur_hw, true, lanes);
                conv_bn_sizes(&mut bag, spec, n, c2, spec.layers[c1].ox, false, lanes);
                if let Some(d) = dn {
                    conv_bn_sizes(&mut bag, spec, n, d, cur_hw, false, lanes);
                    n_search += spec.layers[d].searchable as usize;
                }
                // residual add + trailing relu
                let l2 = &spec.layers[c2];
                bag.add_node(n * l2.ox * l2.oy * l2.cout, 2);
                n_search += spec.layers[c1].searchable as usize
                    + spec.layers[c2].searchable as usize;
                cur_hw = l2.ox;
            }
            PlanStep::DwPw { dw, pw } => {
                conv_bn_sizes(&mut bag, spec, n, dw, cur_hw, true, lanes);
                conv_bn_sizes(&mut bag, spec, n, pw, spec.layers[dw].ox, true, lanes);
                cur_hw = spec.layers[pw].ox;
                n_search += spec.layers[dw].searchable as usize
                    + spec.layers[pw].searchable as usize;
            }
        }
    }

    // --- head + loss ---------------------------------------------------
    bag.add_node(n * spec.fc_cin, 1); // global average pool
    bag.add_node(n * spec.classes, 1); // fc matmul
    bag.add(n * spec.fc_cin, 1); // fc dA scratch
    bag.add(spec.fc_cin * spec.classes, 1); // fc dB scratch
    bag.add_node(n * spec.classes, 1); // bias add
    bag.add(n * spec.classes, 1); // CE probabilities (aux)
    bag.add_node(1, 1); // CE loss

    // --- differentiable cost term + loss scaling ------------------------
    bag.add_node(1, 1); // shard-fraction loss scale (always recorded)
    if n_search > 0 {
        bag.add_node(2, n_search); // per-layer [lat, energy]
        bag.add_node(2, n_search - 1); // running sum
        bag.add_node(1, 3); // weighted pair, λ scale, total loss
    }

    bag.counts.into_iter().collect()
}

/// Buffers of one conv→bn[→relu] group on an `n`-row shard: θ machinery
/// per search mode, conv output + im2col/backward scratch, batch-norm
/// intermediates (mirrors `supernet::forward`'s `conv_bn`).
fn conv_bn_sizes(
    bag: &mut SizeBag,
    spec: &SupernetSpec,
    n: usize,
    gi: usize,
    input_hw: usize,
    with_relu: bool,
    lanes: usize,
) {
    let l = &spec.layers[gi];
    let k = spec.platform.n_cus();
    let (cout, f) = (l.cout, spec.fan_in(gi));
    let rows = n * l.ox * l.oy;
    if l.searchable {
        match spec.search {
            SearchMode::Channel | SearchMode::Fixed => {
                bag.add_node(cout * k, 1); // probs
                bag.add_node(k, 1); // counts
                bag.add(cout * f, k); // quant branches (aux)
                bag.add_node(cout * f, 1); // effective weights
            }
            SearchMode::Prune => {
                bag.add_node(cout * 2, 1); // probs
                bag.add_node(2, 1); // (keep, prune) pair
                bag.add_node(k, 1); // embedded counts
                bag.add(cout * f, 2); // keep + zero branches (aux)
                bag.add_node(cout * f, 1); // effective weights
            }
            SearchMode::Layerwise => {
                bag.add_node(k, 2); // gate row + counts
                bag.add_node(cout * k, 1); // broadcast probs
                bag.add(cout * f, k); // quant branches (aux)
                bag.add_node(cout * f, 1); // effective weights
            }
        }
    } else {
        bag.add_node(cout * f, 1); // fake-quant STE weights
    }
    // conv output + its backward scratch
    bag.add_node(rows * cout, 1);
    if l.ltype == LayerType::Dw {
        // transposed weight panel (aux, shared by forward and backward)
        bag.add(cout * f, 1);
        // dw backward: dx (input-shaped) + transposed dwt + dw fold
        bag.add(n * input_hw * input_hw * l.cin, 1);
        bag.add(cout * f, 2);
    } else if l.k == 1 && l.stride == 1 {
        // pointwise fast path: no im2col patches, no col2im — just the
        // dW and dX matmul scratch (both builds run the packed at tier
        // for dW now, so the Aᵀ-panel pack scratch is unconditional)
        bag.add(cout * f, 1); // dW scratch
        bag.add(rows * f, 1); // dX scratch
        bag.add(rows * cout, 1); // dW Aᵀ-panel pack scratch
    } else {
        // general conv: the fused lowering streams per-lane FUSE_ROWS
        // A-panels in the forward and rematerializes the patch matrix
        // in the backward; the unpacked reference keeps it as a forward
        // aux instead — both peak at the same two rows·f buffers, so
        // one set of entries serves either packing-toggle state
        bag.add(lanes.min(rows).max(1) * FUSE_ROWS * f, 1); // fused A-panels
        bag.add(rows * f, 1); // patch matrix (aux or backward remat)
        bag.add(rows * f, 1); // dcols scratch
        bag.add(cout * f, 1); // dW scratch
        bag.add(rows * cout, 1); // dW Aᵀ-panel pack scratch
    }
    // batch norm: x̂ (aux) + output node + 2 per-channel scratch rows
    bag.add(rows * cout, 1);
    bag.add_node(rows * cout, 1);
    bag.add(cout, 2);
    if with_relu {
        bag.add_node(rows * cout, 1);
    }
}
