//! Buffer arena for the planned executor: a free-list pool of `Vec<f32>`
//! buffers keyed by exact length.
//!
//! The native engine records a fresh tape every step, but the *shapes* it
//! allocates are identical from one step to the next (same supernet, same
//! batch split). The arena exploits that: every tensor buffer the tape
//! creates is taken from here and given back when the step's tape is
//! recycled, so after the first step (or after [`Arena::prime`] from the
//! execution plan) the steady-state step performs **no** buffer
//! allocations at all — `grown()` stops moving, which
//! `tests/native_exec.rs` pins.
//!
//! The arena is deliberately single-threaded (each batch shard owns its
//! own arena, see `backend.rs`); recycling a buffer into a *different*
//! shard's arena is harmless — the free lists are keyed by length only.
//!
//! Exact-length keying also carries the packed-GEMM scratch (see
//! `plan.rs`): the fused-im2col A-panel block is sized by the *task's
//! lane count* (`pool::task_lanes`), which the plan mirrors exactly so
//! the primed buffer length matches the tape's request bit for bit —
//! a near-miss length would silently defeat priming and show up as
//! steady-state growth in the arena pin. Pack scratch that a backward
//! op re-takes (the rematerialized patch matrix, the Aᵀ-panel buffer)
//! deliberately reuses a size class the forward already primed, so
//! fusion adds lane-panel buffers but no per-step allocations.

use std::collections::HashMap;

/// Exact-size free-list pool of f32 buffers.
#[derive(Default)]
pub struct Arena {
    free: HashMap<usize, Vec<Vec<f32>>>,
    /// cumulative count of buffers that had to be freshly allocated
    grown: u64,
}

impl Arena {
    pub fn new() -> Arena {
        Arena::default()
    }

    /// Pre-allocate `count` buffers of `len` elements (the planning pass).
    /// Primed buffers do not count as growth.
    pub fn prime(&mut self, len: usize, count: usize) {
        if len == 0 {
            return;
        }
        let list = self.free.entry(len).or_default();
        for _ in 0..count {
            list.push(vec![0.0; len]);
        }
    }

    /// A zero-filled buffer of exactly `len` elements.
    pub fn take_zeroed(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take_raw(len);
        v.iter_mut().for_each(|x| *x = 0.0);
        v
    }

    /// A buffer of exactly `len` elements with arbitrary contents — for
    /// ops that overwrite every element before reading.
    pub fn take_raw(&mut self, len: usize) -> Vec<f32> {
        if let Some(list) = self.free.get_mut(&len) {
            if let Some(v) = list.pop() {
                debug_assert_eq!(v.len(), len);
                return v;
            }
        }
        self.grown += 1;
        vec![0.0; len]
    }

    /// Return a buffer to the pool.
    pub fn give(&mut self, v: Vec<f32>) {
        if !v.is_empty() {
            self.free.entry(v.len()).or_default().push(v);
        }
    }

    /// Number of buffers that were allocated because the pool had no
    /// buffer of the requested size (primed buffers excluded).
    pub fn grown(&self) -> u64 {
        self.grown
    }

    /// Total f32 elements currently parked in the free lists.
    pub fn pooled_elems(&self) -> usize {
        self.free
            .iter()
            .map(|(len, list)| len * list.len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_stops_growth() {
        let mut a = Arena::new();
        let b1 = a.take_zeroed(16);
        assert_eq!(a.grown(), 1);
        a.give(b1);
        let b2 = a.take_zeroed(16);
        assert_eq!(a.grown(), 1, "same-size take must reuse");
        assert_eq!(b2, vec![0.0; 16]);
        let _b3 = a.take_zeroed(8);
        assert_eq!(a.grown(), 2, "new size must grow");
    }

    #[test]
    fn primed_buffers_do_not_count_as_growth() {
        let mut a = Arena::new();
        a.prime(32, 3);
        for _ in 0..3 {
            let v = a.take_raw(32);
            assert_eq!(v.len(), 32);
        }
        assert_eq!(a.grown(), 0);
        let _ = a.take_raw(32);
        assert_eq!(a.grown(), 1);
    }

    #[test]
    fn zeroed_take_clears_recycled_contents() {
        let mut a = Arena::new();
        a.give(vec![3.0; 4]);
        assert_eq!(a.take_zeroed(4), vec![0.0; 4]);
        assert_eq!(a.grown(), 0);
    }
}
