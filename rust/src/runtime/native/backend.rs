//! The native [`ModelBackend`]: supernet + tape + SGD, no artifacts.
//!
//! One `train` step is: read the state leaves onto a fresh [`Tape`], run
//! the supernet forward with batch statistics, add the differentiable
//! cost term `λ · ((1−sel)·lat + sel·energy)` over the θ-expected channel
//! counts (Eq. 1), reverse-sweep, then apply SGD-with-momentum to the W
//! family (`lr_w`) and plain SGD to θ (`lr_th`) — the per-group learning
//! rates of the paper's joint descent. BN running statistics update
//! outside the tape with the usual 0.9 momentum.
//!
//! The state layout (leaf names/order) is the same contract the AOT
//! manifests use: `params/<layer>/{w,bn/*,theta}`, `params/fc/{w,b}`,
//! then one `opt_w/…` momentum buffer per trainable W leaf — so the
//! coordinator's θ plumbing, snapshots and Table-II memory accounting
//! work identically on both backends.

use anyhow::{anyhow, Result};

use crate::runtime::manifest::{CostScale, IoSpec, Manifest};
use crate::runtime::{ModelBackend, StepHparams, TrainState};

use super::supernet::{forward, init_conv_weight, init_fc, LayerVars, SupernetSpec};
use super::tape::{eval_layer_cost, Tape, Var};
use super::tensor::Tensor;

const BN_MOMENTUM: f32 = 0.9;
const W_MOMENTUM: f32 = 0.9;

/// Per-conv-geometry leaf indices into the state vector.
struct GeomLeaves {
    w: usize,
    scale: usize,
    bias: usize,
    mean: usize,
    var: usize,
    theta: Option<usize>,
}

pub struct NativeBackend {
    spec: SupernetSpec,
    manifest: Manifest,
    state_specs: Vec<IoSpec>,
    geoms: Vec<GeomLeaves>,
    fc_w: usize,
    fc_b: usize,
    /// `(param leaf, momentum leaf)` pairs, in W-update order
    momenta: Vec<(usize, usize)>,
    /// per-geometry sequential-stage flag (DW→PW chains cost the sum)
    seq: Vec<bool>,
    /// cost of the non-searchable layers (always CU column 0)
    fixed_lat: f64,
    fixed_energy_uj: f64,
}

impl NativeBackend {
    /// Build the engine for a native variant name
    /// (`<platform>_<arch>_<task>[_w050|_w025][_fixed]`).
    pub fn build(variant: &str) -> Result<NativeBackend> {
        let spec = SupernetSpec::build(variant)?;
        let n_cus = spec.platform.n_cus();

        // --- state layout -------------------------------------------------
        let mut state_specs: Vec<IoSpec> = Vec::new();
        let push = |specs: &mut Vec<IoSpec>, name: String, shape: Vec<usize>| -> usize {
            specs.push(IoSpec {
                name,
                shape,
                dtype: "f32".into(),
            });
            specs.len() - 1
        };
        let mut geoms = Vec::with_capacity(spec.n_convs());
        for gi in 0..spec.n_convs() {
            let l = &spec.layers[gi];
            let name = &l.name;
            let w = push(&mut state_specs, format!("params/{name}/w"), spec.w_shape(gi));
            let scale = push(
                &mut state_specs,
                format!("params/{name}/bn/scale"),
                vec![l.cout],
            );
            let bias = push(
                &mut state_specs,
                format!("params/{name}/bn/bias"),
                vec![l.cout],
            );
            let mean = push(
                &mut state_specs,
                format!("params/{name}/bn/mean"),
                vec![l.cout],
            );
            let var = push(
                &mut state_specs,
                format!("params/{name}/bn/var"),
                vec![l.cout],
            );
            let theta = l.searchable.then(|| {
                push(
                    &mut state_specs,
                    format!("params/{name}/theta"),
                    vec![l.cout, n_cus],
                )
            });
            geoms.push(GeomLeaves {
                w,
                scale,
                bias,
                mean,
                var,
                theta,
            });
        }
        let fc_w = push(
            &mut state_specs,
            "params/fc/w".into(),
            vec![spec.fc_cin, spec.classes],
        );
        let fc_b = push(&mut state_specs, "params/fc/b".into(), vec![spec.classes]);
        // momentum buffers shadow every trainable W leaf
        let w_params: Vec<usize> = geoms
            .iter()
            .flat_map(|g| [g.w, g.scale, g.bias])
            .chain([fc_w, fc_b])
            .collect();
        let mut momenta = Vec::with_capacity(w_params.len());
        for &p in &w_params {
            let suffix = state_specs[p]
                .name
                .strip_prefix("params/")
                .expect("trainable leaves live under params/")
                .to_string();
            let shape = state_specs[p].shape.clone();
            let m = push(&mut state_specs, format!("opt_w/{suffix}"), shape);
            momenta.push((p, m));
        }

        // --- manifest + derived cost constants ----------------------------
        let mut manifest = spec.to_manifest(CostScale {
            latency_cycles: 1.0,
            energy_uj: 1.0,
        });
        let seq_names = crate::soc::sequential_layers(&manifest);
        let seq: Vec<bool> = spec
            .layers
            .iter()
            .map(|l| seq_names.iter().any(|s| s == &l.name))
            .collect();

        let cus = spec.platform.cus();
        let us = 1.0 / spec.platform.freq_mhz();
        let p_idle = spec.platform.p_idle_mw();
        let mut fixed_lat = 0.0;
        let mut fixed_energy_uj = 0.0;
        for l in spec.layers.iter().filter(|l| !l.searchable) {
            let mut counts = vec![0.0f64; cus.len()];
            counts[0] = l.cout as f64;
            let e = eval_layer_cost(cus, l, &counts, p_idle, us, false);
            fixed_lat += e.latency;
            fixed_energy_uj += e.energy_uj;
        }
        // scale = whole-network cost at the uniform-θ init point, so
        // config λ values stay comparable across variants and platforms
        let mut scale_lat = fixed_lat;
        let mut scale_energy = fixed_energy_uj;
        for (gi, l) in spec.layers.iter().enumerate().filter(|(_, l)| l.searchable) {
            let counts = spec.uniform_counts(gi);
            let e = eval_layer_cost(cus, l, &counts, p_idle, us, seq[gi]);
            scale_lat += e.latency;
            scale_energy += e.energy_uj;
        }
        manifest.cost_scale = CostScale {
            latency_cycles: scale_lat.max(1.0),
            energy_uj: scale_energy.max(1e-9),
        };

        Ok(NativeBackend {
            spec,
            manifest,
            state_specs,
            geoms,
            fc_w,
            fc_b,
            momenta,
            seq,
            fixed_lat,
            fixed_energy_uj,
        })
    }

    pub fn spec(&self) -> &SupernetSpec {
        &self.spec
    }

    /// Put every parameter leaf on a fresh tape; returns the per-layer
    /// handles plus the list of `(leaf, var)` pairs per group.
    #[allow(clippy::type_complexity)]
    fn stage_params(
        &self,
        tape: &mut Tape,
        state: &TrainState,
    ) -> (Vec<LayerVars>, Var, Var, Vec<Var>, Vec<(usize, Var)>) {
        let mut lvs = Vec::with_capacity(self.geoms.len());
        let mut w_vars = Vec::with_capacity(self.momenta.len());
        let mut theta_vars = Vec::new();
        let leaf = |tape: &mut Tape, idx: usize| -> Var {
            tape.leaf(Tensor::new(
                self.state_specs[idx].shape.clone(),
                state.leaves[idx].clone(),
            ))
        };
        for gl in &self.geoms {
            let w = leaf(tape, gl.w);
            let scale = leaf(tape, gl.scale);
            let bias = leaf(tape, gl.bias);
            w_vars.extend([w, scale, bias]);
            let theta = gl.theta.map(|t| {
                let v = leaf(tape, t);
                theta_vars.push((t, v));
                v
            });
            lvs.push(LayerVars {
                w,
                scale,
                bias,
                theta,
            });
        }
        let fcw = leaf(tape, self.fc_w);
        let fcb = leaf(tape, self.fc_b);
        w_vars.extend([fcw, fcb]);
        (lvs, fcw, fcb, w_vars, theta_vars)
    }

    fn running_stats(&self, state: &TrainState) -> Vec<(Vec<f32>, Vec<f32>)> {
        self.geoms
            .iter()
            .map(|g| (state.leaves[g.mean].clone(), state.leaves[g.var].clone()))
            .collect()
    }

    fn check_batch(&self, x: &[f32], y: &[i32]) -> Result<usize> {
        let hw = self.manifest.dataset.hw;
        let n = y.len();
        if x.len() != n * hw * hw * 3 {
            return Err(anyhow!(
                "batch shape mismatch: {} labels but {} pixels (expected {}·{hw}·{hw}·3)",
                n,
                x.len(),
                n
            ));
        }
        Ok(n)
    }
}

/// θ → expected per-CU counts, through the *same* tape ops the training
/// graph uses (masked row softmax + column sum) so the report and the
/// in-graph objective cannot drift apart.
fn masked_expected_counts(theta: &[f32], cout: usize, mask: &[bool]) -> Vec<f64> {
    let mut tape = Tape::new();
    let th = tape.leaf(Tensor::new(vec![cout, mask.len()], theta.to_vec()));
    let p = tape.softmax_rows_masked(th, mask);
    let n = tape.col_sum(p);
    tape.val(n).data.iter().map(|&v| v as f64).collect()
}

impl ModelBackend for NativeBackend {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn state_specs(&self) -> &[IoSpec] {
        &self.state_specs
    }

    fn init_state(&self, seed: i32) -> Result<TrainState> {
        let mut leaves: Vec<Vec<f32>> = self
            .state_specs
            .iter()
            .map(|s| vec![0.0; s.elem_count()])
            .collect();
        for (gi, gl) in self.geoms.iter().enumerate() {
            let cout = self.spec.layers[gi].cout;
            leaves[gl.w] = init_conv_weight(&self.spec, gi, seed as u64, gi as u64);
            leaves[gl.scale] = vec![1.0; cout];
            leaves[gl.bias] = vec![0.0; cout];
            leaves[gl.mean] = vec![0.0; cout];
            leaves[gl.var] = vec![1.0; cout];
            if let Some(t) = gl.theta {
                leaves[t] = self.spec.theta_init(gi);
            }
        }
        let (w, b) = init_fc(self.spec.fc_cin, self.spec.classes, seed as u64);
        leaves[self.fc_w] = w;
        leaves[self.fc_b] = b;
        Ok(TrainState {
            leaves,
            names: self.state_specs.iter().map(|s| s.name.clone()).collect(),
        })
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        x: &[f32],
        y: &[i32],
        hp: StepHparams,
    ) -> Result<Vec<f32>> {
        let n = self.check_batch(x, y)?;
        let hw = self.manifest.dataset.hw;
        let mut tape = Tape::new();
        let (lvs, fcw, fcb, w_vars, theta_vars) = self.stage_params(&mut tape, state);
        let running = self.running_stats(state);
        let xv = tape.leaf(Tensor::new(vec![n, hw, hw, 3], x.to_vec()));
        let out = forward(&self.spec, &mut tape, &lvs, fcw, fcb, xv, true, &running);
        let (ce, bits) = tape.softmax_ce(out.logits, y);

        // differentiable cost term over the searchable layers
        let platform = self.spec.platform;
        let mut tot: Option<Var> = None;
        for gi in 0..self.spec.n_convs() {
            if let Some(cv) = out.counts[gi] {
                let lc = tape.layer_cost(
                    cv,
                    &self.spec.layers[gi],
                    platform.cus(),
                    platform.p_idle_mw(),
                    platform.freq_mhz(),
                    self.seq[gi],
                );
                tot = Some(match tot {
                    None => lc,
                    Some(t) => tape.add(t, lc),
                });
            }
        }
        let (loss, lat_metric, energy_metric) = match tot {
            Some(t) => {
                let tv = tape.val(t);
                let lat = tv.data[0] as f64 + self.fixed_lat;
                let en = tv.data[1] as f64 + self.fixed_energy_uj;
                let cost = tape.weighted_pair(t, 1.0 - hp.cost_sel, hp.cost_sel);
                let scaled = tape.scale(cost, hp.lam);
                (tape.add(ce, scaled), lat, en)
            }
            None => (ce, self.fixed_lat, self.fixed_energy_uj),
        };
        let loss_val = tape.val(loss).item();
        let grads = tape.backward(loss);

        // --- SGD updates --------------------------------------------------
        debug_assert_eq!(w_vars.len(), self.momenta.len());
        for (&(pleaf, mleaf), pvar) in self.momenta.iter().zip(&w_vars) {
            let g = &grads[pvar.id()].data;
            {
                let mom = &mut state.leaves[mleaf];
                for (mv, &gv) in mom.iter_mut().zip(g) {
                    *mv = W_MOMENTUM * *mv + gv;
                }
            }
            let mom = std::mem::take(&mut state.leaves[mleaf]);
            for (pv, &mv) in state.leaves[pleaf].iter_mut().zip(&mom) {
                *pv -= hp.lr_w * mv;
            }
            state.leaves[mleaf] = mom;
        }
        for (tleaf, tvar) in &theta_vars {
            let g = &grads[tvar.id()].data;
            for (tv, &gv) in state.leaves[*tleaf].iter_mut().zip(g) {
                *tv -= hp.lr_th * gv;
            }
        }
        // --- BN running statistics ---------------------------------------
        for (gi, gl) in self.geoms.iter().enumerate() {
            if let Some((mean, var)) = &out.batch_stats[gi] {
                for (m, &b) in state.leaves[gl.mean].iter_mut().zip(mean) {
                    *m = BN_MOMENTUM * *m + (1.0 - BN_MOMENTUM) * b;
                }
                for (v, &b) in state.leaves[gl.var].iter_mut().zip(var) {
                    *v = BN_MOMENTUM * *v + (1.0 - BN_MOMENTUM) * b;
                }
            }
        }
        Ok(vec![
            loss_val,
            bits.loss_sum / n as f32,
            bits.correct / n as f32,
            lat_metric as f32,
            energy_metric as f32,
        ])
    }

    fn eval_batch(&self, state: &TrainState, x: &[f32], y: &[i32]) -> Result<Vec<f32>> {
        let n = self.check_batch(x, y)?;
        let hw = self.manifest.dataset.hw;
        let mut tape = Tape::new();
        let (lvs, fcw, fcb, _, _) = self.stage_params(&mut tape, state);
        let running = self.running_stats(state);
        let xv = tape.leaf(Tensor::new(vec![n, hw, hw, 3], x.to_vec()));
        let out = forward(&self.spec, &mut tape, &lvs, fcw, fcb, xv, false, &running);
        let (_, bits) = tape.softmax_ce(out.logits, y);
        Ok(vec![bits.correct, bits.loss_sum])
    }

    fn cost_report(&self, state: &TrainState) -> Result<(Vec<f32>, Vec<f32>)> {
        let platform = self.spec.platform;
        let cus = platform.cus();
        let k = cus.len();
        let us = 1.0 / platform.freq_mhz();
        let p_idle = platform.p_idle_mw();
        let mut mat = Vec::with_capacity(self.spec.layers.len() * 2 * k);
        let mut lat_total = 0.0f64;
        let mut energy_total = 0.0f64;
        for (gi, l) in self.spec.layers.iter().enumerate() {
            let counts: Vec<f64> = match self.geoms.get(gi).and_then(|g| g.theta) {
                Some(t) => masked_expected_counts(&state.leaves[t], l.cout, &self.spec.masks[gi]),
                None => {
                    let mut c = vec![0.0; k];
                    c[0] = l.cout as f64;
                    c
                }
            };
            let e = eval_layer_cost(cus, l, &counts, p_idle, us, self.seq[gi]);
            lat_total += e.latency;
            energy_total += e.energy_uj;
            mat.extend(counts.iter().map(|&n| n as f32));
            mat.extend(e.cycles.iter().map(|&c| c as f32));
        }
        Ok((mat, vec![lat_total as f32, energy_total as f32]))
    }
}
