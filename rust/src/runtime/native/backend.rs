//! The native [`ModelBackend`]: supernet + tape + optimizer — now a
//! *planned executor*, no artifacts.
//!
//! One `train` step is: split the batch into [`NSHARDS`] fixed shards,
//! run each shard's forward/backward on its own arena-backed [`Tape`]
//! (shards execute data-parallel as tasks of the backend's persistent
//! [`WorkerPool`] when `threads > 1` — no per-step thread spawning),
//! tree-reduce the shard gradients in a fixed binary order, then apply
//! one optimizer update — SGD-with-momentum or Adam (with bias
//! correction) for the W family (`lr_w`) and plain SGD for θ (`lr_th`),
//! the per-group learning rates of the paper's joint descent. When
//! `threads` exceeds the shard count the surplus pool slots become
//! kernel lanes of their shard group (see `runtime/native/pool.rs`)
//! instead of nested scoped spawns.
//!
//! Determinism contract: the shard structure depends only on the batch
//! size (never on the thread count), every shard is computed serially
//! with a fixed accumulation order (the row-sharded kernels are
//! bit-identical for any lane count), and both the gradient tree
//! reduction and the metric/BN-statistic sums run in shard-index order —
//! so 1-thread and N-thread steps produce bit-identical losses, weights
//! and θ (pinned by `tests/native_exec.rs`). Batch statistics are
//! computed per shard ("ghost batch norm"): the shard split *is* the
//! numerical contract, threading is just scheduling.
//!
//! Each shard owns an [`Arena`] sized by the [`ExecPlan`] shape-inference
//! pass at build time, so steady-state steps allocate no tensor buffers.
//! Dense-conv and FC weights are additionally relaid into packed GEMM
//! panels once per step via shared [`WeightPackSlot`]s (geometry-sized
//! at build time by [`weight_pack_plan`]): a monotone pack epoch
//! invalidates the cache at the top of every train step and f32 eval
//! batch, the first shard to reach a layer packs it (the effective
//! weights are bit-identical across shards, so which one is
//! unobservable), and every GEMM that consumes the weight — forward and
//! both backward orientations — reuses the panels.
//!
//! The loss adds the differentiable cost term
//! `λ · ((1−sel)·lat + sel·energy)` over the θ-expected channel counts
//! (Eq. 1) inside every shard (scaled by the shard's batch fraction, so
//! the total carries it exactly once). BN running statistics update
//! outside the tape with the usual 0.9 momentum from the shard-weighted
//! batch statistics.
//!
//! The state layout (leaf names/order) keeps the AOT manifest contract:
//! `params/<layer>/{w,bn/*,theta}`, `params/fc/{w,b}`, then the
//! optimizer leaves — one `opt_w/…` momentum buffer per trainable W leaf
//! for SGD, or `opt_w/…/{m,v}` pairs plus the shared `opt_w/t` step
//! counter for Adam — so the coordinator's θ plumbing, snapshots and
//! Table-II memory accounting work identically on both backends.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::runtime::manifest::{CostScale, IoSpec, Manifest};
use crate::runtime::{ModelBackend, StepHparams, TrainState};

use super::arena::Arena;
use super::plan::{weight_pack_plan, ExecPlan};
use super::pool::{max_threads, KernelScope, WorkerPool};
use super::profile::{self, Op};
use super::qkernels::{GeomParams, QuantNet};
use super::supernet::{
    forward, init_conv_weight, init_fc, theta_counts, LayerVars, SupernetSpec,
};
use super::tape::{eval_layer_cost, EvalBits, Tape, Var};
use super::tensor::{axpy_into, scale_add_into, PackHandle, WeightPackSlot};

const BN_MOMENTUM: f32 = 0.9;
const W_MOMENTUM: f32 = 0.9;
const ADAM_B1: f64 = 0.9;
const ADAM_B2: f64 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Fixed intra-step shard count. Part of the numerical contract (shard
/// batch statistics and gradient reduction follow this split), so it is
/// a constant — *never* derived from the thread count.
pub const NSHARDS: usize = 4;

/// W-family optimizer of the native engine (θ always uses plain SGD).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WOptimizer {
    /// SGD with 0.9 momentum (the paper's setting)
    #[default]
    SgdMomentum,
    /// Adam with bias correction (β1=0.9, β2=0.999, ε=1e-8)
    Adam,
}

impl WOptimizer {
    pub fn name(self) -> &'static str {
        match self {
            WOptimizer::SgdMomentum => "sgdm",
            WOptimizer::Adam => "adam",
        }
    }
}

impl std::str::FromStr for WOptimizer {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<WOptimizer> {
        match s {
            "sgdm" => Ok(WOptimizer::SgdMomentum),
            "adam" => Ok(WOptimizer::Adam),
            other => bail!("unknown w_optimizer '{other}' (expected sgdm|adam)"),
        }
    }
}

/// Execution knobs of the native engine (all numerics-neutral except the
/// optimizer choice, which is part of the training recipe).
#[derive(Debug, Clone, Copy)]
pub struct NativeOptions {
    /// worker threads for batch shards / kernels (≥1; results are
    /// bit-identical for any value; capped at 4× the available cores —
    /// [`NativeBackend::build_with`] rejects absurd oversubscription)
    pub threads: usize,
    pub w_optimizer: WOptimizer,
}

impl Default for NativeOptions {
    fn default() -> NativeOptions {
        NativeOptions {
            threads: 1,
            w_optimizer: WOptimizer::SgdMomentum,
        }
    }
}

/// Per-conv-geometry leaf indices into the state vector.
struct GeomLeaves {
    w: usize,
    scale: usize,
    bias: usize,
    mean: usize,
    var: usize,
    theta: Option<usize>,
}

/// One trainable W leaf and its optimizer-state leaves.
struct OptSlot {
    p: usize,
    /// momentum (SGD) or first-moment (Adam) buffer
    m: usize,
    /// second-moment buffer (Adam only)
    v: Option<usize>,
}

/// What one batch shard's forward/backward produced.
struct ShardOut {
    /// shard batch fraction n_i / n (its loss/gradient weight)
    scale: f32,
    /// scaled shard loss (summing these in shard order gives the step loss)
    loss: f32,
    bits: EvalBits,
    lat: f64,
    energy_uj: f64,
    /// gradient buffers in update order: W family first, then θ
    grads: Vec<Vec<f32>>,
    stats: Vec<Option<(Vec<f32>, Vec<f32>)>>,
    arena: Arena,
}

pub struct NativeBackend {
    spec: SupernetSpec,
    manifest: Manifest,
    state_specs: Vec<IoSpec>,
    geoms: Vec<GeomLeaves>,
    fc_w: usize,
    fc_b: usize,
    /// trainable W leaves + optimizer slots, in update order
    opt: Vec<OptSlot>,
    /// Adam step-counter leaf
    step_leaf: Option<usize>,
    optimizer: WOptimizer,
    /// persistent worker pool: `threads` slots created once, reused by
    /// every train/eval step for shard tasks and kernel lanes
    pool: WorkerPool,
    plan: ExecPlan,
    /// per-shard-slot buffer arenas, recycled across steps
    arenas: Mutex<Vec<Arena>>,
    /// shared f32 weight-pack slots, one per dense conv (None for
    /// depthwise) — each is filled once per pack epoch by whichever
    /// shard reaches the layer first and reused by every GEMM that
    /// consumes that weight (fwd + both backward orientations)
    wpacks: Vec<Option<Arc<WeightPackSlot>>>,
    /// the FC head's weight-pack slot
    fc_pack: Arc<WeightPackSlot>,
    /// monotone pack epoch, bumped at the top of every train step and
    /// every f32 eval batch so stale packs never survive a weight update
    pack_epoch: AtomicU64,
    /// per-geometry sequential-stage flag (DW→PW chains cost the sum)
    seq: Vec<bool>,
    /// cost of the non-searchable layers (always CU column 0)
    fixed_lat: f64,
    fixed_energy_uj: f64,
}

impl NativeBackend {
    /// Build the engine for a native variant name with default options
    /// (single-threaded, SGD+momentum).
    pub fn build(variant: &str) -> Result<NativeBackend> {
        NativeBackend::build_with(variant, NativeOptions::default())
    }

    /// Build the engine for a native variant name
    /// (`<platform>_<arch>_<task>[_w050|_w025][_fixed|_prune|_layerwise]`).
    pub fn build_with(variant: &str, opts: NativeOptions) -> Result<NativeBackend> {
        let cap = max_threads();
        if opts.threads > cap {
            bail!(
                "threads = {} exceeds {cap} (4x the machine's available cores): \
                 refusing to oversubscribe — use 0 (or omit --threads) for all cores",
                opts.threads
            );
        }
        let spec = SupernetSpec::build(variant)?;

        // --- state layout -------------------------------------------------
        let mut state_specs: Vec<IoSpec> = Vec::new();
        let push = |specs: &mut Vec<IoSpec>, name: String, shape: Vec<usize>| -> usize {
            specs.push(IoSpec {
                name,
                shape,
                dtype: "f32".into(),
            });
            specs.len() - 1
        };
        let mut geoms = Vec::with_capacity(spec.n_convs());
        for gi in 0..spec.n_convs() {
            let l = &spec.layers[gi];
            let name = &l.name;
            let w = push(&mut state_specs, format!("params/{name}/w"), spec.w_shape(gi));
            let scale = push(
                &mut state_specs,
                format!("params/{name}/bn/scale"),
                vec![l.cout],
            );
            let bias = push(
                &mut state_specs,
                format!("params/{name}/bn/bias"),
                vec![l.cout],
            );
            let mean = push(
                &mut state_specs,
                format!("params/{name}/bn/mean"),
                vec![l.cout],
            );
            let var = push(
                &mut state_specs,
                format!("params/{name}/bn/var"),
                vec![l.cout],
            );
            let theta = l.searchable.then(|| {
                push(
                    &mut state_specs,
                    format!("params/{name}/theta"),
                    spec.theta_shape(gi),
                )
            });
            geoms.push(GeomLeaves {
                w,
                scale,
                bias,
                mean,
                var,
                theta,
            });
        }
        let fc_w = push(
            &mut state_specs,
            "params/fc/w".into(),
            vec![spec.fc_cin, spec.classes],
        );
        let fc_b = push(&mut state_specs, "params/fc/b".into(), vec![spec.classes]);
        // optimizer leaves shadow every trainable W leaf
        let w_params: Vec<usize> = geoms
            .iter()
            .flat_map(|g| [g.w, g.scale, g.bias])
            .chain([fc_w, fc_b])
            .collect();
        let mut opt = Vec::with_capacity(w_params.len());
        for &p in &w_params {
            let suffix = state_specs[p]
                .name
                .strip_prefix("params/")
                .expect("trainable leaves live under params/")
                .to_string();
            let shape = state_specs[p].shape.clone();
            let (m, v) = match opts.w_optimizer {
                WOptimizer::SgdMomentum => {
                    (push(&mut state_specs, format!("opt_w/{suffix}"), shape), None)
                }
                WOptimizer::Adam => {
                    let m = push(&mut state_specs, format!("opt_w/{suffix}/m"), shape.clone());
                    let v = push(&mut state_specs, format!("opt_w/{suffix}/v"), shape);
                    (m, Some(v))
                }
            };
            opt.push(OptSlot { p, m, v });
        }
        let step_leaf = (opts.w_optimizer == WOptimizer::Adam)
            .then(|| push(&mut state_specs, "opt_w/t".into(), vec![1]));

        // --- manifest + derived cost constants ----------------------------
        let mut manifest = spec.to_manifest(CostScale {
            latency_cycles: 1.0,
            energy_uj: 1.0,
        });
        manifest.w_optimizer = opts.w_optimizer.name().into();
        let seq_names = crate::soc::sequential_layers(&manifest);
        let seq: Vec<bool> = spec
            .layers
            .iter()
            .map(|l| seq_names.iter().any(|s| s == &l.name))
            .collect();

        let cus = spec.platform.cus();
        let us = 1.0 / spec.platform.freq_mhz();
        let p_idle = spec.platform.p_idle_mw();
        let mut fixed_lat = 0.0;
        let mut fixed_energy_uj = 0.0;
        for l in spec.layers.iter().filter(|l| !l.searchable) {
            let mut counts = vec![0.0f64; cus.len()];
            counts[0] = l.cout as f64;
            let e = eval_layer_cost(cus, l, &counts, p_idle, us, false);
            fixed_lat += e.latency;
            fixed_energy_uj += e.energy_uj;
        }
        // scale = whole-network cost at the uniform-θ init point, so
        // config λ values stay comparable across variants and platforms
        let mut scale_lat = fixed_lat;
        let mut scale_energy = fixed_energy_uj;
        for (gi, l) in spec.layers.iter().enumerate().filter(|(_, l)| l.searchable) {
            let counts = spec.uniform_counts(gi);
            let e = eval_layer_cost(cus, l, &counts, p_idle, us, seq[gi]);
            scale_lat += e.latency;
            scale_energy += e.energy_uj;
        }
        manifest.cost_scale = CostScale {
            latency_cycles: scale_lat.max(1.0),
            energy_uj: scale_energy.max(1e-9),
        };

        // --- execution plan: size the per-shard arenas up front -----------
        let width = opts.threads.max(1);
        let plan = ExecPlan::new(&spec, spec.dataset.batch, NSHARDS, width);
        let mut arenas = Vec::with_capacity(plan.shards());
        for i in 0..plan.shards() {
            let mut a = Arena::new();
            plan.prime(i, &mut a);
            arenas.push(a);
        }

        // --- step-scoped f32 weight-pack slots (geometry-sized once) ------
        let wpp = weight_pack_plan(&spec);
        let wpacks: Vec<Option<Arc<WeightPackSlot>>> = wpp
            .convs
            .iter()
            .map(|g| g.map(|(rows, cols)| Arc::new(WeightPackSlot::new(rows, cols))))
            .collect();
        let fc_pack = Arc::new(WeightPackSlot::new(wpp.fc.0, wpp.fc.1));

        Ok(NativeBackend {
            spec,
            manifest,
            state_specs,
            geoms,
            fc_w,
            fc_b,
            opt,
            step_leaf,
            optimizer: opts.w_optimizer,
            pool: WorkerPool::new(width),
            plan,
            arenas: Mutex::new(arenas),
            wpacks,
            fc_pack,
            pack_epoch: AtomicU64::new(0),
            seq,
            fixed_lat,
            fixed_energy_uj,
        })
    }

    pub fn spec(&self) -> &SupernetSpec {
        &self.spec
    }

    /// Total fresh allocations the shard arenas had to perform beyond the
    /// execution plan (diagnostics; steady-state steps add zero).
    pub fn arena_grown(&self) -> u64 {
        self.arenas.lock().unwrap().iter().map(|a| a.grown()).sum()
    }

    /// Total f32 elements the execution plan provisioned.
    pub fn planned_elems(&self) -> usize {
        self.plan.planned_elems()
    }

    /// Fixed shard row ranges of an `n`-row batch (thread-count
    /// independent — this split is the numerical contract).
    fn shard_bounds(n: usize) -> Vec<(usize, usize)> {
        let s = NSHARDS.min(n).max(1);
        (0..s).map(|i| (i * n / s, (i + 1) * n / s)).collect()
    }

    fn take_arenas(&self, s: usize) -> Vec<Arena> {
        let mut pool = self.arenas.lock().unwrap();
        let mut out: Vec<Arena> = pool.drain(..s.min(pool.len())).collect();
        while out.len() < s {
            out.push(Arena::new());
        }
        out
    }

    fn put_arenas(&self, arenas: Vec<Arena>) {
        let mut pool = self.arenas.lock().unwrap();
        for (i, a) in arenas.into_iter().enumerate() {
            pool.insert(i.min(pool.len()), a);
        }
    }

    /// Put every parameter leaf on a fresh tape; returns the per-layer
    /// handles (carrying this epoch's weight-pack handles), the FC
    /// vars + pack handle, plus the list of `(leaf, var)` pairs per
    /// group. The effective weights each pack covers are bit-identical
    /// across shards (determinism contract), so whichever shard packs a
    /// slot first is unobservable.
    #[allow(clippy::type_complexity)]
    fn stage_params(
        &self,
        tape: &mut Tape,
        state: &TrainState,
    ) -> (Vec<LayerVars>, Var, Var, PackHandle, Vec<Var>, Vec<(usize, Var)>) {
        // bumped once per step/batch before the shard fan-out; the pool's
        // task handoff orders the load after the bump
        let epoch = self.pack_epoch.load(Ordering::Relaxed);
        let mut lvs = Vec::with_capacity(self.geoms.len());
        let mut w_vars = Vec::with_capacity(self.opt.len());
        let mut theta_vars = Vec::new();
        let leaf = |tape: &mut Tape, idx: usize| -> Var {
            tape.leaf_copy(self.state_specs[idx].shape.clone(), &state.leaves[idx])
        };
        for (gi, gl) in self.geoms.iter().enumerate() {
            let w = leaf(tape, gl.w);
            let scale = leaf(tape, gl.scale);
            let bias = leaf(tape, gl.bias);
            w_vars.extend([w, scale, bias]);
            let theta = gl.theta.map(|t| {
                let v = tape.leaf_copy(self.spec.theta_stage_shape(gi), &state.leaves[t]);
                theta_vars.push((t, v));
                v
            });
            let pack = self.wpacks[gi].as_ref().map(|slot| {
                PackHandle::new(
                    Arc::clone(slot),
                    epoch,
                    self.spec.layers[gi].cout,
                    self.spec.fan_in(gi),
                )
            });
            lvs.push(LayerVars {
                w,
                scale,
                bias,
                theta,
                pack,
            });
        }
        let fcw = leaf(tape, self.fc_w);
        let fcb = leaf(tape, self.fc_b);
        w_vars.extend([fcw, fcb]);
        let fcp = PackHandle::new(
            Arc::clone(&self.fc_pack),
            epoch,
            self.spec.fc_cin,
            self.spec.classes,
        );
        (lvs, fcw, fcb, fcp, w_vars, theta_vars)
    }

    fn running_stats(&self, state: &TrainState) -> Vec<(Vec<f32>, Vec<f32>)> {
        self.geoms
            .iter()
            .map(|g| (state.leaves[g.mean].clone(), state.leaves[g.var].clone()))
            .collect()
    }

    fn check_batch(&self, x: &[f32], y: &[i32]) -> Result<usize> {
        let hw = self.manifest.dataset.hw;
        let n = y.len();
        if x.len() != n * hw * hw * 3 {
            return Err(anyhow!(
                "batch shape mismatch: {} labels but {} pixels (expected {}·{hw}·{hw}·3)",
                n,
                x.len(),
                n
            ));
        }
        Ok(n)
    }

    /// Forward + backward of one batch shard on its own tape/arena.
    #[allow(clippy::too_many_arguments)]
    fn train_shard(
        &self,
        state: &TrainState,
        running: &[(Vec<f32>, Vec<f32>)],
        x: &[f32],
        y: &[i32],
        hp: StepHparams,
        scale: f32,
        scope: &KernelScope,
        arena: Arena,
    ) -> ShardOut {
        let hw = self.manifest.dataset.hw;
        let nb = y.len();
        let mut tape = Tape::with_arena(arena);
        tape.set_kernel_scope(scope.clone());
        let (lvs, fcw, fcb, fcp, w_vars, theta_vars) = self.stage_params(&mut tape, state);
        let xv = tape.leaf_copy(vec![nb, hw, hw, 3], x);
        let out = forward(
            &self.spec,
            &mut tape,
            &lvs,
            fcw,
            fcb,
            Some(&fcp),
            xv,
            true,
            running,
        );
        let (ce, bits) = tape.softmax_ce(out.logits, y);

        // differentiable cost term over the searchable layers — recorded
        // identically in every shard, weighted by the shard fraction so
        // the reduced gradient carries it exactly once
        let platform = self.spec.platform;
        let mut tot: Option<Var> = None;
        for gi in 0..self.spec.n_convs() {
            if let Some(cv) = out.counts[gi] {
                let lc = tape.layer_cost(
                    cv,
                    &self.spec.layers[gi],
                    platform.cus(),
                    platform.p_idle_mw(),
                    platform.freq_mhz(),
                    self.seq[gi],
                );
                tot = Some(match tot {
                    None => lc,
                    Some(t) => tape.add(t, lc),
                });
            }
        }
        let (loss, lat, energy_uj) = match tot {
            Some(t) => {
                let tv = tape.val(t);
                let lat = tv.data[0] as f64 + self.fixed_lat;
                let en = tv.data[1] as f64 + self.fixed_energy_uj;
                let cost = tape.weighted_pair(t, 1.0 - hp.cost_sel, hp.cost_sel);
                let scaled = tape.scale(cost, hp.lam);
                (tape.add(ce, scaled), lat, en)
            }
            None => (ce, self.fixed_lat, self.fixed_energy_uj),
        };
        let loss_scaled = tape.scale(loss, scale);
        let loss_val = tape.val(loss_scaled).item();
        let mut grads = tape.backward(loss_scaled);
        let keep: Vec<Vec<f32>> = w_vars
            .iter()
            .copied()
            .chain(theta_vars.iter().map(|&(_, v)| v))
            .map(|v| grads.take(v))
            .collect();
        tape.reclaim(grads);
        let arena = tape.recycle();
        ShardOut {
            scale,
            loss: loss_val,
            bits,
            lat,
            energy_uj,
            grads: keep,
            stats: out.batch_stats,
            arena,
        }
    }

    /// Inference forward of one batch shard.
    fn eval_shard(
        &self,
        state: &TrainState,
        running: &[(Vec<f32>, Vec<f32>)],
        x: &[f32],
        y: &[i32],
        scope: &KernelScope,
        arena: Arena,
    ) -> (EvalBits, Arena) {
        let hw = self.manifest.dataset.hw;
        let nb = y.len();
        let mut tape = Tape::with_arena(arena);
        tape.set_kernel_scope(scope.clone());
        let (lvs, fcw, fcb, fcp, _, _) = self.stage_params(&mut tape, state);
        let xv = tape.leaf_copy(vec![nb, hw, hw, 3], x);
        let out = forward(
            &self.spec,
            &mut tape,
            &lvs,
            fcw,
            fcb,
            Some(&fcp),
            xv,
            false,
            running,
        );
        let (_, bits) = tape.softmax_ce(out.logits, y);
        (bits, tape.recycle())
    }

    /// Discretize + quantize the current state into a real int8/ternary
    /// inference network: θ argmax per the spec's search mode, weights
    /// stored as i8 codes with per-channel scales, BN running stats
    /// folded — see [`super::qkernels`]. Build time also prepacks every
    /// dense conv's codes into the panel-major GEMM layout (one slab,
    /// sized by `plan::quant_pack_plan`, written exactly once) and fixes
    /// the qmatmul kernel tier from runtime CPU-feature detection —
    /// steady-state evals never repack or re-dispatch.
    pub fn quantize(&self, state: &TrainState) -> Result<QuantNet<'_>> {
        let geoms: Vec<GeomParams> = self
            .geoms
            .iter()
            .map(|g| GeomParams {
                w: &state.leaves[g.w],
                scale: &state.leaves[g.scale],
                bias: &state.leaves[g.bias],
                mean: &state.leaves[g.mean],
                var: &state.leaves[g.var],
                theta: g.theta.map(|t| state.leaves[t].as_slice()),
            })
            .collect();
        let mut qnet = QuantNet::build(
            &self.spec,
            &geoms,
            &state.leaves[self.fc_w],
            &state.leaves[self.fc_b],
        )?;
        // quantized evals shard onto the same persistent pool as f32
        // steps (scheduling only — outputs are thread-count independent)
        qnet.set_pool(&self.pool);
        Ok(qnet)
    }

    /// `[correct, loss_sum]` of the genuinely-quantized forward — the
    /// convenience one-shot form: it rebuilds the [`QuantNet`] from
    /// `state` on every call. Loops over many batches should call
    /// [`NativeBackend::quantize`] once and reuse the returned net
    /// (weights are constant during eval), as `repro eval --quantized`
    /// and the bench do.
    ///
    /// same metric pair as [`ModelBackend::eval_batch`], computed by the
    /// int8 GEMM path instead of the tape.
    pub fn eval_batch_quantized(
        &self,
        state: &TrainState,
        x: &[f32],
        y: &[i32],
    ) -> Result<Vec<f32>> {
        self.check_batch(x, y)?;
        self.quantize(state)?.eval_batch(x, y)
    }

    /// Run one closure per shard on the persistent pool and return the
    /// results in shard order. Shards become pool tasks (`i % groups`
    /// round-robin onto group leaders); pool slots beyond the shard
    /// count serve as kernel lanes inside their group, via the
    /// [`KernelScope`] handed to the closure. The closure must be pure
    /// per shard — ordering of execution never affects the outputs.
    fn run_sharded<T: Send, F: Fn(usize, Arena, &KernelScope) -> T + Sync>(
        &self,
        arenas: Vec<Arena>,
        run: F,
    ) -> Vec<T> {
        let s = arenas.len();
        let slots: Vec<Mutex<Option<Arena>>> =
            arenas.into_iter().map(|a| Mutex::new(Some(a))).collect();
        self.pool.run_tasks(s, &|i, scope| {
            let arena = slots[i]
                .lock()
                .unwrap()
                .take()
                .expect("each shard task runs exactly once");
            run(i, arena, scope)
        })
    }
}

/// Fixed-order binary tree reduction of per-shard gradients: each leaf
/// accumulates `((g0+g1)+(g2+g3))…` regardless of how many threads
/// produced (or reduce) them. Leaves are independent of one another, so
/// they fan out as pool tasks — the binary tree *within* a leaf keeps
/// the exact serial association, which is the whole determinism
/// argument: parallelism is across leaves, never across the reduction
/// order. Right-hand buffers are recycled into the paired shard's arena
/// *after* the parallel region (arenas are single-threaded).
fn tree_reduce_grads(outs: &mut [ShardOut], pool: &WorkerPool) -> Vec<Vec<f32>> {
    let s = outs.len();
    let nleaves = outs[0].grads.len();
    // transpose to leaf-major (Vec moves only, no element copies)
    let mut by_leaf: Vec<Vec<Vec<f32>>> = (0..nleaves).map(|_| Vec::with_capacity(s)).collect();
    for o in outs.iter_mut() {
        for (l, b) in std::mem::take(&mut o.grads).into_iter().enumerate() {
            by_leaf[l].push(b);
        }
    }
    let cells: Vec<Mutex<Option<Vec<Vec<f32>>>>> =
        by_leaf.into_iter().map(|v| Mutex::new(Some(v))).collect();
    let done: Vec<Vec<Vec<f32>>> = pool.run_tasks(nleaves, &|l, _scope| {
        let _p = profile::time(Op::Reduce);
        let mut g = cells[l]
            .lock()
            .unwrap()
            .take()
            .expect("each leaf reduces exactly once");
        let mut d = 1;
        while d < s {
            let mut i = 0;
            while i + d < s {
                let right = std::mem::take(&mut g[i + d]);
                for (a, &b) in g[i].iter_mut().zip(&right) {
                    *a += b;
                }
                // park the spent buffer back in its slot for recycling
                g[i + d] = right;
                i += 2 * d;
            }
            d *= 2;
        }
        g
    });
    let mut reduced = Vec::with_capacity(nleaves);
    for g in done {
        let mut it = g.into_iter();
        reduced.push(it.next().expect("leaf tree leaves the sum in slot 0"));
        for (j, spent) in it.enumerate() {
            outs[j + 1].arena.give(spent);
        }
    }
    reduced
}

/// θ → expected per-CU counts through [`theta_counts`] — the *same*
/// tape graph the training objective records, so the report and the
/// in-graph objective cannot drift apart.
fn expected_counts_native(spec: &SupernetSpec, gi: usize, theta: &[f32]) -> Vec<f64> {
    let mut tape = Tape::new();
    let th = tape.leaf_copy(spec.theta_stage_shape(gi), theta);
    let (_, n) = theta_counts(spec, &mut tape, gi, th);
    tape.val(n).data.iter().map(|&v| v as f64).collect()
}

impl ModelBackend for NativeBackend {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn state_specs(&self) -> &[IoSpec] {
        &self.state_specs
    }

    fn init_state(&self, seed: i32) -> Result<TrainState> {
        let mut leaves: Vec<Vec<f32>> = self
            .state_specs
            .iter()
            .map(|s| vec![0.0; s.elem_count()])
            .collect();
        for (gi, gl) in self.geoms.iter().enumerate() {
            let cout = self.spec.layers[gi].cout;
            leaves[gl.w] = init_conv_weight(&self.spec, gi, seed as u64, gi as u64);
            leaves[gl.scale] = vec![1.0; cout];
            leaves[gl.bias] = vec![0.0; cout];
            leaves[gl.mean] = vec![0.0; cout];
            leaves[gl.var] = vec![1.0; cout];
            if let Some(t) = gl.theta {
                leaves[t] = self.spec.theta_init(gi);
            }
        }
        let (w, b) = init_fc(self.spec.fc_cin, self.spec.classes, seed as u64);
        leaves[self.fc_w] = w;
        leaves[self.fc_b] = b;
        Ok(TrainState {
            leaves,
            names: self.state_specs.iter().map(|s| s.name.clone()).collect(),
        })
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        x: &[f32],
        y: &[i32],
        hp: StepHparams,
    ) -> Result<Vec<f32>> {
        let n = self.check_batch(x, y)?;
        let hw = self.manifest.dataset.hw;
        // new step, new weights: invalidate every cached weight pack
        self.pack_epoch.fetch_add(1, Ordering::Relaxed);
        let bounds = Self::shard_bounds(n);
        let s = bounds.len();
        let arenas = self.take_arenas(s);
        let state_ro: &TrainState = state;
        let running = self.running_stats(state_ro);
        let mut outs: Vec<ShardOut> = self.run_sharded(arenas, |i, arena, scope| {
            let (b0, b1) = bounds[i];
            let row = hw * hw * 3;
            self.train_shard(
                state_ro,
                &running,
                &x[b0 * row..b1 * row],
                &y[b0..b1],
                hp,
                (b1 - b0) as f32 / n as f32,
                scope,
                arena,
            )
        });

        // --- fixed-order reduction + metrics ------------------------------
        // (the Op::Reduce probes live inside the per-leaf tasks — lane-
        // summed attribution, see `super::profile`)
        let reduced = tree_reduce_grads(&mut outs, &self.pool);
        let mut loss_val = 0.0f32;
        let mut correct = 0.0f32;
        let mut loss_sum = 0.0f32;
        for o in &outs {
            loss_val += o.loss;
            correct += o.bits.correct;
            loss_sum += o.bits.loss_sum;
        }
        let (lat_metric, energy_metric) = (outs[0].lat, outs[0].energy_uj);

        // --- optimizer update (once, on the reduced gradients) ------------
        let n_w = self.opt.len();
        debug_assert_eq!(
            reduced.len(),
            n_w + self.geoms.iter().filter(|g| g.theta.is_some()).count()
        );
        // Each W leaf's update touches only its own parameter/optimizer
        // buffers, so leaves fan out as pool tasks; the arithmetic within
        // a leaf is the serial loop's, so results are thread-count
        // independent. Leaves are moved out via per-task cells and put
        // back in slot order (Op::Optimizer probes sit inside the tasks —
        // lane-summed attribution, see `super::profile`).
        match self.optimizer {
            WOptimizer::SgdMomentum => {
                let cells: Vec<Mutex<Option<(Vec<f32>, Vec<f32>)>>> = self
                    .opt
                    .iter()
                    .map(|slot| {
                        Mutex::new(Some((
                            std::mem::take(&mut state.leaves[slot.p]),
                            std::mem::take(&mut state.leaves[slot.m]),
                        )))
                    })
                    .collect();
                let reduced_ro: &[Vec<f32>] = &reduced;
                let done = self.pool.run_tasks(n_w, &|i, _scope| {
                    let _p = profile::time(Op::Optimizer);
                    let (mut p, mut m) = cells[i]
                        .lock()
                        .unwrap()
                        .take()
                        .expect("each W leaf updates exactly once");
                    scale_add_into(&mut m, W_MOMENTUM, &reduced_ro[i]);
                    axpy_into(&mut p, -hp.lr_w, &m);
                    (p, m)
                });
                for (slot, (p, m)) in self.opt.iter().zip(done) {
                    state.leaves[slot.p] = p;
                    state.leaves[slot.m] = m;
                }
            }
            WOptimizer::Adam => {
                // the shared step counter / bias corrections are scalar
                // work: serial, before the fan-out
                let tl = self.step_leaf.expect("adam state has a step leaf");
                state.leaves[tl][0] += 1.0;
                let t = state.leaves[tl][0] as i32;
                let b1c = (1.0 - ADAM_B1.powi(t)) as f32;
                let b2c = (1.0 - ADAM_B2.powi(t)) as f32;
                type PmV = (Vec<f32>, Vec<f32>, Vec<f32>);
                let cells: Vec<Mutex<Option<PmV>>> = self
                    .opt
                    .iter()
                    .map(|slot| {
                        let v_leaf = slot.v.expect("adam slots carry a second moment");
                        Mutex::new(Some((
                            std::mem::take(&mut state.leaves[slot.p]),
                            std::mem::take(&mut state.leaves[slot.m]),
                            std::mem::take(&mut state.leaves[v_leaf]),
                        )))
                    })
                    .collect();
                let reduced_ro: &[Vec<f32>] = &reduced;
                let done = self.pool.run_tasks(n_w, &|i, _scope| {
                    let _p = profile::time(Op::Optimizer);
                    let (mut p, mut m, mut v) = cells[i]
                        .lock()
                        .unwrap()
                        .take()
                        .expect("each W leaf updates exactly once");
                    let g = &reduced_ro[i];
                    for (mv, &gv) in m.iter_mut().zip(g) {
                        *mv = (ADAM_B1 as f32) * *mv + (1.0 - ADAM_B1 as f32) * gv;
                    }
                    for (vv, &gv) in v.iter_mut().zip(g) {
                        *vv = (ADAM_B2 as f32) * *vv + (1.0 - ADAM_B2 as f32) * gv * gv;
                    }
                    for ((pv, &mv), &vv) in p.iter_mut().zip(&m).zip(&v) {
                        let mhat = mv / b1c;
                        let vhat = vv / b2c;
                        *pv -= hp.lr_w * mhat / (vhat.sqrt() + ADAM_EPS);
                    }
                    (p, m, v)
                });
                for (slot, (p, m, v)) in self.opt.iter().zip(done) {
                    state.leaves[slot.p] = p;
                    state.leaves[slot.m] = m;
                    state.leaves[slot.v.expect("adam slots carry a second moment")] = v;
                }
            }
        }
        // θ: plain SGD on its own learning rate — a handful of tiny [c,k]
        // tables, not worth a fan-out
        {
            let _p = profile::time(Op::Optimizer);
            let theta_leaves: Vec<usize> = self.geoms.iter().filter_map(|g| g.theta).collect();
            for (tleaf, g) in theta_leaves.iter().zip(&reduced[n_w..]) {
                axpy_into(&mut state.leaves[*tleaf], -hp.lr_th, g);
            }
        }

        // --- BN running statistics (shard-weighted, fixed order) ----------
        // geometries are independent (each owns its mean/var leaves), so
        // they fan out as pool tasks; the shard-weighted sum within a
        // geometry stays in shard-index order — the numerical contract
        {
            let with_stats: Vec<usize> = (0..self.geoms.len())
                .filter(|&gi| outs[0].stats[gi].is_some())
                .collect();
            type MeanVar = (Vec<f32>, Vec<f32>);
            let cells: Vec<Mutex<Option<MeanVar>>> = with_stats
                .iter()
                .map(|&gi| {
                    let gl = &self.geoms[gi];
                    Mutex::new(Some((
                        std::mem::take(&mut state.leaves[gl.mean]),
                        std::mem::take(&mut state.leaves[gl.var]),
                    )))
                })
                .collect();
            let outs_ro: &[ShardOut] = &outs;
            let done = self.pool.run_tasks(with_stats.len(), &|i, _scope| {
                let _p = profile::time(Op::Reduce);
                let gi = with_stats[i];
                let cout = self.spec.layers[gi].cout;
                let (mut rm, mut rv) = cells[i]
                    .lock()
                    .unwrap()
                    .take()
                    .expect("each geometry merges exactly once");
                let mut mean = vec![0.0f32; cout];
                let mut var = vec![0.0f32; cout];
                for o in outs_ro {
                    let (m, v) = o.stats[gi].as_ref().expect("shards share the geometry");
                    for (acc, &x) in mean.iter_mut().zip(m) {
                        *acc += o.scale * x;
                    }
                    for (acc, &x) in var.iter_mut().zip(v) {
                        *acc += o.scale * x;
                    }
                }
                for (m, &b) in rm.iter_mut().zip(&mean) {
                    *m = BN_MOMENTUM * *m + (1.0 - BN_MOMENTUM) * b;
                }
                for (v, &b) in rv.iter_mut().zip(&var) {
                    *v = BN_MOMENTUM * *v + (1.0 - BN_MOMENTUM) * b;
                }
                (rm, rv)
            });
            for (&gi, (rm, rv)) in with_stats.iter().zip(done) {
                let gl = &self.geoms[gi];
                state.leaves[gl.mean] = rm;
                state.leaves[gl.var] = rv;
            }
        }

        // --- recycle ------------------------------------------------------
        for g in reduced {
            outs[0].arena.give(g);
        }
        self.put_arenas(outs.into_iter().map(|o| o.arena).collect());

        Ok(vec![
            loss_val,
            loss_sum / n as f32,
            correct / n as f32,
            lat_metric as f32,
            energy_metric as f32,
        ])
    }

    fn eval_batch(&self, state: &TrainState, x: &[f32], y: &[i32]) -> Result<Vec<f32>> {
        let n = self.check_batch(x, y)?;
        let hw = self.manifest.dataset.hw;
        // eval weights may differ from the last packed step's
        self.pack_epoch.fetch_add(1, Ordering::Relaxed);
        let bounds = Self::shard_bounds(n);
        let s = bounds.len();
        let arenas = self.take_arenas(s);
        let running = self.running_stats(state);
        let outs = self.run_sharded(arenas, |i, arena, scope| {
            let (b0, b1) = bounds[i];
            let row = hw * hw * 3;
            self.eval_shard(
                state,
                &running,
                &x[b0 * row..b1 * row],
                &y[b0..b1],
                scope,
                arena,
            )
        });
        let mut correct = 0.0f32;
        let mut loss_sum = 0.0f32;
        let mut arenas = Vec::with_capacity(s);
        for (bits, arena) in outs {
            correct += bits.correct;
            loss_sum += bits.loss_sum;
            arenas.push(arena);
        }
        self.put_arenas(arenas);
        Ok(vec![correct, loss_sum])
    }

    fn cost_report(&self, state: &TrainState) -> Result<(Vec<f32>, Vec<f32>)> {
        let platform = self.spec.platform;
        let cus = platform.cus();
        let k = cus.len();
        let us = 1.0 / platform.freq_mhz();
        let p_idle = platform.p_idle_mw();
        let mut mat = Vec::with_capacity(self.spec.layers.len() * 2 * k);
        let mut lat_total = 0.0f64;
        let mut energy_total = 0.0f64;
        for (gi, l) in self.spec.layers.iter().enumerate() {
            let counts: Vec<f64> = match self.geoms.get(gi).and_then(|g| g.theta) {
                Some(t) => expected_counts_native(&self.spec, gi, &state.leaves[t]),
                None => {
                    let mut c = vec![0.0; k];
                    c[0] = l.cout as f64;
                    c
                }
            };
            let e = eval_layer_cost(cus, l, &counts, p_idle, us, self.seq[gi]);
            lat_total += e.latency;
            energy_total += e.energy_uj;
            mat.extend(counts.iter().map(|&n| n as f32));
            mat.extend(e.cycles.iter().map(|&c| c as f32));
        }
        Ok((mat, vec![lat_total as f32, energy_total as f32]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(vals: &[Vec<f32>]) -> ShardOut {
        ShardOut {
            scale: 0.25,
            loss: 0.0,
            bits: EvalBits {
                correct: 0.0,
                loss_sum: 0.0,
            },
            lat: 0.0,
            energy_uj: 0.0,
            grads: vals.to_vec(),
            stats: Vec::new(),
            arena: Arena::new(),
        }
    }

    /// The per-leaf-parallel reduce must reproduce the serial reference
    /// tree bit for bit: leaves only move across tasks, the fixed
    /// ((g0+g1)+(g2+g3)) association within each leaf never changes.
    #[test]
    fn parallel_tree_reduce_matches_serial_reference() {
        let leaves: Vec<Vec<Vec<f32>>> = (0..4)
            .map(|s| {
                (0..5)
                    .map(|l| {
                        (0..(l + 3))
                            .map(|j| ((s * 31 + l * 7 + j) as f32 * 0.37).sin())
                            .collect()
                    })
                    .collect()
            })
            .collect();
        let run = |width: usize| -> Vec<Vec<u32>> {
            let pool = WorkerPool::new(width);
            let mut outs: Vec<ShardOut> = leaves.iter().map(|g| shard(g)).collect();
            tree_reduce_grads(&mut outs, &pool)
                .into_iter()
                .map(|v| v.iter().map(|x| x.to_bits()).collect())
                .collect()
        };
        let serial = run(1);
        for width in [2usize, 3, 6] {
            assert_eq!(serial, run(width), "reduce differs at width {width}");
        }
    }
}
