//! Persistent worker pool for the native engine: long-lived threads
//! created once per backend, driven by a barrier/epoch protocol — no
//! per-step or per-kernel thread spawning.
//!
//! Before this module every `par_matmul_*` call and every sharded train
//! step paid a `std::thread::scope` spawn/join round trip; at the small
//! batch shards the supernets actually train on, that fixed cost rivaled
//! the kernel work itself. The pool amortizes it: [`WorkerPool::new`]
//! spawns `width − 1` workers once (the caller is always slot 0), and
//! each parallel region is one condvar broadcast plus one barrier wait.
//!
//! Two tiers share the same pool:
//!
//! * **Tasks** ([`WorkerPool::run_tasks`]) — the step executor's batch
//!   shards. The pool's slots are partitioned into `min(width, ntasks)`
//!   contiguous *groups*; the first slot of each group is the leader and
//!   runs tasks `g, g + ngroups, …` in index order.
//! * **Kernel lanes** ([`KernelScope`]) — the row sharding inside the
//!   blocked matmul/conv kernels. A group's non-leader slots park on the
//!   group's [`GroupGate`]; when the leader's tape hits a parallel
//!   kernel it publishes the row closure to the gate and the group's
//!   lanes execute their static index-ordered ranges — the nested
//!   scoped spawns of the previous executor become slot reuse.
//!
//! Determinism: the pool never makes scheduling decisions that reach the
//! numbers. Task→group assignment is `i % ngroups`, lane ranges are the
//! same `lane·rows/t` split the scoped-thread wrappers used, and every
//! output element is still produced by exactly one lane in a fixed
//! accumulation order — so results are bit-identical for any `width`
//! (the PR-4 1/2/4-thread matrix passes unchanged).
//!
//! Panic safety: a panicking task or kernel lane marks its gate/pool
//! poisoned, the barrier still completes (so no borrow outlives its
//! frame), and the panic is re-raised on the caller — the pool itself
//! stays usable. Dropping the pool shuts the workers down and joins
//! them; no thread outlives the backend.
//!
//! Affinity: `ODIMO_PIN_WORKERS=1` pins slot `i` round-robin over an
//! SMT-aware core order — physical (primary) cores first, hyperthread
//! siblings only after every physical core has a worker — read once
//! from `/sys/devices/system/cpu/*/topology/thread_siblings_list`; when
//! sysfs is unreadable the order degrades to the identity `i % cores`
//! (Linux only; a no-op elsewhere — see [`pin_thread_to_core`]).
//! Default off, because the OS scheduler usually does fine at ≤ 8
//! threads and pinning hurts when the pool shares the machine. It helps
//! when worker count approaches or exceeds the core count (the
//! ROADMAP's ">8-thread scaling" debt): pinned lanes stop migrating
//! between cores mid-kernel, so per-core caches stay warm across the
//! barrier/epoch rounds and NUMA nodes keep their arena buffers local.
//! Pinning never reaches the numbers — results stay bit-identical.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Upper bound on a sane worker count, as a multiple of the machine's
/// available cores: beyond this, "more threads" is pure oversubscription
/// overhead and almost certainly a config typo.
pub const MAX_THREADS_PER_CORE: usize = 4;

/// Largest worker count this machine accepts (`4 × available cores`).
pub fn max_threads() -> usize {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    MAX_THREADS_PER_CORE * cores
}

/// True when the user opted into worker→core affinity pinning
/// (`ODIMO_PIN_WORKERS=1`). Read at pool construction, so the flag must
/// be set before the backend is built.
pub fn pin_workers_requested() -> bool {
    std::env::var("ODIMO_PIN_WORKERS").as_deref() == Ok("1")
}

/// SMT-aware pinning order: every CPU whose
/// `topology/thread_siblings_list` names it as the lowest member of its
/// sibling set (i.e. the "primary" hyperthread) comes first, ascending;
/// sibling hyperthreads follow, also ascending — so round-robin pinning
/// lands one worker per physical core before doubling any of them up.
/// If any CPU's sysfs entry is unreadable the order degrades to the
/// identity permutation (the previous `i % cores` behaviour). Computed
/// once per process.
#[cfg(target_os = "linux")]
fn core_order() -> &'static [usize] {
    use std::sync::OnceLock;
    static ORDER: OnceLock<Vec<usize>> = OnceLock::new();
    ORDER.get_or_init(|| {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(1024);
        let mut primary = Vec::new();
        let mut sibling = Vec::new();
        for cpu in 0..cores {
            let path = format!("/sys/devices/system/cpu/cpu{cpu}/topology/thread_siblings_list");
            match std::fs::read_to_string(&path).ok().and_then(|s| siblings_min(&s)) {
                Some(min) if min != cpu => sibling.push(cpu),
                Some(_) => primary.push(cpu),
                None => return (0..cores).collect(),
            }
        }
        primary.extend(sibling);
        primary
    })
}

/// Lowest CPU id in a sysfs siblings list ("0,4", "0-1", "2", …).
#[cfg(target_os = "linux")]
fn siblings_min(list: &str) -> Option<usize> {
    list.trim()
        .split(',')
        .filter_map(|tok| tok.split('-').next())
        .filter_map(|tok| tok.trim().parse::<usize>().ok())
        .min()
}

/// Pin the calling thread to the `core`-th entry of the SMT-aware core
/// order (best effort). Returns whether the platform supports pinning
/// at all; the syscall's own result is ignored — a failed pin just
/// leaves the thread where the scheduler put it, which is exactly the
/// default behaviour.
#[cfg(target_os = "linux")]
pub fn pin_thread_to_core(core: usize) -> bool {
    // glibc cpu_set_t: 1024 bits. No libc crate in-tree, so declare the
    // one symbol we need; pid 0 = the calling thread.
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    let order = core_order();
    let bit = order[core % order.len().max(1)] % 1024;
    let mut mask = [0u64; 16];
    mask[bit / 64] = 1u64 << (bit % 64);
    unsafe {
        let _ = sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr());
    }
    true
}

/// Non-Linux: affinity pinning is a no-op (returns `false`).
#[cfg(not(target_os = "linux"))]
pub fn pin_thread_to_core(_core: usize) -> bool {
    false
}

// ---------------------------------------------------------------------------
// type-erased jobs
// ---------------------------------------------------------------------------

/// A borrowed `Fn(usize)` with its lifetime erased so it can sit in a
/// `Mutex` the worker threads read. Soundness contract: whoever
/// publishes a `RawJob` must not return (or unwind past the closure's
/// frame) until every participant is known to have finished running it
/// — both tiers below wait out their barrier even when the closure
/// panics, which is exactly that guarantee.
#[derive(Clone, Copy)]
struct RawJob {
    /// `*const &'a (dyn Fn(usize) + Sync)` — a thin pointer to the fat
    /// reference, which lives on the publisher's stack
    data: *const (),
    call: unsafe fn(*const (), usize),
}

unsafe impl Send for RawJob {}

unsafe fn call_erased(data: *const (), idx: usize) {
    let f = *(data as *const &(dyn Fn(usize) + Sync));
    f(idx)
}

impl RawJob {
    /// Erase `f`'s lifetime. See the struct-level soundness contract.
    unsafe fn of(f: &&(dyn Fn(usize) + Sync)) -> RawJob {
        RawJob {
            data: f as *const &(dyn Fn(usize) + Sync) as *const (),
            call: call_erased,
        }
    }
}

// ---------------------------------------------------------------------------
// group gate: the kernel-lane tier
// ---------------------------------------------------------------------------

struct GateState {
    epoch: u64,
    job: Option<RawJob>,
    finished: bool,
    done: usize,
    poisoned: bool,
}

/// Rendezvous point of one slot group: the leader publishes kernel
/// closures, the member lanes execute them, a done-count barrier closes
/// each region.
pub struct GroupGate {
    state: Mutex<GateState>,
    go: Condvar,
    done_cv: Condvar,
}

impl GroupGate {
    fn new() -> GroupGate {
        GroupGate {
            state: Mutex::new(GateState {
                epoch: 0,
                job: None,
                finished: false,
                done: 0,
                poisoned: false,
            }),
            go: Condvar::new(),
            done_cv: Condvar::new(),
        }
    }

    /// Park as lane `lane` (≥ 1): run each published job, leave when the
    /// leader declares the group finished.
    fn member_loop(&self, lane: usize) {
        let mut seen = 0u64;
        loop {
            let job = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if st.epoch != seen {
                        break;
                    }
                    if st.finished {
                        return;
                    }
                    st = self.go.wait(st).unwrap();
                }
                seen = st.epoch;
                st.job.expect("gate epoch advanced without a job")
            };
            let r = catch_unwind(AssertUnwindSafe(|| unsafe { (job.call)(job.data, lane) }));
            let mut st = self.state.lock().unwrap();
            if r.is_err() {
                st.poisoned = true;
            }
            st.done += 1;
            self.done_cv.notify_all();
        }
    }

    /// Leader side: release the member lanes (called once, after the
    /// group's last task — also on unwind, via [`FinishGuard`]).
    fn finish(&self) {
        let mut st = self.state.lock().unwrap();
        st.finished = true;
        self.go.notify_all();
    }
}

/// Calls [`GroupGate::finish`] on drop so member lanes are released even
/// when the leader's task unwinds.
struct FinishGuard<'a>(&'a GroupGate);

impl Drop for FinishGuard<'_> {
    fn drop(&mut self) {
        self.0.finish();
    }
}

/// Kernel-lane handle a task executes under: `lanes()` slots (leader =
/// lane 0) that [`KernelScope::run`] fans a closure across. Cheap to
/// clone (it rides inside tape backward closures); must only be run
/// from the task that received it, while that task is live.
#[derive(Clone, Default)]
pub struct KernelScope {
    gate: Option<Arc<GroupGate>>,
    lanes: usize,
}

impl KernelScope {
    /// A scope with a single lane: every kernel runs serially inline.
    pub fn serial() -> KernelScope {
        KernelScope {
            gate: None,
            lanes: 1,
        }
    }

    fn group(gate: Arc<GroupGate>, lanes: usize) -> KernelScope {
        debug_assert!(lanes >= 1);
        KernelScope {
            gate: if lanes > 1 { Some(gate) } else { None },
            lanes: lanes.max(1),
        }
    }

    /// Worker slots available to a kernel (≥ 1).
    pub fn lanes(&self) -> usize {
        self.lanes.max(1)
    }

    /// Run `f(lane)` on every lane (0 = the calling thread), returning
    /// when all lanes are done. Lanes that have no work must simply
    /// return. Panics in any lane are re-raised here after the barrier.
    pub fn run(&self, f: &(dyn Fn(usize) + Sync)) {
        let gate = match (&self.gate, self.lanes) {
            (Some(g), n) if n > 1 => g,
            _ => {
                f(0);
                return;
            }
        };
        let fr: &(dyn Fn(usize) + Sync) = f;
        let job = unsafe { RawJob::of(&fr) };
        {
            let mut st = gate.state.lock().unwrap();
            st.epoch += 1;
            st.job = Some(job);
            st.done = 0;
            gate.go.notify_all();
        }
        let r = catch_unwind(AssertUnwindSafe(|| f(0)));
        let mut st = gate.state.lock().unwrap();
        while st.done < self.lanes - 1 {
            st = gate.done_cv.wait(st).unwrap();
        }
        st.job = None;
        let poisoned = std::mem::replace(&mut st.poisoned, false);
        drop(st);
        if let Err(p) = r {
            std::panic::resume_unwind(p);
        }
        if poisoned {
            panic!("kernel lane panicked");
        }
    }
}

// ---------------------------------------------------------------------------
// the pool
// ---------------------------------------------------------------------------

struct JobSlot {
    epoch: u64,
    job: Option<RawJob>,
    shutdown: bool,
}

struct DoneState {
    done: usize,
    poisoned: bool,
}

struct PoolShared {
    job: Mutex<JobSlot>,
    go: Condvar,
    done: Mutex<DoneState>,
    done_cv: Condvar,
}

/// Persistent pool of `width` slots: the caller is slot 0, slots
/// `1..width` are long-lived threads spawned once and joined on drop.
pub struct WorkerPool {
    width: usize,
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
    /// serializes concurrent broadcasts (the pool carries one job at a time)
    run_lock: Mutex<()>,
}

impl WorkerPool {
    /// Spawn `width - 1` workers (a 1-wide pool spawns nothing and runs
    /// everything inline on the caller).
    pub fn new(width: usize) -> WorkerPool {
        let width = width.max(1);
        let shared = Arc::new(PoolShared {
            job: Mutex::new(JobSlot {
                epoch: 0,
                job: None,
                shutdown: false,
            }),
            go: Condvar::new(),
            done: Mutex::new(DoneState {
                done: 0,
                poisoned: false,
            }),
            done_cv: Condvar::new(),
        });
        let pin = pin_workers_requested();
        let handles = (1..width)
            .map(|slot| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("odimo-worker-{slot}"))
                    .spawn(move || {
                        if pin {
                            pin_thread_to_core(slot);
                        }
                        worker_loop(&sh, slot)
                    })
                    .expect("spawning pool worker")
            })
            .collect();
        if pin {
            // slot 0 is the constructing thread — the one that will
            // drive run_tasks — so it gets core 0
            pin_thread_to_core(0);
        }
        WorkerPool {
            width,
            shared,
            handles,
            run_lock: Mutex::new(()),
        }
    }

    /// Slot count (worker threads + the caller).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Broadcast `f(slot)` to every slot and wait for all of them.
    /// Panics in any slot are re-raised here after the barrier.
    fn run(&self, f: &(dyn Fn(usize) + Sync)) {
        if self.width <= 1 {
            f(0);
            return;
        }
        // a propagated task panic unwinds through this guard and poisons
        // the mutex; the pool state itself is consistent (the barrier
        // completed), so poisoning is ignorable
        let _serial = self
            .run_lock
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        let fr: &(dyn Fn(usize) + Sync) = f;
        let job = unsafe { RawJob::of(&fr) };
        {
            let mut st = self.shared.job.lock().unwrap();
            st.epoch += 1;
            st.job = Some(job);
            self.shared.go.notify_all();
        }
        let r = catch_unwind(AssertUnwindSafe(|| f(0)));
        let mut d = self.shared.done.lock().unwrap();
        while d.done < self.width - 1 {
            d = self.shared.done_cv.wait(d).unwrap();
        }
        d.done = 0;
        let poisoned = std::mem::replace(&mut d.poisoned, false);
        drop(d);
        {
            // retire the job pointer before the closure's frame can die
            let mut st = self.shared.job.lock().unwrap();
            st.job = None;
        }
        if let Err(p) = r {
            std::panic::resume_unwind(p);
        }
        if poisoned {
            panic!("pool worker panicked");
        }
    }

    /// Run `ntasks` independent tasks across the pool and return their
    /// results in task order.
    ///
    /// Slots are partitioned into `min(width, ntasks)` contiguous
    /// groups; each group's leader executes tasks `g, g + ngroups, …`
    /// (so the assignment depends only on `width` and `ntasks`, never on
    /// timing) and passes its [`KernelScope`] — the group's lanes — to
    /// the task closure for row-sharded kernels.
    pub fn run_tasks<T: Send>(
        &self,
        ntasks: usize,
        f: &(dyn Fn(usize, &KernelScope) -> T + Sync),
    ) -> Vec<T> {
        if ntasks == 0 {
            return Vec::new();
        }
        if self.width <= 1 {
            let scope = KernelScope::serial();
            return (0..ntasks).map(|i| f(i, &scope)).collect();
        }
        let ngroups = self.width.min(ntasks);
        // contiguous slot ranges [g·width/ngroups, (g+1)·width/ngroups)
        let starts: Vec<usize> = (0..=ngroups).map(|g| g * self.width / ngroups).collect();
        let gates: Vec<Arc<GroupGate>> = (0..ngroups).map(|_| Arc::new(GroupGate::new())).collect();
        let results: Vec<Mutex<Option<T>>> = (0..ntasks).map(|_| Mutex::new(None)).collect();
        let spmd = |slot: usize| {
            let g = match starts.binary_search(&slot) {
                Ok(g) if g < ngroups => g,
                Ok(g) => g - 1, // slot == width can't occur; defensive
                Err(ins) => ins - 1,
            };
            let size = starts[g + 1] - starts[g];
            if slot == starts[g] {
                // group leader: run this group's tasks in index order
                let _release_lanes = FinishGuard(&gates[g]);
                let scope = KernelScope::group(Arc::clone(&gates[g]), size);
                let mut i = g;
                while i < ntasks {
                    let out = f(i, &scope);
                    *results[i].lock().unwrap() = Some(out);
                    i += ngroups;
                }
            } else {
                gates[g].member_loop(slot - starts[g]);
            }
        };
        self.run(&spmd);
        results
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap()
                    .expect("every task index is covered by exactly one leader")
            })
            .collect()
    }
}

/// The kernel-lane count [`WorkerPool::run_tasks`] hands task `i` on a
/// pool of `width` slots — the same `min(width, ntasks)`-group
/// partition, computed without a pool. `plan::ExecPlan` uses this to
/// size per-shard lane scratch (fused im2col panels) to the exact lane
/// count the shard will run with, so the arena's exact-length free
/// lists hit in the steady state.
pub fn task_lanes(width: usize, ntasks: usize, i: usize) -> usize {
    debug_assert!(ntasks > 0);
    if width <= 1 {
        return 1;
    }
    let ngroups = width.min(ntasks);
    let g = i % ngroups;
    (g + 1) * width / ngroups - g * width / ngroups
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.job.lock().unwrap();
            st.shutdown = true;
            self.shared.go.notify_all();
        }
        for h in std::mem::take(&mut self.handles) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared, slot: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.job.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    break;
                }
                st = shared.go.wait(st).unwrap();
            }
            seen = st.epoch;
            st.job.expect("pool epoch advanced without a job")
        };
        let r = catch_unwind(AssertUnwindSafe(|| unsafe { (job.call)(job.data, slot) }));
        let mut d = shared.done.lock().unwrap();
        if r.is_err() {
            d.poisoned = true;
        }
        d.done += 1;
        shared.done_cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_tasks_returns_in_task_order() {
        for width in [1usize, 2, 3, 5, 8] {
            let pool = WorkerPool::new(width);
            let out = pool.run_tasks(7, &|i, _scope| i * 10);
            assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60], "width={width}");
        }
    }

    #[test]
    fn single_task_gets_all_lanes() {
        let pool = WorkerPool::new(4);
        let lanes = pool.run_tasks(1, &|_i, scope| scope.lanes());
        assert_eq!(lanes, vec![4]);
        // more tasks than slots → every group is one lane wide
        let lanes = pool.run_tasks(8, &|_i, scope| scope.lanes());
        assert!(lanes.iter().all(|&l| l == 1), "{lanes:?}");
    }

    #[test]
    fn task_lanes_predicts_actual_scope_lanes() {
        // the plan sizes lane scratch from task_lanes — it must agree
        // with the lane count run_tasks actually hands each task
        for width in [1usize, 2, 3, 4, 5, 8] {
            for ntasks in [1usize, 2, 3, 4, 7] {
                let pool = WorkerPool::new(width);
                let got = pool.run_tasks(ntasks, &|i, scope| (i, scope.lanes()));
                for (i, lanes) in got {
                    assert_eq!(
                        lanes,
                        task_lanes(width, ntasks, i),
                        "width={width} ntasks={ntasks} task={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn kernel_scope_covers_every_lane_exactly_once() {
        let pool = WorkerPool::new(6);
        let hits = pool.run_tasks(1, &|_i, scope| {
            let n = scope.lanes();
            let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            for _round in 0..3 {
                scope.run(&|lane| {
                    counts[lane].fetch_add(1, Ordering::Relaxed);
                });
            }
            counts.iter().map(|c| c.load(Ordering::Relaxed)).collect::<Vec<_>>()
        });
        assert_eq!(hits[0], vec![3; 6]);
    }

    #[test]
    fn drop_joins_and_releases_workers() {
        let pool = WorkerPool::new(5);
        let _ = pool.run_tasks(3, &|i, _s| i);
        let weak = Arc::downgrade(&pool.shared);
        drop(pool);
        assert!(
            weak.upgrade().is_none(),
            "joined workers must release their shared-state handles"
        );
    }

    #[test]
    fn oversubscribed_pool_still_completes() {
        // far more slots than this machine has cores
        let pool = WorkerPool::new(16);
        let out = pool.run_tasks(16, &|i, _s| i);
        assert_eq!(out.len(), 16);
        assert!(out.iter().enumerate().all(|(i, &v)| i == v));
    }

    #[test]
    fn pool_survives_a_panicking_task() {
        let pool = WorkerPool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run_tasks(4, &|i, _s| {
                if i == 2 {
                    panic!("task boom");
                }
                i
            })
        }));
        assert!(r.is_err(), "the panic must propagate to the caller");
        // the pool is still usable afterwards
        let out = pool.run_tasks(4, &|i, _s| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4]);
    }

    #[test]
    fn kernel_lane_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run_tasks(1, &|_i, scope| {
                scope.run(&|lane| {
                    if lane == 1 {
                        panic!("lane boom");
                    }
                });
            })
        }));
        assert!(r.is_err());
        let out = pool.run_tasks(2, &|i, _s| i);
        assert_eq!(out, vec![0, 1]);
    }

    #[test]
    fn max_threads_scales_with_cores() {
        assert!(max_threads() >= MAX_THREADS_PER_CORE);
    }

    #[test]
    fn affinity_pinning_is_best_effort_and_safe() {
        // exercises the syscall path (or the no-op stub) directly; the
        // env flag itself isn't tested because env vars are process-
        // global and tests run concurrently
        let supported = pin_thread_to_core(0);
        assert_eq!(supported, cfg!(target_os = "linux"));
        // out-of-range cores wrap instead of producing an empty mask
        pin_thread_to_core(usize::MAX);
        let pool = WorkerPool::new(3);
        let out = pool.run_tasks(3, &|i, _s| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    /// The SMT-aware order must still visit every CPU exactly once —
    /// it only *reorders* (physical cores first), never drops or
    /// duplicates, including on hosts where sysfs is unreadable (the
    /// identity fallback).
    #[cfg(target_os = "linux")]
    #[test]
    fn core_order_is_a_permutation_of_available_cpus() {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(1024);
        let mut order = core_order().to_vec();
        order.sort_unstable();
        assert_eq!(order, (0..cores).collect::<Vec<_>>());
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn siblings_list_parses_all_sysfs_formats() {
        assert_eq!(siblings_min("0,4\n"), Some(0));
        assert_eq!(siblings_min("0-1"), Some(0));
        assert_eq!(siblings_min("2"), Some(2));
        assert_eq!(siblings_min("3,7\n"), Some(3));
        assert_eq!(siblings_min(""), None);
        assert_eq!(siblings_min("garbage"), None);
    }
}
