//! Per-op step profiler for the native engine: a feature-gated timing
//! layer over the tape ops and the backend's reduce/optimize phases, so
//! kernel work is guided by measured breakdowns (im2col vs matmul vs BN
//! vs optimizer) instead of guesses.
//!
//! Compiled in by the default `op-profile` cargo feature (build with
//! `--no-default-features` to remove every timing call); *enabled* at
//! runtime by [`set_enabled`] — `repro … --profile` and the
//! `native_train` bench flip it on. Disabled, each probe is a single
//! relaxed atomic load; enabled, two `Instant` reads per op plus two
//! relaxed `fetch_add`s into global counters, so worker threads record
//! concurrently without locks. Timings are *observational only* — the
//! profiler never touches the numbers, so determinism is unaffected.
//!
//! Usage: wrap an op body in `let _p = profile::time(Op::Matmul);` —
//! the guard records on drop. [`snapshot`] returns the accumulated
//! `(op, total_ns, calls)` rows; [`report`] formats them as a table;
//! the bench emits them into `BENCH_native_train.json` as the per-op
//! trajectory record.
//!
//! # Lane attribution (the `Op::QMatmul` convention)
//!
//! Because the counters are global atomics, a probe placed *inside* a
//! `par_rows`/pool-lane closure records each lane's own elapsed time,
//! and the bucket total is the **summed CPU time across lanes** (not
//! wall time). A probe placed *outside* a parallel region times the
//! caller's wall clock instead. Every laned op places its probe inside
//! the lane closure — and its call site carries **no** outer probe, so
//! nothing is double-counted:
//!
//! - `Op::QMatmul` — the quantized int8 GEMM lanes (the original)
//! - `Op::Matmul` — the three `par_matmul_*` orientations + packed tier
//! - `Op::Im2col` — per-image im2col fill and col2im scatter lanes
//! - `Op::DwConv` — depthwise forward row lanes / backward channel lanes
//! - `Op::BatchNorm` — the laned normalize/affine and dx row maps
//! - `Op::Quant` / `Op::QuantBwd` — branch-quant, W_eff mix and STE lanes
//! - `Op::Loss` — softmax row lanes and the laned CE backward
//! - `Op::Reduce` — per-leaf gradient tree tasks + BN stat-merge tasks
//! - `Op::Optimizer` — per-leaf W update tasks (θ's SGD stays serial)
//! - `Op::Pack` — per-lane at-panel transposes and fused im2col
//!   A-panel fills; the step-scoped weight packs record on whichever
//!   shard packs first (once per step, tiny)
//!
//! The serial remnants of those ops (BN/softmax cross-row reductions,
//! the depthwise dW fold, θ updates) keep caller-side probes in the same
//! buckets. Consequence for readers of `per_op`: a bucket's share can
//! exceed its wall-clock share once its op runs on >1 lane, and the
//! bench's `serial_fraction` treats exactly the never-laned buckets
//! (`theta`, `cost_model`, `elementwise`) as the Amdahl serial term.

/// The op buckets the breakdown reports. Coarse by design: buckets are
/// stable across refactors so trajectories stay comparable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// im2col patch fill + col2im scatter (the copy overhead the 1×1
    /// fast path removes)
    Im2col,
    /// the three blocked matmul kernels, forward and backward
    Matmul,
    /// int8 GEMM with i32 accumulators (the real quantized path);
    /// recorded per kernel lane, so the total is summed CPU time
    QMatmul,
    /// depthwise conv forward + backward
    DwConv,
    /// batch-stat normalization (train) / folded affine (eval)
    BatchNorm,
    /// fake-quant branches + Eq. 5 effective weights (forward only)
    Quant,
    /// STE backward of the fake-quant / effective-weight ops
    QuantBwd,
    /// θ machinery: masked softmax, broadcast, column sums
    Theta,
    /// softmax cross-entropy
    Loss,
    /// differentiable layer-cost term
    Cost,
    /// elementwise glue: relu, add, scale, bias, pooling
    Elementwise,
    /// fixed-order gradient tree reduction + BN stat merge
    Reduce,
    /// W/θ optimizer updates
    Optimizer,
    /// packed-panel relayouts of the f32 tier: the step-scoped weight
    /// packs, per-lane at-panel transposes and fused im2col A-panels —
    /// split out of `matmul`/`im2col` so the GEMM buckets measure
    /// arithmetic, not data movement
    Pack,
}

impl Op {
    pub const ALL: [Op; 14] = [
        Op::Im2col,
        Op::Matmul,
        Op::QMatmul,
        Op::DwConv,
        Op::BatchNorm,
        Op::Quant,
        Op::QuantBwd,
        Op::Theta,
        Op::Loss,
        Op::Cost,
        Op::Elementwise,
        Op::Reduce,
        Op::Optimizer,
        Op::Pack,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Op::Im2col => "im2col",
            Op::Matmul => "matmul",
            Op::QMatmul => "qmatmul",
            Op::DwConv => "dw_conv",
            Op::BatchNorm => "batch_norm",
            Op::Quant => "quant",
            Op::QuantBwd => "quant_bwd",
            Op::Theta => "theta",
            Op::Loss => "loss",
            Op::Cost => "cost_model",
            Op::Elementwise => "elementwise",
            Op::Reduce => "reduce",
            Op::Optimizer => "optimizer",
            Op::Pack => "pack",
        }
    }

    /// Counter index: the enum discriminant. `ALL` is declared in
    /// discriminant order, which `ops_index_their_counters` pins.
    fn idx(self) -> usize {
        self as usize
    }
}

/// One accumulated profiler row.
#[derive(Debug, Clone, Copy)]
pub struct OpStat {
    pub op: Op,
    pub total_ns: u64,
    pub calls: u64,
}

#[cfg(feature = "op-profile")]
mod imp {
    use super::{Op, OpStat};
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::time::Instant;

    static ENABLED: AtomicBool = AtomicBool::new(false);
    #[allow(clippy::declare_interior_mutable_const)]
    const ZERO: AtomicU64 = AtomicU64::new(0);
    static NANOS: [AtomicU64; Op::ALL.len()] = [ZERO; Op::ALL.len()];
    static CALLS: [AtomicU64; Op::ALL.len()] = [ZERO; Op::ALL.len()];

    /// Drop guard recording one op's elapsed time.
    pub struct OpTimer {
        op: Op,
        start: Instant,
    }

    impl Drop for OpTimer {
        fn drop(&mut self) {
            let ns = self.start.elapsed().as_nanos() as u64;
            NANOS[self.op.idx()].fetch_add(ns, Ordering::Relaxed);
            CALLS[self.op.idx()].fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn compiled_in() -> bool {
        true
    }

    pub fn enabled() -> bool {
        ENABLED.load(Ordering::Relaxed)
    }

    pub fn set_enabled(on: bool) {
        ENABLED.store(on, Ordering::Relaxed);
    }

    /// Start timing `op` (None when the profiler is off).
    #[inline]
    pub fn time(op: Op) -> Option<OpTimer> {
        if enabled() {
            Some(OpTimer {
                op,
                start: Instant::now(),
            })
        } else {
            None
        }
    }

    pub fn reset() {
        for i in 0..Op::ALL.len() {
            NANOS[i].store(0, Ordering::Relaxed);
            CALLS[i].store(0, Ordering::Relaxed);
        }
    }

    /// Accumulated rows, ops with zero calls skipped.
    pub fn snapshot() -> Vec<OpStat> {
        Op::ALL
            .iter()
            .filter_map(|&op| {
                let calls = CALLS[op.idx()].load(Ordering::Relaxed);
                (calls > 0).then(|| OpStat {
                    op,
                    total_ns: NANOS[op.idx()].load(Ordering::Relaxed),
                    calls,
                })
            })
            .collect()
    }
}

#[cfg(not(feature = "op-profile"))]
mod imp {
    use super::{Op, OpStat};

    /// Zero-sized stand-in when the profiler is compiled out.
    pub struct OpTimer;

    pub fn compiled_in() -> bool {
        false
    }

    pub fn enabled() -> bool {
        false
    }

    pub fn set_enabled(_on: bool) {}

    #[inline]
    pub fn time(_op: Op) -> Option<OpTimer> {
        None
    }

    pub fn reset() {}

    pub fn snapshot() -> Vec<OpStat> {
        Vec::new()
    }
}

pub use imp::{compiled_in, enabled, reset, set_enabled, snapshot, time, OpTimer};

/// The qmatmul kernel tier of the most recent `QuantNet::build` — a
/// label, not a timer, so it lives outside the `op-profile` gate and is
/// recorded on every build. Lets a per-op report (or a human reading
/// two bench artifacts) attribute qmatmul deltas to the tier that
/// actually ran.
static TIER_TAG: std::sync::Mutex<Option<&'static str>> = std::sync::Mutex::new(None);

pub fn set_tier_tag(tag: &'static str) {
    *TIER_TAG.lock().unwrap() = Some(tag);
}

pub fn tier_tag() -> Option<&'static str> {
    *TIER_TAG.lock().unwrap()
}

/// Human-readable breakdown table (share of the profiled total, mean
/// per call), rows sorted by total time descending.
pub fn report() -> String {
    if !compiled_in() {
        return "per-op profiler compiled out (rebuild with the default `op-profile` feature)"
            .to_string();
    }
    let mut rows = snapshot();
    if rows.is_empty() {
        return "per-op profiler: no samples recorded (pass --profile / set_enabled)".to_string();
    }
    rows.sort_by(|a, b| b.total_ns.cmp(&a.total_ns));
    let total: u64 = rows.iter().map(|r| r.total_ns).sum();
    let mut out = String::from("per-op breakdown (native engine):\n");
    out.push_str(&format!(
        "  {:<12} {:>10} {:>7} {:>10} {:>12}\n",
        "op", "total", "share", "calls", "mean/call"
    ));
    for r in rows {
        out.push_str(&format!(
            "  {:<12} {:>10} {:>6.1}% {:>10} {:>12}\n",
            r.op.name(),
            crate::util::bench::fmt_ns(r.total_ns as f64),
            100.0 * r.total_ns as f64 / total.max(1) as f64,
            r.calls,
            crate::util::bench::fmt_ns(r.total_ns as f64 / r.calls.max(1) as f64),
        ));
    }
    if let Some(t) = tier_tag() {
        out.push_str(&format!("  qmatmul tier: {t}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One combined lifecycle test: the profiler is process-global and
    /// other tests in this binary run tape ops concurrently, so all
    /// enable/disable assertions live in a single test and only inspect
    /// the Im2col bucket, which nothing else in this binary records.
    #[cfg(feature = "op-profile")]
    #[test]
    fn probe_lifecycle() {
        set_enabled(false);
        assert!(time(Op::Im2col).is_none(), "disabled probes must be free");
        set_enabled(true);
        {
            let _t = time(Op::Im2col);
            std::hint::black_box((0..100u64).sum::<u64>());
        }
        {
            let _t = time(Op::Im2col);
        }
        set_enabled(false);
        let snap = snapshot();
        let row = snap.iter().find(|r| r.op == Op::Im2col).expect("im2col row");
        assert!(row.calls >= 2, "both probes must accumulate: {row:?}");
        assert!(report().contains("im2col"));
    }

    #[cfg(not(feature = "op-profile"))]
    #[test]
    fn compiled_out_probes_are_inert() {
        set_enabled(true);
        assert!(time(Op::Im2col).is_none());
        assert!(snapshot().is_empty());
        assert!(report().contains("compiled out"));
    }

    #[test]
    fn every_op_has_a_distinct_name() {
        let mut names: Vec<&str> = Op::ALL.iter().map(|o| o.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Op::ALL.len());
    }

    #[test]
    fn ops_index_their_counters() {
        // idx() is the discriminant, so ALL must list ops in declaration
        // order — each op maps to its own counter slot
        for (i, &op) in Op::ALL.iter().enumerate() {
            assert_eq!(op.idx(), i, "{op:?} out of order in Op::ALL");
        }
    }
}
