//! The native training engine: pure-Rust tensors, reverse-mode autodiff
//! and a K-column supernet builder — the `--backend native` implementation
//! of [`crate::runtime::ModelBackend`].
//!
//! Layering (bottom-up):
//!
//! * [`tensor`] — dense f32 buffers + the three matmul kernels;
//! * [`tape`] — the autodiff core: exactly the ops the supernets need
//!   (conv2d via im2col, depthwise conv, fake-quant STE, batch-stat norm,
//!   ReLU, global-avg-pool, softmax/CE) plus the differentiable cost term
//!   pinned to `soc::analytical::cu_cycles` by piecewise-linear
//!   interpolation;
//! * [`supernet`] — ResNet/MobileNet search spaces built from the layer
//!   table and the platform registry: θ is `[cout, K]` for a K-CU SoC,
//!   per-column weight branches follow each CU's `quant`, ineligible CUs
//!   are softmax-masked;
//! * [`backend`] — [`NativeBackend`]: the train/eval/cost loop with
//!   SGD(+momentum) per-group updates and BN running statistics.
//!
//! Everything is deterministic: seeded [`crate::datasets::rng::Rng`]
//! init, fixed accumulation order, no threads — two same-seed runs
//! produce bit-identical `RunRecord`s (pinned by `tests/native.rs`).

pub mod backend;
pub mod supernet;
pub mod tape;
pub mod tensor;

pub use backend::NativeBackend;
pub use supernet::{Arch, SupernetSpec};
pub use tape::{EvalBits, QuantKind, Tape, Var};
pub use tensor::Tensor;
