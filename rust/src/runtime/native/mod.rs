//! The native training engine: pure-Rust tensors, reverse-mode autodiff
//! and a K-column supernet builder — the `--backend native` implementation
//! of [`crate::runtime::ModelBackend`], executed as a *planned engine*
//! since the arena/sharding rework.
//!
//! Layering (bottom-up):
//!
//! * [`pool`] — the persistent worker pool: long-lived threads driven
//!   by a barrier/epoch protocol, serving both batch-shard tasks and
//!   in-kernel row lanes (no per-step or per-call spawning);
//! * [`profile`] — the feature-gated per-op step profiler behind
//!   `repro … --profile` and the bench's per-op breakdown;
//! * [`tensor`] — dense f32 buffers + the three cache-blocked matmul
//!   kernels with row-sharded persistent-pool wrappers, plus the
//!   packed-panel f32 tier: panel-major operand packing with zero-padded
//!   edges, bit-identical to the unpacked kernels per build, and the
//!   step-scoped [`WeightPackSlot`]/[`PackHandle`] weight-pack cache;
//! * [`arena`] — the exact-size buffer pool every step's tape draws from
//!   and recycles into (steady-state steps allocate nothing);
//! * [`tape`] — the autodiff core: exactly the ops the supernets need
//!   (conv2d via im2col, depthwise conv, fake-quant STE, batch-stat norm,
//!   ReLU, global-avg-pool, softmax/CE) plus the differentiable cost term
//!   pinned to `soc::analytical::cu_cycles` by piecewise-linear
//!   interpolation; gradient slots are `Option`s that fail loudly when a
//!   consumed slot is touched;
//! * [`plan`] — the one-time shape-inference pass that sizes the
//!   per-shard arenas before the first step runs;
//! * [`supernet`] — ResNet/MobileNet search spaces built from the layer
//!   table and the platform registry: the ODiMO channel search plus the
//!   `_prune` / `_layerwise` baseline spaces, per-column weight branches
//!   following each CU's `quant`, ineligible CUs softmax-masked;
//! * [`qkernels`] — the real quantized inference path: θ-argmax
//!   discretization, i8/ternary weight codes with per-channel scales,
//!   int8 activations and an integer GEMM with i32 accumulators
//!   (`repro eval --quantized`), validated against the f32 fake-quant
//!   forward; weights are prepacked once per `QuantNet` build into
//!   panel-major blocks and driven by a kernel tier picked by runtime
//!   CPU-feature detection (`arch-kernels`: AVX2/VNNI/NEON — all tiers
//!   bit-identical to the i64 reference);
//! * [`backend`] — [`NativeBackend`]: the train/eval/cost loop with
//!   intra-step batch sharding, fixed-order gradient tree reduction, and
//!   SGD+momentum or Adam per-group updates.
//!
//! Everything is deterministic *independent of the thread count*: seeded
//! [`crate::datasets::rng::Rng`] init, a batch-size-only shard structure,
//! fixed accumulation order inside every shard and kernel row chunk, and
//! shard-index-ordered reductions — two same-seed runs produce
//! bit-identical `RunRecord`s at 1 or N threads (pinned by
//! `tests/native.rs` and `tests/native_exec.rs`).

pub mod arena;
pub mod backend;
pub mod plan;
pub mod pool;
pub mod profile;
pub mod qkernels;
pub mod supernet;
pub mod tape;
pub mod tensor;

pub use arena::Arena;
pub use backend::{NativeBackend, NativeOptions, WOptimizer, NSHARDS};
pub use plan::ExecPlan;
pub use pool::{max_threads, KernelScope, WorkerPool};
pub use qkernels::{QTier, QuantNet};
pub use supernet::{Arch, SearchMode, SupernetSpec};
pub use tape::{EvalBits, Gradients, QuantKind, Tape, Var};
pub use tensor::{packing_enabled, set_packing_enabled, PackHandle, Tensor, WeightPackSlot};
