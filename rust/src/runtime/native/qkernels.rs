//! Real quantized inference: i8 / 2-bit-ternary weight storage with
//! per-channel scales, symmetric int8 activations, and a blocked integer
//! GEMM with i32 accumulators — the arithmetic the [`QuantKind`]
//! fake-quant ops only *emulate* in f32 during training.
//!
//! [`QuantNet`] is a frozen, discretized snapshot of a trained state:
//! each searchable conv's θ row is argmax-discretized to one CU column
//! and the row's weights are stored as that CU's representation —
//! `i8` codes (int8: −127..127, ternary: −1/0/+1) plus one f32 scale
//! per output channel, chosen so `code · scale` reproduces the training
//! forward's [`QuantKind::quant_row`] output *bit-exactly*. Identity
//! (full-precision) rows stay f32; Zero (pruned) rows produce zeros.
//! Batch-norm running stats are folded into a per-channel affine with
//! the same [`BN_EPS`] as the tape's eval forward; the FC head is never
//! quantized, matching the training graph.
//!
//! # Kernel tiers
//!
//! Integer addition is associative, so — unlike the f32 kernels, where
//! the scalar path *defines* the bits and every other tier must replay
//! its exact reduction — any blocking, vectorization or threading of
//! the integer GEMM is bit-identical by construction. That freedom buys
//! three tiers that all produce the same `i32`s:
//!
//! * [`qmatmul_bt_into_naive`] — the original triple loop, kept as the
//!   reference (its serial `acc +=` chain blocks vectorization);
//! * [`qmatmul_bt_into_blocked`] — 4-column register panels sharing one
//!   streamed activation row, each dot split over 8 independent i32
//!   accumulator lanes the autovectorizer maps to vector registers;
//! * `simd-kernels` builds add a widening-lane variant on
//!   [`I16x8`]/[`I32x8`]: codes widen i8→i16 on load and multiply as
//!   i32 (127² fits comfortably), 8 products per step;
//! * `arch-kernels` builds add architecture-intrinsic panel kernels
//!   (AVX2 `maddubs` / AVX-512-VNNI `vpdpbusd`, NEON `vmull` / `sdot` —
//!   see `tensor::arch`) behind **runtime** CPU-feature detection.
//!
//! The arch tier runs on **prepacked** weights: [`pack_b_into`] repacks
//! each layer's `[n, k]` code matrix once, at [`QuantNet::build`] time,
//! into panel-major `QNR×QLANES` blocks (k zero-padded to a lane
//! multiple, n to a panel multiple — exact, the pads contribute 0), so
//! the inner loop streams one contiguous 32-byte block per step instead
//! of re-slicing `b[(j+t)*k..]` per panel. The packed drive
//! ([`qmatmul_bt_packed_into`]) speeds up the portable tiers too. The
//! tier is decided **once per `QuantNet` build** ([`QTier::detect`] +
//! the per-layer −128 gate in [`QTier::for_packed`]), never per call.
//!
//! [`qmatmul_bt_into`] dispatches to the best compiled-in tier;
//! `tests/kernels.rs` pins all tiers — unpacked, packed and arch —
//! exactly equal on panel-edge shapes and saturation-edge inputs.
//!
//! # Execution
//!
//! The forward is sharded over the same fixed [`NSHARDS`] batch split as
//! the f32 engine (shard structure depends only on the batch size, never
//! the thread count) and runs the shards as tasks of the backend's
//! persistent [`WorkerPool`] when one is attached ([`QuantNet::set_pool`],
//! done by `NativeBackend::quantize`); surplus pool slots become row
//! lanes inside each conv via [`par_rows`]. Activations quantize per
//! shard (`scale = max|x|/127`, no zero point — the integer analogue of
//! the engine's ghost batch norm), so outputs are bit-identical for any
//! thread count *and* any kernel tier. Each shard owns a small recycled
//! scratch (free-listed f32 buffers + code/dequant rows) sized up front
//! by [`quant_shard_plan`], so steady-state quantized evals allocate
//! nothing.
//!
//! Validation contract: [`QuantNet::forward_f32_reference`] runs the
//! same discretized network in f32 with the dequantized weights and *no*
//! activation quantization — exactly the fake-quant emulation — and
//! `tests/quantized.rs` pins the quantized logits against it to a
//! documented tolerance on every builtin SoC's supernet.
//!
//! A `QuantNet` is built **once per trained state** and reused across
//! batches (weights are constant during eval; `repro eval --quantized`
//! and the bench hold one instance for the whole run — requantizing per
//! batch was pure waste).

use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::soc::LayerType;

use super::backend::NSHARDS;
use super::plan::{quant_pack_plan, quant_shard_plan, QuantPlan};
use super::pool::{KernelScope, WorkerPool};
use super::profile::{self, Op};
use super::supernet::{PlanStep, SearchMode, SupernetSpec, BN_EPS};
use super::tape::{im2col_slice_into, same_geometry, QuantKind};
#[cfg(feature = "simd-kernels")]
use super::tensor::simd::{I16x8, I32x8};
#[cfg(feature = "simd-kernels")]
use super::tensor::simd_enabled;
use super::tensor::{matmul_bt_into, matmul_into, par_rows};

/// One conv geometry's frozen quantized parameters.
pub struct QLayer {
    /// per-output-channel quantizer actually applied after θ argmax
    pub kinds: Vec<QuantKind>,
    /// row-major `[cout, f]` integer codes (int8 or ternary rows;
    /// Identity/Zero rows are all-zero placeholders)
    pub codes: Vec<i8>,
    /// per-row dequantization scale (`code · scale` = fake-quant value)
    pub scales: Vec<f32>,
    /// the fake-quant f32 weights (`quant_row` output): the f32
    /// reference forward reads all rows, the quantized forward reads
    /// only Identity rows
    pub w_deq: Vec<f32>,
    /// indices of Identity (full-precision) output channels — the rows
    /// the quantized GEMM leaves to the f32 fix-up pass
    pub ident_cols: Vec<usize>,
    /// folded BN affine `y = a·x + b` from the running stats
    pub bn_a: Vec<f32>,
    pub bn_b: Vec<f32>,
}

/// Raw state slices of one conv geometry (assembled by
/// `NativeBackend::quantize` from its leaf table).
pub struct GeomParams<'a> {
    pub w: &'a [f32],
    pub scale: &'a [f32],
    pub bias: &'a [f32],
    pub mean: &'a [f32],
    pub var: &'a [f32],
    pub theta: Option<&'a [f32]>,
}

/// A discretized, genuinely-quantized inference network.
pub struct QuantNet<'a> {
    spec: &'a SupernetSpec,
    layers: Vec<QLayer>,
    fc_w: Vec<f32>,
    fc_b: Vec<f32>,
    /// worker pool the sharded forward runs on (serial when absent)
    pool: Option<&'a WorkerPool>,
    /// one recycled buffer set per batch shard
    scratch: Vec<Mutex<QScratch>>,
    /// qmatmul tier this build + host detected at build time
    tier: QTier,
    /// one contiguous slab of panel-major packed codes for every dense
    /// conv, written exactly once at build (sized by `quant_pack_plan`)
    pack: Vec<i8>,
    /// per-geometry view into `pack` (None for depthwise layers, which
    /// run per-channel taps, not a GEMM)
    pack_meta: Vec<Option<PackInfo>>,
}

/// One dense conv's slice of the prepacked weight slab, plus the tier
/// its GEMM drives (detection refined by the per-matrix −128 gate —
/// both resolved once at build).
struct PackInfo {
    off: usize,
    len: usize,
    k_pad: usize,
    tier: QTier,
}

/// Masked argmax over one θ row; ties keep the lowest eligible column.
fn masked_argmax(row: &[f32], mask: &[bool]) -> usize {
    let mut best: Option<usize> = None;
    for (j, &v) in row.iter().enumerate() {
        if !mask[j] {
            continue;
        }
        match best {
            Some(b) if row[b] >= v => {}
            _ => best = Some(j),
        }
    }
    best.unwrap_or(0)
}

/// Per-output-channel quantizer of geometry `gi` after θ discretization.
pub fn row_kinds(spec: &SupernetSpec, gi: usize, theta: Option<&[f32]>) -> Vec<QuantKind> {
    let l = &spec.layers[gi];
    let cout = l.cout;
    let th = match theta {
        Some(t) if l.searchable => t,
        // fixed-precision layers run on the primary CU's representation,
        // matching the training forward's `fake_quant_ste(w, quants[0])`
        _ => return vec![spec.quants[0]; cout],
    };
    match spec.search {
        SearchMode::Channel | SearchMode::Fixed => {
            let k = spec.platform.n_cus();
            debug_assert_eq!(th.len(), cout * k);
            (0..cout)
                .map(|r| spec.quants[masked_argmax(&th[r * k..(r + 1) * k], &spec.masks[gi])])
                .collect()
        }
        SearchMode::Prune => {
            debug_assert_eq!(th.len(), cout * 2);
            (0..cout)
                .map(|r| {
                    if th[r * 2] >= th[r * 2 + 1] {
                        spec.quants[0]
                    } else {
                        QuantKind::Zero
                    }
                })
                .collect()
        }
        SearchMode::Layerwise => {
            let kind = spec.quants[masked_argmax(th, &spec.masks[gi])];
            vec![kind; cout]
        }
    }
}

impl QLayer {
    /// Quantize one geometry's weights row-by-row and fold its BN stats.
    fn build(spec: &SupernetSpec, gi: usize, p: &GeomParams) -> QLayer {
        let cout = spec.layers[gi].cout;
        let f = spec.fan_in(gi);
        debug_assert_eq!(p.w.len(), cout * f);
        let kinds = row_kinds(spec, gi, p.theta);
        let mut codes = vec![0i8; cout * f];
        let mut scales = vec![0.0f32; cout];
        let mut w_deq = vec![0.0f32; cout * f];
        for r in 0..cout {
            let row = &p.w[r * f..(r + 1) * f];
            kinds[r].quant_row(row, &mut w_deq[r * f..(r + 1) * f]);
            let crow = &mut codes[r * f..(r + 1) * f];
            match kinds[r] {
                QuantKind::Identity | QuantKind::Zero => {}
                QuantKind::Int8 => {
                    let amax = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                    let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
                    scales[r] = scale;
                    for (c, &v) in crow.iter_mut().zip(row) {
                        *c = (v / scale).round().clamp(-127.0, 127.0) as i8;
                    }
                }
                QuantKind::Ternary => {
                    // same thr/scale recipe as `quant_row`, so
                    // code·scale == the fake-quant value bit-exactly
                    let amax = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                    let thr = 0.05 * amax;
                    let mut kept = 0.0f32;
                    let mut sum = 0.0f32;
                    for &v in row {
                        if v.abs() > thr {
                            kept += 1.0;
                            sum += v.abs();
                        }
                    }
                    scales[r] = sum / kept.max(1.0);
                    for (c, &v) in crow.iter_mut().zip(row) {
                        *c = if v.abs() > thr {
                            if v > 0.0 {
                                1
                            } else {
                                -1
                            }
                        } else {
                            0
                        };
                    }
                }
            }
        }
        let ident_cols = kinds
            .iter()
            .enumerate()
            .filter(|&(_, &k)| k == QuantKind::Identity)
            .map(|(j, _)| j)
            .collect();
        let bn_a: Vec<f32> = p
            .scale
            .iter()
            .zip(p.var)
            .map(|(&s, &v)| s / (v + BN_EPS).sqrt())
            .collect();
        let bn_b: Vec<f32> = p
            .bias
            .iter()
            .zip(p.mean.iter().zip(&bn_a))
            .map(|(&b, (&m, &a))| b - m * a)
            .collect();
        QLayer {
            kinds,
            codes,
            scales,
            w_deq,
            ident_cols,
            bn_a,
            bn_b,
        }
    }

    /// True if any row runs on integer codes (int8 or ternary).
    fn any_integer(&self) -> bool {
        self.kinds
            .iter()
            .any(|&k| k == QuantKind::Int8 || k == QuantKind::Ternary)
    }
}

// ---------------------------------------------------------------------------
// integer kernels
// ---------------------------------------------------------------------------

/// Output-column panel width: four weight rows share one streamed
/// activation row (mirrors the f32 `NR_S` panels).
const QNR: usize = 4;
/// Accumulator lanes per dot: splitting `acc +=` over 8 independent
/// i32 lanes breaks the serial dependency chain so the autovectorizer
/// can keep the multiply-accumulate in vector registers. Exact for any
/// split — integer adds are associative.
const QLANES: usize = 8;

// The arch panel kernels hard-code the 4×8 granule: one packed block is
// 4 rows × 8 codes = 32 bytes = one AVX2 register / two NEON d-regs.
const _: () = assert!(QNR == 4 && QLANES == 8);

/// Reduction length padded up to a whole number of [`QLANES`] chunks —
/// the per-row stride of the packed layout.
pub fn quant_k_pad(k: usize) -> usize {
    k.div_ceil(QLANES) * QLANES
}

/// Total packed bytes of an `[n, k]` code matrix: `n` padded to a whole
/// number of [`QNR`]-row panels, each row zero-padded to
/// [`quant_k_pad`]. The zero pads contribute 0 to every dot — packing
/// is exactness-preserving by construction.
pub fn quant_packed_len(k: usize, n: usize) -> usize {
    n.div_ceil(QNR) * QNR * quant_k_pad(k)
}

/// A panel-major prepacked weight matrix: per [`QNR`]-row panel, blocks
/// of `QNR×QLANES` codes laid out `[row0 8B][row1 8B][row2 8B][row3 8B]`
/// so a panel step reads one contiguous 32-byte block.
pub struct PackedB {
    pub data: Vec<i8>,
    pub k: usize,
    pub n: usize,
    pub k_pad: usize,
    /// any −128 code present — the x86 sign-transfer kernels must fall
    /// back to the portable tier (`sign_epi8` wraps −(−128)); production
    /// codes are clamped to ±127 so this only fires on adversarial input
    pub has_m128: bool,
}

/// Pack `[n, k]` row-major codes into `out` (sized [`quant_packed_len`]).
/// Returns whether any −128 code was seen (see [`PackedB::has_m128`]).
pub fn pack_b_into(b: &[i8], k: usize, n: usize, out: &mut [i8]) -> bool {
    debug_assert!(k > 0);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), quant_packed_len(k, n));
    let k_pad = quant_k_pad(k);
    out.fill(0);
    let mut has_m128 = false;
    for (j, row) in b.chunks_exact(k).enumerate() {
        let base = (j / QNR) * QNR * k_pad + (j % QNR) * QLANES;
        for (bi, chunk) in row.chunks(QLANES).enumerate() {
            let dst = base + bi * QNR * QLANES;
            out[dst..dst + chunk.len()].copy_from_slice(chunk);
        }
        has_m128 |= row.contains(&i8::MIN);
    }
    has_m128
}

/// Allocating convenience form of [`pack_b_into`].
pub fn pack_b(b: &[i8], k: usize, n: usize) -> PackedB {
    let mut data = vec![0i8; quant_packed_len(k, n)];
    let has_m128 = pack_b_into(b, k, n, &mut data);
    PackedB {
        data,
        k,
        n,
        k_pad: quant_k_pad(k),
        has_m128,
    }
}

/// The qmatmul kernel tier a `QuantNet` dispatches to. Decided once per
/// build ([`QTier::detect`]), refined per layer by the −128 gate
/// ([`QTier::for_packed`]), never re-decided per call. Every tier
/// produces identical i32s (integer associativity + the saturation
/// arguments in `tensor::arch`), so the choice is pure throughput.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QTier {
    Naive,
    Blocked,
    Simd,
    Avx2,
    Avx512Vnni,
    Neon,
    NeonDot,
}

impl QTier {
    /// Best tier this build + this host supports. Arch tiers need the
    /// `arch-kernels` feature *and* runtime CPU-feature detection.
    pub fn detect() -> QTier {
        #[cfg(feature = "arch-kernels")]
        {
            use super::tensor::arch::Isa;
            match super::tensor::arch::isa() {
                Isa::Avx512Vnni => return QTier::Avx512Vnni,
                Isa::Avx2 => return QTier::Avx2,
                Isa::NeonDot => return QTier::NeonDot,
                Isa::Neon => return QTier::Neon,
                Isa::None => {}
            }
        }
        Self::portable()
    }

    /// Best portable (non-arch) tier of this build.
    fn portable() -> QTier {
        #[cfg(feature = "simd-kernels")]
        if simd_enabled() {
            return QTier::Simd;
        }
        QTier::Blocked
    }

    /// The tier actually driven for one packed matrix: the x86
    /// sign-transfer kernels cannot process −128 codes, so those
    /// matrices fall back to the portable tier (NEON is signed×signed
    /// and unaffected).
    pub fn for_packed(self, has_m128: bool) -> QTier {
        match self {
            QTier::Avx2 | QTier::Avx512Vnni if has_m128 => Self::portable(),
            t => t,
        }
    }

    /// Whether this tier runs architecture-intrinsic kernels.
    pub fn is_arch(self) -> bool {
        matches!(
            self,
            QTier::Avx2 | QTier::Avx512Vnni | QTier::Neon | QTier::NeonDot
        )
    }

    pub fn name(self) -> &'static str {
        match self {
            QTier::Naive => "naive",
            QTier::Blocked => "blocked",
            QTier::Simd => "simd",
            QTier::Avx2 => "avx2",
            QTier::Avx512Vnni => "avx512vnni",
            QTier::Neon => "neon",
            QTier::NeonDot => "neon_dot",
        }
    }
}

/// 8-lane max-abs scan. f32 `max` is exact and order-free (no rounding),
/// so the lane split returns the same amax bits as a serial fold.
fn max_abs(x: &[f32]) -> f32 {
    let xc = x.chunks_exact(QLANES);
    let rem = xc.remainder();
    let mut lanes = [0.0f32; QLANES];
    for cx in xc {
        for (m, &v) in lanes.iter_mut().zip(cx) {
            *m = m.max(v.abs());
        }
    }
    let mut m = lanes.iter().fold(0.0f32, |a, &b| a.max(b));
    for &v in rem {
        m = m.max(v.abs());
    }
    m
}

/// Symmetric per-tensor int8 activation quantization into a reused code
/// buffer: `scale = max|x| / 127`, codes rounded and clamped to ±127,
/// no zero point. The rounding recipe (`(v / scale).round()`, true
/// division) is shared by every build, so activation codes — and with
/// them the whole quantized forward — are identical across kernel tiers.
pub fn quantize_act_into(x: &[f32], codes: &mut Vec<i8>) -> f32 {
    let amax = max_abs(x);
    let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
    codes.clear();
    codes.extend(
        x.iter()
            .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8),
    );
    scale
}

/// Allocating convenience form of [`quantize_act_into`].
pub fn quantize_act(x: &[f32]) -> (Vec<i8>, f32) {
    let mut codes = Vec::new();
    let scale = quantize_act_into(x, &mut codes);
    (codes, scale)
}

/// Naive reference tier of the integer GEMM
/// `C[m,n] = A[m,k] · B[n,k]ᵀ`: one serial i32 accumulator per output.
/// Kept for the bench (speedup denominator) and the tier-equality tests.
pub fn qmatmul_bt_into_naive(a: &[i8], b: &[i8], c: &mut [i32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0i32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av as i32 * bv as i32;
            }
            *cv = acc;
        }
    }
}

/// Lane-split integer dot (tail columns of a panel sweep).
#[inline(always)]
fn qdot_scalar(x: &[i8], y: &[i8]) -> i32 {
    let xc = x.chunks_exact(QLANES);
    let yc = y.chunks_exact(QLANES);
    let (xr, yr) = (xc.remainder(), yc.remainder());
    let mut acc = [0i32; QLANES];
    for (cx, cy) in xc.zip(yc) {
        for l in 0..QLANES {
            acc[l] += cx[l] as i32 * cy[l] as i32;
        }
    }
    let mut s: i32 = acc.iter().sum();
    for (&a, &b) in xr.iter().zip(yr) {
        s += a as i32 * b as i32;
    }
    s
}

/// One register panel: the four dots of activation row `arow` against
/// weight rows `j..j+QNR`, each split over [`QLANES`] i32 accumulators.
#[inline(always)]
fn qpanel_scalar(arow: &[i8], b: &[i8], k: usize, j: usize) -> [i32; QNR] {
    let k_main = k - k % QLANES;
    let mut acc = [[0i32; QLANES]; QNR];
    let mut p = 0;
    while p < k_main {
        let ar = &arow[p..p + QLANES];
        for (t, at) in acc.iter_mut().enumerate() {
            let br = &b[(j + t) * k + p..(j + t) * k + p + QLANES];
            for l in 0..QLANES {
                at[l] += ar[l] as i32 * br[l] as i32;
            }
        }
        p += QLANES;
    }
    let mut out = [0i32; QNR];
    for (t, at) in acc.iter().enumerate() {
        let mut s: i32 = at.iter().sum();
        for q in k_main..k {
            s += arow[q] as i32 * b[(j + t) * k + q] as i32;
        }
        out[t] = s;
    }
    out
}

/// Widening-lane tier: i8 codes widen to [`I16x8`] on load and multiply-
/// accumulate into [`I32x8`] (products of int8 codes never exceed 127²,
/// so every step is exact).
#[cfg(feature = "simd-kernels")]
mod qsimd {
    use super::{I16x8, I32x8, QLANES, QNR};

    #[inline(always)]
    pub fn qdot(x: &[i8], y: &[i8]) -> i32 {
        let k = x.len();
        let k_main = k - k % QLANES;
        let mut acc = I32x8::zero();
        let mut p = 0;
        while p < k_main {
            acc = acc.mul_add_widen(I16x8::widen(&x[p..]), I16x8::widen(&y[p..]));
            p += QLANES;
        }
        let mut s = acc.hsum();
        for q in k_main..k {
            s += x[q] as i32 * y[q] as i32;
        }
        s
    }

    #[inline(always)]
    pub fn qpanel(arow: &[i8], b: &[i8], k: usize, j: usize) -> [i32; QNR] {
        let k_main = k - k % QLANES;
        let mut acc = [I32x8::zero(); QNR];
        let mut p = 0;
        while p < k_main {
            let av = I16x8::widen(&arow[p..]);
            for (t, at) in acc.iter_mut().enumerate() {
                *at = at.mul_add_widen(av, I16x8::widen(&b[(j + t) * k + p..]));
            }
            p += QLANES;
        }
        let mut out = [0i32; QNR];
        for (t, at) in acc.iter().enumerate() {
            let mut s = at.hsum();
            for q in k_main..k {
                s += arow[q] as i32 * b[(j + t) * k + q] as i32;
            }
            out[t] = s;
        }
        out
    }

    /// Packed-panel variant: the weight blocks arrive contiguous
    /// (`[row0 8][row1 8][row2 8][row3 8]` per step), the final partial
    /// activation chunk from the caller's zero-padded tail buffer.
    #[inline(always)]
    pub fn qpanel_packed(arow: &[i8], atail: &[i8; QLANES], panel: &[i8]) -> [i32; QNR] {
        let full = arow.len() / QLANES;
        let mut acc = [I32x8::zero(); QNR];
        for (bi, blk) in panel.chunks_exact(QNR * QLANES).enumerate() {
            let ac: &[i8] = if bi < full {
                &arow[bi * QLANES..(bi + 1) * QLANES]
            } else {
                atail
            };
            let av = I16x8::widen(ac);
            for (t, at) in acc.iter_mut().enumerate() {
                *at = at.mul_add_widen(av, I16x8::widen(&blk[t * QLANES..]));
            }
        }
        let mut out = [0i32; QNR];
        for (t, at) in acc.iter().enumerate() {
            out[t] = at.hsum();
        }
        out
    }
}

/// Shared panel-sweep skeleton of the blocked tiers: stream each
/// activation row once across QNR-column register panels, `store`ing
/// each finished i32 (plain or dequantized). Monomorphizes per tier, so
/// the panel/dot calls inline.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn bt_drive<P, D, S>(
    a: &[i8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    panel: P,
    dot1: D,
    mut store: S,
) where
    P: Fn(&[i8], &[i8], usize, usize) -> [i32; QNR],
    D: Fn(&[i8], &[i8]) -> i32,
    S: FnMut(usize, usize, i32),
{
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let mut j = 0;
        while j + QNR <= n {
            let acc = panel(arow, b, k, j);
            for (t, &s) in acc.iter().enumerate() {
                store(i, j + t, s);
            }
            j += QNR;
        }
        for jj in j..n {
            store(i, jj, dot1(arow, &b[jj * k..(jj + 1) * k]));
        }
    }
}

/// Blocked scalar tier of the integer GEMM (register panels + lane-split
/// accumulators). Bit-identical to the naive tier — integer adds.
pub fn qmatmul_bt_into_blocked(a: &[i8], b: &[i8], c: &mut [i32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(c.len(), m * n);
    bt_drive(a, b, m, k, n, qpanel_scalar, qdot_scalar, |i, j, s| {
        c[i * n + j] = s
    });
}

/// Widening SIMD tier of the integer GEMM.
#[cfg(feature = "simd-kernels")]
pub fn qmatmul_bt_into_simd(a: &[i8], b: &[i8], c: &mut [i32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(c.len(), m * n);
    bt_drive(a, b, m, k, n, qsimd::qpanel, qsimd::qdot, |i, j, s| {
        c[i * n + j] = s
    });
}

/// Integer GEMM `C[m,n] = A[m,k] · B[n,k]ᵀ` on i8 codes with i32
/// accumulators — the dot-product (`A·Bᵀ`) layout the conv lowering
/// uses, weights as rows of codes. Dispatches to the best compiled-in
/// tier; all tiers produce the same bits (integer associativity), so
/// unlike the f32 kernels the dispatch is *not* part of any numerics
/// contract.
pub fn qmatmul_bt_into(a: &[i8], b: &[i8], c: &mut [i32], m: usize, k: usize, n: usize) {
    #[cfg(feature = "simd-kernels")]
    if simd_enabled() {
        qmatmul_bt_into_simd(a, b, c, m, k, n);
        return;
    }
    qmatmul_bt_into_blocked(a, b, c, m, k, n);
}

/// Fused integer-GEMM + dequantize: `C[i,j] = (Σ a·b) · dq[j]` straight
/// into the f32 conv output, accumulators staying in registers (the
/// conv never materializes an i32 matrix). `dq[j]` is
/// `scale_act · scale_w[j]`; pruned rows carry `dq = 0`.
pub fn qmatmul_bt_dequant_into(
    a: &[i8],
    b: &[i8],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    dq: &[f32],
) {
    debug_assert_eq!(c.len(), m * n);
    debug_assert_eq!(dq.len(), n);
    #[cfg(feature = "simd-kernels")]
    if simd_enabled() {
        bt_drive(a, b, m, k, n, qsimd::qpanel, qsimd::qdot, |i, j, s| {
            c[i * n + j] = s as f32 * dq[j]
        });
        return;
    }
    bt_drive(a, b, m, k, n, qpanel_scalar, qdot_scalar, |i, j, s| {
        c[i * n + j] = s as f32 * dq[j]
    });
}

/// Packed-panel scalar kernel: same lane-split accumulators as
/// [`qpanel_scalar`], but streaming contiguous packed blocks.
#[inline(always)]
fn qpanel_packed_scalar(arow: &[i8], atail: &[i8; QLANES], panel: &[i8]) -> [i32; QNR] {
    let full = arow.len() / QLANES;
    let mut acc = [[0i32; QNR]; QLANES];
    for (bi, blk) in panel.chunks_exact(QNR * QLANES).enumerate() {
        let ac: &[i8] = if bi < full {
            &arow[bi * QLANES..(bi + 1) * QLANES]
        } else {
            atail
        };
        for (l, al) in acc.iter_mut().enumerate() {
            let av = ac[l] as i32;
            for (t, at) in al.iter_mut().enumerate() {
                *at += av * blk[t * QLANES + l] as i32;
            }
        }
    }
    let mut out = [0i32; QNR];
    for al in &acc {
        for (t, &v) in al.iter().enumerate() {
            out[t] += v;
        }
    }
    out
}

/// Shared drive of the packed tiers: per activation row, zero-pad the
/// final partial chunk into a stack tail buffer once, then sweep the
/// packed panels with unit stride. Monomorphizes per panel kernel.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn bt_drive_packed<P, S>(
    a: &[i8],
    pb: &[i8],
    m: usize,
    k: usize,
    k_pad: usize,
    n: usize,
    panel: P,
    mut store: S,
) where
    P: Fn(&[i8], &[i8; QLANES], &[i8]) -> [i32; QNR],
    S: FnMut(usize, usize, i32),
{
    debug_assert!(k > 0);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(pb.len(), quant_packed_len(k, n));
    debug_assert_eq!(k_pad, quant_k_pad(k));
    let rem = k % QLANES;
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let mut atail = [0i8; QLANES];
        if rem != 0 {
            atail[..rem].copy_from_slice(&arow[k - rem..]);
        }
        for (pi, pdata) in pb.chunks_exact(QNR * k_pad).enumerate() {
            let j0 = pi * QNR;
            let acc = panel(arow, &atail, pdata);
            for (t, &s) in acc.iter().take(n - j0).enumerate() {
                store(i, j0 + t, s);
            }
        }
    }
}

/// Drive one packed GEMM on an already-resolved tier. The `tier` comes
/// from [`QTier::detect`]`/`[`QTier::for_packed`] — by the time we are
/// here, runtime feature detection and the −128 gate have both passed
/// for any arch arm, which is what makes the `unsafe` calls sound.
#[allow(clippy::too_many_arguments)]
fn drive_packed_tier<S: FnMut(usize, usize, i32)>(
    tier: QTier,
    a: &[i8],
    pb: &[i8],
    m: usize,
    k: usize,
    k_pad: usize,
    n: usize,
    store: S,
) {
    match tier {
        QTier::Naive | QTier::Blocked => {
            bt_drive_packed(a, pb, m, k, k_pad, n, qpanel_packed_scalar, store)
        }
        #[cfg(feature = "simd-kernels")]
        QTier::Simd => bt_drive_packed(a, pb, m, k, k_pad, n, qsimd::qpanel_packed, store),
        #[cfg(all(feature = "arch-kernels", target_arch = "x86_64"))]
        QTier::Avx2 => bt_drive_packed(
            a,
            pb,
            m,
            k,
            k_pad,
            n,
            // SAFETY: tier == Avx2 only after runtime AVX2 detection and
            // a −128-free pack (caller contract of qpanel_avx2)
            |ar, at, p| unsafe { super::tensor::arch::x86::qpanel_avx2(ar, at, p) },
            store,
        ),
        #[cfg(all(feature = "arch-kernels", target_arch = "x86_64"))]
        QTier::Avx512Vnni => bt_drive_packed(
            a,
            pb,
            m,
            k,
            k_pad,
            n,
            // SAFETY: tier == Avx512Vnni only after runtime
            // avx512vnni+avx512vl detection and a −128-free pack
            |ar, at, p| unsafe { super::tensor::arch::x86::qpanel_vnni(ar, at, p) },
            store,
        ),
        #[cfg(all(feature = "arch-kernels", target_arch = "aarch64"))]
        QTier::Neon => bt_drive_packed(
            a,
            pb,
            m,
            k,
            k_pad,
            n,
            // SAFETY: tier == Neon only after runtime NEON detection
            |ar, at, p| unsafe { super::tensor::arch::aarch::qpanel_neon(ar, at, p) },
            store,
        ),
        #[cfg(all(feature = "arch-kernels", target_arch = "aarch64"))]
        QTier::NeonDot => bt_drive_packed(
            a,
            pb,
            m,
            k,
            k_pad,
            n,
            // SAFETY: tier == NeonDot only after runtime dotprod detection
            |ar, at, p| unsafe { super::tensor::arch::aarch::qpanel_neon_dot(ar, at, p) },
            store,
        ),
        // tiers whose kernels are not compiled into this build (e.g. a
        // QTier::Simd value in a non-simd build) degrade to the scalar
        // packed kernel — still bit-identical, just slower
        _ => bt_drive_packed(a, pb, m, k, k_pad, n, qpanel_packed_scalar, store),
    }
}

/// Packed-B integer GEMM, blocked scalar tier.
pub fn qmatmul_bt_packed_into_blocked(a: &[i8], pb: &PackedB, c: &mut [i32], m: usize) {
    debug_assert_eq!(c.len(), m * pb.n);
    let n = pb.n;
    drive_packed_tier(
        QTier::Blocked,
        a,
        &pb.data,
        m,
        pb.k,
        pb.k_pad,
        n,
        |i, j, s| c[i * n + j] = s,
    );
}

/// Packed-B integer GEMM, widening SIMD tier.
#[cfg(feature = "simd-kernels")]
pub fn qmatmul_bt_packed_into_simd(a: &[i8], pb: &PackedB, c: &mut [i32], m: usize) {
    debug_assert_eq!(c.len(), m * pb.n);
    let n = pb.n;
    drive_packed_tier(QTier::Simd, a, &pb.data, m, pb.k, pb.k_pad, n, |i, j, s| {
        c[i * n + j] = s
    });
}

/// Packed-B integer GEMM on the detected arch tier. Returns `true` when
/// an arch kernel actually ran — `false` proves the dispatch fell back
/// (feature undetected, or the pack contains −128 codes on x86), which
/// the bench uses to decide whether the arch speedup gate applies.
#[cfg(feature = "arch-kernels")]
pub fn qmatmul_bt_packed_into_arch(a: &[i8], pb: &PackedB, c: &mut [i32], m: usize) -> bool {
    debug_assert_eq!(c.len(), m * pb.n);
    let tier = QTier::detect().for_packed(pb.has_m128);
    let n = pb.n;
    drive_packed_tier(tier, a, &pb.data, m, pb.k, pb.k_pad, n, |i, j, s| {
        c[i * n + j] = s
    });
    tier.is_arch()
}

/// Packed-B integer GEMM, full dispatch (detection + −128 gate).
pub fn qmatmul_bt_packed_into(a: &[i8], pb: &PackedB, c: &mut [i32], m: usize) {
    let tier = QTier::detect().for_packed(pb.has_m128);
    let n = pb.n;
    drive_packed_tier(tier, a, &pb.data, m, pb.k, pb.k_pad, n, |i, j, s| {
        c[i * n + j] = s
    });
}

/// Fused packed integer GEMM + dequantize (packed analogue of
/// [`qmatmul_bt_dequant_into`]).
pub fn qmatmul_bt_packed_dequant_into(a: &[i8], pb: &PackedB, c: &mut [f32], m: usize, dq: &[f32]) {
    debug_assert_eq!(c.len(), m * pb.n);
    debug_assert_eq!(dq.len(), pb.n);
    let tier = QTier::detect().for_packed(pb.has_m128);
    let n = pb.n;
    drive_packed_tier(tier, a, &pb.data, m, pb.k, pb.k_pad, n, |i, j, s| {
        c[i * n + j] = s as f32 * dq[j]
    });
}

/// f32 dot (Identity-row fix-up of a mixed-precision conv).
fn fdot(x: &[f32], y: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (&a, &b) in x.iter().zip(y) {
        s += a * b;
    }
    s
}

// ---------------------------------------------------------------------------
// forward
// ---------------------------------------------------------------------------

/// One activation tensor flowing through a shard of the plan (its
/// buffer comes from — and returns to — the shard's [`QScratch`]).
struct Act {
    buf: Vec<f32>,
    n: usize,
    h: usize,
    w: usize,
    c: usize,
}

/// Recycled per-shard buffers of the quantized forward: a free list of
/// f32 buffers (activation ping-pong, residual, patch matrix, pooled
/// head) plus the activation-code / dequant-scale / logits rows.
/// Capacity-primed from [`quant_shard_plan`], so steady-state evals
/// allocate nothing.
struct QScratch {
    bufs: Vec<Vec<f32>>,
    a8: Vec<i8>,
    dq: Vec<f32>,
    logits: Vec<f32>,
}

impl QScratch {
    fn primed(plan: &QuantPlan) -> QScratch {
        QScratch {
            bufs: (0..plan.buf_count)
                .map(|_| Vec::with_capacity(plan.buf_elems))
                .collect(),
            a8: Vec::with_capacity(plan.code_elems),
            dq: Vec::with_capacity(plan.chan_max),
            logits: Vec::with_capacity(plan.logit_elems),
        }
    }

    /// Pop a zeroed `len`-element buffer off the free list.
    fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.bufs.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0.0);
        v
    }

    fn put(&mut self, v: Vec<f32>) {
        self.bufs.push(v);
    }
}

/// Fixed shard row ranges of an `n`-row batch — the same thread-count-
/// independent split as `NativeBackend::shard_bounds` (the shard-local
/// activation scales make the split part of the quantized numerics,
/// exactly like ghost batch norm on the training side).
fn shard_bounds(n: usize) -> Vec<(usize, usize)> {
    let s = NSHARDS.min(n).max(1);
    (0..s).map(|i| (i * n / s, (i + 1) * n / s)).collect()
}

/// Raw mutable logits base smuggled into the shard closure; each shard
/// reslices its own disjoint row range.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);

unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl<'a> QuantNet<'a> {
    /// Build from a spec plus per-geometry state slices (normally via
    /// `NativeBackend::quantize`). The result is meant to be built once
    /// per trained state and reused for every eval batch.
    pub fn build(
        spec: &'a SupernetSpec,
        geoms: &[GeomParams],
        fc_w: &[f32],
        fc_b: &[f32],
    ) -> Result<QuantNet<'a>> {
        if geoms.len() != spec.n_convs() {
            return Err(anyhow!(
                "quantize: {} geometries supplied, spec has {}",
                geoms.len(),
                spec.n_convs()
            ));
        }
        let layers: Vec<QLayer> = geoms
            .iter()
            .enumerate()
            .map(|(gi, p)| QLayer::build(spec, gi, p))
            .collect();
        // one-time weight prepacking: a single slab sized by the plan
        // walk, filled here and never touched again (steady-state evals
        // stream it read-only — the zero-allocation pin covers it)
        let tier = QTier::detect();
        let pplan = quant_pack_plan(spec);
        let mut pack = vec![0i8; pplan.total];
        let mut pack_meta = Vec::with_capacity(spec.n_convs());
        for (gi, ql) in layers.iter().enumerate() {
            pack_meta.push(pplan.offsets[gi].map(|off| {
                let (k, n) = (spec.fan_in(gi), spec.layers[gi].cout);
                let len = quant_packed_len(k, n);
                let has_m128 = pack_b_into(&ql.codes, k, n, &mut pack[off..off + len]);
                PackInfo {
                    off,
                    len,
                    k_pad: quant_k_pad(k),
                    tier: tier.for_packed(has_m128),
                }
            }));
        }
        profile::set_tier_tag(tier.name());
        // prime scratch for the manifest batch size; odd batch sizes
        // just grow capacity once and settle
        let batch = spec.dataset.batch.max(1);
        let max_shard = shard_bounds(batch)
            .iter()
            .map(|&(a, b)| b - a)
            .max()
            .unwrap_or(1);
        let qplan = quant_shard_plan(spec, max_shard);
        let scratch = (0..NSHARDS)
            .map(|_| Mutex::new(QScratch::primed(&qplan)))
            .collect();
        Ok(QuantNet {
            spec,
            layers,
            fc_w: fc_w.to_vec(),
            fc_b: fc_b.to_vec(),
            pool: None,
            scratch,
            tier,
            pack,
            pack_meta,
        })
    }

    /// The qmatmul tier detected when this net was built (individual
    /// layers may still fall back via the −128 gate).
    pub fn tier(&self) -> QTier {
        self.tier
    }

    /// Total bytes of the prepacked weight slab (pinned by the
    /// zero-allocation test: must equal `quant_pack_plan(spec).total`
    /// and never change after build).
    pub fn packed_len(&self) -> usize {
        self.pack.len()
    }

    /// Run batch shards as tasks of `pool` (surplus slots become kernel
    /// row lanes). Purely a scheduling choice — outputs are bit-identical
    /// with or without a pool.
    pub fn set_pool(&mut self, pool: &'a WorkerPool) {
        self.pool = Some(pool);
    }

    pub fn spec(&self) -> &SupernetSpec {
        self.spec
    }

    pub fn layer(&self, gi: usize) -> &QLayer {
        &self.layers[gi]
    }

    /// Quantized logits for an NHWC batch `x` of `n` images.
    pub fn forward(&self, x: &[f32], n: usize) -> Vec<f32> {
        let mut logits = vec![0.0f32; n * self.spec.classes];
        self.forward_into(x, n, true, &mut logits);
        logits
    }

    /// The fake-quant emulation of the same discretized network: f32
    /// arithmetic on the dequantized weights, unquantized activations.
    /// This is what the training-time eval forward computes for a
    /// frozen/discretized θ — the validation reference.
    pub fn forward_f32_reference(&self, x: &[f32], n: usize) -> Vec<f32> {
        let mut logits = vec![0.0f32; n * self.spec.classes];
        self.forward_into(x, n, false, &mut logits);
        logits
    }

    /// `[correct, loss_sum]` of the quantized forward — the same metric
    /// pair as `ModelBackend::eval_batch`. Metrics reduce in shard-index
    /// order, matching the f32 engine's contract.
    pub fn eval_batch(&self, x: &[f32], y: &[i32]) -> Result<Vec<f32>> {
        let hw = self.spec.dataset.hw;
        let n = y.len();
        if x.len() != n * hw * hw * 3 {
            return Err(anyhow!(
                "quantized eval: {} labels but {} pixels (expected {n}·{hw}·{hw}·3)",
                n,
                x.len()
            ));
        }
        let classes = self.spec.classes;
        let row = hw * hw * 3;
        let bounds = shard_bounds(n);
        let metrics = self.run_shards(bounds.len(), &|i, scope| {
            let (b0, b1) = bounds[i];
            let nb = b1 - b0;
            let mut sc = self.scratch[i].lock().unwrap();
            let mut logits = std::mem::take(&mut sc.logits);
            logits.clear();
            logits.resize(nb * classes, 0.0);
            self.forward_shard(&x[b0 * row..b1 * row], nb, true, scope, &mut sc, &mut logits);
            let mc = logits_metrics(&logits, &y[b0..b1], classes);
            sc.logits = logits;
            mc
        });
        let (mut correct, mut loss_sum) = (0.0f32, 0.0f32);
        for (c, l) in metrics {
            correct += c;
            loss_sum += l;
        }
        Ok(vec![correct, loss_sum])
    }

    /// One closure per batch shard, on the pool when attached; results
    /// in shard order.
    fn run_shards<T: Send>(
        &self,
        s: usize,
        f: &(dyn Fn(usize, &KernelScope) -> T + Sync),
    ) -> Vec<T> {
        match self.pool {
            Some(p) => p.run_tasks(s, f),
            None => {
                let scope = KernelScope::serial();
                (0..s).map(|i| f(i, &scope)).collect()
            }
        }
    }

    /// Shard-split forward writing each shard's logits rows in place.
    fn forward_into(&self, x: &[f32], n: usize, quantized: bool, logits: &mut [f32]) {
        let hw = self.spec.dataset.hw;
        let classes = self.spec.classes;
        debug_assert_eq!(x.len(), n * hw * hw * 3);
        debug_assert_eq!(logits.len(), n * classes);
        let row = hw * hw * 3;
        let bounds = shard_bounds(n);
        let base = SendPtr(logits.as_mut_ptr());
        self.run_shards(bounds.len(), &|i, scope| {
            let (b0, b1) = bounds[i];
            let mut sc = self.scratch[i].lock().unwrap();
            // disjoint logits rows per shard; run_shards joins all
            // shards before returning, so the reslices never alias
            let chunk = unsafe {
                std::slice::from_raw_parts_mut(base.0.add(b0 * classes), (b1 - b0) * classes)
            };
            self.forward_shard(&x[b0 * row..b1 * row], b1 - b0, quantized, scope, &mut sc, chunk);
        });
    }

    /// One shard's plan walk: conv/resblock/dwpw steps on recycled
    /// buffers, then the (never-quantized) GAP → FC head.
    fn forward_shard(
        &self,
        x: &[f32],
        n: usize,
        quantized: bool,
        scope: &KernelScope,
        sc: &mut QScratch,
        logits: &mut [f32],
    ) {
        let hw = self.spec.dataset.hw;
        debug_assert_eq!(x.len(), n * hw * hw * 3);
        let mut cur = Act {
            buf: {
                let mut b = sc.take(x.len());
                b.copy_from_slice(x);
                b
            },
            n,
            h: hw,
            w: hw,
            c: 3,
        };
        for step in &self.spec.plan {
            match *step {
                PlanStep::Conv(i) => {
                    let y = self.conv_bn(i, &cur, true, quantized, scope, sc);
                    sc.put(std::mem::replace(&mut cur, y).buf);
                }
                PlanStep::ResBlock { c1, c2, dn } => {
                    let h = self.conv_bn(c1, &cur, true, quantized, scope, sc);
                    let mut h2 = self.conv_bn(c2, &h, false, quantized, scope, sc);
                    sc.put(h.buf);
                    match dn {
                        Some(d) => {
                            let s = self.conv_bn(d, &cur, false, quantized, scope, sc);
                            for (a, &b) in h2.buf.iter_mut().zip(&s.buf) {
                                *a = (*a + b).max(0.0);
                            }
                            sc.put(s.buf);
                        }
                        None => {
                            for (a, &b) in h2.buf.iter_mut().zip(&cur.buf) {
                                *a = (*a + b).max(0.0);
                            }
                        }
                    }
                    sc.put(std::mem::replace(&mut cur, h2).buf);
                }
                PlanStep::DwPw { dw, pw } => {
                    let y = self.conv_bn(dw, &cur, true, quantized, scope, sc);
                    sc.put(std::mem::replace(&mut cur, y).buf);
                    let y = self.conv_bn(pw, &cur, true, quantized, scope, sc);
                    sc.put(std::mem::replace(&mut cur, y).buf);
                }
            }
        }
        // GAP → FC head, always f32 (the training graph never quantizes
        // the classifier)
        let (nb, hwp, c) = (cur.n, cur.h * cur.w, cur.c);
        let mut pooled = sc.take(nb * c);
        for b in 0..nb {
            for p in 0..hwp {
                let row = &cur.buf[(b * hwp + p) * c..(b * hwp + p + 1) * c];
                for (acc, &v) in pooled[b * c..(b + 1) * c].iter_mut().zip(row) {
                    *acc += v;
                }
            }
        }
        pooled.iter_mut().for_each(|v| *v /= hwp as f32);
        let classes = self.spec.classes;
        debug_assert_eq!(logits.len(), nb * classes);
        matmul_into(&pooled, &self.fc_w, logits, nb, c, classes);
        for lrow in logits.chunks_exact_mut(classes) {
            for (l, &b) in lrow.iter_mut().zip(&self.fc_b) {
                *l += b;
            }
        }
        sc.put(pooled);
        sc.put(cur.buf);
    }

    /// conv/dw → folded BN affine → optional relu.
    fn conv_bn(
        &self,
        gi: usize,
        x: &Act,
        with_relu: bool,
        quantized: bool,
        scope: &KernelScope,
        sc: &mut QScratch,
    ) -> Act {
        let l = &self.spec.layers[gi];
        let mut y = match l.ltype {
            LayerType::Dw => self.dw_conv(gi, x, quantized, scope, sc),
            _ => self.conv(gi, x, quantized, scope, sc),
        };
        let ql = &self.layers[gi];
        for row in y.buf.chunks_exact_mut(y.c) {
            for ((v, &a), &b) in row.iter_mut().zip(&ql.bn_a).zip(&ql.bn_b) {
                *v = *v * a + b;
                if with_relu {
                    *v = v.max(0.0);
                }
            }
        }
        y
    }

    /// Standard / pointwise conv: im2col (skipped for 1×1/stride-1),
    /// then — for quantized layers — one fused integer GEMM + dequant
    /// over *all* output channels (pruned rows carry `dq = 0`, Identity
    /// rows are fixed up with f32 dots afterwards), output rows sharded
    /// across the scope's kernel lanes. The f32 reference path runs the
    /// dequantized weights through the shared `matmul_bt_into`.
    fn conv(
        &self,
        gi: usize,
        x: &Act,
        quantized: bool,
        scope: &KernelScope,
        sc: &mut QScratch,
    ) -> Act {
        let l = &self.spec.layers[gi];
        let ql = &self.layers[gi];
        let (k, stride) = (l.k, l.stride);
        let cout = l.cout;
        let f = k * k * x.c;
        let (oh, ow, _) = same_geometry(x.h, x.w, k, stride);
        let rows = x.n * oh * ow;
        let pointwise = k == 1 && stride == 1;
        let cols_owned: Option<Vec<f32>> = if pointwise {
            None
        } else {
            // take() zeroes, so padding taps stay 0
            let mut buf = sc.take(rows * f);
            im2col_slice_into(&x.buf, x.n, x.h, x.w, x.c, k, stride, &mut buf, scope);
            Some(buf)
        };
        let cols: &[f32] = cols_owned.as_deref().unwrap_or(&x.buf);
        let use_int = quantized && ql.any_integer();
        let scale_a = if use_int {
            quantize_act_into(cols, &mut sc.a8)
        } else {
            1.0
        };
        if use_int {
            sc.dq.clear();
            sc.dq.extend(ql.scales.iter().map(|&s| s * scale_a));
        }
        let mut out = sc.take(rows * cout);
        {
            let a8: &[i8] = &sc.a8;
            let dq: &[f32] = &sc.dq;
            let pinfo = self.pack_meta[gi].as_ref();
            let pack: &[i8] = &self.pack;
            par_rows(&mut out, rows, cout, scope, |r0, r1, chunk| {
                if use_int {
                    // probe inside the lane closure: the Op counters are
                    // atomics, so concurrent lanes sum to the true CPU
                    // time of the quantized GEMM
                    let _p = profile::time(Op::QMatmul);
                    let pi = pinfo.expect("dense conv layers are always packed");
                    drive_packed_tier(
                        pi.tier,
                        &a8[r0 * f..r1 * f],
                        &pack[pi.off..pi.off + pi.len],
                        r1 - r0,
                        f,
                        pi.k_pad,
                        cout,
                        |i, j, s| chunk[i * cout + j] = s as f32 * dq[j],
                    );
                    for &j in &ql.ident_cols {
                        for i in r0..r1 {
                            chunk[(i - r0) * cout + j] =
                                fdot(&cols[i * f..(i + 1) * f], &ql.w_deq[j * f..(j + 1) * f]);
                        }
                    }
                } else {
                    matmul_bt_into(&cols[r0 * f..r1 * f], &ql.w_deq, chunk, r1 - r0, f, cout);
                }
            });
        }
        if let Some(b) = cols_owned {
            sc.put(b);
        }
        Act {
            buf: out,
            n: x.n,
            h: oh,
            w: ow,
            c: cout,
        }
    }

    /// Depthwise conv: per-channel integer tap accumulation (i32) for
    /// quantized channels, f32 taps on dequantized weights otherwise;
    /// flattened output pixels sharded across the scope's kernel lanes.
    fn dw_conv(
        &self,
        gi: usize,
        x: &Act,
        quantized: bool,
        scope: &KernelScope,
        sc: &mut QScratch,
    ) -> Act {
        let l = &self.spec.layers[gi];
        let ql = &self.layers[gi];
        let (k, stride) = (l.k, l.stride);
        let c = x.c;
        debug_assert_eq!(l.cout, c);
        let (oh, ow, pad) = same_geometry(x.h, x.w, k, stride);
        let rows = x.n * oh * ow;
        let use_int = quantized && ql.any_integer();
        let scale_a = if use_int {
            quantize_act_into(&x.buf, &mut sc.a8)
        } else {
            1.0
        };
        if use_int {
            sc.dq.clear();
            sc.dq.extend(ql.scales.iter().map(|&s| s * scale_a));
        }
        let mut out = sc.take(rows * c);
        {
            let a8: &[i8] = &sc.a8;
            let dq: &[f32] = &sc.dq;
            let (xh, xw) = (x.h, x.w);
            let xbuf: &[f32] = &x.buf;
            par_rows(&mut out, rows, c, scope, |r0, r1, chunk| {
                let _p = use_int.then(|| profile::time(Op::QMatmul));
                for ri in r0..r1 {
                    let b = ri / (oh * ow);
                    let rem = ri % (oh * ow);
                    let (oy, ox) = (rem / ow, rem % ow);
                    let orow = &mut chunk[(ri - r0) * c..(ri - r0 + 1) * c];
                    for (ch, ov) in orow.iter_mut().enumerate() {
                        let int_ch = use_int
                            && matches!(ql.kinds[ch], QuantKind::Int8 | QuantKind::Ternary);
                        let mut acc_i = 0i32;
                        let mut acc_f = 0.0f32;
                        for ky in 0..k {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            if iy < 0 || iy >= xh as isize {
                                continue;
                            }
                            for kx in 0..k {
                                let ix = (ox * stride + kx) as isize - pad as isize;
                                if ix < 0 || ix >= xw as isize {
                                    continue;
                                }
                                let src = ((b * xh + iy as usize) * xw + ix as usize) * c + ch;
                                let wi = ch * k * k + ky * k + kx;
                                if int_ch {
                                    acc_i += a8[src] as i32 * ql.codes[wi] as i32;
                                } else {
                                    acc_f += xbuf[src] * ql.w_deq[wi];
                                }
                            }
                        }
                        *ov = if int_ch { acc_i as f32 * dq[ch] } else { acc_f };
                    }
                }
            });
        }
        Act {
            buf: out,
            n: x.n,
            h: oh,
            w: ow,
            c,
        }
    }
}

/// `(correct, loss_sum)` of a logits matrix against integer labels —
/// the same softmax/argmax recipe (first-strictly-greater tie-breaking)
/// as the tape's `softmax_ce`, so metric comparisons are apples-to-apples.
pub fn logits_metrics(logits: &[f32], labels: &[i32], classes: usize) -> (f32, f32) {
    let n = labels.len();
    debug_assert_eq!(logits.len(), n * classes);
    let mut correct = 0.0f32;
    let mut loss_sum = 0.0f32;
    let mut probs = vec![0.0f32; classes];
    for b in 0..n {
        let row = &logits[b * classes..(b + 1) * classes];
        let mx = row.iter().cloned().fold(f32::MIN, f32::max);
        let mut z = 0.0f32;
        for (p, &v) in probs.iter_mut().zip(row) {
            *p = (v - mx).exp();
            z += *p;
        }
        probs.iter_mut().for_each(|p| *p /= z);
        let mut best = 0;
        for (j, &p) in probs.iter().enumerate() {
            if p > probs[best] {
                best = j;
            }
        }
        let lab = labels[b] as usize;
        loss_sum += -probs[lab].max(1e-12).ln();
        if best == lab {
            correct += 1.0;
        }
    }
    (correct, loss_sum)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmatmul_tiers_match_wide_integer_reference() {
        let (m, k, n) = (5, 19, 7);
        let a: Vec<i8> = (0..m * k).map(|i| ((i * 37 + 11) % 255) as i8).collect();
        let b: Vec<i8> = (0..n * k).map(|i| ((i * 53 + 5) % 255) as i8).collect();
        let mut naive = vec![0i32; m * n];
        let mut blocked = vec![0i32; m * n];
        let mut dispatch = vec![0i32; m * n];
        qmatmul_bt_into_naive(&a, &b, &mut naive, m, k, n);
        qmatmul_bt_into_blocked(&a, &b, &mut blocked, m, k, n);
        qmatmul_bt_into(&a, &b, &mut dispatch, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let want: i64 = (0..k)
                    .map(|p| a[i * k + p] as i64 * b[j * k + p] as i64)
                    .sum();
                assert_eq!(naive[i * n + j] as i64, want, "naive ({i},{j})");
            }
        }
        assert_eq!(naive, blocked);
        assert_eq!(naive, dispatch);
    }

    #[test]
    fn dequant_kernel_fuses_scale_exactly() {
        let (m, k, n) = (3, 11, 6);
        let a: Vec<i8> = (0..m * k).map(|i| ((i * 29 + 3) % 255) as i8).collect();
        let b: Vec<i8> = (0..n * k).map(|i| ((i * 31 + 7) % 255) as i8).collect();
        let dq: Vec<f32> = (0..n).map(|j| 0.01 * (j as f32 + 1.0)).collect();
        let mut ints = vec![0i32; m * n];
        qmatmul_bt_into_naive(&a, &b, &mut ints, m, k, n);
        let mut fused = vec![0.0f32; m * n];
        qmatmul_bt_dequant_into(&a, &b, &mut fused, m, k, n, &dq);
        for i in 0..m {
            for j in 0..n {
                let want = ints[i * n + j] as f32 * dq[j];
                assert_eq!(fused[i * n + j].to_bits(), want.to_bits(), "({i},{j})");
            }
        }
    }

    #[test]
    fn act_quantization_round_trips_within_half_step() {
        let x: Vec<f32> = (0..100).map(|i| ((i as f32) * 0.37).sin() * 3.0).collect();
        let (codes, scale) = quantize_act(&x);
        for (&c, &v) in codes.iter().zip(&x) {
            assert!(
                (c as f32 * scale - v).abs() <= 0.5 * scale + 1e-6,
                "code {c} scale {scale} value {v}"
            );
        }
        // all-zero input takes the scale=1 escape hatch
        let (codes, scale) = quantize_act(&[0.0; 8]);
        assert_eq!(scale, 1.0);
        assert!(codes.iter().all(|&c| c == 0));
        // the reusable form reuses its buffer and agrees with the
        // allocating one
        let mut buf = Vec::new();
        let s2 = quantize_act_into(&x, &mut buf);
        let (codes, scale) = quantize_act(&x);
        assert_eq!(s2.to_bits(), scale.to_bits());
        assert_eq!(buf, codes);
    }

    #[test]
    fn masked_argmax_respects_mask_and_ties() {
        assert_eq!(masked_argmax(&[1.0, 5.0, 3.0], &[true, true, true]), 1);
        assert_eq!(masked_argmax(&[1.0, 5.0, 3.0], &[true, false, true]), 2);
        // tie → lowest eligible index
        assert_eq!(masked_argmax(&[2.0, 2.0, 2.0], &[true, true, true]), 0);
        assert_eq!(masked_argmax(&[2.0, 2.0, 2.0], &[false, true, true]), 1);
    }
}
