//! Real quantized inference: i8 / 2-bit-ternary weight storage with
//! per-channel scales, symmetric int8 activations, and an integer GEMM
//! with i32 accumulators — the arithmetic the [`QuantKind`] fake-quant
//! ops only *emulate* in f32 during training.
//!
//! [`QuantNet`] is a frozen, discretized snapshot of a trained state:
//! each searchable conv's θ row is argmax-discretized to one CU column
//! and the row's weights are stored as that CU's representation —
//! `i8` codes (int8: −127..127, ternary: −1/0/+1) plus one f32 scale
//! per output channel, chosen so `code · scale` reproduces the training
//! forward's [`QuantKind::quant_row`] output *bit-exactly*. Identity
//! (full-precision) rows stay f32; Zero (pruned) rows produce zeros.
//! Batch-norm running stats are folded into a per-channel affine with
//! the same [`BN_EPS`] as the tape's eval forward; the FC head is never
//! quantized, matching the training graph.
//!
//! At inference each quantized conv's *input* is quantized symmetric
//! per-tensor (`scale = max|x| / 127`, no zero point), the GEMM runs on
//! `i8 × i8 → i32` (integer accumulation is associative, so this path
//! is trivially deterministic for any execution order), and the output
//! dequantizes by `scale_act · scale_w[ch]`. Validation contract:
//! [`QuantNet::forward_f32_reference`] runs the same discretized
//! network in f32 with the dequantized weights and *no* activation
//! quantization — exactly the fake-quant emulation — and
//! `tests/quantized.rs` pins the quantized logits against it to a
//! documented tolerance on every builtin SoC's supernet.
//!
//! Everything here allocates per call (no arena): this is the deploy
//! path, run once per batch, not the training hot loop.

use anyhow::{anyhow, Result};

use crate::soc::LayerType;

use super::profile::{self, Op};
use super::supernet::{PlanStep, SearchMode, SupernetSpec, BN_EPS};
use super::tape::{im2col_into, same_geometry, QuantKind};
use super::tensor::{matmul_into, Tensor};

/// One conv geometry's frozen quantized parameters.
pub struct QLayer {
    /// per-output-channel quantizer actually applied after θ argmax
    pub kinds: Vec<QuantKind>,
    /// row-major `[cout, f]` integer codes (int8 or ternary rows;
    /// Identity/Zero rows are all-zero placeholders)
    pub codes: Vec<i8>,
    /// per-row dequantization scale (`code · scale` = fake-quant value)
    pub scales: Vec<f32>,
    /// the fake-quant f32 weights (`quant_row` output): the f32
    /// reference forward reads all rows, the quantized forward reads
    /// only Identity rows
    pub w_deq: Vec<f32>,
    /// folded BN affine `y = a·x + b` from the running stats
    pub bn_a: Vec<f32>,
    pub bn_b: Vec<f32>,
}

/// Raw state slices of one conv geometry (assembled by
/// `NativeBackend::quantize` from its leaf table).
pub struct GeomParams<'a> {
    pub w: &'a [f32],
    pub scale: &'a [f32],
    pub bias: &'a [f32],
    pub mean: &'a [f32],
    pub var: &'a [f32],
    pub theta: Option<&'a [f32]>,
}

/// A discretized, genuinely-quantized inference network.
pub struct QuantNet<'a> {
    spec: &'a SupernetSpec,
    layers: Vec<QLayer>,
    fc_w: Vec<f32>,
    fc_b: Vec<f32>,
}

/// Masked argmax over one θ row; ties keep the lowest eligible column.
fn masked_argmax(row: &[f32], mask: &[bool]) -> usize {
    let mut best: Option<usize> = None;
    for (j, &v) in row.iter().enumerate() {
        if !mask[j] {
            continue;
        }
        match best {
            Some(b) if row[b] >= v => {}
            _ => best = Some(j),
        }
    }
    best.unwrap_or(0)
}

/// Per-output-channel quantizer of geometry `gi` after θ discretization.
pub fn row_kinds(spec: &SupernetSpec, gi: usize, theta: Option<&[f32]>) -> Vec<QuantKind> {
    let l = &spec.layers[gi];
    let cout = l.cout;
    let th = match theta {
        Some(t) if l.searchable => t,
        // fixed-precision layers run on the primary CU's representation,
        // matching the training forward's `fake_quant_ste(w, quants[0])`
        _ => return vec![spec.quants[0]; cout],
    };
    match spec.search {
        SearchMode::Channel | SearchMode::Fixed => {
            let k = spec.platform.n_cus();
            debug_assert_eq!(th.len(), cout * k);
            (0..cout)
                .map(|r| spec.quants[masked_argmax(&th[r * k..(r + 1) * k], &spec.masks[gi])])
                .collect()
        }
        SearchMode::Prune => {
            debug_assert_eq!(th.len(), cout * 2);
            (0..cout)
                .map(|r| {
                    if th[r * 2] >= th[r * 2 + 1] {
                        spec.quants[0]
                    } else {
                        QuantKind::Zero
                    }
                })
                .collect()
        }
        SearchMode::Layerwise => {
            let kind = spec.quants[masked_argmax(th, &spec.masks[gi])];
            vec![kind; cout]
        }
    }
}

impl QLayer {
    /// Quantize one geometry's weights row-by-row and fold its BN stats.
    fn build(spec: &SupernetSpec, gi: usize, p: &GeomParams) -> QLayer {
        let cout = spec.layers[gi].cout;
        let f = spec.fan_in(gi);
        debug_assert_eq!(p.w.len(), cout * f);
        let kinds = row_kinds(spec, gi, p.theta);
        let mut codes = vec![0i8; cout * f];
        let mut scales = vec![0.0f32; cout];
        let mut w_deq = vec![0.0f32; cout * f];
        for r in 0..cout {
            let row = &p.w[r * f..(r + 1) * f];
            kinds[r].quant_row(row, &mut w_deq[r * f..(r + 1) * f]);
            let crow = &mut codes[r * f..(r + 1) * f];
            match kinds[r] {
                QuantKind::Identity | QuantKind::Zero => {}
                QuantKind::Int8 => {
                    let amax = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                    let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
                    scales[r] = scale;
                    for (c, &v) in crow.iter_mut().zip(row) {
                        *c = (v / scale).round().clamp(-127.0, 127.0) as i8;
                    }
                }
                QuantKind::Ternary => {
                    // same thr/scale recipe as `quant_row`, so
                    // code·scale == the fake-quant value bit-exactly
                    let amax = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                    let thr = 0.05 * amax;
                    let mut kept = 0.0f32;
                    let mut sum = 0.0f32;
                    for &v in row {
                        if v.abs() > thr {
                            kept += 1.0;
                            sum += v.abs();
                        }
                    }
                    scales[r] = sum / kept.max(1.0);
                    for (c, &v) in crow.iter_mut().zip(row) {
                        *c = if v.abs() > thr {
                            if v > 0.0 {
                                1
                            } else {
                                -1
                            }
                        } else {
                            0
                        };
                    }
                }
            }
        }
        let bn_a: Vec<f32> = p
            .scale
            .iter()
            .zip(p.var)
            .map(|(&s, &v)| s / (v + BN_EPS).sqrt())
            .collect();
        let bn_b: Vec<f32> = p
            .bias
            .iter()
            .zip(p.mean.iter().zip(&bn_a))
            .map(|(&b, (&m, &a))| b - m * a)
            .collect();
        QLayer {
            kinds,
            codes,
            scales,
            w_deq,
            bn_a,
            bn_b,
        }
    }

    /// True if any row runs on integer codes (int8 or ternary).
    fn any_integer(&self) -> bool {
        self.kinds
            .iter()
            .any(|&k| k == QuantKind::Int8 || k == QuantKind::Ternary)
    }
}

// ---------------------------------------------------------------------------
// integer kernels
// ---------------------------------------------------------------------------

/// Symmetric per-tensor int8 activation quantization: `scale = max|x| /
/// 127`, codes rounded and clamped to ±127, no zero point.
pub fn quantize_act(x: &[f32]) -> (Vec<i8>, f32) {
    let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
    let codes = x
        .iter()
        .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8)
        .collect();
    (codes, scale)
}

/// Integer GEMM `C[m,n] = A[m,k] · B[n,k]ᵀ` on i8 codes with i32
/// accumulators — the dot-product (`A·Bᵀ`) layout the conv lowering
/// uses, weights as rows of codes. Integer adds are associative, so any
/// blocking/threading of this kernel is bit-identical by construction.
pub fn qmatmul_bt_into(a: &[i8], b: &[i8], c: &mut [i32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0i32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av as i32 * bv as i32;
            }
            *cv = acc;
        }
    }
}

/// f32 dot (Identity rows of a mixed-precision conv).
fn fdot(x: &[f32], y: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (&a, &b) in x.iter().zip(y) {
        s += a * b;
    }
    s
}

// ---------------------------------------------------------------------------
// forward
// ---------------------------------------------------------------------------

/// One activation tensor flowing through the plan.
struct Act {
    data: Vec<f32>,
    n: usize,
    h: usize,
    w: usize,
    c: usize,
}

impl QuantNet<'_> {
    /// Build from a spec plus per-geometry state slices (normally via
    /// `NativeBackend::quantize`).
    pub fn build<'a>(
        spec: &'a SupernetSpec,
        geoms: &[GeomParams],
        fc_w: &[f32],
        fc_b: &[f32],
    ) -> Result<QuantNet<'a>> {
        if geoms.len() != spec.n_convs() {
            return Err(anyhow!(
                "quantize: {} geometries supplied, spec has {}",
                geoms.len(),
                spec.n_convs()
            ));
        }
        let layers = geoms
            .iter()
            .enumerate()
            .map(|(gi, p)| QLayer::build(spec, gi, p))
            .collect();
        Ok(QuantNet {
            spec,
            layers,
            fc_w: fc_w.to_vec(),
            fc_b: fc_b.to_vec(),
        })
    }

    pub fn spec(&self) -> &SupernetSpec {
        self.spec
    }

    pub fn layer(&self, gi: usize) -> &QLayer {
        &self.layers[gi]
    }

    /// Quantized logits for an NHWC batch `x` of `n` images.
    pub fn forward(&self, x: &[f32], n: usize) -> Vec<f32> {
        self.forward_inner(x, n, true)
    }

    /// The fake-quant emulation of the same discretized network: f32
    /// arithmetic on the dequantized weights, unquantized activations.
    /// This is what the training-time eval forward computes for a
    /// frozen/discretized θ — the validation reference.
    pub fn forward_f32_reference(&self, x: &[f32], n: usize) -> Vec<f32> {
        self.forward_inner(x, n, false)
    }

    /// `[correct, loss_sum]` of the quantized forward — the same metric
    /// pair as `ModelBackend::eval_batch`.
    pub fn eval_batch(&self, x: &[f32], y: &[i32]) -> Result<Vec<f32>> {
        let hw = self.spec.dataset.hw;
        let n = y.len();
        if x.len() != n * hw * hw * 3 {
            return Err(anyhow!(
                "quantized eval: {} labels but {} pixels (expected {n}·{hw}·{hw}·3)",
                n,
                x.len()
            ));
        }
        let logits = self.forward(x, n);
        let (correct, loss_sum) = logits_metrics(&logits, y, self.spec.classes);
        Ok(vec![correct, loss_sum])
    }

    fn forward_inner(&self, x: &[f32], n: usize, quantized: bool) -> Vec<f32> {
        let hw = self.spec.dataset.hw;
        debug_assert_eq!(x.len(), n * hw * hw * 3);
        let mut cur = Act {
            data: x.to_vec(),
            n,
            h: hw,
            w: hw,
            c: 3,
        };
        for step in &self.spec.plan {
            match *step {
                PlanStep::Conv(i) => {
                    cur = self.conv_bn(i, &cur, true, quantized);
                }
                PlanStep::ResBlock { c1, c2, dn } => {
                    let h = self.conv_bn(c1, &cur, true, quantized);
                    let mut h2 = self.conv_bn(c2, &h, false, quantized);
                    let sc = match dn {
                        Some(d) => self.conv_bn(d, &cur, false, quantized),
                        None => cur,
                    };
                    for (a, &b) in h2.data.iter_mut().zip(&sc.data) {
                        *a = (*a + b).max(0.0);
                    }
                    cur = h2;
                }
                PlanStep::DwPw { dw, pw } => {
                    cur = self.conv_bn(dw, &cur, true, quantized);
                    cur = self.conv_bn(pw, &cur, true, quantized);
                }
            }
        }
        // GAP → FC head, always f32 (the training graph never quantizes
        // the classifier)
        let (nb, hwp, c) = (cur.n, cur.h * cur.w, cur.c);
        let mut pooled = vec![0.0f32; nb * c];
        for b in 0..nb {
            for p in 0..hwp {
                let row = &cur.data[(b * hwp + p) * c..(b * hwp + p + 1) * c];
                for (acc, &v) in pooled[b * c..(b + 1) * c].iter_mut().zip(row) {
                    *acc += v;
                }
            }
        }
        pooled.iter_mut().for_each(|v| *v /= hwp as f32);
        let classes = self.spec.classes;
        let mut logits = vec![0.0f32; nb * classes];
        matmul_into(&pooled, &self.fc_w, &mut logits, nb, c, classes);
        for lrow in logits.chunks_exact_mut(classes) {
            for (l, &b) in lrow.iter_mut().zip(&self.fc_b) {
                *l += b;
            }
        }
        logits
    }

    /// conv/dw → folded BN affine → optional relu.
    fn conv_bn(&self, gi: usize, x: &Act, with_relu: bool, quantized: bool) -> Act {
        let l = &self.spec.layers[gi];
        let mut y = match l.ltype {
            LayerType::Dw => self.dw_conv(gi, x, quantized),
            _ => self.conv(gi, x, quantized),
        };
        let ql = &self.layers[gi];
        for row in y.data.chunks_exact_mut(y.c) {
            for ((v, &a), &b) in row.iter_mut().zip(&ql.bn_a).zip(&ql.bn_b) {
                *v = *v * a + b;
                if with_relu {
                    *v = v.max(0.0);
                }
            }
        }
        y
    }

    /// Standard / pointwise conv: im2col (skipped for 1×1/stride-1) then
    /// a per-row mixed GEMM — integer dot with i32 accumulators for
    /// int8/ternary rows, f32 dot on the dequantized weights for
    /// Identity rows, zeros for pruned rows.
    fn conv(&self, gi: usize, x: &Act, quantized: bool) -> Act {
        let l = &self.spec.layers[gi];
        let ql = &self.layers[gi];
        let (k, stride) = (l.k, l.stride);
        let cout = l.cout;
        let f = k * k * x.c;
        let (oh, ow, _) = same_geometry(x.h, x.w, k, stride);
        let rows = x.n * oh * ow;
        let pointwise = k == 1 && stride == 1;
        let cols_owned: Vec<f32>;
        let cols: &[f32] = if pointwise {
            &x.data
        } else {
            let xt = Tensor::new(vec![x.n, x.h, x.w, x.c], x.data.clone());
            let mut buf = vec![0.0f32; rows * f];
            im2col_into(&xt, k, stride, &mut buf);
            cols_owned = buf;
            &cols_owned
        };
        let mut out = vec![0.0f32; rows * cout];
        let use_int = quantized && ql.any_integer();
        let (a8, scale_a) = if use_int {
            quantize_act(cols)
        } else {
            (Vec::new(), 1.0)
        };
        let _p = use_int.then(|| profile::time(Op::QMatmul));
        for i in 0..rows {
            let arowf = &cols[i * f..(i + 1) * f];
            let orow = &mut out[i * cout..(i + 1) * cout];
            for (j, ov) in orow.iter_mut().enumerate() {
                let wrow = j * f..(j + 1) * f;
                *ov = match ql.kinds[j] {
                    QuantKind::Zero => 0.0,
                    QuantKind::Identity => fdot(arowf, &ql.w_deq[wrow]),
                    QuantKind::Int8 | QuantKind::Ternary => {
                        if use_int {
                            let arow8 = &a8[i * f..(i + 1) * f];
                            let mut acc = 0i32;
                            for (&av, &bv) in arow8.iter().zip(&ql.codes[wrow]) {
                                acc += av as i32 * bv as i32;
                            }
                            acc as f32 * scale_a * ql.scales[j]
                        } else {
                            fdot(arowf, &ql.w_deq[wrow])
                        }
                    }
                };
            }
        }
        Act {
            data: out,
            n: x.n,
            h: oh,
            w: ow,
            c: cout,
        }
    }

    /// Depthwise conv: per-channel integer tap accumulation (i32) for
    /// quantized channels, f32 taps on dequantized weights otherwise.
    fn dw_conv(&self, gi: usize, x: &Act, quantized: bool) -> Act {
        let l = &self.spec.layers[gi];
        let ql = &self.layers[gi];
        let (k, stride) = (l.k, l.stride);
        let c = x.c;
        debug_assert_eq!(l.cout, c);
        let (oh, ow, pad) = same_geometry(x.h, x.w, k, stride);
        let mut out = vec![0.0f32; x.n * oh * ow * c];
        let use_int = quantized && ql.any_integer();
        let (a8, scale_a) = if use_int {
            quantize_act(&x.data)
        } else {
            (Vec::new(), 1.0)
        };
        let _p = use_int.then(|| profile::time(Op::QMatmul));
        for b in 0..x.n {
            for oy in 0..oh {
                for ox in 0..ow {
                    let orow =
                        &mut out[((b * oh + oy) * ow + ox) * c..((b * oh + oy) * ow + ox + 1) * c];
                    for (ch, ov) in orow.iter_mut().enumerate() {
                        let int_ch = use_int
                            && matches!(ql.kinds[ch], QuantKind::Int8 | QuantKind::Ternary);
                        let mut acc_i = 0i32;
                        let mut acc_f = 0.0f32;
                        for ky in 0..k {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            if iy < 0 || iy >= x.h as isize {
                                continue;
                            }
                            for kx in 0..k {
                                let ix = (ox * stride + kx) as isize - pad as isize;
                                if ix < 0 || ix >= x.w as isize {
                                    continue;
                                }
                                let src =
                                    ((b * x.h + iy as usize) * x.w + ix as usize) * c + ch;
                                let wi = ch * k * k + ky * k + kx;
                                if int_ch {
                                    acc_i += a8[src] as i32 * ql.codes[wi] as i32;
                                } else {
                                    acc_f += x.data[src] * ql.w_deq[wi];
                                }
                            }
                        }
                        *ov = if int_ch {
                            acc_i as f32 * scale_a * ql.scales[ch]
                        } else {
                            acc_f
                        };
                    }
                }
            }
        }
        Act {
            data: out,
            n: x.n,
            h: oh,
            w: ow,
            c,
        }
    }
}

/// `(correct, loss_sum)` of a logits matrix against integer labels —
/// the same softmax/argmax recipe (first-strictly-greater tie-breaking)
/// as the tape's `softmax_ce`, so metric comparisons are apples-to-apples.
pub fn logits_metrics(logits: &[f32], labels: &[i32], classes: usize) -> (f32, f32) {
    let n = labels.len();
    debug_assert_eq!(logits.len(), n * classes);
    let mut correct = 0.0f32;
    let mut loss_sum = 0.0f32;
    let mut probs = vec![0.0f32; classes];
    for b in 0..n {
        let row = &logits[b * classes..(b + 1) * classes];
        let mx = row.iter().cloned().fold(f32::MIN, f32::max);
        let mut z = 0.0f32;
        for (p, &v) in probs.iter_mut().zip(row) {
            *p = (v - mx).exp();
            z += *p;
        }
        probs.iter_mut().for_each(|p| *p /= z);
        let mut best = 0;
        for (j, &p) in probs.iter().enumerate() {
            if p > probs[best] {
                best = j;
            }
        }
        let lab = labels[b] as usize;
        loss_sum += -probs[lab].max(1e-12).ln();
        if best == lab {
            correct += 1.0;
        }
    }
    (correct, loss_sum)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmatmul_matches_wide_integer_reference() {
        let (m, k, n) = (5, 19, 7);
        let a: Vec<i8> = (0..m * k).map(|i| ((i * 37 + 11) % 255) as i8).collect();
        let b: Vec<i8> = (0..n * k).map(|i| ((i * 53 + 5) % 255) as i8).collect();
        let mut c = vec![0i32; m * n];
        qmatmul_bt_into(&a, &b, &mut c, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let want: i64 = (0..k)
                    .map(|p| a[i * k + p] as i64 * b[j * k + p] as i64)
                    .sum();
                assert_eq!(c[i * n + j] as i64, want, "({i},{j})");
            }
        }
    }

    #[test]
    fn act_quantization_round_trips_within_half_step() {
        let x: Vec<f32> = (0..100).map(|i| ((i as f32) * 0.37).sin() * 3.0).collect();
        let (codes, scale) = quantize_act(&x);
        for (&c, &v) in codes.iter().zip(&x) {
            assert!(
                (c as f32 * scale - v).abs() <= 0.5 * scale + 1e-6,
                "code {c} scale {scale} value {v}"
            );
        }
        // all-zero input takes the scale=1 escape hatch
        let (codes, scale) = quantize_act(&[0.0; 8]);
        assert_eq!(scale, 1.0);
        assert!(codes.iter().all(|&c| c == 0));
    }

    #[test]
    fn masked_argmax_respects_mask_and_ties() {
        assert_eq!(masked_argmax(&[1.0, 5.0, 3.0], &[true, true, true]), 1);
        assert_eq!(masked_argmax(&[1.0, 5.0, 3.0], &[true, false, true]), 2);
        // tie → lowest eligible index
        assert_eq!(masked_argmax(&[2.0, 2.0, 2.0], &[true, true, true]), 0);
        assert_eq!(masked_argmax(&[2.0, 2.0, 2.0], &[false, true, true]), 1);
    }
}
