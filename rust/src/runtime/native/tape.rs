//! Reverse-mode autodiff over [`Tensor`]s: exactly the ops the ODiMO
//! supernets need, nothing more.
//!
//! A [`Tape`] records one forward pass as a flat list of nodes; each op
//! pushes its output value plus a backward closure that, given `dL/dout`,
//! accumulates into its operands' gradient slots. Because a node's output
//! can only be consumed by later-created nodes, one reverse sweep in
//! creation order is a valid topological backward pass.
//!
//! Op inventory (mirroring `python/compile/{layers,kernels}`):
//! conv2d via im2col matmul, depthwise conv, per-row int8/ternary
//! fake-quant with the straight-through estimator, Eq. 5 effective
//! weights, batch-stat normalization, ReLU, global average pool, bias
//! add, softmax cross-entropy, masked θ-softmax — plus [`Tape::layer_cost`],
//! the differentiable cost term: a piecewise-linear interpolation of
//! `soc::analytical::cu_cycles` that is *exact at integer channel counts*,
//! so the in-graph cost is pinned to the simulator the searches deploy on.

use std::rc::Rc;

use crate::soc::{analytical::cu_cycles, CuSpec, Layer};

use super::tensor::{matmul, matmul_at, matmul_bt, Tensor};

/// Handle to one tape node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Var(usize);

impl Var {
    /// Index into the gradient vector returned by [`Tape::backward`].
    pub fn id(self) -> usize {
        self.0
    }
}

type BackFn = Box<dyn Fn(&Tensor, &mut [Tensor])>;

struct Node {
    val: Rc<Tensor>,
    back: Option<BackFn>,
}

/// One recorded forward pass.
#[derive(Default)]
pub struct Tape {
    nodes: Vec<Node>,
}

fn acc(grads: &mut [Tensor], i: usize, g: &[f32]) {
    for (d, &s) in grads[i].data.iter_mut().zip(g) {
        *d += s;
    }
}

/// Per-output-channel weight quantizer of a CU (selected by the
/// descriptor's `quant` string). Semantics match the Pallas kernels in
/// `python/compile/kernels/fake_quant.py`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantKind {
    /// symmetric per-row int8: scale = max|w| / 127
    Int8,
    /// per-row ternary: threshold 0.05·max|w|, scale = mean |w| above it
    Ternary,
    /// no re-quantization (full-precision CU)
    Identity,
}

impl QuantKind {
    pub fn from_quant_str(s: &str) -> QuantKind {
        match s {
            "int8" => QuantKind::Int8,
            "ternary" => QuantKind::Ternary,
            _ => QuantKind::Identity,
        }
    }

    /// Quantize one row in place into `out`.
    pub fn quant_row(self, row: &[f32], out: &mut [f32]) {
        match self {
            QuantKind::Identity => out.copy_from_slice(row),
            QuantKind::Int8 => {
                let amax = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
                for (o, &v) in out.iter_mut().zip(row) {
                    *o = (v / scale).round().clamp(-127.0, 127.0) * scale;
                }
            }
            QuantKind::Ternary => {
                let amax = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let thr = 0.05 * amax;
                let mut kept = 0.0f32;
                let mut sum = 0.0f32;
                for &v in row {
                    if v.abs() > thr {
                        kept += 1.0;
                        sum += v.abs();
                    }
                }
                let scale = sum / kept.max(1.0);
                for (o, &v) in out.iter_mut().zip(row) {
                    *o = if v.abs() > thr {
                        v.signum() * scale
                    } else {
                        0.0
                    };
                }
            }
        }
    }
}

/// Non-differentiable extras an op reports alongside its output.
#[derive(Debug, Clone, Copy, Default)]
pub struct EvalBits {
    pub correct: f32,
    pub loss_sum: f32,
}

impl Tape {
    pub fn new() -> Tape {
        Tape::default()
    }

    fn push(&mut self, val: Tensor, back: Option<BackFn>) -> Var {
        self.nodes.push(Node {
            val: Rc::new(val),
            back,
        });
        Var(self.nodes.len() - 1)
    }

    /// Record an input/parameter (gradient sink).
    pub fn leaf(&mut self, t: Tensor) -> Var {
        self.push(t, None)
    }

    pub fn val(&self, v: Var) -> &Tensor {
        &self.nodes[v.0].val
    }

    fn rc(&self, v: Var) -> Rc<Tensor> {
        Rc::clone(&self.nodes[v.0].val)
    }

    /// Full reverse sweep from scalar `loss`; returns one gradient tensor
    /// per node (leaves keep their accumulated gradients; interior slots
    /// are consumed during the sweep).
    pub fn backward(&self, loss: Var) -> Vec<Tensor> {
        let mut grads: Vec<Tensor> = self
            .nodes
            .iter()
            .map(|n| Tensor::zeros(n.val.shape.clone()))
            .collect();
        debug_assert_eq!(self.nodes[loss.0].val.elem_count(), 1);
        grads[loss.0].data[0] = 1.0;
        for i in (0..=loss.0).rev() {
            if let Some(back) = &self.nodes[i].back {
                let g = std::mem::replace(&mut grads[i], Tensor::zeros(Vec::new()));
                back(&g, &mut grads);
            }
        }
        grads
    }

    /// Gradient of `loss` w.r.t. one var (convenience for tests).
    pub fn grad_of(&self, loss: Var, v: Var) -> Tensor {
        let mut grads = self.backward(loss);
        std::mem::replace(&mut grads[v.0], Tensor::zeros(Vec::new()))
    }

    // -----------------------------------------------------------------
    // elementwise / shape ops
    // -----------------------------------------------------------------

    pub fn add(&mut self, a: Var, b: Var) -> Var {
        let (av, bv) = (self.rc(a), self.rc(b));
        debug_assert_eq!(av.shape, bv.shape);
        let data = av.data.iter().zip(&bv.data).map(|(x, y)| x + y).collect();
        let val = Tensor::new(av.shape.clone(), data);
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                acc(grads, a.0, &g.data);
                acc(grads, b.0, &g.data);
            })),
        )
    }

    pub fn mul(&mut self, a: Var, b: Var) -> Var {
        let (av, bv) = (self.rc(a), self.rc(b));
        debug_assert_eq!(av.shape, bv.shape);
        let data = av.data.iter().zip(&bv.data).map(|(x, y)| x * y).collect();
        let val = Tensor::new(av.shape.clone(), data);
        let (sa, sb) = (Rc::clone(&av), Rc::clone(&bv));
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                for ((d, &s), &y) in grads[a.0].data.iter_mut().zip(&g.data).zip(&sb.data) {
                    *d += s * y;
                }
                for ((d, &s), &x) in grads[b.0].data.iter_mut().zip(&g.data).zip(&sa.data) {
                    *d += s * x;
                }
            })),
        )
    }

    pub fn scale(&mut self, a: Var, c: f32) -> Var {
        let av = self.rc(a);
        let data = av.data.iter().map(|x| x * c).collect();
        let val = Tensor::new(av.shape.clone(), data);
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                for (d, &s) in grads[a.0].data.iter_mut().zip(&g.data) {
                    *d += s * c;
                }
            })),
        )
    }

    pub fn relu(&mut self, a: Var) -> Var {
        let av = self.rc(a);
        let data = av.data.iter().map(|&x| x.max(0.0)).collect();
        let val = Tensor::new(av.shape.clone(), data);
        let saved = Rc::clone(&av);
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                for ((d, &s), &x) in grads[a.0].data.iter_mut().zip(&g.data).zip(&saved.data) {
                    if x > 0.0 {
                        *d += s;
                    }
                }
            })),
        )
    }

    /// Sum of every element → scalar (test/objective helper).
    pub fn sum_all(&mut self, a: Var) -> Var {
        let av = self.rc(a);
        let val = Tensor::scalar(av.data.iter().sum());
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                let s = g.data[0];
                for d in grads[a.0].data.iter_mut() {
                    *d += s;
                }
            })),
        )
    }

    /// `w0·v[0] + w1·v[1]` of a 2-vector → scalar (cost-target selection).
    pub fn weighted_pair(&mut self, v: Var, w0: f32, w1: f32) -> Var {
        let vv = self.rc(v);
        debug_assert_eq!(vv.elem_count(), 2);
        let val = Tensor::scalar(w0 * vv.data[0] + w1 * vv.data[1]);
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                let s = g.data[0];
                grads[v.0].data[0] += s * w0;
                grads[v.0].data[1] += s * w1;
            })),
        )
    }

    // -----------------------------------------------------------------
    // linear algebra
    // -----------------------------------------------------------------

    /// `A[m,k] · B[k,n]`.
    pub fn matmul(&mut self, a: Var, b: Var) -> Var {
        let (av, bv) = (self.rc(a), self.rc(b));
        let (m, k) = (av.shape[0], av.shape[1]);
        let n = bv.shape[1];
        debug_assert_eq!(bv.shape[0], k);
        let val = Tensor::new(vec![m, n], matmul(&av.data, &bv.data, m, k, n));
        let (sa, sb) = (Rc::clone(&av), Rc::clone(&bv));
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                // dA = g · Bᵀ ; dB = Aᵀ · g
                acc(grads, a.0, &matmul_bt(&g.data, &sb.data, m, n, k));
                acc(grads, b.0, &matmul_at(&sa.data, &g.data, m, k, n));
            })),
        )
    }

    /// Broadcast bias add over the trailing channel axis.
    pub fn add_bias(&mut self, x: Var, b: Var) -> Var {
        let (xv, bv) = (self.rc(x), self.rc(b));
        let c = *xv.shape.last().unwrap();
        debug_assert_eq!(bv.elem_count(), c);
        let data = xv
            .data
            .iter()
            .enumerate()
            .map(|(i, &v)| v + bv.data[i % c])
            .collect();
        let val = Tensor::new(xv.shape.clone(), data);
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                acc(grads, x.0, &g.data);
                for (i, &s) in g.data.iter().enumerate() {
                    grads[b.0].data[i % c] += s;
                }
            })),
        )
    }

    // -----------------------------------------------------------------
    // convolutions
    // -----------------------------------------------------------------

    /// 'SAME' NHWC convolution with flattened weights `w: [cout, k·k·cin]`
    /// (row layout `(ky·k + kx)·cin + ci`, matching the AOT flattening).
    /// Lowered as im2col + matmul, like the Darkside cluster executes it.
    pub fn conv2d(&mut self, x: Var, w: Var, k: usize, stride: usize) -> Var {
        let (xv, wv) = (self.rc(x), self.rc(w));
        let (n, h, ww, cin) = (xv.shape[0], xv.shape[1], xv.shape[2], xv.shape[3]);
        let cout = wv.shape[0];
        let f = k * k * cin;
        debug_assert_eq!(wv.shape[1], f);
        let (cols, oh, ow) = im2col(&xv, k, stride);
        let rows = n * oh * ow;
        let y = matmul_bt(&cols.data, &wv.data, rows, f, cout);
        let val = Tensor::new(vec![n, oh, ow, cout], y);
        let cols = Rc::new(cols);
        let saved_cols = Rc::clone(&cols);
        let saved_w = Rc::clone(&wv);
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                // dW[cout,F] = gᵀ[cout,rows] · cols[rows,F]
                acc(grads, w.0, &matmul_at(&g.data, &saved_cols.data, rows, cout, f));
                // dCols = g[rows,cout] · W[cout,F], scattered back to x
                let dcols = matmul(&g.data, &saved_w.data, rows, cout, f);
                col2im(&dcols, &mut grads[x.0].data, n, h, ww, cin, k, stride, oh, ow);
            })),
        )
    }

    /// 'SAME' depthwise convolution, weights `w: [c, k·k]`.
    pub fn dw_conv2d(&mut self, x: Var, w: Var, k: usize, stride: usize) -> Var {
        let (xv, wv) = (self.rc(x), self.rc(w));
        let (n, h, ww, c) = (xv.shape[0], xv.shape[1], xv.shape[2], xv.shape[3]);
        debug_assert_eq!(wv.shape, vec![c, k * k]);
        let (oh, ow, pad) = same_geometry(h, ww, k, stride);
        let mut y = vec![0.0f32; n * oh * ow * c];
        dw_forward(&xv.data, &wv.data, &mut y, n, h, ww, c, k, stride, pad);
        let val = Tensor::new(vec![n, oh, ow, c], y);
        let (sx, sw) = (Rc::clone(&xv), Rc::clone(&wv));
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                let (dx_slot, dw_slot) = (x.0, w.0);
                let mut dw = vec![0.0f32; c * k * k];
                let mut dx = vec![0.0f32; n * h * ww * c];
                dw_backward(
                    &sx.data, &sw.data, &g.data, &mut dx, &mut dw, n, h, ww, c, k, stride, pad,
                );
                acc(grads, dx_slot, &dx);
                acc(grads, dw_slot, &dw);
            })),
        )
    }

    // -----------------------------------------------------------------
    // normalization / pooling
    // -----------------------------------------------------------------

    /// Batch-stat normalization over all leading axes (training mode).
    /// Returns `(y, batch_mean, batch_var)`; the running-stat update
    /// happens outside the tape.
    pub fn batch_norm_train(
        &mut self,
        x: Var,
        scale: Var,
        bias: Var,
    ) -> (Var, Vec<f32>, Vec<f32>) {
        let (xv, sv, bv) = (self.rc(x), self.rc(scale), self.rc(bias));
        let c = *xv.shape.last().unwrap();
        let m = xv.elem_count() / c;
        const EPS: f32 = 1e-5;
        let mut mean = vec![0.0f32; c];
        for (i, &v) in xv.data.iter().enumerate() {
            mean[i % c] += v;
        }
        for v in mean.iter_mut() {
            *v /= m as f32;
        }
        let mut var = vec![0.0f32; c];
        for (i, &v) in xv.data.iter().enumerate() {
            let d = v - mean[i % c];
            var[i % c] += d * d;
        }
        for v in var.iter_mut() {
            *v /= m as f32;
        }
        let inv: Vec<f32> = var.iter().map(|&v| 1.0 / (v + EPS).sqrt()).collect();
        let mut xhat = vec![0.0f32; xv.elem_count()];
        let mut y = vec![0.0f32; xv.elem_count()];
        for (i, &v) in xv.data.iter().enumerate() {
            let ch = i % c;
            let xh = (v - mean[ch]) * inv[ch];
            xhat[i] = xh;
            y[i] = xh * sv.data[ch] + bv.data[ch];
        }
        let val = Tensor::new(xv.shape.clone(), y);
        let xhat = Rc::new(xhat);
        let inv_s = inv.clone();
        let saved_scale = Rc::clone(&sv);
        let saved_xhat = Rc::clone(&xhat);
        let out = self.push(
            val,
            Some(Box::new(move |g, grads| {
                let mut sum_dy = vec![0.0f32; c];
                let mut sum_dy_xhat = vec![0.0f32; c];
                for (i, &s) in g.data.iter().enumerate() {
                    let ch = i % c;
                    sum_dy[ch] += s;
                    sum_dy_xhat[ch] += s * saved_xhat[i];
                }
                for (i, &s) in g.data.iter().enumerate() {
                    let ch = i % c;
                    let mf = m as f32;
                    let dx = saved_scale.data[ch] * inv_s[ch] / mf
                        * (mf * s - sum_dy[ch] - saved_xhat[i] * sum_dy_xhat[ch]);
                    grads[x.0].data[i] += dx;
                }
                acc(grads, scale.0, &sum_dy_xhat);
                acc(grads, bias.0, &sum_dy);
            })),
        );
        (out, mean, var)
    }

    /// Inference-mode normalization: per-channel affine with *constant*
    /// coefficients folded from the running stats.
    pub fn channel_affine(&mut self, x: Var, a: Vec<f32>, b: Vec<f32>) -> Var {
        let xv = self.rc(x);
        let c = *xv.shape.last().unwrap();
        debug_assert_eq!(a.len(), c);
        let data = xv
            .data
            .iter()
            .enumerate()
            .map(|(i, &v)| v * a[i % c] + b[i % c])
            .collect();
        let val = Tensor::new(xv.shape.clone(), data);
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                for (i, &s) in g.data.iter().enumerate() {
                    grads[x.0].data[i] += s * a[i % c];
                }
            })),
        )
    }

    /// `[n,h,w,c] → [n,c]` mean over the spatial axes.
    pub fn global_avg_pool(&mut self, x: Var) -> Var {
        let xv = self.rc(x);
        let (n, h, w, c) = (xv.shape[0], xv.shape[1], xv.shape[2], xv.shape[3]);
        let hw = h * w;
        let mut y = vec![0.0f32; n * c];
        for b in 0..n {
            for p in 0..hw {
                for ch in 0..c {
                    y[b * c + ch] += xv.data[(b * hw + p) * c + ch];
                }
            }
        }
        for v in y.iter_mut() {
            *v /= hw as f32;
        }
        let val = Tensor::new(vec![n, c], y);
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                let inv = 1.0 / hw as f32;
                for b in 0..n {
                    for p in 0..hw {
                        for ch in 0..c {
                            grads[x.0].data[(b * hw + p) * c + ch] += g.data[b * c + ch] * inv;
                        }
                    }
                }
            })),
        )
    }

    // -----------------------------------------------------------------
    // loss
    // -----------------------------------------------------------------

    /// Mean softmax cross-entropy of `logits [n, classes]` against integer
    /// labels. Also reports the batch's correct count and loss sum.
    pub fn softmax_ce(&mut self, logits: Var, labels: &[i32]) -> (Var, EvalBits) {
        let lv = self.rc(logits);
        let (n, c) = (lv.shape[0], lv.shape[1]);
        debug_assert_eq!(labels.len(), n);
        let mut probs = vec![0.0f32; n * c];
        let mut loss_sum = 0.0f32;
        let mut correct = 0.0f32;
        for b in 0..n {
            let row = &lv.data[b * c..(b + 1) * c];
            let mx = row.iter().cloned().fold(f32::MIN, f32::max);
            let mut z = 0.0f32;
            for (j, &v) in row.iter().enumerate() {
                let e = (v - mx).exp();
                probs[b * c + j] = e;
                z += e;
            }
            let mut best = 0;
            for j in 0..c {
                probs[b * c + j] /= z;
                if probs[b * c + j] > probs[b * c + best] {
                    best = j;
                }
            }
            let lab = labels[b] as usize;
            loss_sum += -probs[b * c + lab].max(1e-12).ln();
            if best == lab {
                correct += 1.0;
            }
        }
        let val = Tensor::scalar(loss_sum / n as f32);
        let probs = Rc::new(probs);
        let labels: Vec<i32> = labels.to_vec();
        let out = self.push(
            val,
            Some(Box::new(move |g, grads| {
                let s = g.data[0] / n as f32;
                for b in 0..n {
                    let lab = labels[b] as usize;
                    for j in 0..c {
                        let one = if j == lab { 1.0 } else { 0.0 };
                        grads[logits.0].data[b * c + j] += s * (probs[b * c + j] - one);
                    }
                }
            })),
        );
        (out, EvalBits { correct, loss_sum })
    }

    // -----------------------------------------------------------------
    // θ machinery
    // -----------------------------------------------------------------

    /// Row-wise softmax of θ `[c, k]` with ineligible columns masked out
    /// (probability 0, no gradient) — a CU whose descriptor cannot run the
    /// layer's op never receives channels or gradient pressure.
    pub fn softmax_rows_masked(&mut self, theta: Var, mask: &[bool]) -> Var {
        let tv = self.rc(theta);
        let (c, k) = (tv.shape[0], tv.shape[1]);
        debug_assert_eq!(mask.len(), k);
        let mut p = vec![0.0f32; c * k];
        for r in 0..c {
            let row = &tv.data[r * k..(r + 1) * k];
            let mx = row
                .iter()
                .zip(mask)
                .filter(|&(_, &m)| m)
                .map(|(&v, _)| v)
                .fold(f32::MIN, f32::max);
            let mut z = 0.0f32;
            for j in 0..k {
                if mask[j] {
                    let e = (row[j] - mx).exp();
                    p[r * k + j] = e;
                    z += e;
                }
            }
            for j in 0..k {
                p[r * k + j] /= z;
            }
        }
        let val = Tensor::new(vec![c, k], p.clone());
        let p = Rc::new(p);
        let mask: Vec<bool> = mask.to_vec();
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                for r in 0..c {
                    let mut dot = 0.0f32;
                    for j in 0..k {
                        dot += g.data[r * k + j] * p[r * k + j];
                    }
                    for j in 0..k {
                        if mask[j] {
                            grads[theta.0].data[r * k + j] +=
                                p[r * k + j] * (g.data[r * k + j] - dot);
                        }
                    }
                }
            })),
        )
    }

    /// Eq. 5 effective weights for a K-CU platform:
    /// `W_eff[c] = Σ_k p[c,k] · Q_k(W[c])` where `Q_k` is the fake-quant
    /// of CU column k. Straight-through for W (`Σ_k p = 1` over the
    /// unmasked columns); `dθ_k = ⟨g, Q_k(W)⟩` per row.
    pub fn effective_weights(&mut self, w: Var, probs: Var, quants: &[QuantKind]) -> Var {
        let (wv, pv) = (self.rc(w), self.rc(probs));
        let (c, f) = (wv.shape[0], wv.shape[1]);
        let k = pv.shape[1];
        debug_assert_eq!(pv.shape[0], c);
        debug_assert_eq!(quants.len(), k);
        // quantized branches, one [c, f] tensor per CU column
        let mut qs: Vec<Vec<f32>> = Vec::with_capacity(k);
        for &q in quants {
            let mut out = vec![0.0f32; c * f];
            for r in 0..c {
                q.quant_row(&wv.data[r * f..(r + 1) * f], &mut out[r * f..(r + 1) * f]);
            }
            qs.push(out);
        }
        let mut y = vec![0.0f32; c * f];
        for r in 0..c {
            for (col, q) in qs.iter().enumerate() {
                let p = pv.data[r * k + col];
                if p == 0.0 {
                    continue;
                }
                for i in 0..f {
                    y[r * f + i] += p * q[r * f + i];
                }
            }
        }
        let val = Tensor::new(vec![c, f], y);
        let qs = Rc::new(qs);
        let saved_p = Rc::clone(&pv);
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                for r in 0..c {
                    // STE: each branch passes g through scaled by its
                    // probability; the probabilities sum to 1 over the
                    // unmasked columns.
                    let psum: f32 = (0..k).map(|col| saved_p.data[r * k + col]).sum();
                    for i in 0..f {
                        grads[w.0].data[r * f + i] += psum * g.data[r * f + i];
                    }
                    for (col, q) in qs.iter().enumerate() {
                        let mut dot = 0.0f32;
                        for i in 0..f {
                            dot += g.data[r * f + i] * q[r * f + i];
                        }
                        grads[probs.0].data[r * k + col] += dot;
                    }
                }
            })),
        )
    }

    /// Standalone per-row fake-quant with the straight-through estimator
    /// (identity gradient) — the fixed-precision layers' weight path.
    pub fn fake_quant_ste(&mut self, w: Var, kind: QuantKind) -> Var {
        let wv = self.rc(w);
        let (c, f) = (wv.shape[0], wv.shape[1]);
        let mut y = vec![0.0f32; c * f];
        for r in 0..c {
            kind.quant_row(&wv.data[r * f..(r + 1) * f], &mut y[r * f..(r + 1) * f]);
        }
        let val = Tensor::new(vec![c, f], y);
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                acc(grads, w.0, &g.data);
            })),
        )
    }

    /// Column sums of `[c, k]` → expected per-CU channel counts `[k]`.
    pub fn col_sum(&mut self, p: Var) -> Var {
        let pv = self.rc(p);
        let (c, k) = (pv.shape[0], pv.shape[1]);
        let mut y = vec![0.0f32; k];
        for r in 0..c {
            for j in 0..k {
                y[j] += pv.data[r * k + j];
            }
        }
        let val = Tensor::new(vec![k], y);
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                for r in 0..c {
                    for j in 0..k {
                        grads[p.0].data[r * k + j] += g.data[j];
                    }
                }
            })),
        )
    }

    /// Differentiable per-layer cost `[latency_cycles, energy_uj]` from
    /// expected channel counts `n [K]`.
    ///
    /// Each CU's cycles are the piecewise-linear interpolation of the
    /// integer `soc::analytical::cu_cycles` between `⌊n⌋` and `⌈n⌉` — the
    /// value is *exact* at integer counts, so the in-graph cost model and
    /// the deployment simulator can never disagree on a discretized
    /// mapping. Latency is the max (or sum, when `sequential`) of the CU
    /// stages; energy mirrors `analytical::execute` (active + idle share).
    /// Backward feeds each count its local interpolation slope, with the
    /// latency subgradient going to the argmax stage.
    pub fn layer_cost(
        &mut self,
        n: Var,
        layer: &Layer,
        cus: &'static [CuSpec],
        p_idle_mw: f64,
        freq_mhz: f64,
        sequential: bool,
    ) -> Var {
        let nv = self.rc(n);
        let k = cus.len();
        debug_assert_eq!(nv.elem_count(), k);
        let counts: Vec<f64> = nv.data.iter().map(|&v| v as f64).collect();
        let us_per_cycle = 1.0 / freq_mhz;
        let e = eval_layer_cost(cus, layer, &counts, p_idle_mw, us_per_cycle, sequential);
        let val = Tensor::new(vec![2], vec![e.latency as f32, e.energy_uj as f32]);
        let p_act: Vec<f64> = cus.iter().map(|c| c.p_act_mw).collect();
        let (slope, argmax) = (e.slopes, e.argmax);
        self.push(
            val,
            Some(Box::new(move |g, grads| {
                let (g_lat, g_en) = (g.data[0] as f64, g.data[1] as f64);
                for j in 0..k {
                    let on_lat = sequential || j == argmax;
                    let mut d_c = g_en * 1e-3 * p_act[j] * us_per_cycle;
                    if on_lat {
                        d_c += g_lat + g_en * 1e-3 * p_idle_mw * us_per_cycle;
                    }
                    grads[n.0].data[j] += (d_c * slope[j]) as f32;
                }
            })),
        )
    }
}

/// One evaluation of the differentiable cost forward — the *single*
/// implementation shared by [`Tape::layer_cost`] and the host-side
/// consumers (cost report, cost-scale normalization), so the report and
/// the in-graph objective cannot drift apart.
pub struct LayerCostEval {
    /// interpolated per-CU cycles at the (fractional) counts
    pub cycles: Vec<f64>,
    /// local interpolation slope per CU (d cycles / d count)
    pub slopes: Vec<f64>,
    /// max (or sum, when sequential) of the CU stages
    pub latency: f64,
    /// index of the latency-carrying stage (`usize::MAX` when sequential)
    pub argmax: usize,
    /// active + idle energy, matching `analytical::execute`
    pub energy_uj: f64,
}

/// Cost of one layer at fractional per-CU `counts` (see [`LayerCostEval`]).
pub fn eval_layer_cost(
    cus: &[CuSpec],
    layer: &Layer,
    counts: &[f64],
    p_idle_mw: f64,
    us_per_cycle: f64,
    sequential: bool,
) -> LayerCostEval {
    let k = cus.len();
    debug_assert_eq!(counts.len(), k);
    let mut cycles = vec![0.0f64; k];
    let mut slopes = vec![0.0f64; k];
    for (j, cu) in cus.iter().enumerate() {
        let (v, s) = interp_cu_cycles(cu, layer, counts[j]);
        cycles[j] = v;
        slopes[j] = s;
    }
    let (latency, argmax) = if sequential {
        (cycles.iter().sum::<f64>(), usize::MAX)
    } else {
        let mut best = 0;
        for j in 1..k {
            if cycles[j] > cycles[best] {
                best = j;
            }
        }
        (cycles[best], best)
    };
    let active_nj: f64 = cus
        .iter()
        .zip(&cycles)
        .map(|(cu, &c)| cu.p_act_mw * c * us_per_cycle)
        .sum();
    let energy_uj = (active_nj + p_idle_mw * latency * us_per_cycle) * 1e-3;
    LayerCostEval {
        cycles,
        slopes,
        latency,
        argmax,
        energy_uj,
    }
}

/// Interpolated analytical cycles of a *fractional* channel count, plus
/// the local slope. Exact at integer counts by construction.
pub fn interp_cu_cycles(cu: &CuSpec, layer: &Layer, x: f64) -> (f64, f64) {
    let x = x.max(0.0);
    let lo = x.floor() as usize;
    let frac = x - lo as f64;
    let c_lo = cu_cycles(cu, layer, lo) as f64;
    let c_hi = cu_cycles(cu, layer, lo + 1) as f64;
    let slope = c_hi - c_lo;
    (c_lo + frac * slope, slope)
}

// ---------------------------------------------------------------------------
// conv plumbing
// ---------------------------------------------------------------------------

/// 'SAME' output geometry: `(oh, ow, pad_begin)`.
fn same_geometry(h: usize, w: usize, k: usize, stride: usize) -> (usize, usize, usize) {
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let pad_total = ((oh - 1) * stride + k).saturating_sub(h);
    (oh, ow, pad_total / 2)
}

/// Patch matrix `[n·oh·ow, k·k·cin]` (column layout `(ky·k+kx)·cin + ci`).
fn im2col(x: &Tensor, k: usize, stride: usize) -> (Tensor, usize, usize) {
    let (n, h, w, cin) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow, pad) = same_geometry(h, w, k, stride);
    let f = k * k * cin;
    let mut cols = vec![0.0f32; n * oh * ow * f];
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((b * oh + oy) * ow + ox) * f;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((b * h + iy as usize) * w + ix as usize) * cin;
                        let dst = row + (ky * k + kx) * cin;
                        cols[dst..dst + cin].copy_from_slice(&x.data[src..src + cin]);
                    }
                }
            }
        }
    }
    (Tensor::new(vec![n * oh * ow, f], cols), oh, ow)
}

/// Scatter `dcols` back onto the input gradient (inverse of [`im2col`]).
#[allow(clippy::too_many_arguments)]
fn col2im(
    dcols: &[f32],
    dx: &mut [f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    k: usize,
    stride: usize,
    oh: usize,
    ow: usize,
) {
    let pad = {
        let pad_total = ((oh - 1) * stride + k).saturating_sub(h);
        pad_total / 2
    };
    let f = k * k * cin;
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((b * oh + oy) * ow + ox) * f;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let dst = ((b * h + iy as usize) * w + ix as usize) * cin;
                        let src = row + (ky * k + kx) * cin;
                        for ci in 0..cin {
                            dx[dst + ci] += dcols[src + ci];
                        }
                    }
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn dw_forward(
    x: &[f32],
    w: &[f32],
    y: &mut [f32],
    n: usize,
    h: usize,
    ww: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad: usize,
) {
    let (oh, ow, _) = same_geometry(h, ww, k, stride);
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let out = ((b * oh + oy) * ow + ox) * c;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= ww as isize {
                            continue;
                        }
                        let src = ((b * h + iy as usize) * ww + ix as usize) * c;
                        let wi = ky * k + kx;
                        for ch in 0..c {
                            y[out + ch] += x[src + ch] * w[ch * k * k + wi];
                        }
                    }
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn dw_backward(
    x: &[f32],
    w: &[f32],
    g: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
    n: usize,
    h: usize,
    ww: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad: usize,
) {
    let (oh, ow, _) = same_geometry(h, ww, k, stride);
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let out = ((b * oh + oy) * ow + ox) * c;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= ww as isize {
                            continue;
                        }
                        let src = ((b * h + iy as usize) * ww + ix as usize) * c;
                        let wi = ky * k + kx;
                        for ch in 0..c {
                            dx[src + ch] += g[out + ch] * w[ch * k * k + wi];
                            dw[ch * k * k + wi] += g[out + ch] * x[src + ch];
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backward_accumulates_shared_operands() {
        // y = (a + a) summed: dy/da = 2 everywhere
        let mut t = Tape::new();
        let a = t.leaf(Tensor::new(vec![3], vec![1.0, -2.0, 0.5]));
        let s = t.add(a, a);
        let loss = t.sum_all(s);
        let g = t.grad_of(loss, a);
        assert_eq!(g.data, vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn quantizers_match_reference_semantics() {
        let row = [0.5f32, -1.0, 0.02, 0.0];
        let mut q8 = [0.0f32; 4];
        QuantKind::Int8.quant_row(&row, &mut q8);
        let scale = 1.0 / 127.0;
        assert!((q8[1] + 1.0).abs() < 1e-6);
        assert!((q8[0] - (0.5 / scale).round() * scale).abs() < 1e-6);
        let mut qt = [0.0f32; 4];
        QuantKind::Ternary.quant_row(&row, &mut qt);
        // thr = 0.05; kept = {0.5, 1.0}; scale = 0.75
        assert_eq!(qt, [0.75, -0.75, 0.0, 0.0]);
        let mut qi = [0.0f32; 4];
        QuantKind::Identity.quant_row(&row, &mut qi);
        assert_eq!(qi, row);
    }

    #[test]
    fn interp_is_exact_at_integers() {
        let p = crate::soc::Platform::diana();
        let layer = Layer {
            name: "t".into(),
            ltype: crate::soc::LayerType::Conv,
            cin: 16,
            cout: 32,
            k: 3,
            ox: 8,
            oy: 8,
            stride: 1,
            searchable: true,
        };
        for cu in p.cus() {
            for n in [0usize, 1, 7, 32] {
                let (v, _) = interp_cu_cycles(cu, &layer, n as f64);
                assert_eq!(v, cu_cycles(cu, &layer, n) as f64, "{} n={n}", cu.name);
            }
        }
    }
}
