//! Reverse-mode autodiff over [`Tensor`]s: exactly the ops the ODiMO
//! supernets need, nothing more.
//!
//! A [`Tape`] records one forward pass as a flat list of nodes; each op
//! pushes its output value plus a backward closure that, given `dL/dout`,
//! accumulates into its operands' gradient slots. Because a node's output
//! can only be consumed by later-created nodes, one reverse sweep in
//! creation order is a valid topological backward pass.
//!
//! Since the planned-executor rework every *tensor buffer* the tape
//! touches — node values, auxiliary intermediates (im2col patch
//! matrices, BN x̂, softmax probabilities, quant branches), gradient
//! slots and backward scratch — comes from an [`Arena`] the tape owns,
//! and [`Tape::recycle`] returns all of them when the step is done, so
//! steady-state steps perform no tensor-buffer allocations (small
//! bookkeeping — node/closure boxes, per-channel stat vectors — still
//! heap-allocates, and is negligible next to the buffers). Gradient
//! slots are
//! `Option<Vec<f32>>`: an interior node's gradient is *moved out* when
//! its backward closure fires, and any later accumulate into the
//! consumed slot panics loudly instead of silently broadcasting into a
//! stale placeholder.
//!
//! Op inventory (mirroring `python/compile/{layers,kernels}`):
//! conv2d via im2col matmul, depthwise conv, per-row int8/ternary
//! fake-quant with the straight-through estimator, Eq. 5 effective
//! weights, batch-stat norm, ReLU, global average pool, bias
//! add, softmax cross-entropy, masked θ-softmax — plus [`Tape::layer_cost`],
//! the differentiable cost term: a piecewise-linear interpolation of
//! `soc::analytical::cu_cycles` that is *exact at integer channel counts*,
//! so the in-graph cost is pinned to the simulator the searches deploy on.
//! The prune / layerwise baseline search spaces add [`Tape::keep_counts`]
//! and [`Tape::broadcast_rows`] plus the zero-weight branch
//! [`QuantKind::Zero`].
//!
//! Convolution and matmul ops run on the blocked kernels of
//! [`super::tensor`], sharded over the lanes of the tape's
//! [`KernelScope`] (persistent pool slots, no nested spawns) —
//! bit-identical results for any lane count (each output element is
//! produced by exactly one lane in a fixed accumulation order).
//! 1×1/stride-1 convolutions skip im2col entirely: the patch matrix of
//! a pointwise conv *is* the input reshaped, so [`Tape::conv2d`] lowers
//! them straight onto `par_matmul_bt_into` (forward and backward) with
//! no copy — [`Tape::conv2d_im2col`] keeps the general path callable as
//! the bit-identity reference. Every op carries a feature-gated
//! [`super::profile`] probe so `--profile` runs report a per-op time
//! breakdown.
//!
//! When the caller supplies a step-scoped [`PackHandle`] (the
//! `*_with_pack` ops), the f32 GEMMs run on the packed-panel tier of
//! [`super::tensor`]: each weight matrix is repacked at most once per
//! step and the packs are shared across batch shards and the fwd/bwd
//! GEMMs that consume them, and the general conv *fuses* im2col into
//! packing — image patches stream directly into per-lane
//! [`FUSE_ROWS`]-row A-panels, so the full `[rows × k²·cin]` patch
//! matrix is never materialized in the forward (the backward
//! rematerializes it once for dW / col2im; eval never builds it).
//! Every packed tier is bit-identical to its unpacked kernel, so the
//! determinism matrix and the im2col/pointwise bit-identity pins hold
//! with packing on or off.

use std::rc::Rc;

use crate::soc::{analytical::cu_cycles, CuSpec, Layer};

use super::arena::Arena;
use super::pool::KernelScope;
use super::profile::{self, Op};
use super::tensor::{
    matmul_bt_packed_into, packing_enabled, par_matmul_at_into_packed, par_matmul_bt_into,
    par_matmul_bt_packed_into, par_matmul_into, par_matmul_packed_into, par_rows, PackHandle,
    Tensor,
};

/// Patch rows streamed per fused-conv A-panel block: big enough that
/// the packed bt kernel amortizes its per-panel setup, small enough
/// that a panel (`FUSE_ROWS · k²·cin` f32) stays cache-resident next to
/// the weight pack. `pub(crate)`: `plan` sizes the per-lane panel
/// scratch from it.
pub(crate) const FUSE_ROWS: usize = 8;

/// Raw mutable base pointer smuggled into SPMD lane closures for the
/// ops whose lane-disjoint writes are *strided* (channel sub-ranges,
/// paired output buffers) rather than contiguous row blocks — the same
/// soundness argument as `tensor::par_rows`: every element is written
/// by exactly one lane, and `KernelScope::run` does not return until
/// all lanes are done, so the resliced `&mut` views never alias.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);

unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Handle to one tape node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Var(usize);

impl Var {
    /// Index into the gradient slots returned by [`Tape::backward`].
    pub fn id(self) -> usize {
        self.0
    }
}

type BackFn = Box<dyn Fn(&[f32], &mut GradStore)>;

struct Node {
    val: Rc<Tensor>,
    back: Option<BackFn>,
}

/// One recorded forward pass.
pub struct Tape {
    nodes: Vec<Node>,
    /// auxiliary buffers saved for backward (im2col patches, BN x̂, CE
    /// probabilities, quant branches) — tracked so recycle can reclaim
    aux: Vec<Rc<Tensor>>,
    arena: Arena,
    kernel: KernelScope,
}

impl Default for Tape {
    fn default() -> Tape {
        Tape {
            nodes: Vec::new(),
            aux: Vec::new(),
            arena: Arena::new(),
            kernel: KernelScope::serial(),
        }
    }
}

/// Gradient slots + scratch arena threaded through the reverse sweep.
/// `None` marks a slot whose gradient was moved out by its own backward
/// closure — touching it again is a bug and panics.
pub struct GradStore {
    slots: Vec<Option<Vec<f32>>>,
    scratch: Arena,
}

impl GradStore {
    /// Accumulate `src` into slot `i`.
    fn acc(&mut self, i: usize, src: &[f32]) {
        let d = self.slots[i]
            .as_mut()
            .expect("accumulating into a consumed gradient slot");
        debug_assert_eq!(d.len(), src.len());
        for (dv, &sv) in d.iter_mut().zip(src) {
            *dv += sv;
        }
    }

    /// Mutable view of slot `i` (panics if the slot was consumed).
    fn grad_mut(&mut self, i: usize) -> &mut [f32] {
        self.slots[i]
            .as_mut()
            .expect("reading a consumed gradient slot")
    }

    /// Mutable views of two *distinct* slots at once (the
    /// effective-weights backward updates dW and dθ in one laned pass).
    fn grad_mut2(&mut self, i: usize, j: usize) -> (&mut [f32], &mut [f32]) {
        assert_ne!(i, j, "grad_mut2 needs two distinct slots");
        let (lo, hi) = (i.min(j), i.max(j));
        let (a, b) = self.slots.split_at_mut(hi);
        let x = a[lo].as_mut().expect("reading a consumed gradient slot");
        let y = b[0].as_mut().expect("reading a consumed gradient slot");
        if i < j {
            (x, y)
        } else {
            (y, x)
        }
    }

    fn take_raw(&mut self, len: usize) -> Vec<f32> {
        self.scratch.take_raw(len)
    }

    fn take_zeroed(&mut self, len: usize) -> Vec<f32> {
        self.scratch.take_zeroed(len)
    }

    fn give(&mut self, v: Vec<f32>) {
        self.scratch.give(v)
    }
}

/// Result of a full reverse sweep: one gradient buffer per node, with
/// interior slots consumed (`None`) by their own backward closures.
pub struct Gradients {
    slots: Vec<Option<Vec<f32>>>,
}

impl Gradients {
    /// Move out the gradient of `v` (typically a leaf). Panics if the
    /// slot was consumed by the sweep or already taken.
    pub fn take(&mut self, v: Var) -> Vec<f32> {
        self.slots[v.0]
            .take()
            .expect("gradient slot consumed or already taken")
    }

    /// Borrow the gradient of `v`. Panics if the slot was consumed.
    pub fn get(&self, v: Var) -> &[f32] {
        self.slots[v.0]
            .as_ref()
            .expect("gradient slot consumed or already taken")
    }
}

/// Per-output-channel weight quantizer of a CU (selected by the
/// descriptor's `quant` string). Semantics match the Pallas kernels in
/// `python/compile/kernels/fake_quant.py`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantKind {
    /// symmetric per-row int8: scale = max|w| / 127
    Int8,
    /// per-row ternary: threshold 0.05·max|w|, scale = mean |w| above it
    Ternary,
    /// no re-quantization (full-precision CU)
    Identity,
    /// all-zero branch (the "pruned" alternative — not a real CU; it
    /// contributes neither weights nor straight-through gradient)
    Zero,
}

impl QuantKind {
    pub fn from_quant_str(s: &str) -> QuantKind {
        match s {
            "int8" => QuantKind::Int8,
            "ternary" => QuantKind::Ternary,
            _ => QuantKind::Identity,
        }
    }

    /// Quantize one row in place into `out`.
    pub fn quant_row(self, row: &[f32], out: &mut [f32]) {
        match self {
            QuantKind::Identity => out.copy_from_slice(row),
            QuantKind::Zero => out.iter_mut().for_each(|o| *o = 0.0),
            QuantKind::Int8 => {
                let amax = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
                for (o, &v) in out.iter_mut().zip(row) {
                    *o = (v / scale).round().clamp(-127.0, 127.0) * scale;
                }
            }
            QuantKind::Ternary => {
                let amax = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let thr = 0.05 * amax;
                let mut kept = 0.0f32;
                let mut sum = 0.0f32;
                for &v in row {
                    if v.abs() > thr {
                        kept += 1.0;
                        sum += v.abs();
                    }
                }
                let scale = sum / kept.max(1.0);
                for (o, &v) in out.iter_mut().zip(row) {
                    *o = if v.abs() > thr {
                        v.signum() * scale
                    } else {
                        0.0
                    };
                }
            }
        }
    }
}

/// Non-differentiable extras an op reports alongside its output.
#[derive(Debug, Clone, Copy, Default)]
pub struct EvalBits {
    pub correct: f32,
    pub loss_sum: f32,
}

impl Tape {
    pub fn new() -> Tape {
        Tape::default()
    }

    /// A tape whose buffers come from (and recycle back into) `arena`.
    pub fn with_arena(arena: Arena) -> Tape {
        Tape {
            arena,
            ..Tape::default()
        }
    }

    /// Kernel-lane scope for the row-sharded conv/matmul kernels
    /// recorded from now on (results are bit-identical for any lane
    /// count). The scope is cloned into each op's backward closure, so
    /// it must stay valid for the tape's whole forward+backward life —
    /// i.e. the tape must be driven inside the pool task that owns the
    /// scope.
    pub fn set_kernel_scope(&mut self, scope: KernelScope) {
        self.kernel = scope;
    }

    fn alloc_raw(&mut self, len: usize) -> Vec<f32> {
        self.arena.take_raw(len)
    }

    fn alloc_zeroed(&mut self, len: usize) -> Vec<f32> {
        self.arena.take_zeroed(len)
    }

    fn push_rc(&mut self, val: Rc<Tensor>, back: Option<BackFn>) -> Var {
        self.nodes.push(Node { val, back });
        Var(self.nodes.len() - 1)
    }

    fn push(&mut self, val: Tensor, back: Option<BackFn>) -> Var {
        self.push_rc(Rc::new(val), back)
    }

    /// Register an auxiliary buffer so [`Tape::recycle`] can reclaim it.
    fn track_aux(&mut self, t: Tensor) -> Rc<Tensor> {
        let rc = Rc::new(t);
        self.aux.push(Rc::clone(&rc));
        rc
    }

    /// Record an input/parameter (gradient sink).
    pub fn leaf(&mut self, t: Tensor) -> Var {
        self.push(t, None)
    }

    /// Record an input/parameter by copying `src` into an arena buffer.
    pub fn leaf_copy(&mut self, shape: Vec<usize>, src: &[f32]) -> Var {
        let mut buf = self.alloc_raw(src.len());
        buf.copy_from_slice(src);
        self.leaf(Tensor::new(shape, buf))
    }

    pub fn val(&self, v: Var) -> &Tensor {
        &self.nodes[v.0].val
    }

    fn rc(&self, v: Var) -> Rc<Tensor> {
        Rc::clone(&self.nodes[v.0].val)
    }

    /// Core reverse sweep from scalar `loss`: zero-init one slot per
    /// node from `scratch`, seed d loss/d loss = 1, run the closures in
    /// reverse creation order. Interior slots are consumed (and their
    /// buffers recycled into `scratch`) as the sweep passes them.
    fn sweep(&self, loss: Var, mut scratch: Arena) -> (Vec<Option<Vec<f32>>>, Arena) {
        debug_assert_eq!(self.nodes[loss.0].val.elem_count(), 1);
        let slots: Vec<Option<Vec<f32>>> = self
            .nodes
            .iter()
            .map(|n| Some(scratch.take_zeroed(n.val.elem_count())))
            .collect();
        let mut store = GradStore { slots, scratch };
        store.grad_mut(loss.0)[0] = 1.0;
        for i in (0..=loss.0).rev() {
            if let Some(back) = &self.nodes[i].back {
                let g = store.slots[i]
                    .take()
                    .expect("gradient slot consumed before its own sweep step");
                back(&g, &mut store);
                store.give(g);
            }
        }
        (store.slots, store.scratch)
    }

    /// Full reverse sweep from scalar `loss`. Leaf slots keep their
    /// accumulated gradients; interior slots are consumed during the
    /// sweep (their buffers return to the tape's arena).
    pub fn backward(&mut self, loss: Var) -> Gradients {
        let scratch = std::mem::take(&mut self.arena);
        let (slots, scratch) = self.sweep(loss, scratch);
        self.arena = scratch;
        Gradients { slots }
    }

    /// Gradient of `loss` w.r.t. one var (convenience for tests; panics
    /// if `v` is an interior node whose slot the sweep consumed).
    pub fn grad_of(&self, loss: Var, v: Var) -> Tensor {
        let (mut slots, _) = self.sweep(loss, Arena::new());
        let buf = slots[v.0]
            .take()
            .expect("gradient slot consumed during the sweep (interior node)");
        Tensor::new(self.nodes[v.0].val.shape.clone(), buf)
    }

    /// Return leftover gradient buffers to the tape's arena.
    pub fn reclaim(&mut self, grads: Gradients) {
        for slot in grads.slots.into_iter().flatten() {
            self.arena.give(slot);
        }
    }

    /// Return a loose buffer (e.g. a taken gradient) to the arena.
    pub fn give(&mut self, buf: Vec<f32>) {
        self.arena.give(buf);
    }

    /// Tear the tape down and reclaim every buffer it allocated —
    /// node values and auxiliary intermediates — into the arena, which
    /// is returned for the next step's tape. Backward closures are
    /// dropped first so their `Rc` clones release the buffers.
    pub fn recycle(mut self) -> Arena {
        for n in self.nodes.iter_mut() {
            n.back = None;
        }
        let mut arena = self.arena;
        for n in self.nodes {
            if let Ok(t) = Rc::try_unwrap(n.val) {
                arena.give(t.data);
            }
        }
        for a in self.aux {
            if let Ok(t) = Rc::try_unwrap(a) {
                arena.give(t.data);
            }
        }
        arena
    }

    // -----------------------------------------------------------------
    // elementwise / shape ops
    // -----------------------------------------------------------------

    pub fn add(&mut self, a: Var, b: Var) -> Var {
        let (av, bv) = (self.rc(a), self.rc(b));
        debug_assert_eq!(av.shape, bv.shape);
        let _p = profile::time(Op::Elementwise);
        let mut data = self.alloc_raw(av.elem_count());
        for ((d, &x), &y) in data.iter_mut().zip(&av.data).zip(&bv.data) {
            *d = x + y;
        }
        let val = Tensor::new(av.shape.clone(), data);
        self.push(
            val,
            Some(Box::new(move |g, store| {
                let _p = profile::time(Op::Elementwise);
                store.acc(a.0, g);
                store.acc(b.0, g);
            })),
        )
    }

    pub fn mul(&mut self, a: Var, b: Var) -> Var {
        let (av, bv) = (self.rc(a), self.rc(b));
        debug_assert_eq!(av.shape, bv.shape);
        let mut data = self.alloc_raw(av.elem_count());
        for ((d, &x), &y) in data.iter_mut().zip(&av.data).zip(&bv.data) {
            *d = x * y;
        }
        let val = Tensor::new(av.shape.clone(), data);
        let (sa, sb) = (Rc::clone(&av), Rc::clone(&bv));
        self.push(
            val,
            Some(Box::new(move |g, store| {
                {
                    let da = store.grad_mut(a.0);
                    for ((d, &s), &y) in da.iter_mut().zip(g).zip(&sb.data) {
                        *d += s * y;
                    }
                }
                let db = store.grad_mut(b.0);
                for ((d, &s), &x) in db.iter_mut().zip(g).zip(&sa.data) {
                    *d += s * x;
                }
            })),
        )
    }

    pub fn scale(&mut self, a: Var, c: f32) -> Var {
        let av = self.rc(a);
        let mut data = self.alloc_raw(av.elem_count());
        for (d, &x) in data.iter_mut().zip(&av.data) {
            *d = x * c;
        }
        let val = Tensor::new(av.shape.clone(), data);
        self.push(
            val,
            Some(Box::new(move |g, store| {
                let da = store.grad_mut(a.0);
                for (d, &s) in da.iter_mut().zip(g) {
                    *d += s * c;
                }
            })),
        )
    }

    pub fn relu(&mut self, a: Var) -> Var {
        let av = self.rc(a);
        let _p = profile::time(Op::Elementwise);
        let mut data = self.alloc_raw(av.elem_count());
        for (d, &x) in data.iter_mut().zip(&av.data) {
            *d = x.max(0.0);
        }
        let val = Tensor::new(av.shape.clone(), data);
        let saved = Rc::clone(&av);
        self.push(
            val,
            Some(Box::new(move |g, store| {
                let _p = profile::time(Op::Elementwise);
                let da = store.grad_mut(a.0);
                for ((d, &s), &x) in da.iter_mut().zip(g).zip(&saved.data) {
                    if x > 0.0 {
                        *d += s;
                    }
                }
            })),
        )
    }

    /// Sum of every element → scalar (test/objective helper).
    pub fn sum_all(&mut self, a: Var) -> Var {
        let av = self.rc(a);
        let mut data = self.alloc_raw(1);
        data[0] = av.data.iter().sum();
        let val = Tensor::new(Vec::new(), data);
        self.push(
            val,
            Some(Box::new(move |g, store| {
                let s = g[0];
                for d in store.grad_mut(a.0).iter_mut() {
                    *d += s;
                }
            })),
        )
    }

    /// `w0·v[0] + w1·v[1]` of a 2-vector → scalar (cost-target selection).
    pub fn weighted_pair(&mut self, v: Var, w0: f32, w1: f32) -> Var {
        let vv = self.rc(v);
        debug_assert_eq!(vv.elem_count(), 2);
        let mut data = self.alloc_raw(1);
        data[0] = w0 * vv.data[0] + w1 * vv.data[1];
        let val = Tensor::new(Vec::new(), data);
        self.push(
            val,
            Some(Box::new(move |g, store| {
                let s = g[0];
                let dv = store.grad_mut(v.0);
                dv[0] += s * w0;
                dv[1] += s * w1;
            })),
        )
    }

    // -----------------------------------------------------------------
    // linear algebra
    // -----------------------------------------------------------------

    /// `A[m,k] · B[k,n]`.
    pub fn matmul(&mut self, a: Var, b: Var) -> Var {
        self.matmul_with_pack(a, b, None)
    }

    /// [`Tape::matmul`] with B's step-scoped weight pack (the FC layer):
    /// the forward runs on the mm layout, the backward dA on the bt
    /// layout — dB is an activation product and keeps the at-pack tier.
    /// Without a handle (or with packing toggled off) it falls back to
    /// the unpacked kernels; every tier pair is bit-identical, so the
    /// choice never reaches the numbers.
    pub fn matmul_with_pack(&mut self, a: Var, b: Var, pack: Option<&PackHandle>) -> Var {
        let ph = match pack {
            Some(ph) if packing_enabled() => Some(ph.clone()),
            _ => None,
        };
        let (av, bv) = (self.rc(a), self.rc(b));
        let (m, k) = (av.shape[0], av.shape[1]);
        let n = bv.shape[1];
        debug_assert_eq!(bv.shape[0], k);
        let sc = self.kernel.clone();
        let mut y = self.alloc_raw(m * n);
        // the Op::Matmul probes live inside the par_matmul_* lane
        // closures (lane-summed attribution — see `super::profile`)
        match &ph {
            Some(ph) => {
                let guard = ph.packed(&bv.data);
                par_matmul_packed_into(&av.data, guard.mm(), &mut y, m, k, n, &sc);
            }
            None => par_matmul_into(&av.data, &bv.data, &mut y, m, k, n, &sc),
        }
        let val = Tensor::new(vec![m, n], y);
        let (sa, sb) = (Rc::clone(&av), Rc::clone(&bv));
        self.push(
            val,
            Some(Box::new(move |g, store| {
                // dA = g · Bᵀ ; dB = Aᵀ · g
                let mut da = store.take_raw(m * k);
                match &ph {
                    Some(ph) => {
                        let guard = ph.packed(&sb.data);
                        par_matmul_bt_packed_into(g, guard.bt(), &mut da, m, n, k, &sc);
                    }
                    None => par_matmul_bt_into(g, &sb.data, &mut da, m, n, k, &sc),
                }
                store.acc(a.0, &da);
                store.give(da);
                let mut db = store.take_raw(k * n);
                matmul_at_via_pack(&sa.data, g, &mut db, m, k, n, &sc, store);
                store.acc(b.0, &db);
                store.give(db);
            })),
        )
    }

    /// Broadcast bias add over the trailing channel axis.
    pub fn add_bias(&mut self, x: Var, b: Var) -> Var {
        let (xv, bv) = (self.rc(x), self.rc(b));
        let c = *xv.shape.last().unwrap();
        debug_assert_eq!(bv.elem_count(), c);
        let _p = profile::time(Op::Elementwise);
        let mut data = self.alloc_raw(xv.elem_count());
        // row walk instead of `i % c` indexing: same element order,
        // vectorizable inner loop
        for (drow, xrow) in data.chunks_exact_mut(c).zip(xv.data.chunks_exact(c)) {
            for ((d, &v), &bias) in drow.iter_mut().zip(xrow).zip(&bv.data) {
                *d = v + bias;
            }
        }
        let val = Tensor::new(xv.shape.clone(), data);
        self.push(
            val,
            Some(Box::new(move |g, store| {
                let _p = profile::time(Op::Elementwise);
                store.acc(x.0, g);
                let db = store.grad_mut(b.0);
                for grow in g.chunks_exact(c) {
                    for (dbv, &s) in db.iter_mut().zip(grow) {
                        *dbv += s;
                    }
                }
            })),
        )
    }

    // -----------------------------------------------------------------
    // convolutions
    // -----------------------------------------------------------------

    /// 'SAME' NHWC convolution with flattened weights `w: [cout, k·k·cin]`
    /// (row layout `(ky·k + kx)·cin + ci`, matching the AOT flattening).
    ///
    /// 1×1/stride-1 (pointwise) convolutions take the no-copy fast path
    /// — the patch matrix would be the input verbatim, so the matmuls
    /// run on `x` directly (bit-identical to the im2col lowering, pinned
    /// by `tests/native_exec.rs`); everything else lowers through
    /// [`Tape::conv2d_im2col`].
    pub fn conv2d(&mut self, x: Var, w: Var, k: usize, stride: usize) -> Var {
        self.conv2d_with_pack(x, w, k, stride, None)
    }

    /// [`Tape::conv2d`] with the layer's step-scoped weight-pack handle.
    /// With a handle (and packing on) the pointwise fast path runs its
    /// GEMMs on the cached packs and the general path takes the
    /// fused-im2col lowering; without one it falls back to the unpacked
    /// fast path / [`Tape::conv2d_im2col`] reference. All four lowerings
    /// are bit-identical.
    pub fn conv2d_with_pack(
        &mut self,
        x: Var,
        w: Var,
        k: usize,
        stride: usize,
        pack: Option<&PackHandle>,
    ) -> Var {
        if k == 1 && stride == 1 {
            match pack {
                Some(ph) if packing_enabled() => self.conv2d_pointwise_packed(x, w, ph),
                _ => self.conv2d_pointwise(x, w),
            }
        } else {
            match pack {
                Some(ph) if packing_enabled() => self.conv2d_fused(x, w, k, stride, ph),
                _ => self.conv2d_im2col(x, w, k, stride),
            }
        }
    }

    /// The general conv lowering: im2col + matmul, like the Darkside
    /// cluster executes it. Public as the reference path the 1×1 fast
    /// path is pinned against.
    pub fn conv2d_im2col(&mut self, x: Var, w: Var, k: usize, stride: usize) -> Var {
        let (xv, wv) = (self.rc(x), self.rc(w));
        let (n, h, ww, cin) = (xv.shape[0], xv.shape[1], xv.shape[2], xv.shape[3]);
        let cout = wv.shape[0];
        let f = k * k * cin;
        debug_assert_eq!(wv.shape[1], f);
        let (oh, ow, _) = same_geometry(h, ww, k, stride);
        let rows = n * oh * ow;
        let sc = self.kernel.clone();
        let mut cols_buf = self.alloc_zeroed(rows * f);
        im2col_into(&xv, k, stride, &mut cols_buf, &sc);
        let cols = self.track_aux(Tensor::new(vec![rows, f], cols_buf));
        let mut y = self.alloc_raw(rows * cout);
        par_matmul_bt_into(&cols.data, &wv.data, &mut y, rows, f, cout, &sc);
        let val = Tensor::new(vec![n, oh, ow, cout], y);
        let saved_w = Rc::clone(&wv);
        self.push(
            val,
            Some(Box::new(move |g, store| {
                // dW[cout,F] = gᵀ[cout,rows] · cols[rows,F]
                let mut dw = store.take_raw(cout * f);
                matmul_at_via_pack(g, &cols.data, &mut dw, rows, cout, f, &sc, store);
                store.acc(w.0, &dw);
                store.give(dw);
                // dCols = g[rows,cout] · W[cout,F], scattered back to x
                let mut dcols = store.take_raw(rows * f);
                par_matmul_into(g, &saved_w.data, &mut dcols, rows, cout, f, &sc);
                col2im(
                    &dcols,
                    store.grad_mut(x.0),
                    n,
                    h,
                    ww,
                    cin,
                    k,
                    stride,
                    oh,
                    ow,
                    &sc,
                );
                store.give(dcols);
            })),
        )
    }

    /// 1×1/stride-1 fast path: the im2col patch matrix of a pointwise
    /// conv is exactly `x` reshaped to `[n·h·w, cin]`, so the forward is
    /// one `A·Bᵀ` on the input itself and the backward skips the col2im
    /// scatter (`dx` accumulates straight from `g·W`). No patch buffer
    /// is ever materialized — pure copy overhead removed for the layers
    /// that dominate the mbv1 supernet.
    fn conv2d_pointwise(&mut self, x: Var, w: Var) -> Var {
        let (xv, wv) = (self.rc(x), self.rc(w));
        let (n, h, ww, cin) = (xv.shape[0], xv.shape[1], xv.shape[2], xv.shape[3]);
        let cout = wv.shape[0];
        debug_assert_eq!(wv.shape[1], cin);
        let rows = n * h * ww;
        let sc = self.kernel.clone();
        let mut y = self.alloc_raw(rows * cout);
        par_matmul_bt_into(&xv.data, &wv.data, &mut y, rows, cin, cout, &sc);
        let val = Tensor::new(vec![n, h, ww, cout], y);
        let (saved_x, saved_w) = (Rc::clone(&xv), Rc::clone(&wv));
        self.push(
            val,
            Some(Box::new(move |g, store| {
                let mut dw = store.take_raw(cout * cin);
                // dW[cout,cin] = gᵀ[cout,rows] · x[rows,cin]
                matmul_at_via_pack(g, &saved_x.data, &mut dw, rows, cout, cin, &sc, store);
                store.acc(w.0, &dw);
                store.give(dw);
                let mut dx = store.take_raw(rows * cin);
                // dX[rows,cin] = g[rows,cout] · W[cout,cin]
                par_matmul_into(g, &saved_w.data, &mut dx, rows, cout, cin, &sc);
                store.acc(x.0, &dx);
                store.give(dx);
            })),
        )
    }

    /// [`Tape::conv2d_pointwise`] on the step-cached weight packs: the
    /// forward runs from the bt layout, the backward dX from the mm
    /// layout (dW is an activation product and keeps the at-pack tier).
    /// Each packed tier is bit-identical to its unpacked kernel, so the
    /// pointwise-vs-im2col pin covers this path too.
    fn conv2d_pointwise_packed(&mut self, x: Var, w: Var, ph: &PackHandle) -> Var {
        let (xv, wv) = (self.rc(x), self.rc(w));
        let (n, h, ww, cin) = (xv.shape[0], xv.shape[1], xv.shape[2], xv.shape[3]);
        let cout = wv.shape[0];
        debug_assert_eq!(wv.shape[1], cin);
        let rows = n * h * ww;
        let sc = self.kernel.clone();
        let mut y = self.alloc_raw(rows * cout);
        {
            let guard = ph.packed(&wv.data);
            par_matmul_bt_packed_into(&xv.data, guard.bt(), &mut y, rows, cin, cout, &sc);
        }
        let val = Tensor::new(vec![n, h, ww, cout], y);
        let (saved_x, saved_w) = (Rc::clone(&xv), Rc::clone(&wv));
        let ph = ph.clone();
        self.push(
            val,
            Some(Box::new(move |g, store| {
                let mut dw = store.take_raw(cout * cin);
                // dW[cout,cin] = gᵀ[cout,rows] · x[rows,cin]
                matmul_at_via_pack(g, &saved_x.data, &mut dw, rows, cout, cin, &sc, store);
                store.acc(w.0, &dw);
                store.give(dw);
                let mut dx = store.take_raw(rows * cin);
                // dX[rows,cin] = g[rows,cout] · W[cout,cin]
                {
                    let guard = ph.packed(&saved_w.data);
                    par_matmul_packed_into(g, guard.mm(), &mut dx, rows, cout, cin, &sc);
                }
                store.acc(x.0, &dx);
                store.give(dx);
            })),
        )
    }

    /// The fused general-conv lowering: image patches stream directly
    /// into per-lane [`FUSE_ROWS`]-row A-panels ([`fill_patch_rows`],
    /// counted in the `Op::Pack` bucket) and each panel multiplies the
    /// step-cached bt weight pack while still cache-hot — the full
    /// `[rows × f]` im2col matrix is never materialized in the forward.
    /// The backward rematerializes it once (for dW and the col2im
    /// scatter); eval never builds it at all. Panel rows are
    /// content-identical to [`Tape::conv2d_im2col`]'s patch rows, the
    /// lane split is `par_rows`' and the packed bt kernel is
    /// bit-identical to the unpacked one, so this path is bit-identical
    /// to the im2col reference (pinned by `tests/native_exec.rs`).
    fn conv2d_fused(&mut self, x: Var, w: Var, k: usize, stride: usize, ph: &PackHandle) -> Var {
        let (xv, wv) = (self.rc(x), self.rc(w));
        let (n, h, ww, cin) = (xv.shape[0], xv.shape[1], xv.shape[2], xv.shape[3]);
        let cout = wv.shape[0];
        let f = k * k * cin;
        debug_assert_eq!(wv.shape[1], f);
        let (oh, ow, pad) = same_geometry(h, ww, k, stride);
        let rows = n * oh * ow;
        let sc = self.kernel.clone();
        let t = sc.lanes().min(rows).max(1);
        let mut panels = self.alloc_raw(t * FUSE_ROWS * f);
        let mut y = self.alloc_raw(rows * cout);
        {
            let guard = ph.packed(&wv.data);
            let pbt = guard.bt();
            let xdata: &[f32] = &xv.data;
            if t <= 1 {
                conv_rows_fused(
                    xdata,
                    pbt,
                    &mut y,
                    &mut panels[..FUSE_ROWS * f],
                    (h, ww, cin, k, stride, oh, ow, pad),
                    cout,
                    0,
                    rows,
                );
            } else {
                // disjoint y row ranges / per-lane panels: same
                // soundness argument as `tensor::par_rows`
                let ybase = SendPtr(y.as_mut_ptr());
                let pbase = SendPtr(panels.as_mut_ptr());
                sc.run(&|lane| {
                    if lane >= t {
                        return;
                    }
                    let r0 = lane * rows / t;
                    let r1 = (lane + 1) * rows / t;
                    if r0 == r1 {
                        return;
                    }
                    let (yc, panel) = unsafe {
                        (
                            std::slice::from_raw_parts_mut(
                                ybase.0.add(r0 * cout),
                                (r1 - r0) * cout,
                            ),
                            std::slice::from_raw_parts_mut(
                                pbase.0.add(lane * FUSE_ROWS * f),
                                FUSE_ROWS * f,
                            ),
                        )
                    };
                    conv_rows_fused(
                        xdata,
                        pbt,
                        yc,
                        panel,
                        (h, ww, cin, k, stride, oh, ow, pad),
                        cout,
                        r0,
                        r1,
                    );
                });
            }
        }
        self.arena.give(panels);
        let val = Tensor::new(vec![n, oh, ow, cout], y);
        let (saved_x, saved_w) = (Rc::clone(&xv), Rc::clone(&wv));
        let ph = ph.clone();
        self.push(
            val,
            Some(Box::new(move |g, store| {
                // rematerialize the patch matrix once for dW …
                let mut cols = store.take_zeroed(rows * f);
                im2col_into(&saved_x, k, stride, &mut cols, &sc);
                let mut dw = store.take_raw(cout * f);
                matmul_at_via_pack(g, &cols, &mut dw, rows, cout, f, &sc, store);
                store.acc(w.0, &dw);
                store.give(dw);
                // … give it back before taking dcols, so both phases
                // reuse one rows·f buffer
                store.give(cols);
                let mut dcols = store.take_raw(rows * f);
                {
                    let guard = ph.packed(&saved_w.data);
                    par_matmul_packed_into(g, guard.mm(), &mut dcols, rows, cout, f, &sc);
                }
                col2im(
                    &dcols,
                    store.grad_mut(x.0),
                    n,
                    h,
                    ww,
                    cin,
                    k,
                    stride,
                    oh,
                    ow,
                    &sc,
                );
                store.give(dcols);
            })),
        )
    }

    /// 'SAME' depthwise convolution, weights `w: [c, k·k]`.
    ///
    /// The inner loops run over a *transposed* weight panel `wt[k·k, c]`
    /// (built once per call, kept as an aux for backward) so the
    /// per-channel lane walks three contiguous arrays — the same
    /// contiguous-panel structure the blocked matmuls use — instead of
    /// striding `w` by `k·k`; the forward additionally shards output
    /// rows across the kernel lanes. Per-element tap order is unchanged,
    /// so results stay bit-identical to the strided loop at any lane
    /// count.
    pub fn dw_conv2d(&mut self, x: Var, w: Var, k: usize, stride: usize) -> Var {
        let (xv, wv) = (self.rc(x), self.rc(w));
        let (n, h, ww, c) = (xv.shape[0], xv.shape[1], xv.shape[2], xv.shape[3]);
        debug_assert_eq!(wv.shape, vec![c, k * k]);
        let (oh, ow, pad) = same_geometry(h, ww, k, stride);
        let sc = self.kernel.clone();
        // transposed panel wt[wi, ch] = w[ch, wi]
        let mut wt_buf = self.alloc_raw(c * k * k);
        for ch in 0..c {
            for wi in 0..k * k {
                wt_buf[wi * c + ch] = wv.data[ch * k * k + wi];
            }
        }
        let wt = self.track_aux(Tensor::new(vec![k * k, c], wt_buf));
        let mut y = self.alloc_zeroed(n * oh * ow * c);
        dw_forward(&xv.data, &wt.data, &mut y, n, h, ww, c, k, stride, pad, &sc);
        let val = Tensor::new(vec![n, oh, ow, c], y);
        let sx = Rc::clone(&xv);
        self.push(
            val,
            Some(Box::new(move |g, store| {
                // accumulate dW in the transposed layout (contiguous
                // channel lanes), then fold back to the [c, k·k] slot
                let mut dwt = store.take_zeroed(c * k * k);
                let mut dx = store.take_zeroed(n * h * ww * c);
                dw_backward(
                    &sx.data, &wt.data, g, &mut dx, &mut dwt, n, h, ww, c, k, stride, pad, &sc,
                );
                // fold + accumulate remnant stays serial; keep it inside
                // the DwConv bucket so the op's cost is fully attributed
                let _p = profile::time(Op::DwConv);
                let mut dw = store.take_raw(c * k * k);
                for ch in 0..c {
                    for wi in 0..k * k {
                        dw[ch * k * k + wi] = dwt[wi * c + ch];
                    }
                }
                store.acc(x.0, &dx);
                store.acc(w.0, &dw);
                store.give(dx);
                store.give(dw);
                store.give(dwt);
            })),
        )
    }

    // -----------------------------------------------------------------
    // normalization / pooling
    // -----------------------------------------------------------------

    /// Batch-stat normalization over all leading axes (training mode).
    /// Returns `(y, batch_mean, batch_var)`; the running-stat update
    /// happens outside the tape.
    pub fn batch_norm_train(
        &mut self,
        x: Var,
        scale: Var,
        bias: Var,
    ) -> (Var, Vec<f32>, Vec<f32>) {
        let (xv, sv, bv) = (self.rc(x), self.rc(scale), self.rc(bias));
        let c = *xv.shape.last().unwrap();
        let m = xv.elem_count() / c;
        let sc = self.kernel.clone();
        const EPS: f32 = 1e-5;
        // The cross-row per-channel reductions (mean / var, and sum_dy /
        // sum_dy·x̂ in the backward) stay serial by design: sharding rows
        // across lanes would change the accumulation order with lane
        // count and break the bit-identity contract. Row walks (chunks
        // of c) instead of `i % c` indexing: the per-channel accumulation
        // order over rows is unchanged, but the inner loops run over
        // contiguous lanes and vectorize.
        let mut mean = vec![0.0f32; c];
        let mut var = vec![0.0f32; c];
        let inv: Vec<f32> = {
            let _p = profile::time(Op::BatchNorm);
            for xrow in xv.data.chunks_exact(c) {
                for (mv, &v) in mean.iter_mut().zip(xrow) {
                    *mv += v;
                }
            }
            for v in mean.iter_mut() {
                *v /= m as f32;
            }
            for xrow in xv.data.chunks_exact(c) {
                for ((vv, &v), &mu) in var.iter_mut().zip(xrow).zip(&mean) {
                    let d = v - mu;
                    *vv += d * d;
                }
            }
            for v in var.iter_mut() {
                *v /= m as f32;
            }
            var.iter().map(|&v| 1.0 / (v + EPS).sqrt()).collect()
        };
        let mut xhat_buf = self.alloc_raw(xv.elem_count());
        let mut y = self.alloc_raw(xv.elem_count());
        {
            // normalize + affine are pure row maps: shard rows across
            // lanes; each row is written by exactly one lane, so any
            // lane count produces identical bits
            let y_base = SendPtr(y.as_mut_ptr());
            let xs: &[f32] = &xv.data;
            let (sd, bd): (&[f32], &[f32]) = (&sv.data, &bv.data);
            let (mean_r, inv_r) = (&mean, &inv);
            par_rows(&mut xhat_buf, m, c, &sc, |r0, r1, xh_chunk| {
                let _p = profile::time(Op::BatchNorm);
                for (t, r) in (r0..r1).enumerate() {
                    let xhrow = &mut xh_chunk[t * c..(t + 1) * c];
                    sub_mul_row(xhrow, &xs[r * c..(r + 1) * c], mean_r, inv_r);
                    let yrow = unsafe { std::slice::from_raw_parts_mut(y_base.0.add(r * c), c) };
                    affine_row(yrow, xhrow, sd, bd);
                }
            });
        }
        let xhat = self.track_aux(Tensor::new(xv.shape.clone(), xhat_buf));
        let val = Tensor::new(xv.shape.clone(), y);
        let inv_s = inv;
        let saved_scale = Rc::clone(&sv);
        let out = self.push(
            val,
            Some(Box::new(move |g, store| {
                let mut sum_dy = store.take_zeroed(c);
                let mut sum_dy_xhat = store.take_zeroed(c);
                {
                    // cross-row reduction: serial (see the forward's note)
                    let _p = profile::time(Op::BatchNorm);
                    for (grow, xhrow) in g.chunks_exact(c).zip(xhat.data.chunks_exact(c)) {
                        for (((sd, sdx), &s), &xh) in sum_dy
                            .iter_mut()
                            .zip(sum_dy_xhat.iter_mut())
                            .zip(grow)
                            .zip(xhrow)
                        {
                            *sd += s;
                            *sdx += s * xh;
                        }
                    }
                }
                {
                    // dx is a pure row map once the sums exist: laned
                    let dx_slot = store.grad_mut(x.0);
                    let mf = m as f32;
                    let (xh, sdv): (&[f32], &[f32]) = (&xhat.data, &saved_scale.data);
                    let (sdy, sdyx, invs) = (&sum_dy[..], &sum_dy_xhat[..], &inv_s[..]);
                    par_rows(dx_slot, m, c, &sc, |r0, r1, chunk| {
                        let _p = profile::time(Op::BatchNorm);
                        for (t, r) in (r0..r1).enumerate() {
                            let grow = &g[r * c..(r + 1) * c];
                            let xhrow = &xh[r * c..(r + 1) * c];
                            let dxrow = &mut chunk[t * c..(t + 1) * c];
                            for ch in 0..c {
                                let dx = sdv[ch] * invs[ch] / mf
                                    * (mf * grow[ch] - sdy[ch] - xhrow[ch] * sdyx[ch]);
                                dxrow[ch] += dx;
                            }
                        }
                    });
                }
                store.acc(scale.0, &sum_dy_xhat);
                store.acc(bias.0, &sum_dy);
                store.give(sum_dy);
                store.give(sum_dy_xhat);
            })),
        );
        (out, mean, var)
    }

    /// Inference-mode normalization: per-channel affine with *constant*
    /// coefficients folded from the running stats.
    pub fn channel_affine(&mut self, x: Var, a: Vec<f32>, b: Vec<f32>) -> Var {
        let xv = self.rc(x);
        let c = *xv.shape.last().unwrap();
        debug_assert_eq!(a.len(), c);
        let _p = profile::time(Op::BatchNorm);
        let mut data = self.alloc_raw(xv.elem_count());
        for (drow, xrow) in data.chunks_exact_mut(c).zip(xv.data.chunks_exact(c)) {
            affine_row(drow, xrow, &a, &b);
        }
        let val = Tensor::new(xv.shape.clone(), data);
        self.push(
            val,
            Some(Box::new(move |g, store| {
                let _p = profile::time(Op::BatchNorm);
                let dx = store.grad_mut(x.0);
                for (dxrow, grow) in dx.chunks_exact_mut(c).zip(g.chunks_exact(c)) {
                    fma_row(dxrow, grow, &a);
                }
            })),
        )
    }

    /// `[n,h,w,c] → [n,c]` mean over the spatial axes.
    pub fn global_avg_pool(&mut self, x: Var) -> Var {
        let xv = self.rc(x);
        let (n, h, w, c) = (xv.shape[0], xv.shape[1], xv.shape[2], xv.shape[3]);
        let hw = h * w;
        let _p = profile::time(Op::Elementwise);
        let mut y = self.alloc_zeroed(n * c);
        for b in 0..n {
            for p in 0..hw {
                for ch in 0..c {
                    y[b * c + ch] += xv.data[(b * hw + p) * c + ch];
                }
            }
        }
        for v in y.iter_mut() {
            *v /= hw as f32;
        }
        let val = Tensor::new(vec![n, c], y);
        self.push(
            val,
            Some(Box::new(move |g, store| {
                let _p = profile::time(Op::Elementwise);
                let inv = 1.0 / hw as f32;
                let dx = store.grad_mut(x.0);
                for b in 0..n {
                    for p in 0..hw {
                        for ch in 0..c {
                            dx[(b * hw + p) * c + ch] += g[b * c + ch] * inv;
                        }
                    }
                }
            })),
        )
    }

    // -----------------------------------------------------------------
    // loss
    // -----------------------------------------------------------------

    /// Mean softmax cross-entropy of `logits [n, classes]` against integer
    /// labels. Also reports the batch's correct count and loss sum.
    pub fn softmax_ce(&mut self, logits: Var, labels: &[i32]) -> (Var, EvalBits) {
        let lv = self.rc(logits);
        let (n, c) = (lv.shape[0], lv.shape[1]);
        debug_assert_eq!(labels.len(), n);
        let sc = self.kernel.clone();
        let mut probs_buf = self.alloc_raw(n * c);
        {
            // softmax is a pure row map (max / exp / normalize all stay
            // within one row), so rows shard across lanes bit-identically
            let ls: &[f32] = &lv.data;
            par_rows(&mut probs_buf, n, c, &sc, |b0, b1, chunk| {
                let _p = profile::time(Op::Loss);
                for (t, b) in (b0..b1).enumerate() {
                    let row = &ls[b * c..(b + 1) * c];
                    let prow = &mut chunk[t * c..(t + 1) * c];
                    let mx = row.iter().cloned().fold(f32::MIN, f32::max);
                    let mut z = 0.0f32;
                    for (p, &v) in prow.iter_mut().zip(row) {
                        let e = (v - mx).exp();
                        *p = e;
                        z += e;
                    }
                    for p in prow.iter_mut() {
                        *p /= z;
                    }
                }
            });
        }
        // loss / accuracy reduction is cross-row: serial, in batch order,
        // so the scalar bits never depend on the lane count
        let mut loss_sum = 0.0f32;
        let mut correct = 0.0f32;
        {
            let _p = profile::time(Op::Loss);
            for b in 0..n {
                let prow = &probs_buf[b * c..(b + 1) * c];
                let mut best = 0;
                for j in 1..c {
                    if prow[j] > prow[best] {
                        best = j;
                    }
                }
                let lab = labels[b] as usize;
                loss_sum += -prow[lab].max(1e-12).ln();
                if best == lab {
                    correct += 1.0;
                }
            }
        }
        let mut data = self.alloc_raw(1);
        data[0] = loss_sum / n as f32;
        let val = Tensor::new(Vec::new(), data);
        let probs = self.track_aux(Tensor::new(vec![n, c], probs_buf));
        let labels: Vec<i32> = labels.to_vec();
        let out = self.push(
            val,
            Some(Box::new(move |g, store| {
                let s = g[0] / n as f32;
                let dl = store.grad_mut(logits.0);
                let ps: &[f32] = &probs.data;
                let labs: &[i32] = &labels;
                par_rows(dl, n, c, &sc, |b0, b1, chunk| {
                    let _p = profile::time(Op::Loss);
                    for (t, b) in (b0..b1).enumerate() {
                        let lab = labs[b] as usize;
                        let prow = &ps[b * c..(b + 1) * c];
                        let drow = &mut chunk[t * c..(t + 1) * c];
                        for (j, (d, &p)) in drow.iter_mut().zip(prow).enumerate() {
                            let one = if j == lab { 1.0 } else { 0.0 };
                            *d += s * (p - one);
                        }
                    }
                });
            })),
        );
        (out, EvalBits { correct, loss_sum })
    }

    // -----------------------------------------------------------------
    // θ machinery
    // -----------------------------------------------------------------

    /// Row-wise softmax of θ `[c, k]` with ineligible columns masked out
    /// (probability 0, no gradient) — a CU whose descriptor cannot run the
    /// layer's op never receives channels or gradient pressure.
    pub fn softmax_rows_masked(&mut self, theta: Var, mask: &[bool]) -> Var {
        let tv = self.rc(theta);
        let (c, k) = (tv.shape[0], tv.shape[1]);
        debug_assert_eq!(mask.len(), k);
        let _p = profile::time(Op::Theta);
        let mut p = self.alloc_zeroed(c * k);
        for r in 0..c {
            let row = &tv.data[r * k..(r + 1) * k];
            let mx = row
                .iter()
                .zip(mask)
                .filter(|&(_, &m)| m)
                .map(|(&v, _)| v)
                .fold(f32::MIN, f32::max);
            let mut z = 0.0f32;
            for j in 0..k {
                if mask[j] {
                    let e = (row[j] - mx).exp();
                    p[r * k + j] = e;
                    z += e;
                }
            }
            for j in 0..k {
                p[r * k + j] /= z;
            }
        }
        let val = Rc::new(Tensor::new(vec![c, k], p));
        let saved_p = Rc::clone(&val);
        let mask: Vec<bool> = mask.to_vec();
        self.push_rc(
            val,
            Some(Box::new(move |g, store| {
                let _p = profile::time(Op::Theta);
                let dth = store.grad_mut(theta.0);
                for r in 0..c {
                    let mut dot = 0.0f32;
                    for j in 0..k {
                        dot += g[r * k + j] * saved_p.data[r * k + j];
                    }
                    for j in 0..k {
                        if mask[j] {
                            dth[r * k + j] += saved_p.data[r * k + j] * (g[r * k + j] - dot);
                        }
                    }
                }
            })),
        )
    }

    /// Tile a `[1, k]` probability row to `[rows, k]` — the layerwise
    /// search space shares one gate across every channel of a layer.
    pub fn broadcast_rows(&mut self, p: Var, rows: usize) -> Var {
        let pv = self.rc(p);
        debug_assert_eq!(pv.shape[0], 1);
        let k = pv.shape[1];
        let _p = profile::time(Op::Theta);
        let mut data = self.alloc_raw(rows * k);
        for r in 0..rows {
            data[r * k..(r + 1) * k].copy_from_slice(&pv.data);
        }
        let val = Tensor::new(vec![rows, k], data);
        self.push(
            val,
            Some(Box::new(move |g, store| {
                let dp = store.grad_mut(p.0);
                for r in 0..rows {
                    for j in 0..k {
                        dp[j] += g[r * k + j];
                    }
                }
            })),
        )
    }

    /// Eq. 5 effective weights for a K-CU platform:
    /// `W_eff[c] = Σ_k p[c,k] · Q_k(W[c])` where `Q_k` is the fake-quant
    /// of CU column k. Straight-through for W, scaled by the total
    /// probability mass on *weight-carrying* branches — [`QuantKind::Zero`]
    /// branches (the pruned alternative) pass no gradient, matching the
    /// reference `th[:, 0:1] * ste_int8(W)` semantics; `dθ_k = ⟨g, Q_k(W)⟩`
    /// per row.
    pub fn effective_weights(&mut self, w: Var, probs: Var, quants: &[QuantKind]) -> Var {
        let (wv, pv) = (self.rc(w), self.rc(probs));
        let (c, f) = (wv.shape[0], wv.shape[1]);
        let k = pv.shape[1];
        debug_assert_eq!(pv.shape[0], c);
        debug_assert_eq!(quants.len(), k);
        let sc = self.kernel.clone();
        // quantized branches, one [c, f] tensor per CU column; quant is
        // per-row, so each branch fill shards rows across lanes
        let mut qs: Vec<Rc<Tensor>> = Vec::with_capacity(k);
        for &q in quants {
            let mut out = self.alloc_raw(c * f);
            let ws: &[f32] = &wv.data;
            par_rows(&mut out, c, f, &sc, |r0, r1, chunk| {
                let _p = profile::time(Op::Quant);
                for (t, r) in (r0..r1).enumerate() {
                    q.quant_row(&ws[r * f..(r + 1) * f], &mut chunk[t * f..(t + 1) * f]);
                }
            });
            qs.push(self.track_aux(Tensor::new(vec![c, f], out)));
        }
        let ste: Vec<bool> = quants.iter().map(|&q| q != QuantKind::Zero).collect();
        let mut y = self.alloc_zeroed(c * f);
        {
            // each output row mixes the branches in fixed column order;
            // rows are independent, so the mix shards across lanes
            let ps: &[f32] = &pv.data;
            let qrefs: Vec<&[f32]> = qs.iter().map(|q| q.data.as_slice()).collect();
            par_rows(&mut y, c, f, &sc, |r0, r1, chunk| {
                let _p = profile::time(Op::Quant);
                for (t, r) in (r0..r1).enumerate() {
                    let yrow = &mut chunk[t * f..(t + 1) * f];
                    for (col, qd) in qrefs.iter().enumerate() {
                        let p = ps[r * k + col];
                        if p == 0.0 {
                            continue;
                        }
                        axpy_row(yrow, p, &qd[r * f..(r + 1) * f]);
                    }
                }
            });
        }
        let val = Tensor::new(vec![c, f], y);
        let saved_p = Rc::clone(&pv);
        self.push(
            val,
            Some(Box::new(move |g, store| {
                // row r writes dw row r and dp row r only — disjoint
                // across rows, so the row shard is race-free and the
                // per-row accumulation order is lane-count-independent
                let (dw, dp) = store.grad_mut2(w.0, probs.0);
                let dp_base = SendPtr(dp.as_mut_ptr());
                let ps: &[f32] = &saved_p.data;
                let qrefs: Vec<&[f32]> = qs.iter().map(|q| q.data.as_slice()).collect();
                let stes: &[bool] = &ste;
                par_rows(dw, c, f, &sc, |r0, r1, chunk| {
                    let _p = profile::time(Op::QuantBwd);
                    for (t, r) in (r0..r1).enumerate() {
                        // STE: each weight-carrying branch passes g
                        // through scaled by its probability; Zero
                        // branches drop it.
                        let psum: f32 = (0..k)
                            .filter(|&col| stes[col])
                            .map(|col| ps[r * k + col])
                            .sum();
                        let dwrow = &mut chunk[t * f..(t + 1) * f];
                        let grow = &g[r * f..(r + 1) * f];
                        for (d, &gv) in dwrow.iter_mut().zip(grow) {
                            *d += psum * gv;
                        }
                        let dprow =
                            unsafe { std::slice::from_raw_parts_mut(dp_base.0.add(r * k), k) };
                        for (col, qd) in qrefs.iter().enumerate() {
                            let mut dot = 0.0f32;
                            for (&gv, &qv) in grow.iter().zip(&qd[r * f..(r + 1) * f]) {
                                dot += gv * qv;
                            }
                            dprow[col] += dot;
                        }
                    }
                });
            })),
        )
    }

    /// Standalone per-row fake-quant with the straight-through estimator
    /// (identity gradient) — the fixed-precision layers' weight path.
    pub fn fake_quant_ste(&mut self, w: Var, kind: QuantKind) -> Var {
        let wv = self.rc(w);
        let (c, f) = (wv.shape[0], wv.shape[1]);
        let sc = self.kernel.clone();
        let mut y = self.alloc_raw(c * f);
        {
            let ws: &[f32] = &wv.data;
            par_rows(&mut y, c, f, &sc, |r0, r1, chunk| {
                let _p = profile::time(Op::Quant);
                for (t, r) in (r0..r1).enumerate() {
                    kind.quant_row(&ws[r * f..(r + 1) * f], &mut chunk[t * f..(t + 1) * f]);
                }
            });
        }
        let val = Tensor::new(vec![c, f], y);
        self.push(
            val,
            Some(Box::new(move |g, store| {
                // identity gradient: a pure element map, laned by row
                let dw = store.grad_mut(w.0);
                par_rows(dw, c, f, &sc, |r0, r1, chunk| {
                    let _p = profile::time(Op::QuantBwd);
                    for (d, &gv) in chunk.iter_mut().zip(&g[r0 * f..r1 * f]) {
                        *d += gv;
                    }
                });
            })),
        )
    }

    /// Column sums of `[c, k]` → expected per-CU channel counts `[k]`.
    pub fn col_sum(&mut self, p: Var) -> Var {
        let pv = self.rc(p);
        let (c, k) = (pv.shape[0], pv.shape[1]);
        let _p = profile::time(Op::Theta);
        let mut y = self.alloc_zeroed(k);
        for r in 0..c {
            for j in 0..k {
                y[j] += pv.data[r * k + j];
            }
        }
        let val = Tensor::new(vec![k], y);
        self.push(
            val,
            Some(Box::new(move |g, store| {
                let dp = store.grad_mut(p.0);
                for r in 0..c {
                    for j in 0..k {
                        dp[r * k + j] += g[j];
                    }
                }
            })),
        )
    }

    /// Embed the keep/prune count pair `[n_keep, n_prune]` into a K-CU
    /// count vector: the kept channels run on CU column 0, pruned
    /// channels cost nothing anywhere.
    pub fn keep_counts(&mut self, n2: Var, k: usize) -> Var {
        let nv = self.rc(n2);
        debug_assert_eq!(nv.elem_count(), 2);
        let mut y = self.alloc_zeroed(k);
        y[0] = nv.data[0];
        let val = Tensor::new(vec![k], y);
        self.push(
            val,
            Some(Box::new(move |g, store| {
                store.grad_mut(n2.0)[0] += g[0];
            })),
        )
    }

    /// Differentiable per-layer cost `[latency_cycles, energy_uj]` from
    /// expected channel counts `n [K]`.
    ///
    /// Each CU's cycles are the piecewise-linear interpolation of the
    /// integer `soc::analytical::cu_cycles` between `⌊n⌋` and `⌈n⌉` — the
    /// value is *exact* at integer counts, so the in-graph cost model and
    /// the deployment simulator can never disagree on a discretized
    /// mapping. Latency is the max (or sum, when `sequential`) of the CU
    /// stages; energy mirrors `analytical::execute` (active + idle share).
    /// Backward feeds each count its local interpolation slope, with the
    /// latency subgradient going to the argmax stage.
    pub fn layer_cost(
        &mut self,
        n: Var,
        layer: &Layer,
        cus: &'static [CuSpec],
        p_idle_mw: f64,
        freq_mhz: f64,
        sequential: bool,
    ) -> Var {
        let nv = self.rc(n);
        let k = cus.len();
        debug_assert_eq!(nv.elem_count(), k);
        let counts: Vec<f64> = nv.data.iter().map(|&v| v as f64).collect();
        let us_per_cycle = 1.0 / freq_mhz;
        let _p = profile::time(Op::Cost);
        let e = eval_layer_cost(cus, layer, &counts, p_idle_mw, us_per_cycle, sequential);
        let mut data = self.alloc_raw(2);
        data[0] = e.latency as f32;
        data[1] = e.energy_uj as f32;
        let val = Tensor::new(vec![2], data);
        let p_act: Vec<f64> = cus.iter().map(|c| c.p_act_mw).collect();
        let (slope, argmax) = (e.slopes, e.argmax);
        self.push(
            val,
            Some(Box::new(move |g, store| {
                let _p = profile::time(Op::Cost);
                let (g_lat, g_en) = (g[0] as f64, g[1] as f64);
                let dn = store.grad_mut(n.0);
                for j in 0..k {
                    let on_lat = sequential || j == argmax;
                    let mut d_c = g_en * 1e-3 * p_act[j] * us_per_cycle;
                    if on_lat {
                        d_c += g_lat + g_en * 1e-3 * p_idle_mw * us_per_cycle;
                    }
                    dn[j] += (d_c * slope[j]) as f32;
                }
            })),
        )
    }
}

/// One evaluation of the differentiable cost forward — the *single*
/// implementation shared by [`Tape::layer_cost`] and the host-side
/// consumers (cost report, cost-scale normalization), so the report and
/// the in-graph objective cannot drift apart.
pub struct LayerCostEval {
    /// interpolated per-CU cycles at the (fractional) counts
    pub cycles: Vec<f64>,
    /// local interpolation slope per CU (d cycles / d count)
    pub slopes: Vec<f64>,
    /// max (or sum, when sequential) of the CU stages
    pub latency: f64,
    /// index of the latency-carrying stage (`usize::MAX` when sequential)
    pub argmax: usize,
    /// active + idle energy, matching `analytical::execute`
    pub energy_uj: f64,
}

/// Cost of one layer at fractional per-CU `counts` (see [`LayerCostEval`]).
pub fn eval_layer_cost(
    cus: &[CuSpec],
    layer: &Layer,
    counts: &[f64],
    p_idle_mw: f64,
    us_per_cycle: f64,
    sequential: bool,
) -> LayerCostEval {
    let k = cus.len();
    debug_assert_eq!(counts.len(), k);
    let mut cycles = vec![0.0f64; k];
    let mut slopes = vec![0.0f64; k];
    for (j, cu) in cus.iter().enumerate() {
        let (v, s) = interp_cu_cycles(cu, layer, counts[j]);
        cycles[j] = v;
        slopes[j] = s;
    }
    let (latency, argmax) = if sequential {
        (cycles.iter().sum::<f64>(), usize::MAX)
    } else {
        let mut best = 0;
        for j in 1..k {
            if cycles[j] > cycles[best] {
                best = j;
            }
        }
        (cycles[best], best)
    };
    let active_nj: f64 = cus
        .iter()
        .zip(&cycles)
        .map(|(cu, &c)| cu.p_act_mw * c * us_per_cycle)
        .sum();
    let energy_uj = (active_nj + p_idle_mw * latency * us_per_cycle) * 1e-3;
    LayerCostEval {
        cycles,
        slopes,
        latency,
        argmax,
        energy_uj,
    }
}

/// Interpolated analytical cycles of a *fractional* channel count, plus
/// the local slope. Exact at integer counts by construction.
pub fn interp_cu_cycles(cu: &CuSpec, layer: &Layer, x: f64) -> (f64, f64) {
    let x = x.max(0.0);
    let lo = x.floor() as usize;
    let frac = x - lo as f64;
    let c_lo = cu_cycles(cu, layer, lo) as f64;
    let c_hi = cu_cycles(cu, layer, lo + 1) as f64;
    let slope = c_hi - c_lo;
    (c_lo + frac * slope, slope)
}

// ---------------------------------------------------------------------------
// conv plumbing
// ---------------------------------------------------------------------------

/// 'SAME' output geometry: `(oh, ow, pad_begin)`.
pub(crate) fn same_geometry(h: usize, w: usize, k: usize, stride: usize) -> (usize, usize, usize) {
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let pad_total = ((oh - 1) * stride + k).saturating_sub(h);
    (oh, ow, pad_total / 2)
}

// Per-row elementwise panels shared by the depthwise conv, batch-norm
// and effective-weight loops. Under `simd-kernels` they dispatch to the
// 8-lane helpers in [`super::tensor::simd`]; the scalar loop and the
// vector main-loop-plus-tail compute identical bits (pure elementwise
// maps, no reduction reordering), so these are unconditionally safe for
// the determinism contract.

/// `y[j] += x[j] * w[j]`.
#[inline]
fn fma_row(y: &mut [f32], x: &[f32], w: &[f32]) {
    #[cfg(feature = "simd-kernels")]
    if super::tensor::simd_enabled() {
        super::tensor::simd::fma_slice(y, x, w);
        return;
    }
    for ((yv, &xv), &wv) in y.iter_mut().zip(x).zip(w) {
        *yv += xv * wv;
    }
}

/// `y[j] += alpha * x[j]`.
#[inline]
fn axpy_row(y: &mut [f32], alpha: f32, x: &[f32]) {
    #[cfg(feature = "simd-kernels")]
    if super::tensor::simd_enabled() {
        super::tensor::simd::axpy_slice(y, alpha, x);
        return;
    }
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

/// `out[j] = (x[j] - m[j]) * s[j]`.
#[inline]
fn sub_mul_row(out: &mut [f32], x: &[f32], m: &[f32], s: &[f32]) {
    #[cfg(feature = "simd-kernels")]
    if super::tensor::simd_enabled() {
        super::tensor::simd::sub_mul_slice(out, x, m, s);
        return;
    }
    for (((o, &xv), &mv), &sv) in out.iter_mut().zip(x).zip(m).zip(s) {
        *o = (xv - mv) * sv;
    }
}

/// `out[j] = x[j] * a[j] + b[j]`.
#[inline]
fn affine_row(out: &mut [f32], x: &[f32], a: &[f32], b: &[f32]) {
    #[cfg(feature = "simd-kernels")]
    if super::tensor::simd_enabled() {
        super::tensor::simd::affine_slice(out, x, a, b);
        return;
    }
    for (((o, &xv), &av), &bv) in out.iter_mut().zip(x).zip(a).zip(b) {
        *o = xv * av + bv;
    }
}

/// `Aᵀ·B` on the packed-panel at tier: the pack scratch comes from the
/// step arena (sized by `plan`), so the hot loop never allocates. Both
/// builds take the packed tier; the bench's packing toggle falls back
/// (inside [`par_matmul_at_into_packed`]) to the unpacked row-tile
/// kernel, which stays the bit-identity reference.
#[allow(clippy::too_many_arguments)]
fn matmul_at_via_pack(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    sc: &KernelScope,
    store: &mut GradStore,
) {
    let mut pack = store.take_raw(k * m);
    par_matmul_at_into_packed(a, b, c, m, k, n, sc, &mut pack);
    store.give(pack);
}

/// One fused-conv lane: walk patch rows `r0..r1` in [`FUSE_ROWS`]
/// blocks — fill the block's A-panel ([`fill_patch_rows`], `Op::Pack`),
/// then multiply it against the bt weight pack (`Op::Matmul`) while the
/// panel is still cache-hot. The bt kernel is per-output-row
/// independent, so the block subdivision cannot change any element's
/// bits. `geom` is `(h, w, cin, k, stride, oh, ow, pad)`.
fn conv_rows_fused(
    x: &[f32],
    pbt: &[f32],
    y: &mut [f32],
    panel: &mut [f32],
    geom: (usize, usize, usize, usize, usize, usize, usize, usize),
    cout: usize,
    r0: usize,
    r1: usize,
) {
    let (_, _, cin, k, _, _, _, _) = geom;
    let f = k * k * cin;
    let mut r = r0;
    while r < r1 {
        let re = (r + FUSE_ROWS).min(r1);
        {
            let _p = profile::time(Op::Pack);
            fill_patch_rows(x, &mut panel[..(re - r) * f], geom, r, re);
        }
        let _p = profile::time(Op::Matmul);
        matmul_bt_packed_into(
            &panel[..(re - r) * f],
            pbt,
            &mut y[(r - r0) * cout..(re - r0) * cout],
            re - r,
            f,
            cout,
        );
        r = re;
    }
}

/// Write patch rows `r0..r1` of the im2col matrix into `panel`
/// (row-major, `r1−r0` rows of `f = k·k·cin`). Every position is
/// written — padding taps as exact `0.0` — so the panel needs no
/// pre-zeroing and its rows are content-identical to
/// [`im2col_slice_into`]'s (which skips padding taps into a pre-zeroed
/// buffer instead). `geom` is `(h, w, cin, k, stride, oh, ow, pad)`.
fn fill_patch_rows(
    x: &[f32],
    panel: &mut [f32],
    geom: (usize, usize, usize, usize, usize, usize, usize, usize),
    r0: usize,
    r1: usize,
) {
    let (h, w, cin, k, stride, oh, ow, pad) = geom;
    let f = k * k * cin;
    debug_assert_eq!(panel.len(), (r1 - r0) * f);
    for (ri, row) in panel.chunks_exact_mut(f).enumerate() {
        let r = r0 + ri;
        let b = r / (oh * ow);
        let rem = r % (oh * ow);
        let (oy, ox) = (rem / ow, rem % ow);
        for ky in 0..k {
            let iy = (oy * stride + ky) as isize - pad as isize;
            for kx in 0..k {
                let dst = &mut row[(ky * k + kx) * cin..(ky * k + kx + 1) * cin];
                let ix = (ox * stride + kx) as isize - pad as isize;
                if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                    dst.iter_mut().for_each(|v| *v = 0.0);
                    continue;
                }
                let src = ((b * h + iy as usize) * w + ix as usize) * cin;
                dst.copy_from_slice(&x[src..src + cin]);
            }
        }
    }
}

/// Fill the patch matrix `[n·oh·ow, k·k·cin]` (column layout
/// `(ky·k+kx)·cin + ci`). `cols` must be zeroed — padding taps are
/// skipped, not written. Sharded by image `b` across the kernel lanes:
/// image `b`'s patch rows are the contiguous block
/// `[b·oh·ow·f, (b+1)·oh·ow·f)`, so lanes write disjoint regions and
/// the per-element copy order within each image is unchanged — bits are
/// identical at any lane count. `pub(crate)`: the quantized inference
/// path ([`super::qkernels`]) lowers its convs through the same patch
/// fill.
pub(crate) fn im2col_into(
    x: &Tensor,
    k: usize,
    stride: usize,
    cols: &mut [f32],
    scope: &KernelScope,
) {
    im2col_slice_into(
        &x.data,
        x.shape[0],
        x.shape[1],
        x.shape[2],
        x.shape[3],
        k,
        stride,
        cols,
        scope,
    );
}

/// Slice form of [`im2col_into`]: no `Tensor` wrapper, so the quantized
/// forward can lower convs straight from its own activation buffers
/// without cloning them into a `Tensor` first.
#[allow(clippy::too_many_arguments)]
pub(crate) fn im2col_slice_into(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    k: usize,
    stride: usize,
    cols: &mut [f32],
    scope: &KernelScope,
) {
    let (oh, ow, pad) = same_geometry(h, w, k, stride);
    let f = k * k * cin;
    debug_assert_eq!(x.len(), n * h * w * cin);
    debug_assert_eq!(cols.len(), n * oh * ow * f);
    par_rows(cols, n, oh * ow * f, scope, |b0, b1, chunk| {
        let _p = profile::time(Op::Im2col);
        for b in b0..b1 {
            for oy in 0..oh {
                for ox in 0..ow {
                    let row = (((b - b0) * oh + oy) * ow + ox) * f;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let src = ((b * h + iy as usize) * w + ix as usize) * cin;
                            let dst = row + (ky * k + kx) * cin;
                            chunk[dst..dst + cin].copy_from_slice(&x[src..src + cin]);
                        }
                    }
                }
            }
        }
    });
}

/// Scatter `dcols` back onto the input gradient (inverse of
/// [`im2col_into`]). Sharded by image `b`: the `+=` taps for image `b`
/// all land in its own contiguous `dx` block `[b·h·w·cin, (b+1)·…)`, and
/// the scatter order *within* an image is the serial loop's — receptive
/// fields only overlap inside one image, so lane count can't reorder any
/// element's accumulation.
#[allow(clippy::too_many_arguments)]
fn col2im(
    dcols: &[f32],
    dx: &mut [f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    k: usize,
    stride: usize,
    oh: usize,
    ow: usize,
    scope: &KernelScope,
) {
    let pad = {
        let pad_total = ((oh - 1) * stride + k).saturating_sub(h);
        pad_total / 2
    };
    let f = k * k * cin;
    debug_assert_eq!(dx.len(), n * h * w * cin);
    par_rows(dx, n, h * w * cin, scope, |b0, b1, chunk| {
        let _p = profile::time(Op::Im2col);
        for b in b0..b1 {
            for oy in 0..oh {
                for ox in 0..ow {
                    let row = ((b * oh + oy) * ow + ox) * f;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let dst = (((b - b0) * h + iy as usize) * w + ix as usize) * cin;
                            let src = row + (ky * k + kx) * cin;
                            for ci in 0..cin {
                                chunk[dst + ci] += dcols[src + ci];
                            }
                        }
                    }
                }
            }
        }
    });
}

/// Depthwise forward over transposed weights `wt[k·k, c]`: output rows
/// `(b, oy)` shard across the kernel lanes (each lane owns a disjoint
/// contiguous slice of `y`), and the inner channel loop walks three
/// contiguous panels (`y` row, `x` row, `wt` row) so it vectorizes like
/// the blocked matmuls. Tap order per output element is (ky, kx)
/// ascending — identical for every lane count.
#[allow(clippy::too_many_arguments)]
fn dw_forward(
    x: &[f32],
    wt: &[f32],
    y: &mut [f32],
    n: usize,
    h: usize,
    ww: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad: usize,
    scope: &KernelScope,
) {
    let (oh, ow, _) = same_geometry(h, ww, k, stride);
    let rows = n * oh;
    debug_assert_eq!(y.len(), rows * ow * c);
    par_rows(y, rows, ow * c, scope, |r0, r1, chunk| {
        let _p = profile::time(Op::DwConv);
        for row in r0..r1 {
            let (b, oy) = (row / oh, row % oh);
            let yrow = &mut chunk[(row - r0) * ow * c..(row - r0 + 1) * ow * c];
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - pad as isize;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..k {
                    let wrow = &wt[(ky * k + kx) * c..(ky * k + kx + 1) * c];
                    for ox in 0..ow {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= ww as isize {
                            continue;
                        }
                        let src = ((b * h + iy as usize) * ww + ix as usize) * c;
                        let xrow = &x[src..src + c];
                        let yout = &mut yrow[ox * c..(ox + 1) * c];
                        fma_row(yout, xrow, wrow);
                    }
                }
            }
        }
    });
}

/// Depthwise backward over transposed weights `wt[k·k, c]`, accumulating
/// `dwt` in the same transposed layout. A depthwise op never mixes
/// channels, so `dx`/`dwt` shard across lanes *by channel*: lane `l`
/// owns the channel range `[l·c/t, (l+1)·c/t)` of every `dx` pixel and
/// every `dwt` row, and walks the full `b/oy/ox/ky/kx` loop restricted
/// to its own sub-range. Writes are disjoint by construction (strided,
/// hence the raw-pointer reslicing), and each channel's `+=` sequence is
/// exactly the serial loop's — the fixed reduction order promised in the
/// ROADMAP's carried-over debts — so results are bit-identical at any
/// lane count.
#[allow(clippy::too_many_arguments)]
fn dw_backward(
    x: &[f32],
    wt: &[f32],
    g: &[f32],
    dx: &mut [f32],
    dwt: &mut [f32],
    n: usize,
    h: usize,
    ww: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad: usize,
    scope: &KernelScope,
) {
    let (oh, ow, _) = same_geometry(h, ww, k, stride);
    debug_assert_eq!(dx.len(), n * h * ww * c);
    debug_assert_eq!(dwt.len(), k * k * c);
    let t = scope.lanes().min(c).max(1);
    let dx_base = SendPtr(dx.as_mut_ptr());
    let dwt_base = SendPtr(dwt.as_mut_ptr());
    scope.run(&|lane| {
        if lane >= t {
            return;
        }
        let (c0, c1) = (lane * c / t, (lane + 1) * c / t);
        if c0 == c1 {
            return;
        }
        let _p = profile::time(Op::DwConv);
        let cw = c1 - c0;
        for b in 0..n {
            for oy in 0..oh {
                for ox in 0..ow {
                    let out = ((b * oh + oy) * ow + ox) * c;
                    let grow = &g[out + c0..out + c1];
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= ww as isize {
                                continue;
                            }
                            let src = ((b * h + iy as usize) * ww + ix as usize) * c;
                            let wi = ky * k + kx;
                            let wrow = &wt[wi * c + c0..wi * c + c1];
                            let xrow = &x[src + c0..src + c1];
                            let dxrow = unsafe {
                                std::slice::from_raw_parts_mut(dx_base.0.add(src + c0), cw)
                            };
                            fma_row(dxrow, grow, wrow);
                            let dwrow = unsafe {
                                std::slice::from_raw_parts_mut(dwt_base.0.add(wi * c + c0), cw)
                            };
                            fma_row(dwrow, grow, xrow);
                        }
                    }
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backward_accumulates_shared_operands() {
        // y = (a + a) summed: dy/da = 2 everywhere
        let mut t = Tape::new();
        let a = t.leaf(Tensor::new(vec![3], vec![1.0, -2.0, 0.5]));
        let s = t.add(a, a);
        let loss = t.sum_all(s);
        let g = t.grad_of(loss, a);
        assert_eq!(g.data, vec![2.0, 2.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "consumed")]
    fn consumed_interior_grad_fails_loudly() {
        // the interior `add` node's slot is moved out during the sweep;
        // asking for it afterwards must panic, not return a broadcastable
        // scalar placeholder
        let mut t = Tape::new();
        let a = t.leaf(Tensor::new(vec![2], vec![1.0, 2.0]));
        let s = t.add(a, a);
        let loss = t.sum_all(s);
        let _ = t.grad_of(loss, s);
    }

    #[test]
    fn recycle_reclaims_step_buffers() {
        let mut arena = Arena::new();
        for round in 0..3 {
            let mut t = Tape::with_arena(arena);
            let a = t.leaf_copy(vec![4], &[1.0, -1.0, 2.0, 0.5]);
            let r = t.relu(a);
            let loss = t.sum_all(r);
            let grads = t.backward(loss);
            t.reclaim(grads);
            arena = t.recycle();
            if round == 0 {
                assert!(arena.grown() > 0, "first step must allocate");
            }
        }
        let after_two = arena.grown();
        let mut t = Tape::with_arena(arena);
        let a = t.leaf_copy(vec![4], &[0.1, 0.2, 0.3, 0.4]);
        let r = t.relu(a);
        let loss = t.sum_all(r);
        let grads = t.backward(loss);
        t.reclaim(grads);
        arena = t.recycle();
        assert_eq!(arena.grown(), after_two, "steady-state step must not grow");
    }

    #[test]
    fn broadcast_and_keep_counts_gradients() {
        // broadcast_rows: d/dp sums over rows
        let mut t = Tape::new();
        let p = t.leaf(Tensor::new(vec![1, 3], vec![0.2, 0.3, 0.5]));
        let b = t.broadcast_rows(p, 4);
        assert_eq!(t.val(b).shape, vec![4, 3]);
        let loss = t.sum_all(b);
        let g = t.grad_of(loss, p);
        assert_eq!(g.data, vec![4.0, 4.0, 4.0]);
        // keep_counts: only column 0 is live
        let mut t = Tape::new();
        let n2 = t.leaf(Tensor::new(vec![2], vec![5.0, 3.0]));
        let kc = t.keep_counts(n2, 4);
        assert_eq!(t.val(kc).data, vec![5.0, 0.0, 0.0, 0.0]);
        let loss = t.sum_all(kc);
        let g = t.grad_of(loss, n2);
        assert_eq!(g.data, vec![1.0, 0.0]);
    }

    #[test]
    fn zero_branch_blocks_ste_gradient() {
        // prune semantics: W_eff = p_keep · Q(W); dW = p_keep · g
        let mut t = Tape::new();
        let w = t.leaf(Tensor::new(vec![1, 2], vec![1.0, -2.0]));
        let p = t.leaf(Tensor::new(vec![1, 2], vec![0.25, 0.75]));
        let eff = t.effective_weights(w, p, &[QuantKind::Identity, QuantKind::Zero]);
        assert_eq!(t.val(eff).data, vec![0.25, -0.5]);
        let loss = t.sum_all(eff);
        let g = t.grad_of(loss, w);
        assert_eq!(g.data, vec![0.25, 0.25]);
    }

    #[test]
    fn quantizers_match_reference_semantics() {
        let row = [0.5f32, -1.0, 0.02, 0.0];
        let mut q8 = [0.0f32; 4];
        QuantKind::Int8.quant_row(&row, &mut q8);
        let scale = 1.0 / 127.0;
        assert!((q8[1] + 1.0).abs() < 1e-6);
        assert!((q8[0] - (0.5 / scale).round() * scale).abs() < 1e-6);
        let mut qt = [0.0f32; 4];
        QuantKind::Ternary.quant_row(&row, &mut qt);
        // thr = 0.05; kept = {0.5, 1.0}; scale = 0.75
        assert_eq!(qt, [0.75, -0.75, 0.0, 0.0]);
        let mut qi = [0.0f32; 4];
        QuantKind::Identity.quant_row(&row, &mut qi);
        assert_eq!(qi, row);
        let mut qz = [9.0f32; 4];
        QuantKind::Zero.quant_row(&row, &mut qz);
        assert_eq!(qz, [0.0; 4]);
    }

    #[test]
    fn interp_is_exact_at_integers() {
        let p = crate::soc::Platform::diana();
        let layer = Layer {
            name: "t".into(),
            ltype: crate::soc::LayerType::Conv,
            cin: 16,
            cout: 32,
            k: 3,
            ox: 8,
            oy: 8,
            stride: 1,
            searchable: true,
        };
        for cu in p.cus() {
            for n in [0usize, 1, 7, 32] {
                let (v, _) = interp_cu_cycles(cu, &layer, n as f64);
                assert_eq!(v, cu_cycles(cu, &layer, n) as f64, "{} n={n}", cu.name);
            }
        }
    }
}
