//! K-column supernet builder: the ResNet / MobileNetV1 search spaces
//! constructed directly from a layer table and the platform registry.
//!
//! Where the Python supernets hardcode the two CUs of their target SoC,
//! the native builder derives everything from the [`Platform`] descriptor:
//! every searchable layer carries a `[cout, K]` θ (K = CU count), each
//! column's weight branch is fake-quantized with that CU's declared data
//! representation, and columns whose CU cannot run the layer's op are
//! masked out of the softmax (no channels, no gradient). This closes the
//! "supernets are 2-CU" gap: `diana_resnet20_c10`, `trident_mbv1_c10` and
//! `gap9_resnet20_c10` are all the same code path.
//!
//! Besides the ODiMO channel search the builder hosts the two baseline
//! search spaces that used to exist only as XLA artifacts:
//!
//! * `_prune` — keep-vs-prune per channel (θ `[cout, 2]`): the kept
//!   branch runs on CU column 0 with its representation, the pruned
//!   branch is the zero weight ([`QuantKind::Zero`]), and only the kept
//!   expected count reaches the cost model (Fig. 7-top baseline);
//! * `_layerwise` — one gate per layer (θ `[K]`): the whole layer's
//!   channels share a single eligibility-masked softmax over the CUs
//!   (the path-based DNAS baseline, Fig. 7-bottom).
//!
//! Variant grammar:
//! `<platform>_<arch>_<task>[_w050|_w025][_fixed|_prune|_layerwise]` with
//! `arch ∈ {resnet20, resnet8, mbv1, tiny}` and
//! `task ∈ {c10, c100, imgnet, tiny}`; `_fixed` builds the plain
//! fixed-precision baseline net (no θ — Table II's comparison point),
//! `_w050`/`_w025` scale MobileNet widths (Fig. 10).

use anyhow::{bail, Context, Result};

use crate::mapping::ONE_HOT_LOGIT;
use crate::runtime::manifest::{CostScale, DatasetSpec, LayerSpec, Manifest};
use crate::search::eligible_cus;
use crate::soc::{Layer, LayerType, Platform};

use super::tape::{QuantKind, Tape, Var};
use super::tensor::PackHandle;

/// Network families the native builder knows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    Resnet20,
    Resnet8,
    Mbv1,
    /// miniature ResNet for tests/benches (seconds, not minutes)
    Tiny,
}

/// Which search space the variant trains (manifest `search_kind`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchMode {
    /// per-channel K-way CU choice, θ `[cout, K]` (the ODiMO search)
    Channel,
    /// keep-vs-prune per channel, θ `[cout, 2]` (structured-pruning baseline)
    Prune,
    /// one K-way gate per layer, θ `[K]` (path-based DNAS baseline)
    Layerwise,
    /// no θ anywhere: the fixed-precision baseline net
    Fixed,
}

impl SearchMode {
    pub fn kind_str(self) -> &'static str {
        match self {
            SearchMode::Channel => "channel",
            SearchMode::Prune => "prune",
            SearchMode::Layerwise => "layerwise",
            SearchMode::Fixed => "fixed",
        }
    }
}

/// One step of the forward plan (indices into the geometry table).
#[derive(Debug, Clone, Copy)]
pub enum PlanStep {
    /// conv → bn → relu
    Conv(usize),
    /// residual block: relu(bn(c2(relu(bn(c1 x)))) + shortcut)
    ResBlock {
        c1: usize,
        c2: usize,
        dn: Option<usize>,
    },
    /// depthwise-separable block: dw → bn → relu → pw → bn → relu
    DwPw { dw: usize, pw: usize },
}

/// Everything static about one native model variant.
pub struct SupernetSpec {
    pub variant: String,
    pub platform: Platform,
    pub arch: Arch,
    pub search: SearchMode,
    /// no θ anywhere: the fixed-precision baseline net
    pub fixed: bool,
    pub dataset: DatasetSpec,
    /// geometry in manifest order: every conv, then the FC head
    pub layers: Vec<Layer>,
    /// per-layer CU-eligibility mask (θ softmax support)
    pub masks: Vec<Vec<bool>>,
    /// per-CU-column weight quantizer
    pub quants: Vec<QuantKind>,
    pub plan: Vec<PlanStep>,
    pub classes: usize,
    pub fc_cin: usize,
}

impl SupernetSpec {
    /// Number of conv layers (the geometry minus the FC head).
    pub fn n_convs(&self) -> usize {
        self.layers.len() - 1
    }

    /// Parse a variant name and build its search space.
    pub fn build(variant: &str) -> Result<SupernetSpec> {
        let mut toks: Vec<&str> = variant.split('_').collect();
        let mut search = SearchMode::Channel;
        let mut wm = 1.0f64;
        let set_mode = |cur: &mut SearchMode, new: SearchMode| -> Result<()> {
            if *cur != SearchMode::Channel {
                bail!(
                    "variant '{variant}': at most one of _fixed/_prune/_layerwise \
                     (got {} and {})",
                    cur.kind_str(),
                    new.kind_str()
                );
            }
            *cur = new;
            Ok(())
        };
        loop {
            match toks.last().copied() {
                Some("fixed") => {
                    set_mode(&mut search, SearchMode::Fixed)?;
                    toks.pop();
                }
                Some("prune") => {
                    set_mode(&mut search, SearchMode::Prune)?;
                    toks.pop();
                }
                Some("layerwise") => {
                    set_mode(&mut search, SearchMode::Layerwise)?;
                    toks.pop();
                }
                Some("w050") => {
                    wm = 0.5;
                    toks.pop();
                }
                Some("w025") => {
                    wm = 0.25;
                    toks.pop();
                }
                _ => break,
            }
        }
        if toks.len() < 3 {
            bail!(
                "variant '{variant}' does not match the native grammar \
                 <platform>_<arch>_<task>[_w050|_w025][_fixed|_prune|_layerwise]"
            );
        }
        let task = toks.pop().unwrap();
        let arch_tok = toks.pop().unwrap();
        let platform_name = toks.join("_");
        let platform = Platform::get(&platform_name).with_context(|| {
            format!("variant '{variant}': platform '{platform_name}' not registered")
        })?;
        let arch = match arch_tok {
            "resnet20" => Arch::Resnet20,
            "resnet8" => Arch::Resnet8,
            "mbv1" => Arch::Mbv1,
            "tiny" => Arch::Tiny,
            other => bail!(
                "variant '{variant}': unknown arch '{other}' \
                 (expected resnet20|resnet8|mbv1|tiny)"
            ),
        };
        let dataset = match task {
            "c10" => DatasetSpec {
                name: "synth-cifar10".into(),
                hw: 32,
                classes: 10,
                batch: 64,
            },
            "c100" => DatasetSpec {
                name: "synth-cifar100".into(),
                hw: 32,
                classes: 100,
                batch: 64,
            },
            "imgnet" => DatasetSpec {
                name: "synth-imagenet".into(),
                hw: 64,
                classes: 100,
                batch: 32,
            },
            "tiny" => DatasetSpec {
                name: "synth-tiny".into(),
                hw: 8,
                classes: 4,
                batch: 8,
            },
            other => bail!(
                "variant '{variant}': unknown task '{other}' (expected c10|c100|imgnet|tiny)"
            ),
        };
        let (mut layers, plan, fc_cin) = match arch {
            Arch::Resnet20 => resnet_geoms(dataset.hw, 8, &[8, 16, 32], 3),
            Arch::Resnet8 => resnet_geoms(dataset.hw, 16, &[16, 32, 64], 1),
            Arch::Tiny => resnet_geoms(dataset.hw, 4, &[4], 1),
            Arch::Mbv1 => mbv1_geoms(dataset.hw, wm),
        };
        let fixed = search == SearchMode::Fixed;
        if fixed {
            for l in layers.iter_mut() {
                l.searchable = false;
            }
        }
        let classes = dataset.classes;
        layers.push(Layer {
            name: "fc".into(),
            ltype: LayerType::Fc,
            cin: fc_cin,
            cout: classes,
            k: 1,
            ox: 1,
            oy: 1,
            stride: 1,
            searchable: false,
        });
        let masks: Vec<Vec<bool>> = layers.iter().map(|l| eligible_cus(platform, l)).collect();
        let quants: Vec<QuantKind> = platform
            .cus()
            .iter()
            .map(|cu| QuantKind::from_quant_str(&cu.quant))
            .collect();
        Ok(SupernetSpec {
            variant: variant.to_string(),
            platform,
            arch,
            search,
            fixed,
            dataset,
            layers,
            masks,
            quants,
            plan,
            classes,
            fc_cin,
        })
    }

    /// θ leaf shape of searchable conv geometry `gi` for this search mode.
    pub fn theta_shape(&self, gi: usize) -> Vec<usize> {
        let cout = self.layers[gi].cout;
        match self.search {
            SearchMode::Channel | SearchMode::Fixed => vec![cout, self.platform.n_cus()],
            SearchMode::Prune => vec![cout, 2],
            SearchMode::Layerwise => vec![self.platform.n_cus()],
        }
    }

    /// θ shape as staged on a tape: layerwise θ is *stored* flat `[K]`
    /// but staged as one softmax row `[1, K]`.
    pub fn theta_stage_shape(&self, gi: usize) -> Vec<usize> {
        match self.search {
            SearchMode::Layerwise => vec![1, self.platform.n_cus()],
            _ => self.theta_shape(gi),
        }
    }

    /// Assemble the in-memory [`Manifest`] (no files, no functions table).
    pub fn to_manifest(&self, cost_scale: CostScale) -> Manifest {
        let layers = self
            .layers
            .iter()
            .enumerate()
            .map(|(gi, l)| LayerSpec {
                name: l.name.clone(),
                ltype: l.ltype.name().to_string(),
                cin: l.cin,
                cout: l.cout,
                k: l.k,
                ox: l.ox,
                oy: l.oy,
                stride: l.stride,
                searchable: l.searchable,
                theta_len: if l.searchable {
                    self.theta_shape(gi).iter().product()
                } else {
                    0
                },
            })
            .collect();
        Manifest {
            variant: self.variant.clone(),
            platform: self.platform.name().to_string(),
            w_optimizer: "sgdm".into(),
            search_kind: self.search.kind_str().into(),
            dataset: self.dataset.clone(),
            layers,
            cost_scale,
            metrics_train: ["loss", "ce", "acc", "cost_lat_cycles", "cost_energy_uj"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            metrics_eval: ["correct", "loss_sum"].iter().map(|s| s.to_string()).collect(),
            functions: Default::default(),
            dir: std::path::PathBuf::new(),
        }
    }

    /// Expected per-CU counts of layer `gi` at the uniform-θ init point
    /// (where the cost scale is normalized): `cout / #eligible` on each
    /// eligible column — except the prune space, whose init point keeps
    /// half the channels on CU 0 and prunes the rest.
    pub fn uniform_counts(&self, gi: usize) -> Vec<f64> {
        let l = &self.layers[gi];
        let mask = &self.masks[gi];
        if self.search == SearchMode::Prune {
            let mut c = vec![0.0; mask.len()];
            c[0] = l.cout as f64 / 2.0;
            return c;
        }
        let e = mask.iter().filter(|&&m| m).count().max(1);
        mask.iter()
            .map(|&m| if m { l.cout as f64 / e as f64 } else { 0.0 })
            .collect()
    }

    /// He-normal fan-in of a conv geometry's weight rows.
    pub fn fan_in(&self, gi: usize) -> usize {
        let l = &self.layers[gi];
        match l.ltype {
            LayerType::Dw => l.k * l.k,
            _ => l.cin * l.k * l.k,
        }
    }

    /// Flattened weight shape of conv geometry `gi`.
    pub fn w_shape(&self, gi: usize) -> Vec<usize> {
        vec![self.layers[gi].cout, self.fan_in(gi)]
    }

    /// Masked θ init: eligible columns at 0 (uniform), ineligible pinned
    /// to the one-hot floor so discretization can never select them.
    /// The prune space has no ineligible columns (keep/prune are always
    /// both available); the layerwise space is a single masked row.
    pub fn theta_init(&self, gi: usize) -> Vec<f32> {
        let shape = self.theta_shape(gi);
        let n: usize = shape.iter().product();
        if self.search == SearchMode::Prune {
            return vec![0.0; n];
        }
        let mask = &self.masks[gi];
        let k = mask.len();
        let rows = n / k;
        let mut t = vec![0.0f32; n];
        for c in 0..rows {
            for (j, &m) in mask.iter().enumerate() {
                if !m {
                    t[c * k + j] = -ONE_HOT_LOGIT;
                }
            }
        }
        t
    }
}

/// CIFAR-style ResNet geometry (mirrors `supernet_diana.build_geoms`).
fn resnet_geoms(
    input_hw: usize,
    stem: usize,
    widths: &[usize],
    blocks: usize,
) -> (Vec<Layer>, Vec<PlanStep>, usize) {
    let conv = |name: String, ltype, cin, cout, k, hw, stride| Layer {
        name,
        ltype,
        cin,
        cout,
        k,
        ox: hw,
        oy: hw,
        stride,
        searchable: true,
    };
    let mut geoms = Vec::new();
    let mut plan = Vec::new();
    let mut hw = input_hw;
    geoms.push(conv("stem".into(), LayerType::Conv, 3, stem, 3, hw, 1));
    plan.push(PlanStep::Conv(0));
    let mut cin = stem;
    for (si, &cw) in widths.iter().enumerate() {
        for bi in 0..blocks {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            let hw_out = hw.div_ceil(stride);
            let c1 = geoms.len();
            geoms.push(conv(
                format!("s{si}b{bi}c1"),
                LayerType::Conv,
                cin,
                cw,
                3,
                hw_out,
                stride,
            ));
            let c2 = geoms.len();
            geoms.push(conv(
                format!("s{si}b{bi}c2"),
                LayerType::Conv,
                cw,
                cw,
                3,
                hw_out,
                1,
            ));
            let dn = if stride != 1 || cin != cw {
                let d = geoms.len();
                geoms.push(conv(
                    format!("s{si}b{bi}dn"),
                    LayerType::Pw,
                    cin,
                    cw,
                    1,
                    hw_out,
                    stride,
                ));
                Some(d)
            } else {
                None
            };
            plan.push(PlanStep::ResBlock { c1, c2, dn });
            hw = hw_out;
            cin = cw;
        }
    }
    (geoms, plan, cin)
}

/// MobileNetV1 geometry (mirrors `variants.ds_cfg`), widths scaled by `wm`.
fn mbv1_geoms(input_hw: usize, wm: f64) -> (Vec<Layer>, Vec<PlanStep>, usize) {
    let w = |c: usize| ((c as f64 * wm).round() as usize).max(1);
    const BLOCKS: [(usize, usize, usize); 7] = [
        (8, 1, 16),
        (16, 2, 32),
        (32, 1, 32),
        (32, 2, 64),
        (64, 1, 64),
        (64, 2, 128),
        (128, 1, 128),
    ];
    let mut geoms = Vec::new();
    let mut plan = Vec::new();
    let mut hw = input_hw;
    geoms.push(Layer {
        name: "stem".into(),
        ltype: LayerType::Conv,
        cin: 3,
        cout: w(BLOCKS[0].0),
        k: 3,
        ox: hw,
        oy: hw,
        stride: 1,
        searchable: true,
    });
    plan.push(PlanStep::Conv(0));
    let mut cin = w(BLOCKS[0].0);
    for (bi, &(_, stride, cout_t)) in BLOCKS.iter().enumerate() {
        let cout = w(cout_t);
        let hw_out = hw.div_ceil(stride);
        let dw = geoms.len();
        geoms.push(Layer {
            name: format!("b{bi}dw"),
            ltype: LayerType::Dw,
            cin,
            cout: cin,
            k: 3,
            ox: hw_out,
            oy: hw_out,
            stride,
            searchable: true,
        });
        let pw = geoms.len();
        geoms.push(Layer {
            name: format!("b{bi}pw"),
            ltype: LayerType::Pw,
            cin,
            cout,
            k: 1,
            ox: hw_out,
            oy: hw_out,
            stride: 1,
            searchable: true,
        });
        plan.push(PlanStep::DwPw { dw, pw });
        hw = hw_out;
        cin = cout;
    }
    (geoms, plan, cin)
}

// ---------------------------------------------------------------------------
// forward
// ---------------------------------------------------------------------------

/// Tape handles of one conv layer's parameters.
pub struct LayerVars {
    pub w: Var,
    pub scale: Var,
    pub bias: Var,
    pub theta: Option<Var>,
    /// step-scoped handle into the layer's shared weight-pack slot
    /// (None for depthwise layers, whose taps never run a GEMM)
    pub pack: Option<PackHandle>,
}

/// Forward-pass outputs the backend consumes.
pub struct ForwardOut {
    pub logits: Var,
    /// expected per-CU channel counts, one per searchable conv geometry
    pub counts: Vec<Option<Var>>,
    /// batch statistics per conv geometry (training mode only)
    pub batch_stats: Vec<Option<(Vec<f32>, Vec<f32>)>>,
}

/// Batch-norm epsilon of the eval-mode folded affine — shared with the
/// quantized inference path ([`super::qkernels`]) so the two forwards
/// fold the running stats identically.
pub(crate) const BN_EPS: f32 = 1e-5;

/// Record the θ → (weight-branch probabilities, expected counts) graph
/// of one searchable layer for the spec's search mode — the *single*
/// implementation shared by the training forward ([`theta_weights`])
/// and the host-side cost report, so the in-graph objective and the
/// report cannot drift apart. The returned counts var is always a
/// K-vector aligned with the platform's CU columns.
pub fn theta_counts(spec: &SupernetSpec, tape: &mut Tape, gi: usize, th: Var) -> (Var, Var) {
    match spec.search {
        SearchMode::Channel | SearchMode::Fixed => {
            let probs = tape.softmax_rows_masked(th, &spec.masks[gi]);
            let counts = tape.col_sum(probs);
            (probs, counts)
        }
        SearchMode::Prune => {
            // keep-vs-prune: only the kept expected count reaches the
            // cost model, embedded on CU column 0
            let probs = tape.softmax_rows_masked(th, &[true, true]);
            let pair = tape.col_sum(probs);
            let counts = tape.keep_counts(pair, spec.platform.n_cus());
            (probs, counts)
        }
        SearchMode::Layerwise => {
            // one gate per layer: a single masked softmax row shared by
            // every channel
            let p1 = tape.softmax_rows_masked(th, &spec.masks[gi]);
            let pc = tape.broadcast_rows(p1, spec.layers[gi].cout);
            let counts = tape.col_sum(pc);
            (pc, counts)
        }
    }
}

/// θ → (expected counts, Eq. 5 effective weights) of one searchable
/// layer: [`theta_counts`] plus the mode's weight branches (the pruned
/// alternative is the zero weight).
fn theta_weights(
    spec: &SupernetSpec,
    tape: &mut Tape,
    gi: usize,
    w: Var,
    th: Var,
) -> (Var, Var) {
    let (probs, counts) = theta_counts(spec, tape, gi, th);
    let weff = match spec.search {
        SearchMode::Prune => {
            let branches = [spec.quants[0], QuantKind::Zero];
            tape.effective_weights(w, probs, &branches)
        }
        _ => tape.effective_weights(w, probs, &spec.quants),
    };
    (counts, weff)
}

/// Run the supernet forward on `tape`. `running` holds each conv's BN
/// running `(mean, var)` for inference mode.
#[allow(clippy::too_many_arguments)]
pub fn forward(
    spec: &SupernetSpec,
    tape: &mut Tape,
    lv: &[LayerVars],
    fc_w: Var,
    fc_b: Var,
    fc_pack: Option<&PackHandle>,
    x: Var,
    training: bool,
    running: &[(Vec<f32>, Vec<f32>)],
) -> ForwardOut {
    let mut counts: Vec<Option<Var>> = vec![None; spec.layers.len()];
    let mut stats: Vec<Option<(Vec<f32>, Vec<f32>)>> = vec![None; spec.layers.len()];

    let conv_bn = |tape: &mut Tape,
                       gi: usize,
                       input: Var,
                       with_relu: bool,
                       counts: &mut Vec<Option<Var>>,
                       stats: &mut Vec<Option<(Vec<f32>, Vec<f32>)>>|
     -> Var {
        let g = &spec.layers[gi];
        let p = &lv[gi];
        let weff = match p.theta {
            Some(th) => {
                let (cv, weff) = theta_weights(spec, tape, gi, p.w, th);
                counts[gi] = Some(cv);
                weff
            }
            // fixed-precision layers run on the primary CU's representation
            None => tape.fake_quant_ste(p.w, spec.quants[0]),
        };
        let y = match g.ltype {
            LayerType::Dw => tape.dw_conv2d(input, weff, g.k, g.stride),
            _ => tape.conv2d_with_pack(input, weff, g.k, g.stride, p.pack.as_ref()),
        };
        let y = if training {
            let (y, mean, var) = tape.batch_norm_train(y, p.scale, p.bias);
            stats[gi] = Some((mean, var));
            y
        } else {
            let (mean, var) = &running[gi];
            let sv = tape.val(p.scale).data.clone();
            let bv = tape.val(p.bias).data.clone();
            let a: Vec<f32> = sv
                .iter()
                .zip(var)
                .map(|(&s, &v)| s / (v + BN_EPS).sqrt())
                .collect();
            let b: Vec<f32> = bv
                .iter()
                .zip(mean.iter().zip(&a))
                .map(|(&bb, (&m, &aa))| bb - m * aa)
                .collect();
            tape.channel_affine(y, a, b)
        };
        if with_relu {
            tape.relu(y)
        } else {
            y
        }
    };

    let mut cur = x;
    for step in &spec.plan {
        match *step {
            PlanStep::Conv(i) => {
                cur = conv_bn(tape, i, cur, true, &mut counts, &mut stats);
            }
            PlanStep::ResBlock { c1, c2, dn } => {
                let h = conv_bn(tape, c1, cur, true, &mut counts, &mut stats);
                let h2 = conv_bn(tape, c2, h, false, &mut counts, &mut stats);
                let sc = match dn {
                    Some(d) => conv_bn(tape, d, cur, false, &mut counts, &mut stats),
                    None => cur,
                };
                let sum = tape.add(h2, sc);
                cur = tape.relu(sum);
            }
            PlanStep::DwPw { dw, pw } => {
                cur = conv_bn(tape, dw, cur, true, &mut counts, &mut stats);
                cur = conv_bn(tape, pw, cur, true, &mut counts, &mut stats);
            }
        }
    }
    let pooled = tape.global_avg_pool(cur);
    let z = tape.matmul_with_pack(pooled, fc_w, fc_pack);
    let logits = tape.add_bias(z, fc_b);
    ForwardOut {
        logits,
        counts,
        batch_stats: stats,
    }
}

/// Leaf initialization for one conv weight (He normal, seeded stream).
pub fn init_conv_weight(spec: &SupernetSpec, gi: usize, seed: u64, leaf_tag: u64) -> Vec<f32> {
    let shape = spec.w_shape(gi);
    let fan_in = spec.fan_in(gi);
    let std = (2.0 / fan_in as f32).sqrt();
    let mut rng = crate::datasets::rng::Rng::from_stream(seed, 0xD1A0, leaf_tag);
    (0..shape.iter().product::<usize>())
        .map(|_| std * rng.normal())
        .collect()
}

/// FC head init (matches `layers.fc_init`): `w ~ N(0, 1/cin)`, `b = 0`.
pub fn init_fc(cin: usize, cout: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let std = (1.0 / cin as f32).sqrt();
    let mut rng = crate::datasets::rng::Rng::from_stream(seed, 0xFC00, 0);
    let w = (0..cin * cout).map(|_| std * rng.normal()).collect();
    (w, vec![0.0; cout])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_grammar_parses() {
        let s = SupernetSpec::build("diana_resnet20_c10").unwrap();
        assert_eq!(s.platform.name(), "diana");
        assert_eq!(s.arch, Arch::Resnet20);
        assert_eq!(s.dataset.classes, 10);
        assert!(!s.fixed);
        assert_eq!(s.search, SearchMode::Channel);
        // resnet20 scaled: stem + 9 blocks (2 convs + 2 downsamples) + fc
        assert_eq!(s.layers.last().unwrap().name, "fc");
        assert!(s.layers.len() > 10);

        let f = SupernetSpec::build("trident_mbv1_c10_fixed").unwrap();
        assert!(f.fixed);
        assert!(f.layers.iter().all(|l| !l.searchable));
        assert_eq!(f.quants.len(), 3);

        let w = SupernetSpec::build("darkside_mbv1_c10_w050").unwrap();
        // widths halved vs the full net
        let full = SupernetSpec::build("darkside_mbv1_c10").unwrap();
        let wi = w.layers.iter().find(|l| l.name == "b6pw").unwrap();
        let fi = full.layers.iter().find(|l| l.name == "b6pw").unwrap();
        assert_eq!(wi.cout * 2, fi.cout);

        assert!(SupernetSpec::build("nosuchsoc_resnet20_c10").is_err());
        assert!(SupernetSpec::build("diana_vgg_c10").is_err());
        assert!(SupernetSpec::build("diana_resnet20").is_err());
    }

    #[test]
    fn prune_and_layerwise_variants_parse() {
        let p = SupernetSpec::build("diana_resnet20_c10_prune").unwrap();
        assert_eq!(p.search, SearchMode::Prune);
        assert!(!p.fixed);
        assert_eq!(p.theta_shape(0), vec![p.layers[0].cout, 2]);
        // prune init keeps half the channels; only CU 0 carries cost
        let u = p.uniform_counts(0);
        assert_eq!(u[0], p.layers[0].cout as f64 / 2.0);
        assert!(u[1..].iter().all(|&x| x == 0.0));

        let l = SupernetSpec::build("gap9_mbv1_c10_layerwise").unwrap();
        assert_eq!(l.search, SearchMode::Layerwise);
        assert_eq!(l.theta_shape(0), vec![3]);
        // a layerwise θ row still pins ineligible CUs
        let dw_gi = l.layers.iter().position(|x| x.ltype == LayerType::Dw).unwrap();
        let t = l.theta_init(dw_gi);
        assert_eq!(t.len(), 3);

        assert!(SupernetSpec::build("diana_resnet20_c10_fixed_prune").is_err());
    }

    #[test]
    fn masks_follow_cu_ops() {
        // trident's DWE runs dw only: conv layers mask it out, dw layers
        // include it, and the aimc (no dw op) is masked for dw layers
        let s = SupernetSpec::build("trident_mbv1_c10").unwrap();
        let stem = &s.masks[0];
        assert_eq!(stem, &vec![true, false, true]);
        let dw_gi = s.layers.iter().position(|l| l.ltype == LayerType::Dw).unwrap();
        assert_eq!(s.masks[dw_gi], vec![true, true, false]);
    }

    #[test]
    fn theta_init_pins_masked_columns() {
        let s = SupernetSpec::build("trident_resnet20_c10").unwrap();
        let t = s.theta_init(0); // stem: conv → dwe masked
        let k = s.platform.n_cus();
        assert_eq!(t.len(), s.layers[0].cout * k);
        for c in 0..s.layers[0].cout {
            assert_eq!(t[c * k], 0.0);
            assert_eq!(t[c * k + 1], -ONE_HOT_LOGIT);
            assert_eq!(t[c * k + 2], 0.0);
        }
    }

    #[test]
    fn uniform_counts_sum_to_cout() {
        let s = SupernetSpec::build("trident_mbv1_c10").unwrap();
        for gi in 0..s.n_convs() {
            let n = s.uniform_counts(gi);
            let sum: f64 = n.iter().sum();
            assert!((sum - s.layers[gi].cout as f64).abs() < 1e-9, "layer {gi}");
        }
    }
}
