//! # ODiMO — One-shot Differentiable Mapping Optimizer (reproduction)
//!
//! Full-system reproduction of *"Optimizing DNN Inference on
//! Multi-Accelerator SoCs at Training-time"* (Risso, Burrello,
//! Jahier Pagliari — IEEE TCAD 2025) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **Layer 1/2 (build-time Python)** — Pallas kernels + JAX supernets,
//!   AOT-lowered to HLO text by `python/compile/aot.py`; never on the
//!   runtime path.
//! * **Layer 3 (this crate)** — the search coordinator: it drives a
//!   [`runtime::ModelBackend`] through the ODiMO three-phase schedule
//!   (Warmup → Search → Final-Training), sweeps the cost strength λ to
//!   trace Pareto fronts, discretizes θ into channel→CU assignments,
//!   and evaluates the resulting mappings on the SoC simulators in
//!   [`soc`]. Two backends implement the trait: the **native pure-Rust
//!   engine** ([`runtime::native`]: tensor + reverse-mode autodiff +
//!   K-column supernet builder — no artifacts needed, any registered
//!   SoC) and the XLA/PJRT artifact loader (`--backend xla`).
//!
//! The hardware substrate is **data-driven**: every SoC is a JSON
//! descriptor under `hw/` (schema: `hw/README.md`) loaded into the
//! platform registry ([`soc::spec`]). DIANA, Darkside, the synthetic
//! tri-CU `trident`, and the GAP9-style `gap9` SoC ship as built-ins;
//! dropping another `hw/<name>.json` adds a platform — with any number
//! of CUs — without touching simulator code. Mappings, discretization,
//! the Fig. 4 reorg pass, baselines, and all reports are N-way
//! accordingly.
//!
//! Training-free mapping optimization lives in [`search`]: a
//! [`search::SearchStrategy`] trait (greedy / coordinate descent /
//! random-restart) over a memoizing, simulator-backed
//! [`search::CostEvaluator`], with the λ grid swept across scoped
//! threads. The paper's manual baselines implement the same trait.
//!
//! Entry points: the `repro` binary (`rust/src/main.rs`) exposes every
//! paper experiment (`repro exp fig5 …`) plus the artifact-free
//! `repro exp socmap` deployment-pipeline sweep (`--search
//! greedy|descent|restart`) and `repro platforms`; `examples/` hold
//! smaller guided drivers; this library API is what all of them consume.

pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod experiments;
pub mod mapping;
pub mod pareto;
pub mod report;
pub mod runtime;
pub mod search;
pub mod soc;
pub mod stats;
pub mod util;

/// Repository root discovery: honors `ODIMO_ROOT`, else walks up from the
/// current directory looking for `hw/constants.json`.
pub fn repo_root() -> std::path::PathBuf {
    if let Ok(r) = std::env::var("ODIMO_ROOT") {
        return std::path::PathBuf::from(r);
    }
    let mut dir = std::env::current_dir().expect("cwd");
    loop {
        if dir.join("hw/constants.json").exists() {
            return dir;
        }
        if !dir.pop() {
            // fall back to the canonical checkout location
            return std::path::PathBuf::from("/root/repo");
        }
    }
}
