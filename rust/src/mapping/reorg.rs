//! The Fig. 4 layer re-organization pass, for any CU count.
//!
//! ODiMO's raw output assigns channels to CUs in arbitrary interleaved
//! order. Deployed as-is, the CU outputs would interleave in the shared
//! memory and force data marshaling. The paper's pass instead:
//!
//! 1. permutes each layer's output channels (and weight filters) so that
//!    all channels of the same CU are contiguous, in ascending CU column
//!    order (a *stable* grouping — relative order within a CU is
//!    preserved);
//! 2. permutes the **input**-channel dimension of the *next* layer's
//!    weights by the same permutation, preserving network function;
//! 3. splits the layer into one independent sub-layer per active CU.
//!
//! Here the pass operates on the mapping metadata (the simulator consumes
//! channel *counts*, not values), but it produces the exact permutations a
//! code generator would apply to the tensors, and the tests verify the
//! functional-preservation invariants (permutation validity, composition
//! consistency, contiguity after grouping).

use crate::soc::{LayerAssignment, Mapping};

/// Contiguous channel range owned by one CU after grouping.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SubLayer {
    pub cu: u8,
    /// range [start, end) in the *reorganized* channel order
    pub start: usize,
    pub end: usize,
}

/// Re-organization of one layer.
#[derive(Debug, Clone)]
pub struct LayerReorg {
    pub layer: String,
    /// `perm[new_pos] = old_channel`: gather permutation applied to the
    /// layer's output channels / weight filters
    pub perm: Vec<usize>,
    /// per-CU contiguous sub-layers in the new order (ascending CU column)
    pub sub_layers: Vec<SubLayer>,
    /// permutation the next layer must apply to its input-channel axis
    /// (identical to `perm` — recorded separately because the next layer
    /// may be non-searchable and still needs rewiring)
    pub next_input_perm: Vec<usize>,
}

/// Whole-network re-organization.
#[derive(Debug, Clone)]
pub struct NetworkReorg {
    pub layers: Vec<LayerReorg>,
}

/// Stable grouping permutation over `n_cus` columns: CU 0 channels first
/// (original order), then CU 1, and so on. Returns `perm` with
/// `perm[new] = old`.
fn grouping_perm(asg: &LayerAssignment, n_cus: usize) -> Vec<usize> {
    let mut perm = Vec::with_capacity(asg.cu_of.len());
    for want in 0..n_cus as u8 {
        for (c, &cu) in asg.cu_of.iter().enumerate() {
            if cu == want {
                perm.push(c);
            }
        }
    }
    perm
}

/// Apply the Fig. 4 pass to a whole mapping.
pub fn reorganize(mapping: &Mapping) -> NetworkReorg {
    assert!(
        mapping.is_well_formed(),
        "mapping references CU columns beyond platform '{}' ({} CUs)",
        mapping.platform.name(),
        mapping.platform.n_cus()
    );
    let n_cus = mapping.platform.n_cus();
    let mut layers = Vec::with_capacity(mapping.layers.len());
    for asg in &mapping.layers {
        let perm = grouping_perm(asg, n_cus);
        let counts = asg.counts(n_cus);
        let mut sub_layers = Vec::new();
        let mut start = 0usize;
        for (cu, &n) in counts.iter().enumerate() {
            if n > 0 {
                sub_layers.push(SubLayer {
                    cu: cu as u8,
                    start,
                    end: start + n,
                });
                start += n;
            }
        }
        layers.push(LayerReorg {
            layer: asg.layer.clone(),
            next_input_perm: perm.clone(),
            perm,
            sub_layers,
        });
    }
    NetworkReorg { layers }
}

impl LayerReorg {
    /// The assignment after re-organization (contiguous by construction).
    pub fn reorganized_assignment(&self, original: &LayerAssignment) -> LayerAssignment {
        LayerAssignment {
            layer: original.layer.clone(),
            cu_of: self.perm.iter().map(|&old| original.cu_of[old]).collect(),
        }
    }

    /// Check that `perm` is a valid permutation.
    pub fn is_valid_permutation(&self) -> bool {
        let n = self.perm.len();
        let mut seen = vec![false; n];
        for &p in &self.perm {
            if p >= n || seen[p] {
                return false;
            }
            seen[p] = true;
        }
        true
    }

    /// Apply the permutation to per-channel data (gather): simulates
    /// re-ordering weight filters / output slices.
    pub fn apply<T: Copy>(&self, data: &[T]) -> Vec<T> {
        self.perm.iter().map(|&old| data[old]).collect()
    }

    /// Inverse permutation (scatter view).
    pub fn inverse(&self) -> Vec<usize> {
        let mut inv = vec![0usize; self.perm.len()];
        for (new, &old) in self.perm.iter().enumerate() {
            inv[old] = new;
        }
        inv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::Platform;

    fn asg(cu_of: Vec<u8>) -> LayerAssignment {
        LayerAssignment {
            layer: "l".into(),
            cu_of,
        }
    }

    #[test]
    fn grouping_makes_contiguous_and_stable() {
        let a = asg(vec![1, 0, 1, 0, 0, 1]);
        let m = Mapping {
            platform: Platform::diana(),
            layers: vec![a.clone()],
        };
        let r = reorganize(&m);
        let lr = &r.layers[0];
        assert!(lr.is_valid_permutation());
        // CU0 channels in original order (1, 3, 4), then CU1 (0, 2, 5)
        assert_eq!(lr.perm, vec![1, 3, 4, 0, 2, 5]);
        let after = lr.reorganized_assignment(&a);
        assert!(after.is_contiguous());
        assert_eq!(after.count(0), a.count(0));
        assert_eq!(after.count(1), a.count(1));
    }

    #[test]
    fn sub_layers_cover_exactly() {
        let a = asg(vec![1, 0, 1, 1]);
        let m = Mapping {
            platform: Platform::diana(),
            layers: vec![a],
        };
        let r = reorganize(&m);
        let subs = &r.layers[0].sub_layers;
        assert_eq!(subs.len(), 2);
        assert_eq!((subs[0].start, subs[0].end), (0, 1));
        assert_eq!((subs[1].start, subs[1].end), (1, 4));
    }

    #[test]
    fn single_cu_gives_one_sublayer_identity_perm() {
        let a = asg(vec![0, 0, 0]);
        let m = Mapping {
            platform: Platform::darkside(),
            layers: vec![a],
        };
        let r = reorganize(&m);
        assert_eq!(r.layers[0].perm, vec![0, 1, 2]);
        assert_eq!(r.layers[0].sub_layers.len(), 1);
    }

    #[test]
    fn three_cu_grouping() {
        let a = asg(vec![2, 0, 1, 2, 0, 1, 2]);
        let m = Mapping {
            platform: Platform::trident(),
            layers: vec![a.clone()],
        };
        let r = reorganize(&m);
        let lr = &r.layers[0];
        assert!(lr.is_valid_permutation());
        // CU0 (1, 4), CU1 (2, 5), CU2 (0, 3, 6)
        assert_eq!(lr.perm, vec![1, 4, 2, 5, 0, 3, 6]);
        let after = lr.reorganized_assignment(&a);
        assert!(after.is_contiguous());
        assert_eq!(after.cu_of, vec![0, 0, 1, 1, 2, 2, 2]);
        let subs = &lr.sub_layers;
        assert_eq!(subs.len(), 3);
        assert_eq!((subs[0].cu, subs[0].start, subs[0].end), (0, 0, 2));
        assert_eq!((subs[1].cu, subs[1].start, subs[1].end), (1, 2, 4));
        assert_eq!((subs[2].cu, subs[2].start, subs[2].end), (2, 4, 7));
    }

    #[test]
    fn function_preservation_composition() {
        // gather(perm) followed by scatter(inverse) is the identity —
        // i.e. permuting the next layer's input axis by the same perm
        // undoes the output re-ordering.
        let a = asg(vec![1, 0, 0, 1, 0]);
        let m = Mapping {
            platform: Platform::diana(),
            layers: vec![a],
        };
        let r = reorganize(&m);
        let lr = &r.layers[0];
        let data: Vec<usize> = (0..5).collect();
        let shuffled = lr.apply(&data);
        let inv = lr.inverse();
        let mut back = vec![0usize; 5];
        for (new, &v) in shuffled.iter().enumerate() {
            back[lr.perm[new]] = v;
        }
        assert_eq!(back, data);
        // inverse is consistent
        for (old, &new) in inv.iter().enumerate() {
            assert_eq!(lr.perm[new], old);
        }
    }
}
