//! θ → deployment mapping: discretization, the Fig. 4 layer
//! re-organization pass, and one-hot θ construction for phase freezing and
//! baselines — all parameterized on the platform's CU count.
//!
//! After the Search phase the coordinator reads every layer's θ leaf and
//! discretizes it (Sec. IV-A: "the CU whose θ is associated with the
//! largest value is selected"). For DIANA-style channel assignment the raw
//! result interleaves CUs arbitrarily, so [`reorganize`] applies the
//! paper's Fig. 4 pass: group each layer's channels by CU (stable
//! permutation), split into per-CU sub-layers, and record the input-channel
//! permutation the *next* layer must absorb. Darkside-style split search
//! spaces are contiguous by construction (Eq. 6) and need no pass — this
//! is asserted, not assumed.

pub mod reorg;

pub use reorg::{reorganize, LayerReorg, NetworkReorg};

use std::str::FromStr;

use anyhow::{bail, Result};

use crate::soc::LayerAssignment;

/// Logit magnitude that makes softmax effectively one-hot (exp(±24) ratio).
pub const ONE_HOT_LOGIT: f32 = 12.0;

/// Search-space kinds (mirrors the manifest `search_kind`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchKind {
    /// per-channel K-way choice, θ shape `[C, K]` (DIANA-style; K = CU
    /// count of the platform)
    Channel,
    /// contiguous split position, θ shape `[C+1]` (Darkside, Eq. 6;
    /// inherently two-way)
    Split,
    /// one K-way choice per layer, θ shape `[K]` (path-based DNAS baseline)
    Layerwise,
    /// keep-vs-prune per channel, θ shape `[C, 2]` (pruning baseline;
    /// always two columns regardless of CU count)
    Prune,
}

impl FromStr for SearchKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<SearchKind> {
        Ok(match s {
            "channel" => SearchKind::Channel,
            "split" => SearchKind::Split,
            "layerwise" => SearchKind::Layerwise,
            "prune" => SearchKind::Prune,
            // plain baseline nets have no θ; Channel semantics are inert
            "fixed" => SearchKind::Channel,
            other => bail!(
                "unknown search kind '{other}' \
                 (expected channel|split|layerwise|prune|fixed)"
            ),
        })
    }
}

impl SearchKind {
    /// θ length for a layer with `cout` channels on an `n_cus`-CU platform.
    pub fn theta_len(&self, cout: usize, n_cus: usize) -> usize {
        match self {
            SearchKind::Channel => n_cus * cout,
            SearchKind::Prune => 2 * cout,
            SearchKind::Split => cout + 1,
            SearchKind::Layerwise => n_cus,
        }
    }

    /// Number of θ columns (choices per decision).
    pub fn columns(&self, n_cus: usize) -> usize {
        match self {
            SearchKind::Channel | SearchKind::Layerwise => n_cus,
            SearchKind::Prune => 2,
            SearchKind::Split => 2,
        }
    }
}

/// Discretize one layer's θ into a channel→CU assignment.
///
/// * `Channel`: per-row argmax of the `[C, K]` logits;
/// * `Prune`: per-row argmax of the `[C, 2]` keep/prune logits;
/// * `Split`: argmax over the `C+1` split positions — channels below the
///   split go to CU 0 (cluster), the rest to CU 1 (DWE);
/// * `Layerwise`: whole layer to the argmax column.
///
/// Ties resolve toward the lowest column (CU 0), as the paper specifies.
pub fn discretize(
    kind: SearchKind,
    theta: &[f32],
    cout: usize,
    n_cus: usize,
    layer: &str,
) -> LayerAssignment {
    assert_eq!(
        theta.len(),
        kind.theta_len(cout, n_cus),
        "{layer}: θ length mismatch"
    );
    if kind == SearchKind::Split {
        assert_eq!(n_cus, 2, "{layer}: split search is inherently two-way");
    }
    let cu_of = match kind {
        SearchKind::Channel | SearchKind::Prune => {
            let k = kind.columns(n_cus);
            (0..cout)
                .map(|c| argmax(&theta[c * k..(c + 1) * k]) as u8)
                .collect()
        }
        SearchKind::Split => {
            let split = argmax(theta);
            (0..cout).map(|c| u8::from(c >= split)).collect()
        }
        SearchKind::Layerwise => {
            let cu = argmax(theta) as u8;
            vec![cu; cout]
        }
    };
    LayerAssignment {
        layer: layer.to_string(),
        cu_of,
    }
}

/// Contiguous assignment from per-CU channel counts: `counts[0]` channels
/// on CU 0, then `counts[1]` on CU 1, ... — the canonical deployment-order
/// layout every counts-based optimizer (min-cost baseline, the `search`
/// strategies) shares.
pub fn assignment_from_counts(layer: &str, counts: &[usize]) -> LayerAssignment {
    let mut cu_of = Vec::with_capacity(counts.iter().sum());
    for (cu, &n) in counts.iter().enumerate() {
        cu_of.extend(std::iter::repeat(cu as u8).take(n));
    }
    LayerAssignment {
        layer: layer.to_string(),
        cu_of,
    }
}

/// Build the one-hot θ logits that freeze an assignment (used for the
/// Final-Training phase and for all deterministic baselines).
pub fn one_hot_theta(kind: SearchKind, asg: &LayerAssignment, n_cus: usize) -> Vec<f32> {
    let cout = asg.cu_of.len();
    match kind {
        SearchKind::Channel | SearchKind::Prune => {
            let k = kind.columns(n_cus);
            let mut t = vec![-ONE_HOT_LOGIT; k * cout];
            for (c, &cu) in asg.cu_of.iter().enumerate() {
                assert!(
                    (cu as usize) < k,
                    "{}: channel {c} on CU {cu}, but θ has {k} columns",
                    asg.layer
                );
                t[c * k + cu as usize] = ONE_HOT_LOGIT;
            }
            t
        }
        SearchKind::Split => {
            assert!(
                asg.is_contiguous(),
                "{}: split θ requires a contiguous assignment",
                asg.layer
            );
            let split = asg.cu_of.iter().filter(|&&c| c == 0).count();
            let mut t = vec![-ONE_HOT_LOGIT; cout + 1];
            t[split] = ONE_HOT_LOGIT;
            t
        }
        SearchKind::Layerwise => {
            let cu = asg.cu_of.first().copied().unwrap_or(0);
            assert!(
                asg.cu_of.iter().all(|&c| c == cu),
                "{}: layerwise θ requires a uniform assignment",
                asg.layer
            );
            let mut t = vec![-ONE_HOT_LOGIT; n_cus];
            t[cu as usize] = ONE_HOT_LOGIT;
            t
        }
    }
}

/// Softmax over θ rows → expected channel count per CU column (the
/// quantities the differentiable cost models consume). The returned vector
/// has one entry per θ column and sums to `cout`.
pub fn expected_counts(kind: SearchKind, theta: &[f32], cout: usize, n_cus: usize) -> Vec<f64> {
    match kind {
        SearchKind::Channel | SearchKind::Prune => {
            let k = kind.columns(n_cus);
            let mut counts = vec![0.0f64; k];
            for c in 0..cout {
                let row = &theta[c * k..(c + 1) * k];
                for (slot, p) in counts.iter_mut().zip(softmax(row)) {
                    *slot += p;
                }
            }
            counts
        }
        SearchKind::Split => {
            // g_c = P(split > c); n0 = Σ g_c
            let probs = softmax(theta);
            let mut cum = 0.0;
            let mut n0 = 0.0;
            for &p in probs.iter().take(cout) {
                cum += p;
                n0 += 1.0 - cum;
            }
            vec![n0, cout as f64 - n0]
        }
        SearchKind::Layerwise => softmax(theta)
            .into_iter()
            .map(|p| p * cout as f64)
            .collect(),
    }
}

fn softmax(row: &[f32]) -> Vec<f64> {
    let m = row.iter().cloned().fold(f32::MIN, f32::max) as f64;
    let exps: Vec<f64> = row.iter().map(|&t| ((t as f64) - m).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discretize_channel() {
        let theta = vec![1.0, 0.0, -1.0, 2.0, 0.5, 0.5];
        let a = discretize(SearchKind::Channel, &theta, 3, 2, "l");
        assert_eq!(a.cu_of, vec![0, 1, 0]); // ties go to CU 0
    }

    #[test]
    fn discretize_channel_three_way() {
        // rows of 3 logits on a 3-CU platform
        let theta = vec![
            1.0, 0.0, -1.0, // -> 0
            -1.0, 2.0, 0.0, // -> 1
            0.0, 0.5, 3.0, // -> 2
            0.5, 0.5, 0.5, // tie -> 0
        ];
        let a = discretize(SearchKind::Channel, &theta, 4, 3, "l");
        assert_eq!(a.cu_of, vec![0, 1, 2, 0]);
    }

    #[test]
    fn discretize_split_contiguous() {
        let mut theta = vec![0.0; 9]; // C=8
        theta[3] = 5.0;
        let a = discretize(SearchKind::Split, &theta, 8, 2, "l");
        assert_eq!(a.cu_of, vec![0, 0, 0, 1, 1, 1, 1, 1]);
        assert!(a.is_contiguous());
    }

    #[test]
    fn one_hot_roundtrip_channel() {
        let theta = vec![0.3, 0.9, 2.0, -1.0, 0.0, 0.1, -3.0, 4.0];
        let a = discretize(SearchKind::Channel, &theta, 4, 2, "l");
        let oh = one_hot_theta(SearchKind::Channel, &a, 2);
        let a2 = discretize(SearchKind::Channel, &oh, 4, 2, "l");
        assert_eq!(a, a2);
        // and the expected counts at one-hot θ are (near-)integral
        let n = expected_counts(SearchKind::Channel, &oh, 4, 2);
        assert!((n[0] - a.count(0) as f64).abs() < 1e-6);
        assert!((n[1] - a.count(1) as f64).abs() < 1e-6);
    }

    #[test]
    fn one_hot_roundtrip_channel_three_way() {
        let a = LayerAssignment {
            layer: "l".into(),
            cu_of: vec![2, 0, 1, 2, 1, 0],
        };
        let oh = one_hot_theta(SearchKind::Channel, &a, 3);
        assert_eq!(oh.len(), 18);
        let a2 = discretize(SearchKind::Channel, &oh, 6, 3, "l");
        assert_eq!(a, a2);
        let n = expected_counts(SearchKind::Channel, &oh, 6, 3);
        for (col, &want) in [2usize, 2, 2].iter().enumerate() {
            assert!((n[col] - want as f64).abs() < 1e-6, "col {col}: {n:?}");
        }
    }

    #[test]
    fn one_hot_roundtrip_split() {
        for split in 0..=6 {
            let a = LayerAssignment {
                layer: "l".into(),
                cu_of: (0..6).map(|c| u8::from(c >= split)).collect(),
            };
            let oh = one_hot_theta(SearchKind::Split, &a, 2);
            let a2 = discretize(SearchKind::Split, &oh, 6, 2, "l");
            assert_eq!(a, a2, "split={split}");
        }
    }

    #[test]
    fn layerwise_three_way() {
        let theta = vec![0.1, 2.0, -1.0];
        let a = discretize(SearchKind::Layerwise, &theta, 5, 3, "l");
        assert_eq!(a.cu_of, vec![1; 5]);
        let oh = one_hot_theta(SearchKind::Layerwise, &a, 3);
        assert_eq!(discretize(SearchKind::Layerwise, &oh, 5, 3, "l"), a);
        let n = expected_counts(SearchKind::Layerwise, &oh, 5, 3);
        assert!((n[1] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn expected_counts_sum_to_cout() {
        let theta = vec![0.2, -0.4, 1.0, 1.0, -2.0, 0.7];
        let n = expected_counts(SearchKind::Channel, &theta, 3, 2);
        assert!((n.iter().sum::<f64>() - 3.0).abs() < 1e-9);
        let theta_s = vec![0.1, -0.2, 0.5, 0.9];
        let m = expected_counts(SearchKind::Split, &theta_s, 3, 2);
        assert!((m.iter().sum::<f64>() - 3.0).abs() < 1e-9);
        assert!(m.iter().all(|&x| x >= 0.0));
        let theta_3 = vec![0.2, -0.4, 1.0, 1.0, -2.0, 0.7, 0.0, 0.1, 0.2];
        let t = expected_counts(SearchKind::Channel, &theta_3, 3, 3);
        assert_eq!(t.len(), 3);
        assert!((t.iter().sum::<f64>() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn assignment_from_counts_is_contiguous() {
        let a = assignment_from_counts("l", &[2, 0, 3]);
        assert_eq!(a.cu_of, vec![0, 0, 2, 2, 2]);
        assert!(a.is_contiguous());
        assert_eq!(a.counts(3), vec![2, 0, 3]);
        assert!(assignment_from_counts("l", &[0, 0]).cu_of.is_empty());
    }

    #[test]
    fn search_kind_from_str() {
        assert_eq!("channel".parse::<SearchKind>().unwrap(), SearchKind::Channel);
        assert_eq!("fixed".parse::<SearchKind>().unwrap(), SearchKind::Channel);
        assert_eq!("split".parse::<SearchKind>().unwrap(), SearchKind::Split);
        assert!("quantum".parse::<SearchKind>().is_err());
    }

    #[test]
    #[should_panic(expected = "θ length mismatch")]
    fn wrong_theta_len_panics() {
        discretize(SearchKind::Channel, &[0.0; 3], 2, 2, "l");
    }
}
