//! θ → deployment mapping: discretization, the Fig. 4 layer
//! re-organization pass, and one-hot θ construction for phase freezing and
//! baselines.
//!
//! After the Search phase the coordinator reads every layer's θ leaf and
//! discretizes it (Sec. IV-A: "the CU whose θ is associated with the
//! largest value is selected"). For DIANA-style channel assignment the raw
//! result interleaves CUs arbitrarily, so [`reorganize`] applies the
//! paper's Fig. 4 pass: group each layer's channels by CU (stable
//! permutation), split into per-CU sub-layers, and record the input-channel
//! permutation the *next* layer must absorb. Darkside-style split search
//! spaces are contiguous by construction (Eq. 6) and need no pass — this
//! is asserted, not assumed.

pub mod reorg;

pub use reorg::{reorganize, LayerReorg, NetworkReorg};

use crate::soc::LayerAssignment;

/// Logit magnitude that makes softmax effectively one-hot (exp(±24) ratio).
pub const ONE_HOT_LOGIT: f32 = 12.0;

/// Search-space kinds (mirrors the manifest `search_kind`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchKind {
    /// per-channel 2-way choice, θ shape `[C, 2]` (DIANA)
    Channel,
    /// contiguous split position, θ shape `[C+1]` (Darkside, Eq. 6)
    Split,
    /// one 2-way choice per layer, θ shape `[2]` (path-based DNAS baseline)
    Layerwise,
    /// keep-vs-prune per channel, θ shape `[C, 2]` (pruning baseline)
    Prune,
}

impl SearchKind {
    pub fn parse(s: &str) -> SearchKind {
        match s {
            "channel" => SearchKind::Channel,
            "split" => SearchKind::Split,
            "layerwise" => SearchKind::Layerwise,
            "prune" => SearchKind::Prune,
            // plain baseline nets have no θ; Channel semantics are inert
            "fixed" => SearchKind::Channel,
            other => panic!("unknown search kind '{other}'"),
        }
    }

    pub fn theta_len(&self, cout: usize) -> usize {
        match self {
            SearchKind::Channel | SearchKind::Prune => 2 * cout,
            SearchKind::Split => cout + 1,
            SearchKind::Layerwise => 2,
        }
    }
}

/// Discretize one layer's θ into a channel→CU assignment.
///
/// * `Channel`/`Prune`: per-row argmax of the `[C, 2]` logits;
/// * `Split`: argmax over the `C+1` split positions — channels below the
///   split go to CU 0 (cluster), the rest to CU 1 (DWE);
/// * `Layerwise`: whole layer to the argmax column.
pub fn discretize(kind: SearchKind, theta: &[f32], cout: usize, layer: &str) -> LayerAssignment {
    assert_eq!(
        theta.len(),
        kind.theta_len(cout),
        "{layer}: θ length mismatch"
    );
    let cu_of = match kind {
        SearchKind::Channel | SearchKind::Prune => (0..cout)
            .map(|c| u8::from(theta[2 * c + 1] > theta[2 * c]))
            .collect(),
        SearchKind::Split => {
            let split = argmax(theta);
            (0..cout).map(|c| u8::from(c >= split)).collect()
        }
        SearchKind::Layerwise => {
            let cu = u8::from(theta[1] > theta[0]);
            vec![cu; cout]
        }
    };
    LayerAssignment {
        layer: layer.to_string(),
        cu_of,
    }
}

/// Build the one-hot θ logits that freeze an assignment (used for the
/// Final-Training phase and for all deterministic baselines).
pub fn one_hot_theta(kind: SearchKind, asg: &LayerAssignment) -> Vec<f32> {
    let cout = asg.cu_of.len();
    match kind {
        SearchKind::Channel | SearchKind::Prune => {
            let mut t = vec![-ONE_HOT_LOGIT; 2 * cout];
            for (c, &cu) in asg.cu_of.iter().enumerate() {
                t[2 * c + cu as usize] = ONE_HOT_LOGIT;
            }
            t
        }
        SearchKind::Split => {
            assert!(
                asg.is_contiguous(),
                "{}: split θ requires a contiguous assignment",
                asg.layer
            );
            let split = asg.cu_of.iter().filter(|&&c| c == 0).count();
            let mut t = vec![-ONE_HOT_LOGIT; cout + 1];
            t[split] = ONE_HOT_LOGIT;
            t
        }
        SearchKind::Layerwise => {
            let cu = asg.cu_of.first().copied().unwrap_or(0);
            assert!(
                asg.cu_of.iter().all(|&c| c == cu),
                "{}: layerwise θ requires a uniform assignment",
                asg.layer
            );
            let mut t = vec![-ONE_HOT_LOGIT; 2];
            t[cu as usize] = ONE_HOT_LOGIT;
            t
        }
    }
}

/// Softmax over θ rows → expected channel counts `(n_cu0, n_cu1)` (the
/// quantities the differentiable cost models consume).
pub fn expected_counts(kind: SearchKind, theta: &[f32], cout: usize) -> (f64, f64) {
    match kind {
        SearchKind::Channel | SearchKind::Prune => {
            let mut n0 = 0.0;
            for c in 0..cout {
                let (a, b) = (theta[2 * c] as f64, theta[2 * c + 1] as f64);
                let m = a.max(b);
                let ea = (a - m).exp();
                let eb = (b - m).exp();
                n0 += ea / (ea + eb);
            }
            (n0, cout as f64 - n0)
        }
        SearchKind::Split => {
            // g_c = P(split > c); n0 = Σ g_c
            let m = theta.iter().cloned().fold(f32::MIN, f32::max) as f64;
            let exps: Vec<f64> = theta.iter().map(|&t| ((t as f64) - m).exp()).collect();
            let z: f64 = exps.iter().sum();
            let mut cum = 0.0;
            let mut n0 = 0.0;
            for c in 0..cout {
                cum += exps[c] / z;
                n0 += 1.0 - cum;
            }
            (n0, cout as f64 - n0)
        }
        SearchKind::Layerwise => {
            let (a, b) = (theta[0] as f64, theta[1] as f64);
            let m = a.max(b);
            let p0 = (a - m).exp() / ((a - m).exp() + (b - m).exp());
            (p0 * cout as f64, (1.0 - p0) * cout as f64)
        }
    }
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discretize_channel() {
        let theta = vec![1.0, 0.0, -1.0, 2.0, 0.5, 0.5];
        let a = discretize(SearchKind::Channel, &theta, 3, "l");
        assert_eq!(a.cu_of, vec![0, 1, 0]); // ties go to CU 0
    }

    #[test]
    fn discretize_split_contiguous() {
        let mut theta = vec![0.0; 9]; // C=8
        theta[3] = 5.0;
        let a = discretize(SearchKind::Split, &theta, 8, "l");
        assert_eq!(a.cu_of, vec![0, 0, 0, 1, 1, 1, 1, 1]);
        assert!(a.is_contiguous());
    }

    #[test]
    fn one_hot_roundtrip_channel() {
        let theta = vec![0.3, 0.9, 2.0, -1.0, 0.0, 0.1, -3.0, 4.0];
        let a = discretize(SearchKind::Channel, &theta, 4, "l");
        let oh = one_hot_theta(SearchKind::Channel, &a);
        let a2 = discretize(SearchKind::Channel, &oh, 4, "l");
        assert_eq!(a, a2);
        // and the expected counts at one-hot θ are (near-)integral
        let (n0, n1) = expected_counts(SearchKind::Channel, &oh, 4);
        assert!((n0 - a.count(0) as f64).abs() < 1e-6);
        assert!((n1 - a.count(1) as f64).abs() < 1e-6);
    }

    #[test]
    fn one_hot_roundtrip_split() {
        for split in 0..=6 {
            let a = LayerAssignment {
                layer: "l".into(),
                cu_of: (0..6).map(|c| u8::from(c >= split)).collect(),
            };
            let oh = one_hot_theta(SearchKind::Split, &a);
            let a2 = discretize(SearchKind::Split, &oh, 6, "l");
            assert_eq!(a, a2, "split={split}");
        }
    }

    #[test]
    fn expected_counts_sum_to_cout() {
        let theta = vec![0.2, -0.4, 1.0, 1.0, -2.0, 0.7];
        let (n0, n1) = expected_counts(SearchKind::Channel, &theta, 3);
        assert!((n0 + n1 - 3.0).abs() < 1e-9);
        let theta_s = vec![0.1, -0.2, 0.5, 0.9];
        let (m0, m1) = expected_counts(SearchKind::Split, &theta_s, 3);
        assert!((m0 + m1 - 3.0).abs() < 1e-9);
        assert!(m0 >= 0.0 && m1 >= 0.0);
    }

    #[test]
    #[should_panic(expected = "θ length mismatch")]
    fn wrong_theta_len_panics() {
        discretize(SearchKind::Channel, &[0.0; 3], 2, "l");
    }
}
