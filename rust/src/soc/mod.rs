//! SoC simulation substrate: the data-driven platform registry plus two
//! simulators that execute mappings on any registered platform.
//!
//! The paper evaluates ODiMO mappings on physical SoCs that are not
//! available here, so this module *is* the hardware (DESIGN.md §2):
//!
//! * [`spec`] — the platform registry: [`PlatformSpec`] / [`CuSpec`] /
//!   [`CuModel`] descriptors loaded from `hw/<name>.json` (schema:
//!   `hw/README.md`). DIANA, Darkside, the synthetic tri-CU `trident`,
//!   and the GAP9-style `gap9` SoC ship as built-ins; any further
//!   descriptor dropped under `hw/` is discovered at runtime — CU counts
//!   are unbounded and nothing downstream hardcodes "two";
//! * [`hw`] — the shared detailed-sim constants (`hw/constants.json`,
//!   also read by the Python differentiable cost models);
//! * [`model`] — layers, N-way mappings, execution reports;
//! * [`analytical`] — the exact integer version of the differentiable
//!   cost models (what ODiMO believes), dispatching per CU on its
//!   descriptor's cost-model kind;
//! * [`detailed`] — the event-driven simulator standing in for silicon
//!   measurements (what the deployment tables report).
//!
//! Table III is precisely the comparison `analytical` vs `detailed`;
//! Table IV runs whole mapped networks through `detailed`.

pub mod analytical;
pub mod detailed;
pub mod hw;
pub mod model;
pub mod spec;

pub use model::{
    CuCost, ExecReport, Layer, LayerAssignment, LayerReport, LayerType, Mapping,
};
pub use spec::{platform_names, CuModel, CuSpec, Platform, PlatformSpec};

use anyhow::Result;

use crate::runtime::Manifest;

/// Build the simulator layer list from a variant manifest.
pub fn layers_from_manifest(m: &Manifest) -> Result<Vec<Layer>> {
    m.layers.iter().map(Layer::from_spec).collect()
}

/// Names of sequential-stage layers for a manifest (the DW→PW dependency
/// of the `dw_vs_dwsep` ImageNet search space). Only the Darkside *split*
/// search space has serial CU stages; channel-split supernets (the native
/// backend's K-way spaces) run their CU stages concurrently even on the
/// same variant names.
pub fn sequential_layers(m: &Manifest) -> Vec<String> {
    if m.variant.contains("imgnet") && m.platform == "darkside" && m.search_kind == "split" {
        m.layers
            .iter()
            .filter(|l| l.searchable)
            .map(|l| l.name.clone())
            .collect()
    } else {
        Vec::new()
    }
}
