//! SoC simulation substrate: the DIANA and Darkside platforms.
//!
//! The paper evaluates ODiMO mappings on two physical SoCs that are not
//! available here, so this module *is* the hardware (DESIGN.md §2):
//!
//! * [`hw`] — constants shared with the Python cost models;
//! * [`model`] — layers, CUs, mappings, execution reports;
//! * [`analytical`] — the exact integer version of the differentiable
//!   cost models (what ODiMO believes);
//! * [`detailed`] — the event-driven simulator standing in for silicon
//!   measurements (what the deployment tables report).
//!
//! Table III is precisely the comparison `analytical` vs `detailed`;
//! Table IV runs whole mapped networks through `detailed`.

pub mod analytical;
pub mod detailed;
pub mod hw;
pub mod model;

pub use model::{Cu, CuCost, ExecReport, Layer, LayerAssignment, LayerReport, LayerType, Mapping, Platform};

use crate::runtime::Manifest;

/// Build the simulator layer list from a variant manifest.
pub fn layers_from_manifest(m: &Manifest) -> Vec<Layer> {
    m.layers.iter().map(Layer::from_spec).collect()
}

/// Names of sequential-stage layers for a manifest (the DW→PW dependency
/// of the `dw_vs_dwsep` ImageNet search space).
pub fn sequential_layers(m: &Manifest) -> Vec<String> {
    if m.variant.contains("imgnet") && m.platform == "darkside" {
        m.layers
            .iter()
            .filter(|l| l.searchable)
            .map(|l| l.name.clone())
            .collect()
    } else {
        Vec::new()
    }
}
