//! Shared hardware constants — the `detailed_sim` globals (DMA engine, L1
//! banking, fabric sync, pipeline warm-up) both simulators read.
//!
//! Per-CU cost coefficients live in the platform descriptors
//! (`hw/<name>.json` → [`super::spec`]); `hw/constants.json` keeps the
//! legacy flat view of the DIANA/Darkside numbers for the Python
//! differentiable cost models (`python/compile/costs.py`) plus the
//! `detailed_sim` section parsed here. The file is read from the checkout
//! at runtime (so constants can be tuned without recompiling) with the
//! compile-time embedded copy as fallback; a drift test asserts the legacy
//! view matches the descriptors coefficient-for-coefficient.

use anyhow::Result;

use crate::util::json::{parse, Value};

pub const HW_JSON: &str = include_str!("../../../hw/constants.json");

/// Detailed-simulator globals (shared by every platform).
#[derive(Debug, Clone)]
pub struct DetailedSim {
    pub dma_setup_cycles: u64,
    pub dma_bytes_per_cycle: f64,
    pub l1_banks: usize,
    pub bank_conflict_prob: f64,
    pub fabric_sync_cycles: u64,
    pub pipeline_warmup_rows: u64,
}

#[derive(Debug, Clone)]
pub struct HwConstants {
    pub detailed_sim: DetailedSim,
}

fn parse_constants(v: &Value) -> Result<HwConstants> {
    let de = v.req("detailed_sim")?;
    Ok(HwConstants {
        detailed_sim: DetailedSim {
            dma_setup_cycles: de.f64_of("dma_setup_cycles")? as u64,
            dma_bytes_per_cycle: de.f64_of("dma_bytes_per_cycle")?,
            l1_banks: de.usize_of("l1_banks")?,
            bank_conflict_prob: de.f64_of("bank_conflict_prob")?,
            fabric_sync_cycles: de.f64_of("fabric_sync_cycles")? as u64,
            pipeline_warmup_rows: de.f64_of("pipeline_warmup_rows")? as u64,
        },
    })
}

impl HwConstants {
    /// The active constants: `repo_root()/hw/constants.json` when readable,
    /// the embedded copy otherwise. Cached for the process lifetime.
    pub fn load() -> &'static HwConstants {
        use std::sync::OnceLock;
        static HW: OnceLock<HwConstants> = OnceLock::new();
        HW.get_or_init(|| {
            let path = crate::repo_root().join("hw").join("constants.json");
            let from_file = std::fs::read_to_string(&path).ok().and_then(|text| {
                match parse(&text).and_then(|v| parse_constants(&v)) {
                    Ok(hw) => Some(hw),
                    Err(e) => {
                        // a checkout file that exists but doesn't parse is
                        // a tuning mistake, not a missing file — say so
                        // instead of silently using the embedded defaults
                        eprintln!(
                            "warning: {} is unreadable ({e:#}); using embedded constants",
                            path.display()
                        );
                        None
                    }
                }
            });
            from_file.unwrap_or_else(|| {
                let v = parse(HW_JSON).expect("embedded hw/constants.json parses");
                parse_constants(&v).expect("embedded hw/constants.json has all fields")
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::spec::{CuModel, Platform};

    #[test]
    fn constants_parse_and_are_sane() {
        let hw = HwConstants::load();
        let d = &hw.detailed_sim;
        assert!(d.dma_bytes_per_cycle > 0.0);
        assert!((0.0..1.0).contains(&d.bank_conflict_prob));
        assert!(d.l1_banks > 0);
        assert!(d.fabric_sync_cycles > 0);
    }

    /// `hw/constants.json` is the legacy flat view of the built-in
    /// descriptors; this pins every shared coefficient so the Python cost
    /// models and the Rust specs cannot drift apart.
    #[test]
    fn legacy_constants_match_builtin_specs() {
        let v = parse(HW_JSON).unwrap();

        let diana = Platform::diana().spec();
        let dj = v.req("diana").unwrap();
        assert_eq!(dj.f64_of("freq_mhz").unwrap(), diana.freq_mhz);
        assert_eq!(dj.f64_of("p_idle_mw").unwrap(), diana.p_idle_mw);
        let digital = &diana.cus[0];
        let dd = dj.req("digital").unwrap();
        assert_eq!(dd.usize_of("setup_cycles").unwrap() as u64, digital.setup_cycles);
        assert_eq!(dd.f64_of("p_act_mw").unwrap(), digital.p_act_mw);
        match digital.model {
            CuModel::PeGrid {
                pe_rows,
                pe_cols,
                macs_per_cycle_per_pe,
                weight_load_bytes_per_cycle,
                dw_inefficiency,
            } => {
                assert_eq!(dd.usize_of("pe_rows").unwrap(), pe_rows);
                assert_eq!(pe_rows, 16, "DIANA's grid is 16x16 in the paper");
                assert_eq!(dd.usize_of("pe_cols").unwrap(), pe_cols);
                assert_eq!(
                    dd.f64_of("macs_per_cycle_per_pe").unwrap(),
                    macs_per_cycle_per_pe
                );
                assert_eq!(
                    dd.f64_of("weight_load_bytes_per_cycle").unwrap(),
                    weight_load_bytes_per_cycle
                );
                assert_eq!(
                    dj.f64_of("dw_digital_inefficiency").unwrap(),
                    dw_inefficiency
                );
            }
            ref other => panic!("diana cu0 should be a pe_grid, got {other:?}"),
        }
        let analog = &diana.cus[1];
        let da = dj.req("analog").unwrap();
        assert_eq!(da.usize_of("setup_cycles").unwrap() as u64, analog.setup_cycles);
        assert_eq!(da.f64_of("p_act_mw").unwrap(), analog.p_act_mw);
        match analog.model {
            CuModel::AnalogArray {
                array_rows,
                array_cols,
                cells_load_per_cycle,
                cycles_per_analog_op,
            } => {
                assert_eq!(da.usize_of("array_rows").unwrap(), array_rows);
                assert_eq!(da.usize_of("array_cols").unwrap(), array_cols);
                assert!(array_rows * array_cols >= 500_000, "500k-cell AIMC array");
                assert_eq!(
                    da.f64_of("cells_load_per_cycle").unwrap(),
                    cells_load_per_cycle
                );
                assert_eq!(
                    da.f64_of("cycles_per_analog_op").unwrap(),
                    cycles_per_analog_op
                );
            }
            ref other => panic!("diana cu1 should be an analog_array, got {other:?}"),
        }

        let darkside = Platform::darkside().spec();
        let sj = v.req("darkside").unwrap();
        assert_eq!(sj.f64_of("freq_mhz").unwrap(), darkside.freq_mhz);
        assert_eq!(sj.f64_of("p_idle_mw").unwrap(), darkside.p_idle_mw);
        let cluster = &darkside.cus[0];
        let sc = sj.req("cluster").unwrap();
        match cluster.model {
            CuModel::SimdCluster {
                cores,
                macs_per_cycle_std,
                macs_per_cycle_dw,
                im2col_overhead,
            } => {
                assert_eq!(sc.usize_of("cores").unwrap(), cores);
                assert_eq!(sc.f64_of("macs_per_cycle_std").unwrap(), macs_per_cycle_std);
                assert_eq!(sc.f64_of("macs_per_cycle_dw").unwrap(), macs_per_cycle_dw);
                assert!(
                    macs_per_cycle_std > macs_per_cycle_dw,
                    "software dw is the cluster's weak spot"
                );
                assert_eq!(sc.f64_of("im2col_overhead").unwrap(), im2col_overhead);
            }
            ref other => panic!("darkside cu0 should be a simd_cluster, got {other:?}"),
        }
        let dwe = &darkside.cus[1];
        let sd = sj.req("dwe").unwrap();
        match (&dwe.model, &cluster.model) {
            (
                CuModel::DwEngine {
                    macs_per_cycle,
                    weight_cfg_cells_per_cycle,
                },
                CuModel::SimdCluster {
                    macs_per_cycle_dw, ..
                },
            ) => {
                assert_eq!(sd.f64_of("macs_per_cycle").unwrap(), *macs_per_cycle);
                assert_eq!(
                    sd.f64_of("weight_cfg_cells_per_cycle").unwrap(),
                    *weight_cfg_cells_per_cycle
                );
                assert!(
                    *macs_per_cycle > *macs_per_cycle_dw,
                    "the DWE must beat the cluster at its own game"
                );
            }
            other => panic!("unexpected darkside models: {other:?}"),
        }
    }
}
