//! Hardware constants — deserialized from `hw/constants.json`, the single
//! source of truth shared with the Python differentiable cost models
//! (`python/compile/costs.py`). The file is embedded at compile time so
//! the simulator cannot drift from the checked-in constants.

use anyhow::Result;

use crate::util::json::{parse, Value};

pub const HW_JSON: &str = include_str!("../../../hw/constants.json");

#[derive(Debug, Clone)]
pub struct DianaDigital {
    pub pe_rows: usize,
    pub pe_cols: usize,
    pub macs_per_cycle_per_pe: f64,
    pub weight_load_bytes_per_cycle: f64,
    pub setup_cycles: u64,
    pub p_act_mw: f64,
}

#[derive(Debug, Clone)]
pub struct DianaAnalog {
    pub array_rows: usize,
    pub array_cols: usize,
    pub cells_load_per_cycle: f64,
    pub cycles_per_analog_op: f64,
    pub setup_cycles: u64,
    pub p_act_mw: f64,
}

#[derive(Debug, Clone)]
pub struct Diana {
    pub freq_mhz: f64,
    pub digital: DianaDigital,
    pub analog: DianaAnalog,
    pub p_idle_mw: f64,
    pub dw_digital_inefficiency: f64,
}

#[derive(Debug, Clone)]
pub struct DarksideCluster {
    pub cores: usize,
    pub macs_per_cycle_std: f64,
    pub macs_per_cycle_dw: f64,
    pub im2col_overhead: f64,
    pub setup_cycles: u64,
    pub p_act_mw: f64,
}

#[derive(Debug, Clone)]
pub struct DarksideDwe {
    pub macs_per_cycle: f64,
    pub weight_cfg_cells_per_cycle: f64,
    pub setup_cycles: u64,
    pub p_act_mw: f64,
}

#[derive(Debug, Clone)]
pub struct Darkside {
    pub freq_mhz: f64,
    pub cluster: DarksideCluster,
    pub dwe: DarksideDwe,
    pub p_idle_mw: f64,
}

#[derive(Debug, Clone)]
pub struct DetailedSim {
    pub dma_setup_cycles: u64,
    pub dma_bytes_per_cycle: f64,
    pub l1_banks: usize,
    pub bank_conflict_prob: f64,
    pub fabric_sync_cycles: u64,
    pub pipeline_warmup_rows: u64,
    pub diana_analog_variability: f64,
    pub diana_digital_stall_factor: f64,
    pub darkside_cluster_stall_factor: f64,
    pub darkside_dwe_stall_factor: f64,
}

#[derive(Debug, Clone)]
pub struct HwConstants {
    pub diana: Diana,
    pub darkside: Darkside,
    pub detailed_sim: DetailedSim,
}

fn parse_constants(v: &Value) -> Result<HwConstants> {
    let di = v.req("diana")?;
    let dd = di.req("digital")?;
    let da = di.req("analog")?;
    let ds = v.req("darkside")?;
    let dc = ds.req("cluster")?;
    let dw = ds.req("dwe")?;
    let de = v.req("detailed_sim")?;
    Ok(HwConstants {
        diana: Diana {
            freq_mhz: di.f64_of("freq_mhz")?,
            digital: DianaDigital {
                pe_rows: dd.usize_of("pe_rows")?,
                pe_cols: dd.usize_of("pe_cols")?,
                macs_per_cycle_per_pe: dd.f64_of("macs_per_cycle_per_pe")?,
                weight_load_bytes_per_cycle: dd.f64_of("weight_load_bytes_per_cycle")?,
                setup_cycles: dd.f64_of("setup_cycles")? as u64,
                p_act_mw: dd.f64_of("p_act_mw")?,
            },
            analog: DianaAnalog {
                array_rows: da.usize_of("array_rows")?,
                array_cols: da.usize_of("array_cols")?,
                cells_load_per_cycle: da.f64_of("cells_load_per_cycle")?,
                cycles_per_analog_op: da.f64_of("cycles_per_analog_op")?,
                setup_cycles: da.f64_of("setup_cycles")? as u64,
                p_act_mw: da.f64_of("p_act_mw")?,
            },
            p_idle_mw: di.f64_of("p_idle_mw")?,
            dw_digital_inefficiency: di.f64_of("dw_digital_inefficiency")?,
        },
        darkside: Darkside {
            freq_mhz: ds.f64_of("freq_mhz")?,
            cluster: DarksideCluster {
                cores: dc.usize_of("cores")?,
                macs_per_cycle_std: dc.f64_of("macs_per_cycle_std")?,
                macs_per_cycle_dw: dc.f64_of("macs_per_cycle_dw")?,
                im2col_overhead: dc.f64_of("im2col_overhead")?,
                setup_cycles: dc.f64_of("setup_cycles")? as u64,
                p_act_mw: dc.f64_of("p_act_mw")?,
            },
            dwe: DarksideDwe {
                macs_per_cycle: dw.f64_of("macs_per_cycle")?,
                weight_cfg_cells_per_cycle: dw.f64_of("weight_cfg_cells_per_cycle")?,
                setup_cycles: dw.f64_of("setup_cycles")? as u64,
                p_act_mw: dw.f64_of("p_act_mw")?,
            },
            p_idle_mw: ds.f64_of("p_idle_mw")?,
        },
        detailed_sim: DetailedSim {
            dma_setup_cycles: de.f64_of("dma_setup_cycles")? as u64,
            dma_bytes_per_cycle: de.f64_of("dma_bytes_per_cycle")?,
            l1_banks: de.usize_of("l1_banks")?,
            bank_conflict_prob: de.f64_of("bank_conflict_prob")?,
            fabric_sync_cycles: de.f64_of("fabric_sync_cycles")? as u64,
            pipeline_warmup_rows: de.f64_of("pipeline_warmup_rows")? as u64,
            diana_analog_variability: de.f64_of("diana_analog_variability")?,
            diana_digital_stall_factor: de.f64_of("diana_digital_stall_factor")?,
            darkside_cluster_stall_factor: de.f64_of("darkside_cluster_stall_factor")?,
            darkside_dwe_stall_factor: de.f64_of("darkside_dwe_stall_factor")?,
        },
    })
}

impl HwConstants {
    pub fn load() -> &'static HwConstants {
        use std::sync::OnceLock;
        static HW: OnceLock<HwConstants> = OnceLock::new();
        HW.get_or_init(|| {
            let v = parse(HW_JSON).expect("hw/constants.json parses");
            parse_constants(&v).expect("hw/constants.json has all fields")
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_parse_and_are_sane() {
        let hw = HwConstants::load();
        assert_eq!(hw.diana.digital.pe_rows, 16);
        assert!(hw.diana.analog.array_rows * hw.diana.analog.array_cols >= 500_000);
        assert!(hw.darkside.cluster.macs_per_cycle_std > hw.darkside.cluster.macs_per_cycle_dw);
        assert!(hw.darkside.dwe.macs_per_cycle > hw.darkside.cluster.macs_per_cycle_dw);
        assert!(hw.detailed_sim.bank_conflict_prob < 1.0);
        assert!(hw.diana.freq_mhz > 0.0 && hw.darkside.freq_mhz > 0.0);
    }
}
