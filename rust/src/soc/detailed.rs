//! Detailed event-driven SoC simulator — the "measured hardware" stand-in,
//! for any registered platform.
//!
//! Where `analytical.rs` is the idealized model ODiMO searches with, this
//! simulator executes a mapping phase by phase the way the real SoCs do:
//!
//! * the fabric controller dispatches each layer (sync cost);
//! * each active CU issues a **DMA job** to fetch the layer input from L2
//!   into the shared L1 — the single DMA channel serializes these in CU
//!   column order (each CU loads the whole input, the redundancy the
//!   paper's Sec. IV-A accepts);
//! * weight load / array configuration runs per CU;
//! * compute runs concurrently across the active CUs, but whenever several
//!   CUs are active the banked L1 arbiter loses a fraction of cycles to
//!   conflicts (`bank_conflict_prob`), modeled as a mutual slowdown over
//!   each pairwise overlap window (fixpoint iteration) — so a 3-way
//!   overlap contends more than a 2-way one;
//! * per-CU pipeline warm-up and deterministic per-(layer, CU) variability
//!   (hash-seeded, amplitude from the descriptor's `variability`; the
//!   analog AIMC array is the noisiest, matching the error ordering of
//!   paper Table III).
//!
//! None of these components exist in the analytical model, so the
//! analytical numbers *underestimate* the detailed ones — the paper makes
//! the same observation about its models vs the real chips, and Table III
//! quantifies exactly this gap.

use super::analytical::{cu_cycles, power};
use super::hw::HwConstants;
use super::model::{CuCost, ExecReport, Layer, LayerReport, Mapping};
use super::spec::{CuSpec, Platform};

/// Deterministic per-(layer, CU) jitter in [0, 1): FNV-1a hash mapped to
/// the unit interval. Stands in for data-dependent timing (analog
/// variability, cache behaviour) while keeping runs exactly reproducible.
/// Keyed on layer + CU name only — the same key the enum-based seed used
/// (CU labels became CU names verbatim), so DIANA/Darkside detailed
/// numbers are bit-identical to the pre-registry code; same-named CUs on
/// different platforms merely share noise, which is harmless.
fn jitter(layer: &str, cu: &str) -> f64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in layer.bytes().chain(cu.bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// One CU's work for one layer, split into its pipeline phases.
#[derive(Debug, Clone, Copy)]
struct CuJob {
    channels: usize,
    dma_cycles: u64,
    weight_cycles: u64,
    compute_cycles: u64,
}

fn build_job(layer: &Layer, cu: &CuSpec, n: usize) -> Option<CuJob> {
    if n == 0 {
        return None;
    }
    let d = &HwConstants::load().detailed_sim;
    let base = cu_cycles(cu, layer, n); // analytical total (incl. setup)
    let mut compute = base as f64;
    compute *= 1.0 + cu.stall_factor;
    // descriptor-scaled deterministic jitter so no two layers are
    // bit-identical; noisy CUs (analog arrays) get proportionally more
    compute *= 1.0 + cu.variability.max(0.01) * jitter(&layer.name, &cu.name);
    let warmup = d.pipeline_warmup_rows * layer.ox as u64;
    let dma = d.dma_setup_cycles + (layer.input_bytes() as f64 / d.dma_bytes_per_cycle) as u64;
    Some(CuJob {
        channels: n,
        dma_cycles: dma,
        weight_cycles: warmup,
        compute_cycles: compute as u64,
    })
}

/// Resolve the compute-overlap contention between any number of jobs.
///
/// Every job's compute starts at its `start`; while job `i` overlaps any
/// other running job, each overlapped cycle has probability `p` of a bank
/// conflict, stretching the job by `1/(1-p)` over that window. Pairwise
/// overlaps accumulate, so three concurrently-active CUs contend more than
/// two. Returns each job's end time; solved by fixpoint iteration (a
/// handful of steps suffices for the small CU counts involved).
fn resolve_overlap(starts: &[u64], durs: &[u64], p: f64) -> Vec<u64> {
    let slow = 1.0 / (1.0 - p);
    let mut ends: Vec<u64> = starts.iter().zip(durs).map(|(&s, &d)| s + d).collect();
    for _ in 0..8 {
        let mut new_ends = ends.clone();
        for i in 0..durs.len() {
            if durs[i] == 0 {
                continue;
            }
            let mut overlap = 0.0;
            for j in 0..durs.len() {
                if j == i || durs[j] == 0 {
                    continue;
                }
                let ov_start = starts[i].max(starts[j]);
                let ov_end = ends[i].min(ends[j]);
                // cycles executed inside this pairwise window get stretched
                overlap += ov_end.saturating_sub(ov_start).min(durs[i]) as f64;
            }
            let stretched = durs[i] as f64 + overlap * (slow - 1.0);
            new_ends[i] = starts[i] + stretched as u64;
        }
        if new_ends == ends {
            break;
        }
        ends = new_ends;
    }
    ends
}

/// Simulate one layer in isolation under per-CU channel `counts`: per-CU
/// costs (cycles measured from the layer's start) and the layer latency
/// including the fabric sync. The detailed pipeline restarts at every
/// layer boundary (the fabric controller re-dispatches), so whole-network
/// execution is exactly the sum of these latencies — the decomposition the
/// incremental search evaluator relies on, pinned by `tests/search.rs`.
pub fn sim_layer(
    platform: Platform,
    layer: &Layer,
    counts: &[usize],
    sequential: bool,
) -> (Vec<CuCost>, u64) {
    let d = &HwConstants::load().detailed_sim;
    let cus = platform.cus();
    let k = cus.len();
    let jobs: Vec<Option<CuJob>> = cus
        .iter()
        .zip(counts)
        .map(|(cu, &n)| build_job(layer, cu, n))
        .collect();
    let layer_start = d.fabric_sync_cycles;

    // --- DMA: single channel, serialized in CU column order --------------
    let mut dma_free = layer_start;
    let mut ready = vec![layer_start; k];
    for (i, job) in jobs.iter().enumerate() {
        if let Some(j) = job {
            dma_free += j.dma_cycles;
            ready[i] = dma_free + j.weight_cycles;
        }
    }

    // --- compute ----------------------------------------------------------
    let mut per_cu = vec![CuCost::default(); k];
    let active: Vec<usize> = (0..k).filter(|&i| jobs[i].is_some()).collect();
    let layer_end = match active.len() {
        0 => layer_start,
        1 => {
            let i = active[0];
            let j = jobs[i].unwrap();
            let end = ready[i] + j.compute_cycles;
            per_cu[i] = CuCost {
                cycles: end - layer_start,
                channels: j.channels,
            };
            end
        }
        _ if sequential => {
            // sequential stages chain from the highest column down:
            // the producer (e.g. the DWE) runs first, its output feeds
            // the next-lower active CU
            let mut t = layer_start;
            let mut first = true;
            for &i in active.iter().rev() {
                let j = jobs[i].unwrap();
                let start = ready[i].max(t);
                let end = start + j.compute_cycles;
                per_cu[i] = CuCost {
                    cycles: if first {
                        end - layer_start
                    } else {
                        end - start + j.dma_cycles + j.weight_cycles
                    },
                    channels: j.channels,
                };
                first = false;
                t = end;
            }
            t
        }
        _ => {
            let starts: Vec<u64> = active.iter().map(|&i| ready[i]).collect();
            let durs: Vec<u64> = active
                .iter()
                .map(|&i| jobs[i].unwrap().compute_cycles)
                .collect();
            let ends = resolve_overlap(&starts, &durs, d.bank_conflict_prob);
            let mut last = layer_start;
            for (a, &i) in active.iter().enumerate() {
                per_cu[i] = CuCost {
                    cycles: ends[a] - layer_start,
                    channels: jobs[i].unwrap().channels,
                };
                last = last.max(ends[a]);
            }
            last
        }
    };
    (per_cu, layer_end)
}

/// Latency-only view of [`sim_layer`] — the detailed-sim per-layer cost
/// hook behind the search subsystem's `CostEvaluator`.
pub fn layer_latency(platform: Platform, layer: &Layer, counts: &[usize], sequential: bool) -> u64 {
    sim_layer(platform, layer, counts, sequential).1
}

/// Execute a mapping through the detailed simulator.
pub fn execute(layers: &[Layer], mapping: &Mapping, seq_layers: &[String]) -> ExecReport {
    assert!(
        mapping.is_well_formed(),
        "mapping references CU columns beyond platform '{}' ({} CUs)",
        mapping.platform.name(),
        mapping.platform.n_cus()
    );
    let platform = mapping.platform;
    let k = platform.n_cus();
    let mut reports = Vec::with_capacity(layers.len());
    let mut clock = 0u64;
    let mut busy = vec![0u64; k];

    for (layer, asg) in layers.iter().zip(&mapping.layers) {
        debug_assert_eq!(layer.name, asg.layer);
        let counts = asg.counts(k);
        let sequential = seq_layers.iter().any(|s| s == &layer.name);
        let (per_cu, latency) = sim_layer(platform, layer, &counts, sequential);
        for (b, c) in busy.iter_mut().zip(&per_cu) {
            *b += c.cycles;
        }
        reports.push(LayerReport {
            layer: layer.name.clone(),
            per_cu,
            latency,
            sequential,
        });
        clock += latency;
    }

    let (p_act, p_idle, freq) = power(platform);
    let us_per_cycle = 1.0 / freq;
    let active_nj: f64 = reports
        .iter()
        .map(|r| {
            r.per_cu
                .iter()
                .zip(&p_act)
                .map(|(c, p)| p * c.cycles as f64)
                .sum::<f64>()
                * us_per_cycle
        })
        .sum();
    let energy_uj = (active_nj + p_idle * clock as f64 * us_per_cycle) * 1e-3;
    let utilization = busy
        .iter()
        .map(|&b| b as f64 / clock.max(1) as f64)
        .collect();
    ExecReport {
        platform,
        layers: reports,
        total_cycles: clock,
        energy_uj,
        utilization,
        latency_ms: clock as f64 * us_per_cycle / 1e3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::analytical;
    use crate::soc::model::{LayerAssignment, LayerType};
    use crate::soc::Platform;

    fn conv_layer(name: &str, cin: usize, cout: usize, hw: usize) -> Layer {
        Layer {
            name: name.into(),
            ltype: LayerType::Conv,
            cin,
            cout,
            k: 3,
            ox: hw,
            oy: hw,
            stride: 1,
            searchable: true,
        }
    }

    /// Split each layer's channels with `frac_off` of them spilling off
    /// column 0, round-robin over the platform's remaining CUs.
    fn mapping_split(platform: Platform, layers: &[Layer], frac_off: f64) -> Mapping {
        let k = platform.n_cus();
        Mapping {
            platform,
            layers: layers
                .iter()
                .map(|l| {
                    let n_off = (l.cout as f64 * frac_off) as usize;
                    LayerAssignment::offload_round_robin(&l.name, l.cout, n_off, k)
                })
                .collect(),
        }
    }

    #[test]
    fn detailed_exceeds_analytical() {
        // the detailed sim only *adds* latency components, so it must
        // always report more cycles than the analytical model — on every
        // registered platform, including the tri-CU one
        let layers: Vec<Layer> = (0..4)
            .map(|i| conv_layer(&format!("l{i}"), 16, 32, 16))
            .collect();
        for frac in [0.0, 0.3, 0.7, 1.0] {
            for platform in [Platform::diana(), Platform::darkside(), Platform::trident()] {
                let m = mapping_split(platform, &layers, frac);
                let a = analytical::execute(&layers, &m, &[]);
                let de = execute(&layers, &m, &[]);
                assert!(
                    de.total_cycles > a.total_cycles,
                    "{platform:?} frac={frac}: detailed {} <= analytical {}",
                    de.total_cycles,
                    a.total_cycles
                );
            }
        }
    }

    #[test]
    fn execute_is_sum_of_sim_layers() {
        // the fabric controller re-syncs at every layer boundary, so the
        // whole-network total decomposes exactly into per-layer latencies —
        // the contract the incremental search evaluator depends on
        let layers: Vec<Layer> = (0..4)
            .map(|i| conv_layer(&format!("l{i}"), 16, 32, 16))
            .collect();
        for platform in [Platform::diana(), Platform::darkside(), Platform::trident()] {
            let m = mapping_split(platform, &layers, 0.5);
            let r = execute(&layers, &m, &[]);
            let total: u64 = layers
                .iter()
                .zip(&m.layers)
                .map(|(l, a)| layer_latency(platform, l, &a.counts(platform.n_cus()), false))
                .sum();
            assert_eq!(total, r.total_cycles, "{platform:?}");
            // per-layer reports agree with the isolated hook too
            for (l, (a, lr)) in layers.iter().zip(m.layers.iter().zip(&r.layers)) {
                let (per_cu, lat) = sim_layer(platform, l, &a.counts(platform.n_cus()), false);
                assert_eq!(lat, lr.latency);
                for (x, y) in per_cu.iter().zip(&lr.per_cu) {
                    assert_eq!(x.cycles, y.cycles);
                    assert_eq!(x.channels, y.channels);
                }
            }
        }
    }

    #[test]
    fn deterministic() {
        let layers = vec![conv_layer("a", 8, 16, 8)];
        for platform in [Platform::diana(), Platform::trident()] {
            let m = mapping_split(platform, &layers, 0.5);
            let r1 = execute(&layers, &m, &[]);
            let r2 = execute(&layers, &m, &[]);
            assert_eq!(r1.total_cycles, r2.total_cycles);
            assert_eq!(r1.energy_uj, r2.energy_uj);
        }
    }

    #[test]
    fn contention_costs_cycles() {
        // multiple active CUs suffer bank conflicts: the split mapping
        // must exceed its analytical counterpart by more than the fixed
        // overheads alone
        let layers = vec![conv_layer("a", 32, 64, 16)];
        let m_split = mapping_split(Platform::diana(), &layers, 0.5);
        let r_split = execute(&layers, &m_split, &[]);
        let a_split = analytical::execute(&layers, &m_split, &[]);
        assert!(r_split.total_cycles > a_split.total_cycles);
    }

    #[test]
    fn three_way_overlap_contends_more_than_two_way() {
        // same column-0 work, but activating a third CU adds pairwise
        // overlap windows, so column 0 stretches further
        let starts = [0u64, 0, 0];
        let durs = [10_000u64, 10_000, 10_000];
        let p = 0.12;
        let two = resolve_overlap(&starts[..2], &durs[..2], p);
        let three = resolve_overlap(&starts, &durs, p);
        assert!(three[0] > two[0], "3-way {three:?} vs 2-way {two:?}");
    }

    #[test]
    fn utilization_bounded() {
        let layers: Vec<Layer> = (0..3)
            .map(|i| conv_layer(&format!("l{i}"), 16, 32, 8))
            .collect();
        for platform in [Platform::darkside(), Platform::trident()] {
            let m = mapping_split(platform, &layers, 0.4);
            let r = execute(&layers, &m, &[]);
            assert_eq!(r.utilization.len(), platform.n_cus());
            for (i, &u) in r.utilization.iter().enumerate() {
                assert!(u > 0.0 && u <= 1.0, "{platform:?} cu{i}: util {u}");
            }
        }
    }

    #[test]
    fn empty_cu_consumes_nothing() {
        let layers = vec![conv_layer("a", 8, 16, 8)];
        let m = mapping_split(Platform::diana(), &layers, 0.0);
        let r = execute(&layers, &m, &[]);
        assert_eq!(r.layers[0].per_cu[1].cycles, 0);
        assert_eq!(r.layers[0].per_cu[1].channels, 0);
    }

    #[test]
    fn sequential_chains_highest_column_first() {
        let layers = vec![conv_layer("a", 16, 32, 8)];
        let m = mapping_split(Platform::darkside(), &layers, 0.5);
        let par = execute(&layers, &m, &[]);
        let seq = execute(&layers, &m, &["a".to_string()]);
        assert!(seq.total_cycles > par.total_cycles);
    }
}
