//! Detailed event-driven SoC simulator — the "measured hardware" stand-in.
//!
//! Where `analytical.rs` is the idealized model ODiMO searches with, this
//! simulator executes a mapping phase by phase the way the real SoCs do:
//!
//! * the fabric controller dispatches each layer (sync cost);
//! * each active CU issues a **DMA job** to fetch the layer input from L2
//!   into the shared L1 — the single DMA channel serializes these (each CU
//!   loads the whole input, the redundancy the paper's Sec. IV-A accepts);
//! * weight load / array configuration runs per CU;
//! * compute runs concurrently across CUs, but while two CUs are active
//!   the banked L1 arbiter loses a fraction of cycles to conflicts
//!   (`bank_conflict_prob`), modeled as a mutual slowdown over the
//!   overlap window (fixpoint iteration);
//! * per-CU pipeline warm-up and deterministic per-(layer, CU) variability
//!   (hash-seeded; the analog AIMC array is the noisiest, matching the
//!   error ordering of paper Table III).
//!
//! None of these components exist in the analytical model, so the
//! analytical numbers *underestimate* the detailed ones — the paper makes
//! the same observation about its models vs the real chips, and Table III
//! quantifies exactly this gap.

use super::analytical::{cu_cycles, power};
use super::hw::HwConstants;
use super::model::{Cu, CuCost, ExecReport, Layer, LayerReport, Mapping};

/// Deterministic per-(layer, CU) jitter in [0, 1): FNV-1a hash mapped to
/// the unit interval. Stands in for data-dependent timing (analog
/// variability, cache behaviour) while keeping runs exactly reproducible.
fn jitter(layer: &str, cu: Cu) -> f64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in layer.bytes().chain(cu.label().bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// One CU's work for one layer, split into its pipeline phases.
#[derive(Debug, Clone, Copy)]
struct CuJob {
    cu: Cu,
    channels: usize,
    dma_cycles: u64,
    weight_cycles: u64,
    compute_cycles: u64,
}

fn stall_factor(cu: Cu) -> f64 {
    let d = &HwConstants::load().detailed_sim;
    match cu {
        Cu::DianaDigital => d.diana_digital_stall_factor,
        Cu::DianaAnalog => 0.0, // analog variability handled separately
        Cu::DarksideCluster => d.darkside_cluster_stall_factor,
        Cu::DarksideDwe => d.darkside_dwe_stall_factor,
    }
}

fn build_job(layer: &Layer, cu: Cu, n: usize) -> Option<CuJob> {
    if n == 0 {
        return None;
    }
    let hw = HwConstants::load();
    let d = &hw.detailed_sim;
    let base = cu_cycles(cu, layer, n); // analytical total (incl. setup)
    let mut compute = base as f64;
    compute *= 1.0 + stall_factor(cu);
    if cu == Cu::DianaAnalog {
        compute *= 1.0 + d.diana_analog_variability * jitter(&layer.name, cu);
    } else {
        // small universal jitter so no two layers are bit-identical
        compute *= 1.0 + 0.03 * jitter(&layer.name, cu);
    }
    let warmup = d.pipeline_warmup_rows * layer.ox as u64;
    let dma = d.dma_setup_cycles + (layer.input_bytes() as f64 / d.dma_bytes_per_cycle) as u64;
    Some(CuJob {
        cu,
        channels: n,
        dma_cycles: dma,
        weight_cycles: warmup,
        compute_cycles: compute as u64,
    })
}

/// Resolve the compute-overlap contention between (at most) two jobs.
///
/// Both computes start at their respective `start` times; while both are
/// running every cycle has probability `p` of a bank conflict, stretching
/// both by `1/(1-p)` over the overlap window. Returns the end time of
/// each. Solved by fixpoint iteration (2 jobs ⇒ converges in a few steps).
fn resolve_overlap(starts: [u64; 2], durs: [u64; 2], p: f64) -> [u64; 2] {
    let slow = 1.0 / (1.0 - p);
    let mut ends = [starts[0] + durs[0], starts[1] + durs[1]];
    for _ in 0..8 {
        let ov_start = starts[0].max(starts[1]);
        let ov_end = ends[0].min(ends[1]);
        let overlap = ov_end.saturating_sub(ov_start) as f64;
        let mut new_ends = ends;
        for i in 0..2 {
            if durs[i] == 0 {
                continue;
            }
            // cycles executed inside the overlap window get stretched
            let stretched = durs[i] as f64 + overlap.min(durs[i] as f64) * (slow - 1.0);
            new_ends[i] = starts[i] + stretched as u64;
        }
        if new_ends == ends {
            break;
        }
        ends = new_ends;
    }
    ends
}

/// Execute a mapping through the detailed simulator.
pub fn execute(layers: &[Layer], mapping: &Mapping, seq_layers: &[String]) -> ExecReport {
    let hw = HwConstants::load();
    let d = &hw.detailed_sim;
    let platform = mapping.platform;
    let cus = platform.cus();
    let mut reports = Vec::with_capacity(layers.len());
    let mut clock = 0u64;
    let mut busy = [0u64; 2];

    for (layer, asg) in layers.iter().zip(&mapping.layers) {
        debug_assert_eq!(layer.name, asg.layer);
        let jobs = [
            build_job(layer, cus[0], asg.count(0)),
            build_job(layer, cus[1], asg.count(1)),
        ];
        let layer_start = clock + d.fabric_sync_cycles;
        let sequential = seq_layers.iter().any(|s| s == &layer.name);

        // --- DMA: single channel, serialized in CU order -----------------
        let mut dma_free = layer_start;
        let mut ready = [layer_start; 2];
        for (i, job) in jobs.iter().enumerate() {
            if let Some(j) = job {
                let start = dma_free;
                dma_free = start + j.dma_cycles;
                ready[i] = dma_free + j.weight_cycles;
            }
        }

        // --- compute ------------------------------------------------------
        let mut per_cu = [CuCost::default(); 2];
        let layer_end;
        match (jobs[0], jobs[1]) {
            (Some(j0), Some(j1)) if !sequential => {
                let ends = resolve_overlap(
                    [ready[0], ready[1]],
                    [j0.compute_cycles, j1.compute_cycles],
                    d.bank_conflict_prob,
                );
                per_cu[0] = CuCost {
                    cycles: ends[0] - layer_start,
                    channels: j0.channels,
                };
                per_cu[1] = CuCost {
                    cycles: ends[1] - layer_start,
                    channels: j1.channels,
                };
                layer_end = ends[0].max(ends[1]);
            }
            (Some(j0), Some(j1)) => {
                // sequential stages: CU1 (DWE) first, its output feeds CU0
                let end1 = ready[1] + j1.compute_cycles;
                let start0 = ready[0].max(end1);
                let end0 = start0 + j0.compute_cycles;
                per_cu[0] = CuCost {
                    cycles: end0 - start0 + j0.dma_cycles + j0.weight_cycles,
                    channels: j0.channels,
                };
                per_cu[1] = CuCost {
                    cycles: end1 - layer_start,
                    channels: j1.channels,
                };
                layer_end = end0;
            }
            (Some(j0), None) => {
                let end = ready[0] + j0.compute_cycles;
                per_cu[0] = CuCost {
                    cycles: end - layer_start,
                    channels: j0.channels,
                };
                layer_end = end;
            }
            (None, Some(j1)) => {
                let end = ready[1] + j1.compute_cycles;
                per_cu[1] = CuCost {
                    cycles: end - layer_start,
                    channels: j1.channels,
                };
                layer_end = end;
            }
            (None, None) => {
                layer_end = layer_start;
            }
        }

        busy[0] += per_cu[0].cycles;
        busy[1] += per_cu[1].cycles;
        reports.push(LayerReport {
            layer: layer.name.clone(),
            per_cu,
            latency: layer_end - clock,
            sequential,
        });
        clock = layer_end;
    }

    let (p_act, p_idle, freq) = power(platform);
    let us_per_cycle = 1.0 / freq;
    let active_nj: f64 = reports
        .iter()
        .map(|r| {
            (p_act[0] * r.per_cu[0].cycles as f64 + p_act[1] * r.per_cu[1].cycles as f64)
                * us_per_cycle
        })
        .sum();
    let energy_uj = (active_nj + p_idle * clock as f64 * us_per_cycle) * 1e-3;
    ExecReport {
        platform,
        layers: reports,
        total_cycles: clock,
        energy_uj,
        utilization: [
            busy[0] as f64 / clock.max(1) as f64,
            busy[1] as f64 / clock.max(1) as f64,
        ],
        latency_ms: clock as f64 * us_per_cycle / 1e3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::analytical;
    use crate::soc::model::{LayerAssignment, LayerType, Platform};

    fn conv_layer(name: &str, cin: usize, cout: usize, hw: usize) -> Layer {
        Layer {
            name: name.into(),
            ltype: LayerType::Conv,
            cin,
            cout,
            k: 3,
            ox: hw,
            oy: hw,
            stride: 1,
            searchable: true,
        }
    }

    fn mapping_split(platform: Platform, layers: &[Layer], frac1: f64) -> Mapping {
        Mapping {
            platform,
            layers: layers
                .iter()
                .map(|l| {
                    let n1 = (l.cout as f64 * frac1) as usize;
                    LayerAssignment {
                        layer: l.name.clone(),
                        cu_of: (0..l.cout).map(|c| u8::from(c >= l.cout - n1)).collect(),
                    }
                })
                .collect(),
        }
    }

    #[test]
    fn detailed_exceeds_analytical() {
        // the detailed sim only *adds* latency components, so it must
        // always report more cycles than the analytical model
        let layers: Vec<Layer> = (0..4)
            .map(|i| conv_layer(&format!("l{i}"), 16, 32, 16))
            .collect();
        for frac in [0.0, 0.3, 0.7, 1.0] {
            for platform in [Platform::Diana, Platform::Darkside] {
                let m = mapping_split(platform, &layers, frac);
                let a = analytical::execute(&layers, &m, &[]);
                let de = execute(&layers, &m, &[]);
                assert!(
                    de.total_cycles > a.total_cycles,
                    "{platform:?} frac={frac}: detailed {} <= analytical {}",
                    de.total_cycles,
                    a.total_cycles
                );
            }
        }
    }

    #[test]
    fn deterministic() {
        let layers = vec![conv_layer("a", 8, 16, 8)];
        let m = mapping_split(Platform::Diana, &layers, 0.5);
        let r1 = execute(&layers, &m, &[]);
        let r2 = execute(&layers, &m, &[]);
        assert_eq!(r1.total_cycles, r2.total_cycles);
        assert_eq!(r1.energy_uj, r2.energy_uj);
    }

    #[test]
    fn contention_costs_cycles() {
        // two active CUs suffer bank conflicts: the split mapping's CU0
        // portion must take longer than the same channels running alone
        let layers = vec![conv_layer("a", 32, 64, 16)];
        let m_split = mapping_split(Platform::Diana, &layers, 0.5);
        let r_split = execute(&layers, &m_split, &[]);
        // same CU0 channel count, CU1 idle
        let m_half = Mapping {
            platform: Platform::Diana,
            layers: vec![LayerAssignment {
                layer: "a".into(),
                cu_of: (0..64).map(|c| u8::from(c >= 32) * 2 % 2).collect(),
            }],
        };
        // build "32 channels on cu0 only" by assigning the rest to cu1=0?
        // instead compare against analytical: contention implies detailed
        // > analytical by more than the fixed overheads for split runs.
        let a_split = analytical::execute(&layers, &m_split, &[]);
        assert!(r_split.total_cycles > a_split.total_cycles);
        drop(m_half);
    }

    #[test]
    fn utilization_bounded() {
        let layers: Vec<Layer> = (0..3)
            .map(|i| conv_layer(&format!("l{i}"), 16, 32, 8))
            .collect();
        let m = mapping_split(Platform::Darkside, &layers, 0.4);
        let r = execute(&layers, &m, &[]);
        assert!(r.utilization[0] > 0.0 && r.utilization[0] <= 1.0);
        assert!(r.utilization[1] > 0.0 && r.utilization[1] <= 1.0);
    }

    #[test]
    fn empty_cu_consumes_nothing() {
        let layers = vec![conv_layer("a", 8, 16, 8)];
        let m = mapping_split(Platform::Diana, &layers, 0.0);
        let r = execute(&layers, &m, &[]);
        assert_eq!(r.layers[0].per_cu[1].cycles, 0);
        assert_eq!(r.layers[0].per_cu[1].channels, 0);
    }
}
