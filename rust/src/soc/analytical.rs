//! Analytical cycle/energy models — the *exact* (integer-ceil) versions of
//! the differentiable models in `python/compile/costs.py`, generalized to
//! any registered platform.
//!
//! These are the models ODiMO searches with; `detailed.rs` is the
//! event-driven "measured" reference they are validated against
//! (Table III). A CU's formula is selected by its descriptor's
//! [`CuModel`]; the shared DMA/bank constants come from
//! `hw/constants.json`, so the analytical ↔ differentiable agreement is
//! structural, and the analytical ↔ detailed gap is exactly the overhead
//! terms the detailed simulator adds.

use super::hw::HwConstants;
use super::model::{CuCost, ExecReport, Layer, LayerReport, LayerType, Mapping};
use super::spec::{CuModel, CuSpec, Platform};

fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Input-activation DMA load, counted by the analytical model only for CUs
/// whose descriptor sets `input_dma` (the Darkside CUs). The paper's
/// Table III attributes DIANA's larger model errors to "neglected latency
/// components, leading to a constant underestimation"; its Darkside models
/// are more complete (9%/16% error vs 42%/37%). We reproduce that
/// asymmetry structurally via the per-CU flag.
fn dma_in_cycles(layer: &Layer) -> u64 {
    let d = &HwConstants::load().detailed_sim;
    d.dma_setup_cycles + (layer.input_bytes() as f64 / d.dma_bytes_per_cycle) as u64
}

/// Cycles for `n` output channels of `layer` on `cu`.
///
/// For [`LayerType::Search`] layers the operation is CU-dependent:
/// standard conv on grid/cluster-style CUs, depthwise on a DW engine.
pub fn cu_cycles(cu: &CuSpec, layer: &Layer, n: usize) -> u64 {
    if n == 0 {
        return 0;
    }
    let base = match cu.model {
        CuModel::PeGrid {
            pe_rows,
            pe_cols,
            macs_per_cycle_per_pe,
            weight_load_bytes_per_cycle,
            dw_inefficiency,
        } => {
            let kdim = match layer.ltype {
                LayerType::Dw => layer.k * layer.k,
                _ => layer.cin * layer.k * layer.k,
            };
            let inner = ceil_div(kdim, pe_cols);
            let mut compute = (ceil_div(n, pe_rows) * inner * layer.ox * layer.oy) as f64
                / macs_per_cycle_per_pe;
            if layer.ltype == LayerType::Dw {
                compute *= dw_inefficiency;
            }
            let wload = (n * kdim) as f64 / weight_load_bytes_per_cycle;
            (compute + wload) as u64
        }
        CuModel::AnalogArray {
            array_rows,
            array_cols,
            cells_load_per_cycle,
            cycles_per_analog_op,
        } => {
            let kdim = match layer.ltype {
                LayerType::Dw => layer.k * layer.k,
                _ => layer.cin * layer.k * layer.k,
            };
            let row_tiles = ceil_div(kdim, array_rows);
            let col_tiles = ceil_div(n, array_cols);
            let load = (n * kdim) as f64 / cells_load_per_cycle;
            let compute =
                (row_tiles * col_tiles * layer.ox * layer.oy) as f64 * cycles_per_analog_op;
            (load + compute) as u64
        }
        CuModel::SimdCluster {
            macs_per_cycle_std,
            macs_per_cycle_dw,
            im2col_overhead,
            ..
        } => {
            // a Search layer executes as a standard conv on the cluster
            let (macs, eff, ovh) = match layer.ltype {
                LayerType::Dw => (layer.macs_dw(n) as f64, macs_per_cycle_dw, 1.0),
                _ => (layer.macs_std(n) as f64, macs_per_cycle_std, im2col_overhead),
            };
            (macs * ovh / eff) as u64
        }
        CuModel::DwEngine {
            macs_per_cycle,
            weight_cfg_cells_per_cycle,
        } => {
            // a DW engine only ever runs depthwise
            let macs = layer.macs_dw(n) as f64;
            let cfg = (n * layer.k * layer.k) as f64 / weight_cfg_cells_per_cycle;
            (macs / macs_per_cycle + cfg) as u64
        }
    };
    let dma = if cu.input_dma { dma_in_cycles(layer) } else { 0 };
    base + cu.setup_cycles + dma
}

/// Per-CU cycles plus the layer's latency for one layer under per-CU
/// channel `counts` — the per-layer recost hook the search evaluator uses
/// to price single-layer moves without re-running the whole network.
/// [`execute`]'s total is exactly the sum of these latencies.
pub fn layer_costs(
    cus: &[CuSpec],
    layer: &Layer,
    counts: &[usize],
    sequential: bool,
) -> (Vec<u64>, u64) {
    let cycles: Vec<u64> = cus
        .iter()
        .zip(counts)
        .map(|(cu, &n)| cu_cycles(cu, layer, n))
        .collect();
    let latency = if sequential {
        cycles.iter().sum()
    } else {
        cycles.iter().copied().max().unwrap_or(0)
    };
    (cycles, latency)
}

/// Latency-only view of [`layer_costs`].
pub fn layer_latency(platform: Platform, layer: &Layer, counts: &[usize], sequential: bool) -> u64 {
    layer_costs(platform.cus(), layer, counts, sequential).1
}

/// Weight bytes that `n` channels of `layer` park on `cu` — the footprint
/// bounded by the descriptor's optional `mem_capacity_bytes`. Mirrors the
/// operation dispatch of [`cu_cycles`]: a DW engine stores k×k cells per
/// channel; every other CU holds the full filter for the op it runs.
pub fn weight_bytes(cu: &CuSpec, layer: &Layer, n: usize) -> u64 {
    let kdim = match cu.model {
        CuModel::DwEngine { .. } => layer.k * layer.k,
        _ => match layer.ltype {
            LayerType::Dw => layer.k * layer.k,
            _ => layer.cin * layer.k * layer.k,
        },
    };
    (n * kdim) as u64
}

/// Platform power: per-CU active power vector (column order), idle power
/// and frequency (MHz).
pub fn power(platform: Platform) -> (Vec<f64>, f64, f64) {
    (
        platform.cus().iter().map(|c| c.p_act_mw).collect(),
        platform.p_idle_mw(),
        platform.freq_mhz(),
    )
}

/// Layers whose CU stages are sequential (DW on the DWE feeding a
/// pointwise on the cluster — the ImageNet DW-vs-DWSep search space).
pub fn is_sequential(search_kind: &str, layer: &Layer) -> bool {
    search_kind == "dwsep" && layer.searchable
}

/// Execute a mapping through the analytical model.
///
/// `seq_layers` lists layers whose CU stages are sequential (DW→PW); their
/// latency is the *sum* of the active CU times instead of the max.
pub fn execute(layers: &[Layer], mapping: &Mapping, seq_layers: &[String]) -> ExecReport {
    assert!(
        mapping.is_well_formed(),
        "mapping references CU columns beyond platform '{}' ({} CUs)",
        mapping.platform.name(),
        mapping.platform.n_cus()
    );
    let platform = mapping.platform;
    let cus = platform.cus();
    let k = cus.len();
    let mut reports = Vec::with_capacity(layers.len());
    let mut total = 0u64;
    let mut busy = vec![0u64; k];
    for (layer, asg) in layers.iter().zip(&mapping.layers) {
        debug_assert_eq!(layer.name, asg.layer);
        let counts = asg.counts(k);
        let sequential = seq_layers.iter().any(|s| s == &layer.name);
        let (cycles, latency) = layer_costs(cus, layer, &counts, sequential);
        for (b, &c) in busy.iter_mut().zip(&cycles) {
            *b += c;
        }
        total += latency;
        reports.push(LayerReport {
            layer: layer.name.clone(),
            per_cu: cycles
                .iter()
                .zip(&counts)
                .map(|(&cycles, &channels)| CuCost { cycles, channels })
                .collect(),
            latency,
            sequential,
        });
    }
    let (p_act, p_idle, freq) = power(platform);
    let us_per_cycle = 1.0 / freq;
    let active_nj: f64 = reports
        .iter()
        .map(|r| {
            r.per_cu
                .iter()
                .zip(&p_act)
                .map(|(c, p)| p * c.cycles as f64)
                .sum::<f64>()
                * us_per_cycle
        })
        .sum();
    let idle_nj = p_idle * total as f64 * us_per_cycle;
    let energy_uj = (active_nj + idle_nj) * 1e-3;
    let utilization = busy
        .iter()
        .map(|&b| b as f64 / total.max(1) as f64)
        .collect();
    ExecReport {
        platform,
        layers: reports,
        total_cycles: total,
        energy_uj,
        utilization,
        latency_ms: total as f64 * us_per_cycle / 1e3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::model::{LayerAssignment, Mapping};

    fn conv_layer(cin: usize, cout: usize, hw: usize) -> Layer {
        Layer {
            name: "t".into(),
            ltype: LayerType::Conv,
            cin,
            cout,
            k: 3,
            ox: hw,
            oy: hw,
            stride: 1,
            searchable: true,
        }
    }

    fn all_cus() -> Vec<&'static CuSpec> {
        let mut out = Vec::new();
        for p in [Platform::diana(), Platform::darkside(), Platform::trident()] {
            out.extend(p.cus().iter());
        }
        out
    }

    #[test]
    fn zero_channels_zero_cycles() {
        let l = conv_layer(16, 32, 8);
        for cu in all_cus() {
            assert_eq!(cu_cycles(cu, &l, 0), 0, "{}", cu.name);
        }
    }

    #[test]
    fn monotone_in_channels() {
        let l = conv_layer(16, 64, 16);
        for cu in all_cus() {
            let mut prev = 0;
            for n in 1..=64 {
                let c = cu_cycles(cu, &l, n);
                assert!(c >= prev, "{} not monotone at n={n}", cu.name);
                prev = c;
            }
        }
    }

    #[test]
    fn dwe_beats_cluster_on_dw_work() {
        // the whole point of the DWE: a depthwise workload is far cheaper
        // there than a standard conv of the same layer on the cluster
        let l = conv_layer(64, 64, 16);
        let cus = Platform::darkside().cus();
        let cluster = cu_cycles(&cus[0], &l, 64);
        let dwe = cu_cycles(&cus[1], &l, 64);
        assert!(
            (cluster as f64) > 4.0 * dwe as f64,
            "cluster {cluster} vs dwe {dwe}"
        );
    }

    #[test]
    fn analog_faster_than_digital_on_big_convs() {
        let l = conv_layer(64, 64, 16);
        let cus = Platform::diana().cus();
        let d = cu_cycles(&cus[0], &l, 64);
        let a = cu_cycles(&cus[1], &l, 64);
        assert!(a < d, "analog {a} not faster than digital {d}");
    }

    #[test]
    fn execute_splits_and_balances() {
        // layer must be large enough to amortize the analog array's
        // setup + per-pixel ADC cost — that's exactly the regime where
        // intra-layer splitting pays off (the paper's motivation)
        let layers = vec![conv_layer(64, 64, 16)];
        let all0 = Mapping {
            platform: Platform::diana(),
            layers: vec![LayerAssignment::all_on("t", 64, 0)],
        };
        let split = Mapping {
            platform: Platform::diana(),
            layers: vec![LayerAssignment {
                layer: "t".into(),
                cu_of: (0..64).map(|c| u8::from(c >= 32)).collect(),
            }],
        };
        let r0 = execute(&layers, &all0, &[]);
        let rs = execute(&layers, &split, &[]);
        assert!(
            rs.total_cycles < r0.total_cycles,
            "parallel split wins: {} vs {}",
            rs.total_cycles,
            r0.total_cycles
        );
        assert!(rs.energy_uj > 0.0 && r0.energy_uj > 0.0);
        assert!((rs.channel_fraction(1) - 0.5).abs() < 1e-9);
        assert!((rs.offload_channel_fraction() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn tiny_layers_prefer_single_cu() {
        // conversely, for a tiny stem-like layer (cin=3) the analog
        // array's setup cost dominates and the all-digital mapping is
        // cheaper — the crossover the min-cost baseline exploits when it
        // assigns the stem to the digital CU
        let layers = vec![conv_layer(3, 8, 4)];
        let all0 = Mapping {
            platform: Platform::diana(),
            layers: vec![LayerAssignment::all_on("t", 8, 0)],
        };
        let split = Mapping {
            platform: Platform::diana(),
            layers: vec![LayerAssignment {
                layer: "t".into(),
                cu_of: (0..8).map(|c| u8::from(c >= 4)).collect(),
            }],
        };
        let r0 = execute(&layers, &all0, &[]);
        let rs = execute(&layers, &split, &[]);
        assert!(
            r0.total_cycles < rs.total_cycles,
            "all-digital {} vs split {}",
            r0.total_cycles,
            rs.total_cycles
        );
    }

    #[test]
    fn layer_costs_agree_with_execute() {
        // the per-layer hook is the exact decomposition of execute():
        // summing layer_latency over the network reproduces total_cycles
        let layers: Vec<Layer> = (0..3).map(|_| conv_layer(16, 48, 8)).collect();
        let p = Platform::trident();
        let m = Mapping {
            platform: p,
            layers: layers
                .iter()
                .map(|l| LayerAssignment {
                    layer: l.name.clone(),
                    cu_of: (0..l.cout).map(|c| (c % 3) as u8).collect(),
                })
                .collect(),
        };
        let r = execute(&layers, &m, &[]);
        let total: u64 = layers
            .iter()
            .zip(&m.layers)
            .map(|(l, a)| layer_latency(p, l, &a.counts(3), false))
            .sum();
        assert_eq!(total, r.total_cycles);
        let (cycles, lat) = layer_costs(p.cus(), &layers[0], &m.layers[0].counts(3), false);
        assert_eq!(cycles.len(), 3);
        assert_eq!(lat, r.layers[0].latency);
        // sequential latency is the sum instead of the max
        let (cyc_seq, lat_seq) = layer_costs(p.cus(), &layers[0], &m.layers[0].counts(3), true);
        assert_eq!(lat_seq, cyc_seq.iter().sum::<u64>());
        assert!(lat_seq >= lat);
    }

    #[test]
    fn weight_bytes_follow_cu_op_dispatch() {
        let conv = conv_layer(16, 32, 8);
        let dark = Platform::darkside().cus();
        // the cluster runs the full conv filter, the DWE only k×k cells
        assert_eq!(weight_bytes(&dark[0], &conv, 4), (4 * 16 * 9) as u64);
        assert_eq!(weight_bytes(&dark[1], &conv, 4), (4 * 9) as u64);
        // a depthwise layer is k×k everywhere
        let mut dw = conv_layer(16, 16, 8);
        dw.ltype = LayerType::Dw;
        assert_eq!(weight_bytes(&dark[0], &dw, 4), (4 * 9) as u64);
        assert_eq!(weight_bytes(&dark[1], &dw, 4), (4 * 9) as u64);
        assert_eq!(weight_bytes(&dark[0], &conv, 0), 0);
    }

    #[test]
    fn sequential_layers_add() {
        let layers = vec![conv_layer(16, 32, 8)];
        let m = Mapping {
            platform: Platform::darkside(),
            layers: vec![LayerAssignment {
                layer: "t".into(),
                cu_of: (0..32).map(|c| u8::from(c >= 16)).collect(),
            }],
        };
        let par = execute(&layers, &m, &[]);
        let seq = execute(&layers, &m, &["t".to_string()]);
        assert!(seq.total_cycles > par.total_cycles);
        assert_eq!(
            seq.total_cycles,
            par.layers[0].per_cu[0].cycles + par.layers[0].per_cu[1].cycles
        );
    }

    #[test]
    fn tri_cu_execute_reports_three_columns() {
        let layers = vec![conv_layer(32, 48, 16)];
        let m = Mapping {
            platform: Platform::trident(),
            layers: vec![LayerAssignment {
                layer: "t".into(),
                cu_of: (0..48).map(|c| (c / 16) as u8).collect(),
            }],
        };
        assert!(m.is_well_formed());
        let r = execute(&layers, &m, &[]);
        assert_eq!(r.n_cus(), 3);
        assert_eq!(r.layers[0].per_cu.len(), 3);
        for col in 0..3 {
            assert_eq!(r.layers[0].per_cu[col].channels, 16);
            assert!(r.layers[0].per_cu[col].cycles > 0);
            assert!((r.channel_fraction(col) - 1.0 / 3.0).abs() < 1e-9);
        }
        // latency is the slowest column, and all three contribute busy time
        let worst = r.layers[0].per_cu.iter().map(|c| c.cycles).max().unwrap();
        assert_eq!(r.total_cycles, worst);
        assert!(r.utilization.iter().all(|&u| u > 0.0 && u <= 1.0));
        assert!((r.offload_channel_fraction() - 2.0 / 3.0).abs() < 1e-9);
    }
}
