//! Analytical cycle/energy models — the *exact* (integer-ceil) versions of
//! the differentiable models in `python/compile/costs.py`.
//!
//! These are the models ODiMO searches with; `detailed.rs` is the
//! event-driven "measured" reference they are validated against
//! (Table III). The two sides share `hw/constants.json`, so the analytical
//!↔ differentiable agreement is structural, and the analytical ↔ detailed
//! gap is exactly the overhead terms the detailed simulator adds.

use super::hw::HwConstants;
use super::model::{Cu, CuCost, ExecReport, Layer, LayerReport, LayerType, Mapping, Platform};

fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Input-activation DMA load, counted by the *Darkside* analytical model
/// only. The paper's Table III attributes DIANA's larger model errors to
/// "neglected latency components, leading to a constant underestimation";
/// its Darkside models are more complete (9%/16% error vs 42%/37%). We
/// reproduce that asymmetry structurally: the Darkside model includes the
/// L2→L1 input DMA, the DIANA model does not.
fn dma_in_cycles(layer: &Layer) -> u64 {
    let d = &HwConstants::load().detailed_sim;
    d.dma_setup_cycles + (layer.input_bytes() as f64 / d.dma_bytes_per_cycle) as u64
}

/// Cycles for `n` output channels of `layer` on `cu`.
///
/// For `LayerType::Search` layers the operation is CU-dependent (the
/// Darkside search space): standard conv on the cluster, depthwise on the
/// DWE.
pub fn cu_cycles(cu: Cu, layer: &Layer, n: usize) -> u64 {
    if n == 0 {
        return 0;
    }
    let hw = HwConstants::load();
    match cu {
        Cu::DianaDigital => {
            let d = &hw.diana.digital;
            let kdim = match layer.ltype {
                LayerType::Dw => layer.k * layer.k,
                _ => layer.cin * layer.k * layer.k,
            };
            let inner = ceil_div(kdim, d.pe_cols);
            let mut compute = (ceil_div(n, d.pe_rows) * inner * layer.ox * layer.oy) as f64
                / d.macs_per_cycle_per_pe;
            if layer.ltype == LayerType::Dw {
                compute *= hw.diana.dw_digital_inefficiency;
            }
            let wload = (n * kdim) as f64 / d.weight_load_bytes_per_cycle;
            (compute + wload) as u64 + d.setup_cycles
        }
        Cu::DianaAnalog => {
            let a = &hw.diana.analog;
            let kdim = match layer.ltype {
                LayerType::Dw => layer.k * layer.k,
                _ => layer.cin * layer.k * layer.k,
            };
            let row_tiles = ceil_div(kdim, a.array_rows);
            let col_tiles = ceil_div(n, a.array_cols);
            let cells = (n * kdim) as f64;
            let load = cells / a.cells_load_per_cycle;
            let compute = (row_tiles * col_tiles * layer.ox * layer.oy) as f64
                * a.cycles_per_analog_op;
            (load + compute) as u64 + a.setup_cycles
        }
        Cu::DarksideCluster => {
            let c = &hw.darkside.cluster;
            // on the cluster a Search layer executes as a standard conv
            let (macs, eff, ovh) = match layer.ltype {
                LayerType::Dw => (layer.macs_dw(n) as f64, c.macs_per_cycle_dw, 1.0),
                _ => (
                    layer.macs_std(n) as f64,
                    c.macs_per_cycle_std,
                    c.im2col_overhead,
                ),
            };
            (macs * ovh / eff) as u64 + c.setup_cycles + dma_in_cycles(layer)
        }
        Cu::DarksideDwe => {
            let d = &hw.darkside.dwe;
            // the DWE only ever runs depthwise
            let macs = layer.macs_dw(n) as f64;
            let cfg = (n * layer.k * layer.k) as f64 / d.weight_cfg_cells_per_cycle;
            (macs / d.macs_per_cycle + cfg) as u64 + d.setup_cycles + dma_in_cycles(layer)
        }
    }
}

/// Platform power vector `[p_cu0, p_cu1]` + idle power + frequency (MHz).
pub fn power(platform: Platform) -> ([f64; 2], f64, f64) {
    let hw = HwConstants::load();
    match platform {
        Platform::Diana => (
            [hw.diana.digital.p_act_mw, hw.diana.analog.p_act_mw],
            hw.diana.p_idle_mw,
            hw.diana.freq_mhz,
        ),
        Platform::Darkside => (
            [hw.darkside.cluster.p_act_mw, hw.darkside.dwe.p_act_mw],
            hw.darkside.p_idle_mw,
            hw.darkside.freq_mhz,
        ),
    }
}

/// Layers whose two stages are sequential (DW on the DWE feeding a
/// pointwise on the cluster — the ImageNet DW-vs-DWSep search space).
pub fn is_sequential(search_kind: &str, layer: &Layer) -> bool {
    search_kind == "dwsep" && layer.searchable
}

/// Execute a mapping through the analytical model.
///
/// `seq_layers` lists layers whose CU stages are sequential (DW→PW).
pub fn execute(layers: &[Layer], mapping: &Mapping, seq_layers: &[String]) -> ExecReport {
    let platform = mapping.platform;
    let cus = platform.cus();
    let mut reports = Vec::with_capacity(layers.len());
    let mut total = 0u64;
    let mut busy = [0u64; 2];
    for (layer, asg) in layers.iter().zip(&mapping.layers) {
        debug_assert_eq!(layer.name, asg.layer);
        let n0 = asg.count(0);
        let n1 = asg.count(1);
        let c0 = cu_cycles(cus[0], layer, n0);
        let c1 = cu_cycles(cus[1], layer, n1);
        let sequential = seq_layers.iter().any(|s| s == &layer.name);
        let latency = if sequential { c0 + c1 } else { c0.max(c1) };
        busy[0] += c0;
        busy[1] += c1;
        total += latency;
        reports.push(LayerReport {
            layer: layer.name.clone(),
            per_cu: [
                CuCost {
                    cycles: c0,
                    channels: n0,
                },
                CuCost {
                    cycles: c1,
                    channels: n1,
                },
            ],
            latency,
            sequential,
        });
    }
    let (p_act, p_idle, freq) = power(platform);
    let us_per_cycle = 1.0 / freq;
    let active_nj: f64 = reports
        .iter()
        .map(|r| {
            (p_act[0] * r.per_cu[0].cycles as f64 + p_act[1] * r.per_cu[1].cycles as f64)
                * us_per_cycle
        })
        .sum();
    let idle_nj = p_idle * total as f64 * us_per_cycle;
    let energy_uj = (active_nj + idle_nj) * 1e-3;
    let util = [
        busy[0] as f64 / total.max(1) as f64,
        busy[1] as f64 / total.max(1) as f64,
    ];
    ExecReport {
        platform,
        layers: reports,
        total_cycles: total,
        energy_uj,
        utilization: util,
        latency_ms: total as f64 * us_per_cycle / 1e3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv_layer(cin: usize, cout: usize, hw: usize) -> Layer {
        Layer {
            name: "t".into(),
            ltype: LayerType::Conv,
            cin,
            cout,
            k: 3,
            ox: hw,
            oy: hw,
            stride: 1,
            searchable: true,
        }
    }

    #[test]
    fn zero_channels_zero_cycles() {
        let l = conv_layer(16, 32, 8);
        for cu in [
            Cu::DianaDigital,
            Cu::DianaAnalog,
            Cu::DarksideCluster,
            Cu::DarksideDwe,
        ] {
            assert_eq!(cu_cycles(cu, &l, 0), 0);
        }
    }

    #[test]
    fn monotone_in_channels() {
        let l = conv_layer(16, 64, 16);
        for cu in [
            Cu::DianaDigital,
            Cu::DianaAnalog,
            Cu::DarksideCluster,
            Cu::DarksideDwe,
        ] {
            let mut prev = 0;
            for n in 1..=64 {
                let c = cu_cycles(cu, &l, n);
                assert!(c >= prev, "{cu:?} not monotone at n={n}");
                prev = c;
            }
        }
    }

    #[test]
    fn dwe_beats_cluster_on_dw_work() {
        // the whole point of the DWE: a depthwise workload is far cheaper
        // there than a standard conv of the same layer on the cluster
        let l = conv_layer(64, 64, 16);
        let dwe = cu_cycles(Cu::DarksideDwe, &l, 64);
        let cluster = cu_cycles(Cu::DarksideCluster, &l, 64);
        assert!(
            (cluster as f64) > 4.0 * dwe as f64,
            "cluster {cluster} vs dwe {dwe}"
        );
    }

    #[test]
    fn analog_faster_than_digital_on_big_convs() {
        let l = conv_layer(64, 64, 16);
        let d = cu_cycles(Cu::DianaDigital, &l, 64);
        let a = cu_cycles(Cu::DianaAnalog, &l, 64);
        assert!(a < d, "analog {a} not faster than digital {d}");
    }

    #[test]
    fn execute_splits_and_balances() {
        use crate::soc::model::{LayerAssignment, Mapping};
        // layer must be large enough to amortize the analog array's
        // setup + per-pixel ADC cost — that's exactly the regime where
        // intra-layer splitting pays off (the paper's motivation)
        let layers = vec![conv_layer(64, 64, 16)];
        let all0 = Mapping {
            platform: Platform::Diana,
            layers: vec![LayerAssignment::all_on("t", 64, 0)],
        };
        let split = Mapping {
            platform: Platform::Diana,
            layers: vec![LayerAssignment {
                layer: "t".into(),
                cu_of: (0..64).map(|c| u8::from(c >= 32)).collect(),
            }],
        };
        let r0 = execute(&layers, &all0, &[]);
        let rs = execute(&layers, &split, &[]);
        assert!(
            rs.total_cycles < r0.total_cycles,
            "parallel split wins: {} vs {}",
            rs.total_cycles,
            r0.total_cycles
        );
        assert!(rs.energy_uj > 0.0 && r0.energy_uj > 0.0);
        assert!((rs.cu1_channel_fraction() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn tiny_layers_prefer_single_cu() {
        // conversely, for a tiny stem-like layer (cin=3) the analog
        // array's setup cost dominates and the all-digital mapping is
        // cheaper — the crossover the min-cost baseline exploits when it
        // assigns the stem to the digital CU
        let layers = vec![conv_layer(3, 8, 4)];
        use crate::soc::model::{LayerAssignment, Mapping};
        let all0 = Mapping {
            platform: Platform::Diana,
            layers: vec![LayerAssignment::all_on("t", 8, 0)],
        };
        let split = Mapping {
            platform: Platform::Diana,
            layers: vec![LayerAssignment {
                layer: "t".into(),
                cu_of: (0..8).map(|c| u8::from(c >= 4)).collect(),
            }],
        };
        let r0 = execute(&layers, &all0, &[]);
        let rs = execute(&layers, &split, &[]);
        assert!(
            r0.total_cycles < rs.total_cycles,
            "all-digital {} vs split {}",
            r0.total_cycles,
            rs.total_cycles
        );
    }

    #[test]
    fn sequential_layers_add() {
        use crate::soc::model::{LayerAssignment, Mapping};
        let layers = vec![conv_layer(16, 32, 8)];
        let m = Mapping {
            platform: Platform::Darkside,
            layers: vec![LayerAssignment {
                layer: "t".into(),
                cu_of: (0..32).map(|c| u8::from(c >= 16)).collect(),
            }],
        };
        let par = execute(&layers, &m, &[]);
        let seq = execute(&layers, &m, &["t".to_string()]);
        assert!(seq.total_cycles > par.total_cycles);
        assert_eq!(
            seq.total_cycles,
            par.layers[0].per_cu[0].cycles + par.layers[0].per_cu[1].cycles
        );
    }
}
