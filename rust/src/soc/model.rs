//! Core simulator types: layers, compute units, mappings, execution
//! reports.



use crate::runtime::LayerSpec;

/// Supported layer operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerType {
    /// standard k×k convolution
    Conv,
    /// depthwise k×k convolution
    Dw,
    /// pointwise (1×1) convolution
    Pw,
    /// fully connected
    Fc,
    /// searchable Darkside position (std-conv vs depthwise alternatives)
    Search,
}

impl LayerType {
    pub fn parse(s: &str) -> LayerType {
        match s {
            "conv" => LayerType::Conv,
            "dw" => LayerType::Dw,
            "pw" => LayerType::Pw,
            "fc" => LayerType::Fc,
            "search" => LayerType::Search,
            other => panic!("unknown layer type '{other}'"),
        }
    }
}

/// Static geometry of one layer (mirrors the manifest layer table).
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub ltype: LayerType,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub ox: usize,
    pub oy: usize,
    pub stride: usize,
    pub searchable: bool,
}

impl Layer {
    pub fn from_spec(s: &LayerSpec) -> Layer {
        Layer {
            name: s.name.clone(),
            ltype: LayerType::parse(&s.ltype),
            cin: s.cin,
            cout: s.cout,
            k: s.k,
            ox: s.ox,
            oy: s.oy,
            stride: s.stride,
            searchable: s.searchable,
        }
    }

    /// MACs if `n` output channels run as a standard conv.
    pub fn macs_std(&self, n: usize) -> u64 {
        (n * self.cin * self.k * self.k * self.ox * self.oy) as u64
    }

    /// MACs if `n` output channels run depthwise.
    pub fn macs_dw(&self, n: usize) -> u64 {
        (n * self.k * self.k * self.ox * self.oy) as u64
    }

    /// Input activation bytes (int8) one CU must load.
    pub fn input_bytes(&self) -> u64 {
        (self.cin * self.ox * self.stride * self.oy * self.stride) as u64
    }

    /// Output activation bytes (int8) for `n` channels.
    pub fn output_bytes(&self, n: usize) -> u64 {
        (n * self.ox * self.oy) as u64
    }
}

/// The compute units of the two supported SoCs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cu {
    /// DIANA 16×16 int8 digital PE grid
    DianaDigital,
    /// DIANA 500k-cell ternary analog AIMC array
    DianaAnalog,
    /// Darkside 8-core RISC-V cluster (standard/pointwise convs, FC)
    DarksideCluster,
    /// Darkside DepthWise Engine (depthwise 3×3 only)
    DarksideDwe,
}

impl Cu {
    pub fn label(self) -> &'static str {
        match self {
            Cu::DianaDigital => "digital",
            Cu::DianaAnalog => "analog",
            Cu::DarksideCluster => "cluster",
            Cu::DarksideDwe => "dwe",
        }
    }
}

/// Target platform.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Platform {
    Diana,
    Darkside,
}

impl Platform {
    pub fn parse(s: &str) -> Platform {
        match s {
            "diana" => Platform::Diana,
            "darkside" => Platform::Darkside,
            other => panic!("unknown platform '{other}'"),
        }
    }

    /// The two CUs of the platform, in cost-model column order
    /// (column 0, column 1).
    pub fn cus(self) -> [Cu; 2] {
        match self {
            Platform::Diana => [Cu::DianaDigital, Cu::DianaAnalog],
            Platform::Darkside => [Cu::DarksideCluster, Cu::DarksideDwe],
        }
    }
}

/// Per-layer channel→CU assignment: `cu_of[c]` gives the CU *column*
/// (0 or 1) producing output channel `c`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerAssignment {
    pub layer: String,
    pub cu_of: Vec<u8>,
}

impl LayerAssignment {
    pub fn all_on(layer: &str, cout: usize, cu: u8) -> Self {
        Self {
            layer: layer.to_string(),
            cu_of: vec![cu; cout],
        }
    }

    pub fn count(&self, cu: u8) -> usize {
        self.cu_of.iter().filter(|&&c| c == cu).count()
    }

    /// True if the channels of each CU form one contiguous block.
    pub fn is_contiguous(&self) -> bool {
        let mut transitions = 0;
        for w in self.cu_of.windows(2) {
            if w[0] != w[1] {
                transitions += 1;
            }
        }
        transitions <= 1
    }
}

/// A whole-network mapping.
#[derive(Debug, Clone)]
pub struct Mapping {
    pub platform: Platform,
    pub layers: Vec<LayerAssignment>,
}

/// Execution cost of one layer on one CU.
#[derive(Debug, Clone, Copy, Default)]
pub struct CuCost {
    pub cycles: u64,
    pub channels: usize,
}

/// Per-layer execution report.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub layer: String,
    /// cost per CU column (index matches `Platform::cus()`)
    pub per_cu: [CuCost; 2],
    /// layer latency (max across CUs, plus sync in the detailed sim)
    pub latency: u64,
    /// true when the two CUs run sequentially (DW→PW dependency of the
    /// ImageNet search space) rather than in parallel
    pub sequential: bool,
}

/// Whole-network execution report.
#[derive(Debug, Clone)]
pub struct ExecReport {
    pub platform: Platform,
    pub layers: Vec<LayerReport>,
    pub total_cycles: u64,
    pub energy_uj: f64,
    /// fraction of total time each CU is busy
    pub utilization: [f64; 2],
    pub latency_ms: f64,
}

impl ExecReport {
    /// Fraction of output channels mapped to CU column 1 across the whole
    /// network (the paper's "A. Ch." column in Table IV).
    pub fn cu1_channel_fraction(&self) -> f64 {
        let total: usize = self
            .layers
            .iter()
            .map(|l| l.per_cu[0].channels + l.per_cu[1].channels)
            .sum();
        let cu1: usize = self.layers.iter().map(|l| l.per_cu[1].channels).sum();
        if total == 0 {
            0.0
        } else {
            cu1 as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguity() {
        let a = LayerAssignment {
            layer: "l".into(),
            cu_of: vec![0, 0, 1, 1, 1],
        };
        assert!(a.is_contiguous());
        let b = LayerAssignment {
            layer: "l".into(),
            cu_of: vec![0, 1, 0, 1],
        };
        assert!(!b.is_contiguous());
        let c = LayerAssignment::all_on("l", 4, 1);
        assert!(c.is_contiguous());
        assert_eq!(c.count(1), 4);
        assert_eq!(c.count(0), 0);
    }

    #[test]
    fn macs() {
        let l = Layer {
            name: "t".into(),
            ltype: LayerType::Conv,
            cin: 16,
            cout: 32,
            k: 3,
            ox: 8,
            oy: 8,
            stride: 1,
            searchable: true,
        };
        assert_eq!(l.macs_std(32), 32 * 16 * 9 * 64);
        assert_eq!(l.macs_dw(32), 32 * 9 * 64);
    }
}
