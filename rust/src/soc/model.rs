//! Core simulator types: layers, N-way mappings, execution reports.
//!
//! The compute units themselves live in the platform registry
//! ([`super::spec`]); this module holds everything that is *per network* —
//! layer geometry, channel→CU assignments (a CU is always referenced by its
//! column index into `Platform::cus()`), and the per-layer / whole-network
//! execution reports both simulators produce.

use std::str::FromStr;

use anyhow::{bail, Result};

use crate::runtime::LayerSpec;

use super::spec::Platform;

/// Supported layer operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerType {
    /// standard k×k convolution
    Conv,
    /// depthwise k×k convolution
    Dw,
    /// pointwise (1×1) convolution
    Pw,
    /// fully connected
    Fc,
    /// searchable position whose operation is CU-dependent (std conv on a
    /// cluster, depthwise on a DW engine — the Darkside search space)
    Search,
}

impl LayerType {
    pub fn name(self) -> &'static str {
        match self {
            LayerType::Conv => "conv",
            LayerType::Dw => "dw",
            LayerType::Pw => "pw",
            LayerType::Fc => "fc",
            LayerType::Search => "search",
        }
    }
}

impl FromStr for LayerType {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<LayerType> {
        Ok(match s {
            "conv" => LayerType::Conv,
            "dw" => LayerType::Dw,
            "pw" => LayerType::Pw,
            "fc" => LayerType::Fc,
            "search" => LayerType::Search,
            other => bail!("unknown layer type '{other}' (expected conv|dw|pw|fc|search)"),
        })
    }
}

/// Static geometry of one layer (mirrors the manifest layer table).
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub ltype: LayerType,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub ox: usize,
    pub oy: usize,
    pub stride: usize,
    pub searchable: bool,
}

impl Layer {
    pub fn from_spec(s: &LayerSpec) -> Result<Layer> {
        Ok(Layer {
            name: s.name.clone(),
            ltype: s.ltype.parse()?,
            cin: s.cin,
            cout: s.cout,
            k: s.k,
            ox: s.ox,
            oy: s.oy,
            stride: s.stride,
            searchable: s.searchable,
        })
    }

    /// MACs if `n` output channels run as a standard conv.
    pub fn macs_std(&self, n: usize) -> u64 {
        (n * self.cin * self.k * self.k * self.ox * self.oy) as u64
    }

    /// MACs if `n` output channels run depthwise.
    pub fn macs_dw(&self, n: usize) -> u64 {
        (n * self.k * self.k * self.ox * self.oy) as u64
    }

    /// Input activation bytes (int8) one CU must load.
    pub fn input_bytes(&self) -> u64 {
        (self.cin * self.ox * self.stride * self.oy * self.stride) as u64
    }

    /// Output activation bytes (int8) for `n` channels.
    pub fn output_bytes(&self, n: usize) -> u64 {
        (n * self.ox * self.oy) as u64
    }
}

/// Per-layer channel→CU assignment: `cu_of[c]` gives the CU *column*
/// (index into `Platform::cus()`) producing output channel `c`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerAssignment {
    pub layer: String,
    pub cu_of: Vec<u8>,
}

impl LayerAssignment {
    pub fn all_on(layer: &str, cout: usize, cu: u8) -> Self {
        Self {
            layer: layer.to_string(),
            cu_of: vec![cu; cout],
        }
    }

    pub fn count(&self, cu: u8) -> usize {
        self.cu_of.iter().filter(|&&c| c == cu).count()
    }

    /// The first `cout - n_off` channels on column 0, the remaining
    /// `n_off` spread round-robin over columns `1..n_cus` — the standard
    /// synthetic offload pattern the tests, benches, and explorer share.
    pub fn offload_round_robin(layer: &str, cout: usize, n_off: usize, n_cus: usize) -> Self {
        let spread = (n_cus - 1).max(1);
        Self {
            layer: layer.to_string(),
            cu_of: (0..cout)
                .map(|c| {
                    if c < cout - n_off || n_cus == 1 {
                        0
                    } else {
                        (1 + c % spread) as u8
                    }
                })
                .collect(),
        }
    }

    /// Channel counts per CU column.
    pub fn counts(&self, n_cus: usize) -> Vec<usize> {
        let mut out = vec![0usize; n_cus];
        for &c in &self.cu_of {
            if (c as usize) < n_cus {
                out[c as usize] += 1;
            }
        }
        out
    }

    /// Highest CU column referenced, if any channel exists.
    pub fn max_cu(&self) -> Option<u8> {
        self.cu_of.iter().copied().max()
    }

    /// True if the channels of each CU form one contiguous block (every CU
    /// value appears in at most one run).
    pub fn is_contiguous(&self) -> bool {
        let mut closed: Vec<u8> = Vec::new();
        let mut prev: Option<u8> = None;
        for &c in &self.cu_of {
            if prev == Some(c) {
                continue;
            }
            if closed.contains(&c) {
                return false;
            }
            if let Some(p) = prev {
                closed.push(p);
            }
            prev = Some(c);
        }
        true
    }
}

/// A whole-network mapping.
#[derive(Debug, Clone)]
pub struct Mapping {
    pub platform: Platform,
    pub layers: Vec<LayerAssignment>,
}

impl Mapping {
    /// Every assignment references only columns the platform has.
    pub fn is_well_formed(&self) -> bool {
        let n = self.platform.n_cus() as u8;
        self.layers
            .iter()
            .all(|a| a.cu_of.iter().all(|&c| c < n))
    }
}

/// Execution cost of one layer on one CU.
#[derive(Debug, Clone, Copy, Default)]
pub struct CuCost {
    pub cycles: u64,
    pub channels: usize,
}

/// Per-layer execution report.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub layer: String,
    /// cost per CU column (index matches `Platform::cus()`)
    pub per_cu: Vec<CuCost>,
    /// layer latency (max across CUs, plus sync in the detailed sim)
    pub latency: u64,
    /// true when the CU stages run sequentially (DW→PW dependency of the
    /// ImageNet search space) rather than in parallel
    pub sequential: bool,
}

/// Whole-network execution report.
#[derive(Debug, Clone)]
pub struct ExecReport {
    pub platform: Platform,
    pub layers: Vec<LayerReport>,
    pub total_cycles: u64,
    pub energy_uj: f64,
    /// fraction of total time each CU is busy (one entry per CU column)
    pub utilization: Vec<f64>,
    pub latency_ms: f64,
}

impl ExecReport {
    pub fn n_cus(&self) -> usize {
        self.utilization.len()
    }

    fn total_channels(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.per_cu.iter().map(|c| c.channels).sum::<usize>())
            .sum()
    }

    /// Fraction of output channels mapped to CU column `col` across the
    /// whole network.
    pub fn channel_fraction(&self, col: usize) -> f64 {
        let total = self.total_channels();
        if total == 0 {
            return 0.0;
        }
        let on: usize = self
            .layers
            .iter()
            .map(|l| l.per_cu.get(col).map_or(0, |c| c.channels))
            .sum();
        on as f64 / total as f64
    }

    /// Fraction of channels *not* on the primary CU (column 0) — the
    /// paper's "A. Ch." column in Table IV generalized to N CUs.
    pub fn offload_channel_fraction(&self) -> f64 {
        let total = self.total_channels();
        if total == 0 {
            return 0.0;
        }
        let on0: usize = self
            .layers
            .iter()
            .map(|l| l.per_cu.first().map_or(0, |c| c.channels))
            .sum();
        (total - on0) as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguity_two_way() {
        let a = LayerAssignment {
            layer: "l".into(),
            cu_of: vec![0, 0, 1, 1, 1],
        };
        assert!(a.is_contiguous());
        let b = LayerAssignment {
            layer: "l".into(),
            cu_of: vec![0, 1, 0, 1],
        };
        assert!(!b.is_contiguous());
        let c = LayerAssignment::all_on("l", 4, 1);
        assert!(c.is_contiguous());
        assert_eq!(c.count(1), 4);
        assert_eq!(c.count(0), 0);
    }

    #[test]
    fn contiguity_n_way() {
        let good = LayerAssignment {
            layer: "l".into(),
            cu_of: vec![1, 1, 0, 2, 2, 2],
        };
        assert!(good.is_contiguous());
        let bad = LayerAssignment {
            layer: "l".into(),
            cu_of: vec![0, 2, 1, 2],
        };
        assert!(!bad.is_contiguous());
        assert_eq!(good.counts(3), vec![1, 2, 3]);
        assert_eq!(good.max_cu(), Some(2));
    }

    #[test]
    fn macs() {
        let l = Layer {
            name: "t".into(),
            ltype: LayerType::Conv,
            cin: 16,
            cout: 32,
            k: 3,
            ox: 8,
            oy: 8,
            stride: 1,
            searchable: true,
        };
        assert_eq!(l.macs_std(32), 32 * 16 * 9 * 64);
        assert_eq!(l.macs_dw(32), 32 * 9 * 64);
    }

    #[test]
    fn offload_round_robin_spreads_tail() {
        let a = LayerAssignment::offload_round_robin("l", 6, 4, 3);
        assert_eq!(a.cu_of[..2], [0, 0]);
        assert!(a.cu_of[2..].iter().all(|&c| c == 1 || c == 2));
        assert_eq!(a.counts(3).iter().sum::<usize>(), 6);
        // degenerate shapes stay on column 0
        assert_eq!(
            LayerAssignment::offload_round_robin("l", 4, 4, 1).cu_of,
            vec![0; 4]
        );
        assert_eq!(
            LayerAssignment::offload_round_robin("l", 4, 0, 3).cu_of,
            vec![0; 4]
        );
    }

    #[test]
    fn layer_type_from_str() {
        assert_eq!("conv".parse::<LayerType>().unwrap(), LayerType::Conv);
        assert_eq!("search".parse::<LayerType>().unwrap(), LayerType::Search);
        assert!("warp".parse::<LayerType>().is_err());
        assert_eq!(LayerType::Dw.name(), "dw");
    }

    #[test]
    fn well_formed_checks_columns() {
        let m = Mapping {
            platform: Platform::diana(),
            layers: vec![LayerAssignment::all_on("l", 4, 1)],
        };
        assert!(m.is_well_formed());
        let bad = Mapping {
            platform: Platform::diana(),
            layers: vec![LayerAssignment::all_on("l", 4, 2)],
        };
        assert!(!bad.is_well_formed());
    }
}
