//! The platform registry: data-driven N-CU SoC descriptors.
//!
//! A [`PlatformSpec`] describes one SoC — clock, idle power, and an ordered
//! list of [`CuSpec`] compute units, each with its supported ops, data
//! representation, power, detailed-sim factors, and a parameterized
//! [`CuModel`] cost model. Specs are parsed from the JSON descriptors under
//! `hw/` (schema: `hw/README.md`) with the in-tree `util::json`.
//!
//! DIANA, Darkside, the synthetic tri-CU `trident`, and the GAP9-style
//! `gap9` SoC are built in: registered at first use from the checkout's
//! `hw/<name>.json` when present (so descriptors are runtime-tunable,
//! like `hw/constants.json`), falling back to the embedded copies of the
//! same files.
//! [`Platform::get`] additionally discovers any other `hw/<name>.json`
//! descriptor at runtime, so new SoCs need no simulator changes.
//! [`Platform`] itself is a `Copy` handle onto the registered
//! `&'static PlatformSpec` — the type every simulator / mapping / report
//! API carries around.

use std::collections::BTreeMap;
use std::fmt;
use std::str::FromStr;
use std::sync::{Mutex, OnceLock};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{parse, Value};

use super::model::LayerType;

/// Embedded built-in descriptors (same files a checkout has under `hw/`).
pub const DIANA_JSON: &str = include_str!("../../../hw/diana.json");
pub const DARKSIDE_JSON: &str = include_str!("../../../hw/darkside.json");
pub const TRIDENT_JSON: &str = include_str!("../../../hw/trident.json");
/// GAP9-style 3-CU edge SoC: 9-core cluster + NE16 conv engine + fabric
/// controller.
pub const GAP9_JSON: &str = include_str!("../../../hw/gap9.json");

/// Parameterized per-CU cost model (exact formulas:
/// `soc::analytical::cu_cycles`).
#[derive(Debug, Clone, PartialEq)]
pub enum CuModel {
    /// digital PE grid (DIANA digital): output channels tile over rows,
    /// the input patch over columns; weights stream byte-wise
    PeGrid {
        pe_rows: usize,
        pe_cols: usize,
        macs_per_cycle_per_pe: f64,
        weight_load_bytes_per_cycle: f64,
        /// depthwise work wastes the grid (paper Sec. IV-B)
        dw_inefficiency: f64,
    },
    /// in-memory analog array (DIANA AIMC): cell (re)loading dominates,
    /// plus one array operation per output pixel per tile
    AnalogArray {
        array_rows: usize,
        array_cols: usize,
        cells_load_per_cycle: f64,
        cycles_per_analog_op: f64,
    },
    /// software SIMD cluster (Darkside RISC-V octa-core): im2col + MACs
    SimdCluster {
        cores: usize,
        macs_per_cycle_std: f64,
        macs_per_cycle_dw: f64,
        im2col_overhead: f64,
    },
    /// dedicated depthwise engine (Darkside DWE)
    DwEngine {
        macs_per_cycle: f64,
        weight_cfg_cells_per_cycle: f64,
    },
}

impl CuModel {
    pub fn kind(&self) -> &'static str {
        match self {
            CuModel::PeGrid { .. } => "pe_grid",
            CuModel::AnalogArray { .. } => "analog_array",
            CuModel::SimdCluster { .. } => "simd_cluster",
            CuModel::DwEngine { .. } => "dw_engine",
        }
    }

    fn parse(v: &Value) -> Result<CuModel> {
        let kind = v.str_of("kind")?;
        Ok(match kind.as_str() {
            "pe_grid" => CuModel::PeGrid {
                pe_rows: v.usize_of("pe_rows")?,
                pe_cols: v.usize_of("pe_cols")?,
                macs_per_cycle_per_pe: v.f64_of("macs_per_cycle_per_pe")?,
                weight_load_bytes_per_cycle: v.f64_of("weight_load_bytes_per_cycle")?,
                dw_inefficiency: v.f64_of("dw_inefficiency")?,
            },
            "analog_array" => CuModel::AnalogArray {
                array_rows: v.usize_of("array_rows")?,
                array_cols: v.usize_of("array_cols")?,
                cells_load_per_cycle: v.f64_of("cells_load_per_cycle")?,
                cycles_per_analog_op: v.f64_of("cycles_per_analog_op")?,
            },
            "simd_cluster" => CuModel::SimdCluster {
                cores: v.usize_of("cores")?,
                macs_per_cycle_std: v.f64_of("macs_per_cycle_std")?,
                macs_per_cycle_dw: v.f64_of("macs_per_cycle_dw")?,
                im2col_overhead: v.f64_of("im2col_overhead")?,
            },
            "dw_engine" => CuModel::DwEngine {
                macs_per_cycle: v.f64_of("macs_per_cycle")?,
                weight_cfg_cells_per_cycle: v.f64_of("weight_cfg_cells_per_cycle")?,
            },
            other => bail!(
                "unknown cost model kind '{other}' \
                 (expected pe_grid|analog_array|simd_cluster|dw_engine)"
            ),
        })
    }

    fn to_json(&self) -> Value {
        match *self {
            CuModel::PeGrid {
                pe_rows,
                pe_cols,
                macs_per_cycle_per_pe,
                weight_load_bytes_per_cycle,
                dw_inefficiency,
            } => Value::obj(vec![
                ("kind", Value::str("pe_grid")),
                ("pe_rows", Value::num(pe_rows as f64)),
                ("pe_cols", Value::num(pe_cols as f64)),
                ("macs_per_cycle_per_pe", Value::num(macs_per_cycle_per_pe)),
                (
                    "weight_load_bytes_per_cycle",
                    Value::num(weight_load_bytes_per_cycle),
                ),
                ("dw_inefficiency", Value::num(dw_inefficiency)),
            ]),
            CuModel::AnalogArray {
                array_rows,
                array_cols,
                cells_load_per_cycle,
                cycles_per_analog_op,
            } => Value::obj(vec![
                ("kind", Value::str("analog_array")),
                ("array_rows", Value::num(array_rows as f64)),
                ("array_cols", Value::num(array_cols as f64)),
                ("cells_load_per_cycle", Value::num(cells_load_per_cycle)),
                ("cycles_per_analog_op", Value::num(cycles_per_analog_op)),
            ]),
            CuModel::SimdCluster {
                cores,
                macs_per_cycle_std,
                macs_per_cycle_dw,
                im2col_overhead,
            } => Value::obj(vec![
                ("kind", Value::str("simd_cluster")),
                ("cores", Value::num(cores as f64)),
                ("macs_per_cycle_std", Value::num(macs_per_cycle_std)),
                ("macs_per_cycle_dw", Value::num(macs_per_cycle_dw)),
                ("im2col_overhead", Value::num(im2col_overhead)),
            ]),
            CuModel::DwEngine {
                macs_per_cycle,
                weight_cfg_cells_per_cycle,
            } => Value::obj(vec![
                ("kind", Value::str("dw_engine")),
                ("macs_per_cycle", Value::num(macs_per_cycle)),
                (
                    "weight_cfg_cells_per_cycle",
                    Value::num(weight_cfg_cells_per_cycle),
                ),
            ]),
        }
    }
}

/// One compute unit of a platform.
#[derive(Debug, Clone, PartialEq)]
pub struct CuSpec {
    pub name: String,
    /// data representation ("int8", "ternary", ...)
    pub quant: String,
    /// layer operations the CU supports (reporting + mapping heuristics)
    pub ops: Vec<LayerType>,
    /// fixed per-layer configuration cost, cycles
    pub setup_cycles: u64,
    /// active power while computing, mW
    pub p_act_mw: f64,
    /// the *analytical* model counts the L2→L1 input DMA for this CU
    /// (the paper's Darkside-vs-DIANA model-completeness asymmetry)
    pub input_dma: bool,
    /// detailed-sim memory-stall multiplier (fraction of extra cycles)
    pub stall_factor: f64,
    /// detailed-sim deterministic jitter amplitude
    pub variability: f64,
    /// optional weight-memory capacity (bytes / cells): the largest weight
    /// footprint one layer may park on this CU (AIMC array size, L1 weight
    /// budget). `None` = unconstrained. Enforced by the search feasibility
    /// check, not by the simulators — an infeasible mapping still simulates
    /// so that reports can show *why* it was rejected.
    pub mem_capacity_bytes: Option<u64>,
    pub model: CuModel,
}

impl CuSpec {
    pub fn supports(&self, t: LayerType) -> bool {
        self.ops.contains(&t)
    }

    fn parse(v: &Value) -> Result<CuSpec> {
        let ops = v
            .req("ops")?
            .as_arr()?
            .iter()
            .map(|o| o.as_str()?.parse::<LayerType>())
            .collect::<Result<Vec<_>>>()?;
        Ok(CuSpec {
            name: v.str_of("name")?,
            quant: v.str_of("quant")?,
            ops,
            setup_cycles: v.usize_of("setup_cycles")? as u64,
            p_act_mw: v.f64_of("p_act_mw")?,
            input_dma: v.bool_of("input_dma")?,
            stall_factor: v.f64_of("stall_factor")?,
            variability: v.f64_of("variability")?,
            mem_capacity_bytes: match v.get("mem_capacity_bytes") {
                Some(x) => Some(x.as_usize()? as u64),
                None => None,
            },
            model: CuModel::parse(v.req("model")?)
                .with_context(|| format!("cu '{}' cost model", v.str_of("name").unwrap_or_default()))?,
        })
    }

    fn to_json(&self) -> Value {
        let mut pairs = vec![
            ("name", Value::str(&self.name)),
            ("quant", Value::str(&self.quant)),
            (
                "ops",
                Value::arr(self.ops.iter().map(|o| Value::str(o.name()))),
            ),
            ("setup_cycles", Value::num(self.setup_cycles as f64)),
            ("p_act_mw", Value::num(self.p_act_mw)),
            ("input_dma", Value::Bool(self.input_dma)),
            ("stall_factor", Value::num(self.stall_factor)),
            ("variability", Value::num(self.variability)),
        ];
        if let Some(cap) = self.mem_capacity_bytes {
            pairs.push(("mem_capacity_bytes", Value::num(cap as f64)));
        }
        pairs.push(("model", self.model.to_json()));
        Value::obj(pairs)
    }
}

/// A whole-SoC descriptor.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformSpec {
    pub name: String,
    pub freq_mhz: f64,
    pub p_idle_mw: f64,
    /// ordered CUs; the index is the cost-model / θ column
    pub cus: Vec<CuSpec>,
}

impl PlatformSpec {
    /// Parse + validate a descriptor from JSON text.
    pub fn parse(text: &str) -> Result<PlatformSpec> {
        let v = parse(text)?;
        let spec = PlatformSpec {
            name: v.str_of("name")?,
            freq_mhz: v.f64_of("freq_mhz")?,
            p_idle_mw: v.f64_of("p_idle_mw")?,
            cus: v
                .req("cus")?
                .as_arr()?
                .iter()
                .map(CuSpec::parse)
                .collect::<Result<_>>()?,
        };
        spec.validate()?;
        Ok(spec)
    }

    fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            bail!("platform descriptor has an empty name");
        }
        if self.freq_mhz <= 0.0 {
            bail!("{}: freq_mhz must be positive", self.name);
        }
        if self.cus.is_empty() {
            bail!("{}: a platform needs at least one CU", self.name);
        }
        for (i, cu) in self.cus.iter().enumerate() {
            if self.cus[..i].iter().any(|c| c.name == cu.name) {
                bail!("{}: duplicate CU name '{}'", self.name, cu.name);
            }
            if cu.ops.is_empty() {
                bail!("{}/{}: CU supports no ops", self.name, cu.name);
            }
            if !(0.0..1.0).contains(&cu.stall_factor) {
                bail!("{}/{}: stall_factor must be in [0, 1)", self.name, cu.name);
            }
        }
        Ok(())
    }

    /// JSON view — `parse(to_json().to_string_pretty())` round-trips.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", Value::str(&self.name)),
            ("freq_mhz", Value::num(self.freq_mhz)),
            ("p_idle_mw", Value::num(self.p_idle_mw)),
            ("cus", Value::arr(self.cus.iter().map(|c| c.to_json()))),
        ])
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

type Registry = BTreeMap<String, &'static PlatformSpec>;

/// Load a built-in platform: the checkout's `hw/<name>.json` when present
/// and valid (so descriptors are runtime-tunable, like `hw/constants.json`),
/// the embedded copy otherwise.
fn load_builtin(name: &str, embedded: &str) -> PlatformSpec {
    let path = crate::repo_root().join("hw").join(format!("{name}.json"));
    if let Ok(text) = std::fs::read_to_string(&path) {
        match PlatformSpec::parse(&text) {
            Ok(spec) if spec.name == name => return spec,
            Ok(spec) => eprintln!(
                "warning: {} declares name '{}'; using embedded {name} descriptor",
                path.display(),
                spec.name
            ),
            Err(e) => eprintln!(
                "warning: {} is unreadable ({e:#}); using embedded {name} descriptor",
                path.display()
            ),
        }
    }
    PlatformSpec::parse(embedded).expect("built-in platform descriptor parses")
}

fn registry() -> &'static Mutex<Registry> {
    static R: OnceLock<Mutex<Registry>> = OnceLock::new();
    R.get_or_init(|| {
        let mut m = Registry::new();
        for (name, text) in [
            ("diana", DIANA_JSON),
            ("darkside", DARKSIDE_JSON),
            ("trident", TRIDENT_JSON),
            ("gap9", GAP9_JSON),
        ] {
            let spec: &'static PlatformSpec = Box::leak(Box::new(load_builtin(name, text)));
            m.insert(spec.name.clone(), spec);
        }
        Mutex::new(m)
    })
}

/// Names of all registered platforms (built-ins + anything registered or
/// discovered so far), sorted.
pub fn platform_names() -> Vec<String> {
    registry().lock().unwrap().keys().cloned().collect()
}

/// `Copy` handle onto a registered platform descriptor.
#[derive(Clone, Copy)]
pub struct Platform {
    spec: &'static PlatformSpec,
}

impl Platform {
    /// Look up a platform by name. Built-ins resolve immediately; unknown
    /// names fall back to loading `repo_root()/hw/<name>.json` once.
    pub fn get(name: &str) -> Result<Platform> {
        {
            let reg = registry().lock().unwrap();
            if let Some(&spec) = reg.get(name) {
                return Ok(Platform { spec });
            }
        }
        let path = crate::repo_root().join("hw").join(format!("{name}.json"));
        if path.exists() {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading descriptor {}", path.display()))?;
            let spec = PlatformSpec::parse(&text)
                .with_context(|| format!("parsing descriptor {}", path.display()))?;
            if spec.name != name {
                bail!(
                    "descriptor {} declares name '{}', expected '{name}'",
                    path.display(),
                    spec.name
                );
            }
            return Ok(Platform::register(spec));
        }
        Err(anyhow!(
            "unknown platform '{name}' (registered: {}; or add hw/{name}.json)",
            platform_names().join(", ")
        ))
    }

    /// Register (or replace) a spec programmatically; returns its handle.
    pub fn register(spec: PlatformSpec) -> Platform {
        let spec: &'static PlatformSpec = Box::leak(Box::new(spec));
        registry()
            .lock()
            .unwrap()
            .insert(spec.name.clone(), spec);
        Platform { spec }
    }

    pub fn diana() -> Platform {
        Platform::get("diana").expect("built-in diana spec")
    }

    pub fn darkside() -> Platform {
        Platform::get("darkside").expect("built-in darkside spec")
    }

    pub fn trident() -> Platform {
        Platform::get("trident").expect("built-in trident spec")
    }

    pub fn gap9() -> Platform {
        Platform::get("gap9").expect("built-in gap9 spec")
    }

    pub fn name(&self) -> &'static str {
        &self.spec.name
    }

    pub fn spec(&self) -> &'static PlatformSpec {
        self.spec
    }

    pub fn cus(&self) -> &'static [CuSpec] {
        &self.spec.cus
    }

    pub fn n_cus(&self) -> usize {
        self.spec.cus.len()
    }

    pub fn freq_mhz(&self) -> f64 {
        self.spec.freq_mhz
    }

    pub fn p_idle_mw(&self) -> f64 {
        self.spec.p_idle_mw
    }
}

impl fmt::Debug for Platform {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.spec.name)
    }
}

impl PartialEq for Platform {
    fn eq(&self, other: &Platform) -> bool {
        // registry guarantees one live spec per name; replacing a spec
        // keeps old handles comparing equal by name, which is the intent
        self.spec.name == other.spec.name
    }
}

impl Eq for Platform {}

impl FromStr for Platform {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Platform> {
        Platform::get(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_register_and_resolve() {
        for (name, n_cus) in [("diana", 2), ("darkside", 2), ("trident", 3), ("gap9", 3)] {
            let p = Platform::get(name).unwrap();
            assert_eq!(p.name(), name);
            assert_eq!(p.n_cus(), n_cus);
            assert!(p.freq_mhz() > 0.0);
        }
        assert!(platform_names().len() >= 4);
        assert!("nonexistent-soc".parse::<Platform>().is_err());
    }

    #[test]
    fn spec_json_roundtrip() {
        for text in [DIANA_JSON, DARKSIDE_JSON, TRIDENT_JSON, GAP9_JSON] {
            let spec = PlatformSpec::parse(text).unwrap();
            let re = PlatformSpec::parse(&spec.to_json().to_string_pretty()).unwrap();
            assert_eq!(spec, re);
        }
    }

    #[test]
    fn validation_rejects_bad_descriptors() {
        // no CUs
        let bad = r#"{"name": "x", "freq_mhz": 100.0, "p_idle_mw": 1.0, "cus": []}"#;
        assert!(PlatformSpec::parse(bad).is_err());
        // duplicate CU names
        let mut spec = PlatformSpec::parse(TRIDENT_JSON).unwrap();
        spec.cus[1].name = spec.cus[0].name.clone();
        assert!(PlatformSpec::parse(&spec.to_json().to_string_pretty()).is_err());
        // unknown op
        let bad_op = DIANA_JSON.replace("\"conv\"", "\"warp\"");
        assert!(PlatformSpec::parse(&bad_op).is_err());
        // unknown model kind
        let bad_kind = DIANA_JSON.replace("pe_grid", "quantum_grid");
        assert!(PlatformSpec::parse(&bad_kind).is_err());
    }

    #[test]
    fn platform_equality_and_debug() {
        let a = Platform::diana();
        let b = Platform::get("diana").unwrap();
        assert_eq!(a, b);
        assert_ne!(a, Platform::darkside());
        assert_eq!(format!("{a:?}"), "diana");
    }

    #[test]
    fn mem_capacity_is_optional_and_roundtrips() {
        // the built-in descriptors ship capacities for their accelerator CUs
        let spec = PlatformSpec::parse(TRIDENT_JSON).unwrap();
        assert!(
            spec.cus.iter().any(|c| c.mem_capacity_bytes.is_some()),
            "trident should declare at least one weight-memory capacity"
        );
        let re = PlatformSpec::parse(&spec.to_json().to_string_pretty()).unwrap();
        assert_eq!(spec, re);
        // a CU without the key parses to None and round-trips key-less
        let mut uncapped = spec.clone();
        for cu in &mut uncapped.cus {
            cu.mem_capacity_bytes = None;
        }
        let text = uncapped.to_json().to_string_pretty();
        assert!(!text.contains("mem_capacity_bytes"));
        let re = PlatformSpec::parse(&text).unwrap();
        assert_eq!(uncapped, re);
    }

    #[test]
    fn register_makes_platform_resolvable() {
        let mut spec = PlatformSpec::parse(TRIDENT_JSON).unwrap();
        spec.name = "trident-test-clone".into();
        let p = Platform::register(spec);
        assert_eq!(Platform::get("trident-test-clone").unwrap(), p);
    }
}
