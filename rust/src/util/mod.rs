//! In-tree utility substrates (the offline crate cache carries only the
//! `xla` tree + `anyhow`, so JSON, CLI parsing, benching and property
//! testing are implemented here — see Cargo.toml note).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
