//! Micro-benchmark harness (criterion is not in the offline cache).
//!
//! Used by the `cargo bench` targets (`harness = false`): warms up, runs
//! timed iterations until a wall budget or iteration cap is reached, and
//! reports mean / p50 / p95 / min. Deliberately simple, deterministic in
//! iteration count, and dependency-free.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>8} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Benchmark `f`, spending roughly `budget` wall time (after `warmup`
/// iterations), capped at `max_iters`.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, budget: Duration, max_iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget && samples.len() < max_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    if samples.is_empty() {
        samples.push(f64::NAN);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let q = |p: f64| samples[((n as f64 - 1.0) * p) as usize];
    let r = BenchResult {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        p50_ns: q(0.50),
        p95_ns: q(0.95),
        min_ns: samples[0],
    };
    println!("{}", r.report());
    r
}

/// Convenience: default budget (1s) / warmup (3) / cap (10_000).
pub fn quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, 3, Duration::from_secs(1), 10_000, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("noop+sum", 1, Duration::from_millis(50), 1000, || {
            let s: u64 = (0..1000u64).sum();
            std::hint::black_box(s);
        });
        assert!(r.iters >= 1);
        assert!(r.mean_ns > 0.0);
        assert!(r.p95_ns >= r.p50_ns);
        assert!(r.p50_ns >= r.min_ns);
    }

    #[test]
    fn format_scales() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5.0e3).ends_with("us"));
        assert!(fmt_ns(5.0e6).ends_with("ms"));
        assert!(fmt_ns(5.0e9).ends_with(" s"));
    }
}
