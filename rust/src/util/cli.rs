//! Tiny CLI argument parser (clap is not in the offline cache).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Parse raw args. `flag_names` lists options that take no value.
pub fn parse(raw: impl IntoIterator<Item = String>, flag_names: &[&str]) -> Result<Args> {
    let mut out = Args::default();
    let mut iter = raw.into_iter().peekable();
    while let Some(a) = iter.next() {
        if let Some(stripped) = a.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                out.options.insert(k.to_string(), v.to_string());
            } else if flag_names.contains(&stripped) {
                out.flags.push(stripped.to_string());
            } else {
                let v = iter
                    .next()
                    .ok_or_else(|| anyhow!("option --{stripped} needs a value"))?;
                out.options.insert(stripped.to_string(), v);
            }
        } else {
            out.positional.push(a);
        }
    }
    Ok(out)
}

impl Args {
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key}: expected a number, got '{v}'")),
        }
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key}: expected an integer, got '{v}'")),
        }
    }

    /// Parse an optional typed option via `FromStr` (e.g. a search
    /// strategy or platform name), failing fast with the offending key in
    /// the error.
    pub fn opt_parse<T>(&self, key: &str) -> Result<Option<T>>
    where
        T: std::str::FromStr,
        T::Err: Into<anyhow::Error>,
    {
        match self.opt(key) {
            None => Ok(None),
            Some(v) => v.parse::<T>().map(Some).map_err(|e| {
                let e: anyhow::Error = e.into();
                e.context(format!("option --{key}"))
            }),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn require(&self, key: &str) -> Result<String> {
        match self.opt(key) {
            Some(v) => Ok(v.to_string()),
            None => bail!("missing required option --{key}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn mixed_forms() {
        let a = parse(
            s(&["exp", "fig5", "--task=c10", "--soc", "diana", "--fast", "0.5", "--verbose"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["exp", "fig5"]);
        assert_eq!(a.opt("task"), Some("c10"));
        assert_eq!(a.opt("soc"), Some("diana"));
        assert_eq!(a.opt_f64("fast", 1.0).unwrap(), 0.5);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.opt_or("missing", "d"), "d");
    }

    #[test]
    fn opt_parse_typed() {
        let a = parse(s(&["--n", "42"]), &[]).unwrap();
        assert_eq!(a.opt_parse::<usize>("n").unwrap(), Some(42));
        assert_eq!(a.opt_parse::<usize>("missing").unwrap(), None);
        let bad = parse(s(&["--n", "abc"]), &[]).unwrap();
        let err = bad.opt_parse::<usize>("n").unwrap_err();
        assert!(format!("{err:#}").contains("--n"), "{err:#}");
    }

    #[test]
    fn errors() {
        assert!(parse(s(&["--key"]), &[]).is_err());
        let a = parse(s(&["--n", "abc"]), &[]).unwrap();
        assert!(a.opt_usize("n", 0).is_err());
        assert!(a.require("other").is_err());
    }
}
