//! Minimal JSON implementation (parser + writer).
//!
//! The crate cache in this environment has no `serde`/`serde_json`, so the
//! manifest contract and all result files go through this hand-rolled
//! implementation. It supports the full JSON grammar we emit/consume:
//! objects, arrays, strings (with escapes), numbers, booleans, null.
//! Object key order is preserved (insertion order) so written files diff
//! cleanly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// object: ordered (key, value) pairs
    Obj(Vec<(String, Value)>),
}

impl Value {
    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Ok(o),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// String field shortcut.
    pub fn str_of(&self, key: &str) -> Result<String> {
        Ok(self.req(key)?.as_str()?.to_string())
    }

    pub fn f64_of(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64()
    }

    pub fn usize_of(&self, key: &str) -> Result<usize> {
        self.req(key)?.as_usize()
    }

    pub fn bool_of(&self, key: &str) -> Result<bool> {
        self.req(key)?.as_bool()
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Value>>(items: I) -> Value {
        Value::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }

    /// Convert a string-keyed map (sorted output).
    pub fn from_map(m: &BTreeMap<String, Value>) -> Value {
        Value::Obj(m.iter().map(|(k, v)| (k.clone(), v.clone())).collect())
    }

    // -- writer --------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Value::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !pairs.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(text: &str) -> Result<Value> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        bail!("trailing characters at offset {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of JSON"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!(
                "expected '{}' at offset {}, got '{}'",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of JSON"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            pairs.push((key, v));
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => break,
                c => bail!("expected ',' or '}}' in object, got '{}'", c as char),
            }
        }
        Ok(Value::Obj(pairs))
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => break,
                c => bail!("expected ',' or ']' in array, got '{}'", c as char),
            }
        }
        Ok(Value::Arr(items))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => break,
                b'\\' => {
                    let e = self.bump()?;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'u' => {
                            let mut code = 0u32;
                            for _ in 0..4 {
                                let h = self.bump()?;
                                code = code * 16
                                    + (h as char)
                                        .to_digit(16)
                                        .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            }
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                _ => {
                    // multi-byte UTF-8: copy the remaining continuation bytes
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
        Ok(s)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = s
            .parse()
            .map_err(|_| anyhow!("invalid number '{s}' at offset {start}"))?;
        Ok(Value::Num(n))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basics() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny", -2.5e3], "c": {"d": ""}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.usize_of("a").unwrap(), 1);
        let arr = v.req("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Value::Bool(true));
        assert_eq!(arr[1], Value::Null);
        assert_eq!(arr[2].as_str().unwrap(), "x\ny");
        assert_eq!(arr[3].as_f64().unwrap(), -2500.0);
        // reparse what we print
        let v2 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
        let v3 = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#"{"s": "héllo é ∆"}"#).unwrap();
        assert_eq!(v.str_of("s").unwrap(), "héllo é ∆");
        let out = v.to_string_compact();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("123 45").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        let v = Value::obj(vec![("n", Value::num(42.0)), ("f", Value::num(1.5))]);
        let s = v.to_string_compact();
        assert!(s.contains("\"n\":42"), "{s}");
        assert!(s.contains("\"f\":1.5"), "{s}");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(vec![]));
        assert_eq!(parse("[]").unwrap().to_string_pretty(), "[]");
    }
}
