//! Tiny property-testing harness (proptest is not in the offline cache).
//!
//! `check(cases, gen, prop)` draws `cases` random inputs from `gen` using
//! the deterministic dataset RNG and asserts `prop` on each; on failure it
//! reports the seed/case so the exact input can be replayed. Used by the
//! coordinator/mapping/simulator invariant tests.

use crate::datasets::rng::Rng;

/// Run `prop` on `cases` generated inputs. Panics (with the case index and
/// seed) on the first falsified case.
pub fn check<T: std::fmt::Debug>(
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let seed = std::env::var("ODIMO_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD1CEu64);
    for case in 0..cases {
        let mut rng = Rng::from_stream(seed, 0x9999, case as u64);
        let input = gen(&mut rng);
        assert!(
            prop(&input),
            "property falsified on case {case} (seed {seed}): {input:?}"
        );
    }
}

/// Generator helpers.
pub mod gen {
    use crate::datasets::rng::Rng;

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    pub fn f32_vec(rng: &mut Rng, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| rng.uniform(lo, hi)).collect()
    }

    pub fn cu_vec(rng: &mut Rng, len: usize) -> Vec<u8> {
        cu_vec_n(rng, len, 2)
    }

    /// Random channel→CU assignment over `n_cus` columns.
    pub fn cu_vec_n(rng: &mut Rng, len: usize, n_cus: usize) -> Vec<u8> {
        (0..len).map(|_| (rng.below(n_cus)) as u8).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(50, |r| gen::usize_in(r, 1, 10), |&n| n >= 1 && n <= 10);
    }

    #[test]
    #[should_panic(expected = "property falsified")]
    fn reports_failure() {
        check(50, |r| gen::usize_in(r, 0, 10), |&n| n < 10);
    }
}
