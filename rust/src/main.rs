//! `repro` — the ODiMO reproduction CLI.
//!
//! Every paper experiment is one subcommand (`repro exp fig5 ...`); ad-hoc
//! runs go through `repro train` / `repro sweep`; `repro platforms` lists
//! the registered SoC descriptors. See DESIGN.md for the experiment index
//! and the backend boundary.
//!
//! Two training engines sit behind `--backend`:
//!
//! * `native` (default when no artifacts exist) — the pure-Rust
//!   tensor/autodiff engine; variants follow the grammar
//!   `<platform>_<arch>_<task>[_w050|_w025][_fixed]` and work on any
//!   registered SoC (`repro sweep` with no `--variant` traces a Pareto
//!   front on every one of them);
//! * `xla` — the AOT artifact loader (`make artifacts` + real
//!   `xla_extension` bindings).
//!
//! ```text
//! repro list
//! repro platforms
//! repro train --variant diana_resnet20_c10 [--backend native|xla] [--lambda 0.2]
//!             [--cost-target energy] [--fast 0.5]
//! repro sweep [--variant trident_mbv1_c10] [--backend native|xla] [--no-baselines]
//! repro exp <fig5|fig6|fig7|fig8|fig9|fig10|table2|table3|table4|socmap|all>
//!           [--task c10|c100|imagenet] [--soc diana|darkside|trident|gap9|<hw/*.json>]
//!           [--fast f] [--backend native|xla]
//!           [--search greedy|descent|restart]   (socmap strategy)
//! ```

use std::path::PathBuf;

use anyhow::{bail, Result};

use odimo::config::{CostTarget, ExperimentConfig};
use odimo::coordinator::{run_baseline, sweep, Baseline, Trainer};
use odimo::runtime::{BackendKind, ModelBackend};
use odimo::search::feasible_counts;
use odimo::soc::Platform;
use odimo::util::cli;

const USAGE: &str = "usage: repro <list|platforms|train|eval|sweep|exp> [options]
  global: --artifacts DIR  --results DIR  --backend native|xla
          --threads N  (native worker threads; 0/default = all cores,
           capped at 4x the machine's cores — results are bit-identical
           for any value)
          --profile  (print the native engine's per-op time breakdown
           at exit: im2col vs matmul vs batch-norm vs optimizer ...)
  train:  --variant V [--lambda L] [--cost-target latency|energy] [--config F] [--fast F]
  eval:   --variant V [--quantized] [--steps N] [--batches N] [--seed S]
          (native only; --quantized discretizes θ and runs the real
           int8/ternary integer-GEMM inference path, reporting both it
           and the f32 fake-quant reference; --steps trains N warmup
           steps first so BN stats and θ move off init)
  sweep:  [--variant V] [--cost-target T] [--config F] [--fast F] [--no-baselines]
          (no --variant + native backend: sweeps every registered SoC)
  exp:    <fig5|fig6|fig7|fig8|fig9|fig10|table2|table3|table4|socmap|all>
          [--task c10|c100|imagenet] [--soc diana|darkside|trident|gap9|NAME] [--fast F]
          (socmap: --soc any registered platform, --task resnet|mobilenet,
           --search greedy|descent|restart)
  native variants: <platform>_<arch>_<task>[_w050|_w025][_fixed|_prune|_layerwise]
          arch: resnet20|resnet8|mbv1|tiny   task: c10|c100|imgnet|tiny";

fn main() -> Result<()> {
    let args = cli::parse(
        std::env::args().skip(1),
        &["no-baselines", "help", "profile", "quantized"],
    )?;
    if args.has_flag("help") || args.positional.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    // per-op profiler: collect across the whole command, report at exit.
    // The guard prints on drop so the breakdown also appears when a long
    // profiled run dies partway — that is when it is most useful.
    struct ProfileReport(bool);
    impl Drop for ProfileReport {
        fn drop(&mut self) {
            if self.0 {
                println!("{}", odimo::runtime::native::profile::report());
            }
        }
    }
    let profile = args.has_flag("profile");
    let _report_at_exit = ProfileReport(profile);
    if profile {
        odimo::runtime::native::profile::set_enabled(true);
    }
    let root = odimo::repo_root();
    let artifacts = args
        .opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| root.join("artifacts"));
    let results = args
        .opt("results")
        .map(PathBuf::from)
        .unwrap_or_else(|| root.join("results"));
    let fast = args.opt_f64("fast", 1.0)?;
    let backend = args.opt_parse::<BackendKind>("backend")?;
    // native worker threads; None leaves the config value (0 = all cores)
    let threads = args.opt_parse::<usize>("threads")?;

    match args.positional[0].as_str() {
        "list" => {
            let mut found = false;
            if let Ok(rd) = std::fs::read_dir(&artifacts) {
                let mut names: Vec<String> = rd
                    .flatten()
                    .filter_map(|e| {
                        e.file_name()
                            .to_string_lossy()
                            .strip_suffix(".manifest.json")
                            .map(|s| s.to_string())
                    })
                    .collect();
                names.sort();
                for v in names {
                    println!("{v} (xla artifacts)");
                    found = true;
                }
            }
            if !found {
                println!("(no XLA artifacts — run `make artifacts`, or use --backend native)");
            }
            println!(
                "native variants: <platform>_<arch>_<task>[_w050|_w025][_fixed], e.g.:"
            );
            for p in odimo::soc::platform_names() {
                println!("  {}", default_native_variant(&p)?);
            }
        }
        "platforms" => {
            for name in odimo::soc::platform_names() {
                let p = Platform::get(&name)?;
                println!(
                    "{name}: {} CUs @ {} MHz, idle {} mW",
                    p.n_cus(),
                    p.freq_mhz(),
                    p.p_idle_mw()
                );
                let rows: Vec<Vec<String>> = p
                    .cus()
                    .iter()
                    .enumerate()
                    .map(|(i, cu)| {
                        vec![
                            i.to_string(),
                            cu.name.clone(),
                            cu.model.kind().to_string(),
                            cu.quant.clone(),
                            cu.ops
                                .iter()
                                .map(|o| o.name())
                                .collect::<Vec<_>>()
                                .join(","),
                            cu.setup_cycles.to_string(),
                            format!("{}", cu.p_act_mw),
                        ]
                    })
                    .collect();
                println!(
                    "{}",
                    odimo::report::ascii_table(
                        &["col", "cu", "model", "quant", "ops", "setup", "P_act[mW]"],
                        &rows
                    )
                );
            }
        }
        "train" => {
            let variant = args.require("variant")?;
            let mut cfg = load_cfg(&args, &variant)?;
            cfg.cost_target = CostTarget::parse(&args.opt_or("cost-target", "latency"))?;
            cfg.lambdas = vec![args.opt_f64("lambda", 0.2)?];
            if let Some(t) = threads {
                cfg.threads = t;
            }
            let cfg = cfg.scaled(fast);
            let tr = Trainer::create(&artifacts, cfg, backend)?;
            eprintln!("  [backend: {}]", tr.backend.backend_name());
            let recs = sweep(&tr)?;
            for r in &recs {
                println!(
                    "{} λ={:?}: test_acc={:.4} ana_cycles={} det_ms={:.3} det_uJ={:.2} \
                     util={} offload%={:.1}",
                    r.label,
                    r.lambda,
                    r.test_acc,
                    r.ana_cycles,
                    r.det_latency_ms,
                    r.det_energy_uj,
                    r.util_display(),
                    100.0 * r.offload_frac
                );
                r.save_json(&results.join(format!(
                    "train/{}_{}.json",
                    r.variant,
                    r.lambda.unwrap_or(0.0)
                )))?;
            }
        }
        "eval" => {
            let variant = args.require("variant")?;
            let opts = odimo::runtime::native::NativeOptions {
                threads: threads.unwrap_or(1).max(1),
                ..Default::default()
            };
            let be = odimo::runtime::native::NativeBackend::build_with(&variant, opts)?;
            let m = be.manifest();
            let seed = args.opt_usize("seed", 0)?;
            let steps = args.opt_usize("steps", 0)?;
            let batches = args.opt_usize("batches", 4)?;
            let quantized = args.has_flag("quantized");
            let ds = odimo::datasets::SynthDataset::from_name(
                &m.dataset.name,
                m.dataset.hw,
                m.dataset.classes,
                seed as u64 + 1,
            );
            let mut state = be.init_state(seed as i32)?;
            let hp = odimo::runtime::StepHparams {
                lam: 0.0,
                cost_sel: 0.0,
                lr_w: 0.05,
                lr_th: 0.05,
            };
            for i in 0..steps {
                let (x, y) =
                    ds.batch(odimo::datasets::Split::Train, i as u64, m.dataset.batch);
                be.train_step(&mut state, &x, &y, hp)?;
            }
            let mut n = 0usize;
            let mut f32_m = [0.0f32; 2];
            let mut q_m = [0.0f32; 2];
            // discretize + quantize once — weights are constant during
            // eval, so the QuantNet is reused across every batch
            let qnet = if quantized {
                Some(be.quantize(&state)?)
            } else {
                None
            };
            for i in 0..batches {
                let (x, y) =
                    ds.batch(odimo::datasets::Split::Test, i as u64, m.dataset.batch);
                n += y.len();
                let r = be.eval_batch(&state, &x, &y)?;
                f32_m[0] += r[0];
                f32_m[1] += r[1];
                if let Some(q) = &qnet {
                    let r = q.eval_batch(&x, &y)?;
                    q_m[0] += r[0];
                    q_m[1] += r[1];
                }
            }
            println!(
                "{variant} f32:       acc={:.4} loss={:.4}  ({n} images)",
                f32_m[0] / n as f32,
                f32_m[1] / n as f32
            );
            if quantized {
                let tier = qnet.as_ref().map(|q| q.tier().name()).unwrap_or("-");
                println!(
                    "{variant} quantized: acc={:.4} loss={:.4}  (int8/ternary GEMM, \
                     i32 accumulators, qmatmul tier: {tier})",
                    q_m[0] / n as f32,
                    q_m[1] / n as f32
                );
            }
        }
        "sweep" => {
            // (config, pinned backend) per run; the variant-less all-SoC
            // default pins the native engine — per-variant resolution
            // could silently pick XLA for whichever variants happen to
            // have artifacts and abort the multi-SoC sweep partway
            let runs: Vec<(ExperimentConfig, Option<BackendKind>)> =
                match (args.opt("variant"), args.opt("config")) {
                    (Some(v), _) => vec![(load_cfg(&args, v)?, backend)],
                    // an explicit config names its own variant — run just that
                    (None, Some(p)) => {
                        vec![(ExperimentConfig::load(std::path::Path::new(p))?, backend)]
                    }
                    (None, None) => {
                        if backend == Some(BackendKind::Xla) {
                            bail!("sweep with --backend xla needs --variant (see `repro list`)");
                        }
                        odimo::soc::platform_names()
                            .iter()
                            .map(|p| {
                                Ok((
                                    ExperimentConfig::for_variant(&default_native_variant(p)?),
                                    Some(BackendKind::Native),
                                ))
                            })
                            .collect::<Result<_>>()?
                    }
                };
            for (mut cfg, run_backend) in runs {
                let variant = cfg.variant.clone();
                cfg.cost_target = CostTarget::parse(&args.opt_or("cost-target", "latency"))?;
                if let Some(t) = threads {
                    cfg.threads = t;
                }
                let cfg = cfg.scaled(fast);
                let tr = Trainer::create(&artifacts, cfg, run_backend)?;
                eprintln!(
                    "=== sweep {variant} on {} ({} CUs, backend: {}) ===",
                    tr.platform.name(),
                    tr.platform.n_cus(),
                    tr.backend.backend_name()
                );
                let mut recs = sweep(&tr)?;
                report_feasibility(&tr, &recs);
                if !args.has_flag("no-baselines") {
                    for b in Baseline::for_platform(tr.platform) {
                        recs.push(run_baseline(&tr, b)?);
                    }
                }
                odimo::experiments::print_sweep(&recs);
                odimo::experiments::save_records(&results.join("sweep"), &variant, &recs)?;
            }
        }
        "exp" => {
            let id = args
                .positional
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all");
            // validate --search eagerly: a typo'd strategy should fail
            // before any (long) experiment work starts
            let _ = args.opt_parse::<odimo::search::StrategyKind>("search")?;
            odimo::experiments::run(
                id,
                &artifacts,
                &results,
                args.opt("task"),
                args.opt("soc"),
                args.opt("search"),
                backend,
                threads,
                fast,
            )?;
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
    Ok(())
}

/// Native default workload for a platform: MobileNet when the SoC has a
/// depthwise-only engine to exercise (Darkside/trident-style), ResNet-20
/// otherwise.
fn default_native_variant(platform: &str) -> Result<String> {
    let p = Platform::get(platform)?;
    let has_dw_engine = p.cus().iter().any(|cu| {
        cu.supports(odimo::soc::LayerType::Dw) && !cu.supports(odimo::soc::LayerType::Conv)
    });
    let arch = if has_dw_engine { "mbv1" } else { "resnet20" };
    Ok(format!("{platform}_{arch}_c10"))
}

/// Assert-and-report the PR-2 feasibility check (op eligibility + weight
/// memory capacity) on each trained record's discretized mapping.
fn report_feasibility(tr: &Trainer, recs: &[odimo::coordinator::RunRecord]) {
    let k = tr.platform.n_cus();
    let mut bad = 0usize;
    for r in recs {
        for (layer, asg) in tr.layers.iter().zip(&r.mapping.layers) {
            if !feasible_counts(tr.platform, layer, &asg.counts(k)) {
                eprintln!(
                    "  [feasibility] λ={:?}: layer {} violates capacity/eligibility",
                    r.lambda, layer.name
                );
                bad += 1;
            }
        }
    }
    if bad == 0 {
        eprintln!(
            "  [feasibility] all {} mappings pass the capacity/eligibility check",
            recs.len()
        );
    }
}

fn load_cfg(args: &cli::Args, variant: &str) -> Result<ExperimentConfig> {
    match args.opt("config") {
        Some(p) => ExperimentConfig::load(std::path::Path::new(p)),
        None => {
            // prefer a checked-in config if one exists for the variant
            let p = odimo::repo_root().join(format!("configs/{variant}.json"));
            if p.exists() {
                ExperimentConfig::load(&p)
            } else {
                Ok(ExperimentConfig::for_variant(variant))
            }
        }
    }
}
