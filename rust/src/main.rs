//! `repro` — the ODiMO reproduction CLI.
//!
//! Every paper experiment is one subcommand (`repro exp fig5 ...`); ad-hoc
//! runs go through `repro train` / `repro sweep`; `repro platforms` lists
//! the registered SoC descriptors. See DESIGN.md §3 for the experiment
//! index.
//!
//! ```text
//! repro list
//! repro platforms
//! repro train --variant diana_resnet20_c10 [--lambda 0.2] [--cost-target energy] [--fast 0.5]
//! repro sweep --variant darkside_mbv1_c10 [--no-baselines]
//! repro exp <fig5|fig6|fig7|fig8|fig9|fig10|table2|table3|table4|socmap|all>
//!           [--task c10|c100|imagenet] [--soc diana|darkside|trident|<hw/*.json>] [--fast f]
//!           [--search greedy|descent|restart]   (socmap strategy)
//! ```

use std::path::PathBuf;

use anyhow::{bail, Result};

use odimo::config::{CostTarget, ExperimentConfig};
use odimo::coordinator::{run_baseline, sweep, Baseline, Trainer};
use odimo::soc::Platform;
use odimo::util::cli;

const USAGE: &str = "usage: repro <list|platforms|train|sweep|exp> [options]
  global: --artifacts DIR  --results DIR
  train:  --variant V [--lambda L] [--cost-target latency|energy] [--config F] [--fast F]
  sweep:  --variant V [--cost-target T] [--config F] [--fast F] [--no-baselines]
  exp:    <fig5|fig6|fig7|fig8|fig9|fig10|table2|table3|table4|socmap|all>
          [--task c10|c100|imagenet] [--soc diana|darkside|trident|NAME] [--fast F]
          (socmap: --soc any registered platform, --task resnet|mobilenet,
           --search greedy|descent|restart)";

fn main() -> Result<()> {
    let args = cli::parse(std::env::args().skip(1), &["no-baselines", "help"])?;
    if args.has_flag("help") || args.positional.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let root = odimo::repo_root();
    let artifacts = args
        .opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| root.join("artifacts"));
    let results = args
        .opt("results")
        .map(PathBuf::from)
        .unwrap_or_else(|| root.join("results"));
    let fast = args.opt_f64("fast", 1.0)?;

    match args.positional[0].as_str() {
        "list" => {
            let mut found = false;
            if let Ok(rd) = std::fs::read_dir(&artifacts) {
                let mut names: Vec<String> = rd
                    .flatten()
                    .filter_map(|e| {
                        e.file_name()
                            .to_string_lossy()
                            .strip_suffix(".manifest.json")
                            .map(|s| s.to_string())
                    })
                    .collect();
                names.sort();
                for v in names {
                    println!("{v}");
                    found = true;
                }
            }
            if !found {
                println!("(no artifacts — run `make artifacts`)");
            }
        }
        "platforms" => {
            for name in odimo::soc::platform_names() {
                let p = Platform::get(&name)?;
                println!(
                    "{name}: {} CUs @ {} MHz, idle {} mW",
                    p.n_cus(),
                    p.freq_mhz(),
                    p.p_idle_mw()
                );
                let rows: Vec<Vec<String>> = p
                    .cus()
                    .iter()
                    .enumerate()
                    .map(|(i, cu)| {
                        vec![
                            i.to_string(),
                            cu.name.clone(),
                            cu.model.kind().to_string(),
                            cu.quant.clone(),
                            cu.ops
                                .iter()
                                .map(|o| o.name())
                                .collect::<Vec<_>>()
                                .join(","),
                            cu.setup_cycles.to_string(),
                            format!("{}", cu.p_act_mw),
                        ]
                    })
                    .collect();
                println!(
                    "{}",
                    odimo::report::ascii_table(
                        &["col", "cu", "model", "quant", "ops", "setup", "P_act[mW]"],
                        &rows
                    )
                );
            }
        }
        "train" => {
            let variant = args.require("variant")?;
            let mut cfg = load_cfg(&args, &variant)?;
            cfg.cost_target = CostTarget::parse(&args.opt_or("cost-target", "latency"))?;
            cfg.lambdas = vec![args.opt_f64("lambda", 0.2)?];
            let cfg = cfg.scaled(fast);
            let client = odimo::runtime::cpu_client()?;
            let tr = Trainer::new(&client, &artifacts, cfg)?;
            let recs = sweep(&tr)?;
            for r in &recs {
                println!(
                    "{} λ={:?}: test_acc={:.4} ana_cycles={} det_ms={:.3} det_uJ={:.2} \
                     util={} offload%={:.1}",
                    r.label,
                    r.lambda,
                    r.test_acc,
                    r.ana_cycles,
                    r.det_latency_ms,
                    r.det_energy_uj,
                    r.util_display(),
                    100.0 * r.offload_frac
                );
                r.save_json(&results.join(format!(
                    "train/{}_{}.json",
                    r.variant,
                    r.lambda.unwrap_or(0.0)
                )))?;
            }
        }
        "sweep" => {
            let variant = args.require("variant")?;
            let mut cfg = load_cfg(&args, &variant)?;
            cfg.cost_target = CostTarget::parse(&args.opt_or("cost-target", "latency"))?;
            let cfg = cfg.scaled(fast);
            let client = odimo::runtime::cpu_client()?;
            let tr = Trainer::new(&client, &artifacts, cfg)?;
            let mut recs = sweep(&tr)?;
            if !args.has_flag("no-baselines") {
                for b in Baseline::for_platform(tr.platform) {
                    recs.push(run_baseline(&tr, b)?);
                }
            }
            odimo::experiments::print_sweep(&recs);
            odimo::experiments::save_records(&results.join("sweep"), &variant, &recs)?;
        }
        "exp" => {
            let id = args
                .positional
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all");
            // validate --search eagerly: a typo'd strategy should fail
            // before any (long) experiment work starts
            let _ = args.opt_parse::<odimo::search::StrategyKind>("search")?;
            odimo::experiments::run(
                id,
                &artifacts,
                &results,
                args.opt("task"),
                args.opt("soc"),
                args.opt("search"),
                fast,
            )?;
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
    Ok(())
}

fn load_cfg(args: &cli::Args, variant: &str) -> Result<ExperimentConfig> {
    match args.opt("config") {
        Some(p) => ExperimentConfig::load(std::path::Path::new(p)),
        None => {
            // prefer a checked-in config if one exists for the variant
            let p = odimo::repo_root().join(format!("configs/{variant}.json"));
            if p.exists() {
                ExperimentConfig::load(&p)
            } else {
                Ok(ExperimentConfig::for_variant(variant))
            }
        }
    }
}
