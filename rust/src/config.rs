//! Experiment configuration (JSON, hand-parsed via `util::json` — the
//! offline crate cache has neither serde nor toml).
//!
//! Each experiment config fully determines a run: variant, training
//! schedule for the three ODiMO phases, λ sweep, and evaluation sizes.
//! Configs live in `configs/*.json`; every field has a CPU-budget-friendly
//! default so ad-hoc runs work without a file.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{parse, Value};

/// Optimization target (paper Eq. 3 vs Eq. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CostTarget {
    #[default]
    Latency,
    Energy,
}

impl CostTarget {
    /// The `cost_sel` scalar the train artifact expects.
    pub fn sel(self) -> f32 {
        match self {
            CostTarget::Latency => 0.0,
            CostTarget::Energy => 1.0,
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        s.parse()
    }

    pub fn name(self) -> &'static str {
        match self {
            CostTarget::Latency => "latency",
            CostTarget::Energy => "energy",
        }
    }
}

impl std::str::FromStr for CostTarget {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "latency" => Ok(CostTarget::Latency),
            "energy" => Ok(CostTarget::Energy),
            other => bail!("cost_target must be 'latency' or 'energy', got '{other}'"),
        }
    }
}

/// Full experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// model variant name (must have artifacts)
    pub variant: String,
    pub cost_target: CostTarget,
    /// λ values, *relative to the variant's init cost scale* — the
    /// coordinator divides by the manifest `cost_scale` so comparable
    /// values work across variants
    pub lambdas: Vec<f64>,
    pub warmup_epochs: usize,
    pub search_epochs: usize,
    pub final_epochs: usize,
    /// batches per epoch (synthetic data is generated on demand)
    pub steps_per_epoch: usize,
    pub eval_batches: usize,
    pub lr_w: f32,
    pub lr_th: f32,
    pub seed: i32,
    /// early-stopping patience in epochs (0 = disabled); applies to the
    /// warmup and final phases, on validation accuracy
    pub patience: usize,
    /// native-engine worker threads (0 = available parallelism); results
    /// are bit-identical for any value — the shard structure is fixed
    pub threads: usize,
    /// W-family optimizer of the native engine: "sgdm" | "adam"
    pub w_optimizer: String,
}

impl ExperimentConfig {
    pub fn for_variant(variant: &str) -> Self {
        Self {
            variant: variant.to_string(),
            cost_target: CostTarget::Latency,
            lambdas: vec![0.05, 0.2, 1.0, 5.0],
            warmup_epochs: 6,
            search_epochs: 6,
            final_epochs: 4,
            steps_per_epoch: 30,
            eval_batches: 8,
            lr_w: 1e-2,
            lr_th: 5e-2,
            seed: 0,
            patience: 0,
            threads: 0,
            w_optimizer: "sgdm".into(),
        }
    }

    /// Parse from JSON text; missing fields fall back to defaults.
    pub fn parse(text: &str) -> Result<Self> {
        let v = parse(text)?;
        let mut cfg = Self::for_variant(&v.str_of("variant")?);
        if let Some(t) = v.get("cost_target") {
            cfg.cost_target = CostTarget::parse(t.as_str()?)?;
        }
        if let Some(l) = v.get("lambdas") {
            cfg.lambdas = l
                .as_arr()?
                .iter()
                .map(|x| x.as_f64())
                .collect::<Result<_>>()?;
        }
        let get_usize = |key: &str, slot: &mut usize| -> Result<()> {
            if let Some(x) = v.get(key) {
                *slot = x.as_usize()?;
            }
            Ok(())
        };
        get_usize("warmup_epochs", &mut cfg.warmup_epochs)?;
        get_usize("search_epochs", &mut cfg.search_epochs)?;
        get_usize("final_epochs", &mut cfg.final_epochs)?;
        get_usize("steps_per_epoch", &mut cfg.steps_per_epoch)?;
        get_usize("eval_batches", &mut cfg.eval_batches)?;
        get_usize("patience", &mut cfg.patience)?;
        get_usize("threads", &mut cfg.threads)?;
        // reject absurd worker counts eagerly (the backend re-checks the
        // resolved value) — silent oversubscription is always a typo
        let cap = crate::runtime::native::max_threads();
        if cfg.threads > cap {
            bail!(
                "config field 'threads' = {} exceeds {cap} (4x the machine's \
                 available cores): use 0 for all cores",
                cfg.threads
            );
        }
        if let Some(x) = v.get("w_optimizer") {
            cfg.w_optimizer = x.as_str()?.to_string();
            // validate eagerly: a typo'd optimizer should fail at parse time
            cfg.w_optimizer
                .parse::<crate::runtime::WOptimizer>()
                .with_context(|| "config field 'w_optimizer'".to_string())?;
        }
        if let Some(x) = v.get("lr_w") {
            cfg.lr_w = x.as_f64()? as f32;
        }
        if let Some(x) = v.get("lr_th") {
            cfg.lr_th = x.as_f64()? as f32;
        }
        if let Some(x) = v.get("seed") {
            cfg.seed = x.as_f64()? as i32;
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing config {}", path.display()))
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("variant", Value::str(&self.variant)),
            ("cost_target", Value::str(self.cost_target.name())),
            (
                "lambdas",
                Value::arr(self.lambdas.iter().map(|&l| Value::num(l))),
            ),
            ("warmup_epochs", Value::num(self.warmup_epochs as f64)),
            ("search_epochs", Value::num(self.search_epochs as f64)),
            ("final_epochs", Value::num(self.final_epochs as f64)),
            ("steps_per_epoch", Value::num(self.steps_per_epoch as f64)),
            ("eval_batches", Value::num(self.eval_batches as f64)),
            ("lr_w", Value::num(self.lr_w as f64)),
            ("lr_th", Value::num(self.lr_th as f64)),
            ("seed", Value::num(self.seed as f64)),
            ("patience", Value::num(self.patience as f64)),
            ("threads", Value::num(self.threads as f64)),
            ("w_optimizer", Value::str(&self.w_optimizer)),
        ])
    }

    /// Resolve the configured thread count (0 = available parallelism).
    pub fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        }
    }

    /// Scale the schedule by `f` (e.g. 0.25 for a quarter-length run).
    /// Evaluation batches scale too (floored at 1) so `--fast` smoke runs
    /// stay CPU-cheap end to end.
    pub fn scaled(mut self, f: f64) -> Self {
        let s = |e: usize| ((e as f64 * f).round() as usize).max(1);
        self.warmup_epochs = s(self.warmup_epochs);
        self.search_epochs = s(self.search_epochs);
        self.final_epochs = s(self.final_epochs);
        self.steps_per_epoch = s(self.steps_per_epoch);
        self.eval_batches = s(self.eval_batches);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_fill_in() {
        let cfg = ExperimentConfig::parse(r#"{"variant": "x"}"#).unwrap();
        assert_eq!(cfg.variant, "x");
        assert_eq!(cfg.warmup_epochs, 6);
        assert_eq!(cfg.cost_target, CostTarget::Latency);
        assert_eq!(cfg.cost_target.sel(), 0.0);
        assert!(!cfg.lambdas.is_empty());
    }

    #[test]
    fn energy_target_and_overrides() {
        let cfg = ExperimentConfig::parse(
            r#"{"variant": "x", "cost_target": "energy", "lambdas": [0.1, 2],
                "warmup_epochs": 3, "lr_w": 0.001, "seed": 7}"#,
        )
        .unwrap();
        assert_eq!(cfg.cost_target, CostTarget::Energy);
        assert_eq!(cfg.cost_target.sel(), 1.0);
        assert_eq!(cfg.lambdas, vec![0.1, 2.0]);
        assert_eq!(cfg.warmup_epochs, 3);
        assert_eq!(cfg.seed, 7);
        assert!((cfg.lr_w - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn roundtrip_via_json() {
        let cfg = ExperimentConfig::for_variant("v");
        let cfg2 = ExperimentConfig::parse(&cfg.to_json().to_string_pretty()).unwrap();
        assert_eq!(cfg2.variant, cfg.variant);
        assert_eq!(cfg2.lambdas, cfg.lambdas);
        assert_eq!(cfg2.steps_per_epoch, cfg.steps_per_epoch);
    }

    #[test]
    fn scaling_clamps_to_one() {
        let cfg = ExperimentConfig::for_variant("x").scaled(0.01);
        assert!(cfg.warmup_epochs >= 1);
        assert!(cfg.steps_per_epoch >= 1);
    }

    #[test]
    fn bad_cost_target_rejected() {
        assert!(ExperimentConfig::parse(r#"{"variant": "x", "cost_target": "speed"}"#).is_err());
    }

    #[test]
    fn threads_and_optimizer_fields() {
        let cfg = ExperimentConfig::parse(r#"{"variant": "x"}"#).unwrap();
        assert_eq!(cfg.threads, 0, "default = auto");
        assert!(cfg.resolved_threads() >= 1);
        assert_eq!(cfg.w_optimizer, "sgdm");
        let cfg = ExperimentConfig::parse(
            r#"{"variant": "x", "threads": 2, "w_optimizer": "adam"}"#,
        )
        .unwrap();
        assert_eq!(cfg.threads, 2);
        assert_eq!(cfg.resolved_threads(), 2);
        assert_eq!(cfg.w_optimizer, "adam");
        // round-trips through JSON
        let cfg2 = ExperimentConfig::parse(&cfg.to_json().to_string_pretty()).unwrap();
        assert_eq!(cfg2.threads, 2);
        assert_eq!(cfg2.w_optimizer, "adam");
        assert!(
            ExperimentConfig::parse(r#"{"variant": "x", "w_optimizer": "adagrad"}"#).is_err(),
            "unknown optimizer must fail at parse time"
        );
    }

    #[test]
    fn absurd_thread_counts_rejected_at_parse_time() {
        let err = ExperimentConfig::parse(r#"{"variant": "x", "threads": 1000000}"#)
            .expect_err("a million workers is a typo, not a request");
        let msg = format!("{err:#}");
        assert!(msg.contains("threads"), "{msg}");
        assert!(msg.contains("available cores"), "{msg}");
        // sane explicit counts still parse
        let cfg = ExperimentConfig::parse(r#"{"variant": "x", "threads": 2}"#).unwrap();
        assert_eq!(cfg.threads, 2);
    }
}
