//! Pareto-front extraction in the accuracy-vs-cost plane.
//!
//! Every ODiMO figure reports Pareto-optimal mappings: maximize accuracy,
//! minimize cost (latency cycles or energy). A point dominates another if
//! it is no worse on both axes and strictly better on at least one.

/// One candidate mapping in the accuracy/cost plane.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    /// cost to minimize (cycles or µJ)
    pub cost: f64,
    /// accuracy to maximize (fraction in [0,1] or percent — any monotone
    /// scale works)
    pub acc: f64,
}

impl Point {
    pub fn dominates(&self, other: &Point) -> bool {
        (self.cost <= other.cost && self.acc >= other.acc)
            && (self.cost < other.cost || self.acc > other.acc)
    }
}

/// Indices of the non-dominated points, sorted by ascending cost.
///
/// NaN-safe: ordering uses [`f64::total_cmp`] (never panics), and points
/// with a NaN coordinate are excluded from the front — a mapping whose
/// cost or accuracy failed to evaluate cannot be declared optimal.
pub fn pareto_front(points: &[Point]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&i, &j| {
        points[i]
            .cost
            .total_cmp(&points[j].cost)
            .then(points[j].acc.total_cmp(&points[i].acc))
    });
    let mut front = Vec::new();
    let mut best_acc = f64::NEG_INFINITY;
    for &i in &idx {
        if points[i].cost.is_nan() || points[i].acc.is_nan() {
            continue;
        }
        if points[i].acc > best_acc {
            front.push(i);
            best_acc = points[i].acc;
        }
    }
    front
}

/// True if `p` lies on the Pareto front of `points` (p included).
pub fn is_pareto(p: &Point, points: &[Point]) -> bool {
    !points.iter().any(|q| q.dominates(p))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_front() {
        let pts = vec![
            Point { cost: 1.0, acc: 0.5 },
            Point { cost: 2.0, acc: 0.7 },
            Point { cost: 3.0, acc: 0.6 }, // dominated by (2.0, 0.7)
            Point { cost: 4.0, acc: 0.9 },
        ];
        let f = pareto_front(&pts);
        assert_eq!(f, vec![0, 1, 3]);
        assert!(!is_pareto(&pts[2], &pts));
        assert!(is_pareto(&pts[1], &pts));
    }

    #[test]
    fn duplicate_points_keep_one() {
        let pts = vec![
            Point { cost: 1.0, acc: 0.5 },
            Point { cost: 1.0, acc: 0.5 },
        ];
        let f = pareto_front(&pts);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn nan_inputs_do_not_panic_and_are_excluded() {
        // regression: the old partial_cmp(..).unwrap() panicked on NaN
        let pts = vec![
            Point { cost: 1.0, acc: 0.5 },
            Point { cost: f64::NAN, acc: 0.9 },
            Point { cost: 2.0, acc: f64::NAN },
            Point { cost: 2.0, acc: 0.7 },
            Point { cost: f64::NAN, acc: f64::NAN },
        ];
        let f = pareto_front(&pts);
        assert_eq!(f, vec![0, 3], "NaN points must never join the front");
        // all-NaN input: empty front, no panic
        let all_nan = vec![Point { cost: f64::NAN, acc: f64::NAN }; 3];
        assert!(pareto_front(&all_nan).is_empty());
        // dominance involving NaN is always false, both directions
        assert!(!pts[1].dominates(&pts[0]));
        assert!(!pts[0].dominates(&pts[1]));
    }

    #[test]
    fn dominance_is_strict() {
        let a = Point { cost: 1.0, acc: 0.5 };
        assert!(!a.dominates(&a));
        let b = Point { cost: 1.0, acc: 0.6 };
        assert!(b.dominates(&a));
        assert!(!a.dominates(&b));
    }
}
