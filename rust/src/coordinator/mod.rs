//! The L3 coordinator — ODiMO's training-time search, orchestrated over
//! any [`crate::runtime::ModelBackend`] (the native pure-Rust engine or
//! the AOT-compiled XLA executables; the phase logic cannot tell which).
//!
//! * [`trainer`] — epoch/eval driver + θ plumbing for one model variant;
//! * [`odimo`] — the Warmup → Search → Final-Training schedule and the
//!   λ sweep producing Pareto fronts;
//! * [`baselines`] — the paper's manual/heuristic/min-cost comparison
//!   mappings;
//! * [`results`] — serializable run records consumed by the experiment
//!   harness and the report renderers.

pub mod baselines;
pub mod odimo;
pub mod results;
pub mod trainer;

pub use baselines::{run_baseline, Baseline};
pub use odimo::{search_and_finalize, sweep};
pub use results::{LayerBreakdown, RunRecord};
pub use trainer::{EpochMetrics, Trainer};
