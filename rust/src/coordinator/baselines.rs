//! Baseline mappings the paper compares against (Sec. V-A).
//!
//! * **AllCu0** — everything on CU column 0: DIANA "All-8bit" / Darkside
//!   "Standard-Conv on the cluster".
//! * **AllCu1** — everything on CU column 1: DIANA "All-Ternary" /
//!   Darkside "all depthwise on the DWE" (with the fixed pointwise layers
//!   still on the cluster — i.e. the vanilla MobileNetV1 schedule).
//! * **IoCu0** — DIANA heuristic from [8]: first (and the always-digital
//!   FC last) layer on the 8-bit CU, backbone on the AIMC.
//! * **MinCost** — the accuracy-unaware optimum: per layer, the channel
//!   split minimizing the layer's analytical latency (ties resolved
//!   toward CU 0 / digital, as the paper specifies).
//!
//! Every baseline trains its W (with θ frozen one-hot to the baseline
//! mapping) for warmup+final epochs — the same budget an ODiMO point gets.

use anyhow::Result;

use crate::datasets::Split;
use crate::mapping::SearchKind;
use crate::soc::{analytical::cu_cycles, LayerAssignment, Mapping};

use super::odimo::run_phase;
use super::results::RunRecord;
use super::trainer::Trainer;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    AllCu0,
    AllCu1,
    IoCu0,
    MinCost,
}

impl Baseline {
    pub fn label(self, platform: &str) -> &'static str {
        match (self, platform) {
            (Baseline::AllCu0, "diana") => "all-8bit",
            (Baseline::AllCu1, "diana") => "all-ternary",
            (Baseline::IoCu0, _) => "io-8bit-backbone-ternary",
            (Baseline::MinCost, _) => "min-cost",
            (Baseline::AllCu0, _) => "std-conv-cluster",
            (Baseline::AllCu1, _) => "dw-separable",
        }
    }

    /// Baselines applicable to a platform.
    pub fn for_platform(platform: &str) -> Vec<Baseline> {
        match platform {
            "diana" => vec![
                Baseline::AllCu0,
                Baseline::AllCu1,
                Baseline::IoCu0,
                Baseline::MinCost,
            ],
            _ => vec![Baseline::AllCu0, Baseline::AllCu1, Baseline::MinCost],
        }
    }
}

/// Minimum-latency channel split for one layer (accuracy-unaware):
/// minimize `max(lat_cu0(n0), lat_cu1(C-n0))` (or the sum when the two
/// stages are sequential), maximizing `n0` on ties.
pub fn min_cost_split(tr: &Trainer, li: usize) -> usize {
    let layer = &tr.layers[li];
    let cus = tr.platform.cus();
    let sequential = tr.seq_layers.iter().any(|s| s == &layer.name);
    let c = layer.cout;
    let mut best_n0 = 0usize;
    let mut best_cost = u64::MAX;
    for n0 in 0..=c {
        let c0 = cu_cycles(cus[0], layer, n0);
        let c1 = cu_cycles(cus[1], layer, c - n0);
        let cost = if sequential { c0 + c1 } else { c0.max(c1) };
        if cost < best_cost || (cost == best_cost && n0 > best_n0) {
            best_cost = cost;
            best_n0 = n0;
        }
    }
    best_n0
}

/// Build the baseline's mapping over the manifest layer table.
pub fn baseline_mapping(tr: &Trainer, b: Baseline) -> Mapping {
    let specs = &tr.rt.manifest.layers;
    let searchable_names: Vec<&str> = specs
        .iter()
        .filter(|s| s.searchable)
        .map(|s| s.name.as_str())
        .collect();
    let first_searchable = searchable_names.first().copied().unwrap_or("");
    let mut layers = Vec::with_capacity(specs.len());
    for (li, spec) in specs.iter().enumerate() {
        let asg = if !spec.searchable {
            LayerAssignment::all_on(&spec.name, spec.cout, 0)
        } else {
            match b {
                Baseline::AllCu0 => LayerAssignment::all_on(&spec.name, spec.cout, 0),
                Baseline::AllCu1 => LayerAssignment::all_on(&spec.name, spec.cout, 1),
                Baseline::IoCu0 => {
                    let cu = u8::from(spec.name != first_searchable);
                    LayerAssignment::all_on(&spec.name, spec.cout, cu)
                }
                Baseline::MinCost => {
                    let n0 = min_cost_split(tr, li);
                    LayerAssignment {
                        layer: spec.name.clone(),
                        cu_of: (0..spec.cout).map(|c| u8::from(c >= n0)).collect(),
                    }
                }
            }
        };
        layers.push(asg);
    }
    Mapping {
        platform: tr.platform,
        layers,
    }
}

/// Train + deploy one baseline (same W budget as an ODiMO point).
pub fn run_baseline(tr: &Trainer, b: Baseline) -> Result<RunRecord> {
    // layerwise θ cannot express a channel split — min-cost degenerates
    // to whichever whole-layer choice is cheaper
    let mut mapping = baseline_mapping(tr, b);
    if tr.kind == SearchKind::Layerwise {
        for asg in &mut mapping.layers {
            let n0 = asg.count(0);
            let cu = u8::from(n0 * 2 < asg.cu_of.len());
            *asg = LayerAssignment::all_on(&asg.layer, asg.cu_of.len(), cu);
        }
    }
    let mut state = tr.init_state()?;
    tr.freeze_mapping(&mut state, &mapping)?;
    let hp = crate::runtime::StepHparams {
        lam: 0.0,
        cost_sel: 0.0,
        lr_w: tr.cfg.lr_w,
        lr_th: 0.0,
    };
    let label = b.label(&tr.rt.manifest.platform);
    // identical W budget to an ODiMO point: warmup + search + final
    let epochs = tr.cfg.warmup_epochs + tr.cfg.search_epochs + tr.cfg.final_epochs;
    let step_ms = run_phase(tr, &mut state, hp, epochs, tr.cfg.patience, label)?;
    let (val_acc, _) = tr.evaluate(&state, Split::Val)?;
    let (test_acc, _) = tr.evaluate(&state, Split::Test)?;
    let (ana, det) = tr.simulate(&mapping);
    Ok(RunRecord::from_reports(
        label,
        &tr.cfg.variant,
        None,
        "baseline",
        val_acc,
        test_acc,
        &ana,
        &det,
        mapping,
        step_ms,
        tr.state_bytes(),
    ))
}

#[cfg(test)]
mod tests {
    // min_cost_split balances: verified indirectly in integration tests
    // (requires artifacts); the pure parts are covered via
    // soc::analytical tests.
}
