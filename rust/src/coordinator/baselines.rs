//! Baseline mappings the paper compares against (Sec. V-A), enumerated
//! from the platform descriptor rather than hardcoded per SoC.
//!
//! * **AllOn(i)** — everything on CU column `i`. Column 0 is DIANA
//!   "All-8bit" / Darkside "Standard-Conv on the cluster"; column 1 is
//!   DIANA "All-Ternary" / Darkside "all depthwise on the DWE" (with the
//!   fixed pointwise layers still on the cluster — i.e. the vanilla
//!   MobileNetV1 schedule). An N-CU platform gets one such corner per CU.
//! * **IoSplit** — DIANA heuristic from [8]: first (and the always-CU0
//!   FC last) layer on the 8-bit CU, backbone on the second CU.
//! * **MinCost** — the accuracy-unaware optimum: per layer, the channel
//!   partition minimizing the layer's analytical latency (ties resolved
//!   toward CU 0 / digital, as the paper specifies). Exhaustive for two
//!   CUs; greedy channel-by-channel (same tie rule) beyond that.
//!
//! Every baseline trains its W (with θ frozen one-hot to the baseline
//! mapping) for warmup+final epochs — the same budget an ODiMO point gets.

use anyhow::Result;

use crate::datasets::Split;
use crate::mapping::{assignment_from_counts, SearchKind};
use crate::search::{finish_outcome, CostEvaluator, SearchOutcome, SearchStrategy};
use crate::soc::{analytical::cu_cycles, Layer, LayerAssignment, Mapping, Platform};

use super::odimo::run_phase;
use super::results::RunRecord;
use super::trainer::Trainer;

// The op-eligibility rule moved to the search subsystem with the rest of
// the feasibility machinery; re-exported here for its historical callers.
pub use crate::search::eligible_cus;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    /// every searchable layer entirely on CU column `.0`
    AllOn(u8),
    /// IO layers on CU 0, backbone on CU 1 (the DIANA heuristic)
    IoSplit,
    /// per-layer analytical-latency-optimal channel partition
    MinCost,
}

impl Baseline {
    /// Display label; the DIANA/Darkside names match the paper figures.
    pub fn label(self, platform: Platform) -> String {
        match (platform.name(), self) {
            ("diana", Baseline::AllOn(0)) => "all-8bit".into(),
            ("diana", Baseline::AllOn(1)) => "all-ternary".into(),
            ("darkside", Baseline::AllOn(0)) => "std-conv-cluster".into(),
            ("darkside", Baseline::AllOn(1)) => "dw-separable".into(),
            ("diana", Baseline::IoSplit) => "io-8bit-backbone-ternary".into(),
            (_, Baseline::AllOn(i)) => {
                let cu = platform
                    .cus()
                    .get(i as usize)
                    .map(|c| c.name.as_str())
                    .unwrap_or("?");
                format!("all-{cu}")
            }
            (_, Baseline::IoSplit) => {
                let cus = platform.cus();
                format!(
                    "io-{}-backbone-{}",
                    cus[0].name,
                    cus.get(1).map(|c| c.name.as_str()).unwrap_or("?")
                )
            }
            (_, Baseline::MinCost) => "min-cost".into(),
        }
    }

    /// Baselines applicable to a platform: one all-on corner per CU, the
    /// IO heuristic where it is defined (DIANA), and min-cost everywhere.
    pub fn for_platform(platform: Platform) -> Vec<Baseline> {
        let mut out: Vec<Baseline> = (0..platform.n_cus() as u8).map(Baseline::AllOn).collect();
        if platform.name() == "diana" {
            out.push(Baseline::IoSplit);
        }
        out.push(Baseline::MinCost);
        out
    }
}

/// Minimum-latency channel partition for one layer (accuracy-unaware):
/// minimize `max_i lat_i(n_i)` (or the sum when the stages are
/// sequential) over the CUs that support the layer's op (per the
/// descriptor's `ops` list). Returns per-CU channel counts summing to
/// `layer.cout`.
///
/// Two eligible CUs: exhaustive over the split point, maximizing the
/// lower column on ties (the paper's rule). More: greedy
/// channel-by-channel assignment with the same lowest-column tie rule.
pub fn min_cost_counts(platform: Platform, layer: &Layer, sequential: bool) -> Vec<usize> {
    let cus = platform.cus();
    let k = cus.len();
    let c = layer.cout;
    let eligible = eligible_cus(platform, layer);
    let cols: Vec<usize> = (0..k).filter(|&i| eligible[i]).collect();
    let objective = |counts: &[usize]| -> u64 {
        let per: Vec<u64> = cus
            .iter()
            .zip(counts)
            .map(|(cu, &n)| cu_cycles(cu, layer, n))
            .collect();
        if sequential {
            per.iter().sum()
        } else {
            per.iter().copied().max().unwrap_or(0)
        }
    };
    if cols.len() == 1 {
        let mut counts = vec![0usize; k];
        counts[cols[0]] = c;
        return counts;
    }
    if cols.len() == 2 {
        let (a, b) = (cols[0], cols[1]);
        let mut best = vec![0usize; k];
        best[b] = c;
        let mut best_cost = u64::MAX;
        for n_a in 0..=c {
            let mut counts = vec![0usize; k];
            counts[a] = n_a;
            counts[b] = c - n_a;
            let cost = objective(&counts);
            if cost < best_cost || (cost == best_cost && n_a > best[a]) {
                best_cost = cost;
                best = counts;
            }
        }
        return best;
    }
    // N-way greedy: place channels one at a time where they hurt least
    let mut counts = vec![0usize; k];
    for _ in 0..c {
        let mut best_i = cols[0];
        let mut best_cost = u64::MAX;
        for &i in &cols {
            counts[i] += 1;
            let cost = objective(&counts);
            counts[i] -= 1;
            if cost < best_cost {
                best_cost = cost;
                best_i = i;
            }
        }
        counts[best_i] += 1;
    }
    counts
}

/// Build a baseline's per-layer assignments over an explicit layer table —
/// the shared core behind both the trainer-driven [`baseline_mapping`] and
/// the training-free [`SearchStrategy`] view of a baseline.
pub fn baseline_assignments(
    platform: Platform,
    layers: &[Layer],
    b: Baseline,
    seq_layers: &[String],
) -> Vec<LayerAssignment> {
    let first_searchable = layers
        .iter()
        .find(|l| l.searchable)
        .map(|l| l.name.as_str())
        .unwrap_or("");
    layers
        .iter()
        .map(|layer| {
            if !layer.searchable {
                return LayerAssignment::all_on(&layer.name, layer.cout, 0);
            }
            match b {
                Baseline::AllOn(cu) => {
                    debug_assert!((cu as usize) < platform.n_cus());
                    // the paper's corner keeps layers the CU cannot run on
                    // the primary CU (Darkside "all-DWE" leaves pointwise
                    // on the cluster); trained variants encode this via
                    // non-searchable layers, training-free workloads via
                    // the descriptor's ops list
                    let cu = if platform.cus()[cu as usize].supports(layer.ltype) {
                        cu
                    } else {
                        0
                    };
                    LayerAssignment::all_on(&layer.name, layer.cout, cu)
                }
                Baseline::IoSplit => {
                    let cu = u8::from(layer.name != first_searchable);
                    LayerAssignment::all_on(&layer.name, layer.cout, cu)
                }
                Baseline::MinCost => {
                    let sequential = seq_layers.iter().any(|s| s == &layer.name);
                    let counts = min_cost_counts(platform, layer, sequential);
                    assignment_from_counts(&layer.name, &counts)
                }
            }
        })
        .collect()
}

/// Build the baseline's mapping over the manifest layer table.
pub fn baseline_mapping(tr: &Trainer, b: Baseline) -> Mapping {
    Mapping {
        platform: tr.platform,
        layers: baseline_assignments(tr.platform, &tr.layers, b, &tr.seq_layers),
    }
}

/// Baselines are enumerable through the same [`SearchStrategy`] trait as
/// the optimizers, so sweeps and reports can treat manual corners and
/// searched mappings uniformly. λ is ignored — a baseline is one fixed
/// point in the trade-off plane, not a family.
impl SearchStrategy for Baseline {
    fn name(&self) -> &str {
        match self {
            Baseline::AllOn(_) => "baseline-allon",
            Baseline::IoSplit => "baseline-iosplit",
            Baseline::MinCost => "baseline-mincost",
        }
    }

    fn search(
        &self,
        platform: Platform,
        layers: &[Layer],
        _lambda: f64,
        eval: &mut dyn CostEvaluator,
    ) -> SearchOutcome {
        // min-cost must optimize under the same sequential-stage latency
        // model the evaluator prices with (sum vs max of the CU stages)
        let seq_layers: Vec<String> = layers
            .iter()
            .enumerate()
            .filter(|&(li, _)| eval.layer_sequential(li))
            .map(|(_, l)| l.name.clone())
            .collect();
        let mapping = Mapping {
            platform,
            layers: baseline_assignments(platform, layers, *self, &seq_layers),
        };
        finish_outcome(self.name(), 0, 0, mapping, layers, eval)
    }
}

/// Train + deploy one baseline (same W budget as an ODiMO point).
pub fn run_baseline(tr: &Trainer, b: Baseline) -> Result<RunRecord> {
    // layerwise θ cannot express a channel split — min-cost degenerates
    // to whichever whole-layer choice carries the most channels
    let mut mapping = baseline_mapping(tr, b);
    if tr.kind == SearchKind::Layerwise {
        for asg in &mut mapping.layers {
            let counts = asg.counts(tr.platform.n_cus());
            let cu = counts
                .iter()
                .enumerate()
                .max_by_key(|&(i, &n)| (n, std::cmp::Reverse(i)))
                .map(|(i, _)| i as u8)
                .unwrap_or(0);
            *asg = LayerAssignment::all_on(&asg.layer, asg.cu_of.len(), cu);
        }
    }
    let mut state = tr.init_state()?;
    tr.freeze_mapping(&mut state, &mapping)?;
    let hp = crate::runtime::StepHparams {
        lam: 0.0,
        cost_sel: 0.0,
        lr_w: tr.cfg.lr_w,
        lr_th: 0.0,
    };
    let label = b.label(tr.platform);
    // identical W budget to an ODiMO point: warmup + search + final
    let epochs = tr.cfg.warmup_epochs + tr.cfg.search_epochs + tr.cfg.final_epochs;
    let step_ms = run_phase(tr, &mut state, hp, epochs, tr.cfg.patience, &label)?;
    let (val_acc, _) = tr.evaluate(&state, Split::Val)?;
    let (test_acc, _) = tr.evaluate(&state, Split::Test)?;
    let (ana, det) = tr.simulate(&mapping);
    Ok(RunRecord::from_reports(
        &label,
        &tr.cfg.variant,
        None,
        "baseline",
        val_acc,
        test_acc,
        &ana,
        &det,
        mapping,
        step_ms,
        tr.state_bytes(),
    )
    .with_search(SearchStrategy::name(&b), 0, 0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::LayerType;

    fn conv_layer(cin: usize, cout: usize, hw: usize) -> Layer {
        Layer {
            name: "t".into(),
            ltype: LayerType::Conv,
            cin,
            cout,
            k: 3,
            ox: hw,
            oy: hw,
            stride: 1,
            searchable: true,
        }
    }

    #[test]
    fn baselines_enumerate_from_spec() {
        let d = Baseline::for_platform(Platform::diana());
        assert_eq!(
            d,
            vec![
                Baseline::AllOn(0),
                Baseline::AllOn(1),
                Baseline::IoSplit,
                Baseline::MinCost
            ]
        );
        assert_eq!(Baseline::AllOn(0).label(Platform::diana()), "all-8bit");
        assert_eq!(Baseline::AllOn(1).label(Platform::diana()), "all-ternary");
        let s = Baseline::for_platform(Platform::darkside());
        assert_eq!(
            s,
            vec![Baseline::AllOn(0), Baseline::AllOn(1), Baseline::MinCost]
        );
        let t = Baseline::for_platform(Platform::trident());
        assert_eq!(t.len(), 4); // three corners + min-cost
        assert_eq!(Baseline::AllOn(2).label(Platform::trident()), "all-aimc");
    }

    #[test]
    fn min_cost_two_way_is_exhaustively_optimal() {
        let l = conv_layer(64, 64, 16);
        let p = Platform::diana();
        let counts = min_cost_counts(p, &l, false);
        assert_eq!(counts.iter().sum::<usize>(), 64);
        let obj = |cts: &[usize]| -> u64 {
            p.cus()
                .iter()
                .zip(cts)
                .map(|(cu, &n)| cu_cycles(cu, &l, n))
                .max()
                .unwrap()
        };
        for n0 in 0..=64usize {
            assert!(
                obj(&counts) <= obj(&[n0, 64 - n0]),
                "{counts:?} worse than split {n0}"
            );
        }
    }

    #[test]
    fn min_cost_respects_ops_lists() {
        // a standard conv on trident is not a dwe op (ops = [dw, search]),
        // so min-cost must never place conv channels there, however cheap
        // the alternative-op cost model would price them
        let l = conv_layer(64, 96, 16);
        let p = Platform::trident();
        let counts = min_cost_counts(p, &l, false);
        assert_eq!(counts.len(), 3);
        assert_eq!(counts.iter().sum::<usize>(), 96);
        assert_eq!(counts[1], 0, "dwe got conv channels: {counts:?}");
        let obj = |cts: &[usize]| -> u64 {
            p.cus()
                .iter()
                .zip(cts)
                .map(|(cu, &n)| cu_cycles(cu, &l, n))
                .max()
                .unwrap()
        };
        for corner in [[96, 0, 0], [0, 0, 96]] {
            assert!(obj(&counts) <= obj(&corner), "{counts:?} vs {corner:?}");
        }
    }

    #[test]
    fn min_cost_splits_dw_between_cluster_and_dwe() {
        // depthwise on trident: cluster and dwe are eligible, the aimc is
        // not (no "dw" in its ops) — a big dw layer splits across the two
        let l = Layer {
            name: "t".into(),
            ltype: LayerType::Dw,
            cin: 256,
            cout: 256,
            k: 3,
            ox: 4,
            oy: 4,
            stride: 1,
            searchable: true,
        };
        let p = Platform::trident();
        let counts = min_cost_counts(p, &l, false);
        assert_eq!(counts.iter().sum::<usize>(), 256);
        assert_eq!(counts[2], 0, "aimc got dw channels: {counts:?}");
        assert!(
            counts[0] > 0 && counts[1] > 0,
            "big dw layer should split cluster/dwe: {counts:?}"
        );
    }

    #[test]
    fn min_cost_tiny_layer_avoids_expensive_setups() {
        // stem-like layer: the analog arrays' setup cost dominates, so
        // everything stays on the (eligible) primary CU — on DIANA and on
        // the tri-CU SoC alike
        let l = conv_layer(3, 8, 4);
        assert_eq!(min_cost_counts(Platform::diana(), &l, false), vec![8, 0]);
        assert_eq!(
            min_cost_counts(Platform::trident(), &l, false),
            vec![8, 0, 0]
        );
    }

    #[test]
    fn baselines_run_through_the_search_trait() {
        use crate::search::CachingEvaluator;
        let layers: Vec<Layer> = (0..3)
            .map(|i| {
                let mut l = conv_layer(16, 32, 8);
                l.name = format!("l{i}");
                l
            })
            .collect();
        let p = Platform::trident();
        for b in Baseline::for_platform(p) {
            let mut eval = CachingEvaluator::analytical(p, &layers);
            let out = b.search(p, &layers, 0.0, &mut eval);
            assert_eq!(out.mapping.layers.len(), 3);
            assert!(out.cost > 0);
            assert_eq!(out.stats.strategy, SearchStrategy::name(&b));
            // the trait view agrees with the assignment core
            let direct = baseline_assignments(p, &layers, b, &[]);
            assert_eq!(out.mapping.layers, direct);
        }
        assert_eq!(SearchStrategy::name(&Baseline::MinCost), "baseline-mincost");
        // corners fall back to column 0 where the CU lacks the op: the
        // all-dwe corner on a conv workload is the all-cluster mapping,
        // not a nonsensically-priced impossible schedule
        let dwe_corner = baseline_assignments(p, &layers, Baseline::AllOn(1), &[]);
        assert!(dwe_corner
            .iter()
            .all(|a| a.cu_of.iter().all(|&c| c == 0)));
    }
}
