//! The ODiMO three-phase search (paper Sec. IV-A) and the λ sweep that
//! traces a Pareto front.
//!
//! Phase schedule, with the single train artifact serving all phases:
//!
//! * **Warmup** — `λ = 0`, `lr_θ = 0`: only W trains, θ stays at its
//!   uniform init, so the task-performance ranking of alternatives is
//!   meaningful before cost pressure is applied.
//! * **Search** — `λ > 0`, `lr_θ > 0`: W and θ optimize Eq. 1 jointly.
//! * **Final-Training** — θ frozen to the *discretized* one-hot mapping,
//!   `λ = 0`, `lr_θ = 0`: W recovers the accuracy lost to discretization.
//!
//! The warmup is λ-independent, so the sweep trains it once, snapshots the
//! state, and restores it per λ — the paper trains each point from
//! scratch; this is an exact-equivalent optimization (same seed, same
//! stream of batches).

use anyhow::Result;

use crate::config::CostTarget;
use crate::datasets::Split;
use crate::runtime::{StepHparams, TrainState};

use super::results::RunRecord;
use super::trainer::Trainer;

/// Per-phase hyper-parameters derived from the config.
impl Trainer {
    fn hp_warmup(&self) -> StepHparams {
        StepHparams {
            lam: 0.0,
            cost_sel: match self.cfg.cost_target {
                CostTarget::Latency => 0.0,
                CostTarget::Energy => 1.0,
            },
            lr_w: self.cfg.lr_w,
            lr_th: 0.0,
        }
    }

    fn hp_search(&self, lambda_rel: f64) -> StepHparams {
        let scale = match self.cfg.cost_target {
            CostTarget::Latency => self.manifest().cost_scale.latency_cycles,
            CostTarget::Energy => self.manifest().cost_scale.energy_uj,
        };
        StepHparams {
            lam: (lambda_rel / scale) as f32,
            lr_th: self.cfg.lr_th,
            ..self.hp_warmup()
        }
    }

    fn hp_final(&self) -> StepHparams {
        self.hp_warmup()
    }
}

/// Train a phase for `epochs`, with optional early stopping on validation
/// accuracy (patience in epochs; 0 disables). Returns the mean step wall
/// time (ms) across the phase.
pub fn run_phase(
    tr: &Trainer,
    state: &mut TrainState,
    hp: StepHparams,
    epochs: usize,
    patience: usize,
    tag: &str,
) -> Result<f64> {
    let mut best_acc = f64::NEG_INFINITY;
    let mut bad = 0usize;
    let mut step_ms = Vec::new();
    for e in 0..epochs {
        let m = tr.run_epoch(state, hp, e)?;
        step_ms.push(m.step_ms);
        if patience > 0 {
            let (acc, _) = tr.evaluate(state, Split::Val)?;
            if acc > best_acc {
                best_acc = acc;
                bad = 0;
            } else {
                bad += 1;
                if bad >= patience {
                    eprintln!("    [{tag}] early stop at epoch {e} (val {acc:.3})");
                    break;
                }
            }
        }
        if e == 0 || (e + 1) % 4 == 0 {
            eprintln!(
                "    [{tag}] epoch {:>2}: loss {:.3} acc {:.3} cost {:.3e}",
                e + 1,
                m.loss,
                m.acc,
                m.cost_lat
            );
        }
    }
    Ok(crate::stats::mean(&step_ms))
}

/// One full ODiMO run at a fixed λ, starting from a warmed-up state.
pub fn search_and_finalize(
    tr: &Trainer,
    state: &mut TrainState,
    lambda_rel: f64,
) -> Result<RunRecord> {
    let step_ms = run_phase(
        tr,
        state,
        tr.hp_search(lambda_rel),
        tr.cfg.search_epochs,
        0,
        &format!("search λ={lambda_rel}"),
    )?;
    let mapping = tr.discretize_all(state)?;
    tr.freeze_mapping(state, &mapping)?;
    run_phase(
        tr,
        state,
        tr.hp_final(),
        tr.cfg.final_epochs,
        tr.cfg.patience,
        "final",
    )?;
    let (val_acc, _) = tr.evaluate(state, Split::Val)?;
    let (test_acc, _) = tr.evaluate(state, Split::Test)?;
    let (ana, det) = tr.simulate(&mapping);
    // the differentiable search evaluates its cost model inside the
    // training graph, so there are no out-of-graph evaluator calls; the
    // search epochs play the role of descent rounds
    Ok(RunRecord::from_reports(
        "odimo",
        &tr.cfg.variant,
        Some(lambda_rel),
        match tr.cfg.cost_target {
            CostTarget::Latency => "latency",
            CostTarget::Energy => "energy",
        },
        val_acc,
        test_acc,
        &ana,
        &det,
        mapping,
        step_ms,
        tr.state_bytes(),
    )
    .with_search("gradient", tr.cfg.search_epochs, 0))
}

/// Full λ sweep with shared warmup: the Pareto-front generator.
pub fn sweep(tr: &Trainer) -> Result<Vec<RunRecord>> {
    let mut state = tr.init_state()?;
    eprintln!(
        "  [warmup] {} epochs x {} steps",
        tr.cfg.warmup_epochs, tr.cfg.steps_per_epoch
    );
    run_phase(
        tr,
        &mut state,
        tr.hp_warmup(),
        tr.cfg.warmup_epochs,
        tr.cfg.patience,
        "warmup",
    )?;
    let snap = state.snapshot();
    let mut records = Vec::new();
    for &lam in &tr.cfg.lambdas {
        eprintln!("  [sweep] λ = {lam}");
        state.restore(&snap)?;
        records.push(search_and_finalize(tr, &mut state, lam)?);
    }
    Ok(records)
}
