//! Run records: the serializable outcome of one trained + deployed
//! mapping (an ODiMO point, a baseline, or a comparison method). All
//! per-CU quantities are vectors in platform column order, so records work
//! for any registered platform.

use crate::soc::{ExecReport, Mapping};
use crate::util::json::Value;

/// Per-layer deployment breakdown row (Figs. 8/9), one entry per CU.
#[derive(Debug, Clone)]
pub struct LayerBreakdown {
    pub layer: String,
    /// channels per CU column
    pub channels: Vec<usize>,
    /// cycles per CU column
    pub cycles: Vec<u64>,
}

/// One point in every figure: a trained network with a deployed mapping.
#[derive(Debug, Clone)]
pub struct RunRecord {
    /// display label ("odimo", "all-8bit", "min-cost", "pruning", ...)
    pub label: String,
    pub variant: String,
    /// λ (relative units) for search-based points, None for baselines
    pub lambda: Option<f64>,
    pub cost_target: String,
    pub val_acc: f64,
    pub test_acc: f64,
    /// analytical model (what ODiMO believed)
    pub ana_cycles: u64,
    pub ana_energy_uj: f64,
    /// detailed simulator (the "measured" deployment numbers)
    pub det_cycles: u64,
    pub det_energy_uj: f64,
    pub det_latency_ms: f64,
    /// detailed-sim busy fraction per CU column
    pub util: Vec<f64>,
    /// fraction of channels off the primary CU (generalized "A. Ch.")
    pub offload_frac: f64,
    pub per_layer: Vec<LayerBreakdown>,
    pub mapping: Mapping,
    /// mean train-step wall time over the run, ms (Table II input)
    pub mean_step_ms: f64,
    /// total parameter+optimizer state bytes (Table II input)
    pub state_bytes: usize,
    /// how this mapping was found: "gradient" for the trained ODiMO
    /// search, a `search::SearchStrategy` name for training-free
    /// optimizers, "baseline-*" for manual corners
    pub strategy: String,
    /// coordinate-descent rounds (0 for one-shot / gradient searches)
    pub search_rounds: usize,
    /// simulator-backed evaluator calls the search consumed (0 when the
    /// cost model ran inside the training graph)
    pub evaluator_calls: u64,
}

impl RunRecord {
    #[allow(clippy::too_many_arguments)]
    pub fn from_reports(
        label: &str,
        variant: &str,
        lambda: Option<f64>,
        cost_target: &str,
        val_acc: f64,
        test_acc: f64,
        ana: &ExecReport,
        det: &ExecReport,
        mapping: Mapping,
        mean_step_ms: f64,
        state_bytes: usize,
    ) -> Self {
        let per_layer = det
            .layers
            .iter()
            .map(|l| LayerBreakdown {
                layer: l.layer.clone(),
                channels: l.per_cu.iter().map(|c| c.channels).collect(),
                cycles: l.per_cu.iter().map(|c| c.cycles).collect(),
            })
            .collect();
        Self {
            label: label.to_string(),
            variant: variant.to_string(),
            lambda,
            cost_target: cost_target.to_string(),
            val_acc,
            test_acc,
            ana_cycles: ana.total_cycles,
            ana_energy_uj: ana.energy_uj,
            det_cycles: det.total_cycles,
            det_energy_uj: det.energy_uj,
            det_latency_ms: det.latency_ms,
            util: det.utilization.clone(),
            offload_frac: det.offload_channel_fraction(),
            per_layer,
            mapping,
            mean_step_ms,
            state_bytes,
            strategy: String::new(),
            search_rounds: 0,
            evaluator_calls: 0,
        }
    }

    /// Attach search metadata (builder-style, after `from_reports`).
    pub fn with_search(mut self, strategy: &str, rounds: usize, evaluator_calls: u64) -> Self {
        self.strategy = strategy.to_string();
        self.search_rounds = rounds;
        self.evaluator_calls = evaluator_calls;
        self
    }

    /// The cost value on the axis an experiment plots (analytical, like
    /// the paper's estimated-cycles figures).
    pub fn cost(&self, target: &str) -> f64 {
        match target {
            "energy" => self.ana_energy_uj,
            _ => self.ana_cycles as f64,
        }
    }

    /// Utilization rendered as "63%/41%/8%" in CU column order.
    pub fn util_display(&self) -> String {
        self.util
            .iter()
            .map(|u| format!("{:.0}%", 100.0 * u))
            .collect::<Vec<_>>()
            .join("/")
    }

    /// JSON view (in-tree JSON module; no serde in the offline cache).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("label", Value::str(&self.label)),
            ("variant", Value::str(&self.variant)),
            ("platform", Value::str(self.mapping.platform.name())),
            (
                "lambda",
                self.lambda.map(Value::num).unwrap_or(Value::Null),
            ),
            ("cost_target", Value::str(&self.cost_target)),
            ("val_acc", Value::num(self.val_acc)),
            ("test_acc", Value::num(self.test_acc)),
            ("ana_cycles", Value::num(self.ana_cycles as f64)),
            ("ana_energy_uj", Value::num(self.ana_energy_uj)),
            ("det_cycles", Value::num(self.det_cycles as f64)),
            ("det_energy_uj", Value::num(self.det_energy_uj)),
            ("det_latency_ms", Value::num(self.det_latency_ms)),
            (
                "util",
                Value::arr(self.util.iter().map(|&u| Value::num(u))),
            ),
            ("offload_frac", Value::num(self.offload_frac)),
            ("mean_step_ms", Value::num(self.mean_step_ms)),
            ("state_bytes", Value::num(self.state_bytes as f64)),
            ("strategy", Value::str(&self.strategy)),
            ("search_rounds", Value::num(self.search_rounds as f64)),
            ("evaluator_calls", Value::num(self.evaluator_calls as f64)),
            (
                "per_layer",
                Value::arr(self.per_layer.iter().map(|l| {
                    Value::obj(vec![
                        ("layer", Value::str(&l.layer)),
                        (
                            "channels",
                            Value::arr(l.channels.iter().map(|&n| Value::num(n as f64))),
                        ),
                        (
                            "cycles",
                            Value::arr(l.cycles.iter().map(|&c| Value::num(c as f64))),
                        ),
                    ])
                })),
            ),
            (
                "mapping",
                Value::arr(self.mapping.layers.iter().map(|a| {
                    Value::obj(vec![
                        ("layer", Value::str(&a.layer)),
                        (
                            "cu_of",
                            Value::arr(a.cu_of.iter().map(|&c| Value::num(c as f64))),
                        ),
                    ])
                })),
            ),
        ])
    }

    pub fn save_json(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{analytical, detailed, Layer, LayerAssignment, LayerType, Platform};

    #[test]
    fn record_carries_per_cu_vectors() {
        let layer = Layer {
            name: "t".into(),
            ltype: LayerType::Conv,
            cin: 16,
            cout: 24,
            k: 3,
            ox: 8,
            oy: 8,
            stride: 1,
            searchable: true,
        };
        let mapping = Mapping {
            platform: Platform::trident(),
            layers: vec![LayerAssignment {
                layer: "t".into(),
                cu_of: (0..24).map(|c| (c % 3) as u8).collect(),
            }],
        };
        let ana = analytical::execute(std::slice::from_ref(&layer), &mapping, &[]);
        let det = detailed::execute(std::slice::from_ref(&layer), &mapping, &[]);
        let rec = RunRecord::from_reports(
            "test", "v", Some(0.1), "latency", 0.5, 0.5, &ana, &det, mapping, 1.0, 64,
        )
        .with_search("descent", 3, 120);
        assert_eq!(rec.util.len(), 3);
        assert_eq!(rec.strategy, "descent");
        assert_eq!(rec.search_rounds, 3);
        assert_eq!(rec.evaluator_calls, 120);
        assert_eq!(rec.per_layer[0].channels, vec![8, 8, 8]);
        assert_eq!(rec.per_layer[0].cycles.len(), 3);
        assert!((rec.offload_frac - 2.0 / 3.0).abs() < 1e-9);
        assert_eq!(rec.util_display().matches('%').count(), 3);
        // JSON view reparses and keeps the vectors
        let v = crate::util::json::parse(&rec.to_json().to_string_pretty()).unwrap();
        assert_eq!(v.str_of("platform").unwrap(), "trident");
        assert_eq!(v.str_of("strategy").unwrap(), "descent");
        assert_eq!(v.usize_of("search_rounds").unwrap(), 3);
        assert_eq!(v.usize_of("evaluator_calls").unwrap(), 120);
        assert_eq!(v.req("util").unwrap().as_arr().unwrap().len(), 3);
        let pl = v.req("per_layer").unwrap().as_arr().unwrap();
        assert_eq!(
            pl[0].req("channels").unwrap().as_arr().unwrap().len(),
            3
        );
    }
}
