//! Run records: the serializable outcome of one trained + deployed
//! mapping (an ODiMO point, a baseline, or a comparison method).



use crate::soc::{ExecReport, Mapping};
use crate::util::json::Value;

/// Per-layer deployment breakdown row (Figs. 8/9).
#[derive(Debug, Clone)]
pub struct LayerBreakdown {
    pub layer: String,
    pub n_cu0: usize,
    pub n_cu1: usize,
    pub cycles_cu0: u64,
    pub cycles_cu1: u64,
}

/// One point in every figure: a trained network with a deployed mapping.
#[derive(Debug, Clone)]
pub struct RunRecord {
    /// display label ("odimo", "all-8bit", "min-cost", "pruning", ...)
    pub label: String,
    pub variant: String,
    /// λ (relative units) for search-based points, None for baselines
    pub lambda: Option<f64>,
    pub cost_target: String,
    pub val_acc: f64,
    pub test_acc: f64,
    /// analytical model (what ODiMO believed)
    pub ana_cycles: u64,
    pub ana_energy_uj: f64,
    /// detailed simulator (the "measured" deployment numbers)
    pub det_cycles: u64,
    pub det_energy_uj: f64,
    pub det_latency_ms: f64,
    pub util_cu0: f64,
    pub util_cu1: f64,
    /// fraction of channels on CU column 1 (analog / DWE)
    pub cu1_channel_frac: f64,
    pub per_layer: Vec<LayerBreakdown>,
    pub mapping: Mapping,
    /// mean train-step wall time over the run, ms (Table II input)
    pub mean_step_ms: f64,
    /// total parameter+optimizer state bytes (Table II input)
    pub state_bytes: usize,
}

impl RunRecord {
    pub fn from_reports(
        label: &str,
        variant: &str,
        lambda: Option<f64>,
        cost_target: &str,
        val_acc: f64,
        test_acc: f64,
        ana: &ExecReport,
        det: &ExecReport,
        mapping: Mapping,
        mean_step_ms: f64,
        state_bytes: usize,
    ) -> Self {
        let per_layer = det
            .layers
            .iter()
            .map(|l| LayerBreakdown {
                layer: l.layer.clone(),
                n_cu0: l.per_cu[0].channels,
                n_cu1: l.per_cu[1].channels,
                cycles_cu0: l.per_cu[0].cycles,
                cycles_cu1: l.per_cu[1].cycles,
            })
            .collect();
        Self {
            label: label.to_string(),
            variant: variant.to_string(),
            lambda,
            cost_target: cost_target.to_string(),
            val_acc,
            test_acc,
            ana_cycles: ana.total_cycles,
            ana_energy_uj: ana.energy_uj,
            det_cycles: det.total_cycles,
            det_energy_uj: det.energy_uj,
            det_latency_ms: det.latency_ms,
            util_cu0: det.utilization[0],
            util_cu1: det.utilization[1],
            cu1_channel_frac: det.cu1_channel_fraction(),
            per_layer,
            mapping,
            mean_step_ms,
            state_bytes,
        }
    }

    /// The cost value on the axis an experiment plots (analytical, like
    /// the paper's estimated-cycles figures).
    pub fn cost(&self, target: &str) -> f64 {
        match target {
            "energy" => self.ana_energy_uj,
            _ => self.ana_cycles as f64,
        }
    }

    /// JSON view (in-tree JSON module; no serde in the offline cache).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("label", Value::str(&self.label)),
            ("variant", Value::str(&self.variant)),
            (
                "lambda",
                self.lambda.map(Value::num).unwrap_or(Value::Null),
            ),
            ("cost_target", Value::str(&self.cost_target)),
            ("val_acc", Value::num(self.val_acc)),
            ("test_acc", Value::num(self.test_acc)),
            ("ana_cycles", Value::num(self.ana_cycles as f64)),
            ("ana_energy_uj", Value::num(self.ana_energy_uj)),
            ("det_cycles", Value::num(self.det_cycles as f64)),
            ("det_energy_uj", Value::num(self.det_energy_uj)),
            ("det_latency_ms", Value::num(self.det_latency_ms)),
            ("util_cu0", Value::num(self.util_cu0)),
            ("util_cu1", Value::num(self.util_cu1)),
            ("cu1_channel_frac", Value::num(self.cu1_channel_frac)),
            ("mean_step_ms", Value::num(self.mean_step_ms)),
            ("state_bytes", Value::num(self.state_bytes as f64)),
            (
                "per_layer",
                Value::arr(self.per_layer.iter().map(|l| {
                    Value::obj(vec![
                        ("layer", Value::str(&l.layer)),
                        ("n_cu0", Value::num(l.n_cu0 as f64)),
                        ("n_cu1", Value::num(l.n_cu1 as f64)),
                        ("cycles_cu0", Value::num(l.cycles_cu0 as f64)),
                        ("cycles_cu1", Value::num(l.cycles_cu1 as f64)),
                    ])
                })),
            ),
            (
                "mapping",
                Value::arr(self.mapping.layers.iter().map(|a| {
                    Value::obj(vec![
                        ("layer", Value::str(&a.layer)),
                        (
                            "cu_of",
                            Value::arr(a.cu_of.iter().map(|&c| Value::num(c as f64))),
                        ),
                    ])
                })),
            ),
        ])
    }

    pub fn save_json(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}
