//! Trainer: drives one model variant's training backend through epochs,
//! evaluation, and θ manipulation.
//!
//! This is the layer the ODiMO phases are built on: it owns a
//! [`ModelBackend`] (native engine or XLA artifacts — it cannot tell the
//! difference), generates synthetic batches, runs train/eval steps, and
//! exposes θ read/write so the phase logic can freeze, discretize and
//! restore assignments.

use anyhow::{anyhow, Result};

use crate::config::ExperimentConfig;
use crate::datasets::{Split, SynthDataset};
use crate::mapping::{discretize, one_hot_theta, SearchKind};
use crate::runtime::{
    default_backend, load_backend_with, BackendKind, Manifest, ModelBackend, NativeOptions,
    StepHparams, TrainState,
};
use crate::search::{eligible_cus, fits};
use crate::soc::{self, Layer, LayerAssignment, Mapping, Platform};

/// Aggregated metrics of one epoch.
#[derive(Debug, Clone, Copy, Default)]
pub struct EpochMetrics {
    pub loss: f64,
    pub ce: f64,
    pub acc: f64,
    pub cost_lat: f64,
    pub cost_energy: f64,
    /// mean wall-clock per train step, milliseconds
    pub step_ms: f64,
}

pub struct Trainer {
    pub backend: Box<dyn ModelBackend>,
    pub ds: SynthDataset,
    pub cfg: ExperimentConfig,
    pub platform: Platform,
    pub kind: SearchKind,
    pub layers: Vec<Layer>,
    pub seq_layers: Vec<String>,
    eval_val: Vec<(Vec<f32>, Vec<i32>)>,
    eval_test: Vec<(Vec<f32>, Vec<i32>)>,
}

impl Trainer {
    /// Build a trainer over an already-constructed backend.
    pub fn new(backend: Box<dyn ModelBackend>, cfg: ExperimentConfig) -> Result<Self> {
        let m = backend.manifest();
        let ds = SynthDataset::from_name(
            &m.dataset.name,
            m.dataset.hw,
            m.dataset.classes,
            cfg.seed as u64 + 1,
        );
        let platform: Platform = m.platform.parse()?;
        let kind: SearchKind = m.search_kind.parse()?;
        let layers = soc::layers_from_manifest(m)?;
        let seq_layers = soc::sequential_layers(m);
        let batch = m.dataset.batch;
        let mk_batches = |split: Split, n: usize| -> Vec<(Vec<f32>, Vec<i32>)> {
            (0..n).map(|i| ds.batch(split, i as u64, batch)).collect()
        };
        let eval_val = mk_batches(Split::Val, cfg.eval_batches);
        let eval_test = mk_batches(Split::Test, cfg.eval_batches);
        Ok(Self {
            backend,
            ds,
            cfg,
            platform,
            kind,
            layers,
            seq_layers,
            eval_val,
            eval_test,
        })
    }

    /// Build a trainer for `cfg.variant`, selecting the backend:
    /// `kind = None` picks [`default_backend`] (native unless the
    /// variant's AOT artifacts exist). The config's `threads` (0 =
    /// available parallelism) and `w_optimizer` plumb through to the
    /// native engine here.
    pub fn create(
        artifacts: &std::path::Path,
        cfg: ExperimentConfig,
        kind: Option<BackendKind>,
    ) -> Result<Self> {
        let kind = kind.unwrap_or_else(|| default_backend(artifacts, &cfg.variant));
        let opts = NativeOptions {
            threads: cfg.resolved_threads(),
            w_optimizer: cfg.w_optimizer.parse()?,
        };
        let backend = load_backend_with(kind, artifacts, &cfg.variant, opts)?;
        Self::new(backend, cfg)
    }

    pub fn manifest(&self) -> &Manifest {
        self.backend.manifest()
    }

    pub fn init_state(&self) -> Result<TrainState> {
        self.backend.init_state(self.cfg.seed)
    }

    /// Run one epoch of `steps_per_epoch` train steps.
    pub fn run_epoch(
        &self,
        state: &mut TrainState,
        hp: StepHparams,
        epoch: usize,
    ) -> Result<EpochMetrics> {
        let batch = self.backend.batch();
        let mut agg = EpochMetrics::default();
        let t0 = std::time::Instant::now();
        for i in 0..self.cfg.steps_per_epoch {
            let idx = (epoch * self.cfg.steps_per_epoch + i) as u64;
            let (x, y) = self.ds.batch(Split::Train, idx, batch);
            let m = self.backend.train_step(state, &x, &y, hp)?;
            agg.loss += m[0] as f64;
            agg.ce += m[1] as f64;
            agg.acc += m[2] as f64;
            agg.cost_lat += m[3] as f64;
            agg.cost_energy += m[4] as f64;
        }
        let n = self.cfg.steps_per_epoch as f64;
        agg.loss /= n;
        agg.ce /= n;
        agg.acc /= n;
        agg.cost_lat /= n;
        agg.cost_energy /= n;
        agg.step_ms = t0.elapsed().as_secs_f64() * 1e3 / n;
        Ok(agg)
    }

    /// Accuracy + mean loss over the held-out batches of `split`.
    pub fn evaluate(&self, state: &TrainState, split: Split) -> Result<(f64, f64)> {
        let batches = match split {
            Split::Val => &self.eval_val,
            Split::Test => &self.eval_test,
            Split::Train => return Err(anyhow!("evaluate on val/test only")),
        };
        if batches.is_empty() {
            return Err(anyhow!(
                "evaluate: no held-out batches — cfg.eval_batches is 0; \
                 set eval_batches ≥ 1 (accuracy would be 0/0)"
            ));
        }
        let mut correct = 0.0f64;
        let mut loss = 0.0f64;
        let mut n = 0usize;
        for (x, y) in batches {
            let m = self.backend.eval_batch(state, x, y)?;
            correct += m[0] as f64;
            loss += m[1] as f64;
            n += self.backend.batch();
        }
        Ok((correct / n as f64, loss / n as f64))
    }

    // -----------------------------------------------------------------
    // θ access
    // -----------------------------------------------------------------

    fn theta_leaf(&self, layer: &str) -> String {
        format!("params/{layer}/theta")
    }

    pub fn theta_of(&self, state: &TrainState, layer: &str) -> Result<Vec<f32>> {
        state.leaf_f32(&self.theta_leaf(layer))
    }

    pub fn set_theta(&self, state: &mut TrainState, layer: &str, data: &[f32]) -> Result<()> {
        let shape = match self.kind {
            SearchKind::Channel | SearchKind::Prune => {
                let k = self.kind.columns(self.platform.n_cus());
                vec![data.len() / k, k]
            }
            SearchKind::Split | SearchKind::Layerwise => vec![data.len()],
        };
        state.set_leaf_f32(&self.theta_leaf(layer), &shape, data)
    }

    /// Discretize every searchable layer's θ; non-searchable layers are
    /// assigned to CU 0 (cluster / digital — where they always execute).
    ///
    /// Channel-kind assignments are additionally passed through a
    /// capacity-repair step so the emitted mapping always satisfies the
    /// search subsystem's feasibility check (`mem_capacity_bytes` +
    /// op-eligibility): a trained θ knows cost gradients, not hard
    /// capacity walls.
    pub fn discretize_all(&self, state: &TrainState) -> Result<Mapping> {
        let n_cus = self.platform.n_cus();
        let mut layers = Vec::new();
        for spec in &self.manifest().layers {
            if spec.searchable {
                let theta = self.theta_of(state, &spec.name)?;
                let mut asg = discretize(self.kind, &theta, spec.cout, n_cus, &spec.name);
                if self.kind == SearchKind::Channel {
                    let layer = self
                        .layers
                        .iter()
                        .find(|l| l.name == spec.name)
                        .expect("manifest layer table is consistent");
                    repair_capacity(self.platform, layer, &mut asg);
                }
                layers.push(asg);
            } else {
                layers.push(LayerAssignment::all_on(&spec.name, spec.cout, 0));
            }
        }
        Ok(Mapping {
            platform: self.platform,
            layers,
        })
    }

    /// Freeze the mapping: write one-hot θ for every searchable layer.
    pub fn freeze_mapping(&self, state: &mut TrainState, mapping: &Mapping) -> Result<()> {
        let n_cus = self.platform.n_cus();
        for (spec, asg) in self.manifest().layers.iter().zip(&mapping.layers) {
            if spec.searchable {
                let oh = one_hot_theta(self.kind, asg, n_cus);
                self.set_theta(state, &spec.name, &oh)?;
            }
        }
        Ok(())
    }

    /// Simulator views of a mapping (analytical + detailed).
    pub fn simulate(&self, mapping: &Mapping) -> (soc::ExecReport, soc::ExecReport) {
        if self.kind == SearchKind::Prune {
            // pruned channels vanish from the workload instead of running
            // on the second CU; sequentialize through the pruned geometry
            let (layers, mapping) = prune_geometry(&self.layers, mapping);
            let a = soc::analytical::execute(&layers, &mapping, &self.seq_layers);
            let d = soc::detailed::execute(&layers, &mapping, &self.seq_layers);
            (a, d)
        } else {
            let a = soc::analytical::execute(&self.layers, mapping, &self.seq_layers);
            let d = soc::detailed::execute(&self.layers, mapping, &self.seq_layers);
            (a, d)
        }
    }

    /// Total state size in bytes (for the Table II memory column).
    pub fn state_bytes(&self) -> usize {
        self.backend
            .state_specs()
            .iter()
            .map(|s| s.elem_count() * 4)
            .sum()
    }
}

/// Move channels off CUs that cannot legally hold them — either the CU's
/// descriptor lacks the layer's op, or the channel count overflows its
/// `mem_capacity_bytes` weight budget. Overflow lands on the eligible CU
/// with the most remaining capacity headroom (ties toward column 0). If
/// no CU can take a channel, it stays put — the same capacity-waiver rule
/// the training-free search strategies use.
pub fn repair_capacity(platform: Platform, layer: &Layer, asg: &mut LayerAssignment) {
    let cus = platform.cus();
    let k = cus.len();
    let eligible = eligible_cus(platform, layer);
    // per-CU channel budget (usize::MAX = unconstrained)
    let cap: Vec<usize> = cus
        .iter()
        .enumerate()
        .map(|(i, cu)| {
            if !eligible[i] {
                return 0;
            }
            match cu.mem_capacity_bytes {
                None => usize::MAX,
                Some(_) => {
                    // largest n with fits(); weight_bytes is linear in n
                    let per = crate::soc::analytical::weight_bytes(cu, layer, 1).max(1);
                    let cap = cu.mem_capacity_bytes.unwrap();
                    (cap / per) as usize
                }
            }
        })
        .collect();
    let mut counts = asg.counts(k);
    for c in 0..asg.cu_of.len() {
        let cur = asg.cu_of[c] as usize;
        let legal = cur < k && eligible[cur] && counts[cur] <= cap[cur] && {
            // double-check against the exact predicate (guards rounding)
            fits(&cus[cur], layer, counts[cur])
        };
        if legal {
            continue;
        }
        // pick the eligible CU with the most headroom that still fits
        let mut best: Option<usize> = None;
        for j in 0..k {
            if j == cur || !eligible[j] {
                continue;
            }
            if counts[j] + 1 > cap[j] || !fits(&cus[j], layer, counts[j] + 1) {
                continue;
            }
            let head = cap[j].saturating_sub(counts[j]);
            if best.map_or(true, |b| head > cap[b].saturating_sub(counts[b])) {
                best = Some(j);
            }
        }
        if let Some(j) = best {
            if cur < k {
                counts[cur] -= 1;
            }
            counts[j] += 1;
            asg.cu_of[c] = j as u8;
        }
    }
}

/// Rebuild layer geometry for a pruning run: kept channels stay on the
/// digital CU, pruned channels disappear, and each subsequent layer's
/// input-channel count shrinks by the producing layer's keep fraction
/// (sequential approximation — see DESIGN.md).
pub fn prune_geometry(layers: &[Layer], mapping: &Mapping) -> (Vec<Layer>, Mapping) {
    let mut new_layers = Vec::with_capacity(layers.len());
    let mut new_asg = Vec::with_capacity(layers.len());
    let mut prev_keep_frac = 1.0f64;
    for (l, asg) in layers.iter().zip(&mapping.layers) {
        let kept = asg.count(0);
        let keep_frac = if asg.cu_of.is_empty() {
            1.0
        } else {
            kept as f64 / asg.cu_of.len() as f64
        };
        let mut nl = l.clone();
        nl.cout = kept.max(1);
        nl.cin = ((l.cin as f64 * prev_keep_frac).round() as usize).max(1);
        new_layers.push(nl);
        new_asg.push(LayerAssignment::all_on(&l.name, kept.max(1), 0));
        prev_keep_frac = keep_frac;
    }
    (
        new_layers,
        Mapping {
            platform: mapping.platform,
            layers: new_asg,
        },
    )
}
