//! Simulator-backed cost evaluation with an incremental per-layer recost
//! path and a memoized layer-cost cache.
//!
//! Both SoC simulators decompose exactly into per-layer latencies (the
//! fabric controller re-syncs at every layer boundary — see
//! `soc::detailed::sim_layer`), so a whole-network cost is the sum of
//! per-layer costs and a candidate move that touches one layer only needs
//! that one layer re-priced. [`CachingEvaluator`] memoizes each
//! `(layer, per-CU counts)` result, so coordinate descent revisiting a
//! state (or the λ-neighbouring restart descending through the same
//! region) never re-simulates it.

use std::collections::HashMap;

use crate::soc::{analytical, detailed, Layer, Mapping, Platform};

/// Evaluation counters, cumulative over an evaluator's lifetime.
#[derive(Debug, Clone, Copy, Default)]
pub struct EvalStats {
    /// `layer_cost` invocations (what a cache-less evaluator would simulate)
    pub calls: u64,
    /// calls answered from the memo cache
    pub cache_hits: u64,
}

impl EvalStats {
    /// Calls that actually ran a simulator (cache misses).
    pub fn sim_evals(&self) -> u64 {
        self.calls - self.cache_hits
    }
}

/// One cost backend behind the [`CostEvaluator`] trait.
///
/// `Analytical` prices with the model ODiMO searches with;
/// `Detailed` prices with the event-driven simulator (DMA serialization,
/// bank contention, warm-up) — the "measured" cost the paper deploys on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CostModel {
    Analytical,
    Detailed,
}

/// Uniform cost interface every [`super::SearchStrategy`] optimizes
/// against. Implementations must price a single layer in isolation; the
/// provided `network_cost` is the exact whole-network sum (both in-tree
/// simulators are layer-separable — pinned by `tests/search.rs`).
pub trait CostEvaluator {
    fn platform(&self) -> Platform;

    /// Latency cycles of layer `li` under per-CU channel `counts`.
    fn layer_cost(&mut self, li: usize, counts: &[usize]) -> u64;

    /// True if layer `li`'s CU stages execute sequentially (the DW→PW
    /// chains whose latency is the sum, not the max, of the stages).
    /// Strategies that reason about per-layer latency outside
    /// `layer_cost` must use this so their model matches the evaluator's.
    fn layer_sequential(&self, _li: usize) -> bool {
        false
    }

    /// Whole-network cost of `mapping` (sum of per-layer costs).
    fn network_cost(&mut self, mapping: &Mapping) -> u64 {
        let k = self.platform().n_cus();
        let mut total = 0u64;
        for (li, asg) in mapping.layers.iter().enumerate() {
            total += self.layer_cost(li, &asg.counts(k));
        }
        total
    }

    fn stats(&self) -> EvalStats;
}

/// The standard evaluator: one of the two simulators plus the memo cache.
pub struct CachingEvaluator<'a> {
    platform: Platform,
    layers: &'a [Layer],
    /// per-layer sequential-stage flag (DW→PW chains cost the sum, not
    /// the max, of the active CUs)
    sequential: Vec<bool>,
    model: CostModel,
    cache: HashMap<(usize, Vec<usize>), u64>,
    calls: u64,
    hits: u64,
}

impl<'a> CachingEvaluator<'a> {
    pub fn new(
        model: CostModel,
        platform: Platform,
        layers: &'a [Layer],
        seq_layers: &[String],
    ) -> Self {
        let sequential = layers
            .iter()
            .map(|l| seq_layers.iter().any(|s| s == &l.name))
            .collect();
        Self {
            platform,
            layers,
            sequential,
            model,
            cache: HashMap::new(),
            calls: 0,
            hits: 0,
        }
    }

    /// Analytical-model evaluator with no sequential layers.
    pub fn analytical(platform: Platform, layers: &'a [Layer]) -> Self {
        Self::new(CostModel::Analytical, platform, layers, &[])
    }

    /// Detailed-simulator evaluator with no sequential layers.
    pub fn detailed(platform: Platform, layers: &'a [Layer]) -> Self {
        Self::new(CostModel::Detailed, platform, layers, &[])
    }

    pub fn model(&self) -> CostModel {
        self.model
    }

    pub fn layers(&self) -> &'a [Layer] {
        self.layers
    }
}

impl CostEvaluator for CachingEvaluator<'_> {
    fn platform(&self) -> Platform {
        self.platform
    }

    fn layer_cost(&mut self, li: usize, counts: &[usize]) -> u64 {
        self.calls += 1;
        let key = (li, counts.to_vec());
        if let Some(&cached) = self.cache.get(&key) {
            self.hits += 1;
            return cached;
        }
        let layer = &self.layers[li];
        let seq = self.sequential[li];
        let cost = match self.model {
            CostModel::Analytical => analytical::layer_latency(self.platform, layer, counts, seq),
            CostModel::Detailed => detailed::layer_latency(self.platform, layer, counts, seq),
        };
        self.cache.insert(key, cost);
        cost
    }

    fn layer_sequential(&self, li: usize) -> bool {
        self.sequential[li]
    }

    fn stats(&self) -> EvalStats {
        EvalStats {
            calls: self.calls,
            cache_hits: self.hits,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{LayerAssignment, LayerType};

    fn conv(name: &str, cin: usize, cout: usize, hw: usize) -> Layer {
        Layer {
            name: name.into(),
            ltype: LayerType::Conv,
            cin,
            cout,
            k: 3,
            ox: hw,
            oy: hw,
            stride: 1,
            searchable: true,
        }
    }

    #[test]
    fn cache_hits_and_consistency() {
        let layers = vec![conv("a", 16, 32, 8), conv("b", 32, 32, 8)];
        let p = Platform::trident();
        let mut ev = CachingEvaluator::detailed(p, &layers);
        let c1 = ev.layer_cost(0, &[16, 0, 16]);
        let c2 = ev.layer_cost(0, &[16, 0, 16]);
        assert_eq!(c1, c2);
        let s = ev.stats();
        assert_eq!(s.calls, 2);
        assert_eq!(s.cache_hits, 1);
        assert_eq!(s.sim_evals(), 1);
        // different counts are a different cache line: no new hit
        ev.layer_cost(0, &[32, 0, 0]);
        assert_eq!(ev.stats().cache_hits, 1);
        assert_eq!(ev.stats().sim_evals(), 2);
    }

    #[test]
    fn network_cost_matches_both_simulators() {
        let layers = vec![conv("a", 16, 32, 8), conv("b", 32, 48, 8)];
        for p in [Platform::diana(), Platform::trident()] {
            let k = p.n_cus();
            let mapping = Mapping {
                platform: p,
                layers: layers
                    .iter()
                    .map(|l| LayerAssignment {
                        layer: l.name.clone(),
                        cu_of: (0..l.cout).map(|c| (c % k) as u8).collect(),
                    })
                    .collect(),
            };
            let mut ana = CachingEvaluator::analytical(p, &layers);
            let mut det = CachingEvaluator::detailed(p, &layers);
            assert_eq!(
                ana.network_cost(&mapping),
                analytical::execute(&layers, &mapping, &[]).total_cycles
            );
            assert_eq!(
                det.network_cost(&mapping),
                detailed::execute(&layers, &mapping, &[]).total_cycles
            );
        }
    }

    #[test]
    fn sequential_flag_prices_the_sum() {
        let layers = vec![conv("a", 16, 32, 8)];
        let p = Platform::darkside();
        let mut par = CachingEvaluator::new(CostModel::Analytical, p, &layers, &[]);
        let mut seq =
            CachingEvaluator::new(CostModel::Analytical, p, &layers, &["a".to_string()]);
        let counts = [16usize, 16];
        assert!(seq.layer_cost(0, &counts) > par.layer_cost(0, &counts));
    }
}
