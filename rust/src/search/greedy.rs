//! The per-layer greedy channel placer — the training-free heuristic that
//! used to live inline in `experiments.rs` (`socmap_assign`), now behind
//! [`SearchStrategy`] and capacity-aware.
//!
//! Each channel goes to the CU (among those whose descriptor supports the
//! layer's op *and* can still hold the channel's weights) minimizing
//! `λ · layer-latency-after-placement + quality penalty` (ties to the
//! lowest column). λ = 0 keeps everything on the least aggressive CU;
//! large λ approaches the min-latency partition — tracing the same
//! accuracy-vs-cost tension the trained search navigates. The scoring is
//! purely per-layer (analytical, no cross-layer view), which is exactly
//! the gap [`super::CoordinateDescent`] closes.

use crate::soc::{analytical, Layer, LayerAssignment, Mapping, Platform};

use super::{
    eligible_cus, finish_outcome, fits, quant_penalty, CostEvaluator, SearchOutcome,
    SearchStrategy,
};

/// λ-aware greedy channel assignment for one layer.
pub fn greedy_assign(platform: Platform, layer: &Layer, lambda: f64) -> LayerAssignment {
    let cus = platform.cus();
    let eligible = eligible_cus(platform, layer);
    let mut counts = vec![0usize; cus.len()];
    let mut cu_of: Vec<u8> = Vec::with_capacity(layer.cout);
    let macs1 = layer.macs_std(1) as f64;
    for _ in 0..layer.cout {
        // capacity-infeasible CUs drop out of the candidate set; when no
        // eligible CU could take one more channel the layer still needs a
        // home, so capacity is waived (op eligibility never is)
        let any_fit = cus
            .iter()
            .enumerate()
            .any(|(k, cu)| eligible[k] && fits(cu, layer, counts[k] + 1));
        let mut best = usize::MAX;
        let mut best_score = f64::INFINITY;
        for (k, cu) in cus.iter().enumerate() {
            if !eligible[k] || (any_fit && !fits(cu, layer, counts[k] + 1)) {
                continue;
            }
            counts[k] += 1;
            let lat = cus
                .iter()
                .zip(&counts)
                .map(|(c, &n)| analytical::cu_cycles(c, layer, n))
                .max()
                .unwrap_or(0) as f64;
            counts[k] -= 1;
            let score = lambda * lat + quant_penalty(&cu.quant) * macs1;
            if score < best_score {
                best_score = score;
                best = k;
            }
        }
        counts[best] += 1;
        cu_of.push(best as u8);
    }
    LayerAssignment {
        layer: layer.name.clone(),
        cu_of,
    }
}

/// Greedy assignment over a whole workload.
pub fn greedy_mapping(platform: Platform, layers: &[Layer], lambda: f64) -> Mapping {
    Mapping {
        platform,
        layers: layers
            .iter()
            .map(|l| greedy_assign(platform, l, lambda))
            .collect(),
    }
}

/// The greedy heuristic as a [`SearchStrategy`].
pub struct Greedy;

impl SearchStrategy for Greedy {
    fn name(&self) -> &str {
        "greedy"
    }

    fn search(
        &self,
        platform: Platform,
        layers: &[Layer],
        lambda: f64,
        eval: &mut dyn CostEvaluator,
    ) -> SearchOutcome {
        let mapping = greedy_mapping(platform, layers, lambda);
        finish_outcome(self.name(), 0, 0, mapping, layers, eval)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::feasible_counts;
    use crate::soc::LayerType;

    fn conv(name: &str, cin: usize, cout: usize, hw: usize) -> Layer {
        Layer {
            name: name.into(),
            ltype: LayerType::Conv,
            cin,
            cout,
            k: 3,
            ox: hw,
            oy: hw,
            stride: 1,
            searchable: true,
        }
    }

    #[test]
    fn lambda_zero_stays_on_least_aggressive_cu() {
        // with no cost pressure everything stays on the least aggressive
        // CUs; on trident the cluster and dwe are both int8, ties go to
        // column 0
        let p = Platform::trident();
        for l in [conv("a", 16, 32, 16), conv("b", 32, 64, 8)] {
            let a = greedy_assign(p, &l, 0.0);
            assert!(a.cu_of.iter().all(|&c| c == 0), "{}: {:?}", l.name, a.cu_of);
        }
    }

    #[test]
    fn large_lambda_offloads_and_cuts_latency() {
        let p = Platform::trident();
        let layers: Vec<Layer> = (0..4).map(|i| conv(&format!("l{i}"), 32, 64, 16)).collect();
        let m0 = greedy_mapping(p, &layers, 0.0);
        let m_hi = greedy_mapping(p, &layers, 65536.0);
        let a0 = analytical::execute(&layers, &m0, &[]);
        let ahi = analytical::execute(&layers, &m_hi, &[]);
        assert!(ahi.total_cycles < a0.total_cycles);
        assert!(ahi.offload_channel_fraction() > 0.0);
    }

    #[test]
    fn greedy_respects_capacity_when_a_feasible_split_exists() {
        let p = Platform::trident();
        // 256·256·9 ≈ 576 KB of conv weights: more than the cluster's
        // capacity alone but within cluster + aimc combined, so capacity
        // must *bind* (force a split) while staying satisfiable
        let big = conv("big", 256, 256, 4);
        for lambda in [0.0, 16.0, 65536.0] {
            let a = greedy_assign(p, &big, lambda);
            let counts = a.counts(p.n_cus());
            assert_eq!(counts.iter().sum::<usize>(), 256);
            assert!(
                feasible_counts(p, &big, &counts),
                "λ={lambda}: {counts:?} violates a capacity"
            );
            assert!(
                counts[0] < 256,
                "λ={lambda}: the cluster cannot hold every filter"
            );
        }
    }

    #[test]
    fn greedy_waives_capacity_only_when_nothing_fits() {
        let p = Platform::trident();
        // 512·512·9 ≈ 2.4 MB exceeds every eligible capacity combined;
        // each channel still gets a home (capacity waived, eligibility not)
        let huge = conv("huge", 512, 512, 4);
        let a = greedy_assign(p, &huge, 0.0);
        let counts = a.counts(p.n_cus());
        assert_eq!(counts.iter().sum::<usize>(), 512);
        assert_eq!(counts[1], 0, "dwe stays ineligible for conv");
    }
}
