//! Coordinate descent over layer channel splits against the full-network
//! evaluator cost.
//!
//! The optimizer sweeps the layers repeatedly; for each layer it
//! re-splits the channel counts by best-improving block moves (geometric
//! step sizes, so a whole-layer migration costs O(log C) probes rather
//! than C single-channel hops) until the layer admits no improving move,
//! and stops when a full sweep changes nothing — a fixed point of the
//! scalarized objective `J = λ·cost + penalty`.
//!
//! Two properties matter:
//!
//! * **Never worse than greedy.** Descent starts from [`super::Greedy`]'s
//!   solution and accepts a move only if it improves `(J, cost)`
//!   lexicographically, so the final point satisfies `J ≤ J_greedy` and,
//!   at equal `J`, `cost ≤ cost_greedy`. A short case analysis (see
//!   `tests/search.rs`) shows the greedy point can therefore never
//!   dominate the descent point in the (cost, penalty) plane.
//! * **Bounded work.** The move loop re-prices *only the touched layer*
//!   through the evaluator's incremental path, and `max_rounds` /
//!   `max_moves_per_layer` cap the worst case — defaults far above what
//!   the fixed point needs in practice (pinned by the termination test).

use crate::mapping::assignment_from_counts;
use crate::soc::{Layer, Mapping, Platform};

use super::{
    eligible_cus, finish_outcome, fits, greedy_mapping, quant_penalty, CostEvaluator,
    SearchOutcome, SearchStrategy,
};

pub struct CoordinateDescent {
    /// cap on full layer sweeps (the fixed point typically needs 2)
    pub max_rounds: usize,
    /// cap on accepted moves per layer per sweep (safety net; geometric
    /// steps converge in far fewer)
    pub max_moves_per_layer: usize,
}

impl Default for CoordinateDescent {
    fn default() -> Self {
        Self {
            max_rounds: 8,
            max_moves_per_layer: 256,
        }
    }
}

impl CoordinateDescent {
    /// Descend from an explicit starting mapping. Returns the improved
    /// mapping, the number of sweeps executed (the last one is the
    /// no-move confirmation unless `max_rounds` was hit), and the number
    /// of accepted moves.
    pub fn descend(
        &self,
        layers: &[Layer],
        lambda: f64,
        eval: &mut dyn CostEvaluator,
        init: &Mapping,
    ) -> (Mapping, usize, usize) {
        let platform = init.platform;
        let cus = platform.cus();
        let k = cus.len();
        let mut counts: Vec<Vec<usize>> = init.layers.iter().map(|a| a.counts(k)).collect();
        let mut rounds = 0usize;
        let mut moves_total = 0usize;
        while rounds < self.max_rounds {
            rounds += 1;
            let mut moved = false;
            for (li, layer) in layers.iter().enumerate() {
                let eligible = eligible_cus(platform, layer);
                let macs1 = layer.macs_std(1) as f64;
                for _ in 0..self.max_moves_per_layer {
                    let cur_cost = eval.layer_cost(li, &counts[li]);
                    // best improving block move (from, to, delta)
                    let mut best: Option<(f64, u64, usize, usize, usize)> = None;
                    for from in 0..k {
                        if counts[li][from] == 0 {
                            continue;
                        }
                        for to in 0..k {
                            if to == from || !eligible[to] {
                                continue;
                            }
                            let dq = quant_penalty(&cus[to].quant)
                                - quant_penalty(&cus[from].quant);
                            let mut delta = counts[li][from];
                            while delta >= 1 {
                                if fits(&cus[to], layer, counts[li][to] + delta) {
                                    let mut cand = counts[li].clone();
                                    cand[from] -= delta;
                                    cand[to] += delta;
                                    let new_cost = eval.layer_cost(li, &cand);
                                    let dj = lambda * (new_cost as f64 - cur_cost as f64)
                                        + dq * macs1 * delta as f64;
                                    // lexicographic acceptance on (J, cost):
                                    // the invariant behind never-dominated
                                    let improves =
                                        dj < 0.0 || (dj == 0.0 && new_cost < cur_cost);
                                    let beats_best = match best {
                                        None => true,
                                        Some((bj, bc, ..)) => {
                                            dj < bj || (dj == bj && new_cost < bc)
                                        }
                                    };
                                    if improves && beats_best {
                                        best = Some((dj, new_cost, from, to, delta));
                                    }
                                }
                                delta /= 2;
                            }
                        }
                    }
                    match best {
                        Some((_, _, from, to, delta)) => {
                            counts[li][from] -= delta;
                            counts[li][to] += delta;
                            moves_total += 1;
                            moved = true;
                        }
                        None => break,
                    }
                }
            }
            if !moved {
                break;
            }
        }
        let mapping = Mapping {
            platform,
            layers: layers
                .iter()
                .zip(&counts)
                .map(|(l, c)| assignment_from_counts(&l.name, c))
                .collect(),
        };
        (mapping, rounds, moves_total)
    }
}

impl SearchStrategy for CoordinateDescent {
    fn name(&self) -> &str {
        "descent"
    }

    fn search(
        &self,
        platform: Platform,
        layers: &[Layer],
        lambda: f64,
        eval: &mut dyn CostEvaluator,
    ) -> SearchOutcome {
        let init = greedy_mapping(platform, layers, lambda);
        let (mapping, rounds, _) = self.descend(layers, lambda, eval, &init);
        finish_outcome(self.name(), rounds, 0, mapping, layers, eval)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::{mapping_penalty, CachingEvaluator, Greedy};
    use crate::soc::LayerType;

    fn conv(name: &str, cin: usize, cout: usize, hw: usize) -> Layer {
        Layer {
            name: name.into(),
            ltype: LayerType::Conv,
            cin,
            cout,
            k: 3,
            ox: hw,
            oy: hw,
            stride: 1,
            searchable: true,
        }
    }

    fn workload() -> Vec<Layer> {
        (0..5)
            .map(|i| conv(&format!("l{i}"), 16 << (i / 2), 32 << (i / 2), 16))
            .collect()
    }

    #[test]
    fn descent_objective_never_worse_than_greedy() {
        let p = Platform::trident();
        let layers = workload();
        for lambda in [0.0, 1.0, 16.0, 4096.0] {
            let mut eval = CachingEvaluator::detailed(p, &layers);
            let g = Greedy.search(p, &layers, lambda, &mut eval);
            let mut eval = CachingEvaluator::detailed(p, &layers);
            let d = CoordinateDescent::default().search(p, &layers, lambda, &mut eval);
            let jg = lambda * g.cost as f64 + g.penalty;
            let jd = lambda * d.cost as f64 + d.penalty;
            assert!(jd <= jg, "λ={lambda}: descent J {jd} > greedy J {jg}");
        }
    }

    #[test]
    fn descent_reaches_a_fixed_point() {
        let p = Platform::trident();
        let layers = workload();
        let cd = CoordinateDescent::default();
        let mut eval = CachingEvaluator::detailed(p, &layers);
        let out = cd.search(p, &layers, 16.0, &mut eval);
        assert!(out.stats.rounds <= cd.max_rounds);
        // descending again from the result changes nothing and confirms
        // in a single sweep
        let (again, rounds, moves) = cd.descend(&layers, 16.0, &mut eval, &out.mapping);
        assert_eq!(rounds, 1);
        assert_eq!(moves, 0);
        assert_eq!(again.layers, out.mapping.layers);
    }

    #[test]
    fn descent_uses_the_incremental_path() {
        // pricing a whole search through the evaluator must cost far
        // fewer simulator runs than evaluator calls — the cache and the
        // per-layer recost are what make descent affordable
        let p = Platform::trident();
        let layers = workload();
        let mut eval = CachingEvaluator::detailed(p, &layers);
        let out = CoordinateDescent::default().search(p, &layers, 16.0, &mut eval);
        let s = eval.stats();
        assert_eq!(out.stats.evaluator_calls, s.calls);
        assert!(s.calls > 0);
        assert!(
            s.sim_evals() < s.calls,
            "no cache hits at all: {} calls, {} sims",
            s.calls,
            s.sim_evals()
        );
    }

    #[test]
    fn penalty_tracks_shared_formula() {
        let p = Platform::trident();
        let layers = workload();
        let mut eval = CachingEvaluator::detailed(p, &layers);
        let d = CoordinateDescent::default().search(p, &layers, 256.0, &mut eval);
        assert_eq!(d.penalty, mapping_penalty(&layers, &d.mapping));
    }
}
