//! The unified mapping-search subsystem: training-free optimizers over
//! fine-grain layer→CU channel assignments.
//!
//! The paper's core contribution is the *search* over mappings; this
//! module makes that search first-class instead of experiment-file glue.
//! Three layers compose:
//!
//! * **[`CostEvaluator`]** (`evaluator`) — one trait in front of both SoC
//!   simulators (`soc::analytical`, `soc::detailed`). Both are
//!   layer-separable (the fabric re-syncs at every layer boundary), so
//!   the evaluator exposes an *incremental* per-layer recost path:
//!   a candidate move that re-splits one layer re-prices that layer only,
//!   and a memoized `(layer, counts)` cache means revisited states are
//!   never re-simulated. Whole-network cost is the exact sum of the
//!   per-layer costs — pinned by `tests/search.rs`.
//! * **[`SearchStrategy`]** — one trait per optimizer. Shipped
//!   strategies: [`Greedy`] (per-layer λ-aware channel placement, the
//!   heuristic formerly inlined in `experiments.rs`),
//!   [`CoordinateDescent`] (sweeps layers repeatedly, re-splitting each
//!   layer's channels against the full-network evaluator cost until a
//!   fixed point), and [`RandomRestart`] (multi-seed descent via
//!   `datasets::rng`, keeping the per-λ best). The paper's manual
//!   baselines also implement the trait (`coordinator::baselines`), so
//!   corners, heuristics, and optimizers are enumerated uniformly.
//! * **[`sweep_lambdas`]** — the Pareto driver: one scoped thread per λ
//!   (`std::thread::scope`), each with its own evaluator, tracing the
//!   accuracy-proxy-vs-cost front in parallel.
//!
//! The scalarized objective every strategy minimizes at strength λ is
//! `J = λ · cost(mapping) + penalty(mapping)`, where `cost` comes from
//! the evaluator (cycles) and [`mapping_penalty`] is the training-free
//! accuracy proxy (aggressive data representations cost quality — see
//! [`quant_penalty`]). Because [`CoordinateDescent`] starts from
//! [`Greedy`]'s solution and only ever accepts moves that improve
//! `(J, cost)` lexicographically, a descent point can never be dominated
//! by the greedy point at the same λ — the invariant
//! `tests/search.rs` asserts on every registered platform.
//!
//! **Feasibility**: a platform descriptor may bound a CU's weight memory
//! (`mem_capacity_bytes` in `hw/*.json`). [`fits`] checks a candidate
//! channel count against that bound; every shipped strategy consults it
//! before placing or moving channels, falling back to capacity-waived
//! placement only when *no* eligible CU could hold the channel (a layer
//! must run somewhere).
//!
//! **Adding a strategy**: implement [`SearchStrategy`] (take the
//! evaluator as `&mut dyn CostEvaluator`, return a [`SearchOutcome`] via
//! [`finish_outcome`]), add a [`StrategyKind`] variant + `FromStr` arm so
//! `--search <name>` reaches it, and extend the non-domination property
//! test if the strategy claims descent-like guarantees. Trained
//! (gradient) searches keep living in `coordinator`; this module is the
//! home for everything that optimizes against the simulators directly.

pub mod descent;
pub mod evaluator;
pub mod greedy;
pub mod restart;

pub use descent::CoordinateDescent;
pub use evaluator::{CachingEvaluator, CostEvaluator, CostModel, EvalStats};
pub use greedy::{greedy_assign, greedy_mapping, Greedy};
pub use restart::RandomRestart;

use std::str::FromStr;

use anyhow::{bail, Result};

use crate::soc::{analytical, CuSpec, Layer, Mapping, Platform};

// ---------------------------------------------------------------------------
// objective pieces shared by every strategy
// ---------------------------------------------------------------------------

/// Per-channel "accuracy pressure" of placing work on a CU: CUs with more
/// aggressive data representations are assumed to cost more accuracy
/// (ternary > int8), scaled to the layer's per-channel MAC volume so λ is
/// comparable against cycle counts. A crude, training-free stand-in for
/// the task-loss gradient of the real search.
pub fn quant_penalty(quant: &str) -> f64 {
    match quant {
        "int8" => 0.0,
        "ternary" => 1.0,
        _ => 0.5,
    }
}

/// Accuracy-proxy penalty of one layer's per-CU channel counts.
pub fn layer_penalty(platform: Platform, layer: &Layer, counts: &[usize]) -> f64 {
    let macs1 = layer.macs_std(1) as f64;
    platform
        .cus()
        .iter()
        .zip(counts)
        .map(|(cu, &n)| quant_penalty(&cu.quant) * macs1 * n as f64)
        .sum()
}

/// Accuracy-proxy penalty of a whole mapping (sum over layers).
pub fn mapping_penalty(layers: &[Layer], mapping: &Mapping) -> f64 {
    let k = mapping.platform.n_cus();
    layers
        .iter()
        .zip(&mapping.layers)
        .map(|(l, a)| layer_penalty(mapping.platform, l, &a.counts(k)))
        .sum()
}

// ---------------------------------------------------------------------------
// feasibility
// ---------------------------------------------------------------------------

/// CUs of `platform` whose descriptor claims support for `layer`'s op.
/// A layer nothing claims still has to run somewhere: column 0 hosts it.
pub fn eligible_cus(platform: Platform, layer: &Layer) -> Vec<bool> {
    let mut eligible: Vec<bool> = platform
        .cus()
        .iter()
        .map(|cu| cu.supports(layer.ltype))
        .collect();
    if !eligible.iter().any(|&e| e) {
        eligible[0] = true;
    }
    eligible
}

/// True if `n` channels of `layer` fit `cu`'s weight memory (descriptors
/// without `mem_capacity_bytes` are unconstrained).
pub fn fits(cu: &CuSpec, layer: &Layer, n: usize) -> bool {
    match cu.mem_capacity_bytes {
        Some(cap) => analytical::weight_bytes(cu, layer, n) <= cap,
        None => true,
    }
}

/// True if a per-CU `counts` split of `layer` places channels only on
/// eligible CUs and within every CU's weight-memory capacity.
pub fn feasible_counts(platform: Platform, layer: &Layer, counts: &[usize]) -> bool {
    let eligible = eligible_cus(platform, layer);
    platform
        .cus()
        .iter()
        .zip(counts)
        .enumerate()
        .all(|(i, (cu, &n))| n == 0 || (eligible[i] && fits(cu, layer, n)))
}

// ---------------------------------------------------------------------------
// the strategy trait
// ---------------------------------------------------------------------------

/// Bookkeeping every strategy reports alongside its mapping.
#[derive(Debug, Clone, Default)]
pub struct SearchStats {
    /// strategy display name ("greedy", "descent", "restart", ...)
    pub strategy: String,
    /// descent rounds (full layer sweeps); 0 for one-shot strategies
    pub rounds: usize,
    /// evaluator `layer_cost` calls consumed by the search
    pub evaluator_calls: u64,
    /// calls answered from the evaluator's memo cache
    pub cache_hits: u64,
    /// random restarts taken (0 unless the strategy multi-seeds)
    pub restarts: usize,
}

/// One strategy's result at one λ.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// raw (pre-reorg) channel→CU mapping
    pub mapping: Mapping,
    /// evaluator network cost of `mapping`, cycles
    pub cost: u64,
    /// accuracy-proxy penalty of `mapping` (see [`mapping_penalty`])
    pub penalty: f64,
    pub stats: SearchStats,
}

/// A mapping optimizer: given the workload and a cost evaluator, produce
/// the best mapping it can at quality/cost trade-off strength λ.
///
/// `Sync` so one strategy instance can drive every λ of a parallel sweep.
pub trait SearchStrategy: Sync {
    /// Short name, used for CLI selection and result labeling.
    fn name(&self) -> &str;

    fn search(
        &self,
        platform: Platform,
        layers: &[Layer],
        lambda: f64,
        eval: &mut dyn CostEvaluator,
    ) -> SearchOutcome;
}

/// Assemble a [`SearchOutcome`]: price the final mapping through the
/// evaluator and snapshot its counters.
pub fn finish_outcome(
    strategy: &str,
    rounds: usize,
    restarts: usize,
    mapping: Mapping,
    layers: &[Layer],
    eval: &mut dyn CostEvaluator,
) -> SearchOutcome {
    let cost = eval.network_cost(&mapping);
    let penalty = mapping_penalty(layers, &mapping);
    let s = eval.stats();
    SearchOutcome {
        mapping,
        cost,
        penalty,
        stats: SearchStats {
            strategy: strategy.to_string(),
            rounds,
            evaluator_calls: s.calls,
            cache_hits: s.cache_hits,
            restarts,
        },
    }
}

// ---------------------------------------------------------------------------
// CLI selection
// ---------------------------------------------------------------------------

/// The registered strategies, as selected by `--search`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyKind {
    Greedy,
    Descent,
    Restart,
}

impl FromStr for StrategyKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<StrategyKind> {
        Ok(match s {
            "greedy" => StrategyKind::Greedy,
            "descent" => StrategyKind::Descent,
            "restart" => StrategyKind::Restart,
            other => bail!("unknown search strategy '{other}' (expected greedy|descent|restart)"),
        })
    }
}

impl StrategyKind {
    pub fn build(self) -> Box<dyn SearchStrategy + Send + Sync> {
        match self {
            StrategyKind::Greedy => Box::new(Greedy),
            StrategyKind::Descent => Box::new(CoordinateDescent::default()),
            StrategyKind::Restart => Box::new(RandomRestart::default()),
        }
    }
}

// ---------------------------------------------------------------------------
// parallel λ sweep
// ---------------------------------------------------------------------------

/// Run `strategy` at every λ concurrently (one scoped thread per λ, each
/// with its own evaluator from `make_eval`) and return the outcomes in λ
/// order. The λ grid is embarrassingly parallel — evaluator caches are
/// per-λ, so no cross-thread state is shared beyond the immutable
/// workload.
pub fn sweep_lambdas<E, F>(
    strategy: &dyn SearchStrategy,
    platform: Platform,
    layers: &[Layer],
    lambdas: &[f64],
    make_eval: F,
) -> Vec<SearchOutcome>
where
    E: CostEvaluator,
    F: Fn(f64) -> E + Sync,
{
    std::thread::scope(|s| {
        let handles: Vec<_> = lambdas
            .iter()
            .map(|&lam| {
                let make_eval = &make_eval;
                s.spawn(move || {
                    let mut eval = make_eval(lam);
                    strategy.search(platform, layers, lam, &mut eval)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("search worker panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::LayerType;

    fn conv(name: &str, cin: usize, cout: usize, hw: usize) -> Layer {
        Layer {
            name: name.into(),
            ltype: LayerType::Conv,
            cin,
            cout,
            k: 3,
            ox: hw,
            oy: hw,
            stride: 1,
            searchable: true,
        }
    }

    #[test]
    fn strategy_kind_from_str() {
        assert_eq!("greedy".parse::<StrategyKind>().unwrap(), StrategyKind::Greedy);
        assert_eq!(
            "descent".parse::<StrategyKind>().unwrap(),
            StrategyKind::Descent
        );
        assert_eq!(
            "restart".parse::<StrategyKind>().unwrap(),
            StrategyKind::Restart
        );
        assert!("quantum".parse::<StrategyKind>().is_err());
        assert_eq!(StrategyKind::Descent.build().name(), "descent");
    }

    #[test]
    fn penalty_counts_aggressive_quant_only() {
        let l = conv("a", 16, 32, 8);
        let p = Platform::trident(); // cluster int8 / dwe int8 / aimc ternary
        assert_eq!(layer_penalty(p, &l, &[32, 0, 0]), 0.0);
        assert_eq!(layer_penalty(p, &l, &[0, 32, 0]), 0.0);
        let on_aimc = layer_penalty(p, &l, &[0, 0, 32]);
        assert_eq!(on_aimc, 32.0 * l.macs_std(1) as f64);
        // halves split linearly
        assert_eq!(layer_penalty(p, &l, &[16, 0, 16]), on_aimc / 2.0);
    }

    #[test]
    fn eligibility_and_capacity_feasibility() {
        let p = Platform::trident();
        let l = conv("a", 16, 32, 8);
        let e = eligible_cus(p, &l);
        assert_eq!(e, vec![true, false, true]); // dwe has no "conv" op
        assert!(feasible_counts(p, &l, &[16, 0, 16]));
        assert!(!feasible_counts(p, &l, &[16, 16, 0]), "dwe is ineligible");
        // a huge conv exceeds the aimc array capacity for full residency
        let big = conv("big", 512, 512, 4);
        if let Some(cap) = p.cus()[2].mem_capacity_bytes {
            let max_fit = (cap / (512 * 9)) as usize;
            assert!(fits(&p.cus()[2], &big, max_fit));
            assert!(!fits(&p.cus()[2], &big, max_fit + 1));
            assert!(!feasible_counts(p, &big, &[0, 0, 512]));
        }
    }

    #[test]
    fn parallel_sweep_matches_serial() {
        let layers: Vec<Layer> = (0..4).map(|i| conv(&format!("l{i}"), 16, 64, 8)).collect();
        let p = Platform::trident();
        let lambdas = [0.0, 16.0, 4096.0];
        let strat = Greedy;
        let par = sweep_lambdas(&strat, p, &layers, &lambdas, |_| {
            CachingEvaluator::analytical(p, &layers)
        });
        assert_eq!(par.len(), lambdas.len());
        for (outcome, &lam) in par.iter().zip(&lambdas) {
            let mut eval = CachingEvaluator::analytical(p, &layers);
            let serial = strat.search(p, &layers, lam, &mut eval);
            assert_eq!(outcome.mapping.layers, serial.mapping.layers, "λ={lam}");
            assert_eq!(outcome.cost, serial.cost);
            assert_eq!(outcome.penalty, serial.penalty);
        }
    }
}
