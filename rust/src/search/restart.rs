//! Multi-seed coordinate descent: perturb the greedy start, descend from
//! each perturbation, keep the per-λ best.
//!
//! Coordinate descent is exact only per layer; a block move it won't take
//! (because every intermediate step looks worse) can still lead to a
//! better basin. Restarting from randomized initializations — seeded
//! through the deterministic `datasets::rng` stream, so runs are
//! bit-reproducible — probes those basins. Restart 0 is always the plain
//! greedy start, so the result is never worse than [`CoordinateDescent`]
//! alone, and the shared evaluator cache makes later restarts cheap where
//! their descents revisit earlier states.

use crate::datasets::rng::Rng;
use crate::mapping::assignment_from_counts;
use crate::soc::{Layer, Mapping, Platform};

use super::{
    eligible_cus, finish_outcome, fits, greedy_mapping, mapping_penalty, CoordinateDescent,
    CostEvaluator, SearchOutcome, SearchStrategy,
};

pub struct RandomRestart {
    /// perturbed restarts on top of the greedy-start descent
    pub restarts: usize,
    /// RNG stream seed (restart index and λ bits key the sub-streams)
    pub seed: u64,
    /// fraction of each layer's channels the perturbation tries to move
    pub perturb_frac: f64,
    pub descent: CoordinateDescent,
}

impl Default for RandomRestart {
    fn default() -> Self {
        Self {
            restarts: 3,
            seed: 0xD1CE_5EED,
            perturb_frac: 0.25,
            descent: CoordinateDescent::default(),
        }
    }
}

impl RandomRestart {
    /// Randomly re-home ~`perturb_frac` of each layer's channels among
    /// the eligible, capacity-feasible CUs.
    fn perturb(&self, layers: &[Layer], base: &Mapping, rng: &mut Rng) -> Mapping {
        let platform = base.platform;
        let cus = platform.cus();
        let k = cus.len();
        let mut out = Vec::with_capacity(base.layers.len());
        for (layer, asg) in layers.iter().zip(&base.layers) {
            let eligible = eligible_cus(platform, layer);
            let mut counts = asg.counts(k);
            let n_moves = (layer.cout as f64 * self.perturb_frac) as usize;
            for _ in 0..n_moves {
                // random source channel, located by cumulative counts
                let mut c = rng.below(layer.cout.max(1));
                let mut from = 0usize;
                for (i, &n) in counts.iter().enumerate() {
                    if c < n {
                        from = i;
                        break;
                    }
                    c -= n;
                }
                let to = rng.below(k);
                if to == from
                    || !eligible[to]
                    || counts[from] == 0
                    || !fits(&cus[to], layer, counts[to] + 1)
                {
                    continue;
                }
                counts[from] -= 1;
                counts[to] += 1;
            }
            out.push(assignment_from_counts(&layer.name, &counts));
        }
        Mapping {
            platform,
            layers: out,
        }
    }
}

impl SearchStrategy for RandomRestart {
    fn name(&self) -> &str {
        "restart"
    }

    fn search(
        &self,
        platform: Platform,
        layers: &[Layer],
        lambda: f64,
        eval: &mut dyn CostEvaluator,
    ) -> SearchOutcome {
        let base = greedy_mapping(platform, layers, lambda);
        let mut best: Option<(f64, u64, Mapping)> = None;
        let mut rounds_total = 0usize;
        for r in 0..=self.restarts {
            let init = if r == 0 {
                base.clone()
            } else {
                let mut rng = Rng::from_stream(self.seed, r as u64, lambda.to_bits());
                self.perturb(layers, &base, &mut rng)
            };
            let (mapping, rounds, _) = self.descent.descend(layers, lambda, eval, &init);
            rounds_total += rounds;
            let cost = eval.network_cost(&mapping);
            let penalty = mapping_penalty(layers, &mapping);
            let j = lambda * cost as f64 + penalty;
            let better = match &best {
                None => true,
                Some((bj, bc, _)) => j < *bj || (j == *bj && cost < *bc),
            };
            if better {
                best = Some((j, cost, mapping));
            }
        }
        let (_, _, mapping) = best.expect("restart 0 always runs");
        finish_outcome(self.name(), rounds_total, self.restarts, mapping, layers, eval)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::CachingEvaluator;
    use crate::soc::LayerType;

    fn conv(name: &str, cin: usize, cout: usize, hw: usize) -> Layer {
        Layer {
            name: name.into(),
            ltype: LayerType::Conv,
            cin,
            cout,
            k: 3,
            ox: hw,
            oy: hw,
            stride: 1,
            searchable: true,
        }
    }

    fn workload() -> Vec<Layer> {
        (0..4)
            .map(|i| conv(&format!("l{i}"), 32, 64, 16))
            .collect()
    }

    #[test]
    fn restart_never_worse_than_plain_descent() {
        let p = Platform::trident();
        let layers = workload();
        for lambda in [0.0, 16.0, 4096.0] {
            let mut eval = CachingEvaluator::detailed(p, &layers);
            let d = CoordinateDescent::default().search(p, &layers, lambda, &mut eval);
            let mut eval = CachingEvaluator::detailed(p, &layers);
            let r = RandomRestart::default().search(p, &layers, lambda, &mut eval);
            let jd = lambda * d.cost as f64 + d.penalty;
            let jr = lambda * r.cost as f64 + r.penalty;
            assert!(jr <= jd, "λ={lambda}: restart J {jr} > descent J {jd}");
            assert_eq!(r.stats.restarts, RandomRestart::default().restarts);
        }
    }

    #[test]
    fn restart_is_deterministic() {
        let p = Platform::trident();
        let layers = workload();
        let run = || {
            let mut eval = CachingEvaluator::detailed(p, &layers);
            RandomRestart::default().search(p, &layers, 16.0, &mut eval)
        };
        let a = run();
        let b = run();
        assert_eq!(a.mapping.layers, b.mapping.layers);
        assert_eq!(a.cost, b.cost);
        assert_eq!(a.stats.evaluator_calls, b.stats.evaluator_calls);
    }

    #[test]
    fn perturbation_preserves_totals_and_feasibility() {
        let p = Platform::trident();
        let layers = workload();
        let rr = RandomRestart::default();
        let base = greedy_mapping(p, &layers, 16.0);
        let mut rng = Rng::from_stream(rr.seed, 1, 16.0f64.to_bits());
        let perturbed = rr.perturb(&layers, &base, &mut rng);
        for (l, (a, b)) in layers.iter().zip(base.layers.iter().zip(&perturbed.layers)) {
            let ca = a.counts(3);
            let cb = b.counts(3);
            assert_eq!(ca.iter().sum::<usize>(), cb.iter().sum::<usize>());
            assert!(crate::search::feasible_counts(p, l, &cb), "{}: {cb:?}", l.name);
        }
        // something actually moved somewhere
        assert!(layers
            .iter()
            .zip(base.layers.iter().zip(&perturbed.layers))
            .any(|(_, (a, b))| a.counts(3) != b.counts(3)));
    }
}
