//! Experiment harness: one entry point per paper table/figure
//! (DESIGN.md §3 maps each id to the paper artifact it regenerates), plus
//! the registry-driven `socmap` scenario that exercises the full
//! deployment pipeline on any platform — including N-CU ones — without
//! training artifacts.
//!
//! Results are printed as ASCII tables (same rows/series as the paper's
//! figures) and written as CSV + JSON under `results/<id>/`.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::config::{CostTarget, ExperimentConfig};
use crate::coordinator::{run_baseline, sweep, Baseline, RunRecord, Trainer};
use crate::mapping::{discretize, one_hot_theta, reorganize, SearchKind};
use crate::pareto::{pareto_front, Point};
use crate::report::{ascii_table, cyc, f as ff, write_csv};
use crate::runtime::{BackendKind, StepHparams};
use crate::search::{
    sweep_lambdas, CachingEvaluator, SearchOutcome, SearchStrategy, StrategyKind,
};
use crate::soc::{
    analytical, detailed, ExecReport, Layer, LayerAssignment, LayerType, Mapping, Platform,
};
use crate::stats;

/// Run an experiment by id. `search` selects the training-free mapping
/// strategy for `socmap` (`greedy|descent|restart`); `backend` pins the
/// training engine for the trained experiments (`None` = per-variant
/// default: native unless artifacts exist); `threads` overrides the
/// native worker count (`None` = the config value, whose default is all
/// cores). `socmap`/`table3` never train and ignore these knobs.
#[allow(clippy::too_many_arguments)]
pub fn run(
    id: &str,
    artifacts: &Path,
    results: &Path,
    task: Option<&str>,
    soc: Option<&str>,
    search: Option<&str>,
    backend: Option<BackendKind>,
    threads: Option<usize>,
    fast: f64,
) -> Result<()> {
    match id {
        "fig5" => fig5(artifacts, results, task, soc, backend, threads, fast),
        "fig6" => fig6(artifacts, results, soc, backend, threads, fast),
        "fig7" => fig7(artifacts, results, soc, backend, threads, fast),
        "fig8" => fig8(artifacts, results, backend, threads, fast),
        "fig9" => fig9(artifacts, results, backend, threads, fast),
        "fig10" => fig10(artifacts, results, backend, threads, fast),
        "table2" => table2(artifacts, results, task, backend, threads, fast),
        "table3" => table3(results),
        "table4" => table4(artifacts, results, task, backend, threads, fast),
        "socmap" => socmap(results, soc, task, search),
        "all" => {
            for e in [
                "table3", "socmap", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "table2",
                "table4",
            ] {
                eprintln!("=== exp {e} ===");
                run(e, artifacts, results, task, soc, search, backend, threads, fast)?;
            }
            Ok(())
        }
        other => Err(anyhow!("unknown experiment '{other}' (see DESIGN.md §3)")),
    }
}

// ---------------------------------------------------------------------------
// shared plumbing
// ---------------------------------------------------------------------------

fn cfg_for(variant: &str, fast: f64, target: CostTarget) -> ExperimentConfig {
    let root = crate::repo_root();
    let path = root.join(format!("configs/{variant}.json"));
    let mut cfg = if path.exists() {
        ExperimentConfig::load(&path).unwrap_or_else(|_| ExperimentConfig::for_variant(variant))
    } else {
        ExperimentConfig::for_variant(variant)
    };
    cfg.cost_target = target;
    cfg.scaled(fast)
}

fn trainer(
    artifacts: &Path,
    mut cfg: ExperimentConfig,
    backend: Option<BackendKind>,
    threads: Option<usize>,
) -> Result<Trainer> {
    if let Some(t) = threads {
        cfg.threads = t;
    }
    Trainer::create(artifacts, cfg, backend)
}

/// Sweep a variant + its baselines.
#[allow(clippy::too_many_arguments)]
fn panel(
    artifacts: &Path,
    variant: &str,
    target: CostTarget,
    backend: Option<BackendKind>,
    threads: Option<usize>,
    fast: f64,
    with_baselines: bool,
) -> Result<Vec<RunRecord>> {
    let tr = trainer(artifacts, cfg_for(variant, fast, target), backend, threads)?;
    let mut recs = sweep(&tr)?;
    if with_baselines {
        for b in Baseline::for_platform(tr.platform) {
            recs.push(run_baseline(&tr, b)?);
        }
    }
    Ok(recs)
}

/// Print a sweep as an accuracy-vs-cost table with Pareto markers.
pub fn print_sweep(recs: &[RunRecord]) {
    let target = recs
        .iter()
        .find(|r| r.lambda.is_some())
        .map(|r| r.cost_target.clone())
        .unwrap_or_else(|| "latency".into());
    let pts: Vec<Point> = recs
        .iter()
        .map(|r| Point {
            cost: r.cost(&target),
            acc: r.test_acc,
        })
        .collect();
    let front = pareto_front(&pts);
    let rows: Vec<Vec<String>> = recs
        .iter()
        .enumerate()
        .map(|(i, r)| {
            vec![
                r.label.clone(),
                r.lambda.map(|l| format!("{l}")).unwrap_or_default(),
                ff(100.0 * r.test_acc, 2),
                cyc(r.ana_cycles as f64),
                ff(r.ana_energy_uj, 2),
                ff(r.det_latency_ms, 3),
                ff(r.det_energy_uj, 2),
                r.util_display(),
                ff(100.0 * r.offload_frac, 1),
                if front.contains(&i) { "*".into() } else { "".into() },
            ]
        })
        .collect();
    println!(
        "{}",
        ascii_table(
            &[
                "mapping", "λ", "acc%", "cycles", "E_ana[uJ]", "lat[ms]", "E_det[uJ]",
                "util/cu", "offload%", "pareto"
            ],
            &rows
        )
    );
}

/// CSV + JSON dump of a record set.
pub fn save_records(dir: &Path, name: &str, recs: &[RunRecord]) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let rows: Vec<Vec<String>> = recs
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                r.lambda.map(|l| l.to_string()).unwrap_or_default(),
                r.cost_target.clone(),
                r.val_acc.to_string(),
                r.test_acc.to_string(),
                r.ana_cycles.to_string(),
                r.ana_energy_uj.to_string(),
                r.det_cycles.to_string(),
                r.det_energy_uj.to_string(),
                r.det_latency_ms.to_string(),
                r.util
                    .iter()
                    .map(|u| u.to_string())
                    .collect::<Vec<_>>()
                    .join("|"),
                r.offload_frac.to_string(),
                r.strategy.clone(),
                r.search_rounds.to_string(),
                r.evaluator_calls.to_string(),
            ]
        })
        .collect();
    write_csv(
        &dir.join(format!("{name}.csv")),
        &[
            "label",
            "lambda",
            "cost_target",
            "val_acc",
            "test_acc",
            "ana_cycles",
            "ana_energy_uj",
            "det_cycles",
            "det_energy_uj",
            "det_latency_ms",
            "util_per_cu",
            "offload_frac",
            "strategy",
            "search_rounds",
            "evaluator_calls",
        ],
        &rows,
    )?;
    let json =
        crate::util::json::Value::arr(recs.iter().map(|r| r.to_json())).to_string_pretty();
    std::fs::write(dir.join(format!("{name}.json")), json)?;
    Ok(())
}

/// True when `variant` is runnable with the resolved backend. The
/// `_prune`/`_layerwise` baseline search spaces build natively from the
/// variant name alone; only a pinned XLA backend still needs its AOT
/// artifacts, and skips with a notice instead of aborting the whole run.
fn baseline_variant_available(
    artifacts: &Path,
    variant: &str,
    backend: Option<BackendKind>,
) -> bool {
    let resolved =
        backend.unwrap_or_else(|| crate::runtime::default_backend(artifacts, variant));
    if resolved == BackendKind::Native
        || artifacts.join(format!("{variant}.manifest.json")).exists()
    {
        return true;
    }
    eprintln!(
        "    (skipping {variant}: --backend xla needs its AOT artifacts — \
         run `make artifacts`, or drop the pin to use the native engine)"
    );
    false
}

fn variant_for(soc: &str, task: &str) -> &'static str {
    match (soc, task) {
        ("diana", "c10") => "diana_resnet20_c10",
        ("diana", "c100") => "diana_resnet8_c100",
        ("diana", "imagenet") => "diana_resnet8_imgnet",
        ("darkside", "c10") => "darkside_mbv1_c10",
        ("darkside", "c100") => "darkside_mbv1_c100",
        ("darkside", "imagenet") => "darkside_mbv1_imgnet",
        _ => panic!("unknown (soc, task) = ({soc}, {task})"),
    }
}

fn filtered<'a>(all: &[&'a str], chosen: Option<&str>) -> Vec<&'a str> {
    match chosen {
        Some(c) => all.iter().filter(|&&x| x == c).copied().collect(),
        None => all.to_vec(),
    }
}

// ---------------------------------------------------------------------------
// Fig. 5 — accuracy vs (estimated) latency, 3 tasks × 2 SoCs
// ---------------------------------------------------------------------------

fn fig5(
    artifacts: &Path,
    results: &Path,
    task: Option<&str>,
    soc: Option<&str>,
    backend: Option<BackendKind>,
    threads: Option<usize>,
    fast: f64,
) -> Result<()> {
    for s in filtered(&["diana", "darkside"], soc) {
        for t in filtered(&["c10", "c100", "imagenet"], task) {
            let variant = variant_for(s, t);
            eprintln!("--- fig5 panel: {s}/{t} ({variant})");
            let recs =
                panel(artifacts, variant, CostTarget::Latency, backend, threads, fast, true)?;
            print_sweep(&recs);
            save_records(&results.join("fig5"), variant, &recs)?;
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 6 — accuracy vs energy, CIFAR-10 × 2 SoCs
// ---------------------------------------------------------------------------

fn fig6(
    artifacts: &Path,
    results: &Path,
    soc: Option<&str>,
    backend: Option<BackendKind>,
    threads: Option<usize>,
    fast: f64,
) -> Result<()> {
    for s in filtered(&["diana", "darkside"], soc) {
        let variant = variant_for(s, "c10");
        eprintln!("--- fig6 panel: {s} ({variant}, energy target)");
        let recs = panel(artifacts, variant, CostTarget::Energy, backend, threads, fast, true)?;
        print_sweep(&recs);
        save_records(&results.join("fig6"), variant, &recs)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 7 — vs structured pruning (DIANA) / path-based DNAS (Darkside)
// ---------------------------------------------------------------------------

fn fig7(
    artifacts: &Path,
    results: &Path,
    soc: Option<&str>,
    backend: Option<BackendKind>,
    threads: Option<usize>,
    fast: f64,
) -> Result<()> {
    if filtered(&["diana"], soc).len() == 1 {
        eprintln!("--- fig7 top: ODiMO vs structured pruning (DIANA, c10)");
        let mut recs = panel(
            artifacts,
            "diana_resnet20_c10",
            CostTarget::Latency,
            backend,
            threads,
            fast,
            false,
        )?;
        // pruning's cost floors at zero channels, so the shared λ grid
        // over-prunes; sweep it at gentler strengths (see fig8 note)
        if baseline_variant_available(artifacts, "diana_resnet20_c10_prune", backend) {
            let mut cfgp = cfg_for("diana_resnet20_c10_prune", fast, CostTarget::Latency);
            cfgp.lambdas = vec![0.005, 0.02, 0.1];
            let trp = trainer(artifacts, cfgp, backend, threads)?;
            let mut prune = sweep(&trp)?;
            for r in &mut prune {
                r.label = "pruning".into();
            }
            recs.extend(prune);
        }
        print_sweep(&recs);
        save_records(&results.join("fig7"), "diana_vs_pruning", &recs)?;
    }
    if filtered(&["darkside"], soc).len() == 1 {
        eprintln!("--- fig7 bottom: ODiMO vs layer-wise DNAS (Darkside, c10)");
        let mut recs = panel(
            artifacts,
            "darkside_mbv1_c10",
            CostTarget::Latency,
            backend,
            threads,
            fast,
            false,
        )?;
        if baseline_variant_available(artifacts, "darkside_mbv1_c10_layerwise", backend) {
            let mut pb = panel(
                artifacts,
                "darkside_mbv1_c10_layerwise",
                CostTarget::Latency,
                backend,
                threads,
                fast,
                false,
            )?;
            for r in &mut pb {
                r.label = "layerwise-dnas".into();
            }
            recs.extend(pb);
        }
        print_sweep(&recs);
        save_records(&results.join("fig7"), "darkside_vs_layerwise", &recs)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Figs. 8/9 — per-layer assignment & cycle breakdowns
// ---------------------------------------------------------------------------

fn breakdown_table(recs: &[RunRecord]) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    for r in recs {
        for l in &r.per_layer {
            let tot = l.channels.iter().sum::<usize>().max(1);
            let off: usize = l.channels.iter().skip(1).sum();
            rows.push(vec![
                r.label.clone(),
                l.layer.clone(),
                l.channels
                    .iter()
                    .map(|n| n.to_string())
                    .collect::<Vec<_>>()
                    .join("/"),
                ff(100.0 * off as f64 / tot as f64, 1),
                l.cycles
                    .iter()
                    .map(|c| c.to_string())
                    .collect::<Vec<_>>()
                    .join("/"),
            ]);
        }
    }
    rows
}

const BREAKDOWN_HEADERS: [&str; 5] = ["mapping", "layer", "ch/cu", "offload %", "cyc/cu"];

fn fig8(
    artifacts: &Path,
    results: &Path,
    backend: Option<BackendKind>,
    threads: Option<usize>,
    fast: f64,
) -> Result<()> {
    eprintln!("--- fig8: DIANA layer breakdown (Ours vs pruning)");
    let mut cfg = cfg_for("diana_resnet20_c10", fast, CostTarget::Latency);
    cfg.lambdas = vec![0.2];
    let tr = trainer(artifacts, cfg, backend, threads)?;
    let mut recs = sweep(&tr)?;
    recs[0].label = "ours".into();
    // pruning collapses whole layers under strong λ (its cost keeps
    // falling all the way to zero channels, unlike a mapping whose cost
    // floors at the cheap CU) — compare at gentler strengths
    if baseline_variant_available(artifacts, "diana_resnet20_c10_prune", backend) {
        let mut cfgp = cfg_for("diana_resnet20_c10_prune", fast, CostTarget::Latency);
        cfgp.lambdas = vec![0.02, 0.1];
        let trp = trainer(artifacts, cfgp, backend, threads)?;
        let mut prune = sweep(&trp)?;
        prune[0].label = "pr-l".into();
        prune[1].label = "pr-m".into();
        recs.extend(prune);
    }
    let rows = breakdown_table(&recs);
    println!("{}", ascii_table(&BREAKDOWN_HEADERS, &rows));
    write_csv(
        &results.join("fig8/breakdown.csv"),
        &["mapping", "layer", "channels_per_cu", "offload_pct", "cycles_per_cu"],
        &rows,
    )?;
    save_records(&results.join("fig8"), "records", &recs)?;
    Ok(())
}

fn fig9(
    artifacts: &Path,
    results: &Path,
    backend: Option<BackendKind>,
    threads: Option<usize>,
    fast: f64,
) -> Result<()> {
    eprintln!("--- fig9: Darkside layer breakdown (Ours vs layer-wise)");
    let mut cfg = cfg_for("darkside_mbv1_c10", fast, CostTarget::Latency);
    cfg.lambdas = vec![0.05, 0.5];
    let tr = trainer(artifacts, cfg, backend, threads)?;
    let mut recs = sweep(&tr)?;
    recs[0].label = "ours-l".into();
    recs[1].label = "ours-m".into();
    if baseline_variant_available(artifacts, "darkside_mbv1_c10_layerwise", backend) {
        let mut cfgp = cfg_for("darkside_mbv1_c10_layerwise", fast, CostTarget::Latency);
        cfgp.lambdas = vec![0.05, 0.5];
        let trp = trainer(artifacts, cfgp, backend, threads)?;
        let mut pb = sweep(&trp)?;
        pb[0].label = "pb-l".into();
        pb[1].label = "pb-m".into();
        recs.extend(pb);
    }
    let rows = breakdown_table(&recs);
    println!("{}", ascii_table(&BREAKDOWN_HEADERS, &rows));
    write_csv(
        &results.join("fig9/breakdown.csv"),
        &["mapping", "layer", "channels_per_cu", "offload_pct", "cycles_per_cu"],
        &rows,
    )?;
    save_records(&results.join("fig9"), "records", &recs)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 10 — width-multiplier sweep (Darkside, c10)
// ---------------------------------------------------------------------------

fn fig10(
    artifacts: &Path,
    results: &Path,
    backend: Option<BackendKind>,
    threads: Option<usize>,
    fast: f64,
) -> Result<()> {
    let mut all = Vec::new();
    for (variant, wm) in [
        ("darkside_mbv1_c10", "1.0x"),
        ("darkside_mbv1_c10_w050", "0.5x"),
        ("darkside_mbv1_c10_w025", "0.25x"),
    ] {
        eprintln!("--- fig10: width {wm} ({variant})");
        let mut recs =
            panel(artifacts, variant, CostTarget::Latency, backend, threads, fast, true)?;
        for r in &mut recs {
            r.label = format!("{} ({wm})", r.label);
        }
        print_sweep(&recs);
        all.extend(recs);
    }
    save_records(&results.join("fig10"), "width_sweep", &all)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table II — search overhead (epoch time ×, memory ×)
// ---------------------------------------------------------------------------

fn table2(
    artifacts: &Path,
    results: &Path,
    task: Option<&str>,
    backend: Option<BackendKind>,
    threads: Option<usize>,
    fast: f64,
) -> Result<()> {
    eprintln!("--- table2: ODiMO search overhead vs most-demanding baseline");
    let mut rows = Vec::new();
    for t in filtered(&["c10", "c100", "imagenet"], task) {
        for s in ["diana", "darkside"] {
            let search_v = variant_for(s, t);
            let fixed_v = format!("{search_v}_fixed");
            // one engine per row — comparing a native search net against
            // an XLA fixed net (or vice versa) would measure the backends,
            // not the search overhead. The XLA engine additionally needs
            // compiled artifacts for the fixed net; the native engine
            // builds it from the variant name alone.
            let row_backend =
                backend.unwrap_or_else(|| crate::runtime::default_backend(artifacts, search_v));
            if row_backend == BackendKind::Xla
                && !artifacts.join(format!("{fixed_v}.manifest.json")).exists()
            {
                eprintln!("    (skipping {s}/{t}: no {fixed_v} artifacts)");
                continue;
            }
            let measure = |variant: &str, lam: f32, lr_th: f32| -> Result<(f64, usize)> {
                let mut cfg = cfg_for(variant, fast, CostTarget::Latency);
                cfg.steps_per_epoch = (cfg.steps_per_epoch / 2).max(5);
                let tr = trainer(artifacts, cfg, Some(row_backend), threads)?;
                let mut st = tr.init_state()?;
                let hp = StepHparams {
                    lam,
                    cost_sel: 0.0,
                    lr_w: tr.cfg.lr_w,
                    lr_th,
                };
                tr.run_epoch(&mut st, hp, 0)?; // warm the executable
                let m = tr.run_epoch(&mut st, hp, 1)?;
                Ok((m.step_ms, tr.state_bytes()))
            };
            let (ms_search, bytes_search) = measure(search_v, 1e-7, 0.05)?;
            let (ms_fixed, bytes_fixed) = measure(&fixed_v, 0.0, 0.0)?;
            rows.push(vec![
                t.to_string(),
                s.to_string(),
                format!("{:.2}x", ms_search / ms_fixed),
                format!("{:.2}x", bytes_search as f64 / bytes_fixed as f64),
                ff(ms_search, 1),
                ff(ms_fixed, 1),
            ]);
        }
    }
    println!(
        "{}",
        ascii_table(
            &["task", "platform", "epoch time", "memory", "search ms/step", "baseline ms/step"],
            &rows
        )
    );
    write_csv(
        &results.join("table2/overhead.csv"),
        &["task", "platform", "time_ratio", "mem_ratio", "search_ms", "fixed_ms"],
        &rows,
    )?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table III — HW model micro-benchmarking (MAPE / Pearson / Spearman)
// ---------------------------------------------------------------------------

/// ResNet / MobileNet layer geometries used as micro-benchmark workloads.
pub fn microbench_layers(style: &str) -> Vec<Layer> {
    let mut layers = Vec::new();
    let mut add = |name: String, ltype, cin, cout, k, hw| {
        layers.push(Layer {
            name,
            ltype,
            cin,
            cout,
            k,
            ox: hw,
            oy: hw,
            stride: 1,
            searchable: true,
        });
    };
    match style {
        "resnet" => {
            for (i, (cin, cout, hw)) in [
                (3, 16, 32),
                (16, 16, 32),
                (16, 32, 16),
                (32, 32, 16),
                (32, 64, 8),
                (64, 64, 8),
                (64, 128, 4),
                (128, 128, 4),
                (16, 64, 32),
                (64, 256, 8),
            ]
            .iter()
            .enumerate()
            {
                add(format!("res{i}"), LayerType::Conv, *cin, *cout, 3, *hw);
            }
        }
        _ => {
            for (i, (c, hw)) in [
                (8, 32),
                (16, 32),
                (16, 16),
                (32, 16),
                (64, 8),
                (128, 8),
                (128, 4),
                (256, 4),
            ]
            .iter()
            .enumerate()
            {
                add(format!("mb_dw{i}"), LayerType::Dw, *c, *c, 3, *hw);
                add(format!("mb_pw{i}"), LayerType::Pw, *c, 2 * c, 1, *hw);
            }
        }
    }
    layers
}

/// Micro-benchmark workload style fitting a platform's strengths.
fn microbench_style(platform: Platform) -> &'static str {
    if platform.name() == "diana" {
        "resnet"
    } else {
        "mobilenet"
    }
}

/// One Table III row: per-CU analytical-vs-detailed agreement.
pub struct Table3Row {
    pub platform: String,
    pub cu: String,
    pub mape: f64,
    pub pearson: f64,
    pub spearman: f64,
}

/// The Table III micro-benchmark over every built-in platform and CU
/// column — the N-CU generalization of the paper's four rows. Shared by
/// `repro exp table3` and the `hw_models` bench so the two cannot
/// diverge.
pub fn table3_rows() -> Result<Vec<Table3Row>> {
    let mut rows = Vec::new();
    for name in ["diana", "darkside", "trident"] {
        let platform = Platform::get(name)?;
        let layers = microbench_layers(microbench_style(platform));
        for (col, cu) in platform.cus().iter().enumerate() {
            let mut pred = Vec::new();
            let mut meas = Vec::new();
            for l in &layers {
                // only benchmark ops the CU's descriptor claims to run
                if !cu.supports(l.ltype) {
                    continue;
                }
                for frac in [0.25, 0.5, 1.0] {
                    // isolate the CU: run `n` channels on it, others idle
                    let n = ((l.cout as f64 * frac) as usize).max(1);
                    let mapping = Mapping {
                        platform,
                        layers: vec![LayerAssignment {
                            layer: l.name.clone(),
                            cu_of: vec![col as u8; n],
                        }],
                    };
                    let mut ll = l.clone();
                    ll.cout = n;
                    let a = analytical::execute(std::slice::from_ref(&ll), &mapping, &[]);
                    let d = detailed::execute(std::slice::from_ref(&ll), &mapping, &[]);
                    pred.push(a.layers[0].per_cu[col].cycles as f64);
                    meas.push(d.layers[0].per_cu[col].cycles as f64);
                }
            }
            rows.push(Table3Row {
                platform: name.to_string(),
                cu: cu.name.clone(),
                mape: stats::mape(&pred, &meas),
                pearson: stats::pearson(&pred, &meas),
                spearman: stats::spearman(&pred, &meas),
            });
        }
    }
    Ok(rows)
}

fn table3(results: &Path) -> Result<()> {
    eprintln!("--- table3: analytical vs detailed-sim micro-benchmarking");
    let rows: Vec<Vec<String>> = table3_rows()?
        .into_iter()
        .map(|r| {
            vec![
                r.platform,
                r.cu,
                format!("{:.0}%", r.mape),
                format!("{:.1}%", 100.0 * r.pearson),
                format!("{:.1}%", 100.0 * r.spearman),
            ]
        })
        .collect();
    println!(
        "{}",
        ascii_table(&["platform", "CU", "error", "Pearson", "Spearman"], &rows)
    );
    write_csv(
        &results.join("table3/hw_models.csv"),
        &["platform", "cu", "mape", "pearson", "spearman"],
        &rows,
    )?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table IV — deployment of selected solutions on DIANA
// ---------------------------------------------------------------------------

fn table4(
    artifacts: &Path,
    results: &Path,
    task: Option<&str>,
    backend: Option<BackendKind>,
    threads: Option<usize>,
    fast: f64,
) -> Result<()> {
    eprintln!("--- table4: DIANA deployment (detailed simulator)");
    let mut rows = Vec::new();
    for t in filtered(&["c10", "c100", "imagenet"], task) {
        let variant = variant_for("diana", t);
        let mut cfg = cfg_for(variant, fast, CostTarget::Latency);
        cfg.lambdas = vec![0.05, 2.0]; // Accurate / Fast
        let tr = trainer(artifacts, cfg, backend, threads)?;
        let mut recs = sweep(&tr)?;
        recs[0].label = "odimo-accurate".into();
        recs[1].label = "odimo-fast".into();
        recs.insert(0, run_baseline(&tr, Baseline::AllOn(0))?);
        recs.push(run_baseline(&tr, Baseline::MinCost)?);
        for r in &recs {
            rows.push(vec![
                t.to_string(),
                r.label.clone(),
                ff(100.0 * r.test_acc, 2),
                ff(r.det_latency_ms, 3),
                ff(r.det_energy_uj, 2),
                r.util_display(),
                ff(100.0 * r.offload_frac, 1),
            ]);
        }
        save_records(&results.join("table4"), variant, &recs)?;
    }
    println!(
        "{}",
        ascii_table(
            &["task", "network", "acc%", "lat[ms]", "E[uJ]", "util/cu", "offload%"],
            &rows
        )
    );
    write_csv(
        &results.join("table4/deployment.csv"),
        &["task", "network", "acc", "lat_ms", "energy_uj", "util", "offload_pct"],
        &rows,
    )?;
    Ok(())
}

// ---------------------------------------------------------------------------
// socmap — registry-driven mapping sweep on any platform, no artifacts
// ---------------------------------------------------------------------------

/// Deploy a raw search mapping exactly as the coordinator does: θ one-hot
/// round-trip through the *real* `discretize`, the Fig. 4 reorg pass,
/// then both simulators on the reorganized (deployment-order) mapping.
pub fn socmap_deploy(
    platform: Platform,
    layers: &[Layer],
    raw: &Mapping,
) -> (Mapping, ExecReport, ExecReport) {
    let n_cus = platform.n_cus();
    for (l, asg) in layers.iter().zip(&raw.layers) {
        // exercise the θ machinery exactly as the coordinator does
        let theta = one_hot_theta(SearchKind::Channel, asg, n_cus);
        let back = discretize(SearchKind::Channel, &theta, l.cout, n_cus, &l.name);
        assert_eq!(*asg, back, "{}: θ one-hot round-trip drifted", l.name);
    }
    let reorg = reorganize(raw);
    let deployed = Mapping {
        platform,
        layers: raw
            .layers
            .iter()
            .zip(&reorg.layers)
            .map(|(asg, lr)| {
                assert!(lr.is_valid_permutation(), "{}: invalid perm", asg.layer);
                let contiguous = lr.reorganized_assignment(asg);
                assert!(contiguous.is_contiguous());
                contiguous
            })
            .collect(),
    };
    let ana = analytical::execute(layers, &deployed, &[]);
    let det = detailed::execute(layers, &deployed, &[]);
    (deployed, ana, det)
}

/// One full training-free greedy sweep point (compat shim: greedy
/// assignment — `search::greedy_assign` — piped through
/// [`socmap_deploy`]).
pub fn socmap_point(
    platform: Platform,
    layers: &[Layer],
    lambda: f64,
) -> (Mapping, ExecReport, ExecReport) {
    let raw = crate::search::greedy_mapping(platform, layers, lambda);
    socmap_deploy(platform, layers, &raw)
}

/// The default λ grid of the socmap sweep. The quality penalty is scaled
/// by per-channel MACs while λ multiplies whole-layer latency, so the
/// interesting transitions (int8 offload first, then the ternary array)
/// spread over several orders of magnitude — hence the geometric grid.
pub const SOCMAP_LAMBDAS: [f64; 6] = [0.0, 1.0, 16.0, 256.0, 4096.0, 65536.0];

/// One deployed socmap row: a search outcome (or baseline) pushed through
/// the full deploy pipeline.
struct SocmapRow {
    label: String,
    lambda: Option<f64>,
    outcome: SearchOutcome,
    mapping: Mapping,
    ana: ExecReport,
    det: ExecReport,
}

/// Registry-driven deployment-pipeline sweep. `soc` defaults to the
/// synthetic tri-CU `trident` platform; `task` selects the workload style
/// (`resnet` or `mobilenet`); `search` the mapping strategy
/// (`greedy|descent|restart`, default greedy). The λ grid runs in
/// parallel against a detailed-sim-backed evaluator; the paper's manual
/// baselines ride along through the same `SearchStrategy` trait.
pub fn socmap(
    results: &Path,
    soc: Option<&str>,
    task: Option<&str>,
    search: Option<&str>,
) -> Result<()> {
    let platform = Platform::get(soc.unwrap_or("trident"))?;
    // socmap's --task selects a workload *style*, unlike the dataset tasks
    // of the paper experiments — ignore anything else (e.g. the c10/c100
    // values `exp all --task ...` forwards) rather than mislabel results
    let style = match task {
        Some(s @ ("resnet" | "mobilenet")) => s,
        Some(other) => {
            eprintln!("    (socmap: ignoring --task '{other}'; styles are resnet|mobilenet)");
            "mobilenet"
        }
        None => "mobilenet",
    };
    let kind: StrategyKind = search.unwrap_or("greedy").parse()?;
    let strategy = kind.build();
    let layers = microbench_layers(style);
    eprintln!(
        "--- socmap: {} ({} CUs: {}), {style} workload, {} layers, {} search",
        platform.name(),
        platform.n_cus(),
        platform
            .cus()
            .iter()
            .map(|c| c.name.as_str())
            .collect::<Vec<_>>()
            .join("+"),
        layers.len(),
        strategy.name()
    );

    // λ grid in parallel, one detailed-sim-backed evaluator per λ
    let outcomes = sweep_lambdas(
        strategy.as_ref(),
        platform,
        &layers,
        &SOCMAP_LAMBDAS,
        |_| CachingEvaluator::detailed(platform, &layers),
    );

    // a descent-family strategy starts from the greedy solution and only
    // accepts improving moves, so no point of its front may be dominated
    // by the greedy point at the same λ — enforce, don't just hope
    if kind != StrategyKind::Greedy {
        let greedy = sweep_lambdas(
            &crate::search::Greedy,
            platform,
            &layers,
            &SOCMAP_LAMBDAS,
            |_| CachingEvaluator::detailed(platform, &layers),
        );
        for (lam, (g, o)) in SOCMAP_LAMBDAS.iter().zip(greedy.iter().zip(&outcomes)) {
            let gp = Point {
                cost: g.cost as f64,
                acc: -g.penalty,
            };
            let op = Point {
                cost: o.cost as f64,
                acc: -o.penalty,
            };
            assert!(
                !gp.dominates(&op),
                "λ={lam}: greedy front dominates the {} point",
                strategy.name()
            );
        }
        eprintln!(
            "    (verified: no {} point dominated by the greedy front at any λ)",
            strategy.name()
        );
    }

    let mut table: Vec<SocmapRow> = SOCMAP_LAMBDAS
        .iter()
        .zip(outcomes)
        .map(|(&lam, outcome)| {
            let (mapping, ana, det) = socmap_deploy(platform, &layers, &outcome.mapping);
            SocmapRow {
                label: outcome.stats.strategy.clone(),
                lambda: Some(lam),
                outcome,
                mapping,
                ana,
                det,
            }
        })
        .collect();

    // the paper's manual corners, enumerated through the same trait
    for b in Baseline::for_platform(platform) {
        let mut eval = CachingEvaluator::detailed(platform, &layers);
        let outcome = b.search(platform, &layers, 0.0, &mut eval);
        let (mapping, ana, det) = socmap_deploy(platform, &layers, &outcome.mapping);
        table.push(SocmapRow {
            label: b.label(platform),
            lambda: None,
            outcome,
            mapping,
            ana,
            det,
        });
    }

    // Pareto front in the (detailed cycles, −penalty) plane over all rows
    let pts: Vec<Point> = table
        .iter()
        .map(|r| Point {
            cost: r.det.total_cycles as f64,
            acc: -r.outcome.penalty,
        })
        .collect();
    let front = pareto_front(&pts);

    let mut rows = Vec::new();
    let mut csv_rows = Vec::new();
    let mut json_points = Vec::new();
    for (i, r) in table.iter().enumerate() {
        let util = r
            .det
            .utilization
            .iter()
            .map(|u| format!("{:.0}%", 100.0 * u))
            .collect::<Vec<_>>()
            .join("/");
        let lam_str = r.lambda.map(|l| format!("{l}")).unwrap_or_default();
        let on_front = front.contains(&i);
        rows.push(vec![
            r.label.clone(),
            lam_str.clone(),
            cyc(r.ana.total_cycles as f64),
            cyc(r.det.total_cycles as f64),
            ff(r.det.latency_ms, 3),
            ff(r.det.energy_uj, 2),
            util,
            ff(100.0 * r.det.offload_channel_fraction(), 1),
            r.outcome.stats.rounds.to_string(),
            r.outcome.stats.evaluator_calls.to_string(),
            if on_front { "*".into() } else { String::new() },
        ]);
        // CSV carries raw machine-readable values, like save_records()
        csv_rows.push(vec![
            r.label.clone(),
            lam_str,
            r.outcome.stats.strategy.clone(),
            r.outcome.stats.rounds.to_string(),
            r.outcome.stats.evaluator_calls.to_string(),
            r.outcome.penalty.to_string(),
            r.ana.total_cycles.to_string(),
            r.det.total_cycles.to_string(),
            r.det.latency_ms.to_string(),
            r.ana.energy_uj.to_string(),
            r.det.energy_uj.to_string(),
            r.det
                .utilization
                .iter()
                .map(|u| u.to_string())
                .collect::<Vec<_>>()
                .join("|"),
            r.det.offload_channel_fraction().to_string(),
            on_front.to_string(),
        ]);
        json_points.push(crate::util::json::Value::obj(vec![
            ("label", crate::util::json::Value::str(&r.label)),
            (
                "lambda",
                r.lambda
                    .map(crate::util::json::Value::num)
                    .unwrap_or(crate::util::json::Value::Null),
            ),
            (
                "strategy",
                crate::util::json::Value::str(&r.outcome.stats.strategy),
            ),
            (
                "rounds",
                crate::util::json::Value::num(r.outcome.stats.rounds as f64),
            ),
            (
                "evaluator_calls",
                crate::util::json::Value::num(r.outcome.stats.evaluator_calls as f64),
            ),
            (
                "cache_hits",
                crate::util::json::Value::num(r.outcome.stats.cache_hits as f64),
            ),
            ("penalty", crate::util::json::Value::num(r.outcome.penalty)),
            ("pareto", crate::util::json::Value::Bool(on_front)),
            (
                "ana_cycles",
                crate::util::json::Value::num(r.ana.total_cycles as f64),
            ),
            (
                "det_cycles",
                crate::util::json::Value::num(r.det.total_cycles as f64),
            ),
            (
                "det_latency_ms",
                crate::util::json::Value::num(r.det.latency_ms),
            ),
            (
                "det_energy_uj",
                crate::util::json::Value::num(r.det.energy_uj),
            ),
            (
                "util",
                crate::util::json::Value::arr(
                    r.det
                        .utilization
                        .iter()
                        .map(|&u| crate::util::json::Value::num(u)),
                ),
            ),
            (
                "offload_frac",
                crate::util::json::Value::num(r.det.offload_channel_fraction()),
            ),
            (
                "mapping",
                crate::util::json::Value::arr(r.mapping.layers.iter().map(|a| {
                    crate::util::json::Value::obj(vec![
                        ("layer", crate::util::json::Value::str(&a.layer)),
                        (
                            "counts",
                            crate::util::json::Value::arr(
                                a.counts(platform.n_cus())
                                    .iter()
                                    .map(|&n| crate::util::json::Value::num(n as f64)),
                            ),
                        ),
                    ])
                })),
            ),
        ]));
    }
    println!(
        "{}",
        ascii_table(
            &[
                "mapping", "λ", "cyc (ana)", "cyc (det)", "lat[ms]", "E_det[uJ]", "util/cu",
                "offload%", "rounds", "evals", "pareto"
            ],
            &rows
        )
    );
    let dir = results.join("socmap");
    std::fs::create_dir_all(&dir)?;
    write_csv(
        &dir.join(format!("{}_{style}.csv", platform.name())),
        &[
            "label",
            "lambda",
            "strategy",
            "search_rounds",
            "evaluator_calls",
            "penalty",
            "ana_cycles",
            "det_cycles",
            "det_latency_ms",
            "ana_energy_uj",
            "det_energy_uj",
            "util_per_cu",
            "offload_frac",
            "pareto",
        ],
        &csv_rows,
    )?;
    std::fs::write(
        dir.join(format!("{}_{style}.json", platform.name())),
        crate::util::json::Value::obj(vec![
            ("platform", crate::util::json::Value::str(platform.name())),
            ("style", crate::util::json::Value::str(style)),
            ("strategy", crate::util::json::Value::str(strategy.name())),
            (
                "cus",
                crate::util::json::Value::arr(
                    platform
                        .cus()
                        .iter()
                        .map(|c| crate::util::json::Value::str(&c.name)),
                ),
            ),
            ("points", crate::util::json::Value::Arr(json_points)),
        ])
        .to_string_pretty(),
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn socmap_large_lambda_offloads() {
        let layers = microbench_layers("resnet");
        let p = Platform::trident();
        let lam = *SOCMAP_LAMBDAS.last().unwrap();
        let (_, ana, det) = socmap_point(p, &layers, lam);
        assert!(det.offload_channel_fraction() > 0.0);
        assert!(det.total_cycles > ana.total_cycles);
        // cost pressure must actually reduce latency vs the λ=0 mapping
        let (_, ana0, _) = socmap_point(p, &layers, 0.0);
        assert!(ana.total_cycles < ana0.total_cycles);
    }

    #[test]
    fn socmap_deploy_accepts_any_strategy_mapping() {
        use crate::search::CoordinateDescent;
        let layers = microbench_layers("mobilenet");
        let p = Platform::trident();
        let mut eval = CachingEvaluator::detailed(p, &layers);
        let out = CoordinateDescent::default().search(p, &layers, 16.0, &mut eval);
        let (mapping, ana, det) = socmap_deploy(p, &layers, &out.mapping);
        for asg in &mapping.layers {
            assert!(asg.is_contiguous(), "{}", asg.layer);
        }
        assert!(det.total_cycles > ana.total_cycles);
        // reorg only permutes within layers: the detailed cost of the
        // deployed mapping equals the evaluator cost of the raw one
        assert_eq!(det.total_cycles, out.cost);
    }

    #[test]
    fn microbench_styles_differ() {
        assert!(microbench_layers("resnet")
            .iter()
            .all(|l| l.ltype == LayerType::Conv));
        assert!(microbench_layers("mobilenet")
            .iter()
            .any(|l| l.ltype == LayerType::Dw));
    }
}
