//! Result rendering: ASCII tables (stdout) + CSV files under `results/`.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

/// Render an ASCII table with a header row.
pub fn ascii_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        for w in &widths {
            let _ = write!(out, "+{}", "-".repeat(w + 2));
        }
        out.push_str("+\n");
    };
    sep(&mut out);
    for (i, h) in headers.iter().enumerate() {
        let _ = write!(out, "| {:<w$} ", h, w = widths[i]);
    }
    out.push_str("|\n");
    sep(&mut out);
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            let _ = write!(out, "| {:<w$} ", cell, w = widths[i]);
        }
        out.push_str("|\n");
    }
    sep(&mut out);
    out
}

/// Write rows as CSV (simple quoting: fields with commas get quoted).
pub fn write_csv(path: &Path, headers: &[&str], rows: &[Vec<String>]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let quote = |s: &str| {
        if s.contains(',') || s.contains('"') {
            format!("\"{}\"", s.replace('"', "\"\""))
        } else {
            s.to_string()
        }
    };
    let mut text = headers.iter().map(|h| quote(h)).collect::<Vec<_>>().join(",");
    text.push('\n');
    for row in rows {
        text.push_str(&row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","));
        text.push('\n');
    }
    std::fs::write(path, text).with_context(|| format!("writing {}", path.display()))
}

/// Format helper: fixed decimals.
pub fn f(v: f64, d: usize) -> String {
    format!("{v:.d$}")
}

/// Format helper: scientific for large cycle counts.
pub fn cyc(v: f64) -> String {
    if v >= 1e6 {
        format!("{:.2}e6", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1}k", v / 1e3)
    } else {
        format!("{v:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders() {
        let t = ascii_table(
            &["name", "value"],
            &[
                vec!["a".into(), "1".into()],
                vec!["long-name".into(), "2.5".into()],
            ],
        );
        assert!(t.contains("| name"));
        assert!(t.contains("| long-name"));
        assert!(t.lines().count() >= 6);
    }

    #[test]
    fn csv_quotes_commas() {
        let dir = std::env::temp_dir().join("odimo_csv_test");
        let p = dir.join("t.csv");
        write_csv(&p, &["a", "b"], &[vec!["x,y".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("\"x,y\",2"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn cyc_formats() {
        assert_eq!(cyc(500.0), "500");
        assert_eq!(cyc(1500.0), "1.5k");
        assert_eq!(cyc(2_000_000.0), "2.00e6");
    }
}
