//! Host-side stub of the `xla_extension` PJRT bindings.
//!
//! The offline crate cache does not carry the real XLA bindings, so this
//! crate reproduces the exact API surface `odimo::runtime` consumes:
//!
//! * [`Literal`] is **fully functional** — a typed host buffer with shape,
//!   so literal construction, reshape, host read-back, and the snapshot /
//!   restore path of `TrainState` all behave exactly like the real thing;
//! * the device half ([`PjRtClient::compile`] onward) returns a descriptive
//!   runtime error, so any artifact-driven path fails loudly with
//!   "xla stub: ..." instead of producing garbage.
//!
//! All non-XLA functionality (simulators, mapping, baselines over the
//! analytical models, the `socmap` scenario, every pure test) runs fully on
//! the stub. Pointing `rust/Cargo.toml`'s `xla` entry at a real
//! `xla_extension` build re-enables training without touching `odimo`.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type; rendered with `{:?}` by the callers.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the real xla_extension bindings (this build uses the \
         host-side stub; see rust/xla-stub/src/lib.rs)"
    ))
}

// ---------------------------------------------------------------------------
// Literal: a real host-side typed buffer
// ---------------------------------------------------------------------------

/// Element payload of a [`Literal`].
#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    /// tuple literal (as produced by `return_tuple=True` executables)
    Tuple(Vec<Literal>),
}

/// Types storable in a [`Literal`].
pub trait NativeType: Copy {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<f32>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<i32>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A shaped host buffer, mirroring `xla::Literal`.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    shape: Vec<i64>,
    data: Data,
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            shape: Vec::new(),
            data: T::wrap(vec![v]),
        }
    }

    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            shape: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    fn elem_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    pub fn shape(&self) -> &[i64] {
        &self.shape
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n as usize != self.elem_count() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.shape
            )));
        }
        Ok(Literal {
            shape: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::unwrap(&self.data).ok_or_else(|| Error("literal dtype mismatch".into()))
    }

    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal {
            shape: vec![elements.len() as i64],
            data: Data::Tuple(elements),
        }
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        match self.data {
            Data::Tuple(v) => Ok(v),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

// ---------------------------------------------------------------------------
// Device half: loud stubs
// ---------------------------------------------------------------------------

/// Parsed HLO module handle (never constructible on the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto, Error> {
        Err(unavailable(&format!(
            "loading HLO text {}",
            path.display()
        )))
    }
}

/// Computation wrapper, mirroring `xla::XlaComputation`.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        // unreachable in practice: HloModuleProto cannot be constructed
        XlaComputation { _private: () }
    }
}

/// Device buffer handle returned by `execute`.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("reading device buffers"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("executing compiled functions"))
    }
}

/// PJRT client. `cpu()` succeeds so artifact discovery and error reporting
/// happen in `odimo` (where the messages are better); `compile` fails.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("compiling HLO"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
        let s = Literal::scalar(7i32);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
        assert!(s.shape().is_empty());
    }

    #[test]
    fn tuple_roundtrip() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::scalar(2i32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(0.0f32).to_tuple().is_err());
    }

    #[test]
    fn device_half_errors() {
        let c = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto::from_text_file(Path::new("/nonexistent"));
        assert!(proto.is_err());
        let exe = c.compile(&XlaComputation { _private: () });
        assert!(exe.is_err());
    }
}
