//! Bench: training-free mapping-search cost per Pareto point — greedy vs
//! coordinate descent vs random restart, with and without a warm
//! evaluator cache.
//!
//! This is the overhead story of the `search` subsystem: descent buys
//! whole-network optimality at the price of extra evaluator calls, and
//! the memoized per-layer cache is what keeps that price sub-linear in
//! the number of probed moves. Artifact-free (the Table II *training*
//! overhead bench lives in `repro exp table2` / `benches/coordinator.rs`).

use odimo::experiments::microbench_layers;
use odimo::search::{
    CachingEvaluator, CoordinateDescent, CostEvaluator, Greedy, RandomRestart, SearchStrategy,
};
use odimo::soc::Platform;
use odimo::util::bench::quick;

fn bench_platform(name: &str, style: &str) {
    let platform = Platform::get(name).expect("built-in platform");
    let layers = microbench_layers(style);
    println!("-- {name} ({style}, {} layers), λ=16", layers.len());
    let strategies: [&dyn SearchStrategy; 3] = [
        &Greedy,
        &CoordinateDescent::default(),
        &RandomRestart::default(),
    ];
    for strategy in strategies {
        // cold cache: a fresh evaluator per point (the sweep_lambdas setup)
        let r = quick(&format!("{name} {} cold-cache point", strategy.name()), || {
            let mut eval = CachingEvaluator::detailed(platform, &layers);
            let out = strategy.search(platform, &layers, 16.0, &mut eval);
            std::hint::black_box(out.cost);
        });
        // one instrumented run for the evaluator-call story
        let mut eval = CachingEvaluator::detailed(platform, &layers);
        let out = strategy.search(platform, &layers, 16.0, &mut eval);
        let s = eval.stats();
        println!(
            "     {}: {} evaluator calls, {} sims ({} cache hits), {:.1} us/call",
            strategy.name(),
            s.calls,
            s.sim_evals(),
            s.cache_hits,
            r.mean_ns / 1e3 / s.calls.max(1) as f64,
        );
        std::hint::black_box(out.penalty);
        // warm cache: re-searching with the same evaluator shows the memo
        // path (every state already priced)
        let mut warm = CachingEvaluator::detailed(platform, &layers);
        strategy.search(platform, &layers, 16.0, &mut warm);
        quick(&format!("{name} {} warm-cache point", strategy.name()), || {
            let out = strategy.search(platform, &layers, 16.0, &mut warm);
            std::hint::black_box(out.cost);
        });
    }
}

fn main() {
    println!("== search_overhead bench: cost per training-free Pareto point ==");
    bench_platform("trident", "resnet");
    bench_platform("darkside", "mobilenet");
}
