//! Bench: train-step wall time, ODiMO supernet vs plain baseline
//! (the engine behind paper Table II). Needs artifacts; exits with a
//! notice when they are missing.

use odimo::config::ExperimentConfig;
use odimo::coordinator::Trainer;
use odimo::runtime::StepHparams;
use odimo::util::bench::bench;

fn step_time(variant: &str, lam: f32, lr_th: f32) -> Option<(f64, usize)> {
    let artifacts = odimo::repo_root().join("artifacts");
    if !artifacts
        .join(format!("{variant}.manifest.json"))
        .exists()
    {
        return None;
    }
    let mut cfg = ExperimentConfig::for_variant(variant);
    cfg.steps_per_epoch = 4;
    cfg.eval_batches = 1;
    let client = odimo::runtime::cpu_client().expect("client");
    let tr = Trainer::new(&client, &artifacts, cfg).expect("trainer");
    let mut state = tr.init_state().expect("init");
    let hp = StepHparams {
        lam,
        cost_sel: 0.0,
        lr_w: 1e-2,
        lr_th,
    };
    tr.run_epoch(&mut state, hp, 0).expect("warm"); // compile+warm
    let mut e = 1usize;
    let r = bench(
        &format!("train epoch (4 steps) {variant}"),
        0,
        std::time::Duration::from_secs(8),
        24,
        || {
            tr.run_epoch(&mut state, hp, e).expect("epoch");
            e += 1;
        },
    );
    Some((r.mean_ns / 4.0 / 1e6, tr.state_bytes()))
}

fn main() {
    println!("== search_overhead bench (Table II engine) ==");
    let pairs = [
        ("diana_resnet20_c10", "diana_resnet20_c10_fixed"),
        ("darkside_mbv1_c10", "darkside_mbv1_c10_fixed"),
    ];
    let mut any = false;
    for (search, fixed) in pairs {
        let Some((ms_s, by_s)) = step_time(search, 1e-7, 0.05) else {
            continue;
        };
        let Some((ms_f, by_f)) = step_time(fixed, 0.0, 0.0) else {
            continue;
        };
        any = true;
        println!(
            "  {search}: search {ms_s:.1} ms/step vs baseline {ms_f:.1} ms/step \
             -> time {:.2}x, memory {:.2}x",
            ms_s / ms_f,
            by_s as f64 / by_f as f64
        );
    }
    if !any {
        println!("  (no artifacts — run `make artifacts` first)");
    }
}
