//! Bench: the SoC simulators (Table III's engine).
//!
//! Measures the throughput of the analytical model and the detailed
//! event-driven simulator over the micro-benchmark layer corpus — on
//! every built-in platform, including the tri-CU `trident` — then prints
//! the Table III correlation summary itself (fast — no training).

use odimo::experiments::microbench_layers;
use odimo::soc::{analytical, detailed, Layer, LayerAssignment, Mapping, Platform};
use odimo::util::bench::quick;

/// Spread `frac_off` of each layer's channels off column 0, round-robin
/// over the remaining CUs.
fn mapping_for(layers: &[Layer], platform: Platform, frac_off: f64) -> Mapping {
    let k = platform.n_cus();
    Mapping {
        platform,
        layers: layers
            .iter()
            .map(|l| {
                let n_off = (l.cout as f64 * frac_off) as usize;
                LayerAssignment::offload_round_robin(&l.name, l.cout, n_off, k)
            })
            .collect(),
    }
}

fn main() {
    println!("== hw_models bench ==");
    let resnet = microbench_layers("resnet");
    let mbv1 = microbench_layers("mobilenet");
    let m_diana = mapping_for(&resnet, Platform::diana(), 0.5);
    let m_dark = mapping_for(&mbv1, Platform::darkside(), 0.5);
    let m_tri = mapping_for(&mbv1, Platform::trident(), 0.5);

    quick("analytical::execute resnet(10L, diana)", || {
        std::hint::black_box(analytical::execute(&resnet, &m_diana, &[]));
    });
    quick("detailed::execute   resnet(10L, diana)", || {
        std::hint::black_box(detailed::execute(&resnet, &m_diana, &[]));
    });
    quick("analytical::execute mbv1(16L, darkside)", || {
        std::hint::black_box(analytical::execute(&mbv1, &m_dark, &[]));
    });
    quick("detailed::execute   mbv1(16L, darkside)", || {
        std::hint::black_box(detailed::execute(&mbv1, &m_dark, &[]));
    });
    quick("analytical::execute mbv1(16L, trident/3CU)", || {
        std::hint::black_box(analytical::execute(&mbv1, &m_tri, &[]));
    });
    quick("detailed::execute   mbv1(16L, trident/3CU)", || {
        std::hint::black_box(detailed::execute(&mbv1, &m_tri, &[]));
    });

    // whole-network throughput: simulated networks per second at ODiMO
    // sweep granularity (what the λ sweep pays per candidate)
    let r = quick("detailed::execute full sweep (21 splits)", || {
        for i in 0..=20 {
            let m = mapping_for(&resnet, Platform::diana(), i as f64 / 20.0);
            std::hint::black_box(detailed::execute(&resnet, &m, &[]));
        }
    });
    println!(
        "   -> {:.0} mappings/s through the detailed simulator",
        21.0 / (r.mean_ns / 1e9)
    );

    // and the actual Table III summary, via the same code path as
    // `repro exp table3` (so the two cannot diverge)
    println!("\nTable III (analytical vs detailed):");
    for r in odimo::experiments::table3_rows().expect("built-in platforms resolve") {
        println!(
            "  {}/{}: MAPE {:>5.1}%  Pearson {:>5.1}%  Spearman {:>5.1}%",
            r.platform,
            r.cu,
            r.mape,
            100.0 * r.pearson,
            100.0 * r.spearman
        );
    }
}
