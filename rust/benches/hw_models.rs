//! Bench: the SoC simulators (Table III's engine).
//!
//! Measures the throughput of the analytical model and the detailed
//! event-driven simulator over the micro-benchmark layer corpus, then
//! prints the Table III correlation summary itself (fast — no training).

use odimo::experiments::microbench_layers;
use odimo::soc::{analytical, detailed, Layer, LayerAssignment, Mapping, Platform};
use odimo::stats;
use odimo::util::bench::quick;

fn mapping_for(layers: &[Layer], platform: Platform, frac1: f64) -> Mapping {
    Mapping {
        platform,
        layers: layers
            .iter()
            .map(|l| {
                let n1 = (l.cout as f64 * frac1) as usize;
                LayerAssignment {
                    layer: l.name.clone(),
                    cu_of: (0..l.cout).map(|c| u8::from(c >= l.cout - n1)).collect(),
                }
            })
            .collect(),
    }
}

fn main() {
    println!("== hw_models bench ==");
    let resnet = microbench_layers("resnet");
    let mbv1 = microbench_layers("mobilenet");
    let m_diana = mapping_for(&resnet, Platform::Diana, 0.5);
    let m_dark = mapping_for(&mbv1, Platform::Darkside, 0.5);

    quick("analytical::execute resnet(10L, diana)", || {
        std::hint::black_box(analytical::execute(&resnet, &m_diana, &[]));
    });
    quick("detailed::execute   resnet(10L, diana)", || {
        std::hint::black_box(detailed::execute(&resnet, &m_diana, &[]));
    });
    quick("analytical::execute mbv1(16L, darkside)", || {
        std::hint::black_box(analytical::execute(&mbv1, &m_dark, &[]));
    });
    quick("detailed::execute   mbv1(16L, darkside)", || {
        std::hint::black_box(detailed::execute(&mbv1, &m_dark, &[]));
    });

    // whole-network throughput: simulated networks per second at ODiMO
    // sweep granularity (what the λ sweep pays per candidate)
    let r = quick("detailed::execute full sweep (21 splits)", || {
        for i in 0..=20 {
            let m = mapping_for(&resnet, Platform::Diana, i as f64 / 20.0);
            std::hint::black_box(detailed::execute(&resnet, &m, &[]));
        }
    });
    println!(
        "   -> {:.0} mappings/s through the detailed simulator",
        21.0 / (r.mean_ns / 1e9)
    );

    // and the actual Table III summary, for convenience
    println!("\nTable III (analytical vs detailed):");
    for (platform, style, col) in [
        (Platform::Diana, "resnet", 0u8),
        (Platform::Diana, "resnet", 1),
        (Platform::Darkside, "mobilenet", 0),
        (Platform::Darkside, "mobilenet", 1),
    ] {
        let layers = microbench_layers(style);
        let mut pred = Vec::new();
        let mut meas = Vec::new();
        for l in &layers {
            if col == 1
                && platform == Platform::Darkside
                && l.ltype != odimo::soc::LayerType::Dw
            {
                continue;
            }
            let mut ll = l.clone();
            for frac in [0.25, 0.5, 1.0] {
                let n = ((l.cout as f64 * frac) as usize).max(1);
                ll.cout = n;
                let m = Mapping {
                    platform,
                    layers: vec![LayerAssignment::all_on(&l.name, n, col)],
                };
                let a = analytical::execute(std::slice::from_ref(&ll), &m, &[]);
                let d = detailed::execute(std::slice::from_ref(&ll), &m, &[]);
                pred.push(a.layers[0].per_cu[col as usize].cycles as f64);
                meas.push(d.layers[0].per_cu[col as usize].cycles as f64);
            }
        }
        println!(
            "  {:?} cu{}: MAPE {:>5.1}%  Pearson {:>5.1}%  Spearman {:>5.1}%",
            platform,
            col,
            stats::mape(&pred, &meas),
            100.0 * stats::pearson(&pred, &meas),
            100.0 * stats::spearman(&pred, &meas)
        );
    }
}
