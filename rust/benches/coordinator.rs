//! Bench: coordinator hot-path components (no artifacts needed).
//!
//! The per-step L3 overhead budget is: batch generation + literal
//! creation + θ discretization + (per sweep point) simulator execution +
//! Pareto extraction. This bench tracks each piece so the §Perf pass can
//! see where the non-XLA time goes.

use odimo::datasets::{Split, SynthDataset};
use odimo::mapping::{discretize, one_hot_theta, reorganize, SearchKind};
use odimo::pareto::{pareto_front, Point};
use odimo::soc::{LayerAssignment, Mapping, Platform};
use odimo::util::bench::quick;

fn main() {
    println!("== coordinator bench ==");

    // --- dataset batch generation (the per-step host work) ---------------
    let ds32 = SynthDataset::new(32, 10, 0.9, 42);
    let r = quick("synth batch 64x32x32x3", || {
        std::hint::black_box(ds32.batch(Split::Train, 7, 64));
    });
    println!(
        "   -> {:.1} MB/s of training data",
        (64.0 * 32.0 * 32.0 * 3.0 * 4.0) / (r.mean_ns / 1e9) / 1e6
    );
    let ds64 = SynthDataset::new(64, 100, 1.3, 42);
    quick("synth batch 32x64x64x3 (imagenet-proxy)", || {
        std::hint::black_box(ds64.batch(Split::Train, 7, 32));
    });

    // --- θ discretization / freezing -------------------------------------
    let theta: Vec<f32> = (0..512).map(|i| ((i * 37 % 100) as f32 - 50.0) / 25.0).collect();
    quick("discretize channel θ (256 ch)", || {
        std::hint::black_box(discretize(SearchKind::Channel, &theta, 256, 2, "l"));
    });
    let asg = discretize(SearchKind::Channel, &theta, 256, 2, "l");
    quick("one_hot_theta (256 ch)", || {
        std::hint::black_box(one_hot_theta(SearchKind::Channel, &asg, 2));
    });

    // --- Fig. 4 reorg pass -------------------------------------------------
    let mapping = Mapping {
        platform: Platform::diana(),
        layers: (0..20)
            .map(|i| LayerAssignment {
                layer: format!("l{i}"),
                cu_of: (0..256).map(|c| ((c * 7 + i) % 3 == 0) as u8).collect(),
            })
            .collect(),
    };
    quick("reorganize 20x256-ch network", || {
        std::hint::black_box(reorganize(&mapping));
    });

    // --- pareto extraction --------------------------------------------------
    let pts: Vec<Point> = (0..1000)
        .map(|i| Point {
            cost: ((i * 2654435761u64 as usize) % 10007) as f64,
            acc: ((i * 40503) % 997) as f64 / 997.0,
        })
        .collect();
    quick("pareto_front over 1000 points", || {
        std::hint::black_box(pareto_front(&pts));
    });
}
