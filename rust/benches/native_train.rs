//! Bench: native-engine train-step throughput, single- vs multi-thread.
//!
//! Records the perf trajectory of the planned executor on a fixed shape
//! (the DIANA ResNet-8/CIFAR-10 supernet, the acceptance workload) plus
//! the miniature test supernet, and emits `BENCH_native_train.json` at
//! the repo root so CI archives the numbers per commit.
//!
//! Regression gate: when `BENCH_CHECK=1` (set by the CI job) the bench
//! compares its single-thread steps/sec against the committed
//! `rust/benches/native_train.baseline.json` and exits non-zero on a
//! >20% regression. The committed baseline is a conservative floor
//! (machines differ); re-pin it from a CI run's emitted JSON whenever
//! the engine gets deliberately faster.

use std::time::Duration;

use odimo::runtime::{ModelBackend, NativeBackend, NativeOptions, StepHparams, WOptimizer};
use odimo::util::bench::bench;
use odimo::util::json::{parse, Value};

const ACCEPTANCE_VARIANT: &str = "diana_resnet8_c10";

fn hp() -> StepHparams {
    StepHparams {
        lam: 1e-7,
        cost_sel: 0.0,
        lr_w: 1e-2,
        lr_th: 5e-2,
    }
}

/// Train-step throughput of `variant` at `threads` workers (steps/sec,
/// from the mean over a few seconds of timed steps after one warm step).
fn train_steps_per_sec(variant: &str, threads: usize, budget: Duration) -> f64 {
    let be = NativeBackend::build_with(
        variant,
        NativeOptions {
            threads,
            w_optimizer: WOptimizer::SgdMomentum,
        },
    )
    .expect("native variant");
    let m = be.manifest();
    let ds = odimo::datasets::SynthDataset::from_name(
        &m.dataset.name,
        m.dataset.hw,
        m.dataset.classes,
        1,
    );
    let (x, y) = ds.batch(odimo::datasets::Split::Train, 0, m.dataset.batch);
    let mut state = be.init_state(0).expect("init");
    let r = bench(
        &format!("train_step {variant} t={threads} (batch {})", m.dataset.batch),
        1,
        budget,
        50,
        || {
            std::hint::black_box(be.train_step(&mut state, &x, &y, hp()).expect("step"));
        },
    );
    let sps = 1e9 / r.mean_ns;
    println!(
        "   -> {:.3} steps/s, {:.1} samples/s (arena growth after warmup: {})",
        sps,
        m.dataset.batch as f64 * sps,
        be.arena_grown()
    );
    sps
}

/// Eval-batch throughput of `variant` at 1 thread (evals/sec).
fn eval_batches_per_sec(variant: &str, budget: Duration) -> f64 {
    let be = NativeBackend::build(variant).expect("native variant");
    let m = be.manifest();
    let ds = odimo::datasets::SynthDataset::from_name(
        &m.dataset.name,
        m.dataset.hw,
        m.dataset.classes,
        2,
    );
    let (x, y) = ds.batch(odimo::datasets::Split::Val, 0, m.dataset.batch);
    let state = be.init_state(0).expect("init");
    let r = bench(&format!("eval_batch {variant} t=1"), 1, budget, 200, || {
        std::hint::black_box(be.eval_batch(&state, &x, &y).expect("eval"));
    });
    1e9 / r.mean_ns
}

fn main() {
    println!("== native train-step bench (planned executor) ==");

    // trajectory entries: the miniature supernet, train + eval paths
    let tiny_sps = train_steps_per_sec("trident_tiny_tiny", 1, Duration::from_secs(1));
    let tiny_eval_sps = eval_batches_per_sec("trident_tiny_tiny", Duration::from_secs(1));

    // acceptance shape: single- vs multi-thread on the resnet8 supernet
    let s1 = train_steps_per_sec(ACCEPTANCE_VARIANT, 1, Duration::from_secs(4));
    let s4 = train_steps_per_sec(ACCEPTANCE_VARIANT, 4, Duration::from_secs(4));
    let speedup = s4 / s1;
    println!("   -> 4-thread speedup on {ACCEPTANCE_VARIANT}: {speedup:.2}x");

    // emit the trajectory record
    let out = Value::obj(vec![
        ("variant", Value::str(ACCEPTANCE_VARIANT)),
        ("threads1_steps_per_sec", Value::num(s1)),
        ("threads4_steps_per_sec", Value::num(s4)),
        ("speedup_4_threads", Value::num(speedup)),
        ("tiny_steps_per_sec", Value::num(tiny_sps)),
        ("tiny_eval_per_sec", Value::num(tiny_eval_sps)),
    ]);
    let path = odimo::repo_root().join("BENCH_native_train.json");
    std::fs::write(&path, out.to_string_pretty()).expect("write bench json");
    println!("   -> wrote {}", path.display());

    // regression gate (CI sets BENCH_CHECK=1)
    if std::env::var("BENCH_CHECK").as_deref() == Ok("1") {
        let base_path = odimo::repo_root().join("rust/benches/native_train.baseline.json");
        let text = std::fs::read_to_string(&base_path).expect("committed bench baseline");
        let base = parse(&text).expect("baseline json");
        let floor = base
            .f64_of("threads1_steps_per_sec")
            .expect("baseline threads1_steps_per_sec");
        let min_ok = 0.8 * floor;
        if s1 < min_ok {
            eprintln!(
                "BENCH REGRESSION: single-thread {s1:.3} steps/s is more than 20% below \
                 the committed baseline {floor:.3} (floor {min_ok:.3})"
            );
            std::process::exit(1);
        }
        println!(
            "   -> baseline gate ok: {s1:.3} steps/s >= 0.8 x {floor:.3}"
        );
    }
}
